//! Quickstart: layer-parallel training in ~30 lines of the Session API.
//!
//! Trains the morphological-classification preset with MGRIT layer-
//! parallelism and compares the result against exact serial training from
//! the same initialization — the paper's core accuracy claim in miniature.
//!
//! Run with:  cargo run --release --example quickstart [-- --workers N]
//!            (N > 1 runs the relaxation on the ThreadedMgrit backend)

use layertime::config::presets;
use layertime::coordinator::{Serial, Session, Task};
use layertime::model::{Init, ParamStore};
use layertime::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let workers = args.get_usize("workers", 1);

    // 1. pick a preset (paper Table 2/3 analogue) and shrink the run
    let mut rc = presets::mc_tiny();
    rc.model.n_enc_layers = 16;
    rc.train.steps = 80;
    rc.train.eval_every = 20;

    // 2. one shared initialization for a fair comparison
    let init = ParamStore::init(&rc.model, Init::Default, rc.train.seed);

    // 3. serial baseline (the Serial backend propagates exactly)
    let mut serial = Session::builder()
        .config(rc.clone())
        .task(Task::Tag)
        .params(init.deep_clone())
        .backend(Box::new(Serial))
        .build()?;
    let serial_report = serial.train()?;

    // 4. layer-parallel (MGRIT, cf=2, 2 levels, 2 fwd + 1 bwd iterations);
    //    --workers N>1 drives the relaxation over N threads, bitwise equal
    let mut lp = Session::builder()
        .config(rc)
        .task(Task::Tag)
        .params(init)
        .workers(workers)
        .build()?;
    let lp_report = lp.train()?;

    // 5. compare
    println!("backends: {} vs {}", serial.backend_name(), lp.backend_name());
    println!("step   serial-loss   layer-parallel-loss");
    for (a, b) in serial_report.curve.iter().zip(&lp_report.curve).step_by(10) {
        println!("{:>4}   {:>11.4}   {:>19.4}", a.step, a.loss, b.loss);
    }
    println!(
        "\nfinal val accuracy: serial {:.3} vs layer-parallel {:.3}",
        serial_report.final_metric, lp_report.final_metric
    );
    println!(
        "Φ evaluations: serial {} fwd / {} vjp; layer-parallel {} fwd / {} vjp",
        serial_report.phi_fwd, serial_report.phi_vjp, lp_report.phi_fwd, lp_report.phi_vjp
    );
    println!(
        "MGRIT hierarchies built: {} over {} solves (persistent per-session contexts)",
        lp.solve_core_builds(),
        2 * lp_report.curve.len()
    );
    println!("\n(the extra Φ evals are the price of the exposed parallelism: on P");
    println!(" devices the layer-parallel evals run concurrently — see");
    println!(" `cargo bench --bench fig6_speedup` for the modeled wall-clock.)");
    Ok(())
}
