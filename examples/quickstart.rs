//! Quickstart: layer-parallel training in ~30 lines.
//!
//! Trains the morphological-classification preset with MGRIT layer-
//! parallelism and compares the result against exact serial training from
//! the same initialization — the paper's core accuracy claim in miniature.
//!
//! Run with:  cargo run --release --example quickstart

use layertime::config::{presets, MgritConfig};
use layertime::coordinator::{Task, TrainRun};
use layertime::model::{Init, ParamStore};

fn main() -> anyhow::Result<()> {
    // 1. pick a preset (paper Table 2/3 analogue) and shrink the run
    let mut rc = presets::mc_tiny();
    rc.model.n_enc_layers = 16;
    rc.train.steps = 80;
    rc.train.eval_every = 20;

    // 2. one shared initialization for a fair comparison
    let init = ParamStore::init(&rc.model, Init::Default, rc.train.seed);

    // 3. serial baseline
    let mut serial_rc = rc.clone();
    serial_rc.mgrit = MgritConfig::serial();
    let mut serial = TrainRun::from_params(serial_rc, Task::Tag, init.deep_clone(), None)?;
    let serial_report = serial.train()?;

    // 4. layer-parallel (MGRIT, cf=2, 2 levels, 2 fwd + 1 bwd iterations)
    let mut lp = TrainRun::from_params(rc, Task::Tag, init, None)?;
    let lp_report = lp.train()?;

    // 5. compare
    println!("step   serial-loss   layer-parallel-loss");
    for (a, b) in serial_report.curve.iter().zip(&lp_report.curve).step_by(10) {
        println!("{:>4}   {:>11.4}   {:>19.4}", a.step, a.loss, b.loss);
    }
    println!(
        "\nfinal val accuracy: serial {:.3} vs layer-parallel {:.3}",
        serial_report.final_metric, lp_report.final_metric
    );
    println!(
        "Φ evaluations: serial {} fwd / {} vjp; layer-parallel {} fwd / {} vjp",
        serial_report.phi_fwd, serial_report.phi_vjp, lp_report.phi_fwd, lp_report.phi_vjp
    );
    println!("\n(the extra Φ evals are the price of the exposed parallelism: on P");
    println!(" devices the layer-parallel evals run concurrently — see");
    println!(" `cargo bench --bench fig6_speedup` for the modeled wall-clock.)");
    Ok(())
}
