//! Morphological classification at depth (paper Fig. 3 left): train the MC
//! task with an increasingly deep encoder and show that MGRIT layer-
//! parallel training matches serial validation accuracy while exposing
//! N/c_f-way parallelism.
//!
//! Run with:  cargo run --release --example morpho_tagging
//!            [-- --depth N] [--steps N] [--workers N]

use layertime::config::{presets, MgritConfig};
use layertime::coordinator::{Serial, Session, Task};
use layertime::mgrit::GridHierarchy;
use layertime::model::{Init, ParamStore};
use layertime::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let depth = args.get_usize("depth", 32);
    let steps = args.get_usize("steps", 100);
    let workers = args.get_usize("workers", 1);

    let mut rc = presets::mc_tiny();
    rc.model.n_enc_layers = depth;
    rc.mgrit = MgritConfig { cf: 2, levels: 2, fwd_iters: Some(2), bwd_iters: Some(1), fcf: true };
    rc.train.steps = steps;
    rc.train.eval_every = (steps / 5).max(1);
    rc.train.opt = layertime::config::OptKind::Adam;
    rc.train.lr = 3e-3;

    let grid = GridHierarchy::new(depth, rc.mgrit.cf, rc.mgrit.levels);
    println!(
        "MC task, {} encoder layers; MGRIT grid {:?}, relaxation exposes {}-way parallelism ({} worker(s))",
        depth,
        grid.steps,
        grid.relax_parallelism(0),
        workers.max(1)
    );

    let init = ParamStore::init(&rc.model, Init::Default, rc.train.seed);
    let mut serial = Session::builder()
        .config(rc.clone())
        .task(Task::Tag)
        .params(init.deep_clone())
        .backend(Box::new(Serial))
        .build()?;
    let s_rep = serial.train()?;
    let mut lp = Session::builder()
        .config(rc)
        .task(Task::Tag)
        .params(init)
        .workers(workers)
        .build()?;
    let p_rep = lp.train()?;

    println!("\n        validation accuracy");
    println!("step    serial   layer-parallel ({})", lp.backend_name());
    for (a, b) in s_rep.evals.iter().zip(&p_rep.evals) {
        println!("{:>5}   {:<6.3}   {:<6.3}", a.step, a.metric, b.metric);
    }
    println!(
        "\nfinal: serial {:.3} vs layer-parallel {:.3}  (Δ = {:+.3})",
        s_rep.final_metric,
        p_rep.final_metric,
        p_rep.final_metric - s_rep.final_metric
    );
    println!(
        "(solve contexts: {} MGRIT hierarchies built across the whole run)",
        lp.solve_core_builds()
    );
    Ok(())
}
