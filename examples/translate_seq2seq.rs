//! Encoder-decoder translation (the paper's novel contribution: a neural-
//! ODE formulation of the full encoder-decoder transformer, §3.1 eq. 2-3).
//!
//! Trains the MT preset on cipher-translation pairs with MGRIT over the
//! *stacked* state Z = [X, Y], comparing pure layer-parallel against the
//! parallel→serial switching scheme of Fig. 3 (right), and reports BLEU.
//!
//! Run with:  cargo run --release --example translate_seq2seq
//!            [-- --steps N] [--workers N]

use layertime::config::{presets, MgritConfig};
use layertime::coordinator::{Session, Task};
use layertime::model::{Init, ParamStore};
use layertime::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.get_usize("steps", 150);
    let workers = args.get_usize("workers", 1);

    let mut rc = presets::mt_small();
    rc.model.n_enc_layers = 6;
    rc.model.n_dec_layers = 6;
    // Table 3 MT row: cf=3, L=2, serial forward, 3 backward iterations
    rc.mgrit = MgritConfig { cf: 3, levels: 2, fwd_iters: None, bwd_iters: Some(3), fcf: true };
    rc.train.steps = steps;
    rc.train.eval_every = (steps / 6).max(1);
    rc.train.lr = 2e-3;
    rc.train.warmup = steps / 10;

    let init = ParamStore::init(&rc.model, Init::Default, rc.train.seed);

    // pure layer-parallel (no switching)
    let mut pure_rc = rc.clone();
    pure_rc.train.adaptive = false;
    let mut pure = Session::builder()
        .config(pure_rc)
        .task(Task::Translate)
        .params(init.deep_clone())
        .workers(workers)
        .build()?;
    let pure_rep = pure.train()?;

    // adaptive: parallel phase then switch to serial (Fig. 3 right, "2->1")
    let mut ada_rc = rc.clone();
    ada_rc.train.adaptive = true;
    ada_rc.train.probe_every = (steps / 5).max(5);
    let mut ada = Session::builder()
        .config(ada_rc)
        .task(Task::Translate)
        .params(init)
        .workers(workers)
        .build()?;
    let ada_rep = ada.train()?;

    println!("backend: {} ({} worker(s))", pure.backend_name(), workers.max(1));
    println!("step   pure-LP loss   adaptive loss");
    for (a, b) in pure_rep.curve.iter().zip(&ada_rep.curve).step_by((steps / 15).max(1)) {
        println!("{:>4}   {:>12.4}   {:>13.4}", a.step, a.loss, b.loss);
    }
    println!("\nvalidation BLEU-4 (teacher-forced greedy):");
    println!("  pure layer-parallel : {:.4}", pure_rep.final_metric);
    println!(
        "  adaptive (switch@{}) : {:.4}",
        ada_rep.switched_at.map(|s| s.to_string()).unwrap_or_else(|| "never".into()),
        ada_rep.final_metric
    );
    Ok(())
}
