//! End-to-end driver (DESIGN.md deliverable b/e2e): pre-train a GPT-style
//! decoder-only char-LM **through the full three-layer stack** — rust
//! coordinator → MGRIT → AOT/Pallas Φ on PJRT — with buffer layers
//! (Appendix B) and the §3.2.3 adaptive controller armed, then report the
//! loss curve, validation accuracy, and Φ-evaluation accounting.
//!
//! Requires artifacts:  make artifacts
//! Run with:            cargo run --release --example pretrain_charlm
//!                      [--steps N] [--layers N] [--workers N] [--no-xla]
//!
//! `--workers N` (N > 1) runs the MGRIT adjoint relaxation on the
//! ThreadedMgrit backend — bitwise identical losses, real OS threads.
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use std::sync::Arc;

use layertime::config::{presets, MgritConfig};
use layertime::coordinator::{Session, Task};
use layertime::runtime::XlaEngine;
use layertime::util::cli::Args;
use layertime::util::csv::CsvWriter;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.get_usize("steps", 200);
    let layers = args.get_usize("layers", 20);
    let workers = args.get_usize("workers", 1);
    let use_xla = !args.has_flag("no-xla");

    // GPT preset (paper Appendix B): 2+2 buffer layers, serial forward,
    // 1 MGRIT backward iteration, cf=4, AdamW.
    let mut rc = presets::gpt_small();
    rc.model.n_dec_layers = layers;
    rc.mgrit = MgritConfig { cf: 4, levels: 2, fwd_iters: None, bwd_iters: Some(1), fcf: true };
    rc.train.steps = steps;
    rc.train.eval_every = (steps / 8).max(1);
    rc.train.probe_every = (steps / 6).max(10);
    rc.train.adaptive = true;
    rc.train.lr = 3e-3;
    rc.train.warmup = steps / 10;

    let engine = if use_xla {
        let e = Arc::new(XlaEngine::load("artifacts")?);
        e.warmup()?; // compile all entry points up front
        println!("PJRT platform: {}", e.platform());
        Some(e)
    } else {
        None
    };

    println!(
        "pre-training char-LM: {} decoder layers ({}+{} serial buffers, dt=1/{}), {} steps, Φ on {}",
        rc.model.n_dec_layers,
        rc.model.buffer_open,
        rc.model.buffer_close,
        rc.model.parallel_layers(),
        steps,
        if use_xla { "XLA/PJRT (Pallas kernels)" } else { "rust reference" }
    );

    let mut run = Session::builder()
        .config(rc)
        .task(Task::Lm)
        .engine(engine)
        .workers(workers)
        .build()?;
    println!("backend: {} ({} worker(s))", run.backend_name(), workers.max(1));
    let t0 = std::time::Instant::now();
    let report = run.train()?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\nstep   loss     acc    serial  rho_bwd");
    for r in report.curve.iter().step_by((steps / 20).max(1)) {
        println!(
            "{:>4}   {:<7.4}  {:<5.3}  {:<6}  {}",
            r.step,
            r.loss,
            r.acc,
            r.serial,
            r.rho_bwd.map(|v| format!("{:.3}", v)).unwrap_or_else(|| "-".into())
        );
    }
    println!(
        "\nfinal loss {:.4} | val next-token accuracy {:.3} | wall {:.1}s ({:.2} s/step)",
        report.final_loss,
        report.final_metric,
        wall,
        wall / steps as f64
    );
    println!(
        "Φ evals: {} fwd, {} vjp{}",
        report.phi_fwd,
        report.phi_vjp,
        report
            .switched_at
            .map(|s| format!(" | adaptive switch to serial at step {}", s))
            .unwrap_or_default()
    );

    let mut w = CsvWriter::create("bench_out/pretrain_charlm.csv", &["step", "loss", "acc"])?;
    for r in &report.curve {
        w.row(&[r.step.to_string(), r.loss.to_string(), r.acc.to_string()])?;
    }
    w.flush()?;
    println!("curve written to bench_out/pretrain_charlm.csv");
    Ok(())
}
