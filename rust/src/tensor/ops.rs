//! Matrix / reduction kernels for the pure-Rust reference transformer.
//!
//! With `rust/vendor/xla` as an offline stub these kernels ARE the
//! production hot path, so they are written for throughput:
//!
//! * Slice-level `mm_into` / `mm_at_into` / `mm_bt_into` kernels write into
//!   caller-provided buffers (the buffer-reuse contract: `out` must have
//!   exactly `m*n` elements; with `acc = false` it is fully overwritten, so
//!   it need not be zeroed) — no per-call heap allocation.
//! * `mm_into` processes four output rows per pass so every row of `b` is
//!   streamed once per four rows of `a` (register/cache blocking), and
//!   `mm_at_into` batches four k-steps per pass over `out`.
//! * Inner loops are branch-free: the old `av == 0.0` skip is gone. It
//!   defeated autovectorization *and* was an IEEE-correctness bug — skipping
//!   a row dropped `0.0 * NaN = NaN` / `0.0 * inf = NaN` propagation. The
//!   property tests below pin kernel outputs against a naive triple loop.
//!
//! Numerical contract: `mm_into` and `mm_at_into` accumulate each output
//! element over `k` in ascending order with one rounding per term — bitwise
//! identical to the naive `i,j,k` triple loop. `mm_bt_into` runs its dot
//! products over eight partial lanes (a vectorizable reduction), which
//! reassociates the sum: results agree with the naive loop to relative
//! rounding error, and IEEE specials (NaN/inf) still propagate.
//!
//! # SIMD dispatch (`--features simd`)
//!
//! With the `simd` feature compiled in, `mm_into` / `mm_at_into` /
//! `mm_bt_into` / `softmax_rows` dispatch per call to the explicit-SIMD
//! kernels in [`super::simd`] when [`super::simd_active`] is true
//! (AVX2+FMA detected on x86_64, NEON on aarch64; probed once and cached).
//! The dispatched kernels keep this module's numerical contracts:
//! `mm_into` / `mm_at_into` stay **bitwise** identical to the naive
//! ascending-k triple loop (the SIMD lanes use separate mul/add roundings,
//! never FMA — incremental-decode parity depends on it), while `mm_bt_into`
//! and `softmax_rows` may reassociate/fuse within their existing
//! rounding-level contract (pinned against the scalar kernels by
//! `tests/simd_parity.rs`). The `*_scalar` variants below are the
//! always-scalar entry points those parity tests and the scalar-vs-simd
//! benches compare against; without the feature (or on unsupported hosts)
//! the public kernels *are* the scalar kernels.
//!
//! The Tensor-level wrappers (`matmul*`, `matmul*_into`) add shape checks;
//! the `*_into` forms are the hot-path entry points used by
//! [`crate::reference`].

use super::Tensor;

/// out (+)= a[m,k] @ b[k,n] (row-major slices).
///
/// Bitwise identical to the naive triple loop (ascending-k accumulation)
/// in both the scalar and SIMD paths.
pub fn mm_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32], acc: bool) {
    debug_assert_eq!(a.len(), m * k, "mm_into: a length");
    debug_assert_eq!(b.len(), k * n, "mm_into: b length");
    debug_assert_eq!(out.len(), m * n, "mm_into: out length");
    if !acc {
        out.fill(0.0);
    }
    if m == 0 || n == 0 {
        return;
    }
    #[cfg(feature = "simd")]
    if super::simd_active() {
        super::simd::mm_accum(a, b, m, k, n, out);
        return;
    }
    mm_accum_scalar(a, b, m, k, n, out);
}

/// Always-scalar `mm_into` (the SIMD parity/bench baseline).
pub fn mm_into_scalar(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
    acc: bool,
) {
    debug_assert_eq!(a.len(), m * k, "mm_into: a length");
    debug_assert_eq!(b.len(), k * n, "mm_into: b length");
    debug_assert_eq!(out.len(), m * n, "mm_into: out length");
    if !acc {
        out.fill(0.0);
    }
    if m == 0 || n == 0 {
        return;
    }
    mm_accum_scalar(a, b, m, k, n, out);
}

fn mm_accum_scalar(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    // Four output rows per pass: one streamed read of b serves four rows
    // of a, quadrupling arithmetic intensity over row-at-a-time.
    let mut blocks = out.chunks_exact_mut(4 * n);
    let mut i = 0;
    for oblock in blocks.by_ref() {
        let (o0, rest) = oblock.split_at_mut(n);
        let (o1, rest) = rest.split_at_mut(n);
        let (o2, o3) = rest.split_at_mut(n);
        let a0 = &a[i * k..(i + 1) * k];
        let a1 = &a[(i + 1) * k..(i + 2) * k];
        let a2 = &a[(i + 2) * k..(i + 3) * k];
        let a3 = &a[(i + 3) * k..(i + 4) * k];
        for kk in 0..k {
            let brow = &b[kk * n..(kk + 1) * n];
            let (v0, v1, v2, v3) = (a0[kk], a1[kk], a2[kk], a3[kk]);
            for j in 0..n {
                let bv = brow[j];
                o0[j] += v0 * bv;
                o1[j] += v1 * bv;
                o2[j] += v2 * bv;
                o3[j] += v3 * bv;
            }
        }
        i += 4;
    }
    for orow in blocks.into_remainder().chunks_exact_mut(n) {
        let arow = &a[i * k..(i + 1) * k];
        for (kk, &av) in arow.iter().enumerate() {
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
        i += 1;
    }
}

/// out (+)= aᵀ @ b where a is stored [k,m], b is [k,n] → out [m,n]
/// (weight-gradient helper).
///
/// Bitwise identical to the naive triple loop: the four-step unroll only
/// batches row loads — each output element still receives one rounded
/// addition per k term, in ascending k order.
pub fn mm_at_into(a: &[f32], b: &[f32], k: usize, m: usize, n: usize, out: &mut [f32], acc: bool) {
    debug_assert_eq!(a.len(), k * m, "mm_at_into: a length");
    debug_assert_eq!(b.len(), k * n, "mm_at_into: b length");
    debug_assert_eq!(out.len(), m * n, "mm_at_into: out length");
    if !acc {
        out.fill(0.0);
    }
    if m == 0 || n == 0 {
        return;
    }
    #[cfg(feature = "simd")]
    if super::simd_active() {
        super::simd::mm_at_accum(a, b, k, m, n, out);
        return;
    }
    mm_at_accum_scalar(a, b, k, m, n, out);
}

/// Always-scalar `mm_at_into` (the SIMD parity/bench baseline).
pub fn mm_at_into_scalar(
    a: &[f32],
    b: &[f32],
    k: usize,
    m: usize,
    n: usize,
    out: &mut [f32],
    acc: bool,
) {
    debug_assert_eq!(a.len(), k * m, "mm_at_into: a length");
    debug_assert_eq!(b.len(), k * n, "mm_at_into: b length");
    debug_assert_eq!(out.len(), m * n, "mm_at_into: out length");
    if !acc {
        out.fill(0.0);
    }
    if m == 0 || n == 0 {
        return;
    }
    mm_at_accum_scalar(a, b, k, m, n, out);
}

fn mm_at_accum_scalar(a: &[f32], b: &[f32], k: usize, m: usize, n: usize, out: &mut [f32]) {
    let mut kk = 0;
    while kk + 4 <= k {
        let a0 = &a[kk * m..(kk + 1) * m];
        let a1 = &a[(kk + 1) * m..(kk + 2) * m];
        let a2 = &a[(kk + 2) * m..(kk + 3) * m];
        let a3 = &a[(kk + 3) * m..(kk + 4) * m];
        let b0 = &b[kk * n..(kk + 1) * n];
        let b1 = &b[(kk + 1) * n..(kk + 2) * n];
        let b2 = &b[(kk + 2) * n..(kk + 3) * n];
        let b3 = &b[(kk + 3) * n..(kk + 4) * n];
        for i in 0..m {
            let (v0, v1, v2, v3) = (a0[i], a1[i], a2[i], a3[i]);
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                let mut o = orow[j];
                o += v0 * b0[j];
                o += v1 * b1[j];
                o += v2 * b2[j];
                o += v3 * b3[j];
                orow[j] = o;
            }
        }
        kk += 4;
    }
    while kk < k {
        let arow = &a[kk * m..(kk + 1) * m];
        let brow = &b[kk * n..(kk + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
        kk += 1;
    }
}

/// Eight-lane dot product (vectorizable reduction). Reassociates the sum
/// order; NaN/inf inputs still poison the result per IEEE semantics.
#[inline]
fn dot_lanes(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let split = x.len() - x.len() % 8;
    let (xh, xt) = x.split_at(split);
    let (yh, yt) = y.split_at(split);
    let mut lanes = [0.0f32; 8];
    for (xc, yc) in xh.chunks_exact(8).zip(yh.chunks_exact(8)) {
        for l in 0..8 {
            lanes[l] += xc[l] * yc[l];
        }
    }
    let mut tail = 0.0f32;
    for (xv, yv) in xt.iter().zip(yt) {
        tail += xv * yv;
    }
    let head = ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
        + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]));
    head + tail
}

/// out (+)= a @ bᵀ where a is [m,k], b is stored [n,k] → out [m,n]
/// (attention scores / input-gradient helper).
///
/// Reassociating kernel: the SIMD path packs eight b-rows into a
/// contiguous 32-byte-aligned panel and runs one FMA chain per element,
/// which stays within the eight-lane rounding/NaN-mask contract above.
pub fn mm_bt_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32], acc: bool) {
    debug_assert_eq!(a.len(), m * k, "mm_bt_into: a length");
    debug_assert_eq!(b.len(), n * k, "mm_bt_into: b length");
    debug_assert_eq!(out.len(), m * n, "mm_bt_into: out length");
    if !acc {
        out.fill(0.0);
    }
    if m == 0 || n == 0 {
        return;
    }
    #[cfg(feature = "simd")]
    if super::simd_active() {
        super::simd::mm_bt_accum(a, b, m, k, n, out);
        return;
    }
    mm_bt_accum_scalar(a, b, m, k, n, out);
}

/// Always-scalar `mm_bt_into` (the SIMD parity/bench baseline).
pub fn mm_bt_into_scalar(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
    acc: bool,
) {
    debug_assert_eq!(a.len(), m * k, "mm_bt_into: a length");
    debug_assert_eq!(b.len(), n * k, "mm_bt_into: b length");
    debug_assert_eq!(out.len(), m * n, "mm_bt_into: out length");
    if !acc {
        out.fill(0.0);
    }
    if m == 0 || n == 0 {
        return;
    }
    mm_bt_accum_scalar(a, b, m, k, n, out);
}

fn mm_bt_accum_scalar(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (j, o) in orow.iter_mut().enumerate() {
            *o += dot_lanes(arow, &b[j * k..(j + 1) * k]);
        }
    }
}

/// c[m,n] = a[m,k] @ b[k,n], writing into `out` (shape-checked).
pub fn matmul_into(a: &Tensor, b: &Tensor, out: &mut Tensor) {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul inner dim mismatch");
    assert_eq!(out.len(), m * n, "matmul out size mismatch");
    mm_into(a.data(), b.data(), m, k, n, out.data_mut(), false);
}

/// c[m,n] = a[m,k] @ b[k,n]
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul inner dim mismatch");
    let mut c = Tensor::zeros(&[m, n]);
    mm_into(a.data(), b.data(), m, k, n, c.data_mut(), false);
    c
}

/// c[m,n] = aᵀ[m,k] @ b[k,n] where a is stored [k,m], writing into `out`.
pub fn matmul_at_into(a: &Tensor, b: &Tensor, out: &mut Tensor) {
    let (k, m) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul_at inner dim mismatch");
    assert_eq!(out.len(), m * n, "matmul_at out size mismatch");
    mm_at_into(a.data(), b.data(), k, m, n, out.data_mut(), false);
}

/// c[m,n] = aᵀ[m,k] @ b[k,n]  where a is stored [k,m] (gradient helper).
pub fn matmul_at(a: &Tensor, b: &Tensor) -> Tensor {
    let (k, m) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul_at inner dim mismatch");
    let mut c = Tensor::zeros(&[m, n]);
    mm_at_into(a.data(), b.data(), k, m, n, c.data_mut(), false);
    c
}

/// c[m,n] = a[m,k] @ bᵀ[k,n] where b is stored [n,k], writing into `out`.
pub fn matmul_bt_into(a: &Tensor, b: &Tensor, out: &mut Tensor) {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (n, k2) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul_bt inner dim mismatch");
    assert_eq!(out.len(), m * n, "matmul_bt out size mismatch");
    mm_bt_into(a.data(), b.data(), m, k, n, out.data_mut(), false);
}

/// c[m,n] = a[m,k] @ bᵀ[k,n]  where b is stored [n,k] (gradient helper).
pub fn matmul_bt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (n, k2) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul_bt inner dim mismatch");
    let mut c = Tensor::zeros(&[m, n]);
    mm_bt_into(a.data(), b.data(), m, k, n, c.data_mut(), false);
    c
}

/// Row-wise softmax over the last axis of a [m,n] tensor (in place).
pub fn softmax_rows(x: &mut Tensor) {
    let n = *x.shape().last().expect("softmax needs rank >= 1");
    let rows = x.len() / n;
    let d = x.data_mut();
    for r in 0..rows {
        softmax_row(&mut d[r * n..(r + 1) * n]);
    }
}

/// Row softmax on a slice — the single softmax kernel behind
/// `softmax_rows` and the reference block's masked attention softmax.
///
/// A row's output bits depend only on that row's contents (never on the
/// row count or position), and trailing `exp(-inf) = 0` masked entries
/// are additive identities under the ascending sum — the two properties
/// incremental-decode parity rests on. The SIMD path keeps both: exact
/// max reduction, a polynomial exp whose scalar tail mirrors the vector
/// lanes bit for bit, a scalar ascending sum, and one rounding per
/// element in the final scale.
pub fn softmax_row(row: &mut [f32]) {
    #[cfg(feature = "simd")]
    if super::simd_active() {
        super::simd::softmax_row(row);
        return;
    }
    softmax_row_scalar(row);
}

/// Always-scalar row softmax (the SIMD parity/bench baseline).
pub fn softmax_row_scalar(row: &mut [f32]) {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in row.iter_mut() {
        *v *= inv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;
    use crate::util::rng::Rng;

    /// Reference oracle: the naive i,j,k triple loop, no special-casing.
    fn naive_mm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                for kk in 0..k {
                    c[i * n + j] += a[i * k + kk] * b[kk * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn matmul_small_known() {
        let a = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        let b = Tensor::from_vec(vec![1.0, 1.0, 1.0, 1.0], &[2, 2]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn prop_mm_bitwise_matches_naive_triple_loop() {
        // mm_into and mm_at_into keep the naive ascending-k accumulation
        // order, so they must agree with the oracle bit for bit — including
        // sizes that hit both the blocked body and the remainder paths.
        forall("mm-bitwise-naive", 40, |rng| {
            let (m, k, n) = (1 + rng.range(13), 1 + rng.range(13), 1 + rng.range(13));
            let a: Vec<f32> = rng.normal_vec(m * k, 1.0);
            let b: Vec<f32> = rng.normal_vec(k * n, 1.0);
            let want = naive_mm(&a, &b, m, k, n);

            let mut c = vec![0.0f32; m * n];
            mm_into(&a, &b, m, k, n, &mut c, false);
            assert_eq!(c, want, "mm_into m={} k={} n={}", m, k, n);

            // aᵀ stored [k,m]
            let mut at = vec![0.0; k * m];
            for i in 0..m {
                for j in 0..k {
                    at[j * m + i] = a[i * k + j];
                }
            }
            let mut c2 = vec![0.0f32; m * n];
            mm_at_into(&at, &b, k, m, n, &mut c2, false);
            assert_eq!(c2, want, "mm_at_into m={} k={} n={}", m, k, n);
        });
    }

    #[test]
    fn prop_mm_bt_matches_naive_up_to_rounding() {
        // mm_bt_into reassociates its dot products (eight lanes), so pin
        // it to the oracle with a relative tolerance instead of bitwise.
        forall("mm-bt-naive", 40, |rng| {
            let (m, k, n) = (1 + rng.range(13), 1 + rng.range(20), 1 + rng.range(13));
            let a: Vec<f32> = rng.normal_vec(m * k, 1.0);
            let b: Vec<f32> = rng.normal_vec(k * n, 1.0);
            let want = naive_mm(&a, &b, m, k, n);
            let mut bt = vec![0.0; n * k];
            for i in 0..k {
                for j in 0..n {
                    bt[j * k + i] = b[i * n + j];
                }
            }
            let mut c = vec![0.0f32; m * n];
            mm_bt_into(&a, &bt, m, k, n, &mut c, false);
            for (x, y) in c.iter().zip(&want) {
                assert!((x - y).abs() <= 1e-4 + 1e-4 * y.abs(), "{} vs {}", x, y);
            }
        });
    }

    #[test]
    fn prop_kernels_propagate_ieee_specials() {
        // Regression for the `av == 0.0` skip: 0.0 * NaN must poison the
        // output exactly where the naive triple loop says it does.
        forall("mm-ieee-nan", 25, |rng| {
            let (m, k, n) = (1 + rng.range(6), 2 + rng.range(6), 1 + rng.range(6));
            let mut a: Vec<f32> = rng.normal_vec(m * k, 1.0);
            let mut b: Vec<f32> = rng.normal_vec(k * n, 1.0);
            // sprinkle zeros into a and specials into b
            a[rng.range(m * k)] = 0.0;
            a[rng.range(m * k)] = 0.0;
            b[rng.range(k * n)] = f32::NAN;
            b[rng.range(k * n)] = f32::INFINITY;
            let want = naive_mm(&a, &b, m, k, n);

            let mut c = vec![0.0f32; m * n];
            mm_into(&a, &b, m, k, n, &mut c, false);
            let mut at = vec![0.0; k * m];
            for i in 0..m {
                for j in 0..k {
                    at[j * m + i] = a[i * k + j];
                }
            }
            let mut c_at = vec![0.0f32; m * n];
            mm_at_into(&at, &b, k, m, n, &mut c_at, false);
            let mut bt = vec![0.0; n * k];
            for i in 0..k {
                for j in 0..n {
                    bt[j * k + i] = b[i * n + j];
                }
            }
            let mut c_bt = vec![0.0f32; m * n];
            mm_bt_into(&a, &bt, m, k, n, &mut c_bt, false);

            for i in 0..m * n {
                assert_eq!(c[i].is_nan(), want[i].is_nan(), "mm NaN mask at {}", i);
                assert_eq!(c_at[i].is_nan(), want[i].is_nan(), "mm_at NaN mask at {}", i);
                assert_eq!(c_bt[i].is_nan(), want[i].is_nan(), "mm_bt NaN mask at {}", i);
            }
        });
    }

    #[test]
    fn zero_times_nan_poisons_output() {
        // The exact shape of the old bug: a == 0.0 used to skip the row.
        let a = Tensor::from_vec(vec![0.0], &[1, 1]);
        let b = Tensor::from_vec(vec![f32::NAN], &[1, 1]);
        assert!(matmul(&a, &b).data()[0].is_nan());
        assert!(matmul_at(&a, &b).data()[0].is_nan());
        assert!(matmul_bt(&a, &b).data()[0].is_nan());
    }

    #[test]
    fn into_variants_accumulate_and_overwrite() {
        let a = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        let b = Tensor::from_vec(vec![1.0, 0.0, 0.0, 1.0], &[2, 2]);
        // acc = false fully overwrites garbage in out
        let mut out = Tensor::from_vec(vec![9.0; 4], &[2, 2]);
        matmul_into(&a, &b, &mut out);
        assert_eq!(out.data(), a.data());
        // acc = true adds on top
        let mut c = vec![1.0f32; 4];
        mm_into(a.data(), b.data(), 2, 2, 2, &mut c, true);
        assert_eq!(c, vec![2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn prop_transpose_variants_agree() {
        forall("matmul-transpose", 30, |rng| {
            let (m, k, n) = (1 + rng.range(6), 1 + rng.range(6), 1 + rng.range(6));
            let a = Tensor::randn(rng, &[m, k], 1.0);
            let b = Tensor::randn(rng, &[k, n], 1.0);
            let c = matmul(&a, &b);

            // a stored transposed
            let mut at = vec![0.0; m * k];
            for i in 0..m {
                for j in 0..k {
                    at[j * m + i] = a.data()[i * k + j];
                }
            }
            let c2 = matmul_at(&Tensor::from_vec(at, &[k, m]), &b);
            assert!(c.allclose(&c2, 1e-4, 1e-4));

            // b stored transposed
            let mut bt = vec![0.0; k * n];
            for i in 0..k {
                for j in 0..n {
                    bt[j * k + i] = b.data()[i * n + j];
                }
            }
            let c3 = matmul_bt(&a, &Tensor::from_vec(bt, &[n, k]));
            assert!(c.allclose(&c3, 1e-4, 1e-4));
        });
    }

    #[test]
    fn prop_matmul_associates_with_identity() {
        forall("matmul-identity", 20, |rng| {
            let n = 1 + rng.range(8);
            let mut eye = Tensor::zeros(&[n, n]);
            for i in 0..n {
                eye.data_mut()[i * n + i] = 1.0;
            }
            let a = Tensor::randn(rng, &[n, n], 1.0);
            assert!(matmul(&a, &eye).allclose(&a, 1e-6, 1e-6));
            assert!(matmul(&eye, &a).allclose(&a, 1e-6, 1e-6));
        });
    }

    #[test]
    fn softmax_rows_normalizes() {
        let mut rng = Rng::new(1);
        let mut x = Tensor::randn(&mut rng, &[5, 7], 3.0);
        softmax_rows(&mut x);
        for r in 0..5 {
            let s: f32 = x.data()[r * 7..(r + 1) * 7].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(x.data()[r * 7..(r + 1) * 7].iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn softmax_handles_large_logits() {
        let mut x = Tensor::from_vec(vec![1000.0, 1001.0], &[1, 2]);
        softmax_rows(&mut x);
        assert!((x.data()[0] + x.data()[1] - 1.0).abs() < 1e-6);
        assert!(x.data()[1] > x.data()[0]);
    }
}
