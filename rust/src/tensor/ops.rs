//! Matrix / reduction kernels for the pure-Rust reference transformer.
//!
//! These are deliberately simple row-major loops (with a k-blocked inner
//! loop for cache friendliness); the *production* hot path runs in XLA via
//! the AOT artifacts — these ops exist so algorithms are testable without
//! artifacts and to power the Lipschitz/analysis tooling.

use super::Tensor;

/// c[m,n] = a[m,k] @ b[k,n]
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul inner dim mismatch");
    let mut c = vec![0.0f32; m * n];
    let ad = a.data();
    let bd = b.data();
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &bd[kk * n..(kk + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                *cv += av * bv;
            }
        }
    }
    Tensor::from_vec(c, &[m, n])
}

/// c[m,n] = aᵀ[m,k] @ b[k,n]  where a is stored [k,m] (gradient helper).
pub fn matmul_at(a: &Tensor, b: &Tensor) -> Tensor {
    let (k, m) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul_at inner dim mismatch");
    let mut c = vec![0.0f32; m * n];
    let ad = a.data();
    let bd = b.data();
    for kk in 0..k {
        let arow = &ad[kk * m..(kk + 1) * m];
        let brow = &bd[kk * n..(kk + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                *cv += av * bv;
            }
        }
    }
    Tensor::from_vec(c, &[m, n])
}

/// c[m,n] = a[m,k] @ bᵀ[k,n]  where b is stored [n,k] (gradient helper).
pub fn matmul_bt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (n, k2) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul_bt inner dim mismatch");
    let mut c = vec![0.0f32; m * n];
    let ad = a.data();
    let bd = b.data();
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &bd[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += arow[kk] * brow[kk];
            }
            c[i * n + j] = acc;
        }
    }
    Tensor::from_vec(c, &[m, n])
}

/// Row-wise softmax over the last axis of a [m,n] tensor (in place).
pub fn softmax_rows(x: &mut Tensor) {
    let n = *x.shape().last().expect("softmax needs rank >= 1");
    let rows = x.len() / n;
    let d = x.data_mut();
    for r in 0..rows {
        let row = &mut d[r * n..(r + 1) * n];
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;
    use crate::util::rng::Rng;

    #[test]
    fn matmul_small_known() {
        let a = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        let b = Tensor::from_vec(vec![1.0, 1.0, 1.0, 1.0], &[2, 2]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn prop_transpose_variants_agree() {
        forall("matmul-transpose", 30, |rng| {
            let (m, k, n) = (1 + rng.range(6), 1 + rng.range(6), 1 + rng.range(6));
            let a = Tensor::randn(rng, &[m, k], 1.0);
            let b = Tensor::randn(rng, &[k, n], 1.0);
            let c = matmul(&a, &b);

            // a stored transposed
            let mut at = vec![0.0; m * k];
            for i in 0..m {
                for j in 0..k {
                    at[j * m + i] = a.data()[i * k + j];
                }
            }
            let c2 = matmul_at(&Tensor::from_vec(at, &[k, m]), &b);
            assert!(c.allclose(&c2, 1e-4, 1e-4));

            // b stored transposed
            let mut bt = vec![0.0; k * n];
            for i in 0..k {
                for j in 0..n {
                    bt[j * k + i] = b.data()[i * n + j];
                }
            }
            let c3 = matmul_bt(&a, &Tensor::from_vec(bt, &[n, k]));
            assert!(c.allclose(&c3, 1e-4, 1e-4));
        });
    }

    #[test]
    fn prop_matmul_associates_with_identity() {
        forall("matmul-identity", 20, |rng| {
            let n = 1 + rng.range(8);
            let mut eye = Tensor::zeros(&[n, n]);
            for i in 0..n {
                eye.data_mut()[i * n + i] = 1.0;
            }
            let a = Tensor::randn(rng, &[n, n], 1.0);
            assert!(matmul(&a, &eye).allclose(&a, 1e-6, 1e-6));
            assert!(matmul(&eye, &a).allclose(&a, 1e-6, 1e-6));
        });
    }

    #[test]
    fn softmax_rows_normalizes() {
        let mut rng = Rng::new(1);
        let mut x = Tensor::randn(&mut rng, &[5, 7], 3.0);
        softmax_rows(&mut x);
        for r in 0..5 {
            let s: f32 = x.data()[r * 7..(r + 1) * 7].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(x.data()[r * 7..(r + 1) * 7].iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn softmax_handles_large_logits() {
        let mut x = Tensor::from_vec(vec![1000.0, 1001.0], &[1, 2]);
        softmax_rows(&mut x);
        assert!((x.data()[0] + x.data()[1] - 1.0).abs() < 1e-6);
        assert!(x.data()[1] > x.data()[0]);
    }
}
