//! Host tensor substrate: a dense f32 tensor with the algebra the MGRIT
//! engine needs (axpy/scale/norm), plus the small matmuls and reductions
//! the pure-Rust reference transformer is built from.

mod ops;
mod tensor;

pub use ops::{
    matmul, matmul_at, matmul_at_into, matmul_bt, matmul_bt_into, matmul_into, mm_at_into,
    mm_bt_into, mm_into, softmax_rows,
};
pub use tensor::Tensor;
