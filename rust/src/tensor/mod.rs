//! Host tensor substrate: a dense f32 tensor with the algebra the MGRIT
//! engine needs (axpy/scale/norm), plus the small matmuls and reductions
//! the pure-Rust reference transformer is built from.
//!
//! Backing stores ([`Tensor`], the [`crate::reference::Scratch`] arena)
//! are [`AlignedVec`]s — 32-byte-aligned so SIMD `f32x8` loads from
//! buffer starts never split a cache line. With `--features simd` the
//! hot kernels (`mm_into` / `mm_at_into` / `mm_bt_into` / `softmax_row`)
//! dispatch at runtime to the explicit-SIMD implementations in [`simd`]
//! (AVX2+FMA on x86_64, NEON on aarch64); everywhere else they are the
//! scalar kernels. See `ops.rs` for the numerical contracts.

mod aligned;
mod ops;
#[cfg(feature = "simd")]
pub(crate) mod simd;
mod tensor;

pub use aligned::AlignedVec;
pub use ops::{
    matmul, matmul_at, matmul_at_into, matmul_bt, matmul_bt_into, matmul_into, mm_at_into,
    mm_at_into_scalar, mm_bt_into, mm_bt_into_scalar, mm_into, mm_into_scalar, softmax_row,
    softmax_row_scalar, softmax_rows,
};
pub use tensor::Tensor;

/// True when the runtime-dispatched SIMD kernels are in use: the `simd`
/// feature is compiled in, the host supports them (AVX2+FMA / NEON), and
/// [`set_force_scalar`] has not disabled them.
#[cfg(feature = "simd")]
pub fn simd_active() -> bool {
    simd::simd_active()
}

/// Without `--features simd` the kernels are always scalar.
#[cfg(not(feature = "simd"))]
pub fn simd_active() -> bool {
    false
}

/// Force the scalar kernels even when SIMD is compiled in and supported
/// (scalar-vs-simd benches, parity tests). No-op without the feature.
#[cfg(feature = "simd")]
pub fn set_force_scalar(on: bool) {
    simd::set_force_scalar(on);
}

/// No-op without `--features simd` (the kernels are already scalar).
#[cfg(not(feature = "simd"))]
pub fn set_force_scalar(_on: bool) {}
