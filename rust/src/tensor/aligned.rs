//! 32-byte-aligned f32 buffer for the SIMD kernel layer.
//!
//! [`AlignedVec`] is a growable f32 buffer whose backing store is always
//! aligned to 32 bytes (one AVX2 `f32x8` register / half a cache line), so
//! eight-lane loads from the start of a buffer never split a cache line.
//! The SIMD kernels use unaligned load instructions throughout — alignment
//! is a performance property, not a safety requirement — which keeps every
//! kernel correct on arbitrary row offsets while the common case (buffer
//! starts, packed panels) stays aligned.
//!
//! It is the backing store of [`crate::reference::Scratch`] pool buffers
//! and [`crate::tensor::Tensor`], and of the per-thread packing panel used
//! by the SIMD `mm_bt` kernel. The implementation avoids manual
//! allocation: storage is a `Vec` of `#[repr(C, align(32))]` eight-float
//! chunks, so capacity reuse, growth, and deallocation all inherit `Vec`'s
//! (audited) behavior. `Deref<Target = [f32]>` lets every existing
//! slice-shaped call site keep working unchanged.

use std::fmt;
use std::ops::{Deref, DerefMut};

/// One 8-lane f32 register worth of storage, 32-byte aligned.
#[derive(Clone, Copy)]
#[repr(C, align(32))]
struct Chunk([f32; 8]);

const ZERO_CHUNK: Chunk = Chunk([0.0; 8]);

/// Growable f32 buffer with a 32-byte-aligned backing store.
///
/// Semantically a `Vec<f32>` restricted to the operations the kernel layer
/// needs; `len` is in f32 elements and need not be a multiple of 8 (the
/// backing store rounds up internally).
#[derive(Default)]
pub struct AlignedVec {
    chunks: Vec<Chunk>,
    len: usize,
}

impl AlignedVec {
    /// Empty buffer (does not allocate).
    pub const fn new() -> AlignedVec {
        AlignedVec { chunks: Vec::new(), len: 0 }
    }

    /// Buffer copied from a slice.
    pub fn from_slice(src: &[f32]) -> AlignedVec {
        let mut v = AlignedVec::new();
        v.resize_zeroed(src.len());
        v.copy_from_slice(src);
        v
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Capacity in f32 elements (always a multiple of 8).
    pub fn capacity(&self) -> usize {
        self.chunks.capacity() * 8
    }

    /// Resize to `len` elements, all zero — `Vec::clear` +
    /// `resize(len, 0.0)` semantics. Reuses capacity; only grows the
    /// backing store when `len` exceeds it.
    pub fn resize_zeroed(&mut self, len: usize) {
        let nch = (len + 7) / 8; // usize::div_ceil needs Rust 1.73; crate pins 1.70
        if self.chunks.len() < nch {
            self.chunks.resize(nch, ZERO_CHUNK);
        }
        self.chunks[..nch].fill(ZERO_CHUNK);
        self.len = len;
        self.debug_check_alignment();
    }

    /// Resize to `len` elements preserving the prefix — `Vec::truncate` /
    /// `Vec::resize(len, 0.0)` semantics: shrinking keeps the first `len`
    /// elements, growing zero-fills the appended tail. (The tail must be
    /// zeroed explicitly: the chunked backing store can hold stale data
    /// beyond a previous logical length.)
    pub fn resize_preserve(&mut self, len: usize) {
        let old = self.len;
        let nch = (len + 7) / 8; // usize::div_ceil needs Rust 1.73; crate pins 1.70
        if self.chunks.len() < nch {
            self.chunks.resize(nch, ZERO_CHUNK);
        }
        if len > old {
            self.storage_mut()[old..len].fill(0.0);
        }
        self.len = len;
        self.debug_check_alignment();
    }

    /// Copy out into a plain `Vec<f32>` (test/serialization paths).
    pub fn to_vec(&self) -> Vec<f32> {
        self.as_slice().to_vec()
    }

    pub fn as_slice(&self) -> &[f32] {
        // Sound: `chunks` owns `chunks.len() * 8 >= self.len` initialized,
        // contiguous f32s starting at a 32-byte-aligned address.
        unsafe { std::slice::from_raw_parts(self.chunks.as_ptr().cast::<f32>(), self.len) }
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        unsafe { std::slice::from_raw_parts_mut(self.chunks.as_mut_ptr().cast::<f32>(), self.len) }
    }

    /// The full chunk-rounded storage (may extend past `len`).
    fn storage_mut(&mut self) -> &mut [f32] {
        let n = self.chunks.len() * 8;
        unsafe { std::slice::from_raw_parts_mut(self.chunks.as_mut_ptr().cast::<f32>(), n) }
    }

    #[inline]
    fn debug_check_alignment(&self) {
        debug_assert_eq!(
            self.chunks.as_ptr() as usize % 32,
            0,
            "AlignedVec backing store must be 32-byte aligned"
        );
    }
}

impl Deref for AlignedVec {
    type Target = [f32];
    #[inline]
    fn deref(&self) -> &[f32] {
        self.as_slice()
    }
}

impl DerefMut for AlignedVec {
    #[inline]
    fn deref_mut(&mut self) -> &mut [f32] {
        self.as_mut_slice()
    }
}

impl Clone for AlignedVec {
    fn clone(&self) -> AlignedVec {
        AlignedVec::from_slice(self)
    }
}

impl PartialEq for AlignedVec {
    fn eq(&self, other: &AlignedVec) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl fmt::Debug for AlignedVec {
    /// Debug-print as the logical slice (the chunked store is an
    /// implementation detail).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self.as_slice(), f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_aligned_and_stays_aligned_across_growth() {
        let mut v = AlignedVec::new();
        for len in [1usize, 7, 8, 9, 31, 32, 33, 1000] {
            v.resize_zeroed(len);
            assert_eq!(v.len(), len);
            assert_eq!(v.as_ptr() as usize % 32, 0, "len={}", len);
            assert!(v.iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn resize_zeroed_clears_previous_contents() {
        let mut v = AlignedVec::new();
        v.resize_zeroed(10);
        v.iter_mut().for_each(|x| *x = 5.0);
        v.resize_zeroed(6);
        assert_eq!(&v[..], &[0.0; 6]);
        // growth back within the old chunk footprint is zeroed too
        v.resize_zeroed(10);
        assert_eq!(&v[..], &[0.0; 10]);
    }

    #[test]
    fn resize_preserve_matches_vec_truncate_then_resize() {
        let mut v = AlignedVec::new();
        v.resize_zeroed(8);
        v.iter_mut().for_each(|x| *x = 3.0);
        v.resize_preserve(4);
        assert_eq!(&v[..], &[3.0; 4]);
        // grow: prefix retained, tail zeroed even though the chunk still
        // holds stale 3.0s past the old logical length
        v.resize_preserve(6);
        assert_eq!(&v[..], &[3.0, 3.0, 3.0, 3.0, 0.0, 0.0]);
    }

    #[test]
    fn from_slice_round_trips() {
        let data = [1.0f32, 2.0, 3.0, 4.0, 5.0];
        let v = AlignedVec::from_slice(&data);
        assert_eq!(v.to_vec(), data.to_vec());
        assert_eq!(v.clone(), v);
    }

    #[test]
    fn capacity_is_reused_not_reallocated() {
        let mut v = AlignedVec::new();
        v.resize_zeroed(64);
        let ptr = v.as_ptr();
        v.resize_zeroed(8);
        v.resize_preserve(64);
        assert_eq!(v.as_ptr(), ptr, "shrink/regrow within capacity must not reallocate");
    }
}
