//! Dense row-major f32 tensor.

use super::AlignedVec;
use std::fmt;

/// Dense f32 tensor with explicit shape; the state/adjoint type flowing
/// through the MGRIT engine and the PJRT runtime boundary.
///
/// The backing store is 32-byte aligned ([`AlignedVec`]) so the SIMD
/// kernels' eight-lane loads from tensor starts never split a cache line.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    data: AlignedVec,
    shape: Vec<usize>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}(", self.shape)?;
        let n = self.data.len().min(6);
        for (i, v) in self.data[..n].iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{:.4}", v)?;
        }
        if self.data.len() > n {
            write!(f, ", …")?;
        }
        write!(f, ")")
    }
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize]) -> Tensor {
        let mut data = AlignedVec::new();
        data.resize_zeroed(shape.iter().product());
        Tensor { data, shape: shape.to_vec() }
    }

    /// Construct from data, validating the element count.
    pub fn from_vec(data: Vec<f32>, shape: &[usize]) -> Tensor {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/data mismatch");
        Tensor { data: AlignedVec::from_slice(&data), shape: shape.to_vec() }
    }

    /// Scalar (rank-0) tensor.
    pub fn scalar(v: f32) -> Tensor {
        Tensor { data: AlignedVec::from_slice(&[v]), shape: vec![] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data.to_vec()
    }

    /// First element (for scalar outputs).
    pub fn item(&self) -> f32 {
        self.data[0]
    }

    /// Reinterpret with a new shape of equal element count.
    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(self.len(), shape.iter().product::<usize>(), "reshape count mismatch");
        self.shape = shape.to_vec();
        self
    }

    /// self += alpha * other  (the MGRIT correction/residual primitive).
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        debug_assert_eq!(self.shape, other.shape, "axpy shape mismatch");
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += alpha * b;
        }
    }

    /// self *= alpha.
    pub fn scale(&mut self, alpha: f32) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }

    /// Element-wise difference.
    pub fn sub(&self, other: &Tensor) -> Tensor {
        debug_assert_eq!(self.shape, other.shape, "sub shape mismatch");
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(other.data.iter()) {
            *a -= b;
        }
        out
    }

    /// Element-wise sum.
    pub fn add(&self, other: &Tensor) -> Tensor {
        debug_assert_eq!(self.shape, other.shape, "add shape mismatch");
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(other.data.iter()) {
            *a += b;
        }
        out
    }

    /// Euclidean norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// ‖self − other‖.
    pub fn dist(&self, other: &Tensor) -> f32 {
        debug_assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt()
    }

    /// Dot product (flattened).
    pub fn dot(&self, other: &Tensor) -> f32 {
        debug_assert_eq!(self.len(), other.len());
        self.data.iter().zip(other.data.iter()).map(|(a, b)| a * b).sum()
    }

    /// Max |a-b| over elements.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Mixed relative/absolute closeness (numpy-style).
    pub fn allclose(&self, other: &Tensor, rtol: f32, atol: f32) -> bool {
        self.shape == other.shape
            && self
                .data
                .iter()
                .zip(other.data.iter())
                .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }

    /// Gaussian-filled tensor.
    pub fn randn(rng: &mut crate::util::rng::Rng, shape: &[usize], std: f32) -> Tensor {
        Tensor::from_vec(rng.normal_vec(shape.iter().product(), std), shape)
    }

    /// Fill with zeros in place (buffer reuse on the hot path).
    pub fn fill_zero(&mut self) {
        self.data.iter_mut().for_each(|x| *x = 0.0);
    }

    /// Copy contents from another tensor of identical shape.
    pub fn copy_from(&mut self, other: &Tensor) {
        debug_assert_eq!(self.shape, other.shape);
        self.data.copy_from_slice(&other.data);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    #[test]
    fn construct_and_reshape() {
        let t = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        assert_eq!(t.shape(), &[2, 2]);
        let t = t.reshape(&[4]);
        assert_eq!(t.shape(), &[4]);
    }

    #[test]
    #[should_panic]
    fn reshape_rejects_bad_count() {
        Tensor::zeros(&[2, 2]).reshape(&[3]);
    }

    #[test]
    fn axpy_scale_norm() {
        let mut a = Tensor::from_vec(vec![1.0, 2.0], &[2]);
        let b = Tensor::from_vec(vec![3.0, 4.0], &[2]);
        a.axpy(2.0, &b);
        assert_eq!(a.data(), &[7.0, 10.0]);
        a.scale(0.5);
        assert_eq!(a.data(), &[3.5, 5.0]);
        assert!((Tensor::from_vec(vec![3.0, 4.0], &[2]).norm() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn allclose_tolerances() {
        let a = Tensor::from_vec(vec![1.0, 100.0], &[2]);
        let b = Tensor::from_vec(vec![1.0 + 1e-6, 100.0 + 1e-4], &[2]);
        assert!(a.allclose(&b, 1e-5, 1e-5));
        let c = Tensor::from_vec(vec![1.1, 100.0], &[2]);
        assert!(!a.allclose(&c, 1e-5, 1e-5));
    }

    #[test]
    fn prop_axpy_linear() {
        forall("axpy-linear", 50, |rng| {
            let n = 1 + rng.range(32);
            let x = Tensor::randn(rng, &[n], 1.0);
            let y = Tensor::randn(rng, &[n], 1.0);
            let alpha = rng.normal();
            // (y + a x) - y == a x
            let mut z = y.clone();
            z.axpy(alpha, &x);
            let d = z.sub(&y);
            let mut ax = x.clone();
            ax.scale(alpha);
            assert!(d.allclose(&ax, 1e-5, 1e-5));
        });
    }

    #[test]
    fn prop_norm_triangle_inequality() {
        forall("norm-triangle", 50, |rng| {
            let n = 1 + rng.range(16);
            let a = Tensor::randn(rng, &[n], 1.0);
            let b = Tensor::randn(rng, &[n], 1.0);
            assert!(a.add(&b).norm() <= a.norm() + b.norm() + 1e-4);
        });
    }

    #[test]
    fn randn_respects_std() {
        let mut rng = crate::util::rng::Rng::new(0);
        let t = Tensor::randn(&mut rng, &[10_000], 0.5);
        let mean = t.data().iter().sum::<f32>() / t.len() as f32;
        let var = t.data().iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / t.len() as f32;
        assert!((var.sqrt() - 0.5).abs() < 0.02);
    }
}
