//! Explicit SIMD kernels (`--features simd`): AVX2/FMA on x86_64, NEON on
//! aarch64, runtime-detected with the scalar kernels in
//! [`super::ops`] as the always-available fallback.
//!
//! # Dispatch
//!
//! CPU capability is probed **once** (`is_x86_feature_detected!` cached in
//! a [`OnceLock`]) — the hot loop never re-runs cpuid. Hosts that are
//! neither AVX2-x86_64 nor aarch64 silently report [`simd_active`] `==
//! false` and take the scalar path; the feature flag never fails to
//! compile. [`set_force_scalar`] is a runtime kill switch used by the
//! parity tests and `perf_hotpath` to produce scalar-vs-simd rows from one
//! process.
//!
//! # Numerical contracts (see `tensor/ops.rs` and the README)
//!
//! Two classes of kernel, matching the scalar layer's contracts:
//!
//! * **Bitwise** (`mm_accum`, `mm_at_accum`): vector lanes accumulate each
//!   output element over `k` in ascending order with *separate* mul and
//!   add roundings — never FMA — so every element is bit-identical to the
//!   scalar/naive triple loop regardless of how rows and columns fall into
//!   register tiles. Incremental-decode parity (`tests/decode_cache.rs`)
//!   rests on this.
//! * **Reassociated** (`mm_bt_accum`, `softmax_row`, `ln_row`,
//!   `gelu_row`): free to fuse and regroup, pinned to the scalar kernels
//!   by NaN-mask + bounded-ulp parity (`tests/simd_parity.rs`). Their one
//!   hard invariant is *shape independence*: an element's bits depend only
//!   on its own row/contraction inputs, never on row count, row length, or
//!   tile position. `mm_bt_accum` therefore uses a single FMA chain per
//!   element (packed eight-column panels; scalar `f32::mul_add` chains —
//!   the same fused op — on remainder columns), and the transcendental
//!   kernels evaluate vector-lane and scalar-tail elements through
//!   *mirrored* polynomial code (`exp_v`/`exp_s`), so cached single-row
//!   decode reproduces full-board rows bit for bit under SIMD too.
//!
//! # Cache-aware layout
//!
//! `mm_bt_accum` contracts along `k` with `b` stored row-major `[n, k]`:
//! the scalar kernel streams `b` rows per output element, but eight-lane
//! code would need a gather. Instead each eight-column tile of `b` is
//! packed once into a 32-byte-aligned `[k, 8]` panel (a per-thread
//! [`AlignedVec`] that stabilizes after warmup — zero steady-state
//! allocations, audited by `tests/alloc_audit.rs`), and all `m` rows
//! stream that panel contiguously.

#![allow(clippy::too_many_arguments, clippy::excessive_precision)]

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

use super::aligned::AlignedVec;

static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);
static HAVE_SIMD: OnceLock<bool> = OnceLock::new();

/// One-time CPU capability probe (cached so hot loops never re-probe).
fn have_simd() -> bool {
    *HAVE_SIMD.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
        }
        #[cfg(target_arch = "aarch64")]
        {
            true // NEON is baseline on every aarch64 std target
        }
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        {
            false
        }
    })
}

/// True when the SIMD kernels will actually run: the `simd` feature is
/// compiled in, the CPU supports AVX2+FMA (or is aarch64/NEON), and the
/// force-scalar override is off.
#[inline]
pub fn simd_active() -> bool {
    have_simd() && !FORCE_SCALAR.load(Ordering::Relaxed)
}

/// Runtime kill switch: `set_force_scalar(true)` routes every dispatched
/// kernel to the scalar path (for A/B benches and parity tests).
pub fn set_force_scalar(on: bool) {
    FORCE_SCALAR.store(on, Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// eight-lane vector abstraction
// ---------------------------------------------------------------------------

/// Eight f32 lanes. Implementations are thin intrinsic wrappers; the
/// kernels below are generic over this trait and monomorphized inside
/// per-arch `#[target_feature]` entry points so everything inlines.
///
/// Safety: all methods require the implementation's CPU features to be
/// present (guaranteed by dispatching through [`simd_active`]).
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
trait V8: Copy {
    unsafe fn splat(v: f32) -> Self;
    unsafe fn load(p: *const f32) -> Self;
    unsafe fn store(self, p: *mut f32);
    unsafe fn add(self, o: Self) -> Self;
    unsafe fn sub(self, o: Self) -> Self;
    unsafe fn mul(self, o: Self) -> Self;
    unsafe fn div(self, o: Self) -> Self;
    /// Fused `self * m + acc` (single rounding).
    unsafe fn fma(self, m: Self, acc: Self) -> Self;
    unsafe fn min(self, o: Self) -> Self;
    unsafe fn max(self, o: Self) -> Self;
    unsafe fn floor(self) -> Self;
    /// Per lane: `if self < bound { a } else { b }` (false for NaN).
    unsafe fn blend_lt(self, bound: Self, a: Self, b: Self) -> Self;
    /// Per lane: `if self.is_nan() { a } else { b }`.
    unsafe fn blend_nan(self, a: Self, b: Self) -> Self;
    /// `2^self` for integral `self` in `[-126, 127]` (exponent-bit trick).
    unsafe fn pow2i(self) -> Self;
    unsafe fn to_array(self) -> [f32; 8];
}

#[cfg(target_arch = "x86_64")]
mod lanes {
    use super::V8;
    use std::arch::x86_64::*;

    #[derive(Clone, Copy)]
    pub(super) struct F32x8(__m256);

    impl V8 for F32x8 {
        #[inline(always)]
        unsafe fn splat(v: f32) -> Self {
            F32x8(_mm256_set1_ps(v))
        }
        #[inline(always)]
        unsafe fn load(p: *const f32) -> Self {
            F32x8(_mm256_loadu_ps(p))
        }
        #[inline(always)]
        unsafe fn store(self, p: *mut f32) {
            _mm256_storeu_ps(p, self.0)
        }
        #[inline(always)]
        unsafe fn add(self, o: Self) -> Self {
            F32x8(_mm256_add_ps(self.0, o.0))
        }
        #[inline(always)]
        unsafe fn sub(self, o: Self) -> Self {
            F32x8(_mm256_sub_ps(self.0, o.0))
        }
        #[inline(always)]
        unsafe fn mul(self, o: Self) -> Self {
            F32x8(_mm256_mul_ps(self.0, o.0))
        }
        #[inline(always)]
        unsafe fn div(self, o: Self) -> Self {
            F32x8(_mm256_div_ps(self.0, o.0))
        }
        #[inline(always)]
        unsafe fn fma(self, m: Self, acc: Self) -> Self {
            F32x8(_mm256_fmadd_ps(self.0, m.0, acc.0))
        }
        #[inline(always)]
        unsafe fn min(self, o: Self) -> Self {
            F32x8(_mm256_min_ps(self.0, o.0))
        }
        #[inline(always)]
        unsafe fn max(self, o: Self) -> Self {
            F32x8(_mm256_max_ps(self.0, o.0))
        }
        #[inline(always)]
        unsafe fn floor(self) -> Self {
            F32x8(_mm256_floor_ps(self.0))
        }
        #[inline(always)]
        unsafe fn blend_lt(self, bound: Self, a: Self, b: Self) -> Self {
            let mask = _mm256_cmp_ps::<_CMP_LT_OQ>(self.0, bound.0);
            F32x8(_mm256_blendv_ps(b.0, a.0, mask))
        }
        #[inline(always)]
        unsafe fn blend_nan(self, a: Self, b: Self) -> Self {
            let mask = _mm256_cmp_ps::<_CMP_UNORD_Q>(self.0, self.0);
            F32x8(_mm256_blendv_ps(b.0, a.0, mask))
        }
        #[inline(always)]
        unsafe fn pow2i(self) -> Self {
            let k = _mm256_cvtps_epi32(self.0);
            let bits = _mm256_slli_epi32::<23>(_mm256_add_epi32(k, _mm256_set1_epi32(127)));
            F32x8(_mm256_castsi256_ps(bits))
        }
        #[inline(always)]
        unsafe fn to_array(self) -> [f32; 8] {
            let mut out = [0.0f32; 8];
            _mm256_storeu_ps(out.as_mut_ptr(), self.0);
            out
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod lanes {
    use super::V8;
    use std::arch::aarch64::*;

    /// Two NEON quads form one eight-lane vector.
    #[derive(Clone, Copy)]
    pub(super) struct F32x8(float32x4_t, float32x4_t);

    impl V8 for F32x8 {
        #[inline(always)]
        unsafe fn splat(v: f32) -> Self {
            F32x8(vdupq_n_f32(v), vdupq_n_f32(v))
        }
        #[inline(always)]
        unsafe fn load(p: *const f32) -> Self {
            F32x8(vld1q_f32(p), vld1q_f32(p.add(4)))
        }
        #[inline(always)]
        unsafe fn store(self, p: *mut f32) {
            vst1q_f32(p, self.0);
            vst1q_f32(p.add(4), self.1);
        }
        #[inline(always)]
        unsafe fn add(self, o: Self) -> Self {
            F32x8(vaddq_f32(self.0, o.0), vaddq_f32(self.1, o.1))
        }
        #[inline(always)]
        unsafe fn sub(self, o: Self) -> Self {
            F32x8(vsubq_f32(self.0, o.0), vsubq_f32(self.1, o.1))
        }
        #[inline(always)]
        unsafe fn mul(self, o: Self) -> Self {
            F32x8(vmulq_f32(self.0, o.0), vmulq_f32(self.1, o.1))
        }
        #[inline(always)]
        unsafe fn div(self, o: Self) -> Self {
            F32x8(vdivq_f32(self.0, o.0), vdivq_f32(self.1, o.1))
        }
        #[inline(always)]
        unsafe fn fma(self, m: Self, acc: Self) -> Self {
            F32x8(vfmaq_f32(acc.0, self.0, m.0), vfmaq_f32(acc.1, self.1, m.1))
        }
        #[inline(always)]
        unsafe fn min(self, o: Self) -> Self {
            F32x8(vminq_f32(self.0, o.0), vminq_f32(self.1, o.1))
        }
        #[inline(always)]
        unsafe fn max(self, o: Self) -> Self {
            F32x8(vmaxq_f32(self.0, o.0), vmaxq_f32(self.1, o.1))
        }
        #[inline(always)]
        unsafe fn floor(self) -> Self {
            F32x8(vrndmq_f32(self.0), vrndmq_f32(self.1))
        }
        #[inline(always)]
        unsafe fn blend_lt(self, bound: Self, a: Self, b: Self) -> Self {
            let m0 = vcltq_f32(self.0, bound.0);
            let m1 = vcltq_f32(self.1, bound.1);
            F32x8(vbslq_f32(m0, a.0, b.0), vbslq_f32(m1, a.1, b.1))
        }
        #[inline(always)]
        unsafe fn blend_nan(self, a: Self, b: Self) -> Self {
            // vceqq(self, self) is the *ordered* mask: select b when
            // ordered, a when NaN.
            let o0 = vceqq_f32(self.0, self.0);
            let o1 = vceqq_f32(self.1, self.1);
            F32x8(vbslq_f32(o0, b.0, a.0), vbslq_f32(o1, b.1, a.1))
        }
        #[inline(always)]
        unsafe fn pow2i(self) -> Self {
            let bias = vdupq_n_s32(127);
            let b0 = vshlq_n_s32::<23>(vaddq_s32(vcvtq_s32_f32(self.0), bias));
            let b1 = vshlq_n_s32::<23>(vaddq_s32(vcvtq_s32_f32(self.1), bias));
            F32x8(vreinterpretq_f32_s32(b0), vreinterpretq_f32_s32(b1))
        }
        #[inline(always)]
        unsafe fn to_array(self) -> [f32; 8] {
            let mut out = [0.0f32; 8];
            vst1q_f32(out.as_mut_ptr(), self.0);
            vst1q_f32(out.as_mut_ptr().add(4), self.1);
            out
        }
    }
}

// ---------------------------------------------------------------------------
// generic kernel bodies (monomorphized inside the per-arch entry points)
// ---------------------------------------------------------------------------

/// Fixed lane-reduction tree plus scalar tail — the same association as
/// the scalar layer's `dot_lanes`.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[inline(always)]
unsafe fn reduce_add_tree<V: V8>(v: V, tail: f32) -> f32 {
    let l = v.to_array();
    (((l[0] + l[1]) + (l[2] + l[3])) + ((l[4] + l[5]) + (l[6] + l[7]))) + tail
}

/// out += a[m,k] @ b[k,n] — bitwise identical to the scalar ascending-k
/// kernel: per element, one mul rounding + one add rounding per k term,
/// k ascending, independent of register-tile membership.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[inline(always)]
unsafe fn mm_accum_v<V: V8>(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    let n8 = n - n % 8;
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let op = out.as_mut_ptr();
    let mut i = 0;
    // 4-row × 16-column tiles: eight independent add chains keep the FPU
    // pipeline full while every chain stays in scalar accumulation order.
    while i + 4 <= m {
        let (r0, r1, r2, r3) = (i * n, (i + 1) * n, (i + 2) * n, (i + 3) * n);
        let (s0, s1, s2, s3) = (i * k, (i + 1) * k, (i + 2) * k, (i + 3) * k);
        let mut j = 0;
        while j + 16 <= n8 {
            let mut c00 = V::load(op.add(r0 + j));
            let mut c01 = V::load(op.add(r0 + j + 8));
            let mut c10 = V::load(op.add(r1 + j));
            let mut c11 = V::load(op.add(r1 + j + 8));
            let mut c20 = V::load(op.add(r2 + j));
            let mut c21 = V::load(op.add(r2 + j + 8));
            let mut c30 = V::load(op.add(r3 + j));
            let mut c31 = V::load(op.add(r3 + j + 8));
            for kk in 0..k {
                let b0 = V::load(bp.add(kk * n + j));
                let b1 = V::load(bp.add(kk * n + j + 8));
                let a0 = V::splat(*ap.add(s0 + kk));
                let a1 = V::splat(*ap.add(s1 + kk));
                let a2 = V::splat(*ap.add(s2 + kk));
                let a3 = V::splat(*ap.add(s3 + kk));
                // mul-then-add, not FMA: the bitwise contract needs one
                // rounding per operation, like the scalar loop
                c00 = c00.add(a0.mul(b0));
                c01 = c01.add(a0.mul(b1));
                c10 = c10.add(a1.mul(b0));
                c11 = c11.add(a1.mul(b1));
                c20 = c20.add(a2.mul(b0));
                c21 = c21.add(a2.mul(b1));
                c30 = c30.add(a3.mul(b0));
                c31 = c31.add(a3.mul(b1));
            }
            c00.store(op.add(r0 + j));
            c01.store(op.add(r0 + j + 8));
            c10.store(op.add(r1 + j));
            c11.store(op.add(r1 + j + 8));
            c20.store(op.add(r2 + j));
            c21.store(op.add(r2 + j + 8));
            c30.store(op.add(r3 + j));
            c31.store(op.add(r3 + j + 8));
            j += 16;
        }
        while j + 8 <= n8 {
            let mut c0 = V::load(op.add(r0 + j));
            let mut c1 = V::load(op.add(r1 + j));
            let mut c2 = V::load(op.add(r2 + j));
            let mut c3 = V::load(op.add(r3 + j));
            for kk in 0..k {
                let bv = V::load(bp.add(kk * n + j));
                c0 = c0.add(V::splat(*ap.add(s0 + kk)).mul(bv));
                c1 = c1.add(V::splat(*ap.add(s1 + kk)).mul(bv));
                c2 = c2.add(V::splat(*ap.add(s2 + kk)).mul(bv));
                c3 = c3.add(V::splat(*ap.add(s3 + kk)).mul(bv));
            }
            c0.store(op.add(r0 + j));
            c1.store(op.add(r1 + j));
            c2.store(op.add(r2 + j));
            c3.store(op.add(r3 + j));
            j += 8;
        }
        for r in i..i + 4 {
            for jj in n8..n {
                let mut o = *op.add(r * n + jj);
                for kk in 0..k {
                    o += *ap.add(r * k + kk) * *bp.add(kk * n + jj);
                }
                *op.add(r * n + jj) = o;
            }
        }
        i += 4;
    }
    while i < m {
        let mut j = 0;
        while j + 8 <= n8 {
            let mut c0 = V::load(op.add(i * n + j));
            for kk in 0..k {
                c0 = c0.add(V::splat(*ap.add(i * k + kk)).mul(V::load(bp.add(kk * n + j))));
            }
            c0.store(op.add(i * n + j));
            j += 8;
        }
        for jj in n8..n {
            let mut o = *op.add(i * n + jj);
            for kk in 0..k {
                o += *ap.add(i * k + kk) * *bp.add(kk * n + jj);
            }
            *op.add(i * n + jj) = o;
        }
        i += 1;
    }
}

/// out += aᵀ @ b with a stored [k,m], b [k,n] — same bitwise ascending-k
/// contract as `mm_accum_v` (only the `a` indexing differs).
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[inline(always)]
unsafe fn mm_at_accum_v<V: V8>(
    a: &[f32],
    b: &[f32],
    k: usize,
    m: usize,
    n: usize,
    out: &mut [f32],
) {
    let n8 = n - n % 8;
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let op = out.as_mut_ptr();
    let mut i = 0;
    while i + 4 <= m {
        let (r0, r1, r2, r3) = (i * n, (i + 1) * n, (i + 2) * n, (i + 3) * n);
        let mut j = 0;
        while j + 16 <= n8 {
            let mut c00 = V::load(op.add(r0 + j));
            let mut c01 = V::load(op.add(r0 + j + 8));
            let mut c10 = V::load(op.add(r1 + j));
            let mut c11 = V::load(op.add(r1 + j + 8));
            let mut c20 = V::load(op.add(r2 + j));
            let mut c21 = V::load(op.add(r2 + j + 8));
            let mut c30 = V::load(op.add(r3 + j));
            let mut c31 = V::load(op.add(r3 + j + 8));
            for kk in 0..k {
                let b0 = V::load(bp.add(kk * n + j));
                let b1 = V::load(bp.add(kk * n + j + 8));
                let a0 = V::splat(*ap.add(kk * m + i));
                let a1 = V::splat(*ap.add(kk * m + i + 1));
                let a2 = V::splat(*ap.add(kk * m + i + 2));
                let a3 = V::splat(*ap.add(kk * m + i + 3));
                c00 = c00.add(a0.mul(b0));
                c01 = c01.add(a0.mul(b1));
                c10 = c10.add(a1.mul(b0));
                c11 = c11.add(a1.mul(b1));
                c20 = c20.add(a2.mul(b0));
                c21 = c21.add(a2.mul(b1));
                c30 = c30.add(a3.mul(b0));
                c31 = c31.add(a3.mul(b1));
            }
            c00.store(op.add(r0 + j));
            c01.store(op.add(r0 + j + 8));
            c10.store(op.add(r1 + j));
            c11.store(op.add(r1 + j + 8));
            c20.store(op.add(r2 + j));
            c21.store(op.add(r2 + j + 8));
            c30.store(op.add(r3 + j));
            c31.store(op.add(r3 + j + 8));
            j += 16;
        }
        while j + 8 <= n8 {
            let mut c0 = V::load(op.add(r0 + j));
            let mut c1 = V::load(op.add(r1 + j));
            let mut c2 = V::load(op.add(r2 + j));
            let mut c3 = V::load(op.add(r3 + j));
            for kk in 0..k {
                let bv = V::load(bp.add(kk * n + j));
                c0 = c0.add(V::splat(*ap.add(kk * m + i)).mul(bv));
                c1 = c1.add(V::splat(*ap.add(kk * m + i + 1)).mul(bv));
                c2 = c2.add(V::splat(*ap.add(kk * m + i + 2)).mul(bv));
                c3 = c3.add(V::splat(*ap.add(kk * m + i + 3)).mul(bv));
            }
            c0.store(op.add(r0 + j));
            c1.store(op.add(r1 + j));
            c2.store(op.add(r2 + j));
            c3.store(op.add(r3 + j));
            j += 8;
        }
        for r in i..i + 4 {
            for jj in n8..n {
                let mut o = *op.add(r * n + jj);
                for kk in 0..k {
                    o += *ap.add(kk * m + r) * *bp.add(kk * n + jj);
                }
                *op.add(r * n + jj) = o;
            }
        }
        i += 4;
    }
    while i < m {
        let mut j = 0;
        while j + 8 <= n8 {
            let mut c0 = V::load(op.add(i * n + j));
            for kk in 0..k {
                c0 = c0.add(V::splat(*ap.add(kk * m + i)).mul(V::load(bp.add(kk * n + j))));
            }
            c0.store(op.add(i * n + j));
            j += 8;
        }
        for jj in n8..n {
            let mut o = *op.add(i * n + jj);
            for kk in 0..k {
                o += *ap.add(kk * m + i) * *bp.add(kk * n + jj);
            }
            *op.add(i * n + jj) = o;
        }
        i += 1;
    }
}

/// out += a @ bᵀ with b stored [n,k] — packed-panel FMA. Reassociated
/// relative to the scalar `dot_lanes` kernel (allowed: NaN-mask +
/// ulp-bounded contract), but *shape-independent*: every output element is
/// one fused chain over ascending k, whether it lands in a vector lane or
/// the scalar `f32::mul_add` remainder, so cached single-row decode
/// (m = 1, n = position count) matches full boards bitwise.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[inline(always)]
unsafe fn mm_bt_accum_v<V: V8>(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
    pack: &mut AlignedVec,
) {
    let n8 = n - n % 8;
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let op = out.as_mut_ptr();
    if n8 > 0 {
        // [k, 8] panel: pack once per eight-column tile, stream it for
        // every row of a (contiguous, 32-byte aligned).
        pack.resize_preserve(k * 8);
        let pp = pack.as_mut_ptr();
        let mut j = 0;
        while j < n8 {
            for kk in 0..k {
                for l in 0..8 {
                    *pp.add(kk * 8 + l) = *bp.add((j + l) * k + kk);
                }
            }
            let mut i = 0;
            while i + 4 <= m {
                let mut c0 = V::load(op.add(i * n + j));
                let mut c1 = V::load(op.add((i + 1) * n + j));
                let mut c2 = V::load(op.add((i + 2) * n + j));
                let mut c3 = V::load(op.add((i + 3) * n + j));
                for kk in 0..k {
                    let pv = V::load(pp.add(kk * 8));
                    c0 = V::splat(*ap.add(i * k + kk)).fma(pv, c0);
                    c1 = V::splat(*ap.add((i + 1) * k + kk)).fma(pv, c1);
                    c2 = V::splat(*ap.add((i + 2) * k + kk)).fma(pv, c2);
                    c3 = V::splat(*ap.add((i + 3) * k + kk)).fma(pv, c3);
                }
                c0.store(op.add(i * n + j));
                c1.store(op.add((i + 1) * n + j));
                c2.store(op.add((i + 2) * n + j));
                c3.store(op.add((i + 3) * n + j));
                i += 4;
            }
            while i < m {
                let mut c0 = V::load(op.add(i * n + j));
                for kk in 0..k {
                    c0 = V::splat(*ap.add(i * k + kk)).fma(V::load(pp.add(kk * 8)), c0);
                }
                c0.store(op.add(i * n + j));
                i += 1;
            }
            j += 8;
        }
    }
    // Remainder columns: scalar fused chains — f32::mul_add is the same
    // single-rounding op as the vector FMA lanes, so these elements are
    // bitwise identical to what a wider tile would have produced.
    for jj in n8..n {
        for i in 0..m {
            let mut o = *op.add(i * n + jj);
            for kk in 0..k {
                o = (*ap.add(i * k + kk)).mul_add(*bp.add(jj * k + kk), o);
            }
            *op.add(i * n + jj) = o;
        }
    }
}

// ---------------------------------------------------------------------------
// transcendental row kernels (mirrored vector/scalar polynomial paths)
// ---------------------------------------------------------------------------

const EXP_HI: f32 = 88.0; // keeps 2^k in range (k ≤ 127)
const EXP_LO: f32 = -87.0; // below: flush to exactly 0.0 (masked-tail invariant)
const LOG2E: f32 = std::f32::consts::LOG2_E;
const EXP_C1: f32 = 0.693359375; // ln2 high part (exact in f32)
const EXP_C2: f32 = -2.12194440e-4; // ln2 low part
const EXP_P0: f32 = 1.9875691500e-4;
const EXP_P1: f32 = 1.3981999507e-3;
const EXP_P2: f32 = 8.3334519073e-3;
const EXP_P3: f32 = 4.1665795894e-2;
const EXP_P4: f32 = 1.6666665459e-1;
const EXP_P5: f32 = 5.0000001201e-1;

/// Scalar mirror of the vector `exp_v` polynomial: identical operations in
/// identical order (`f32::mul_add` is the same fused op as the FMA lanes),
/// so a tail element's bits match what a vector lane would produce. Used
/// for row tails and sub-eight rows; **not** `f32::exp`.
///
/// Domain notes: `x < -87` flushes to exactly `0.0` (this is what keeps
/// `-inf`-masked softmax tails exactly zero); `x` is clamped to `88.0`
/// above (softmax feeds only `x ≤ 0`); NaN propagates.
#[inline(always)]
fn exp_s(x0: f32) -> f32 {
    if x0.is_nan() {
        return x0;
    }
    if x0 < EXP_LO {
        return 0.0;
    }
    // identical to the vector path's max-then-min (NaN already returned)
    let x = x0.clamp(EXP_LO, EXP_HI);
    let t = x.mul_add(LOG2E, 0.5);
    let k = t.floor();
    let xr = k.mul_add(-EXP_C1, x);
    let xr = k.mul_add(-EXP_C2, xr);
    let mut y = EXP_P0;
    y = y.mul_add(xr, EXP_P1);
    y = y.mul_add(xr, EXP_P2);
    y = y.mul_add(xr, EXP_P3);
    y = y.mul_add(xr, EXP_P4);
    y = y.mul_add(xr, EXP_P5);
    let z = xr * xr;
    let y = y.mul_add(z, xr) + 1.0;
    y * f32::from_bits((((k as i32) + 127) << 23) as u32)
}

/// Eight-lane exp; bitwise mirror of [`exp_s`] per lane.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[inline(always)]
unsafe fn exp_v<V: V8>(x0: V) -> V {
    let lo = V::splat(EXP_LO);
    let x = x0.max(lo).min(V::splat(EXP_HI));
    let t = x.fma(V::splat(LOG2E), V::splat(0.5));
    let k = t.floor();
    let xr = k.fma(V::splat(-EXP_C1), x);
    let xr = k.fma(V::splat(-EXP_C2), xr);
    let mut y = V::splat(EXP_P0);
    y = y.fma(xr, V::splat(EXP_P1));
    y = y.fma(xr, V::splat(EXP_P2));
    y = y.fma(xr, V::splat(EXP_P3));
    y = y.fma(xr, V::splat(EXP_P4));
    y = y.fma(xr, V::splat(EXP_P5));
    let z = xr.mul(xr);
    let y = y.fma(z, xr).add(V::splat(1.0));
    let r = y.mul(k.pow2i());
    let r = x0.blend_lt(lo, V::splat(0.0), r);
    x0.blend_nan(x0, r)
}

const GELU_C: f32 = 0.7978845608; // sqrt(2/π) — same constants as math::gelu
const GELU_A: f32 = 0.044715;

/// tanh(u) = 1 − 2/(exp(2u) + 1) through the mirrored exp; saturates
/// exactly at ±1 (exp flushes to 0 / the quotient underflows) and
/// propagates NaN.
#[inline(always)]
fn tanh_s(u: f32) -> f32 {
    1.0 - 2.0 / (exp_s(u + u) + 1.0)
}

#[inline(always)]
fn gelu_s(x: f32) -> f32 {
    let x3 = (x * x) * x;
    let inner = GELU_A.mul_add(x3, x);
    let th = tanh_s(GELU_C * inner);
    (x * 0.5) * (1.0 + th)
}

#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[inline(always)]
unsafe fn tanh_v<V: V8>(u: V) -> V {
    let e = exp_v::<V>(u.add(u));
    V::splat(1.0).sub(V::splat(2.0).div(e.add(V::splat(1.0))))
}

#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[inline(always)]
unsafe fn gelu_v<V: V8>(x: V) -> V {
    let x3 = x.mul(x).mul(x);
    let inner = V::splat(GELU_A).fma(x3, x);
    let th = tanh_v::<V>(V::splat(GELU_C).mul(inner));
    x.mul(V::splat(0.5)).mul(V::splat(1.0).add(th))
}

/// In-place row softmax. Decode-cache parity requirements: max is exact
/// under any grouping; exp uses mirrored vector/scalar paths so an
/// element's bits are independent of row length; the sum is a scalar
/// ascending pass so trailing exact-zero masked entries are additive
/// identities; the final scale is one rounding per element.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[inline(always)]
unsafe fn softmax_row_v<V: V8>(row: &mut [f32]) {
    let n = row.len();
    let n8 = n - n % 8;
    let mut max = f32::NEG_INFINITY;
    if n8 > 0 {
        let p = row.as_ptr();
        let mut vm = V::load(p);
        let mut q = 8;
        while q < n8 {
            vm = vm.max(V::load(p.add(q)));
            q += 8;
        }
        for l in vm.to_array() {
            max = max.max(l);
        }
    }
    for &v in &row[n8..] {
        max = max.max(v);
    }
    {
        let p = row.as_mut_ptr();
        let vmax = V::splat(max);
        let mut q = 0;
        while q < n8 {
            exp_v::<V>(V::load(p.add(q)).sub(vmax)).store(p.add(q));
            q += 8;
        }
    }
    for v in &mut row[n8..] {
        *v = exp_s(*v - max);
    }
    let mut sum = 0.0f32;
    for &v in row.iter() {
        sum += v;
    }
    let inv = 1.0 / sum;
    {
        let p = row.as_mut_ptr();
        let vinv = V::splat(inv);
        let mut q = 0;
        while q < n8 {
            V::load(p.add(q)).mul(vinv).store(p.add(q));
            q += 8;
        }
    }
    for v in &mut row[n8..] {
        *v *= inv;
    }
}

/// One LayerNorm row: lane-parallel mean/variance reductions (fixed tree +
/// scalar tail, like `dot_lanes`) and a fused normalize pass. Rows always
/// span the full model width in every path, so the lane/tail split is the
/// same for a given `d` everywhere — cached decode included. Returns
/// `(mu, inv_sigma)` so the stats-capturing caller shares these exact bits.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[inline(always)]
unsafe fn ln_row_v<V: V8>(
    xr: &[f32],
    g: &[f32],
    b: &[f32],
    eps: f32,
    or: &mut [f32],
) -> (f32, f32) {
    let d = xr.len();
    let d8 = d - d % 8;
    let xp = xr.as_ptr();
    let mut vs = V::splat(0.0);
    let mut q = 0;
    while q < d8 {
        vs = vs.add(V::load(xp.add(q)));
        q += 8;
    }
    let mut tail = 0.0f32;
    for &v in &xr[d8..] {
        tail += v;
    }
    let mu = reduce_add_tree(vs, tail) / d as f32;
    let vmu = V::splat(mu);
    let mut vv = V::splat(0.0);
    q = 0;
    while q < d8 {
        let dv = V::load(xp.add(q)).sub(vmu);
        vv = dv.fma(dv, vv);
        q += 8;
    }
    let mut vtail = 0.0f32;
    for &v in &xr[d8..] {
        let dv = v - mu;
        vtail = dv.mul_add(dv, vtail);
    }
    let var = reduce_add_tree(vv, vtail) / d as f32;
    let inv = 1.0 / (var + eps).sqrt();
    let vinv = V::splat(inv);
    let gp = g.as_ptr();
    let bp = b.as_ptr();
    let op = or.as_mut_ptr();
    q = 0;
    while q < d8 {
        let t = V::load(xp.add(q)).sub(vmu).mul(vinv);
        t.fma(V::load(gp.add(q)), V::load(bp.add(q))).store(op.add(q));
        q += 8;
    }
    for i in d8..d {
        or[i] = ((xr[i] - mu) * inv).mul_add(g[i], b[i]);
    }
    (mu, inv)
}

/// In-place row GELU: vector body + mirrored scalar tail. Callers apply it
/// per logical row (not to the flat buffer) so chunk boundaries — and thus
/// element bits — are independent of the row count.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[inline(always)]
unsafe fn gelu_row_v<V: V8>(row: &mut [f32]) {
    let n8 = row.len() - row.len() % 8;
    {
        let p = row.as_mut_ptr();
        let mut q = 0;
        while q < n8 {
            gelu_v::<V>(V::load(p.add(q))).store(p.add(q));
            q += 8;
        }
    }
    for v in &mut row[n8..] {
        *v = gelu_s(*v);
    }
}

// ---------------------------------------------------------------------------
// per-arch entry points (#[target_feature] wrappers so everything inlines)
// ---------------------------------------------------------------------------

#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
mod entry {
    use super::lanes::F32x8;
    use super::*;

    /// Generates the monomorphic `#[target_feature]` entry point for one
    /// generic kernel: the feature attribute lets LLVM inline the whole
    /// `#[inline(always)]` call tree (kernel body + intrinsic wrappers)
    /// into a single vectorized function per architecture.
    macro_rules! simd_entry {
        ($(fn $name:ident / $generic:ident
            ($($arg:ident: $ty:ty),*) $(-> $ret:ty)?;)*) => {
            $(
                #[cfg_attr(target_arch = "x86_64", target_feature(enable = "avx2,fma"))]
                #[cfg_attr(target_arch = "aarch64", target_feature(enable = "neon"))]
                pub(super) unsafe fn $name($($arg: $ty),*) $(-> $ret)? {
                    $generic::<F32x8>($($arg),*)
                }
            )*
        };
    }

    simd_entry! {
        fn mm_accum / mm_accum_v
            (a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]);
        fn mm_at_accum / mm_at_accum_v
            (a: &[f32], b: &[f32], k: usize, m: usize, n: usize, out: &mut [f32]);
        fn mm_bt_accum / mm_bt_accum_v
            (
                a: &[f32],
                b: &[f32],
                m: usize,
                k: usize,
                n: usize,
                out: &mut [f32],
                pack: &mut AlignedVec
            );
        fn softmax_row / softmax_row_v (row: &mut [f32]);
        fn ln_row / ln_row_v
            (xr: &[f32], g: &[f32], b: &[f32], eps: f32, or: &mut [f32]) -> (f32, f32);
        fn gelu_row / gelu_row_v (row: &mut [f32]);
    }
}

// ---------------------------------------------------------------------------
// crate-facing dispatched kernels (callers check `simd_active()` first)
// ---------------------------------------------------------------------------

#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
thread_local! {
    /// Per-thread mm_bt packing panel (each MGRIT relaxation worker packs
    /// independently). Grows to the largest `k * 8` seen, then stays put —
    /// zero allocations at steady state.
    static PACK: RefCell<AlignedVec> = const { RefCell::new(AlignedVec::new()) };
}

/// out += a[m,k] @ b[k,n]. Caller guarantees `simd_active()`.
pub(crate) fn mm_accum(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    unsafe {
        entry::mm_accum(a, b, m, k, n, out)
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        let _ = (a, b, m, k, n, out);
        unreachable!("simd_active() is false on this architecture")
    }
}

/// out += aᵀ @ b (a stored [k,m]). Caller guarantees `simd_active()`.
pub(crate) fn mm_at_accum(a: &[f32], b: &[f32], k: usize, m: usize, n: usize, out: &mut [f32]) {
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    unsafe {
        entry::mm_at_accum(a, b, k, m, n, out)
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        let _ = (a, b, k, m, n, out);
        unreachable!("simd_active() is false on this architecture")
    }
}

/// out += a @ bᵀ (b stored [n,k]). Caller guarantees `simd_active()`.
pub(crate) fn mm_bt_accum(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    PACK.with(|p| unsafe { entry::mm_bt_accum(a, b, m, k, n, out, &mut p.borrow_mut()) });
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        let _ = (a, b, m, k, n, out);
        unreachable!("simd_active() is false on this architecture")
    }
}

/// In-place softmax over one row. Caller guarantees `simd_active()`.
pub(crate) fn softmax_row(row: &mut [f32]) {
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    unsafe {
        entry::softmax_row(row)
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        let _ = row;
        unreachable!("simd_active() is false on this architecture")
    }
}

/// One LayerNorm row; returns `(mu, inv_sigma)`. Caller guarantees
/// `simd_active()`.
pub(crate) fn ln_row(xr: &[f32], g: &[f32], b: &[f32], eps: f32, or: &mut [f32]) -> (f32, f32) {
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    unsafe {
        entry::ln_row(xr, g, b, eps, or)
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        let _ = (xr, g, b, eps, or);
        unreachable!("simd_active() is false on this architecture")
    }
}

/// In-place GELU over one row. Caller guarantees `simd_active()`.
pub(crate) fn gelu_row(row: &mut [f32]) {
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    unsafe {
        entry::gelu_row(row)
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        let _ = row;
        unreachable!("simd_active() is false on this architecture")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// exp_s is a polynomial mirror, not libm exp — pin it to libm within
    /// a few ulp across the softmax-relevant domain, plus the flush/NaN
    /// special cases the decode-parity invariants depend on.
    #[test]
    fn exp_s_tracks_libm_and_flushes_masked_tails() {
        assert_eq!(exp_s(f32::NEG_INFINITY), 0.0);
        assert_eq!(exp_s(-1000.0), 0.0);
        assert_eq!(exp_s(0.0), 1.0);
        assert!(exp_s(f32::NAN).is_nan());
        let mut x = -87.0f32;
        while x <= 1.0 {
            let got = exp_s(x);
            let want = x.exp();
            let tol = 4.0 * (want * f32::EPSILON).abs() + f32::MIN_POSITIVE;
            assert!((got - want).abs() <= tol, "exp_s({x}) = {got}, libm {want}");
            x += 0.317;
        }
    }

    #[test]
    fn gelu_s_tracks_scalar_gelu() {
        // same tanh-approximate GELU, different tanh evaluation: agree to
        // ~1e-6 absolute over the activation range and at saturation
        let scalar = |x: f32| 0.5 * x * (1.0 + (GELU_C * (x + GELU_A * x * x * x)).tanh());
        let mut x = -8.0f32;
        while x <= 8.0 {
            let (got, want) = (gelu_s(x), scalar(x));
            assert!(
                (got - want).abs() <= 2e-6 * (1.0 + want.abs()),
                "gelu_s({x}) = {got}, scalar {want}"
            );
            x += 0.173;
        }
        assert_eq!(gelu_s(100.0), 100.0);
        assert_eq!(gelu_s(-100.0), -0.0);
        assert!(gelu_s(f32::NAN).is_nan());
    }

    /// On hosts where the vector path runs, every lane of the vector
    /// kernels must mirror the scalar helpers bitwise — this is what makes
    /// tail elements independent of row length.
    #[test]
    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    fn vector_lanes_mirror_scalar_helpers_bitwise() {
        if !simd_active() {
            return; // non-AVX2 x86 host: nothing to compare
        }
        let inputs: Vec<f32> = vec![
            -87.5, -87.0, -10.0, -1.0, -0.5, -0.0, 0.0, 0.25, 1.0, 3.5, 7.75, 87.9, 88.0, 100.0,
            f32::NEG_INFINITY, f32::NAN,
        ];
        for chunk in inputs.chunks(8) {
            let mut buf = [0.0f32; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            let mut got = [0.0f32; 8];
            unsafe {
                let v = exp_v::<lanes::F32x8>(V8::load(buf.as_ptr()));
                v.store(got.as_mut_ptr());
            }
            for (i, &x) in buf.iter().enumerate() {
                let want = exp_s(x);
                assert_eq!(
                    got[i].to_bits(),
                    want.to_bits(),
                    "exp lane {i} for input {x}: vector {} vs scalar {want}",
                    got[i]
                );
            }
            let mut gelu_got = buf;
            unsafe {
                let v = gelu_v::<lanes::F32x8>(V8::load(buf.as_ptr()));
                v.store(gelu_got.as_mut_ptr());
            }
            for (i, &x) in buf.iter().enumerate() {
                let want = gelu_s(x);
                assert_eq!(gelu_got[i].to_bits(), want.to_bits(), "gelu lane {i} for input {x}");
            }
        }
    }

    // NOTE: no unit test toggles `set_force_scalar` — unit tests run on
    // parallel threads in this binary, and block.rs pins bitwise equality
    // between pairs of dispatched calls (a toggle landing between the two
    // would flip the reassociated kernels' bits). The round-trip behavior
    // is covered by tests/simd_parity.rs, where every test serializes on
    // one dispatch mutex.
}
