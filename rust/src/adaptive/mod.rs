//! Adaptive control of MGRIT inexactness (paper §3.2.3).
//!
//! Biased gradients from inexact MGRIT solves eventually stall or diverge
//! training (paper Fig. 4). The controller monitors the MGRIT *convergence
//! factor* ρ = ‖r^(k+1)‖/‖r^(k)‖: every `probe_every` batches it doubles
//! the iteration count for one probe solve and inspects the final ρ.
//! ρ ≥ 1 means extra iterations no longer contract the residual — the
//! mitigation is either to raise the standing iteration count or to switch
//! to serial (exact) propagation for the rest of training.

use crate::config::MgritConfig;

/// What the controller decided after a probe.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdaptiveDecision {
    /// ρ comfortably < 1: keep the current configuration.
    Keep,
    /// ρ drifting towards 1: double the standing iteration counts.
    IncreaseIters,
    /// ρ ≥ 1 (or iteration budget exhausted): switch to serial training.
    SwitchSerial,
}

/// Controller state threaded through the training loop.
#[derive(Debug, Clone)]
pub struct AdaptiveController {
    /// Probe cadence in batches (paper: every ~500).
    pub probe_every: usize,
    /// ρ at or above this triggers SwitchSerial (paper: 1.0).
    pub rho_switch: f64,
    /// ρ at or above this (but below `rho_switch`) triggers IncreaseIters.
    pub rho_grow: f64,
    /// Iteration count beyond which growing is pointless -> switch serial.
    pub max_iters: usize,
    /// Batch counter.
    step: usize,
    /// Sticky: once serial, stay serial (paper's scheme).
    switched: bool,
    /// History of (step, rho_fwd, rho_bwd, decision) for Fig. 5 logging.
    pub history: Vec<ProbeRecord>,
}

/// One probe observation (drives the Fig. 5 indicator plot).
#[derive(Debug, Clone)]
pub struct ProbeRecord {
    pub step: usize,
    pub rho_fwd: Option<f64>,
    pub rho_bwd: Option<f64>,
    pub decision: AdaptiveDecision,
}

impl AdaptiveController {
    pub fn new(probe_every: usize) -> AdaptiveController {
        AdaptiveController {
            probe_every,
            rho_switch: 1.0,
            rho_grow: 0.9,
            max_iters: 8,
            step: 0,
            switched: false,
            history: Vec::new(),
        }
    }

    /// Has the controller permanently switched to serial?
    pub fn is_serial(&self) -> bool {
        self.switched
    }

    /// Advance the batch counter; true if this batch should run a probe
    /// (doubled-iteration solve with residual tracking).
    pub fn should_probe(&mut self) -> bool {
        self.step += 1;
        !self.switched && self.probe_every > 0 && self.step % self.probe_every == 0
    }

    /// Iteration counts to use for a probe solve (doubled, per the paper).
    pub fn probe_iters(&self, cfg: &MgritConfig) -> (Option<usize>, Option<usize>) {
        (cfg.fwd_iters.map(|k| k * 2), cfg.bwd_iters.map(|k| k * 2))
    }

    /// Digest the convergence factors observed in a probe and mutate `cfg`
    /// accordingly. Returns the decision (also appended to `history`).
    pub fn observe(
        &mut self,
        rho_fwd: Option<f64>,
        rho_bwd: Option<f64>,
        cfg: &mut MgritConfig,
    ) -> AdaptiveDecision {
        let worst = [rho_fwd, rho_bwd].into_iter().flatten().fold(0.0f64, f64::max);
        let at_budget = cfg.fwd_iters.unwrap_or(0).max(cfg.bwd_iters.unwrap_or(0)) >= self.max_iters;
        let decision = if worst >= self.rho_switch || (worst >= self.rho_grow && at_budget) {
            self.switched = true;
            cfg.fwd_iters = None;
            cfg.bwd_iters = None;
            AdaptiveDecision::SwitchSerial
        } else if worst >= self.rho_grow {
            cfg.fwd_iters = cfg.fwd_iters.map(|k| (k * 2).min(self.max_iters));
            cfg.bwd_iters = cfg.bwd_iters.map(|k| (k * 2).min(self.max_iters));
            AdaptiveDecision::IncreaseIters
        } else {
            AdaptiveDecision::Keep
        };
        self.history.push(ProbeRecord { step: self.step, rho_fwd, rho_bwd, decision });
        decision
    }

    /// Manual override: force serial from the next batch (used when an
    /// external signal — e.g. loss divergence — fires first).
    pub fn force_serial(&mut self, cfg: &mut MgritConfig) {
        self.switched = true;
        cfg.fwd_iters = None;
        cfg.bwd_iters = None;
        self.history.push(ProbeRecord {
            step: self.step,
            rho_fwd: None,
            rho_bwd: None,
            decision: AdaptiveDecision::SwitchSerial,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> MgritConfig {
        MgritConfig { cf: 4, levels: 2, fwd_iters: Some(1), bwd_iters: Some(1), fcf: true }
    }

    #[test]
    fn probes_fire_on_cadence() {
        let mut c = AdaptiveController::new(3);
        let fires: Vec<bool> = (0..9).map(|_| c.should_probe()).collect();
        assert_eq!(fires, vec![false, false, true, false, false, true, false, false, true]);
    }

    #[test]
    fn healthy_rho_keeps_config() {
        let mut c = AdaptiveController::new(1);
        let mut m = cfg();
        assert_eq!(c.observe(Some(0.3), Some(0.2), &mut m), AdaptiveDecision::Keep);
        assert_eq!(m.fwd_iters, Some(1));
        assert!(!c.is_serial());
    }

    #[test]
    fn drifting_rho_doubles_iters_then_switches_at_budget() {
        let mut c = AdaptiveController::new(1);
        c.max_iters = 4;
        let mut m = cfg();
        assert_eq!(c.observe(Some(0.95), None, &mut m), AdaptiveDecision::IncreaseIters);
        assert_eq!(m.fwd_iters, Some(2));
        assert_eq!(c.observe(Some(0.95), None, &mut m), AdaptiveDecision::IncreaseIters);
        assert_eq!(m.fwd_iters, Some(4));
        // at budget and still drifting -> serial
        assert_eq!(c.observe(Some(0.95), None, &mut m), AdaptiveDecision::SwitchSerial);
        assert!(m.is_serial());
        assert!(c.is_serial());
    }

    #[test]
    fn rho_above_one_switches_immediately() {
        let mut c = AdaptiveController::new(1);
        let mut m = cfg();
        assert_eq!(c.observe(Some(0.4), Some(1.3), &mut m), AdaptiveDecision::SwitchSerial);
        assert!(m.is_serial());
        // sticky: no more probes once serial
        assert!(!c.should_probe());
    }

    #[test]
    fn probe_iters_doubled() {
        let c = AdaptiveController::new(5);
        let m = cfg();
        assert_eq!(c.probe_iters(&m), (Some(2), Some(2)));
        let m2 = MgritConfig { fwd_iters: None, ..m };
        assert_eq!(c.probe_iters(&m2), (None, Some(2)));
    }

    #[test]
    fn history_records_everything() {
        let mut c = AdaptiveController::new(1);
        let mut m = cfg();
        c.observe(Some(0.5), Some(0.6), &mut m);
        c.force_serial(&mut m);
        assert_eq!(c.history.len(), 2);
        assert_eq!(c.history[1].decision, AdaptiveDecision::SwitchSerial);
    }
}
