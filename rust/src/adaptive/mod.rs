//! Adaptive control of MGRIT inexactness (paper §3.2.3).
//!
//! Biased gradients from inexact MGRIT solves eventually stall or diverge
//! training (paper Fig. 4). The controller monitors the MGRIT *convergence
//! factor* ρ = ‖r^(k+1)‖/‖r^(k)‖: every `probe_every` batches it doubles
//! the iteration count for one probe solve and inspects the final ρ.
//! ρ ≥ 1 means extra iterations no longer contract the residual — the
//! mitigation is either to raise the standing iteration count or to switch
//! to serial (exact) propagation for the rest of training.

use crate::config::MgritConfig;
use crate::util::json::{self, Json};

/// Default retained probe-history window (see
/// [`AdaptiveController::set_history_cap`]).
pub const DEFAULT_HISTORY_CAP: usize = 512;

/// What the controller decided after a probe.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdaptiveDecision {
    /// ρ comfortably < 1: keep the current configuration.
    Keep,
    /// ρ drifting towards 1: double the standing iteration counts.
    IncreaseIters,
    /// ρ ≥ 1 (or iteration budget exhausted): switch to serial training.
    SwitchSerial,
    /// The divergence watchdog restored the last good autosave instead of
    /// switching serial (see `coordinator::Session`'s rollback policy);
    /// MGRIT inexactness is kept and the run replays from the restored
    /// step. Recorded by [`AdaptiveController::record_rollback`].
    Rollback,
}

impl AdaptiveDecision {
    pub fn as_str(&self) -> &'static str {
        match self {
            AdaptiveDecision::Keep => "keep",
            AdaptiveDecision::IncreaseIters => "increase_iters",
            AdaptiveDecision::SwitchSerial => "switch_serial",
            AdaptiveDecision::Rollback => "rollback",
        }
    }
}

/// Controller state threaded through the training loop.
#[derive(Debug, Clone)]
pub struct AdaptiveController {
    /// Probe cadence in batches (paper: every ~500).
    pub probe_every: usize,
    /// ρ at or above this triggers SwitchSerial (paper: 1.0).
    pub rho_switch: f64,
    /// ρ at or above this (but below `rho_switch`) triggers IncreaseIters.
    pub rho_grow: f64,
    /// Iteration count beyond which growing is pointless -> switch serial.
    pub max_iters: usize,
    /// Batch counter.
    step: usize,
    /// Sticky: once serial, stay serial (paper's scheme).
    switched: bool,
    /// Rolling window of probe observations (Fig. 5 logging). Bounded by
    /// `history_cap`: long runs probe indefinitely, so an unbounded log
    /// would grow forever — the oldest record is evicted at the cap.
    history: Vec<ProbeRecord>,
    /// Maximum retained history records (≥ 1).
    history_cap: usize,
}

/// One probe observation (drives the Fig. 5 indicator plot).
#[derive(Debug, Clone, PartialEq)]
pub struct ProbeRecord {
    pub step: usize,
    pub rho_fwd: Option<f64>,
    pub rho_bwd: Option<f64>,
    pub decision: AdaptiveDecision,
}

impl ProbeRecord {
    pub fn to_json(&self) -> Json {
        // JSON numbers cannot encode NaN/Inf (a 0/0 convergence factor or
        // a diverged solve would emit unparseable output) — map to null
        let rho = |v: Option<f64>| match v {
            Some(x) if x.is_finite() => json::num(x),
            _ => Json::Null,
        };
        json::obj(vec![
            ("step", json::int(self.step as i64)),
            ("rho_fwd", rho(self.rho_fwd)),
            ("rho_bwd", rho(self.rho_bwd)),
            ("decision", json::s(self.decision.as_str())),
        ])
    }
}

impl AdaptiveController {
    pub fn new(probe_every: usize) -> AdaptiveController {
        AdaptiveController {
            probe_every,
            rho_switch: 1.0,
            rho_grow: 0.9,
            max_iters: 8,
            step: 0,
            switched: false,
            history: Vec::new(),
            history_cap: DEFAULT_HISTORY_CAP,
        }
    }

    /// Rebuild a controller from checkpointed state (the exact counterpart
    /// of the accessors: `batch_step`, `is_serial`, `history`,
    /// `history_cap`). `history` longer than `cap` keeps the tail.
    #[allow(clippy::too_many_arguments)]
    pub fn restore(
        probe_every: usize,
        rho_switch: f64,
        rho_grow: f64,
        max_iters: usize,
        step: usize,
        switched: bool,
        history_cap: usize,
        mut history: Vec<ProbeRecord>,
    ) -> AdaptiveController {
        let history_cap = history_cap.max(1);
        if history.len() > history_cap {
            history.drain(..history.len() - history_cap);
        }
        AdaptiveController {
            probe_every,
            rho_switch,
            rho_grow,
            max_iters,
            step,
            switched,
            history,
            history_cap,
        }
    }

    /// Has the controller permanently switched to serial?
    pub fn is_serial(&self) -> bool {
        self.switched
    }

    /// Batch counter (checkpointing; advanced by `should_probe`).
    pub fn batch_step(&self) -> usize {
        self.step
    }

    /// The retained probe-history window, oldest first.
    pub fn history(&self) -> &[ProbeRecord] {
        &self.history
    }

    /// Current history bound.
    pub fn history_cap(&self) -> usize {
        self.history_cap
    }

    /// Bound the retained probe history (clamped to ≥ 1); an over-full
    /// window is trimmed to the most recent `cap` records immediately.
    pub fn set_history_cap(&mut self, cap: usize) {
        self.history_cap = cap.max(1);
        if self.history.len() > self.history_cap {
            self.history.drain(..self.history.len() - self.history_cap);
        }
    }

    /// Append to the bounded history, evicting the oldest at the cap
    /// (`remove(0)` is O(cap), and probes fire every `probe_every`
    /// batches — negligible next to a solve).
    fn push_history(&mut self, rec: ProbeRecord) {
        if self.history.len() >= self.history_cap {
            self.history.remove(0);
        }
        self.history.push(rec);
    }

    /// Advance the batch counter; true if this batch should run a probe
    /// (doubled-iteration solve with residual tracking).
    pub fn should_probe(&mut self) -> bool {
        self.step += 1;
        !self.switched && self.probe_every > 0 && self.step % self.probe_every == 0
    }

    /// Iteration counts to use for a probe solve (doubled, per the paper).
    pub fn probe_iters(&self, cfg: &MgritConfig) -> (Option<usize>, Option<usize>) {
        (cfg.fwd_iters.map(|k| k * 2), cfg.bwd_iters.map(|k| k * 2))
    }

    /// Digest the convergence factors observed in a probe and mutate `cfg`
    /// accordingly. Returns the decision (also appended to `history`).
    pub fn observe(
        &mut self,
        rho_fwd: Option<f64>,
        rho_bwd: Option<f64>,
        cfg: &mut MgritConfig,
    ) -> AdaptiveDecision {
        let worst = [rho_fwd, rho_bwd].into_iter().flatten().fold(0.0f64, f64::max);
        let at_budget = cfg.fwd_iters.unwrap_or(0).max(cfg.bwd_iters.unwrap_or(0)) >= self.max_iters;
        let decision = if worst >= self.rho_switch || (worst >= self.rho_grow && at_budget) {
            self.switched = true;
            cfg.fwd_iters = None;
            cfg.bwd_iters = None;
            AdaptiveDecision::SwitchSerial
        } else if worst >= self.rho_grow {
            cfg.fwd_iters = cfg.fwd_iters.map(|k| (k * 2).min(self.max_iters));
            cfg.bwd_iters = cfg.bwd_iters.map(|k| (k * 2).min(self.max_iters));
            AdaptiveDecision::IncreaseIters
        } else {
            AdaptiveDecision::Keep
        };
        self.push_history(ProbeRecord { step: self.step, rho_fwd, rho_bwd, decision });
        decision
    }

    /// Undo the batch-counter advance of one [`should_probe`] call. The
    /// non-finite-step guard rewinds the RNG and replays a batch; without
    /// this the replay would double-count the batch and shift the probe
    /// cadence relative to a clean run. (If the anomalous batch was a
    /// probe batch, its history record has already been appended and is
    /// *not* popped — the replayed probe appends its own record, so an
    /// anomaly on a probe step may leave a duplicate entry. Documented
    /// behaviour: anomalies are rare and history is diagnostic.)
    ///
    /// [`should_probe`]: AdaptiveController::should_probe
    pub fn rewind_batch(&mut self) {
        self.step = self.step.saturating_sub(1);
    }

    /// Record an auto-rollback in the probe history (the Fig. 5 indicator
    /// stream then shows *why* the loss curve jumps backwards). Does not
    /// switch serial and does not touch the MGRIT config — the whole point
    /// of rollback is to keep layer-parallel training running.
    pub fn record_rollback(&mut self) {
        self.push_history(ProbeRecord {
            step: self.step,
            rho_fwd: None,
            rho_bwd: None,
            decision: AdaptiveDecision::Rollback,
        });
    }

    /// Manual override: force serial from the next batch (used when an
    /// external signal — e.g. loss divergence — fires first).
    pub fn force_serial(&mut self, cfg: &mut MgritConfig) {
        self.switched = true;
        cfg.fwd_iters = None;
        cfg.bwd_iters = None;
        self.push_history(ProbeRecord {
            step: self.step,
            rho_fwd: None,
            rho_bwd: None,
            decision: AdaptiveDecision::SwitchSerial,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> MgritConfig {
        MgritConfig { cf: 4, levels: 2, fwd_iters: Some(1), bwd_iters: Some(1), fcf: true }
    }

    #[test]
    fn probes_fire_on_cadence() {
        let mut c = AdaptiveController::new(3);
        let fires: Vec<bool> = (0..9).map(|_| c.should_probe()).collect();
        assert_eq!(fires, vec![false, false, true, false, false, true, false, false, true]);
    }

    #[test]
    fn healthy_rho_keeps_config() {
        let mut c = AdaptiveController::new(1);
        let mut m = cfg();
        assert_eq!(c.observe(Some(0.3), Some(0.2), &mut m), AdaptiveDecision::Keep);
        assert_eq!(m.fwd_iters, Some(1));
        assert!(!c.is_serial());
    }

    #[test]
    fn drifting_rho_doubles_iters_then_switches_at_budget() {
        let mut c = AdaptiveController::new(1);
        c.max_iters = 4;
        let mut m = cfg();
        assert_eq!(c.observe(Some(0.95), None, &mut m), AdaptiveDecision::IncreaseIters);
        assert_eq!(m.fwd_iters, Some(2));
        assert_eq!(c.observe(Some(0.95), None, &mut m), AdaptiveDecision::IncreaseIters);
        assert_eq!(m.fwd_iters, Some(4));
        // at budget and still drifting -> serial
        assert_eq!(c.observe(Some(0.95), None, &mut m), AdaptiveDecision::SwitchSerial);
        assert!(m.is_serial());
        assert!(c.is_serial());
    }

    #[test]
    fn rho_above_one_switches_immediately() {
        let mut c = AdaptiveController::new(1);
        let mut m = cfg();
        assert_eq!(c.observe(Some(0.4), Some(1.3), &mut m), AdaptiveDecision::SwitchSerial);
        assert!(m.is_serial());
        // sticky: no more probes once serial
        assert!(!c.should_probe());
    }

    #[test]
    fn probe_iters_doubled() {
        let c = AdaptiveController::new(5);
        let m = cfg();
        assert_eq!(c.probe_iters(&m), (Some(2), Some(2)));
        let m2 = MgritConfig { fwd_iters: None, ..m };
        assert_eq!(c.probe_iters(&m2), (None, Some(2)));
    }

    #[test]
    fn rewind_batch_undoes_one_probe_advance() {
        let mut c = AdaptiveController::new(3);
        assert!(!c.should_probe()); // step 1
        assert!(!c.should_probe()); // step 2
        c.rewind_batch(); // replayed batch: back to step 1
        assert!(!c.should_probe()); // step 2 again
        assert!(c.should_probe(), "cadence must be unshifted after a replay");
        let mut z = AdaptiveController::new(3);
        z.rewind_batch();
        assert_eq!(z.batch_step(), 0, "rewind at step 0 saturates");
    }

    #[test]
    fn rollback_is_recorded_without_switching_serial() {
        let mut c = AdaptiveController::new(1);
        let mut m = cfg();
        c.record_rollback();
        assert!(!c.is_serial(), "rollback must keep layer-parallel training");
        assert_eq!(m.fwd_iters, Some(1), "rollback must not touch the MGRIT config");
        assert_eq!(c.history().len(), 1);
        assert_eq!(c.history()[0].decision, AdaptiveDecision::Rollback);
        assert_eq!(c.history()[0].decision.as_str(), "rollback");
    }

    #[test]
    fn history_records_everything() {
        let mut c = AdaptiveController::new(1);
        let mut m = cfg();
        c.observe(Some(0.5), Some(0.6), &mut m);
        c.force_serial(&mut m);
        assert_eq!(c.history().len(), 2);
        assert_eq!(c.history()[1].decision, AdaptiveDecision::SwitchSerial);
    }

    #[test]
    fn history_is_bounded_by_the_cap() {
        let mut c = AdaptiveController::new(1);
        c.set_history_cap(4);
        let mut m = cfg();
        for _ in 0..20 {
            c.observe(Some(0.1), Some(0.1), &mut m);
        }
        assert_eq!(c.history().len(), 4, "history must not outgrow the cap");
        // the retained window is the most recent one (observe is called
        // without should_probe here, so steps stay 0 — tag via rho instead)
        let mut c = AdaptiveController::new(1);
        c.set_history_cap(3);
        for i in 0..10 {
            c.observe(Some(i as f64 / 100.0), None, &mut m);
        }
        let kept: Vec<f64> = c.history().iter().map(|r| r.rho_fwd.unwrap()).collect();
        assert_eq!(kept, vec![0.07, 0.08, 0.09], "eviction must drop the oldest records");
        // shrinking the cap trims immediately
        c.set_history_cap(1);
        assert_eq!(c.history().len(), 1);
        assert_eq!(c.history()[0].rho_fwd, Some(0.09));
    }

    #[test]
    fn restore_roundtrips_controller_state() {
        let mut c = AdaptiveController::new(3);
        c.set_history_cap(8);
        let mut m = cfg();
        for _ in 0..7 {
            c.should_probe();
        }
        c.observe(Some(0.95), None, &mut m); // IncreaseIters
        c.observe(Some(0.5), Some(0.4), &mut m); // Keep
        let r = AdaptiveController::restore(
            c.probe_every,
            c.rho_switch,
            c.rho_grow,
            c.max_iters,
            c.batch_step(),
            c.is_serial(),
            c.history_cap(),
            c.history().to_vec(),
        );
        assert_eq!(r.batch_step(), c.batch_step());
        assert_eq!(r.is_serial(), c.is_serial());
        assert_eq!(r.history(), c.history());
        assert_eq!(r.history_cap(), c.history_cap());
        // the restored controller continues the probe cadence in lockstep
        let mut c2 = c.clone();
        let mut r2 = r;
        for _ in 0..6 {
            assert_eq!(c2.should_probe(), r2.should_probe());
        }
    }

    #[test]
    fn probe_record_json_shape() {
        let r = ProbeRecord {
            step: 5,
            rho_fwd: Some(0.25),
            rho_bwd: None,
            decision: AdaptiveDecision::Keep,
        };
        let j = r.to_json();
        assert_eq!(j.get("step").unwrap().int(), Some(5));
        assert_eq!(j.get("rho_fwd").unwrap().num(), Some(0.25));
        assert_eq!(j.get("rho_bwd"), Some(&crate::util::json::Json::Null));
        assert_eq!(j.get("decision").unwrap().str(), Some("keep"));
    }
}
