//! Versioned binary checkpoints for whole training sessions.
//!
//! A [`Checkpoint`] captures everything a [`crate::coordinator::Session`]
//! needs to continue a run **bitwise identically**: the full
//! [`RunConfig`] (including the MGRIT iteration counts the §3.2.3
//! controller may have mutated), every parameter group, the optimizer
//! moments and bias-correction counter, the adaptive controller (batch
//! counter, sticky serial switch, retained ρ-history window), the training
//! RNG stream (state word + cached Box-Muller spare), the step counter,
//! and — when valid — the TorchBraid-style warm-start iterate, so the
//! first post-resume solve warm-starts exactly like the uninterrupted
//! run's would have.
//!
//! ## File format (version 2, all integers little-endian)
//!
//! ```text
//! magic        b"LTCP"
//! version      u32                  (= 2)
//! config       u32 len + RunConfig JSON (utf-8; u64 seed as string)
//! run state    u64 step
//!              u8 flag + f32        initial_loss    (divergence watchdog)
//!              u8 flag + u64        switched_at
//!              u8                   warm_start option
//!              u64 + u8 flag + f32  training-RNG state word / spare
//! controller   u64 probe_every, f64 rho_switch, f64 rho_grow,
//!              u64 max_iters, u64 batch step, u8 switched,
//!              u64 history_cap, u32 n records
//!              per record: u64 step, (u8+f64) ρ_fwd, (u8+f64) ρ_bwd,
//!                          u8 decision (0 keep / 1 grow / 2 serial /
//!                          3 rollback)
//! optimizer    u64 t (bias-correction counter)
//! tensor table u32 n entries; per entry u16 name-len + name + u64 count
//!              then every payload (count × f32) in entry order
//! checksum     u64 FNV-1a over every preceding byte
//! ```
//!
//! Tensor-table entry names are structural and **validated against the
//! model config on read**: `param.layer.{i}` (length
//! [`crate::config::ModelConfig::layer_theta_len`]), `param.{emb,pos,out,cls}`,
//! `opt.{m,v}.{g}` for every optimizer group (layers…, emb, pos, out, cls),
//! and optionally `warm.{j}` for the
//! `dp_degree.max(1) × (parallel_layers() + 1)` mid-range warm states —
//! replica-major, so replica `r`'s iterate is the contiguous run
//! `warm.{r·(P+1)} .. warm.{(r+1)·(P+1) - 1}` (each of `state_shape()`
//! element count). Any missing, reordered,
//! unknown, or wrongly-sized entry is a hard error, as are a bad magic,
//! an unknown version, a truncated file, or a checksum mismatch.
//!
//! ## Versioning rules
//!
//! The version is bumped whenever the byte layout or the entry-name
//! contract changes; readers reject versions they don't know (no silent
//! best-effort decoding of foreign layouts). New *optional* tensor-table
//! entries may be added within a version only if absence keeps old files
//! readable (the warm-start section works this way). Version 2 widened
//! the warm section from one iterate to one per data-parallel replica
//! when `--dp` replicas started executing concurrently, each with its own
//! warm-start chain.

use anyhow::{bail, Context, Result};

use crate::adaptive::{AdaptiveDecision, ProbeRecord};
use crate::config::RunConfig;
use crate::tensor::Tensor;
use crate::util::json::Json;

/// File magic ("LayerTime CheckPoint").
pub const MAGIC: &[u8; 4] = b"LTCP";
/// Current format version.
pub const VERSION: u32 = 2;

/// Autosave file name for step `step` next to the base save path:
/// `run.ltcp` → `run.step00000040.ltcp`. The step is zero-padded so
/// lexicographic order equals chronological order — retention pruning and
/// the serve hot-reload watcher both rely on that.
pub fn autosave_path(base: &str, step: usize) -> String {
    let p = std::path::Path::new(base);
    let stem = p.file_stem().and_then(|s| s.to_str()).unwrap_or("ckpt");
    let name = format!("{}.step{:08}.ltcp", stem, step);
    match p.parent() {
        Some(dir) if !dir.as_os_str().is_empty() => {
            dir.join(name).to_string_lossy().into_owned()
        }
        _ => name,
    }
}

/// Prune old autosaves next to `base`, keeping the newest `keep` files of
/// the `{stem}.step*.ltcp` family (lexicographic = chronological by the
/// [`autosave_path`] naming). Returns how many files were removed; unlink
/// errors are ignored — retention is best-effort and must never take the
/// training loop down.
pub fn prune_autosaves(base: &str, keep: usize) -> usize {
    let p = std::path::Path::new(base);
    let stem = match p.file_stem().and_then(|s| s.to_str()) {
        Some(s) => s,
        None => return 0,
    };
    let dir = match p.parent() {
        Some(d) if !d.as_os_str().is_empty() => d.to_path_buf(),
        _ => std::path::PathBuf::from("."),
    };
    let prefix = format!("{}.step", stem);
    let mut family: Vec<String> = match std::fs::read_dir(&dir) {
        Ok(entries) => entries
            .filter_map(|e| e.ok())
            .filter_map(|e| e.file_name().to_str().map(String::from))
            .filter(|n| n.starts_with(&prefix) && n.ends_with(".ltcp"))
            .collect(),
        Err(_) => return 0,
    };
    if family.len() <= keep {
        return 0;
    }
    family.sort();
    let excess = family.len() - keep;
    let mut removed = 0;
    for name in family.iter().take(excess) {
        if std::fs::remove_file(dir.join(name)).is_ok() {
            removed += 1;
        }
    }
    removed
}

/// Adaptive-controller snapshot carried by a checkpoint (mirrors the
/// accessors on [`crate::adaptive::AdaptiveController`]).
#[derive(Debug, Clone, PartialEq)]
pub struct ControllerState {
    pub probe_every: usize,
    pub rho_switch: f64,
    pub rho_grow: f64,
    pub max_iters: usize,
    pub step: usize,
    pub switched: bool,
    pub history_cap: usize,
    /// The retained ρ-history window only (the controller's cap bounds it).
    pub history: Vec<ProbeRecord>,
}

/// In-memory image of one session checkpoint (see module docs).
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// The run description at save time — including controller-mutated
    /// MGRIT iteration counts, so a resumed run solves identically.
    pub rc: RunConfig,
    /// Completed optimizer steps.
    pub step: usize,
    /// First-step loss (the divergence watchdog's reference point).
    pub initial_loss: Option<f32>,
    /// Step at which the run switched to serial, if it did.
    pub switched_at: Option<usize>,
    /// The session's warm-start *option* (builder setting).
    pub warm_start: bool,
    /// Training-RNG state word.
    pub rng_state: u64,
    /// Training-RNG cached Box-Muller spare.
    pub rng_spare: Option<f32>,
    pub controller: ControllerState,
    /// Optimizer bias-correction counter.
    pub opt_t: u64,
    /// First optimizer moment per group (layers…, emb, pos, out, cls).
    pub opt_m: Vec<Vec<f32>>,
    /// Second optimizer moment per group.
    pub opt_v: Vec<Vec<f32>>,
    /// Per-layer flat θ.
    pub layers: Vec<Vec<f32>>,
    pub w_emb: Vec<f32>,
    pub w_pos: Vec<f32>,
    pub w_out: Vec<f32>,
    pub w_cls: Vec<f32>,
    /// Mid-range warm-start iterates when the saved session held valid
    /// ones (`None` otherwise): replica-major, `dp_degree.max(1)`
    /// contiguous runs of `parallel_layers() + 1` states — replica `r`'s
    /// `Z_{bo}..Z_{bo+n_mid}` occupies `warm[r·(P+1)..(r+1)·(P+1)]`.
    pub warm: Option<Vec<Tensor>>,
}

impl Checkpoint {
    /// Serialize and write to `path` (parent directories are created).
    ///
    /// The write is **atomic**: bytes land in `{path}.tmp`, are fsynced,
    /// and the file is renamed over `path` only then. A crash (or the
    /// `checkpoint.partial_write` fault point) mid-save can therefore
    /// never leave a truncated `*.ltcp` for `--resume` or the serve
    /// hot-reload watcher to trip on — at worst a stale `.tmp` litters
    /// the directory, which no reader matches.
    pub fn write(&self, path: &str) -> Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir).ok();
        }
        let bytes = self.encode();
        let tmp = format!("{}.tmp", path);
        {
            use std::io::Write;
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating checkpoint temp {}", tmp))?;
            if crate::faultpoint!("checkpoint.partial_write") {
                // simulate a crash mid-save: half the bytes reach the temp
                // file, the rename never happens, `path` is untouched
                f.write_all(&bytes[..bytes.len() / 2])
                    .with_context(|| format!("writing checkpoint temp {}", tmp))?;
                f.sync_all().ok();
                bail!("injected: checkpoint.partial_write (crash before rename)");
            }
            f.write_all(&bytes).with_context(|| format!("writing checkpoint temp {}", tmp))?;
            f.sync_all().with_context(|| format!("fsyncing checkpoint temp {}", tmp))?;
        }
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming {} over checkpoint {}", tmp, path))?;
        Ok(())
    }

    /// Read and fully validate a checkpoint written by [`Checkpoint::write`].
    pub fn read(path: &str) -> Result<Checkpoint> {
        let bytes =
            std::fs::read(path).with_context(|| format!("opening checkpoint {}", path))?;
        Checkpoint::decode(&bytes).with_context(|| format!("reading checkpoint {}", path))
    }

    /// The expected tensor-table layout for a config: (name, element count)
    /// for the parameter and optimizer entries, in file order.
    fn expected_entries(rc: &RunConfig) -> Vec<(String, usize)> {
        let m = &rc.model;
        let n_layers = m.total_layers();
        let head_sizes = [
            ("emb", m.vocab * m.d_model),
            ("pos", m.seq * m.d_model),
            ("out", m.d_model * m.vocab),
            ("cls", m.d_model * m.n_classes),
        ];
        let mut out = Vec::with_capacity(4 * n_layers + 12);
        for l in 0..n_layers {
            out.push((format!("param.layer.{}", l), m.layer_theta_len(l)));
        }
        for (name, len) in head_sizes {
            out.push((format!("param.{}", name), len));
        }
        // optimizer groups mirror ParamStore::group_sizes order
        for which in ["m", "v"] {
            for l in 0..n_layers {
                out.push((format!("opt.{}.{}", which, l), m.layer_theta_len(l)));
            }
            for (i, (_, len)) in head_sizes.iter().enumerate() {
                out.push((format!("opt.{}.{}", which, n_layers + i), *len));
            }
        }
        out
    }

    fn encode(&self) -> Vec<u8> {
        let mut b = Buf(Vec::new());
        b.bytes(MAGIC);
        b.u32(VERSION);
        let cfg = self.rc.to_json().to_string_compact();
        b.u32(cfg.len() as u32);
        b.bytes(cfg.as_bytes());
        b.u64(self.step as u64);
        b.opt_f32(self.initial_loss);
        b.opt_u64(self.switched_at.map(|v| v as u64));
        b.u8(self.warm_start as u8);
        b.u64(self.rng_state);
        b.opt_f32(self.rng_spare);
        let c = &self.controller;
        b.u64(c.probe_every as u64);
        b.f64(c.rho_switch);
        b.f64(c.rho_grow);
        b.u64(c.max_iters as u64);
        b.u64(c.step as u64);
        b.u8(c.switched as u8);
        b.u64(c.history_cap as u64);
        b.u32(c.history.len() as u32);
        for r in &c.history {
            b.u64(r.step as u64);
            b.opt_f64(r.rho_fwd);
            b.opt_f64(r.rho_bwd);
            b.u8(match r.decision {
                AdaptiveDecision::Keep => 0,
                AdaptiveDecision::IncreaseIters => 1,
                AdaptiveDecision::SwitchSerial => 2,
                AdaptiveDecision::Rollback => 3,
            });
        }
        b.u64(self.opt_t);
        // tensor table: params, opt moments, optional warm states
        let heads = [&self.w_emb, &self.w_pos, &self.w_out, &self.w_cls];
        let mut entries: Vec<(String, &[f32])> = Vec::new();
        for (l, v) in self.layers.iter().enumerate() {
            entries.push((format!("param.layer.{}", l), v));
        }
        for (name, v) in ["emb", "pos", "out", "cls"].iter().zip(heads) {
            entries.push((format!("param.{}", name), v));
        }
        for (which, groups) in [("m", &self.opt_m), ("v", &self.opt_v)] {
            for (g, v) in groups.iter().enumerate() {
                entries.push((format!("opt.{}.{}", which, g), v));
            }
        }
        if let Some(warm) = &self.warm {
            for (j, t) in warm.iter().enumerate() {
                entries.push((format!("warm.{}", j), t.data()));
            }
        }
        b.u32(entries.len() as u32);
        for (name, data) in &entries {
            b.u16(name.len() as u16);
            b.bytes(name.as_bytes());
            b.u64(data.len() as u64);
        }
        for (_, data) in &entries {
            for x in *data {
                b.bytes(&x.to_le_bytes());
            }
        }
        let sum = fnv1a64(&b.0);
        b.u64(sum);
        b.0
    }

    fn decode(bytes: &[u8]) -> Result<Checkpoint> {
        // checksum first: everything else assumes uncorrupted bytes
        if bytes.len() < MAGIC.len() + 4 + 8 {
            bail!("truncated checkpoint ({} bytes)", bytes.len());
        }
        let (body, tail) = bytes.split_at(bytes.len() - 8);
        let stored = u64::from_le_bytes(tail.try_into().expect("8-byte tail"));
        let computed = fnv1a64(body);
        if stored != computed {
            bail!("checksum mismatch (file corrupt or truncated mid-record)");
        }
        let mut r = Rdr { b: body, i: 0 };
        if r.take(4)? != MAGIC {
            bail!("bad magic: not a layertime session checkpoint");
        }
        let version = r.u32()?;
        if version != VERSION {
            bail!("unsupported checkpoint version {} (this build reads {})", version, VERSION);
        }
        let cfg_len = r.u32()? as usize;
        let cfg_text = std::str::from_utf8(r.take(cfg_len)?).context("config is not utf-8")?;
        let cfg_json = Json::parse(cfg_text).context("config JSON")?;
        let rc = RunConfig::from_json(&cfg_json)
            .ok_or_else(|| anyhow::anyhow!("config JSON is missing required fields"))?;
        let step = r.u64()? as usize;
        let initial_loss = r.opt_f32()?;
        let switched_at = r.opt_u64()?.map(|v| v as usize);
        let warm_start = r.u8()? != 0;
        let rng_state = r.u64()?;
        let rng_spare = r.opt_f32()?;
        let controller = ControllerState {
            probe_every: r.u64()? as usize,
            rho_switch: r.f64()?,
            rho_grow: r.f64()?,
            max_iters: r.u64()? as usize,
            step: r.u64()? as usize,
            switched: r.u8()? != 0,
            history_cap: r.u64()? as usize,
            history: {
                let n = r.u32()? as usize;
                let mut h = Vec::with_capacity(n.min(1 << 16));
                for _ in 0..n {
                    h.push(ProbeRecord {
                        step: r.u64()? as usize,
                        rho_fwd: r.opt_f64()?,
                        rho_bwd: r.opt_f64()?,
                        decision: match r.u8()? {
                            0 => AdaptiveDecision::Keep,
                            1 => AdaptiveDecision::IncreaseIters,
                            2 => AdaptiveDecision::SwitchSerial,
                            3 => AdaptiveDecision::Rollback,
                            d => bail!("unknown probe decision tag {}", d),
                        },
                    });
                }
                h
            },
        };
        let opt_t = r.u64()?;

        // tensor table, validated name-by-name against the config
        let n_entries = r.u32()? as usize;
        let mut names = Vec::with_capacity(n_entries);
        let mut counts = Vec::with_capacity(n_entries);
        for _ in 0..n_entries {
            let nl = r.u16()? as usize;
            let name = std::str::from_utf8(r.take(nl)?)
                .context("tensor-table entry name is not utf-8")?
                .to_string();
            names.push(name);
            counts.push(r.u64()? as usize);
        }
        let expected = Self::expected_entries(&rc);
        if n_entries < expected.len() {
            bail!(
                "tensor table has {} entries, config requires at least {}",
                n_entries,
                expected.len()
            );
        }
        for (i, (want_name, want_len)) in expected.iter().enumerate() {
            if &names[i] != want_name {
                bail!("tensor-table entry {}: expected '{}', found '{}'", i, want_name, names[i]);
            }
            if counts[i] != *want_len {
                bail!(
                    "tensor '{}' has {} elements, config requires {}",
                    want_name,
                    counts[i],
                    want_len
                );
            }
        }
        // trailing entries must be exactly the warm-start section
        let n_warm = n_entries - expected.len();
        let state_shape = rc.model.state_shape();
        let state_elems: usize = state_shape.iter().product();
        if n_warm != 0 {
            let want_warm = rc.dp_degree.max(1) * (rc.model.parallel_layers() + 1);
            if n_warm != want_warm {
                bail!(
                    "warm-start section has {} states, config requires {} (dp × (parallel_layers + 1))",
                    n_warm,
                    want_warm
                );
            }
            for (j, (name, count)) in
                names[expected.len()..].iter().zip(&counts[expected.len()..]).enumerate()
            {
                if name != &format!("warm.{}", j) {
                    bail!("unexpected tensor-table entry '{}' in the warm section", name);
                }
                if *count != state_elems {
                    bail!(
                        "warm state {} has {} elements, state shape {:?} requires {}",
                        j,
                        count,
                        state_shape,
                        state_elems
                    );
                }
            }
        }

        // payloads, in table order
        let mut payloads = Vec::with_capacity(n_entries);
        for &count in &counts {
            payloads.push(r.f32s(count)?);
        }
        if r.i != body.len() {
            bail!("{} trailing bytes after the last payload", body.len() - r.i);
        }
        let n_layers = rc.model.total_layers();
        let mut it = payloads.into_iter();
        let layers: Vec<Vec<f32>> = (0..n_layers).map(|_| it.next().unwrap()).collect();
        let w_emb = it.next().unwrap();
        let w_pos = it.next().unwrap();
        let w_out = it.next().unwrap();
        let w_cls = it.next().unwrap();
        let opt_m: Vec<Vec<f32>> = (0..n_layers + 4).map(|_| it.next().unwrap()).collect();
        let opt_v: Vec<Vec<f32>> = (0..n_layers + 4).map(|_| it.next().unwrap()).collect();
        let warm = if n_warm > 0 {
            Some(it.map(|v| Tensor::from_vec(v, &state_shape)).collect())
        } else {
            None
        };
        Ok(Checkpoint {
            rc,
            step,
            initial_loss,
            switched_at,
            warm_start,
            rng_state,
            rng_spare,
            controller,
            opt_t,
            opt_m,
            opt_v,
            layers,
            w_emb,
            w_pos,
            w_out,
            w_cls,
            warm,
        })
    }
}

/// FNV-1a (64-bit) over a byte slice — the corruption tripwire appended to
/// every checkpoint. Not cryptographic; it catches torn writes and bit rot.
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Little-endian byte-sink used by the encoder.
struct Buf(Vec<u8>);

impl Buf {
    fn bytes(&mut self, b: &[u8]) {
        self.0.extend_from_slice(b);
    }
    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }
    fn u16(&mut self, v: u16) {
        self.bytes(&v.to_le_bytes());
    }
    fn u32(&mut self, v: u32) {
        self.bytes(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.bytes(&v.to_le_bytes());
    }
    fn opt_f32(&mut self, v: Option<f32>) {
        self.u8(v.is_some() as u8);
        self.bytes(&v.unwrap_or(0.0).to_le_bytes());
    }
    fn opt_f64(&mut self, v: Option<f64>) {
        self.u8(v.is_some() as u8);
        self.f64(v.unwrap_or(0.0));
    }
    fn opt_u64(&mut self, v: Option<u64>) {
        self.u8(v.is_some() as u8);
        self.u64(v.unwrap_or(0));
    }
}

/// Bounds-checked little-endian reader over the (checksum-verified) body.
struct Rdr<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Rdr<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("truncated checkpoint (wanted {} bytes at offset {})", n, self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn opt_f32(&mut self) -> Result<Option<f32>> {
        let flag = self.u8()? != 0;
        let v = self.f32()?;
        Ok(flag.then_some(v))
    }
    fn opt_f64(&mut self) -> Result<Option<f64>> {
        let flag = self.u8()? != 0;
        let v = self.f64()?;
        Ok(flag.then_some(v))
    }
    fn opt_u64(&mut self) -> Result<Option<u64>> {
        let flag = self.u8()? != 0;
        let v = self.u64()?;
        Ok(flag.then_some(v))
    }
    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::model::{Init, ParamStore};

    fn tiny_checkpoint() -> Checkpoint {
        let mut rc = presets::mc_tiny();
        presets::shrink_for_bench(&mut rc);
        rc.model.n_enc_layers = 3;
        let ps = ParamStore::init(&rc.model, Init::Default, 7);
        let n = rc.model.total_layers();
        let sizes = ps.group_sizes();
        let layers = ps.layers.read().unwrap().clone();
        Checkpoint {
            rc: rc.clone(),
            step: 42,
            initial_loss: Some(1.5),
            switched_at: None,
            warm_start: true,
            rng_state: u64::MAX - 3,
            rng_spare: Some(-0.25),
            controller: ControllerState {
                probe_every: 50,
                rho_switch: 1.0,
                rho_grow: 0.9,
                max_iters: 8,
                step: 42,
                switched: false,
                history_cap: 512,
                history: vec![ProbeRecord {
                    step: 40,
                    rho_fwd: Some(0.3),
                    rho_bwd: None,
                    decision: AdaptiveDecision::Keep,
                }],
            },
            opt_t: 42,
            opt_m: sizes.iter().map(|&s| vec![0.5; s]).collect(),
            opt_v: sizes.iter().map(|&s| vec![0.25; s]).collect(),
            layers,
            w_emb: ps.w_emb.clone(),
            w_pos: ps.w_pos.clone(),
            w_out: ps.w_out.clone(),
            w_cls: ps.w_cls.clone(),
            warm: Some(
                (0..=n).map(|j| Tensor::from_vec(
                    vec![j as f32; rc.model.state_shape().iter().product()],
                    &rc.model.state_shape(),
                )).collect(),
            ),
        }
    }

    #[test]
    fn autosave_naming_is_chronological() {
        assert_eq!(
            autosave_path("runs/gpt.ltcp", 40),
            format!("runs{}gpt.step00000040.ltcp", std::path::MAIN_SEPARATOR)
        );
        assert_eq!(autosave_path("gpt.ltcp", 7), "gpt.step00000007.ltcp");
        let a = autosave_path("m.ltcp", 9);
        let b = autosave_path("m.ltcp", 10);
        assert!(a < b, "zero-padding keeps lexicographic = chronological");
    }

    #[test]
    fn prune_keeps_the_newest_autosaves() {
        let dir = std::env::temp_dir().join(format!("layertime_prune_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let base_buf = dir.join("m.ltcp");
        let base = base_buf.to_str().unwrap();
        for step in [1usize, 2, 3, 4] {
            std::fs::write(autosave_path(base, step), b"x").unwrap();
        }
        // the base save itself is not part of the autosave family
        std::fs::write(&base_buf, b"x").unwrap();
        assert_eq!(prune_autosaves(base, 2), 2);
        let mut left: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        left.sort();
        assert_eq!(left, vec!["m.ltcp", "m.step00000003.ltcp", "m.step00000004.ltcp"]);
        assert_eq!(prune_autosaves(base, 2), 0, "already at retention");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn write_is_atomic_and_leaves_no_temp() {
        let dir = std::env::temp_dir().join(format!("layertime_atomic_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path_buf = dir.join("ck.ltcp");
        let path = path_buf.to_str().unwrap();
        let ck = tiny_checkpoint();
        ck.write(path).unwrap();
        assert!(!std::path::Path::new(&format!("{}.tmp", path)).exists(), "temp must be renamed away");
        assert_eq!(Checkpoint::read(path).unwrap().step, ck.step);
        // overwriting an existing checkpoint goes through the same rename
        let mut ck2 = ck.clone();
        ck2.step = 43;
        ck2.write(path).unwrap();
        assert_eq!(Checkpoint::read(path).unwrap().step, 43);
        assert!(!std::path::Path::new(&format!("{}.tmp", path)).exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn encode_decode_roundtrip() {
        let ck = tiny_checkpoint();
        let bytes = ck.encode();
        let back = Checkpoint::decode(&bytes).unwrap();
        assert_eq!(back.rc, ck.rc);
        assert_eq!(back.step, ck.step);
        assert_eq!(back.initial_loss, ck.initial_loss);
        assert_eq!(back.switched_at, ck.switched_at);
        assert_eq!(back.warm_start, ck.warm_start);
        assert_eq!(back.rng_state, ck.rng_state);
        assert_eq!(back.rng_spare, ck.rng_spare);
        assert_eq!(back.controller, ck.controller);
        assert_eq!(back.opt_t, ck.opt_t);
        assert_eq!(back.opt_m, ck.opt_m);
        assert_eq!(back.opt_v, ck.opt_v);
        assert_eq!(back.layers, ck.layers);
        assert_eq!(back.w_emb, ck.w_emb);
        assert_eq!(back.w_cls, ck.w_cls);
        let (a, b) = (back.warm.unwrap(), ck.warm.unwrap());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.shape(), y.shape());
            assert_eq!(x.data(), y.data());
        }
    }

    #[test]
    fn truncated_and_corrupt_bytes_are_rejected() {
        let bytes = tiny_checkpoint().encode();
        // truncation at every-ish prefix length fails cleanly
        for cut in [0, 3, 11, bytes.len() / 2, bytes.len() - 1] {
            assert!(Checkpoint::decode(&bytes[..cut]).is_err(), "cut at {}", cut);
        }
        // a single flipped payload byte trips the checksum
        let mut bad = bytes.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x40;
        let err = Checkpoint::decode(&bad).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{}", err);
        // bad magic (fix the checksum so the magic check itself fires)
        let mut bad = bytes.clone();
        bad[0] = b'X';
        let n = bad.len();
        let sum = fnv1a64(&bad[..n - 8]);
        bad[n - 8..].copy_from_slice(&sum.to_le_bytes());
        let err = Checkpoint::decode(&bad).unwrap_err().to_string();
        assert!(err.contains("magic"), "{}", err);
        // unknown version
        let mut bad = bytes.clone();
        bad[4..8].copy_from_slice(&99u32.to_le_bytes());
        let sum = fnv1a64(&bad[..n - 8]);
        bad[n - 8..].copy_from_slice(&sum.to_le_bytes());
        let err = Checkpoint::decode(&bad).unwrap_err().to_string();
        assert!(err.contains("version"), "{}", err);
    }

    #[test]
    fn config_mismatches_are_rejected() {
        // a layer payload whose length disagrees with the config
        let mut ck = tiny_checkpoint();
        ck.layers[0].pop();
        let err = Checkpoint::decode(&ck.encode()).unwrap_err().to_string();
        assert!(err.contains("param.layer.0"), "{}", err);
        // wrong warm-state count
        let mut ck = tiny_checkpoint();
        ck.warm.as_mut().unwrap().pop();
        let err = Checkpoint::decode(&ck.encode()).unwrap_err().to_string();
        assert!(err.contains("warm"), "{}", err);
        // wrong optimizer group size
        let mut ck = tiny_checkpoint();
        ck.opt_v.last_mut().unwrap().push(0.0);
        let err = Checkpoint::decode(&ck.encode()).unwrap_err().to_string();
        assert!(err.contains("opt.v"), "{}", err);
        // no warm section at all is fine
        let mut ck = tiny_checkpoint();
        ck.warm = None;
        assert!(Checkpoint::decode(&ck.encode()).unwrap().warm.is_none());
    }

    #[test]
    fn dp_checkpoints_carry_one_warm_iterate_per_replica() {
        // dp = 2: the warm section is replica-major, 2 × (P + 1) states
        let mut ck = tiny_checkpoint();
        ck.rc.dp_degree = 2;
        let per = ck.rc.model.parallel_layers() + 1;
        let shape = ck.rc.model.state_shape();
        let elems: usize = shape.iter().product();
        ck.warm = Some(
            (0..2 * per)
                .map(|j| Tensor::from_vec(vec![j as f32; elems], &shape))
                .collect(),
        );
        let back = Checkpoint::decode(&ck.encode()).unwrap();
        let warm = back.warm.unwrap();
        assert_eq!(warm.len(), 2 * per);
        // replica 1's run starts at index P + 1, values untouched
        assert_eq!(warm[per].data()[0], per as f32);
        // a single-replica-sized warm section no longer matches dp = 2
        let mut short = ck.clone();
        short.warm.as_mut().unwrap().truncate(per);
        let err = Checkpoint::decode(&short.encode()).unwrap_err().to_string();
        assert!(err.contains("warm-start section"), "{}", err);
    }
}
