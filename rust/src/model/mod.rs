//! Model state: parameter store, initialization schemes, checkpoints.
//!
//! Layer parameters live in a shared `Arc<RwLock<Vec<Vec<f32>>>>` (one flat
//! θ per layer, layout = manifest's `param_layout`) so the propagators —
//! including threaded-backend workers — and the optimizer view the same
//! storage. Embedding/head parameters are plain vectors owned here.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::config::ModelConfig;
use crate::ode::RustPropagator;
use crate::util::rng::Rng;

pub use crate::ode::{shared_params, SharedParams};

/// All trainable state of one run.
pub struct ParamStore {
    pub model: ModelConfig,
    /// Per-layer flat θ (enc layout; dec layout past n_enc for EncDec).
    pub layers: SharedParams,
    /// Token embedding [V, D].
    pub w_emb: Vec<f32>,
    /// Positional embedding [S, D].
    pub w_pos: Vec<f32>,
    /// LM head [D, V].
    pub w_out: Vec<f32>,
    /// Classifier head [D, C].
    pub w_cls: Vec<f32>,
}

/// Initialization scheme.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Init {
    /// N(0, 0.02) matrices, identity LayerNorm (GPT-2 style default).
    Default,
    /// Pre-LN stability scaling for very deep nets (paper Appendix C /
    /// DeepNet): value/output/MLP projections divided by √(ln 2L).
    DeepNet,
}

/// Fill one layer's flat θ according to the layout and scheme.
fn init_layer(model: &ModelConfig, layer: usize, scheme: Init, rng: &mut Rng) -> Vec<f32> {
    let (d, f) = (model.d_model, model.d_ff);
    let n_layers = model.total_layers().max(1);
    let deep_scale = match scheme {
        Init::Default => 1.0,
        Init::DeepNet => 1.0 / (2.0 * n_layers as f32).ln().sqrt(),
    };
    // (name, rows, cols, kind): kind g=gamma, b=bias/beta, w=plain, s=scaled
    let mut fields: Vec<(&str, usize, usize, char)> = vec![
        ("ln1_g", d, 1, 'g'),
        ("ln1_b", d, 1, 'b'),
        ("wq", d, d, 'w'),
        ("wk", d, d, 'w'),
        ("wv", d, d, 's'),
        ("wo", d, d, 's'),
        ("ln2_g", d, 1, 'g'),
        ("ln2_b", d, 1, 'b'),
        ("w1", d, f, 's'),
        ("b1", f, 1, 'b'),
        ("w2", f, d, 's'),
        ("b2", d, 1, 'b'),
    ];
    if model.layer_theta_len(layer) == model.p_dec() {
        fields.extend([
            ("ln3_g", d, 1, 'g'),
            ("ln3_b", d, 1, 'b'),
            ("cq", d, d, 'w'),
            ("ck", d, d, 'w'),
            ("cv", d, d, 's'),
            ("co", d, d, 's'),
        ]);
    }
    let mut theta = Vec::with_capacity(model.layer_theta_len(layer));
    for (_, rows, cols, kind) in fields {
        let n = rows * cols;
        match kind {
            'g' => theta.extend(std::iter::repeat(1.0f32).take(n)),
            'b' => theta.extend(std::iter::repeat(0.0f32).take(n)),
            'w' => theta.extend(rng.normal_vec(n, 0.02)),
            's' => theta.extend(rng.normal_vec(n, 0.02 * deep_scale)),
            _ => unreachable!(),
        }
    }
    debug_assert_eq!(theta.len(), model.layer_theta_len(layer));
    theta
}

impl ParamStore {
    /// Fresh parameters for a model config.
    pub fn init(model: &ModelConfig, scheme: Init, seed: u64) -> ParamStore {
        let mut rng = Rng::new(seed);
        let layers: Vec<Vec<f32>> = (0..model.total_layers())
            .map(|l| init_layer(model, l, scheme, &mut rng))
            .collect();
        let (v, d, s, c) = (model.vocab, model.d_model, model.seq, model.n_classes);
        ParamStore {
            model: model.clone(),
            layers: shared_params(layers),
            w_emb: rng.normal_vec(v * d, 0.02),
            w_pos: rng.normal_vec(s * d, 0.02),
            w_out: rng.normal_vec(d * v, 0.02),
            w_cls: rng.normal_vec(d * c, 0.02),
        }
    }

    /// Total trainable parameter count.
    pub fn n_params(&self) -> usize {
        self.layers.read().unwrap().iter().map(|l| l.len()).sum::<usize>()
            + self.w_emb.len()
            + self.w_pos.len()
            + self.w_out.len()
            + self.w_cls.len()
    }

    /// Flat-group sizes in optimizer order: layers…, emb, pos, out, cls.
    pub fn group_sizes(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.layers.read().unwrap().iter().map(|l| l.len()).collect();
        v.extend([self.w_emb.len(), self.w_pos.len(), self.w_out.len(), self.w_cls.len()]);
        v
    }

    /// Assemble a store from already-validated flat groups (the session
    /// checkpoint loader's entry point; [`crate::checkpoint`] has checked
    /// every length against `model` before this is called).
    pub fn from_parts(
        model: ModelConfig,
        layers: Vec<Vec<f32>>,
        w_emb: Vec<f32>,
        w_pos: Vec<f32>,
        w_out: Vec<f32>,
        w_cls: Vec<f32>,
    ) -> ParamStore {
        ParamStore { model, layers: shared_params(layers), w_emb, w_pos, w_out, w_cls }
    }

    /// Deep copy (for serial-vs-parallel comparison runs from one init).
    pub fn deep_clone(&self) -> ParamStore {
        ParamStore {
            model: self.model.clone(),
            layers: shared_params(self.layers.read().unwrap().clone()),
            w_emb: self.w_emb.clone(),
            w_pos: self.w_pos.clone(),
            w_out: self.w_out.clone(),
            w_cls: self.w_cls.clone(),
        }
    }

    /// Binary checkpoint (magic + version + sizes + LE f32 payloads).
    pub fn save(&self, path: &str) -> Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir).ok();
        }
        let mut w =
            std::io::BufWriter::new(std::fs::File::create(path).context("creating checkpoint")?);
        w.write_all(b"LTCK")?;
        w.write_all(&1u32.to_le_bytes())?;
        let layers = self.layers.read().unwrap();
        w.write_all(&(layers.len() as u32).to_le_bytes())?;
        let write_vec = |w: &mut dyn Write, v: &[f32]| -> Result<()> {
            w.write_all(&(v.len() as u64).to_le_bytes())?;
            for x in v {
                w.write_all(&x.to_le_bytes())?;
            }
            Ok(())
        };
        for l in layers.iter() {
            write_vec(&mut w, l)?;
        }
        for v in [&self.w_emb, &self.w_pos, &self.w_out, &self.w_cls] {
            write_vec(&mut w, v)?;
        }
        Ok(())
    }

    /// Load a checkpoint saved by [`ParamStore::save`]; shapes must match.
    pub fn load(model: &ModelConfig, path: &str) -> Result<ParamStore> {
        let mut r =
            std::io::BufReader::new(std::fs::File::open(path).context("opening checkpoint")?);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != b"LTCK" {
            bail!("not a layertime checkpoint");
        }
        let mut buf4 = [0u8; 4];
        r.read_exact(&mut buf4)?;
        if u32::from_le_bytes(buf4) != 1 {
            bail!("unsupported checkpoint version");
        }
        r.read_exact(&mut buf4)?;
        let n_layers = u32::from_le_bytes(buf4) as usize;
        if n_layers != model.total_layers() {
            bail!("checkpoint has {} layers, config needs {}", n_layers, model.total_layers());
        }
        let read_vec = |r: &mut dyn Read| -> Result<Vec<f32>> {
            let mut b8 = [0u8; 8];
            r.read_exact(&mut b8)?;
            let n = u64::from_le_bytes(b8) as usize;
            let mut out = vec![0.0f32; n];
            let mut b4 = [0u8; 4];
            for x in out.iter_mut() {
                r.read_exact(&mut b4)?;
                *x = f32::from_le_bytes(b4);
            }
            Ok(out)
        };
        let mut layers = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            let v = read_vec(&mut r)?;
            if v.len() != model.layer_theta_len(l) {
                bail!("layer {} length mismatch", l);
            }
            layers.push(v);
        }
        let w_emb = read_vec(&mut r)?;
        let w_pos = read_vec(&mut r)?;
        let w_out = read_vec(&mut r)?;
        let w_cls = read_vec(&mut r)?;
        Ok(ParamStore {
            model: model.clone(),
            layers: shared_params(layers),
            w_emb,
            w_pos,
            w_out,
            w_cls,
        })
    }

    /// Buffer-aware propagator over all layers (Δt per layer from
    /// `ode::layer_hs`); the coordinator drives buffer layers serially and
    /// MGRIT over the middle range.
    pub fn rust_propagator(&self) -> RustPropagator {
        RustPropagator::for_model(&self.model, self.layers.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn init_shapes_and_ln_identity() {
        let m = presets::mc_tiny().model;
        let ps = ParamStore::init(&m, Init::Default, 0);
        let layers = ps.layers.read().unwrap();
        assert_eq!(layers.len(), m.total_layers());
        assert_eq!(layers[0].len(), m.p_enc());
        // ln1_g is all ones, ln1_b all zeros
        let d = m.d_model;
        assert!(layers[0][..d].iter().all(|&x| x == 1.0));
        assert!(layers[0][d..2 * d].iter().all(|&x| x == 0.0));
        assert!(ps.n_params() > 0);
    }

    #[test]
    fn deepnet_scaling_shrinks_value_proj() {
        let mut m = presets::bert_deep().model;
        m.n_enc_layers = 128;
        let a = ParamStore::init(&m, Init::Default, 1);
        let b = ParamStore::init(&m, Init::DeepNet, 1);
        let d = m.d_model;
        // wv block starts after ln1(2d) + wq + wk
        let off = 2 * d + 2 * d * d;
        let std_of = |ps: &ParamStore| {
            let layers = ps.layers.read().unwrap();
            let w = &layers[0][off..off + d * d];
            (w.iter().map(|x| x * x).sum::<f32>() / w.len() as f32).sqrt()
        };
        let ratio = std_of(&b) / std_of(&a);
        let want = 1.0 / (2.0 * 128.0f32).ln().sqrt();
        assert!((ratio - want).abs() < 0.05, "ratio {} want {}", ratio, want);
    }

    #[test]
    fn encdec_layers_have_two_lengths() {
        let m = presets::mt_small().model;
        let ps = ParamStore::init(&m, Init::Default, 2);
        let layers = ps.layers.read().unwrap();
        assert_eq!(layers[0].len(), m.p_enc());
        assert_eq!(layers[m.n_enc_layers].len(), m.p_dec());
    }

    #[test]
    fn checkpoint_roundtrip() {
        let m = presets::mc_tiny().model;
        let ps = ParamStore::init(&m, Init::Default, 3);
        let path = std::env::temp_dir().join("layertime_ck_test.bin");
        let path = path.to_str().unwrap();
        ps.save(path).unwrap();
        let ps2 = ParamStore::load(&m, path).unwrap();
        assert_eq!(*ps.layers.read().unwrap(), *ps2.layers.read().unwrap());
        assert_eq!(ps.w_emb, ps2.w_emb);
        assert_eq!(ps.w_cls, ps2.w_cls);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn checkpoint_rejects_wrong_depth() {
        let m = presets::mc_tiny().model;
        let ps = ParamStore::init(&m, Init::Default, 4);
        let path = std::env::temp_dir().join("layertime_ck_test2.bin");
        let path = path.to_str().unwrap();
        ps.save(path).unwrap();
        let mut m2 = m.clone();
        m2.n_enc_layers += 1;
        assert!(ParamStore::load(&m2, path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn group_sizes_cover_everything() {
        let m = presets::mc_tiny().model;
        let ps = ParamStore::init(&m, Init::Default, 5);
        assert_eq!(ps.group_sizes().iter().sum::<usize>(), ps.n_params());
        assert_eq!(ps.group_sizes().len(), m.total_layers() + 4);
    }
}
