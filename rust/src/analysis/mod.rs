//! Analysis tooling: BLEU (MT metric), Monte-Carlo Lipschitz estimation
//! (paper Appendix B, Figs. 10-11), and weight-drift tracking.

pub mod bleu;
pub mod lipschitz;

pub use bleu::bleu4;
pub use lipschitz::{estimate_layer_lipschitz, weight_drift};
