//! Monte-Carlo Lipschitz estimation per layer (paper Appendix B).
//!
//! The Jacobian of a transformer layer is intractable to form, so the paper
//! estimates each layer's Lipschitz constant by sampling: draw pairs of
//! nearby inputs, propagate both, and take the max ratio
//! ‖Φ(z+δ) − Φ(z)‖ / ‖δ‖. Layers whose estimate is large destabilize the
//! Euler/MGRIT iteration (error amplification (1 + Δt f')ⁿ) and are the
//! candidates for serial "buffer" placement.

use crate::ode::Propagator;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Estimate L(layer) for every layer of a propagator.
///
/// * `base_states` — representative inputs per layer (e.g. states from a
///   forward solve on a real batch); estimates are taken around them.
/// * `samples` — random directions per layer (paper uses a modest MC budget).
/// * `eps` — probe radius.
pub fn estimate_layer_lipschitz<P: Propagator + ?Sized>(
    prop: &P,
    base_states: &[Tensor],
    samples: usize,
    eps: f32,
    rng: &mut Rng,
) -> Vec<f32> {
    let n = prop.n_steps();
    assert!(base_states.len() >= n, "need a base state per layer");
    let mut out = Vec::with_capacity(n);
    for layer in 0..n {
        let z = &base_states[layer];
        let fz = prop.step(layer, 1.0, z);
        let mut max_ratio = 0.0f32;
        for _ in 0..samples {
            let mut dir = Tensor::randn(rng, z.shape(), 1.0);
            let norm = dir.norm().max(1e-12);
            dir.scale(eps / norm);
            let mut zp = z.clone();
            zp.axpy(1.0, &dir);
            let fzp = prop.step(layer, 1.0, &zp);
            let ratio = fzp.dist(&fz) / eps;
            max_ratio = max_ratio.max(ratio);
        }
        out.push(max_ratio);
    }
    out
}

/// Relative weight drift ‖w − w₀‖ / ‖w₀‖ per layer (paper Fig. 11).
pub fn weight_drift(current: &[Vec<f32>], initial: &[Vec<f32>]) -> Vec<f32> {
    current
        .iter()
        .zip(initial)
        .map(|(w, w0)| {
            let mut num = 0.0f64;
            let mut den = 0.0f64;
            for (a, b) in w.iter().zip(w0) {
                num += ((a - b) as f64).powi(2);
                den += (*b as f64).powi(2);
            }
            (num.sqrt() / den.sqrt().max(1e-12)) as f32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::LinearOde;

    #[test]
    fn linear_ode_lipschitz_matches_operator_norm_bound() {
        // Φ = I + hA: L ≤ ‖I + hA‖₂; MC estimate must sit below and near it.
        let mut rng = Rng::new(1);
        let ode = LinearOde::random_stable(&mut rng, 6, 4, 0.1);
        let states: Vec<Tensor> =
            (0..4).map(|_| Tensor::randn(&mut rng, &[6, 1], 1.0)).collect();
        let est = estimate_layer_lipschitz(&ode, &states, 64, 1e-2, &mut rng);
        assert_eq!(est.len(), 4);
        for &l in &est {
            assert!(l > 0.3 && l < 2.0, "estimate {}", l);
        }
        // linear map: estimate is input-independent across layers
        let spread = est.iter().cloned().fold(0.0f32, f32::max)
            - est.iter().cloned().fold(f32::INFINITY, f32::min);
        assert!(spread < 0.3, "spread {}", spread);
    }

    #[test]
    fn drift_zero_at_init_and_grows() {
        let w0 = vec![vec![1.0f32, 2.0], vec![3.0, 4.0]];
        let same = weight_drift(&w0, &w0);
        assert!(same.iter().all(|&d| d == 0.0));
        let moved = vec![vec![1.1f32, 2.0], vec![3.0, 4.0]];
        let d = weight_drift(&moved, &w0);
        assert!(d[0] > 0.0 && d[1] == 0.0);
    }
}
