//! Corpus BLEU-4 with smoothing — the validation metric of the MT task
//! (paper Fig. 3 right).

use std::collections::HashMap;

fn ngram_counts(seq: &[i32], n: usize) -> HashMap<&[i32], usize> {
    let mut m: HashMap<&[i32], usize> = HashMap::new();
    if seq.len() >= n {
        for i in 0..=seq.len() - n {
            *m.entry(&seq[i..i + n]).or_insert(0) += 1;
        }
    }
    m
}

/// Corpus-level BLEU-4 (uniform weights, +1 smoothing on higher orders,
/// brevity penalty). `pairs` is (hypothesis, reference) token sequences.
pub fn bleu4(pairs: &[(Vec<i32>, Vec<i32>)]) -> f64 {
    let max_n = 4;
    let mut match_n = [0usize; 4];
    let mut total_n = [0usize; 4];
    let mut hyp_len = 0usize;
    let mut ref_len = 0usize;
    for (hyp, rf) in pairs {
        hyp_len += hyp.len();
        ref_len += rf.len();
        for n in 1..=max_n {
            let h = ngram_counts(hyp, n);
            let r = ngram_counts(rf, n);
            for (g, c) in &h {
                let rc = r.get(g).copied().unwrap_or(0);
                match_n[n - 1] += (*c).min(rc);
            }
            total_n[n - 1] += hyp.len().saturating_sub(n - 1);
        }
    }
    if hyp_len == 0 {
        return 0.0;
    }
    let mut logsum = 0.0f64;
    for n in 0..max_n {
        // +1 smoothing for n >= 2 (Lin & Och smoothing-2)
        let (m, t) = if n == 0 {
            (match_n[0] as f64, total_n[0] as f64)
        } else {
            (match_n[n] as f64 + 1.0, total_n[n] as f64 + 1.0)
        };
        if m == 0.0 || t == 0.0 {
            return 0.0;
        }
        logsum += (m / t).ln() / max_n as f64;
    }
    let bp = if hyp_len >= ref_len { 1.0 } else { (1.0 - ref_len as f64 / hyp_len as f64).exp() };
    bp * logsum.exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_match_is_one() {
        let s = vec![1, 2, 3, 4, 5, 6, 7, 8];
        let b = bleu4(&[(s.clone(), s)]);
        assert!((b - 1.0).abs() < 1e-9, "{}", b);
    }

    #[test]
    fn disjoint_is_near_zero() {
        let b = bleu4(&[(vec![1, 2, 3, 4, 5, 6], vec![7, 8, 9, 10, 11, 12])]);
        assert!(b < 0.05, "{}", b);
    }

    #[test]
    fn partial_overlap_between() {
        let hyp = vec![1, 2, 3, 4, 9, 9, 9, 9];
        let rf = vec![1, 2, 3, 4, 5, 6, 7, 8];
        let b = bleu4(&[(hyp, rf)]);
        assert!(b > 0.05 && b < 0.9, "{}", b);
    }

    #[test]
    fn brevity_penalty_applies() {
        let rf = vec![1, 2, 3, 4, 5, 6, 7, 8];
        let full = bleu4(&[(rf.clone(), rf.clone())]);
        let short = bleu4(&[(rf[..4].to_vec(), rf)]);
        assert!(short < full);
    }

    #[test]
    fn corpus_level_aggregates() {
        let a = (vec![1, 2, 3, 4], vec![1, 2, 3, 4]);
        let b = (vec![5, 6, 7, 8], vec![8, 7, 6, 5]);
        let corpus = bleu4(&[a.clone(), b]);
        let solo = bleu4(&[a]);
        assert!(corpus < solo);
    }
}
