//! Optimizers (SGD+momentum, Adam, AdamW) and LR schedules.
//!
//! The trainer keeps parameters as flat `f32` groups (per-layer θ vectors
//! plus embedding/head matrices); the optimizer holds per-group moment
//! state. AdamW applies decoupled weight decay (the paper's BERT/GPT runs);
//! Adam couples none; SGD matches the MC task's configuration (Table 2).

use crate::config::OptKind;

/// Warmup + decay learning-rate schedule.
#[derive(Debug, Clone)]
pub struct LrSchedule {
    pub base_lr: f32,
    pub warmup: usize,
    pub decay: Decay,
}

/// Post-warmup decay law.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Decay {
    Constant,
    /// lr · √(warmup/step) (transformer classic).
    InvSqrt,
    /// Cosine to `min_frac·lr` over `total` steps.
    Cosine { total: usize, min_frac: f32 },
}

impl LrSchedule {
    pub fn constant(lr: f32) -> LrSchedule {
        LrSchedule { base_lr: lr, warmup: 0, decay: Decay::Constant }
    }

    /// LR at 1-based step `t`.
    pub fn at(&self, t: usize) -> f32 {
        let t = t.max(1);
        if self.warmup > 0 && t <= self.warmup {
            return self.base_lr * t as f32 / self.warmup as f32;
        }
        match self.decay {
            Decay::Constant => self.base_lr,
            Decay::InvSqrt => {
                let w = self.warmup.max(1) as f32;
                self.base_lr * (w / t as f32).sqrt()
            }
            Decay::Cosine { total, min_frac } => {
                let total = total.max(self.warmup + 1);
                let prog = ((t - self.warmup) as f32 / (total - self.warmup) as f32).min(1.0);
                let cos = 0.5 * (1.0 + (std::f32::consts::PI * prog).cos());
                self.base_lr * (min_frac + (1.0 - min_frac) * cos)
            }
        }
    }
}

/// Uniform optimizer over named flat parameter groups.
pub struct Optimizer {
    kind: OptKind,
    /// Adam moments / SGD momentum per group.
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    t: u64,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub momentum: f32,
    pub weight_decay: f32,
}

impl Optimizer {
    pub fn new(kind: OptKind, group_sizes: &[usize], weight_decay: f32) -> Optimizer {
        Optimizer {
            kind,
            m: group_sizes.iter().map(|&n| vec![0.0; n]).collect(),
            v: group_sizes.iter().map(|&n| vec![0.0; n]).collect(),
            t: 0,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            momentum: 0.9,
            weight_decay,
        }
    }

    pub fn n_groups(&self) -> usize {
        self.m.len()
    }

    /// Bias-correction step counter (checkpointing).
    pub fn step_count(&self) -> u64 {
        self.t
    }

    /// Per-group moment state `(m, v)` for checkpointing. SGD uses only
    /// `m` (momentum); `v` stays zero-filled and round-trips as such.
    pub fn moments(&self) -> (&[Vec<f32>], &[Vec<f32>]) {
        (&self.m, &self.v)
    }

    /// Are all moment entries finite? The invariant the session's
    /// non-finite guard protects: a skipped/rolled-back step must never
    /// leak NaN/Inf into the Adam state (pinned by `rust/tests/chaos.rs`).
    pub fn moments_finite(&self) -> bool {
        self.m.iter().chain(self.v.iter()).all(|g| g.iter().all(|x| x.is_finite()))
    }

    /// Restore moment state saved by [`Optimizer::moments`] plus the step
    /// counter. Group count and sizes must match this optimizer exactly
    /// (the checkpoint loader validates them against the model config
    /// before this is reached, so a mismatch here is a logic error).
    pub fn restore_moments(&mut self, m: Vec<Vec<f32>>, v: Vec<Vec<f32>>, t: u64) {
        assert_eq!(m.len(), self.m.len(), "optimizer group count changed");
        assert_eq!(v.len(), self.v.len(), "optimizer group count changed");
        for (g, (a, b)) in m.iter().zip(&self.m).enumerate() {
            assert_eq!(a.len(), b.len(), "optimizer group {} size changed", g);
        }
        for (g, (a, b)) in v.iter().zip(&self.v).enumerate() {
            assert_eq!(a.len(), b.len(), "optimizer group {} size changed", g);
        }
        self.m = m;
        self.v = v;
        self.t = t;
    }

    /// Begin an optimizer step (advances Adam's bias-correction counter).
    pub fn begin_step(&mut self) {
        self.t += 1;
    }

    /// Update one group in place. Call `begin_step` once per batch first.
    pub fn update(&mut self, group: usize, lr: f32, params: &mut [f32], grads: &[f32]) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.m[group].len(), "group {} size changed", group);
        match self.kind {
            OptKind::Sgd => {
                let mom = &mut self.m[group];
                for i in 0..params.len() {
                    mom[i] = self.momentum * mom[i] + grads[i];
                    params[i] -= lr * mom[i];
                }
            }
            OptKind::Adam | OptKind::AdamW => {
                let t = self.t.max(1) as i32;
                let bc1 = 1.0 - self.beta1.powi(t);
                let bc2 = 1.0 - self.beta2.powi(t);
                let (m, v) = (&mut self.m[group], &mut self.v[group]);
                let decoupled = self.kind == OptKind::AdamW;
                for i in 0..params.len() {
                    let mut g = grads[i];
                    if !decoupled && self.weight_decay > 0.0 {
                        g += self.weight_decay * params[i];
                    }
                    m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * g;
                    v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * g * g;
                    let mhat = m[i] / bc1;
                    let vhat = v[i] / bc2;
                    if decoupled && self.weight_decay > 0.0 {
                        params[i] -= lr * self.weight_decay * params[i];
                    }
                    params[i] -= lr * mhat / (vhat.sqrt() + self.eps);
                }
            }
        }
    }
}

/// Global-norm gradient clipping over several flat grads; returns the norm.
pub fn clip_global_norm(grads: &mut [&mut [f32]], max_norm: f32) -> f32 {
    let mut sq = 0.0f64;
    for g in grads.iter() {
        for &x in g.iter() {
            sq += (x as f64) * (x as f64);
        }
    }
    let norm = sq.sqrt() as f32;
    if max_norm > 0.0 && norm > max_norm {
        let scale = max_norm / norm;
        for g in grads.iter_mut() {
            for x in g.iter_mut() {
                *x *= scale;
            }
        }
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimize f(w) = ||w - target||² with each optimizer.
    fn converges(kind: OptKind, lr: f32) -> f32 {
        let target = [1.0f32, -2.0, 0.5, 3.0];
        let mut w = vec![0.0f32; 4];
        let mut opt = Optimizer::new(kind, &[4], 0.0);
        for _ in 0..400 {
            let grads: Vec<f32> = w.iter().zip(&target).map(|(a, b)| 2.0 * (a - b)).collect();
            opt.begin_step();
            opt.update(0, lr, &mut w, &grads);
        }
        w.iter().zip(&target).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn all_optimizers_minimize_quadratic() {
        assert!(converges(OptKind::Sgd, 0.05) < 1e-3);
        assert!(converges(OptKind::Adam, 0.05) < 1e-2);
        assert!(converges(OptKind::AdamW, 0.05) < 1e-2);
    }

    #[test]
    fn adamw_decays_weights_decoupled() {
        // zero gradients: AdamW still shrinks params, Adam does not
        let mut w1 = vec![1.0f32; 2];
        let mut w2 = vec![1.0f32; 2];
        let g = vec![0.0f32; 2];
        let mut aw = Optimizer::new(OptKind::AdamW, &[2], 0.1);
        let mut a = Optimizer::new(OptKind::Adam, &[2], 0.0);
        for _ in 0..10 {
            aw.begin_step();
            a.begin_step();
            aw.update(0, 0.1, &mut w1, &g);
            a.update(0, 0.1, &mut w2, &g);
        }
        assert!(w1[0] < 0.95);
        assert!((w2[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn warmup_ramps_linearly() {
        let s = LrSchedule { base_lr: 1.0, warmup: 10, decay: Decay::Constant };
        assert!((s.at(1) - 0.1).abs() < 1e-6);
        assert!((s.at(5) - 0.5).abs() < 1e-6);
        assert!((s.at(10) - 1.0).abs() < 1e-6);
        assert!((s.at(50) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn inv_sqrt_decays() {
        let s = LrSchedule { base_lr: 1.0, warmup: 100, decay: Decay::InvSqrt };
        assert!((s.at(100) - 1.0).abs() < 1e-6);
        assert!((s.at(400) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn cosine_reaches_floor() {
        let s = LrSchedule {
            base_lr: 1.0,
            warmup: 0,
            decay: Decay::Cosine { total: 100, min_frac: 0.1 },
        };
        assert!((s.at(1) - 1.0).abs() < 1e-2);
        assert!((s.at(100) - 0.1).abs() < 1e-3);
        assert!((s.at(1000) - 0.1).abs() < 1e-3);
    }

    #[test]
    fn moments_roundtrip_restores_the_trajectory() {
        // two optimizers, same gradients; B is restored from A's snapshot
        // mid-run and must produce bitwise-identical parameters afterwards
        let target = [1.0f32, -2.0, 0.5];
        let grads_at = |w: &[f32]| -> Vec<f32> {
            w.iter().zip(&target).map(|(a, b)| 2.0 * (a - b)).collect()
        };
        let mut wa = vec![0.0f32; 3];
        let mut a = Optimizer::new(OptKind::Adam, &[3], 0.0);
        for _ in 0..5 {
            let g = grads_at(&wa);
            a.begin_step();
            a.update(0, 0.05, &mut wa, &g);
        }
        let (m, v) = a.moments();
        let (m, v, t) = (m.to_vec(), v.to_vec(), a.step_count());
        let mut wb = wa.clone();
        let mut b = Optimizer::new(OptKind::Adam, &[3], 0.0);
        b.restore_moments(m, v, t);
        for _ in 0..5 {
            let ga = grads_at(&wa);
            a.begin_step();
            a.update(0, 0.05, &mut wa, &ga);
            let gb = grads_at(&wb);
            b.begin_step();
            b.update(0, 0.05, &mut wb, &gb);
        }
        assert_eq!(
            wa.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            wb.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    #[should_panic]
    fn restore_rejects_wrong_group_sizes() {
        let mut o = Optimizer::new(OptKind::Adam, &[3], 0.0);
        o.restore_moments(vec![vec![0.0; 2]], vec![vec![0.0; 2]], 1);
    }

    #[test]
    fn clip_rescales_to_max_norm() {
        let mut a = vec![3.0f32, 0.0];
        let mut b = vec![0.0f32, 4.0];
        let norm = {
            let mut refs: Vec<&mut [f32]> = vec![&mut a, &mut b];
            clip_global_norm(&mut refs, 1.0)
        };
        assert!((norm - 5.0).abs() < 1e-5);
        let new_norm = (a[0] * a[0] + b[1] * b[1]).sqrt();
        assert!((new_norm - 1.0).abs() < 1e-5);
    }
}
