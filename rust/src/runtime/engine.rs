//! `XlaEngine`: PJRT CPU client + compiled-executable cache + marshalling.
//!
//! Follows the /opt/xla-example/load_hlo pattern: HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation` → `client.compile`.
//! Every entry point is compiled once (lazily) and cached; the MGRIT hot
//! loop then only pays Literal marshalling + execution.
//!
//! v2: the engine is `Send + Sync` (Mutex-guarded cache and call counters,
//! `Arc`-shared executables) so one engine can serve the threaded MGRIT
//! backend's relaxation workers. The PJRT C API guarantees clients and
//! loaded executables are safe to invoke from multiple threads.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::{ArtifactManifest, DType, EntrySpec};
use crate::tensor::Tensor;

/// An operand crossing the PJRT boundary.
#[derive(Debug, Clone)]
pub enum Value {
    F32(Tensor),
    I32(Vec<i32>, Vec<usize>),
}

impl Value {
    pub fn tensor(t: Tensor) -> Value {
        Value::F32(t)
    }

    pub fn scalar(v: f32) -> Value {
        Value::F32(Tensor::scalar(v))
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => t.shape(),
            Value::I32(_, s) => s,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Value::F32(_) => DType::F32,
            Value::I32(..) => DType::I32,
        }
    }

    pub fn as_tensor(&self) -> Result<&Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            Value::I32(..) => bail!("expected f32 value, got i32"),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Value::F32(t) => xla::Literal::vec1(t.data()).reshape(&dims)?,
            Value::I32(v, _) => xla::Literal::vec1(v).reshape(&dims)?,
        };
        Ok(lit)
    }
}

/// One compiled entry point.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    spec: EntrySpec,
    name: String,
}

// SAFETY: `PjRtLoadedExecutable` wraps a PJRT executable handle; the PJRT
// C API specifies that loaded executables are immutable after compilation
// and that `Execute` may be called concurrently from multiple threads.
// AUDIT ON SWAP: these blanket impls cover every field. When replacing
// rust/vendor/xla with real bindings, confirm their `PjRtLoadedExecutable`
// wrapper has no non-atomic interior state (e.g. `Rc` refcounts) before
// keeping these impls — the compiler cannot flag a violation here.
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Execute with shape/dtype validation; returns the decomposed tuple.
    pub fn call(&self, args: &[Value]) -> Result<Vec<Tensor>> {
        if args.len() != self.spec.inputs.len() {
            bail!("{}: expected {} args, got {}", self.name, self.spec.inputs.len(), args.len());
        }
        for (i, (a, s)) in args.iter().zip(&self.spec.inputs).enumerate() {
            if a.shape() != s.shape.as_slice() || a.dtype() != s.dtype {
                bail!(
                    "{}: arg {} shape/dtype mismatch: got {:?}/{:?}, manifest says {:?}/{:?}",
                    self.name, i, a.shape(), a.dtype(), s.shape, s.dtype
                );
            }
        }
        let lits: Vec<xla::Literal> =
            args.iter().map(|a| a.to_literal()).collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0]
            .to_literal_sync()
            .with_context(|| format!("executing {}", self.name))?;
        // AOT lowering always uses return_tuple=True
        let parts = result.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!("{}: expected {} outputs, got {}", self.name, self.spec.outputs.len(), parts.len());
        }
        let mut out = Vec::with_capacity(parts.len());
        for (p, s) in parts.into_iter().zip(&self.spec.outputs) {
            // i32 outputs (correct-counts) are converted to f32 tensors
            let data: Vec<f32> = match s.dtype {
                DType::F32 => p.to_vec::<f32>()?,
                DType::I32 => p.to_vec::<i32>()?.into_iter().map(|v| v as f32).collect(),
            };
            out.push(Tensor::from_vec(data, &s.shape));
        }
        Ok(out)
    }

    pub fn spec(&self) -> &EntrySpec {
        &self.spec
    }
}

/// PJRT client + lazy executable cache (thread-safe).
pub struct XlaEngine {
    client: xla::PjRtClient,
    manifest: ArtifactManifest,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
    /// Counters for the §Perf pass.
    calls: Mutex<HashMap<String, u64>>,
}

// SAFETY: the PJRT C API specifies that clients are thread-safe; all
// interior mutability in the engine itself is Mutex-guarded.
// AUDIT ON SWAP: see the note on `Executable` — re-verify the real
// bindings' `PjRtClient` before trusting this impl, and keep new fields
// on this struct `Send + Sync` in their own right.
unsafe impl Send for XlaEngine {}
unsafe impl Sync for XlaEngine {}

impl XlaEngine {
    /// Create a CPU PJRT client over the artifact directory.
    pub fn load(dir: &str) -> Result<XlaEngine> {
        let manifest = ArtifactManifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {:?}", e))?;
        Ok(XlaEngine {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
            calls: Mutex::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Fetch (compiling on first use) an entry point.
    pub fn executable(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.entry(name)?.clone();
        let path = spec
            .file
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path"))?
            .to_string();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling entry point {}", name))?;
        let e = Arc::new(Executable { exe, spec, name: name.to_string() });
        // a racing thread may have compiled the same entry concurrently;
        // keep whichever landed first so callers share one executable
        let mut cache = self.cache.lock().unwrap();
        Ok(cache.entry(name.to_string()).or_insert(e).clone())
    }

    /// Convenience: execute an entry point by name.
    pub fn call(&self, name: &str, args: &[Value]) -> Result<Vec<Tensor>> {
        self.note_calls(name, 1);
        self.executable(name)?.call(args)
    }

    /// Record `n` invocations of an entry point in the §Perf counters
    /// (used by batched callers that hold an [`Executable`] directly).
    pub fn note_calls(&self, name: &str, n: u64) {
        *self.calls.lock().unwrap().entry(name.to_string()).or_insert(0) += n;
    }

    /// Snapshot of the per-entry-point invocation counters.
    pub fn call_counts(&self) -> HashMap<String, u64> {
        self.calls.lock().unwrap().clone()
    }

    /// Pre-compile every entry point (startup cost paid once, not mid-run).
    pub fn warmup(&self) -> Result<()> {
        let names: Vec<String> = self.manifest.entries.keys().cloned().collect();
        for n in names {
            self.executable(&n)?;
        }
        Ok(())
    }
}

// Integration tests against real artifacts live in rust/tests/runtime_integration.rs
// (they skip gracefully when artifacts/ has not been built).
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_shapes_and_dtypes() {
        let v = Value::scalar(2.5);
        assert_eq!(v.shape(), &[] as &[usize]);
        assert_eq!(v.dtype(), DType::F32);
        let t = Value::I32(vec![1, 2, 3, 4], vec![2, 2]);
        assert_eq!(t.shape(), &[2, 2]);
        assert_eq!(t.dtype(), DType::I32);
        assert!(t.as_tensor().is_err());
    }
}
