//! PJRT runtime: load the AOT HLO-text artifacts and execute them from the
//! L3 hot path. Python is never touched here — the artifacts are
//! self-contained XLA programs.
//!
//! * [`manifest`] — typed view of `artifacts/manifest.json` (shapes, param
//!   layout, FLOP estimates), validated against the crate's own
//!   [`crate::config::ModelConfig`] at engine construction.
//! * [`engine`] — [`XlaEngine`]: one `PjRtClient` plus a cache of compiled
//!   executables keyed by entry-point name, with `Tensor`⇄`Literal`
//!   marshalling (f32 and i32).

pub mod engine;
pub mod manifest;

pub use engine::{Value, XlaEngine};
pub use manifest::{ArtifactManifest, DType, EntrySpec, TensorSpec};
