//! Typed view of `artifacts/manifest.json` (written by python/compile/aot.py).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::ModelConfig;
use crate::util::json::Json;

/// Element type of an entry-point operand.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unknown dtype '{}' in manifest", other),
        }
    }
}

/// Shape + dtype of one operand.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")
            .and_then(|s| s.arr())
            .ok_or_else(|| anyhow!("operand missing shape"))?
            .iter()
            .map(|d| d.int().map(|v| v as usize).ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::parse(
            j.get("dtype").and_then(|d| d.str()).ok_or_else(|| anyhow!("operand missing dtype"))?,
        )?;
        Ok(TensorSpec { shape, dtype })
    }
}

/// One AOT entry point: its HLO file and operand signatures.
#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub use_pallas: bool,
    /// Geometry echoed by the compiler (vocab, d_model, … p_enc, p_dec).
    pub config: BTreeMap<String, i64>,
    pub entries: BTreeMap<String, EntrySpec>,
    /// FLOPs of one Φ application (feeds the performance simulator).
    pub flops_enc_step: f64,
    pub flops_dec_step: f64,
    /// Pallas kernel VMEM footprints (bytes), for the §Perf notes.
    pub vmem_attention: u64,
    pub vmem_mlp: u64,
}

impl ArtifactManifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<ArtifactManifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parsing manifest: {}", e))?;

        let format = j.get("format").and_then(|f| f.str()).unwrap_or("");
        if format != "hlo-text/v1" {
            bail!("unsupported manifest format '{}'", format);
        }

        let mut config = BTreeMap::new();
        for (k, v) in j.get("config").and_then(|c| c.obj()).ok_or_else(|| anyhow!("no config"))? {
            if let Some(i) = v.int() {
                config.insert(k.clone(), i);
            }
        }

        let mut entries = BTreeMap::new();
        for (name, e) in
            j.get("entries").and_then(|c| c.obj()).ok_or_else(|| anyhow!("no entries"))?
        {
            let file =
                dir.join(e.get("file").and_then(|f| f.str()).ok_or_else(|| anyhow!("no file"))?);
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                e.get(key)
                    .and_then(|x| x.arr())
                    .ok_or_else(|| anyhow!("entry {} missing {}", name, key))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            entries.insert(
                name.clone(),
                EntrySpec { file, inputs: parse_specs("inputs")?, outputs: parse_specs("outputs")? },
            );
        }

        Ok(ArtifactManifest {
            dir,
            use_pallas: j.get("use_pallas").and_then(|v| v.bool()).unwrap_or(true),
            flops_enc_step: j.at(&["flops", "enc_step"]).and_then(|v| v.num()).unwrap_or(0.0),
            flops_dec_step: j.at(&["flops", "dec_step"]).and_then(|v| v.num()).unwrap_or(0.0),
            vmem_attention: j
                .at(&["vmem", "attention_bytes"])
                .and_then(|v| v.num())
                .unwrap_or(0.0) as u64,
            vmem_mlp: j.at(&["vmem", "mlp_bytes"]).and_then(|v| v.num()).unwrap_or(0.0) as u64,
            config,
            entries,
        })
    }

    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow!("entry point '{}' not in manifest ({} present)", name, self.entries.len()))
    }

    pub fn cfg(&self, key: &str) -> Result<usize> {
        self.config
            .get(key)
            .map(|v| *v as usize)
            .ok_or_else(|| anyhow!("config key '{}' not in manifest", key))
    }

    /// Assert the rust-side model geometry matches the compiled artifacts.
    pub fn validate_model(&self, m: &ModelConfig) -> Result<()> {
        let checks = [
            ("vocab", m.vocab),
            ("d_model", m.d_model),
            ("n_heads", m.n_heads),
            ("d_ff", m.d_ff),
            ("seq", m.seq),
            ("batch", m.batch),
            ("n_classes", m.n_classes),
            ("p_enc", m.p_enc()),
            ("p_dec", m.p_dec()),
        ];
        for (key, want) in checks {
            let got = self.cfg(key)?;
            if got != want {
                bail!(
                    "artifact/config mismatch on {}: artifacts have {}, run config needs {} \
                     (re-run `make artifacts` with matching dims)",
                    key,
                    got,
                    want
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fixture(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        let manifest = r#"{
          "format": "hlo-text/v1",
          "use_pallas": true,
          "config": {"vocab": 8, "d_model": 4, "n_heads": 2, "d_ff": 8, "seq": 4,
                     "batch": 1, "n_classes": 2, "p_enc": 156, "p_dec": 228},
          "param_layout": {},
          "flops": {"enc_step": 1000, "dec_step": 1500},
          "vmem": {"attention_bytes": 4096, "mlp_bytes": 8192},
          "entries": {
            "enc_step": {
              "file": "enc_step.hlo.txt",
              "inputs": [{"shape": [1,4,4], "dtype": "f32"},
                          {"shape": [156], "dtype": "f32"},
                          {"shape": [], "dtype": "f32"}],
              "outputs": [{"shape": [1,4,4], "dtype": "f32"}]
            }
          }
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    }

    #[test]
    fn loads_fixture() {
        let dir = std::env::temp_dir().join("layertime_manifest_test");
        write_fixture(&dir);
        let m = ArtifactManifest::load(&dir).unwrap();
        assert!(m.use_pallas);
        assert_eq!(m.cfg("d_model").unwrap(), 4);
        let e = m.entry("enc_step").unwrap();
        assert_eq!(e.inputs.len(), 3);
        assert_eq!(e.inputs[0].shape, vec![1, 4, 4]);
        assert_eq!(e.inputs[2].shape, Vec::<usize>::new()); // h scalar
        assert_eq!(e.inputs[0].dtype, DType::F32);
        assert_eq!(m.flops_enc_step, 1000.0);
        assert!(m.entry("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_is_helpful() {
        let err = ArtifactManifest::load("/nonexistent/path").unwrap_err();
        assert!(format!("{:#}", err).contains("make artifacts"));
    }
}
