//! # layertime
//!
//! A production-oriented reproduction of **“Layer-Parallel Training for
//! Transformers”** (Jiang, Cyr, Salvadó-Benasco, Kopaničáková, Krause,
//! Schroder — CS.LG 2026): MGRIT (multigrid-reduction-in-time) applied to
//! the layer dimension of neural-ODE transformers, with inexact forward and
//! adjoint propagation, an adaptive inexactness controller, and combined
//! layer-×-data parallelism.
//!
//! ## Architecture (Session API v2)
//!
//! The public surface is a composable [`coordinator::Session`], assembled
//! from four orthogonal pieces:
//!
//! ```text
//! Session::builder()
//!     .preset("mc")                                   // config layer
//!     .propagator(PropagatorKind::Xla(engine))        // Φ layer
//!     .backend(Box::new(ThreadedMgrit::new(4)))       // execution layer
//!     .objective(Box::new(TagObjective::new(task)))   // workload layer
//!     .build()?
//! ```
//!
//! * **Config** — presets + typed overrides ([`config`]).
//! * **Φ (propagator)** — the discrete neural-ODE step and its VJP
//!   ([`ode`]); v2 propagators are `Send + Sync` with atomic counters and
//!   a batched `step_range` entry point, so one Φ serves many relaxation
//!   workers. Implementations: pure-Rust reference, XLA/PJRT artifacts.
//! * **Execution backend** — how the MGRIT-shaped forward/adjoint solves
//!   run ([`coordinator::backend`]): `Serial` (exact), `Mgrit`
//!   (single-threaded V-cycles), `ThreadedMgrit` (multi-worker relaxation
//!   through [`parallel::exec`] with channel-fabric halo exchange — the
//!   paper's Fig. 2 decomposition on the real training hot loop, bitwise
//!   identical to the single-threaded solver). Each session turns its
//!   backend into a persistent [`coordinator::SolveContext`] that caches
//!   the forward/adjoint MGRIT hierarchies, the warm-start iterate, and
//!   the fine-grid step workspace across the whole run — with the
//!   single-threaded backends the steady-state training step performs no
//!   solver-side allocations (threaded sweeps still stage their slabs).
//! * **Objective** — the open workload interface
//!   ([`coordinator::objective`]): data sampling, loss head, validation
//!   metric. The paper's five tasks ship as implementations; new workloads
//!   plug in without touching the coordinator.
//!
//! ## The train/infer forward core
//!
//! The forward solve is shared between training and serving. Its state is
//! layered so inference never allocates training machinery:
//!
//! * [`coordinator::ForwardContext`] (+ [`coordinator::ForwardWorkspace`])
//!   — backend strategy, the cached forward MGRIT hierarchy, the
//!   TorchBraid-style warm-start flag, and the fine-grid states Z_0..Z_N.
//!   `forward_full` runs the whole stack: serial open buffers → mid-range
//!   solve (V-cycles on the cached core, or the exact serial bypass) →
//!   serial close buffers (Appendix B).
//! * [`coordinator::SolveContext`] — a `ForwardContext` plus the cached
//!   **adjoint** hierarchy and the training-only
//!   [`coordinator::StepWorkspace`] (λ, gradient accumulators, loss-head
//!   cotangent + scratch). Owned by [`coordinator::Session`].
//! * [`infer::InferSession`] — a `ForwardContext` plus logits-only head
//!   kernels (`coordinator::heads::{lm,tag,cls}_infer_into`): batched
//!   greedy/top-k autoregressive decoding (LM + Translate) and batched
//!   classification/tagging prediction, allocation-free at steady state
//!   like the training step (`rust/tests/alloc_audit.rs`). Decoding is
//!   **incremental** by default: the prompt costs one exact serial
//!   forward that also fills a per-layer append-only K/V cache
//!   ([`reference::KvCache`] through the [`ode::Propagator`] cache
//!   contract), then each further token is one O(1)-per-layer cached Φ
//!   sweep on a single-position row state — bitwise identical to serial
//!   full forwards (`rust/tests/decode_cache.rs`).
//! * [`serve::ServeLoop`] — a continuous-batching inference service on
//!   top: bounded request queue with backpressure, dynamic batching
//!   (join-mid-flight / early-retirement with per-row warm-start and
//!   cache-row resets; joins prefill, every other step is one cached
//!   sweep), checkpoint hot-reload between decode steps, and queue/
//!   occupancy/latency observability with a prefill/decode step split
//!   (`layertime serve` / `bench-serve`).
//!
//! ## Checkpoints ([`checkpoint`])
//!
//! `layertime train --save ckpt` / [`coordinator::Session::save`] write a
//! versioned little-endian binary: `LTCP` magic + version, the full
//! `RunConfig` as JSON (u64 seed as a string — JSON numbers are doubles),
//! run/controller/optimizer scalar state, a **named tensor table**
//! (`param.layer.{i}`, `param.{emb,pos,out,cls}`, `opt.{m,v}.{g}`,
//! optional `warm.{j}` mid-range states) with payloads, and a trailing
//! FNV-1a checksum. Every entry is validated against the model config on
//! read; resume ([`coordinator::Session::resume`], `--resume`) continues
//! the run **bitwise identically** — weights, Adam moments, RNG streams,
//! adaptive ρ-history, warm iterate and all
//! (`rust/tests/checkpoint_roundtrip.rs`). Version bumps gate any layout
//! change; unknown versions are rejected rather than half-read.
//!
//! ## Stack (Python never on the training path)
//!
//! * **L3 (this crate)** — the coordinator: MGRIT engine ([`mgrit`]),
//!   adaptive controller ([`adaptive`]), device topology + comm fabric +
//!   threaded executor + performance simulator ([`parallel`]), session
//!   layer ([`coordinator`]), optimizers ([`opt`]), data pipelines
//!   ([`data`]), analysis tools ([`analysis`]).
//! * **L2/L1 (build time)** — JAX neural-ODE step functions composed from
//!   Pallas kernels, AOT-lowered to HLO text artifacts by
//!   `python/compile/aot.py`; loaded at startup by [`runtime`] through the
//!   PJRT C API and executed from the MGRIT hot loop.
//!
//! A pure-Rust reference transformer ([`reference`]) mirrors the JAX model
//! so every algorithm in the crate is testable without artifacts.
//!
//! ## Kernel layer ([`tensor`])
//!
//! The reference Φ bottoms out in hand-written f32 kernels: row-sliced
//! matmul and its transposed variants, row softmax, LayerNorm, GELU — all
//! on 32-byte-aligned backing stores ([`tensor::AlignedVec`]). Building
//! with `--features simd` adds explicit 8-lane vector kernels (AVX2+FMA /
//! NEON) behind a runtime dispatch ([`tensor::simd_active`]) that keeps
//! `mm`/`mm_at` **bitwise identical** to the scalar kernels and bounds the
//! reassociated kernels to shape-independent ulp-level drift, so the
//! crate's bitwise pins (checkpoint resume, backend parity, cached decode)
//! hold under the feature (`rust/tests/simd_parity.rs`).
//!
//! ## Fault tolerance ([`fault`])
//!
//! Long runs must survive infrastructure faults, not just detect them.
//! A deterministic fault-injection registry ([`fault`], `--faults` CLI)
//! guards named `faultpoint!` sites threaded through the kernel/forward
//! layer, the pooled MGRIT sweeps, checkpoint I/O, and the serve
//! scheduler — each site costs one relaxed atomic load while disarmed, so
//! the zero-allocation audits are untouched. The self-healing policies it
//! exercises: a non-finite loss/gradient guard that rewinds the RNG and
//! replays the step instead of poisoning Adam moments; divergence-watchdog
//! escalation from "switch serial" to auto-rollback onto the last good
//! autosave; pooled-sweep panic containment that rebuilds the poisoned
//! pool and retries once (then falls back to the in-thread V-cycle, still
//! bitwise identical); atomic tmp+fsync+rename checkpoint writes; typed
//! [`parallel::FabricError`] instead of mailbox panics; and serve-side
//! per-request deadlines with typed `Timeout` outcomes plus graceful
//! drain. Injected and organic anomalies alike land in a typed
//! [`fault::FaultEvent`] log surfaced through `--report` and serve
//! metrics JSON (`rust/tests/chaos.rs` pins recovery bitwise per fault
//! class).

pub mod adaptive;
pub mod analysis;
pub mod checkpoint;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod fault;
pub mod infer;
pub mod mgrit;
pub mod model;
pub mod ode;
pub mod opt;
pub mod parallel;
pub mod reference;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod util;

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::checkpoint::Checkpoint;
    pub use crate::config::{presets, MgritConfig, ModelConfig, TrainConfig};
    pub use crate::coordinator::{
        Backend, Mgrit, Objective, PropagatorKind, Serial, Session, SessionBuilder, Task,
        ThreadedMgrit, TrainReport,
    };
    pub use crate::infer::{DecodeOptions, InferSession};
    pub use crate::serve::{
        CompletedRequest, GenerateRequest, RequestOutcome, RequestQueue, ServeLoop, ServeMetrics,
    };
    pub use crate::tensor::Tensor;
    pub use crate::util::rng::Rng;
}
