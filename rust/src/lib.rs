//! # layertime
//!
//! A production-oriented reproduction of **“Layer-Parallel Training for
//! Transformers”** (Jiang, Cyr, Salvadó-Benasco, Kopaničáková, Krause,
//! Schroder — CS.LG 2026): MGRIT (multigrid-reduction-in-time) applied to
//! the layer dimension of neural-ODE transformers, with inexact forward and
//! adjoint propagation, an adaptive inexactness controller, and combined
//! layer-×-data parallelism.
//!
//! ## Architecture (three layers, Python never on the training path)
//!
//! * **L3 (this crate)** — the coordinator: MGRIT engine ([`mgrit`]),
//!   adaptive controller ([`adaptive`]), device topology + comm fabric +
//!   performance simulator ([`parallel`]), training loop ([`coordinator`]),
//!   optimizers ([`opt`]), data pipelines ([`data`]), analysis tools
//!   ([`analysis`]).
//! * **L2/L1 (build time)** — JAX neural-ODE step functions composed from
//!   Pallas kernels, AOT-lowered to HLO text artifacts by
//!   `python/compile/aot.py`; loaded at startup by [`runtime`] through the
//!   PJRT C API and executed from the MGRIT hot loop.
//!
//! A pure-Rust reference transformer ([`reference`]) mirrors the JAX model
//! so every algorithm in the crate is testable without artifacts.

pub mod adaptive;
pub mod analysis;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod mgrit;
pub mod model;
pub mod ode;
pub mod opt;
pub mod parallel;
pub mod reference;
pub mod runtime;
pub mod tensor;
pub mod util;

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::config::{presets, MgritConfig, ModelConfig, TrainConfig};
    pub use crate::tensor::Tensor;
    pub use crate::util::rng::Rng;
}
