//! `layertime` launcher — the L3 leader entrypoint.
//!
//! Subcommands:
//!   train      run one training job (preset + overrides; --save/--resume
//!              for full-session checkpoints, --save-every/--keep for
//!              periodic autosave + retention, --report for JSON run logs)
//!   generate   batched autoregressive decoding from a checkpoint
//!   predict    batched classification/tagging/LM prediction from a checkpoint
//!   serve      continuous-batching inference service (file-request mode,
//!              per-request sampling params, checkpoint hot-reload)
//!   bench-serve  closed-loop load driver over the serve scheduler
//!   compare    serial vs layer-parallel vs adaptive-switch from one init
//!   simulate   performance-model a topology (layers × lp × dp × MGRIT)
//!   lipschitz  estimate per-layer Lipschitz constants (Appendix B)
//!   info       print preset + artifact information
//!
//! Examples:
//!   layertime train --preset mc --enc-layers 64 --cf 2 --steps 300
//!   layertime train --preset gpt --steps 200 --save runs/gpt.ltcp
//!   layertime train --preset gpt --steps 200 --save runs/gpt.ltcp --save-every 50 --keep 3
//!   layertime train --resume runs/gpt.ltcp --steps 400
//!   layertime generate --ckpt runs/gpt.ltcp --top-k 4 --max-new 16
//!   layertime predict --ckpt runs/mc.ltcp --batches 8
//!   layertime serve --ckpt runs/gpt.ltcp --requests reqs.json --metrics metrics.json
//!   layertime serve --watch runs/ --requests - --out results.json
//!   layertime bench-serve --ckpt runs/gpt.ltcp --count 64 --occupancy 8
//!   layertime simulate --preset bert --lp 8 --dp 4

use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use layertime::config::presets;
use layertime::coordinator::{backend_for_workers, Objective, Serial, Session, Task};
use layertime::infer::{DecodeOptions, InferSession};
use layertime::model::{Init, ParamStore};
use layertime::ode::Propagator;
use layertime::parallel::{DeviceModel, SimConfig, Simulator};
use layertime::runtime::XlaEngine;
use layertime::serve::{drive_load, requests_from_json, GenerateRequest, HotReload, ServeLoop};
use layertime::util::bench::Stats;
use layertime::util::cli::Args;
use layertime::util::csv::CsvWriter;
use layertime::util::json;
use layertime::util::rng::Rng;
use layertime::util::table::{f, i, Table};

const USAGE: &str = "layertime <train|generate|predict|serve|bench-serve|compare|simulate|lipschitz|info> [options]
  common:     --preset {bert|mc|vit|mt|gpt}  --seed N
  model:      --enc-layers N --dec-layers N --batch N --buffer-open N --buffer-close N
  mgrit:      --cf N --levels N --fwd-iters {N|serial} --bwd-iters {N|serial}
  training:   --steps N --lr F --no-adaptive --artifacts DIR (use AOT/PJRT Φ)
  backend:    --workers N (N>1 selects the ThreadedMgrit backend)
              --dp-workers D (concurrent replica lanes, clamped to 1..=dp;
              each lane drives workers/D relaxation workers; default:
              simulator auto-split of --workers across dp x lp)
  topology:   --lp N --dp N --device {v100|a100}
  checkpoint: --save PATH (full session), --resume PATH (continue bitwise;
              only --steps/--workers/--dp-workers/--out/--report/--save
              apply on top),
              --save-every N --keep K (periodic autosave next to --save PATH,
              oldest pruned past K), --checkpoint PATH (weights-only, legacy)
  inference:  generate|predict --ckpt PATH [--workers N] [--fwd-iters {N|serial}]
              [--no-incremental (full forward per token instead of KV-cached decode)]
              generate: --max-new N --top-k K --temperature F --seed N
              predict:  --batches N
  serve:      --ckpt PATH and/or --watch DIR (hot-reload newest valid .ltcp)
              [--no-incremental]
              --requests FILE|- (JSON: [{\"prompt\": [..], \"id\", \"max_new\",
              \"top_k\", \"temperature\", \"seed\", \"deadline_ms\"}, ..]
              or {\"requests\": [..]})
              --queue N (backpressure capacity) --feeders N (producer threads)
              --reload-every N (poll cadence, steps) --out FILE --metrics FILE
  bench-serve: --ckpt PATH --count N --occupancy N [--max-new N --top-k K
              --temperature F --seed N --metrics FILE]
  faults:     --faults 'name@step=N,name@count=K,name' (deterministic fault
              injection, e.g. 'pool.sweep_panic@step=3'; events surface as
              fault_events in --report / --metrics JSON)
  output:     --out runs/NAME.csv --report runs/NAME.json";

fn engine_from(args: &Args) -> Result<Option<Arc<XlaEngine>>> {
    match args.get("artifacts") {
        None => Ok(None),
        Some(dir) => {
            let e = XlaEngine::load(dir)?;
            eprintln!("PJRT platform: {} ({} entry points)", e.platform(), e.manifest().entries.len());
            Ok(Some(Arc::new(e)))
        }
    }
}

fn run_config(args: &Args) -> Result<layertime::config::RunConfig> {
    let preset = args.get_str("preset", "mc");
    let mut rc = presets::by_name(&preset)
        .ok_or_else(|| anyhow!("unknown preset '{}' (have: {})", preset, presets::ALL.join(", ")))?;
    rc.apply_args(args);
    Ok(rc)
}

fn cmd_train(args: &Args) -> Result<()> {
    let engine = engine_from(args)?;
    let workers = args.get_usize("workers", 1);
    let dp_workers: Option<usize> = match args.get("dp-workers") {
        Some(v) => Some(
            v.parse().map_err(|_| anyhow!("--dp-workers expects a replica-lane count"))?,
        ),
        None => None,
    };
    let mut run = match args.get("resume") {
        Some(path) => {
            // the checkpoint carries config + parameters + all run state;
            // only execution choices and the run length apply on top
            let mut b = Session::builder().resume(path).engine(engine).workers(workers);
            if let Some(d) = dp_workers {
                b = b.dp_workers(d);
            }
            let mut run = b.build()?;
            if args.get("steps").is_some() {
                run.set_total_steps(args.get_usize("steps", run.rc.train.steps));
            }
            println!(
                "resumed '{}' from {} at step {} (training to step {}, {} worker(s))",
                run.rc.name,
                path,
                run.step(),
                run.rc.train.steps,
                workers
            );
            if run.step() >= run.rc.train.steps {
                // a checkpoint saved at run completion has step == steps;
                // without a new target the loop below would train nothing
                println!(
                    "note: the checkpoint already reached its configured {} steps — pass \
                     --steps N (> {}) to train further",
                    run.rc.train.steps,
                    run.step()
                );
            }
            run
        }
        None => {
            let rc = run_config(args)?;
            let task = Task::for_preset(&rc.name)?;
            println!(
                "training '{}' ({:?}): {} layers, MGRIT cf={} L={} fwd={:?} bwd={:?}, {} steps, {} worker(s)",
                rc.name,
                task,
                rc.model.total_layers(),
                rc.mgrit.cf,
                rc.mgrit.levels,
                rc.mgrit.fwd_iters,
                rc.mgrit.bwd_iters,
                rc.train.steps,
                workers
            );
            let mut b =
                Session::builder().config(rc).task(task).engine(engine).workers(workers);
            if let Some(d) = dp_workers {
                b = b.dp_workers(d);
            }
            b.build()?
        }
    };
    println!("backend: {}, objective: {}", run.backend_name(), run.objective_name());
    if let Some(every) = args.get("save-every") {
        let every: usize = every.parse().map_err(|_| anyhow!("--save-every expects a step count"))?;
        let base = args.get("save").ok_or_else(|| {
            anyhow!("--save-every needs --save PATH (the autosave base name and directory)")
        })?;
        let keep = args.get_usize("keep", 3);
        run.set_autosave(base, every, keep);
        println!(
            "autosave: every {} step(s) next to {}, keeping the newest {}",
            every.max(1),
            base,
            keep
        );
    }
    let report = run.train()?;
    let mut tbl = Table::new(&["step", "loss", "acc", "serial", "rho_fwd", "rho_bwd"]);
    for r in report.curve.iter().step_by((report.curve.len() / 20).max(1)) {
        tbl.row(vec![
            i(r.step as i64),
            f(r.loss as f64, 4),
            f(r.acc as f64, 3),
            r.serial.to_string(),
            r.rho_fwd.map(|v| f(v, 3)).unwrap_or_else(|| "-".into()),
            r.rho_bwd.map(|v| f(v, 3)).unwrap_or_else(|| "-".into()),
        ]);
    }
    tbl.print();
    println!(
        "final loss {:.4}, final metric {:.4}, Φ fwd/vjp = {}/{}{}",
        report.final_loss,
        report.final_metric,
        report.phi_fwd,
        report.phi_vjp,
        report
            .switched_at
            .map(|s| format!(", switched to serial at step {}", s))
            .unwrap_or_default()
    );
    if let Some(path) = args.get("out") {
        let mut w = CsvWriter::create(path, &["step", "loss", "acc", "serial"])?;
        for r in &report.curve {
            w.row(&[
                r.step.to_string(),
                r.loss.to_string(),
                r.acc.to_string(),
                (r.serial as u8).to_string(),
            ])?;
        }
        w.flush()?;
        println!("wrote {}", path);
    }
    if let Some(path) = args.get("report") {
        // Fig. 4/5-style plots read this instead of scraping stdout
        let j = json::obj(vec![
            ("config", run.rc.to_json()),
            ("report", report.to_json()),
            ("fault_events", layertime::fault::events_json()),
        ]);
        std::fs::write(path, j.to_string_pretty())?;
        println!("wrote {}", path);
    }
    if let Some(path) = args.get("save") {
        run.save(path)?;
        println!("saved session checkpoint {} (resume with --resume)", path);
    }
    if let Some(path) = args.get("checkpoint") {
        run.params.save(path)?;
        println!("saved weights-only checkpoint {}", path);
    }
    Ok(())
}

/// Load an inference session from `--ckpt`, honoring `--workers` and a
/// `--fwd-iters` override.
fn infer_from(args: &Args) -> Result<InferSession> {
    let ckpt = args
        .get("ckpt")
        .ok_or_else(|| anyhow!("--ckpt PATH is required (a file written by train --save)"))?;
    let workers = args.get_usize("workers", 1);
    let mut inf = InferSession::from_checkpoint_with(ckpt, workers)?;
    if let Some(v) = args.get("fwd-iters") {
        inf.set_fwd_iters(if v == "serial" { None } else { Some(v.parse()?) });
    }
    if args.has_flag("no-incremental") {
        inf.set_incremental(false);
    }
    println!(
        "checkpoint '{}' ({:?}): {} layers, backend {}, forward {}, {} decode",
        inf.rc.name,
        inf.task(),
        inf.rc.model.total_layers(),
        inf.backend_name(),
        match inf.rc.mgrit.fwd_iters {
            Some(k) => {
                format!("mgrit cf={} L={} {} iter(s)", inf.rc.mgrit.cf, inf.rc.mgrit.levels, k)
            }
            None => "serial (exact)".into(),
        },
        if inf.incremental() { "incremental (KV-cached)" } else { "full-forward" }
    );
    Ok(inf)
}

fn fmt_tokens(toks: &[i32]) -> String {
    toks.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(" ")
}

fn cmd_generate(args: &Args) -> Result<()> {
    let mut inf = infer_from(args)?;
    let m = inf.rc.model.clone();
    match inf.task() {
        // tagging/classification have no LM head; the bidirectional MLM
        // head cannot autoregress (logits would attend over the unfilled
        // future board) — all three serve batched predictions instead
        Task::Tag | Task::Cls | Task::Mlm => {
            println!(
                "task {:?} has no autoregressive head — running batched prediction instead",
                inf.task()
            );
            return predict_run(args, &mut inf);
        }
        _ => {}
    }
    let mut opts = DecodeOptions {
        top_k: args.get_usize("top-k", 0),
        temperature: args.get_f32("temperature", 1.0),
        seed: args.get_u64("seed", 0),
        max_new: 0,
    };
    // sample inputs from the task's deterministic data source
    let obj = Task::for_preset(&inf.rc.name)?.objective(&m, inf.rc.train.seed);
    let mut rng = Rng::new(args.get_u64("seed", 0) ^ 0x5EED);
    let batch = obj.sample(&mut rng, &m);
    match inf.task() {
        Task::Translate => {
            let preds = inf.translate(&batch.tokens, &opts)?;
            let mut pairs = Vec::with_capacity(m.batch);
            for b in 0..m.batch.min(4) {
                println!("src {}: {}", b, fmt_tokens(&batch.tokens[b * m.seq..(b + 1) * m.seq]));
                println!("out {}: {}", b, fmt_tokens(&preds[b * m.seq..(b + 1) * m.seq]));
                println!("ref {}: {}", b, fmt_tokens(&batch.targets[b * m.seq..(b + 1) * m.seq]));
            }
            for b in 0..m.batch {
                pairs.push((
                    preds[b * m.seq..(b + 1) * m.seq].to_vec(),
                    batch.targets[b * m.seq..(b + 1) * m.seq].to_vec(),
                ));
            }
            let bleu = layertime::analysis::bleu4(&pairs);
            println!("BLEU-4 over {} sequences: {:.4}", m.batch, bleu);
        }
        _ => {
            let max_new = args.get_usize("max-new", m.seq / 2).clamp(1, m.seq - 1);
            let plen = m.seq - max_new;
            let mut prompts = Vec::with_capacity(m.batch * plen);
            for b in 0..m.batch {
                prompts.extend_from_slice(&batch.tokens[b * m.seq..b * m.seq + plen]);
            }
            // route the cap through the decode options so the session
            // validates prompt_len + max_new against its window
            opts.max_new = max_new;
            let out = inf.generate(&prompts, plen, &opts)?;
            println!(
                "generated {} tokens per sequence ({} sequences, {}):",
                max_new,
                m.batch,
                if opts.top_k == 0 { "greedy".into() } else { format!("top-{}", opts.top_k) }
            );
            for b in 0..m.batch.min(4) {
                println!(
                    "seq {}: {} | {}",
                    b,
                    fmt_tokens(&out[b * m.seq..b * m.seq + plen]),
                    fmt_tokens(&out[b * m.seq + plen..(b + 1) * m.seq])
                );
            }
        }
    }
    Ok(())
}

/// Batched prediction over `--batches` sampled batches with the task's own
/// accounting (accuracy; BLEU for translation).
fn predict_run(args: &Args, inf: &mut InferSession) -> Result<()> {
    let m = inf.rc.model.clone();
    let n_batches = args.get_usize("batches", 4);
    let obj = Task::for_preset(&inf.rc.name)?.objective(&m, inf.rc.train.seed);
    let mut rng = Rng::new(args.get_u64("seed", 0) ^ 0x5EED);
    let opts = DecodeOptions { seed: args.get_u64("seed", 0), ..DecodeOptions::default() };
    let mut correct = 0.0f64;
    let mut total = 0.0f64;
    let mut pairs: Vec<(Vec<i32>, Vec<i32>)> = Vec::new();
    let mut preds = Vec::new();
    for _ in 0..n_batches {
        let batch = obj.sample(&mut rng, &m);
        match inf.task() {
            Task::Translate => {
                inf.translate_into(&batch.tokens, &opts, &mut preds)?;
                for b in 0..m.batch {
                    pairs.push((
                        preds[b * m.seq..(b + 1) * m.seq].to_vec(),
                        batch.targets[b * m.seq..(b + 1) * m.seq].to_vec(),
                    ));
                }
            }
            Task::Cls => {
                inf.predict_into(&batch.tokens, &mut preds)?;
                for (p, l) in preds.iter().zip(&batch.labels) {
                    correct += (p == l) as u8 as f64;
                    total += 1.0;
                }
            }
            Task::Tag => {
                inf.predict_into(&batch.tokens, &mut preds)?;
                for (p, t) in preds.iter().zip(&batch.targets) {
                    correct += (p == t) as u8 as f64;
                    total += 1.0;
                }
            }
            Task::Lm | Task::Mlm => {
                inf.predict_into(&batch.tokens, &mut preds)?;
                // score only in-mask positions (all for causal LM)
                for ((p, t), &mk) in preds.iter().zip(&batch.targets).zip(&batch.mask) {
                    if mk > 0.0 {
                        correct += (p == t) as u8 as f64;
                        total += 1.0;
                    }
                }
            }
        }
    }
    match inf.task() {
        Task::Translate => println!(
            "BLEU-4 over {} sequences: {:.4}",
            pairs.len(),
            layertime::analysis::bleu4(&pairs)
        ),
        t => println!(
            "{:?} accuracy over {} predictions: {:.4}",
            t,
            total as u64,
            correct / total.max(1.0)
        ),
    }
    Ok(())
}

fn cmd_predict(args: &Args) -> Result<()> {
    let mut inf = infer_from(args)?;
    predict_run(args, &mut inf)
}

/// Continuous-batching inference service in port-less file-request mode:
/// requests come from a JSON file (or stdin with `--requests -`), feeder
/// worker threads push them through the bounded queue (blocking under
/// backpressure), the scheduler serves until everything drains, and the
/// results/metrics land on stdout and optional JSON files. `--watch DIR`
/// hot-reloads newer autosaves mid-stream.
fn cmd_serve(args: &Args) -> Result<()> {
    let workers = args.get_usize("workers", 1);
    let mut watch: Option<HotReload> = args.get("watch").map(HotReload::new);
    let mut inf = match args.get("ckpt") {
        Some(path) => {
            let inf = InferSession::from_checkpoint_with(path, workers)?;
            println!("serving checkpoint {}", path);
            inf
        }
        None => {
            let hr = watch
                .as_mut()
                .ok_or_else(|| anyhow!("serve needs --ckpt PATH or --watch DIR"))?;
            let (path, ck) = hr.poll().ok_or_else(|| {
                anyhow!("--watch {}: no valid .ltcp checkpoint found", hr.dir().display())
            })?;
            println!("serving newest checkpoint {} from watch dir", path.display());
            InferSession::from_checkpoint_parts(ck, workers)?
        }
    };
    if let Some(v) = args.get("fwd-iters") {
        inf.set_fwd_iters(if v == "serial" { None } else { Some(v.parse()?) });
    }
    if args.has_flag("no-incremental") {
        inf.set_incremental(false);
    }
    let text = match args.get("requests") {
        Some("-") => {
            let mut t = String::new();
            std::io::Read::read_to_string(&mut std::io::stdin(), &mut t)?;
            t
        }
        Some(path) => std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading requests file {}: {}", path, e))?,
        None => bail!("serve runs in file-request mode: pass --requests FILE (or - for stdin)"),
    };
    let requests = requests_from_json(&text)?;
    let mut srv = ServeLoop::new(inf, args.get_usize("queue", 64))?;
    if let Some(hr) = watch {
        srv.set_watch(hr, args.get_u64("reload-every", 64));
    }
    let n_feeders = args.get_usize("feeders", 1).max(1);
    println!(
        "serving {} request(s) on '{}' ({} slot(s), queue capacity {}, {} feeder thread(s))",
        requests.len(),
        srv.session().rc.name,
        srv.session().rc.model.batch,
        srv.queue().capacity(),
        n_feeders
    );
    // feeder worker threads submit round-robin shards into the bounded
    // queue (blocking under backpressure); a closer thread joins them and
    // closes the queue so the serve loop knows when to exit
    let mut shards: Vec<Vec<GenerateRequest>> = (0..n_feeders).map(|_| Vec::new()).collect();
    for (i, req) in requests.into_iter().enumerate() {
        shards[i % n_feeders].push(req);
    }
    let feeders: Vec<_> = shards
        .into_iter()
        .map(|shard| {
            let q = srv.queue();
            std::thread::spawn(move || {
                for req in shard {
                    if q.submit_blocking(req).is_err() {
                        break;
                    }
                }
            })
        })
        .collect();
    let closer_q = srv.queue();
    let closer = std::thread::spawn(move || {
        for h in feeders {
            h.join().ok();
        }
        closer_q.close();
    });
    srv.run(Duration::from_millis(50))?;
    closer.join().ok();
    let completed = srv.take_completed();
    for c in completed.iter().take(4) {
        println!(
            "req {:>3}: {} | {}",
            c.id,
            fmt_tokens(&c.tokens[..c.prompt_len]),
            fmt_tokens(&c.tokens[c.prompt_len..])
        );
    }
    let qs = srv.queue().stats();
    let met = &srv.metrics;
    println!(
        "completed {}/{} request(s) ({} timeout(s)): {:.1} tok/s decode ({:.1} steady-state), \
         mean occupancy {:.2} (peak {}), {} prefill / {} decode step(s), {} reload(s)",
        met.completed,
        qs.submitted,
        met.timeouts,
        met.tokens_per_sec(),
        met.decode_tokens_per_sec(),
        met.mean_occupancy(),
        met.peak_occupancy,
        met.prefill_steps,
        met.decode_steps - met.prefill_steps,
        met.reloads
    );
    if let Some(path) = args.get("out") {
        let j = json::obj(vec![(
            "results",
            json::arr(completed.iter().map(|c| c.to_json()).collect()),
        )]);
        std::fs::write(path, j.to_string_pretty())?;
        println!("wrote {}", path);
    }
    if let Some(path) = args.get("metrics") {
        std::fs::write(path, met.to_json(qs.submitted, qs.rejected).to_string_pretty())?;
        println!("wrote {}", path);
    }
    Ok(())
}

/// Closed-loop load driver over the serve scheduler: `--count` synthetic
/// requests with varied prompt lengths, held at `--occupancy` in-flight.
fn cmd_bench_serve(args: &Args) -> Result<()> {
    let inf = infer_from(args)?;
    let m = inf.rc.model.clone();
    let count = args.get_usize("count", 32).max(1);
    let occupancy = args.get_usize("occupancy", m.batch).max(1);
    let top_k = args.get_usize("top-k", 0);
    let temperature = args.get_f32("temperature", 1.0);
    let max_new = args.get_usize("max-new", 0);
    let mut rng = Rng::new(args.get_u64("seed", 0) ^ 0xBE7C);
    let requests: Vec<GenerateRequest> = (0..count)
        .map(|i| {
            // varied prompt lengths make retirements ragged — the load
            // pattern continuous batching exists for
            let plen = 1 + rng.range(m.seq / 2);
            let prompt = (0..plen).map(|_| rng.range(m.vocab) as i32).collect();
            GenerateRequest {
                id: i as u64,
                prompt,
                max_new,
                top_k,
                temperature,
                seed: i as u64,
                deadline_ms: 0,
            }
        })
        .collect();
    let mut srv = ServeLoop::new(inf, occupancy)?;
    let mut completed = Vec::new();
    let t0 = std::time::Instant::now();
    drive_load(&mut srv, &requests, occupancy, &mut completed)?;
    let wall = t0.elapsed().as_secs_f64();
    let met = &srv.metrics;
    println!(
        "bench-serve: {} request(s) at target occupancy {} ({})",
        count,
        occupancy,
        if top_k == 0 { "greedy".to_string() } else { format!("top-{}", top_k) }
    );
    println!(
        "  {} tokens in {:.3} s wall — {:.1} tok/s decode ({:.1} steady-state over {} pure \
         decode step(s), {} prefill), mean occupancy {:.2} (peak {})",
        met.tokens_generated,
        wall,
        met.tokens_per_sec(),
        met.decode_tokens_per_sec(),
        met.decode_steps - met.prefill_steps,
        met.prefill_steps,
        met.mean_occupancy(),
        met.peak_occupancy
    );
    let lat: Vec<f64> = completed.iter().map(|c| c.latency).collect();
    let ttft: Vec<f64> = completed.iter().map(|c| c.ttft).collect();
    if !lat.is_empty() {
        println!("  latency  {}", Stats::from_samples(lat).summary());
        println!("  ttft     {}", Stats::from_samples(ttft).summary());
    }
    if let Some(path) = args.get("metrics") {
        let qs = srv.queue().stats();
        std::fs::write(path, met.to_json(qs.submitted, qs.rejected).to_string_pretty())?;
        println!("wrote {}", path);
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    let rc = run_config(args)?;
    let task = Task::for_preset(&rc.name)?;
    let workers = args.get_usize("workers", 1);
    let init = ParamStore::init(
        &rc.model,
        if rc.model.total_layers() >= 64 { Init::DeepNet } else { Init::Default },
        rc.train.seed,
    );
    let mut variants: Vec<(&str, layertime::config::RunConfig)> = vec![];
    let mut serial = rc.clone();
    serial.mgrit = layertime::config::MgritConfig::serial();
    serial.train.adaptive = false;
    variants.push(("serial", serial));
    let mut pure = rc.clone();
    pure.train.adaptive = false;
    variants.push(("layer-parallel", pure));
    let mut adaptive = rc.clone();
    adaptive.train.adaptive = true;
    variants.push(("adaptive-switch", adaptive));

    let mut tbl = Table::new(&["variant", "final loss", "final metric", "switched@"]);
    for (name, vrc) in variants {
        let engine = engine_from(args)?;
        let mut builder = Session::builder()
            .config(vrc)
            .task(task)
            .engine(engine)
            .params(init.deep_clone());
        builder = if name == "serial" {
            builder.backend(Box::new(Serial))
        } else {
            builder.backend(backend_for_workers(workers))
        };
        let mut run = builder.build()?;
        let rep = run.train()?;
        tbl.row(vec![
            name.into(),
            f(rep.final_loss as f64, 4),
            f(rep.final_metric, 4),
            rep.switched_at.map(|s| s.to_string()).unwrap_or_else(|| "-".into()),
        ]);
    }
    tbl.print();
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let rc = run_config(args)?;
    let device = match args.get_str("device", "v100").as_str() {
        "a100" => DeviceModel::a100(),
        _ => DeviceModel::v100(),
    };
    let m = &rc.model;
    let flops_per_sample = 12.0 * (m.seq * m.d_model * m.d_model) as f64
        + 4.0 * (m.seq * m.seq * m.d_model) as f64
        + 4.0 * (m.seq * m.d_model * m.d_ff) as f64;
    let sim = Simulator::new(SimConfig {
        n_layers: m.parallel_layers(),
        cf: rc.mgrit.cf,
        levels: rc.mgrit.levels,
        fwd_iters: rc.mgrit.fwd_iters,
        bwd_iters: rc.mgrit.bwd_iters,
        fcf: rc.mgrit.fcf,
        lp: rc.lp_degree,
        dp: rc.dp_degree,
        flops_per_sample_step: flops_per_sample,
        batch: m.batch,
        state_bytes: (m.seq * m.d_model * 4) as f64,
        param_bytes: (m.total_layers() * m.p_enc() * 4) as f64,
        device,
    });
    let rep = sim.batch_time();
    println!(
        "{} on {}: lp={} dp={} layers={}",
        rc.name, sim.cfg.device.name, rc.lp_degree, rc.dp_degree, m.total_layers()
    );
    let mut tbl = Table::new(&["component", "seconds"]);
    tbl.row(vec!["forward solve".into(), format!("{:.6}", rep.fwd)]);
    tbl.row(vec!["adjoint solve".into(), format!("{:.6}", rep.bwd)]);
    tbl.row(vec!["gradient pass".into(), format!("{:.6}", rep.grad)]);
    tbl.row(vec!["dp allreduce".into(), format!("{:.6}", rep.allreduce)]);
    tbl.row(vec!["TOTAL/batch".into(), format!("{:.6}", rep.total)]);
    tbl.print();
    println!("speedup vs 1-device serial: {:.2}x", sim.speedup_vs_serial());
    Ok(())
}

fn cmd_lipschitz(args: &Args) -> Result<()> {
    let rc = run_config(args)?;
    let ps = ParamStore::init(&rc.model, Init::Default, rc.train.seed);
    let prop = ps.rust_propagator();
    let mut rng = Rng::new(rc.train.seed + 99);
    let z0 = layertime::tensor::Tensor::randn(&mut rng, &prop.state_shape(), 1.0);
    // serial forward for representative states
    let mut states = vec![z0];
    for l in 0..prop.n_steps() {
        let next = prop.step(l, 1.0, &states[l]);
        states.push(next);
    }
    let est = layertime::analysis::estimate_layer_lipschitz(&prop, &states, 16, 1e-2, &mut rng);
    let mut tbl = Table::new(&["layer", "lipschitz"]);
    for (l, v) in est.iter().enumerate() {
        tbl.row(vec![i(l as i64), f(*v as f64, 4)]);
    }
    tbl.print();
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    println!("presets:");
    for name in presets::ALL {
        let rc = presets::by_name(name).unwrap();
        println!(
            "  {:<10} arch={:<8} layers={:<4} cf={} L={} fwd={:?} bwd={:?} opt={}",
            name,
            rc.model.arch.as_str(),
            rc.model.total_layers(),
            rc.mgrit.cf,
            rc.mgrit.levels,
            rc.mgrit.fwd_iters,
            rc.mgrit.bwd_iters,
            rc.train.opt.as_str()
        );
    }
    if let Some(engine) = engine_from(args)? {
        let mf = engine.manifest();
        println!("\nartifacts at {} (pallas={}):", mf.dir.display(), mf.use_pallas);
        for (name, e) in &mf.entries {
            println!("  {:<18} {} inputs, {} outputs", name, e.inputs.len(), e.outputs.len());
        }
        println!("  Φ flops: enc {:.2e}, dec {:.2e}", mf.flops_enc_step, mf.flops_dec_step);
        println!("  kernel VMEM: attention {} B, mlp {} B", mf.vmem_attention, mf.vmem_mlp);
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.subcommand().unwrap_or("help").to_string();
    if let Some(spec) = args.get("faults") {
        // arm the deterministic fault-injection registry before any
        // subsystem starts (chaos testing; see the fault module docs)
        layertime::fault::arm(spec).map_err(|e| anyhow!("--faults: {}", e))?;
        eprintln!("fault injection armed: {}", spec);
    }
    match cmd.as_str() {
        "train" => cmd_train(&args),
        "generate" => cmd_generate(&args),
        "predict" => cmd_predict(&args),
        "serve" => cmd_serve(&args),
        "bench-serve" => cmd_bench_serve(&args),
        "compare" => cmd_compare(&args),
        "simulate" => cmd_simulate(&args),
        "lipschitz" => cmd_lipschitz(&args),
        "info" => cmd_info(&args),
        "help" | "--help" => {
            println!("{}", USAGE);
            Ok(())
        }
        other => bail!("unknown subcommand '{}'\n{}", other, USAGE),
    }
}
