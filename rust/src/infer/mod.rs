//! Batched inference on the shared train/infer forward core.
//!
//! An [`InferSession`] is the serving-side counterpart of
//! [`crate::coordinator::Session`]: it owns a
//! [`crate::coordinator::ForwardContext`] (backend strategy + cached
//! forward MGRIT hierarchy + warm-start flag + forward workspace) and a
//! propagator, and **nothing else** — no objective, no adjoint buffers, no
//! optimizer. It is built from a [`crate::checkpoint::Checkpoint`] (or
//! directly from parts for tests/benches) and supports all four task
//! families:
//!
//! * **Autoregressive generation** ([`InferSession::generate_into`]) for
//!   the causal LM head: batched greedy or top-k-sampled decoding inside
//!   the model's fixed attention window. Each decode step embeds the token
//!   board, runs one full forward (serial buffers + mid-range solve on the
//!   cached hierarchy — MGRIT-accelerated for deep stacks, exact serial
//!   when the config says so), projects **one position's** logits through
//!   [`crate::coordinator::heads::lm_infer_into`], and selects the next
//!   token per sequence.
//! * **Translation** ([`InferSession::translate_into`]) for the
//!   encoder-decoder head: the decoder board starts at BOS (= vocab−1,
//!   the [`crate::data::translate::TranslateTask`] convention) and the
//!   stacked state Z = [X, Y] is re-solved per emitted position.
//! * **Batched prediction** ([`InferSession::predict_into`]):
//!   classification labels (mean-pool head), per-token tags, or per-token
//!   LM argmax (masked-fill / teacher-forced next-token predictions).
//!
//! The previous solve's trajectory stays in the workspace between decode
//! steps **within one call**, so V-cycle solves warm-start from it
//! (TorchBraid-style — the board changes by one token per step, making the
//! previous solution an excellent initial iterate); every public entry
//! point starts cold, so a call is a deterministic function of
//! (checkpoint, inputs, options). The steady-state decode loop is
//! **allocation-free**, exactly like the training step: the token board,
//! logits, top-k scratch and all solver storage persist across steps
//! (pinned by `rust/tests/alloc_audit.rs`).
//!
//! **Incremental decode** (on by default — see
//! [`InferSession::set_incremental`]) replaces the per-token full forward
//! with the KV-cached path: the prompt is ingested by **one** exact serial
//! forward whose stored per-layer trajectory also projects every layer's
//! K/V columns into a [`crate::reference::KvCache`], and every further
//! token is a single cached Φ sweep over a `[B, 1, D]` row state — O(1)
//! work per layer per token, no mid-range solve. Because the reference
//! kernels are row-wise with causally-masked prefix-invariant attention,
//! the cached tokens are **bitwise identical** to the full-forward decode
//! loop running serially (pinned by `rust/tests/decode_cache.rs`), and the
//! steady-state sweep is allocation-free. Turning incremental off restores
//! the historical full-board loop, whose forwards may be
//! MGRIT-approximate when the config says so.
//!
//! Top-k sampling draws from **per-sequence RNG streams** ([`row_seed`]
//! derives row `b`'s stream from `DecodeOptions::seed`), so one row's
//! tokens never depend on how many other rows are sampling next to it —
//! the property the continuous-batching scheduler ([`crate::serve`])
//! builds on to keep each request reproducible independent of batch
//! composition. The serve scheduler drives the session through the
//! row-granular entry points ([`InferSession::forward_board`] with
//! per-row warm-start resets, [`InferSession::logits_rows`] with per-row
//! cursors, and [`InferSession::swap_checkpoint`] for hot-reload).

use anyhow::{bail, ensure, Result};

use crate::checkpoint::Checkpoint;
use crate::config::{Arch, RunConfig};
use crate::coordinator::{
    backend_for_workers, heads, mid_range, Backend, ForwardContext, ForwardWorkspace, Task,
};
use crate::model::ParamStore;
use crate::ode::{Propagator, RustPropagator, StepCounters};
use crate::reference::KvCache;
use crate::util::rng::Rng;

/// How tokens are selected from decode-step logits.
#[derive(Debug, Clone)]
pub struct DecodeOptions {
    /// `0` = greedy argmax; `k > 0` = sample from the top-k logits after a
    /// temperature-scaled softmax over just those k.
    pub top_k: usize,
    /// Softmax temperature for top-k sampling (ignored when greedy);
    /// `T ≤ 0` is the argmax limit — it degenerates to greedy.
    pub temperature: f32,
    /// Base sampling seed; every `generate`/`translate` call reseeds, so a
    /// call is a deterministic function of (checkpoint, inputs, options).
    /// Each batch row samples from its own stream ([`row_seed`] mixes the
    /// row index in), so a row's tokens are independent of its neighbours.
    pub seed: u64,
    /// Cap on generated positions for `generate` (`0` = fill the window).
    /// The attention board cannot grow, so `prompt_len + max_new` must fit
    /// in the model window — overrunning it is a hard error, never a
    /// silent truncation.
    pub max_new: usize,
}

impl Default for DecodeOptions {
    fn default() -> DecodeOptions {
        DecodeOptions { top_k: 0, temperature: 1.0, seed: 0, max_new: 0 }
    }
}

/// A batched inference session over one checkpoint (see module docs).
pub struct InferSession {
    pub rc: RunConfig,
    pub params: ParamStore,
    prop: Box<dyn Propagator>,
    /// The shared train/infer forward core.
    ctx: ForwardContext,
    task: Task,
    /// Per-row sampling RNGs (reseeded per decode call from
    /// `DecodeOptions::seed` via [`row_seed`]; the serve scheduler manages
    /// its own per-request streams instead).
    row_rngs: Vec<Rng>,
    /// Reusable logits scratch, sized for the largest head this task
    /// family projects (`B·S·max(V, C)` covers decode and predict).
    logits: Vec<f32>,
    /// Mean-pool scratch for the classification head.
    pooled: Vec<f32>,
    /// Reusable decoder token board for `translate` ([B·S]).
    board: Vec<i32>,
    /// Top-k selection scratch (indices / values, capacity k).
    topk_idx: Vec<usize>,
    topk_val: Vec<f32>,
    /// KV-cached incremental decode enabled? (on by default; propagators
    /// without a cached path fall back to full forwards automatically).
    incremental: bool,
    /// Lazily-built per-layer decode K/V cache (`None` until first used).
    cache: Option<KvCache>,
    /// Serve-path flag: do the cache contents extend the current board
    /// under the current weights? (`false` ⇒ the next serve step prefills)
    cache_live: bool,
    /// Serve-path flag: the last forward was a cached `[B, 1, D]` row
    /// sweep, so `logits_rows` must read the row state, not the board.
    rows_mode: bool,
    /// Per-row board-position scratch for cached steps.
    dec_pos: Vec<usize>,
    /// Per-row newest-token scratch for cached steps.
    tok_rows: Vec<i32>,
}

impl InferSession {
    /// Build from a session checkpoint with default execution (pure-Rust
    /// Φ, single-threaded MGRIT backend).
    pub fn from_checkpoint(path: &str) -> Result<InferSession> {
        InferSession::from_checkpoint_with(path, 1)
    }

    /// Build from a session checkpoint, selecting the relaxation worker
    /// count (`> 1` → the threaded MGRIT backend, bitwise identical).
    pub fn from_checkpoint_with(path: &str, workers: usize) -> Result<InferSession> {
        InferSession::from_checkpoint_parts(Checkpoint::read(path)?, workers)
    }

    /// Build from an in-memory checkpoint image (the hot-reload startup
    /// path: `serve --watch DIR` loads the newest valid file itself).
    pub fn from_checkpoint_parts(ck: Checkpoint, workers: usize) -> Result<InferSession> {
        let params = ParamStore::from_parts(
            ck.rc.model.clone(),
            ck.layers,
            ck.w_emb,
            ck.w_pos,
            ck.w_out,
            ck.w_cls,
        );
        InferSession::from_parts(ck.rc, params, backend_for_workers(workers))
    }

    /// Assemble from already-loaded pieces (tests, benches, or a live
    /// parameter store). `rc.name` must resolve to a task so the session
    /// knows which head family to serve.
    pub fn from_parts(
        rc: RunConfig,
        params: ParamStore,
        backend: Box<dyn Backend>,
    ) -> Result<InferSession> {
        let task = Task::for_preset(&rc.name)?;
        let prop: Box<dyn Propagator> =
            Box::new(RustPropagator::for_model(&rc.model, params.layers.clone()));
        let m = &rc.model;
        let n_layers = m.total_layers();
        let head_shape = [m.batch, m.seq, m.d_model];
        let ws = ForwardWorkspace::new(n_layers, &prop.state_shape(), &head_shape);
        let ctx = ForwardContext::new(backend, ws);
        let logits_len = m.batch * m.seq * m.vocab.max(m.n_classes);
        Ok(InferSession {
            row_rngs: Vec::new(),
            logits: vec![0.0; logits_len],
            pooled: Vec::new(),
            board: Vec::new(),
            topk_idx: Vec::new(),
            topk_val: Vec::new(),
            incremental: true,
            cache: None,
            cache_live: false,
            rows_mode: false,
            dec_pos: Vec::new(),
            tok_rows: Vec::new(),
            rc,
            params,
            prop,
            ctx,
            task,
        })
    }

    /// The task family this session serves.
    pub fn task(&self) -> Task {
        self.task
    }

    /// The active backend's short name.
    pub fn backend_name(&self) -> &'static str {
        self.ctx.backend().name()
    }

    /// Override the forward-solve iteration budget: `None` = exact serial
    /// propagation, `Some(k)` = k MGRIT V-cycles on the cached hierarchy.
    /// Defaults to whatever the checkpointed config trained with (a run
    /// that switched serial under §3.2.3 decodes serially too).
    pub fn set_fwd_iters(&mut self, iters: Option<usize>) {
        self.rc.mgrit.fwd_iters = iters;
    }

    /// Cached-hierarchy introspection (decode steady state builds once).
    pub fn core_builds(&self) -> u64 {
        self.ctx.core_builds()
    }

    /// Toggle KV-cached incremental decode (on by default). Off restores
    /// the historical one-full-forward-per-token loop; tokens are bitwise
    /// identical between the two modes whenever the full forwards run
    /// serially (incremental prompt ingests always do).
    pub fn set_incremental(&mut self, on: bool) {
        self.incremental = on;
        self.cache_live = false;
        self.rows_mode = false;
    }

    /// Is KV-cached incremental decode enabled?
    pub fn incremental(&self) -> bool {
        self.incremental
    }

    /// Φ evaluation counters (full forward / VJP / cached decode steps) —
    /// the O(1)-per-token contract is pinned on these.
    pub fn phi_counters(&self) -> &StepCounters {
        self.prop.counters()
    }

    /// Lazily build the decode cache; `false` when the propagator has no
    /// incremental path (callers fall back to full forwards).
    fn ensure_cache(&mut self) -> bool {
        if self.cache.is_none() {
            self.cache = self.prop.make_cache();
        }
        self.cache.is_some()
    }

    /// One batched forward through the whole stack: embed `tokens` (and
    /// the decoder board for stacked states) into Z_0, then buffers + mid
    /// solve on the shared forward core. The final state is left in the
    /// forward workspace for a head to read.
    fn forward_batch(&mut self, tokens: &[i32], tgt_in: Option<&[i32]>) {
        self.forward_batch_with(tokens, tgt_in, self.rc.mgrit.fwd_iters)
    }

    /// [`InferSession::forward_batch`] with an explicit iteration budget:
    /// incremental prefills force `None` (exact serial) because the cached
    /// steps extend the stored trajectory bitwise.
    fn forward_batch_with(&mut self, tokens: &[i32], tgt_in: Option<&[i32]>, iters: Option<usize>) {
        let m = &self.rc.model;
        heads::embed_state_into(
            tokens,
            tgt_in,
            &self.params.w_emb,
            &self.params.w_pos,
            m.batch,
            m.seq,
            m.d_model,
            self.ctx.ws.states[0].data_mut(),
        );
        let (bo, n_mid) = mid_range(&self.rc.model);
        self.ctx.forward_full(
            self.prop.as_ref(),
            &self.rc.mgrit,
            bo,
            n_mid,
            iters,
            true, // decode steps warm-start from the previous trajectory
            false,
        );
    }

    /// Batched autoregressive generation for the causal LM head (`gpt`,
    /// decoder arch — causal masking is what makes the logits at `p−1`
    /// independent of the not-yet-generated board positions; the
    /// bidirectional MLM head cannot autoregress and is served by
    /// [`InferSession::predict_into`] instead). `prompts` is a dense
    /// `[B, prompt_len]` row-major grid (`B = rc.model.batch`), with
    /// `1 ≤ prompt_len ≤ seq`. `out` is resized to `[B, seq]`: the prompt
    /// copied through, then `max_new` positions generated (`0` = fill the
    /// window; `prompt_len + max_new` must fit — overrunning the board is
    /// an error, never a silent truncation). With incremental decode on
    /// (the default) the prompt costs one exact serial forward and every
    /// further token one cached O(1) Φ sweep; with it off, each position
    /// is a full forward. Returns the number of generated positions per
    /// sequence. Zero allocations at steady state once `out` and the
    /// scratch are warm.
    pub fn generate_into(
        &mut self,
        prompts: &[i32],
        prompt_len: usize,
        opts: &DecodeOptions,
        out: &mut Vec<i32>,
    ) -> Result<usize> {
        match self.task {
            Task::Lm => {}
            t => bail!(
                "generate targets the causal LM head; task {:?} serves predictions — use \
                 predict (or translate for the encoder-decoder head)",
                t
            ),
        }
        // determinism contract: each call is a function of (checkpoint,
        // inputs, options) — start cold; warm starts then chain across
        // the decode steps *within* this call only
        self.ctx.clear_warm();
        let (b, s, vocab) = (self.rc.model.batch, self.rc.model.seq, self.rc.model.vocab);
        ensure!(prompt_len >= 1 && prompt_len <= s, "prompt_len {} outside [1, {}]", prompt_len, s);
        ensure!(
            prompts.len() == b * prompt_len,
            "prompts has {} tokens, expected batch {} × prompt_len {}",
            prompts.len(),
            b,
            prompt_len
        );
        let max_new = if opts.max_new == 0 { s - prompt_len } else { opts.max_new };
        ensure!(
            prompt_len + max_new <= s,
            "prompt_len {} + max_new {} overruns the model window {} — the attention board \
             cannot grow; lower max_new or shorten the prompt",
            prompt_len,
            max_new,
            s
        );
        let end = prompt_len + max_new;
        self.row_rngs.clear();
        self.row_rngs.extend((0..b).map(|bi| Rng::new(row_seed(opts.seed, bi))));
        out.clear();
        out.resize(b * s, 0);
        for bi in 0..b {
            out[bi * s..bi * s + prompt_len]
                .copy_from_slice(&prompts[bi * prompt_len..(bi + 1) * prompt_len]);
        }
        if self.incremental && self.ensure_cache() {
            if end > prompt_len {
                self.decode_cached_lm(prompt_len, end, opts, out)?;
            }
            return Ok(max_new);
        }
        let stacked = self.rc.model.arch == Arch::EncDec;
        let n_layers = self.rc.model.total_layers();
        for p in prompt_len..end {
            self.forward_batch(out, None);
            // logits at position p-1 only (causal masking guarantees board
            // positions ≥ p cannot influence them), then per-row selection
            let x = self.ctx.ws.staged_head_view(n_layers, stacked);
            heads::lm_infer_into(
                x,
                &self.params.w_out,
                p - 1,
                vocab,
                &mut self.logits[..b * vocab],
            );
            for bi in 0..b {
                let lg = &self.logits[bi * vocab..(bi + 1) * vocab];
                let tok = pick_token(
                    lg,
                    opts,
                    &mut self.row_rngs[bi],
                    &mut self.topk_idx,
                    &mut self.topk_val,
                );
                out[bi * s + p] = tok;
            }
        }
        Ok(max_new)
    }

    /// Incremental LM decode: one exact serial prefill forward ingests the
    /// prompt and projects every layer's K/V columns into the cache; each
    /// further token embeds only the newest position per row and pushes
    /// the `[B, 1, D]` slice through the cached stack — O(1) work per
    /// layer per token and zero allocations at steady state. The cached
    /// kernels' row/prefix invariants make these tokens bitwise identical
    /// to the serial full-forward decode loop (`rust/tests/decode_cache.rs`).
    fn decode_cached_lm(
        &mut self,
        prompt_len: usize,
        end: usize,
        opts: &DecodeOptions,
        out: &mut [i32],
    ) -> Result<()> {
        let (b, s, d, vocab) = (
            self.rc.model.batch,
            self.rc.model.seq,
            self.rc.model.d_model,
            self.rc.model.vocab,
        );
        let n_layers = self.rc.model.total_layers();
        // generate clobbers any serve-side cache state
        self.cache_live = false;
        self.rows_mode = false;
        self.cache.as_mut().unwrap().reset_all();
        // prefill: cached steps extend an *exact* trajectory, so the
        // prompt forward is forced serial regardless of the MGRIT budget
        self.forward_batch_with(out, None, None);
        self.dec_pos.clear();
        self.dec_pos.resize(b, prompt_len - 1);
        {
            let cache = self.cache.as_mut().unwrap();
            for l in 0..n_layers {
                self.prop.fill_cached(l, cache, &self.ctx.ws.states[l], &self.dec_pos)?;
            }
            cache.commit(&self.dec_pos);
        }
        // the first generated token comes straight off the prefill board
        let x = self.ctx.ws.staged_head_view(n_layers, false);
        heads::lm_infer_into(
            x,
            &self.params.w_out,
            prompt_len - 1,
            vocab,
            &mut self.logits[..b * vocab],
        );
        for bi in 0..b {
            let lg = &self.logits[bi * vocab..(bi + 1) * vocab];
            let tok =
                pick_token(lg, opts, &mut self.row_rngs[bi], &mut self.topk_idx, &mut self.topk_val);
            out[bi * s + prompt_len] = tok;
        }
        for p in prompt_len + 1..end {
            self.tok_rows.clear();
            self.tok_rows.extend((0..b).map(|bi| out[bi * s + p - 1]));
            for q in self.dec_pos.iter_mut() {
                *q = p - 1;
            }
            heads::embed_rows_into(
                &self.tok_rows,
                &self.dec_pos,
                &self.params.w_emb,
                &self.params.w_pos,
                d,
                self.ctx.ws.row_cur.data_mut(),
            );
            let cache = self.cache.as_mut().unwrap();
            self.prop.step_to_cached(
                0,
                n_layers,
                cache,
                &self.dec_pos,
                &mut self.ctx.ws.row_cur,
                &mut self.ctx.ws.row_pp,
            )?;
            cache.commit(&self.dec_pos);
            heads::lm_infer_into(
                &self.ctx.ws.row_cur,
                &self.params.w_out,
                0,
                vocab,
                &mut self.logits[..b * vocab],
            );
            for bi in 0..b {
                let lg = &self.logits[bi * vocab..(bi + 1) * vocab];
                let tok = pick_token(
                    lg,
                    opts,
                    &mut self.row_rngs[bi],
                    &mut self.topk_idx,
                    &mut self.topk_val,
                );
                out[bi * s + p] = tok;
            }
        }
        Ok(())
    }

    /// Allocating wrapper over [`InferSession::generate_into`].
    pub fn generate(
        &mut self,
        prompts: &[i32],
        prompt_len: usize,
        opts: &DecodeOptions,
    ) -> Result<Vec<i32>> {
        let mut out = Vec::new();
        self.generate_into(prompts, prompt_len, opts, &mut out)?;
        Ok(out)
    }

    /// Batched greedy/top-k translation for the encoder-decoder head:
    /// `src` is the `[B, seq]` source grid; the decoder board starts at
    /// BOS (vocab−1) and each emitted target feeds the next position's
    /// decoder input (`tgt_in[p+1] = target[p]`, the teacher-forcing
    /// layout of the training data). `out` is resized to `[B, seq]` of
    /// predicted target tokens. Zero allocations at steady state.
    pub fn translate_into(
        &mut self,
        src: &[i32],
        opts: &DecodeOptions,
        out: &mut Vec<i32>,
    ) -> Result<()> {
        ensure!(
            self.task == Task::Translate,
            "translate requires the encoder-decoder head (task {:?})",
            self.task
        );
        let (b, s, vocab) = (self.rc.model.batch, self.rc.model.seq, self.rc.model.vocab);
        ensure!(src.len() == b * s, "src has {} tokens, expected {}", src.len(), b * s);
        let bos = (vocab - 1) as i32;
        // per-call determinism: start cold, warm-chain within the call
        self.ctx.clear_warm();
        self.row_rngs.clear();
        self.row_rngs.extend((0..b).map(|bi| Rng::new(row_seed(opts.seed, bi))));
        out.clear();
        out.resize(b * s, 0);
        let mut board = std::mem::take(&mut self.board);
        board.clear();
        board.resize(b * s, 0);
        for bi in 0..b {
            board[bi * s] = bos;
        }
        let n_layers = self.rc.model.total_layers();
        if self.incremental && self.ensure_cache() {
            let r = self.translate_cached(src, opts, &mut board, out);
            self.board = board;
            return r;
        }
        for p in 0..s {
            self.forward_batch(src, Some(&board));
            let x = self.ctx.ws.staged_head_view(n_layers, true);
            heads::lm_infer_into(
                x,
                &self.params.w_out,
                p,
                vocab,
                &mut self.logits[..b * vocab],
            );
            for bi in 0..b {
                let lg = &self.logits[bi * vocab..(bi + 1) * vocab];
                let tok = pick_token(
                    lg,
                    opts,
                    &mut self.row_rngs[bi],
                    &mut self.topk_idx,
                    &mut self.topk_val,
                );
                out[bi * s + p] = tok;
                if p + 1 < s {
                    board[bi * s + p + 1] = tok;
                }
            }
        }
        self.board = board;
        Ok(())
    }

    /// Incremental encoder-decoder decode: the position-0 solve is the
    /// **only** full forward — it runs the encoder once, primes every
    /// decoder layer's cross-attention K/V store from the stored encoder
    /// trajectory, and fills the decoder self-attention cache. Every later
    /// position embeds one target row and sweeps only the cached decoder
    /// layers (encoder time is frozen inside the cross store), O(1) per
    /// layer per token.
    fn translate_cached(
        &mut self,
        src: &[i32],
        opts: &DecodeOptions,
        board: &mut [i32],
        out: &mut [i32],
    ) -> Result<()> {
        let (b, s, d, vocab) = (
            self.rc.model.batch,
            self.rc.model.seq,
            self.rc.model.d_model,
            self.rc.model.vocab,
        );
        let n_layers = self.rc.model.total_layers();
        self.cache_live = false;
        self.rows_mode = false;
        self.cache.as_mut().unwrap().reset_all();
        // exact serial prefill over [src, BOS board] at target position 0
        self.forward_batch_with(src, Some(board), None);
        self.dec_pos.clear();
        self.dec_pos.resize(b, 0);
        let dec_lo;
        {
            let cache = self.cache.as_mut().unwrap();
            dec_lo = cache.layer0();
            for l in 0..n_layers {
                self.prop.fill_cached(l, cache, &self.ctx.ws.states[l], &self.dec_pos)?;
            }
            cache.set_cross_primed(true);
            cache.commit(&self.dec_pos);
        }
        let x = self.ctx.ws.staged_head_view(n_layers, true);
        heads::lm_infer_into(x, &self.params.w_out, 0, vocab, &mut self.logits[..b * vocab]);
        for bi in 0..b {
            let lg = &self.logits[bi * vocab..(bi + 1) * vocab];
            let tok =
                pick_token(lg, opts, &mut self.row_rngs[bi], &mut self.topk_idx, &mut self.topk_val);
            out[bi * s] = tok;
            if s > 1 {
                board[bi * s + 1] = tok;
            }
        }
        for p in 1..s {
            self.tok_rows.clear();
            self.tok_rows.extend((0..b).map(|bi| board[bi * s + p]));
            for q in self.dec_pos.iter_mut() {
                *q = p;
            }
            heads::embed_rows_into(
                &self.tok_rows,
                &self.dec_pos,
                &self.params.w_emb,
                &self.params.w_pos,
                d,
                self.ctx.ws.row_cur.data_mut(),
            );
            let cache = self.cache.as_mut().unwrap();
            self.prop.step_to_cached(
                dec_lo,
                n_layers,
                cache,
                &self.dec_pos,
                &mut self.ctx.ws.row_cur,
                &mut self.ctx.ws.row_pp,
            )?;
            cache.commit(&self.dec_pos);
            heads::lm_infer_into(
                &self.ctx.ws.row_cur,
                &self.params.w_out,
                0,
                vocab,
                &mut self.logits[..b * vocab],
            );
            for bi in 0..b {
                let lg = &self.logits[bi * vocab..(bi + 1) * vocab];
                let tok = pick_token(
                    lg,
                    opts,
                    &mut self.row_rngs[bi],
                    &mut self.topk_idx,
                    &mut self.topk_val,
                );
                out[bi * s + p] = tok;
                if p + 1 < s {
                    board[bi * s + p + 1] = tok;
                }
            }
        }
        Ok(())
    }

    /// Allocating wrapper over [`InferSession::translate_into`].
    pub fn translate(&mut self, src: &[i32], opts: &DecodeOptions) -> Result<Vec<i32>> {
        let mut out = Vec::new();
        self.translate_into(src, opts, &mut out)?;
        Ok(out)
    }

    /// Batched prediction over one `[B, seq]` input grid. Output layout
    /// depends on the head family: classification → `[B]` labels; tagging
    /// → `[B·S]` per-token tags; LM/MLM → `[B·S]` per-token argmax
    /// (masked-fill / teacher-forced next-token predictions). The
    /// encoder-decoder head has no single-forward prediction — use
    /// [`InferSession::translate_into`].
    pub fn predict_into(&mut self, tokens: &[i32], out: &mut Vec<i32>) -> Result<()> {
        let m = self.rc.model.clone();
        let (b, s) = (m.batch, m.seq);
        ensure!(tokens.len() == b * s, "tokens has {} ids, expected {}", tokens.len(), b * s);
        if self.task == Task::Translate {
            bail!("the encoder-decoder head decodes autoregressively — use translate");
        }
        // a prediction is a pure function of (checkpoint, tokens): never
        // warm-start it from whatever a previous call left behind
        self.ctx.clear_warm();
        self.forward_batch(tokens, None);
        let stacked = m.arch == Arch::EncDec;
        let n_layers = m.total_layers();
        let x = self.ctx.ws.staged_head_view(n_layers, stacked);
        match self.task {
            Task::Cls => {
                let c = m.n_classes;
                heads::cls_infer_into(
                    x,
                    &self.params.w_cls,
                    c,
                    &mut self.pooled,
                    &mut self.logits[..b * c],
                );
                argmax_rows(&self.logits[..b * c], c, b, out);
            }
            Task::Tag => {
                let c = m.n_classes;
                heads::tag_infer_into(x, &self.params.w_cls, c, &mut self.logits[..b * s * c]);
                argmax_rows(&self.logits[..b * s * c], c, b * s, out);
            }
            Task::Lm | Task::Mlm => {
                let v = m.vocab;
                heads::tag_infer_into(x, &self.params.w_out, v, &mut self.logits[..b * s * v]);
                argmax_rows(&self.logits[..b * s * v], v, b * s, out);
            }
            Task::Translate => unreachable!("rejected above"),
        }
        Ok(())
    }

    /// Allocating wrapper over [`InferSession::predict_into`].
    pub fn predict(&mut self, tokens: &[i32]) -> Result<Vec<i32>> {
        let mut out = Vec::new();
        self.predict_into(tokens, &mut out)?;
        Ok(out)
    }

    // --- row-granular entry points for the continuous-batching scheduler
    //     (`crate::serve`): the scheduler owns the token board and the
    //     per-request cursors/RNGs; the session supplies the forward solve
    //     and per-row logit projection ---

    /// One batched forward over a caller-owned `[B, seq]` token board for
    /// the causal LM head. Unlike [`InferSession::generate_into`] this does
    /// **not** clear the warm trajectory — the scheduler chains warm starts
    /// across decode steps of a long-lived batch and instead names the
    /// rows whose occupant just changed in `cold_rows`: those rows' warm
    /// iterate is reset to their fresh Z_0 (per-row cold start), so a
    /// newly joined request solves exactly like its solo cold first step
    /// while the neighbouring rows keep their warm parity.
    pub fn forward_board(&mut self, board: &[i32], cold_rows: &[usize]) -> Result<()> {
        ensure!(
            self.task == Task::Lm,
            "serve drives the causal LM head; task {:?} has no row-granular decode",
            self.task
        );
        let m = &self.rc.model;
        let (b, s, d) = (m.batch, m.seq, m.d_model);
        ensure!(board.len() == b * s, "board has {} tokens, expected {}", board.len(), b * s);
        for &r in cold_rows {
            ensure!(r < b, "cold row {} outside batch {}", r, b);
        }
        heads::embed_state_into(
            board,
            None,
            &self.params.w_emb,
            &self.params.w_pos,
            b,
            s,
            d,
            self.ctx.ws.states[0].data_mut(),
        );
        let (bo, n_mid) = mid_range(&self.rc.model);
        self.ctx.forward_full_cold_rows(
            self.prop.as_ref(),
            &self.rc.mgrit,
            bo,
            n_mid,
            self.rc.mgrit.fwd_iters,
            true,
            false,
            cold_rows,
            s * d,
        );
        self.rows_mode = false;
        self.cache_live = false;
        Ok(())
    }

    /// Serve-path forward with incremental decode. A **prefill** step —
    /// cold joiners present, or the cache does not extend this board
    /// (first step, weight swap, mode toggle) — runs one exact serial
    /// full-board forward and projects the missing K/V columns per row
    /// (cold rows ingest their whole prompt, warm rows just their newest
    /// column); a **steady** step embeds only each row's newest token and
    /// runs one cached Φ sweep over the `[B, 1, D]` row state. Returns
    /// `true` when it prefilled (the scheduler's metrics split). Rows stay
    /// independent: a cold join resets exactly the joiner's cache columns
    /// and an idle row only ever touches its own column 0, so a request's
    /// tokens never depend on occupancy, slot index, or join time.
    pub fn forward_board_cached(
        &mut self,
        board: &[i32],
        positions: &[usize],
        cold_rows: &[usize],
    ) -> Result<bool> {
        ensure!(
            self.task == Task::Lm,
            "serve drives the causal LM head; task {:?} has no row-granular decode",
            self.task
        );
        let (b, s, d) = (self.rc.model.batch, self.rc.model.seq, self.rc.model.d_model);
        ensure!(board.len() == b * s, "board has {} tokens, expected {}", board.len(), b * s);
        ensure!(positions.len() == b, "positions has {} rows, expected {}", positions.len(), b);
        for &r in cold_rows {
            ensure!(r < b, "cold row {} outside batch {}", r, b);
        }
        if !self.incremental || !self.ensure_cache() {
            // no cached path: every step is a full forward
            return self.forward_board(board, cold_rows).map(|_| !cold_rows.is_empty());
        }
        let prefill = !self.cache_live || !cold_rows.is_empty();
        let n_layers = self.rc.model.total_layers();
        if prefill {
            {
                let cache = self.cache.as_mut().unwrap();
                if self.cache_live {
                    // only the joiners' columns are stale — every other
                    // row's cache still extends the board bitwise
                    for &r in cold_rows {
                        cache.reset_row(r);
                    }
                } else {
                    cache.reset_all();
                }
            }
            // exact serial forward: cached steps extend an exact
            // trajectory, so prompt ingest cannot be MGRIT-approximate
            heads::embed_state_into(
                board,
                None,
                &self.params.w_emb,
                &self.params.w_pos,
                b,
                s,
                d,
                self.ctx.ws.states[0].data_mut(),
            );
            let (bo, n_mid) = mid_range(&self.rc.model);
            self.ctx.forward_full_cold_rows(
                self.prop.as_ref(),
                &self.rc.mgrit,
                bo,
                n_mid,
                None,
                true,
                false,
                cold_rows,
                s * d,
            );
            let cache = self.cache.as_mut().unwrap();
            for l in 0..n_layers {
                self.prop.fill_cached(l, cache, &self.ctx.ws.states[l], positions)?;
            }
            cache.commit(positions);
            self.cache_live = true;
            self.rows_mode = false;
        } else {
            self.tok_rows.clear();
            self.tok_rows.extend(positions.iter().enumerate().map(|(r, &p)| board[r * s + p]));
            heads::embed_rows_into(
                &self.tok_rows,
                positions,
                &self.params.w_emb,
                &self.params.w_pos,
                d,
                self.ctx.ws.row_cur.data_mut(),
            );
            let cache = self.cache.as_mut().unwrap();
            self.prop.step_to_cached(
                0,
                n_layers,
                cache,
                positions,
                &mut self.ctx.ws.row_cur,
                &mut self.ctx.ws.row_pp,
            )?;
            cache.commit(positions);
            self.rows_mode = true;
        }
        Ok(prefill)
    }

    /// Forget one slot's decode-cache columns (serve retirement): the next
    /// occupant joins as a cold row and prefills from scratch.
    pub fn release_row(&mut self, row: usize) {
        if let Some(cache) = self.cache.as_mut() {
            if row < cache.batch() {
                cache.reset_row(row);
            }
        }
    }

    /// Project logits at a **per-row** position from the final state the
    /// last [`InferSession::forward_board`] /
    /// [`InferSession::forward_board_cached`] left in the workspace: row
    /// `b` reads position `positions[b]`. Returns the `[B, vocab]` logits
    /// slice (row-major, reusable scratch — valid until the next call).
    pub fn logits_rows(&mut self, positions: &[usize]) -> Result<&[f32]> {
        ensure!(
            self.task == Task::Lm,
            "serve drives the causal LM head; task {:?} has no row-granular decode",
            self.task
        );
        let (b, vocab) = (self.rc.model.batch, self.rc.model.vocab);
        ensure!(positions.len() == b, "positions has {} rows, expected {}", positions.len(), b);
        let n_layers = self.rc.model.total_layers();
        if self.rows_mode {
            // the last forward was a cached row sweep: row b's final state
            // is the [B, 1, D] row slice, its board position at column 0
            // (bitwise the same projection as the full-board row read)
            heads::lm_infer_into(
                &self.ctx.ws.row_cur,
                &self.params.w_out,
                0,
                vocab,
                &mut self.logits[..b * vocab],
            );
        } else {
            let x = self.ctx.ws.staged_head_view(n_layers, false);
            heads::lm_infer_rows_into(
                x,
                &self.params.w_out,
                positions,
                vocab,
                &mut self.logits[..b * vocab],
            );
        }
        Ok(&self.logits[..b * vocab])
    }

    /// Drop the warm trajectory (all rows solve cold on the next forward).
    pub fn reset_warm(&mut self) {
        self.ctx.clear_warm();
    }

    /// Hot-swap the session's weights to another checkpoint **in place**
    /// (no solver storage or scratch is reallocated). The new checkpoint
    /// must describe the same model shape and task family; the warm
    /// trajectory is dropped because it belongs to the old weights. The
    /// serve loop calls this only between decode steps, so every request's
    /// step-`p` tokens come from exactly one weight snapshot.
    pub fn swap_checkpoint(&mut self, ck: &Checkpoint) -> Result<()> {
        ensure!(
            ck.rc.model == self.rc.model,
            "hot-reload requires an identical model config (serving {}, checkpoint {})",
            self.rc.name,
            ck.rc.name
        );
        let new_task = Task::for_preset(&ck.rc.name)?;
        ensure!(
            new_task == self.task,
            "hot-reload cannot change the task family ({:?} -> {:?})",
            self.task,
            new_task
        );
        {
            let mut layers = self.params.layers.write().unwrap();
            ensure!(
                layers.len() == ck.layers.len(),
                "layer count changed ({} -> {})",
                layers.len(),
                ck.layers.len()
            );
            for (dst, src) in layers.iter_mut().zip(ck.layers.iter()) {
                ensure!(dst.len() == src.len(), "layer parameter size changed");
                dst.copy_from_slice(src);
            }
        }
        self.params.w_emb.copy_from_slice(&ck.w_emb);
        self.params.w_pos.copy_from_slice(&ck.w_pos);
        self.params.w_out.copy_from_slice(&ck.w_out);
        self.params.w_cls.copy_from_slice(&ck.w_cls);
        self.ctx.clear_warm();
        // the decode cache holds projections of the old weights
        self.cache_live = false;
        self.rows_mode = false;
        if let Some(cache) = self.cache.as_mut() {
            cache.reset_all();
        }
        Ok(())
    }
}

/// Derive batch row `row`'s sampling stream from a base seed (SplitMix64
/// finalizer over a golden-ratio row mix). Distinct rows get well-separated
/// streams, and a row's stream never depends on how many rows exist — the
/// property the serve scheduler's occupancy-independence guarantee rests on.
pub fn row_seed(seed: u64, row: usize) -> u64 {
    let mut z = seed ^ (row as u64).wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Row-wise argmax of a `[rows, width]` logits grid into `out` (resized).
fn argmax_rows(logits: &[f32], width: usize, rows: usize, out: &mut Vec<i32>) {
    out.clear();
    out.resize(rows, 0);
    for r in 0..rows {
        let lg = &logits[r * width..(r + 1) * width];
        let mut best = 0usize;
        for (i, &v) in lg.iter().enumerate() {
            if v > lg[best] {
                best = i;
            }
        }
        out[r] = best as i32;
    }
}

/// Select one token from a logits row: greedy argmax, or temperature
/// softmax over the running top-k (maintained in the caller's reusable
/// scratch — no per-call allocations once capacity ≥ k). Public because
/// the serve scheduler samples from per-request RNG streams it owns.
pub fn pick_token(
    logits: &[f32],
    opts: &DecodeOptions,
    rng: &mut Rng,
    idx: &mut Vec<usize>,
    val: &mut Vec<f32>,
) -> i32 {
    let k = opts.top_k.min(logits.len());
    // T → 0 is the argmax limit: treat non-positive temperatures as greedy
    // (over all logits — identical to argmax over the top-k)
    if k == 0 || k == 1 || opts.temperature <= 0.0 {
        let mut best = 0usize;
        for (i, &v) in logits.iter().enumerate() {
            if v > logits[best] {
                best = i;
            }
        }
        return best as i32;
    }
    // running top-k by insertion: val is kept sorted descending
    idx.clear();
    val.clear();
    for (i, &v) in logits.iter().enumerate() {
        if val.len() < k {
            let mut j = val.len();
            val.push(v);
            idx.push(i);
            while j > 0 && val[j - 1] < v {
                val.swap(j - 1, j);
                idx.swap(j - 1, j);
                j -= 1;
            }
        } else if v > val[k - 1] {
            val[k - 1] = v;
            idx[k - 1] = i;
            let mut j = k - 1;
            while j > 0 && val[j - 1] < v {
                val.swap(j - 1, j);
                idx.swap(j - 1, j);
                j -= 1;
            }
        }
    }
    // temperature softmax over the k survivors, then CDF sampling
    // (temperature is > 0 here — the T ≤ 0 limit returned greedily above)
    let t = opts.temperature;
    let max = val[0];
    let mut z = 0.0f32;
    for v in val.iter_mut() {
        *v = ((*v - max) / t).exp();
        z += *v;
    }
    let mut u = rng.uniform() * z;
    for (j, &w) in val.iter().enumerate() {
        u -= w;
        if u <= 0.0 {
            return idx[j] as i32;
        }
    }
    idx[k - 1] as i32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::coordinator::Mgrit;
    use crate::model::Init;

    fn tiny_session(preset: &str, layers: usize) -> InferSession {
        let mut rc = presets::by_name(preset).unwrap();
        presets::shrink_for_bench(&mut rc);
        if rc.model.n_dec_layers > 0 && rc.model.n_enc_layers == 0 {
            rc.model.n_dec_layers = layers;
            rc.model.buffer_open = rc.model.buffer_open.min(1);
            rc.model.buffer_close = rc.model.buffer_close.min(1);
        } else if rc.model.arch == Arch::EncDec {
            rc.model.n_enc_layers = layers / 2;
            rc.model.n_dec_layers = layers - layers / 2;
        } else {
            rc.model.n_enc_layers = layers;
        }
        let params = ParamStore::init(&rc.model, Init::Default, 3);
        InferSession::from_parts(rc, params, Box::new(Mgrit)).unwrap()
    }

    #[test]
    fn generate_fills_the_window_deterministically() {
        let mut s = tiny_session("gpt", 6);
        let (b, seq) = (s.rc.model.batch, s.rc.model.seq);
        let plen = seq / 2;
        let prompts: Vec<i32> = (0..b * plen).map(|i| (i % 7) as i32).collect();
        let opts = DecodeOptions::default();
        let a = s.generate(&prompts, plen, &opts).unwrap();
        assert_eq!(a.len(), b * seq);
        for bi in 0..b {
            assert_eq!(&a[bi * seq..bi * seq + plen], &prompts[bi * plen..(bi + 1) * plen]);
        }
        let b2 = s.generate(&prompts, plen, &opts).unwrap();
        assert_eq!(a, b2, "greedy decode must be deterministic");
        // top_k = 1 degenerates to greedy
        let g1 = s
            .generate(&prompts, plen, &DecodeOptions { top_k: 1, ..DecodeOptions::default() })
            .unwrap();
        assert_eq!(a, g1);
        // top-k sampling is deterministic per seed and in-vocab
        let sampled = DecodeOptions { top_k: 4, temperature: 0.8, seed: 9, max_new: 0 };
        let t1 = s.generate(&prompts, plen, &sampled).unwrap();
        let t2 = s.generate(&prompts, plen, &sampled).unwrap();
        assert_eq!(t1, t2);
        assert!(t1.iter().all(|&t| (t as usize) < s.rc.model.vocab));
    }

    #[test]
    fn max_new_is_validated_against_the_window() {
        let mut s = tiny_session("gpt", 4);
        let (b, seq) = (s.rc.model.batch, s.rc.model.seq);
        let plen = seq / 2;
        let prompts: Vec<i32> = vec![1; b * plen];
        // overrunning the board is a hard error, not a silent truncation
        let opts = DecodeOptions { max_new: seq, ..DecodeOptions::default() };
        let err = s.generate(&prompts, plen, &opts).unwrap_err();
        assert!(err.to_string().contains("overruns the model window"), "{}", err);
        // a fitting cap generates exactly max_new positions and leaves the
        // board tail untouched
        let opts1 = DecodeOptions { max_new: 1, ..DecodeOptions::default() };
        let g = s.generate(&prompts, plen, &opts1).unwrap();
        assert_eq!(g.len(), b * seq);
        for bi in 0..b {
            assert!(g[bi * seq + plen + 1..(bi + 1) * seq].iter().all(|&t| t == 0));
        }
        // the capped prefix matches the uncapped run token-for-token
        let full = s.generate(&prompts, plen, &DecodeOptions::default()).unwrap();
        for bi in 0..b {
            assert_eq!(g[bi * seq..bi * seq + plen + 1], full[bi * seq..bi * seq + plen + 1]);
        }
    }

    #[test]
    fn incremental_and_full_decode_agree_bitwise() {
        let mut s = tiny_session("gpt", 6);
        let (b, seq) = (s.rc.model.batch, s.rc.model.seq);
        let plen = seq / 2;
        let prompts: Vec<i32> = (0..b * plen).map(|i| (i % 7) as i32).collect();
        // compare against the serial full-forward loop (the cached path's
        // prefill always runs serially, so serial-vs-serial is the
        // like-for-like comparison; MGRIT parity is covered elsewhere)
        s.set_fwd_iters(None);
        for opts in [
            DecodeOptions::default(),
            DecodeOptions { top_k: 4, temperature: 0.8, seed: 9, max_new: 0 },
        ] {
            assert!(s.incremental());
            let cached = s.generate(&prompts, plen, &opts).unwrap();
            s.set_incremental(false);
            let full = s.generate(&prompts, plen, &opts).unwrap();
            s.set_incremental(true);
            assert_eq!(cached, full, "cached decode must be bitwise identical");
        }
    }

    #[test]
    fn mgrit_and_serial_forwards_agree_when_converged() {
        // enough V-cycles converge MGRIT to the exact serial propagation,
        // so predictions must agree between the two forward modes
        let mut s = tiny_session("mc", 6);
        let (b, seq) = (s.rc.model.batch, s.rc.model.seq);
        let tokens: Vec<i32> = (0..b * seq).map(|i| (i % 11) as i32).collect();
        s.set_fwd_iters(None);
        let serial = s.predict(&tokens).unwrap();
        s.set_fwd_iters(Some(8));
        let mgrit = s.predict(&tokens).unwrap();
        assert_eq!(serial, mgrit, "converged MGRIT must predict like the serial forward");
        assert_eq!(serial.len(), b * seq, "tagging predicts per token");
    }

    #[test]
    fn predict_layouts_follow_the_head_family() {
        let mut s = tiny_session("vit", 4);
        let (b, seq, c) = (s.rc.model.batch, s.rc.model.seq, s.rc.model.n_classes);
        let tokens: Vec<i32> = (0..b * seq).map(|i| (i % 5) as i32).collect();
        let labels = s.predict(&tokens).unwrap();
        assert_eq!(labels.len(), b, "classification predicts per sequence");
        assert!(labels.iter().all(|&l| (l as usize) < c));
        // generate on a classification head is a hard error
        let err = s.generate(&tokens[..b], 1, &DecodeOptions::default()).unwrap_err();
        assert!(err.to_string().contains("predict"), "{}", err);
    }

    #[test]
    fn translate_decodes_the_stacked_state() {
        let mut s = tiny_session("mt", 6);
        let (b, seq) = (s.rc.model.batch, s.rc.model.seq);
        let src: Vec<i32> = (0..b * seq).map(|i| (i % 9) as i32).collect();
        let out = s.translate(&src, &DecodeOptions::default()).unwrap();
        assert_eq!(out.len(), b * seq);
        let out2 = s.translate(&src, &DecodeOptions::default()).unwrap();
        assert_eq!(out, out2, "greedy translation must be deterministic");
        // predict is not defined for the encoder-decoder head
        assert!(s.predict(&src).is_err());
        assert!(out.iter().all(|&t| (t as usize) < s.rc.model.vocab));
    }

    #[test]
    fn decode_reuses_one_cached_hierarchy() {
        let mut s = tiny_session("mc", 8);
        s.set_fwd_iters(Some(1));
        let (b, seq) = (s.rc.model.batch, s.rc.model.seq);
        let tokens: Vec<i32> = vec![1; b * seq];
        for _ in 0..5 {
            s.predict(&tokens).unwrap();
        }
        assert_eq!(s.core_builds(), 1, "steady-state inference builds exactly one core");
    }

    #[test]
    fn pick_token_topk_stays_within_the_k_best() {
        let logits = vec![0.0, 5.0, 4.0, -1.0, 4.5, 0.5];
        let mut rng = Rng::new(1);
        let (mut idx, mut val) = (Vec::new(), Vec::new());
        let opts = DecodeOptions { top_k: 3, ..DecodeOptions::default() };
        for _ in 0..200 {
            let t = pick_token(&logits, &opts, &mut rng, &mut idx, &mut val);
            assert!([1, 2, 4].contains(&t), "token {} outside the top-3", t);
        }
        // greedy picks the max
        let g = pick_token(&logits, &DecodeOptions::default(), &mut rng, &mut idx, &mut val);
        assert_eq!(g, 1);
        // the T → 0 limit is greedy, not full-entropy sampling
        let opts0 = DecodeOptions { top_k: 3, temperature: 0.0, ..DecodeOptions::default() };
        for _ in 0..20 {
            assert_eq!(pick_token(&logits, &opts0, &mut rng, &mut idx, &mut val), 1);
        }
    }
}
