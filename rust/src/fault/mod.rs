//! Deterministic fault injection + fault-event observability.
//!
//! Long training and serving runs fail in ways that are hard to reproduce:
//! a NaN gradient at step 40 000, a worker thread panicking mid-sweep, a
//! crash between two autosave writes, a request that never finishes. The
//! self-healing policies that handle those faults (see
//! [`crate::coordinator::session`] and [`crate::serve`]) are only
//! trustworthy if each fault class can be triggered *on demand, at an
//! exact site and hit count*, and the recovery compared bitwise against a
//! clean run. This module is that trigger.
//!
//! ## Fault points
//!
//! A *fault point* is a named site in the code guarded by the
//! [`faultpoint!`] macro:
//!
//! ```ignore
//! if crate::faultpoint!("pool.sweep_panic") {
//!     panic!("injected: pool.sweep_panic");
//! }
//! ```
//!
//! The macro expands to a single **relaxed atomic load** when the registry
//! is disarmed (the common case — `armed()` short-circuits before any
//! lock, string, or hash is touched), so fault points may sit inside the
//! zero-allocation hot paths pinned by `rust/tests/alloc_audit.rs`
//! without perturbing them. Only when `--faults` armed the registry does a
//! hit take the registry mutex to evaluate its trigger.
//!
//! ## Trigger specs
//!
//! `arm` parses a comma-separated spec string (the `--faults` CLI value):
//!
//! * `name@step=N` — fire exactly once, on the N-th hit of that site
//!   (1-based; "step" counts *site hits*, which for once-per-train-step
//!   sites equals the training step since arming).
//! * `name@count=K` — fire on each of the first K hits.
//! * `name` — shorthand for `name@count=1`.
//!
//! Hit counting is per-site and deterministic: the same binary, seed, and
//! spec always fires at the same program point, which is what lets
//! `rust/tests/chaos.rs` demand bitwise-identical recovery.
//!
//! ## Fault events
//!
//! Both *injected* faults and *organic* anomalies (a NaN loss the guard
//! caught, a sweep retry, an autosave rollback, a request deadline) are
//! recorded as typed [`FaultEvent`]s — always, armed or not — and
//! surfaced as a `fault_events` array in `--report` and serve metrics
//! JSON. Recording only happens on the (rare) anomaly paths, never on a
//! clean step, so the disarmed hot path stays allocation-free.
//!
//! The registry is process-global (fault specs cross thread boundaries:
//! a spec armed on the main thread must fire inside pool workers), so
//! tests that arm it serialize on a shared lock and call [`reset`].

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Mutex;

use crate::util::json::{self, Json};

/// Fast-path guard: non-zero while any fault spec is armed.
static ARMED: AtomicU32 = AtomicU32::new(0);

/// Slow-path state: armed specs + the event log. Only locked when a site
/// is hit while armed, or on the anomaly/recovery paths.
static REGISTRY: Mutex<Registry> = Mutex::new(Registry { specs: Vec::new(), events: Vec::new() });

struct Registry {
    specs: Vec<Spec>,
    events: Vec<FaultEvent>,
}

/// When an armed fault point fires (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Trigger {
    /// Fire exactly once, on this 1-based hit.
    AtHit(u64),
    /// Fire on each of the first K hits.
    FirstK(u64),
}

#[derive(Debug, Clone)]
struct Spec {
    name: String,
    trigger: Trigger,
    hits: u64,
    fired: u64,
}

/// One observed fault — injected by the registry or organic (detected and
/// handled by a self-healing policy). The `action` taxonomy is documented
/// in the README's fault-tolerance section.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultEvent {
    /// Fault-point or policy name (e.g. `pool.sweep_panic`,
    /// `train.step_anomaly`).
    pub point: String,
    /// Site hit count at injection, or the training step / serve decode
    /// step the policy acted on.
    pub at: u64,
    /// What happened: `injected`, `skipped_step`, `rollback`,
    /// `sweep_retry`, `sweep_serial_fallback`, `autosave_failed`,
    /// `reload_quarantined`, `timeout`, ...
    pub action: &'static str,
    /// Free-form context (error text, file name, norm values).
    pub detail: String,
}

impl FaultEvent {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("point", json::s(&self.point)),
            ("at", json::int(self.at as i64)),
            ("action", json::s(self.action)),
            ("detail", json::s(&self.detail)),
        ])
    }
}

fn lock() -> std::sync::MutexGuard<'static, Registry> {
    // a panic while holding the lock (never on purpose — fault points fire
    // *after* releasing it) must not wedge every later fault query
    REGISTRY.lock().unwrap_or_else(|e| e.into_inner())
}

/// Is any fault spec armed? One relaxed atomic load — the entire cost of
/// a disarmed fault point.
#[inline(always)]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed) != 0
}

/// Guard a fault-injection site. Expands to `false` after a single
/// relaxed atomic load when the registry is disarmed; when armed, counts
/// a hit on `$name` and returns whether the site should inject its fault
/// now. See [`crate::fault`] module docs.
#[macro_export]
macro_rules! faultpoint {
    ($name:expr) => {
        $crate::fault::armed() && $crate::fault::check($name)
    };
}

/// Slow path of [`faultpoint!`]: count a hit on `name` and decide whether
/// its armed trigger fires. Returns `false` for sites with no armed spec.
pub fn check(name: &str) -> bool {
    let mut reg = lock();
    let Some(spec) = reg.specs.iter_mut().find(|s| s.name == name) else {
        return false;
    };
    spec.hits += 1;
    let fire = match spec.trigger {
        Trigger::AtHit(n) => spec.hits == n,
        Trigger::FirstK(k) => spec.hits <= k,
    };
    if fire {
        spec.fired += 1;
        let (point, at) = (spec.name.clone(), spec.hits);
        reg.events.push(FaultEvent { point, at, action: "injected", detail: String::new() });
    }
    fire
}

/// Parse and arm a `--faults` spec string (see module docs for syntax).
/// Replaces any previously armed specs; the event log is kept.
pub fn arm(spec: &str) -> Result<(), String> {
    let mut specs = Vec::new();
    for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let (name, trigger) = match part.split_once('@') {
            None => (part, Trigger::FirstK(1)),
            Some((name, trig)) => {
                let (key, val) = trig.split_once('=').ok_or_else(|| {
                    format!("fault trigger '{}' must be step=N or count=K", trig)
                })?;
                let val: u64 = val
                    .parse()
                    .map_err(|_| format!("fault trigger '{}' needs an integer", trig))?;
                match key {
                    "step" => (name, Trigger::AtHit(val)),
                    "count" => (name, Trigger::FirstK(val)),
                    other => {
                        return Err(format!(
                            "unknown fault trigger '{}' (have: step=N, count=K)",
                            other
                        ))
                    }
                }
            }
        };
        if name.is_empty() {
            return Err(format!("empty fault-point name in '{}'", part));
        }
        specs.push(Spec { name: name.to_string(), trigger, hits: 0, fired: 0 });
    }
    if specs.is_empty() {
        return Err("empty --faults spec".to_string());
    }
    let mut reg = lock();
    reg.specs = specs;
    ARMED.store(1, Ordering::Relaxed);
    Ok(())
}

/// Disarm every spec and clear the event log (tests; a fresh `arm` call
/// only replaces specs).
pub fn reset() {
    let mut reg = lock();
    reg.specs.clear();
    reg.events.clear();
    ARMED.store(0, Ordering::Relaxed);
}

/// Record an organic fault event (anomaly detected, recovery action
/// taken). Called from the rare anomaly paths only — never from a clean
/// step — so the hot-path allocation audits are unaffected.
pub fn record(point: &str, at: u64, action: &'static str, detail: String) {
    let mut reg = lock();
    reg.events.push(FaultEvent { point: point.to_string(), at, action, detail });
}

/// Snapshot of the event log, oldest first.
pub fn events() -> Vec<FaultEvent> {
    lock().events.clone()
}

/// The event log as a JSON array (the `fault_events` field of `--report`
/// and serve metrics output).
pub fn events_json() -> Json {
    json::arr(lock().events.iter().map(|e| e.to_json()).collect())
}

/// How many times the named fault point actually fired (tests).
pub fn fired(name: &str) -> u64 {
    lock().specs.iter().find(|s| s.name == name).map(|s| s.fired).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-global; tests that arm it must not overlap.
    // Unrelated unit tests in this binary may *record* organic events
    // concurrently (sweep-retry tests and the like), so assertions filter
    // the log by this module's own point names instead of counting
    // globally.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    fn serial_test() -> std::sync::MutexGuard<'static, ()> {
        TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn events_for(point: &str) -> Vec<FaultEvent> {
        events().into_iter().filter(|e| e.point == point).collect()
    }

    #[test]
    fn disarmed_faultpoints_are_inert() {
        let _g = serial_test();
        reset();
        assert!(!armed());
        assert!(!crate::faultpoint!("anything.at_all"));
        assert!(events_for("anything.at_all").is_empty());
    }

    #[test]
    fn at_hit_trigger_fires_exactly_once_on_the_nth_hit() {
        let _g = serial_test();
        reset();
        arm("x.site@step=3").unwrap();
        let fires: Vec<bool> = (0..5).map(|_| crate::faultpoint!("x.site")).collect();
        assert_eq!(fires, vec![false, false, true, false, false]);
        assert_eq!(fired("x.site"), 1);
        let ev = events_for("x.site");
        assert_eq!(ev.len(), 1);
        assert_eq!(ev[0].at, 3);
        assert_eq!(ev[0].action, "injected");
        reset();
    }

    #[test]
    fn count_trigger_fires_on_the_first_k_hits() {
        let _g = serial_test();
        reset();
        arm("y.site@count=2").unwrap();
        let fires: Vec<bool> = (0..4).map(|_| crate::faultpoint!("y.site")).collect();
        assert_eq!(fires, vec![true, true, false, false]);
        assert_eq!(fired("y.site"), 2);
        reset();
    }

    #[test]
    fn bare_name_means_count_one_and_specs_compose() {
        let _g = serial_test();
        reset();
        arm("a.one, b.two@step=2").unwrap();
        assert!(crate::faultpoint!("a.one"));
        assert!(!crate::faultpoint!("a.one"));
        assert!(!crate::faultpoint!("b.two"));
        assert!(crate::faultpoint!("b.two"));
        assert!(!crate::faultpoint!("unarmed.site"));
        assert_eq!(events_for("a.one").len() + events_for("b.two").len(), 2);
        reset();
    }

    #[test]
    fn bad_specs_are_rejected() {
        let _g = serial_test();
        reset();
        assert!(arm("").is_err());
        assert!(arm("x@").is_err());
        assert!(arm("x@step").is_err());
        assert!(arm("x@step=abc").is_err());
        assert!(arm("x@every=3").is_err());
        assert!(arm("@step=1").is_err());
        assert!(!armed(), "a rejected spec must not arm the registry");
    }

    #[test]
    fn organic_events_are_recorded_even_disarmed() {
        let _g = serial_test();
        reset();
        record("test.organic_probe", 7, "skipped_step", "loss=NaN".to_string());
        let ev = events_for("test.organic_probe");
        assert_eq!(ev.len(), 1);
        assert_eq!(ev[0].action, "skipped_step");
        let j = events_json();
        let arr = j.arr().expect("events_json is an array");
        let mine: Vec<_> = arr
            .iter()
            .filter(|e| e.get("point").and_then(|p| p.str()) == Some("test.organic_probe"))
            .collect();
        assert_eq!(mine.len(), 1);
        assert_eq!(mine[0].get("at").unwrap().int(), Some(7));
        reset();
    }
}
