//! Persistent relaxation worker pool.
//!
//! `ThreadedMgrit` used to spawn scoped threads for every relaxation sweep
//! (~2 spawns × levels per V-cycle). A [`WorkerPool`] instead keeps
//! `size` long-lived threads, each owning one [`Endpoint`] of a shared
//! channel [`Fabric`] for halo exchange; between sweeps the workers park
//! on their job channel. One pool lives per `ThreadedMgrit` backend (i.e.
//! per `Session`), amortizing spawn cost across every sweep of a training
//! run while executing the *identical* slab schedule — bitwise parity with
//! the scoped-spawn executor is pinned by tests in
//! [`crate::parallel::exec`] and `rust/tests/backend_parity.rs`.
//!
//! ## Lifecycle
//!
//! * `WorkerPool::new(n)` builds the fabric, takes all endpoints, and
//!   spawns `n` named threads that block on `Receiver::recv` (parked).
//! * `run_scoped(jobs)` sends one closure per active rank (a prefix of the
//!   workers) and **blocks until every job has finished** — that barrier
//!   is what makes lending non-`'static` borrows to the workers sound,
//!   and it also guarantees every in-sweep halo message is consumed
//!   before the next sweep starts.
//! * `Drop` closes the job channels and joins the threads.
//!
//! ## Wiring with persistent solve contexts
//!
//! Since the solve-context refactor, the MGRIT hierarchies that drive the
//! sweeps are themselves cached per `Session`
//! ([`crate::coordinator::SolveContext`]). A cached
//! [`crate::mgrit::MgritCore`] does **not** pin the pool it last ran with:
//! the context re-fetches `Backend::pool()` before every solve and
//! re-attaches it via `MgritCore::set_pool`, so a pool that was poisoned
//! and rebuilt mid-run is picked up transparently while the (expensive)
//! level storage stays cached.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;
use std::thread::JoinHandle;

use super::comm::{Endpoint, Fabric};

/// A type-erased sweep job executed on one worker.
type Job = Box<dyn FnOnce(&mut Endpoint) + Send + 'static>;

/// Long-lived relaxation workers with a persistent halo-exchange fabric.
pub struct WorkerPool {
    size: usize,
    /// Job senders, rank-indexed. Behind a Mutex so the pool is `Sync`
    /// (backends hand out `Arc<WorkerPool>`); sends are cheap and the
    /// lock is only held while enqueueing one sweep.
    senders: Mutex<Vec<Sender<Job>>>,
    /// Set after a panicked/failed sweep: stale halo messages may be
    /// queued in the fabric, so further sweeps would silently consume
    /// previous-sweep state. `run_scoped` refuses a poisoned pool;
    /// owners (`ThreadedMgrit`) rebuild instead of reusing.
    poisoned: AtomicBool,
    /// Serializes whole sweeps. The fabric's halo messages are tagged by
    /// position within a sweep, not by sweep identity, so two sweeps
    /// interleaving on the same pool would dequeue each other's boundary
    /// states — wrong data, silently. In-tree callers are already
    /// serialized (one solve at a time per `Session`), but the pool is
    /// handed out as `Arc` clones; this guard makes concurrent callers
    /// block instead of corrupt.
    sweep: Mutex<()>,
    handles: Vec<JoinHandle<()>>,
}

/// Sends the completion signal even if the job panics (the unwind drops
/// the guard). Note this alone does not unblock a *peer* job waiting on a
/// fabric message from the panicked one — the pooled executors in
/// [`crate::parallel::exec`] handle that by poisoning the halo chain.
struct DoneGuard(Sender<()>);

impl Drop for DoneGuard {
    fn drop(&mut self) {
        let _ = self.0.send(());
    }
}

impl WorkerPool {
    /// Spawn `size` parked worker threads sharing one halo fabric.
    pub fn new(size: usize) -> WorkerPool {
        let size = size.max(1);
        let mut fabric = Fabric::new(size);
        let mut senders = Vec::with_capacity(size);
        let mut handles = Vec::with_capacity(size);
        for rank in 0..size {
            let (tx, rx): (Sender<Job>, Receiver<Job>) = channel();
            let mut ep = fabric.take(rank);
            let handle = std::thread::Builder::new()
                .name(format!("mgrit-worker-{}", rank))
                .spawn(move || {
                    while let Ok(job) = rx.recv() {
                        // a panicking job must not kill the worker: the
                        // sweep's barrier reports it instead (missing
                        // result), and later sweeps still have `size` ranks
                        let _ = catch_unwind(AssertUnwindSafe(|| job(&mut ep)));
                    }
                })
                .expect("spawn mgrit worker");
            senders.push(tx);
            handles.push(handle);
        }
        WorkerPool {
            size,
            senders: Mutex::new(senders),
            poisoned: AtomicBool::new(false),
            sweep: Mutex::new(()),
            handles,
        }
    }

    /// Number of worker threads (= fabric ranks).
    pub fn size(&self) -> usize {
        self.size
    }

    /// Mark the pool unusable (a sweep panicked or lost a worker; the
    /// fabric may hold stale halo messages). Subsequent `run_scoped`
    /// calls panic immediately instead of computing on stale state.
    pub fn poison(&self) {
        self.poisoned.store(true, Ordering::SeqCst);
    }

    /// Has this pool been through a failed sweep?
    pub fn is_poisoned(&self) -> bool {
        self.poisoned.load(Ordering::SeqCst)
    }

    /// Run one job per rank `0..jobs.len()` and block until all complete.
    ///
    /// Jobs may borrow from the caller's stack: the barrier guarantees the
    /// borrows outlive every access. Results travel through whatever
    /// channel the caller baked into the closures.
    ///
    /// Ranks only ever wait on *lower* ranks (the left-to-right halo flow
    /// in `exec`), so if dispatch fails at rank r — a worker thread died —
    /// the already-dispatched prefix `0..r` is self-contained: the barrier
    /// still completes for it before this method reports the dead worker.
    pub fn run_scoped<'scope>(&self, jobs: Vec<Box<dyn FnOnce(&mut Endpoint) + Send + 'scope>>) {
        // one sweep at a time on the shared fabric (see the `sweep` field);
        // mutex poisoning is ignored — the pool's own `poisoned` flag is
        // the authoritative failed-sweep signal and is checked right after
        let _sweep = self.sweep.lock().unwrap_or_else(|e| e.into_inner());
        assert!(
            !self.is_poisoned(),
            "worker pool poisoned by an earlier failed sweep; drop and rebuild it"
        );
        assert!(jobs.len() <= self.size, "more jobs than pool workers");
        let (done_tx, done_rx) = channel::<()>();
        let mut attempted = 0usize;
        let mut dead_worker = false;
        {
            let senders = self.senders.lock().unwrap();
            for (rank, job) in jobs.into_iter().enumerate() {
                let guard = DoneGuard(done_tx.clone());
                let wrapped: Box<dyn FnOnce(&mut Endpoint) + Send + 'scope> =
                    Box::new(move |ep: &mut Endpoint| {
                        let _guard = guard;
                        job(ep);
                    });
                // SAFETY: the job may borrow data with lifetime 'scope.
                // Every wrapped job signals `done_tx` exactly once — when
                // it finishes or unwinds on a worker (DoneGuard), or
                // immediately below if the send fails (the returned
                // SendError drops the job, firing its guard) — and we
                // block until all `attempted` signals arrive before
                // returning OR panicking, so no borrow is accessed after
                // run_scoped exits by any path. The transmute only erases
                // the lifetime bound; the trait-object layout is
                // unchanged.
                let job_static: Job = unsafe {
                    std::mem::transmute::<
                        Box<dyn FnOnce(&mut Endpoint) + Send + 'scope>,
                        Box<dyn FnOnce(&mut Endpoint) + Send + 'static>,
                    >(wrapped)
                };
                attempted += 1;
                if senders[rank].send(job_static).is_err() {
                    // never panic mid-dispatch: jobs already on workers
                    // still borrow the caller's stack — finish the barrier
                    // first, then report
                    dead_worker = true;
                    break;
                }
            }
        }
        drop(done_tx);
        for _ in 0..attempted {
            done_rx.recv().expect("mgrit worker dropped its sweep job");
        }
        if dead_worker {
            self.poison();
            panic!("mgrit worker thread died; sweep aborted");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // closing the job channels lets the recv loops exit
        self.senders.lock().unwrap().clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn jobs_run_on_distinct_parked_workers() {
        let pool = WorkerPool::new(3);
        let hits = AtomicUsize::new(0);
        let ranks = Mutex::new(Vec::new());
        // several sweeps through the same threads (persistence)
        for _ in 0..4 {
            let jobs: Vec<Box<dyn FnOnce(&mut Endpoint) + Send + '_>> = (0..3)
                .map(|_| {
                    Box::new(|ep: &mut Endpoint| {
                        hits.fetch_add(1, Ordering::SeqCst);
                        ranks.lock().unwrap().push(ep.rank);
                    }) as Box<dyn FnOnce(&mut Endpoint) + Send + '_>
                })
                .collect();
            pool.run_scoped(jobs);
        }
        assert_eq!(hits.load(Ordering::SeqCst), 12);
        let mut seen = ranks.lock().unwrap().clone();
        seen.sort_unstable();
        // each of the three ranks ran once per sweep, four sweeps
        assert_eq!(seen, vec![0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2]);
    }

    #[test]
    fn workers_exchange_halos_over_the_persistent_fabric() {
        let pool = WorkerPool::new(2);
        for sweep in 0..3u64 {
            let out = Mutex::new(0.0f32);
            let jobs: Vec<Box<dyn FnOnce(&mut Endpoint) + Send + '_>> = vec![
                Box::new(move |ep: &mut Endpoint| {
                    ep.send(1, 7, vec![sweep as f32 + 0.5]);
                }),
                Box::new(|ep: &mut Endpoint| {
                    let v = ep.recv(0, 7);
                    *out.lock().unwrap() = v[0];
                }),
            ];
            pool.run_scoped(jobs);
            assert_eq!(*out.lock().unwrap(), sweep as f32 + 0.5);
        }
    }

    #[test]
    fn partial_sweeps_use_a_rank_prefix() {
        let pool = WorkerPool::new(4);
        let ranks = Mutex::new(Vec::new());
        let jobs: Vec<Box<dyn FnOnce(&mut Endpoint) + Send + '_>> = (0..2)
            .map(|_| {
                Box::new(|ep: &mut Endpoint| {
                    ranks.lock().unwrap().push(ep.rank);
                }) as Box<dyn FnOnce(&mut Endpoint) + Send + '_>
            })
            .collect();
        pool.run_scoped(jobs);
        let mut seen = ranks.into_inner().unwrap();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1]);
    }
}
