//! Persistent relaxation worker pool.
//!
//! `ThreadedMgrit` used to spawn scoped threads for every relaxation sweep
//! (~2 spawns × levels per V-cycle). A [`WorkerPool`] instead keeps
//! `size` long-lived threads, each owning one [`Endpoint`] of a shared
//! channel [`Fabric`] for halo exchange plus a persistent [`Workspace`];
//! between sweeps the workers park on a condition variable. One pool lives
//! per `ThreadedMgrit` backend (i.e. per `Session`), amortizing spawn cost
//! across every sweep of a training run while executing the *identical*
//! slab schedule — bitwise parity with the scoped-spawn executor is pinned
//! by tests in [`crate::parallel::exec`] and `rust/tests/backend_parity.rs`.
//!
//! ## Allocation discipline
//!
//! [`WorkerPool::run_sweep`] is the hot dispatch path: the caller hands
//! *one* shared `&dyn Fn(rank, &mut Endpoint, &mut Workspace)` body and an
//! active-rank count. Dispatch is a generation bump + `notify_all`, the
//! barrier a counted condvar — no per-sweep boxing, no job channels, no
//! result channels. Together with the in-place slab bodies in
//! [`crate::parallel::exec`] and the buffer-recycling fabric this makes a
//! steady-state threaded relaxation sweep perform **zero** heap
//! allocations (pinned by `rust/tests/alloc_audit.rs`).
//!
//! The boxed-closure [`WorkerPool::run_scoped`] API is kept as a thin
//! compatibility wrapper (per-rank `FnOnce` jobs, allocating); the staged
//! executors and ad-hoc callers use it.
//!
//! ## Lifecycle
//!
//! * `WorkerPool::new(n)` builds the fabric and spawns `n` named threads
//!   that park on the job condvar.
//! * `run_sweep(active, body)` runs `body(rank, ..)` on ranks
//!   `0..active` and **blocks until every worker has passed the sweep
//!   barrier** — that is what makes lending non-`'static` borrows to the
//!   workers sound, and it also guarantees every in-sweep halo message is
//!   consumed before the next sweep starts.
//! * `Drop` sets the shutdown flag and joins the threads.
//!
//! ## Per-worker workspaces
//!
//! Each worker owns a [`Workspace`]: a type-erased slot for whatever
//! typed scratch the sweep body needs (the in-place FCF executor keeps
//! its boundary-step state there). The slot is sized on the first sweep
//! that needs it and rebuilt only when the requested type/shape changes;
//! [`WorkerPool::workspace_builds`] counts (re)builds so tests can pin the
//! reuse. A pool poisoned by a panicked sweep is rebuilt by its owner,
//! which also replaces every workspace — panic-poisoned workspaces are
//! recycled exactly like poisoned cores.
//!
//! ## Wiring with persistent solve contexts
//!
//! Since the solve-context refactor, the MGRIT hierarchies that drive the
//! sweeps are themselves cached per `Session`
//! ([`crate::coordinator::SolveContext`]). A cached
//! [`crate::mgrit::MgritCore`] does **not** pin the pool it last ran with:
//! the context re-fetches `Backend::pool()` before every solve and
//! re-attaches it via `MgritCore::set_pool`, so a pool that was poisoned
//! and rebuilt mid-run is picked up transparently while the (expensive)
//! level storage stays cached.

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::comm::{Endpoint, Fabric};

/// The shared sweep body: one closure for all ranks, borrowed from the
/// caller's stack for the duration of one sweep.
type SweepBody = dyn Fn(usize, &mut Endpoint, &mut Workspace) + Sync;

/// Per-worker persistent scratch (see module docs). Owned by the worker
/// thread itself; sweep bodies reach it through their `&mut Workspace`
/// argument and fetch typed storage with [`Workspace::typed`].
pub struct Workspace {
    slot: Option<Box<dyn Any + Send>>,
    builds: Arc<AtomicU64>,
}

impl Workspace {
    /// Fetch this worker's typed scratch, (re)building it when the cached
    /// value is missing, of another type, or rejected by `matches` (shape
    /// change). Rebuilds are counted in [`WorkerPool::workspace_builds`].
    /// (Named generics rather than `impl Trait` so callers can turbofish
    /// the storage type: `ws.typed::<T, _, _>(..)`.)
    pub fn typed<T, M, K>(&mut self, matches: M, make: K) -> &mut T
    where
        T: Any + Send,
        M: FnOnce(&T) -> bool,
        K: FnOnce() -> T,
    {
        let ok = self.slot.as_ref().and_then(|b| b.downcast_ref::<T>()).is_some_and(matches);
        if !ok {
            self.slot = Some(Box::new(make()));
            self.builds.fetch_add(1, Ordering::Relaxed);
        }
        self.slot.as_mut().unwrap().downcast_mut::<T>().unwrap()
    }
}

/// One dispatched sweep, published to the workers under the job mutex.
struct JobSlot {
    /// Sweep sequence number; a bump wakes every parked worker exactly once.
    gen: u64,
    /// Ranks `0..active` run the body; the rest just pass the barrier.
    active: usize,
    /// The shared body, lifetime-erased (sound: `run_sweep` holds the
    /// caller's borrow across the barrier and clears the slot before
    /// returning).
    body: Option<&'static SweepBody>,
}

struct Shared {
    job: Mutex<JobSlot>,
    job_cv: Condvar,
    /// Barrier: workers yet to finish the current sweep.
    remaining: Mutex<usize>,
    done_cv: Condvar,
    /// Panic payloads captured during the current sweep (cold path).
    panics: Mutex<Vec<Box<dyn Any + Send>>>,
    shutdown: AtomicBool,
}

/// Long-lived relaxation workers with a persistent halo-exchange fabric.
pub struct WorkerPool {
    size: usize,
    shared: Arc<Shared>,
    /// Set after a panicked/failed sweep: stale halo messages may be
    /// queued in the fabric, so further sweeps would silently consume
    /// previous-sweep state. `run_sweep` refuses a poisoned pool;
    /// owners (`ThreadedMgrit`) rebuild instead of reusing.
    poisoned: AtomicBool,
    /// Serializes whole sweeps. The fabric's halo messages are tagged by
    /// position within a sweep, not by sweep identity, so two sweeps
    /// interleaving on the same pool would dequeue each other's boundary
    /// states — wrong data, silently. In-tree callers are already
    /// serialized (one solve at a time per `Session`), but the pool is
    /// handed out as `Arc` clones; this guard makes concurrent callers
    /// block instead of corrupt.
    sweep: Mutex<()>,
    ws_builds: Arc<AtomicU64>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `size` parked worker threads sharing one halo fabric.
    pub fn new(size: usize) -> WorkerPool {
        let size = size.max(1);
        let mut fabric = Fabric::new(size);
        let shared = Arc::new(Shared {
            job: Mutex::new(JobSlot { gen: 0, active: 0, body: None }),
            job_cv: Condvar::new(),
            remaining: Mutex::new(0),
            done_cv: Condvar::new(),
            panics: Mutex::new(Vec::new()),
            shutdown: AtomicBool::new(false),
        });
        let ws_builds = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::with_capacity(size);
        for rank in 0..size {
            let mut ep = fabric.take(rank);
            let shared = shared.clone();
            let mut ws = Workspace { slot: None, builds: ws_builds.clone() };
            let handle = std::thread::Builder::new()
                .name(format!("mgrit-worker-{}", rank))
                .spawn(move || {
                    let mut seen = 0u64;
                    loop {
                        let (body, active) = {
                            let mut slot = shared.job.lock().unwrap();
                            loop {
                                if shared.shutdown.load(Ordering::SeqCst) {
                                    return;
                                }
                                if slot.gen != seen {
                                    seen = slot.gen;
                                    break (slot.body.expect("published sweep body"), slot.active);
                                }
                                slot = shared.job_cv.wait(slot).unwrap();
                            }
                        };
                        if rank < active {
                            // a panicking body must not kill the worker:
                            // the payload is recorded and re-raised at the
                            // dispatch site after the barrier
                            if let Err(p) =
                                catch_unwind(AssertUnwindSafe(|| body(rank, &mut ep, &mut ws)))
                            {
                                shared.panics.lock().unwrap().push(p);
                            }
                        }
                        let mut rem = shared.remaining.lock().unwrap();
                        *rem -= 1;
                        if *rem == 0 {
                            shared.done_cv.notify_all();
                        }
                    }
                })
                .expect("spawn mgrit worker");
            handles.push(handle);
        }
        WorkerPool {
            size,
            shared,
            poisoned: AtomicBool::new(false),
            sweep: Mutex::new(()),
            ws_builds,
            handles,
        }
    }

    /// Number of worker threads (= fabric ranks).
    pub fn size(&self) -> usize {
        self.size
    }

    /// How many per-worker typed workspaces have been (re)built on this
    /// pool so far — the workspace-reuse acceptance counter: stable shapes
    /// build once per participating worker and then never again.
    pub fn workspace_builds(&self) -> u64 {
        self.ws_builds.load(Ordering::Relaxed)
    }

    /// Mark the pool unusable (a sweep panicked; the fabric may hold stale
    /// halo messages and desynced recycled buffers). Subsequent sweeps
    /// panic immediately instead of computing on stale state.
    pub fn poison(&self) {
        self.poisoned.store(true, Ordering::SeqCst);
    }

    /// Has this pool been through a failed sweep?
    pub fn is_poisoned(&self) -> bool {
        self.poisoned.load(Ordering::SeqCst)
    }

    /// Run `body(rank, endpoint, workspace)` on ranks `0..active` of the
    /// parked workers and block until **all** `size` workers have passed
    /// the sweep barrier (inactive ranks pass it without running the
    /// body). The allocation-free dispatch primitive: one shared borrowed
    /// closure, no boxing, no channels.
    ///
    /// The body may borrow from the caller's stack: the barrier guarantees
    /// the borrows outlive every access. A body panic on any rank is
    /// re-raised here after the barrier, with the pool poisoned first.
    pub fn run_sweep(
        &self,
        active: usize,
        body: &(dyn Fn(usize, &mut Endpoint, &mut Workspace) + Sync),
    ) {
        // one sweep at a time on the shared fabric (see the `sweep` field);
        // mutex poisoning is ignored — the pool's own `poisoned` flag is
        // the authoritative failed-sweep signal and is checked right after
        let _sweep = self.sweep.lock().unwrap_or_else(|e| e.into_inner());
        assert!(
            !self.is_poisoned(),
            "worker pool poisoned by an earlier failed sweep; drop and rebuild it"
        );
        assert!(active <= self.size, "more active ranks than pool workers");
        // SAFETY: the transmute only erases the borrow's lifetime; the
        // trait-object layout is unchanged. Every worker passes the
        // barrier below before this method returns by any path, so the
        // erased borrow is never accessed after it expires.
        let body_static: &'static SweepBody = unsafe {
            std::mem::transmute::<&SweepBody, &'static SweepBody>(body)
        };
        *self.shared.remaining.lock().unwrap() = self.size;
        {
            let mut slot = self.shared.job.lock().unwrap();
            slot.gen += 1;
            slot.active = active;
            slot.body = Some(body_static);
        }
        self.shared.job_cv.notify_all();
        {
            // counted barrier with a liveness backstop: a worker thread
            // that dies outside the body catch (it "never" should) would
            // otherwise leave `remaining` stuck and freeze training
            // silently — fail loudly and poison instead, like the old
            // boxed-job dispatcher did.
            let mut rem = self.shared.remaining.lock().unwrap();
            while *rem > 0 {
                let (guard, timeout) = self
                    .shared
                    .done_cv
                    .wait_timeout(rem, std::time::Duration::from_millis(200))
                    .unwrap();
                rem = guard;
                if timeout.timed_out()
                    && *rem > 0
                    && self.handles.iter().any(|h| h.is_finished())
                {
                    drop(rem);
                    self.poison();
                    panic!("mgrit worker thread died; sweep aborted");
                }
            }
        }
        // the borrow expires with this frame: drop the erased copy first
        self.shared.job.lock().unwrap().body = None;
        let payload = {
            let mut panics = self.shared.panics.lock().unwrap();
            if panics.is_empty() {
                None
            } else {
                let first = panics.swap_remove(0);
                panics.clear();
                Some(first)
            }
        };
        if let Some(p) = payload {
            self.poison();
            resume_unwind(p);
        }
    }

    /// Compatibility dispatch: one boxed `FnOnce` job per rank
    /// `0..jobs.len()`, executed through [`WorkerPool::run_sweep`]. Used
    /// by the staged executors and ad-hoc callers; allocates per sweep
    /// (the in-place hot path uses `run_sweep` directly).
    pub fn run_scoped<'scope>(&self, jobs: Vec<Box<dyn FnOnce(&mut Endpoint) + Send + 'scope>>) {
        assert!(jobs.len() <= self.size, "more jobs than pool workers");
        let active = jobs.len();
        type JobSlotCell<'s> = Mutex<Option<Box<dyn FnOnce(&mut Endpoint) + Send + 's>>>;
        let slots: Vec<JobSlotCell<'scope>> =
            jobs.into_iter().map(|j| Mutex::new(Some(j))).collect();
        self.run_sweep(active, &|rank: usize, ep: &mut Endpoint, _ws: &mut Workspace| {
            let job = slots[rank].lock().unwrap().take().expect("job dispatched once");
            job(ep);
        });
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        // grab the job lock so parked workers are guaranteed to observe
        // the flag on wakeup
        drop(self.shared.job.lock().unwrap());
        self.shared.job_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn jobs_run_on_distinct_parked_workers() {
        let pool = WorkerPool::new(3);
        let hits = AtomicUsize::new(0);
        let ranks = Mutex::new(Vec::new());
        // several sweeps through the same threads (persistence)
        for _ in 0..4 {
            let jobs: Vec<Box<dyn FnOnce(&mut Endpoint) + Send + '_>> = (0..3)
                .map(|_| {
                    Box::new(|ep: &mut Endpoint| {
                        hits.fetch_add(1, Ordering::SeqCst);
                        ranks.lock().unwrap().push(ep.rank);
                    }) as Box<dyn FnOnce(&mut Endpoint) + Send + '_>
                })
                .collect();
            pool.run_scoped(jobs);
        }
        assert_eq!(hits.load(Ordering::SeqCst), 12);
        let mut seen = ranks.lock().unwrap().clone();
        seen.sort_unstable();
        // each of the three ranks ran once per sweep, four sweeps
        assert_eq!(seen, vec![0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2]);
    }

    #[test]
    fn run_sweep_shares_one_body_across_ranks() {
        let pool = WorkerPool::new(4);
        let ranks = Mutex::new(Vec::new());
        for _ in 0..3 {
            pool.run_sweep(4, &|rank: usize, ep: &mut Endpoint, _ws: &mut Workspace| {
                assert_eq!(rank, ep.rank);
                ranks.lock().unwrap().push(rank);
            });
        }
        let mut seen = ranks.into_inner().unwrap();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 0, 0, 1, 1, 1, 2, 2, 2, 3, 3, 3]);
    }

    #[test]
    fn workers_exchange_halos_over_the_persistent_fabric() {
        let pool = WorkerPool::new(2);
        for sweep in 0..3u64 {
            let out = Mutex::new(0.0f32);
            let jobs: Vec<Box<dyn FnOnce(&mut Endpoint) + Send + '_>> = vec![
                Box::new(move |ep: &mut Endpoint| {
                    ep.send(1, 7, vec![sweep as f32 + 0.5]);
                }),
                Box::new(|ep: &mut Endpoint| {
                    let v = ep.recv(0, 7);
                    *out.lock().unwrap() = v[0];
                }),
            ];
            pool.run_scoped(jobs);
            assert_eq!(*out.lock().unwrap(), sweep as f32 + 0.5);
        }
    }

    #[test]
    fn partial_sweeps_use_a_rank_prefix() {
        let pool = WorkerPool::new(4);
        let ranks = Mutex::new(Vec::new());
        pool.run_sweep(2, &|rank: usize, _ep: &mut Endpoint, _ws: &mut Workspace| {
            ranks.lock().unwrap().push(rank);
        });
        let mut seen = ranks.into_inner().unwrap();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1]);
    }

    #[test]
    fn workspaces_persist_and_rebuild_on_shape_change() {
        let pool = WorkerPool::new(2);
        assert_eq!(pool.workspace_builds(), 0);
        let sweep = |len: usize| {
            pool.run_sweep(2, &|_rank: usize, _ep: &mut Endpoint, ws: &mut Workspace| {
                let v = ws.typed::<Vec<f32>, _, _>(|v| v.len() == len, || vec![0.0; len]);
                assert_eq!(v.len(), len);
            });
        };
        // first sweep builds one workspace per active worker...
        sweep(8);
        assert_eq!(pool.workspace_builds(), 2);
        // ...steady-state sweeps reuse them...
        for _ in 0..5 {
            sweep(8);
        }
        assert_eq!(pool.workspace_builds(), 2, "stable shapes must not rebuild");
        // ...and a shape change rebuilds exactly once per worker
        sweep(16);
        assert_eq!(pool.workspace_builds(), 4);
        for _ in 0..3 {
            sweep(16);
        }
        assert_eq!(pool.workspace_builds(), 4);
    }

    #[test]
    fn sweep_panic_poisons_and_reraises_after_the_barrier() {
        use std::panic::{catch_unwind as cu, AssertUnwindSafe as Aus};
        let pool = WorkerPool::new(3);
        let r = cu(Aus(|| {
            pool.run_sweep(3, &|rank: usize, _ep: &mut Endpoint, _ws: &mut Workspace| {
                assert_ne!(rank, 1, "boom");
            });
        }));
        assert!(r.is_err(), "a body panic must re-raise at the dispatch site");
        assert!(pool.is_poisoned());
        let noop = |_r: usize, _e: &mut Endpoint, _w: &mut Workspace| {};
        let retry = cu(Aus(|| pool.run_sweep(3, &noop)));
        assert!(retry.is_err(), "poisoned pool must refuse further sweeps");
    }
}
