//! Device grid: lp_degree ranks along the layer/time dimension ×
//! dp_degree data-parallel replicas (paper §4.2, Fig. 9).

/// The lp×dp grid. Rank layout: rank = dp_idx * lp + lp_idx.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Topology {
    pub lp: usize,
    pub dp: usize,
}

impl Topology {
    pub fn new(lp: usize, dp: usize) -> Topology {
        assert!(lp >= 1 && dp >= 1);
        Topology { lp, dp }
    }

    pub fn n_ranks(&self) -> usize {
        self.lp * self.dp
    }

    pub fn lp_index(&self, rank: usize) -> usize {
        rank % self.lp
    }

    pub fn dp_index(&self, rank: usize) -> usize {
        rank / self.lp
    }

    pub fn rank_of(&self, lp_idx: usize, dp_idx: usize) -> usize {
        dp_idx * self.lp + lp_idx
    }

    /// Ranks in the same data-parallel replica (one layer-parallel group).
    pub fn lp_group(&self, dp_idx: usize) -> Vec<usize> {
        (0..self.lp).map(|l| self.rank_of(l, dp_idx)).collect()
    }

    /// Ranks holding the same layer slab across replicas (the gradient
    /// allreduce group).
    pub fn dp_group(&self, lp_idx: usize) -> Vec<usize> {
        (0..self.dp).map(|d| self.rank_of(lp_idx, d)).collect()
    }
}

/// All ways to split a `--workers` budget across the dp×lp grid: every
/// divisor `D` of `workers` with `D <= dp` yields the candidate
/// `Topology { lp: workers / D, dp: D }` (D concurrent replica lanes,
/// each driving `workers / D` relaxation workers). Ascending in `D`, so
/// the all-layer-parallel split comes first. `dp = 0` is treated as 1.
pub fn worker_splits(workers: usize, dp: usize) -> Vec<Topology> {
    let workers = workers.max(1);
    let dp = dp.max(1);
    (1..=workers.min(dp))
        .filter(|d| workers % d == 0)
        .map(|d| Topology { lp: workers / d, dp: d })
        .collect()
}

/// Pick the worker split minimizing `cost(dp_workers, lp_workers)` over
/// [`worker_splits`] — the auto-split heuristic behind `--workers` when no
/// explicit `--dp-workers` is given. The session's cost closure consults
/// [`crate::parallel::Simulator`]: replica waves × modeled batch time, the
/// convex dp-vs-lp tradeoff of paper Fig. 9. Ties keep the earliest (most
/// layer-parallel) candidate. The choice is an *execution* detail: any
/// split produces bitwise-identical training, only wall-clock differs.
pub fn auto_split(
    workers: usize,
    dp: usize,
    mut cost: impl FnMut(usize, usize) -> f64,
) -> Topology {
    let mut best: Option<(Topology, f64)> = None;
    for t in worker_splits(workers, dp) {
        let c = cost(t.dp, t.lp);
        let better = match best {
            None => true,
            Some((_, bc)) => c < bc,
        };
        if better {
            best = Some((t, c));
        }
    }
    best.map(|(t, _)| t).expect("worker_splits is never empty")
}

/// Contiguous partition of `n_items` over `parts` owners: the first
/// `n_items % parts` owners get one extra. Returns (start, end) per owner.
pub fn slab_partition(n_items: usize, parts: usize) -> Vec<(usize, usize)> {
    assert!(parts >= 1);
    (0..parts).map(|p| slab_range(n_items, parts, p)).collect()
}

/// O(1) form of [`slab_partition`]: owner `p`'s (start, end) range without
/// materializing the whole partition. The allocation-free per-worker form
/// the in-place relaxation executors compute inside each slab body; by
/// construction the ranges of distinct owners are pairwise disjoint and
/// cover `0..n_items` contiguously (pinned by `prop_partition_covers_exactly`).
pub fn slab_range(n_items: usize, parts: usize, p: usize) -> (usize, usize) {
    assert!(parts >= 1 && p < parts, "owner {} of {} parts", p, parts);
    let base = n_items / parts;
    let extra = n_items % parts;
    let start = p * base + p.min(extra);
    (start, start + base + usize::from(p < extra))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    #[test]
    fn grid_indexing_roundtrip() {
        let t = Topology::new(4, 2);
        assert_eq!(t.n_ranks(), 8);
        for rank in 0..8 {
            assert_eq!(t.rank_of(t.lp_index(rank), t.dp_index(rank)), rank);
        }
        assert_eq!(t.lp_group(1), vec![4, 5, 6, 7]);
        assert_eq!(t.dp_group(2), vec![2, 6]);
    }

    #[test]
    fn prop_partition_covers_exactly() {
        forall("slab-partition", 100, |rng| {
            let n = rng.range(200);
            let parts = 1 + rng.range(16);
            let slabs = slab_partition(n, parts);
            assert_eq!(slabs.len(), parts);
            assert_eq!(slabs[0].0, 0);
            assert_eq!(slabs[parts - 1].1, n);
            for w in slabs.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
            }
            // balanced within 1
            let sizes: Vec<usize> = slabs.iter().map(|(a, b)| b - a).collect();
            let (min, max) =
                (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1);
        });
    }

    #[test]
    fn worker_splits_enumerate_divisor_grids() {
        // 8 workers, dp=4: D ∈ {1, 2, 4}
        assert_eq!(
            worker_splits(8, 4),
            vec![
                Topology { lp: 8, dp: 1 },
                Topology { lp: 4, dp: 2 },
                Topology { lp: 2, dp: 4 },
            ]
        );
        // dp caps the replica-lane count even with more divisors available
        assert_eq!(
            worker_splits(8, 2),
            vec![Topology { lp: 8, dp: 1 }, Topology { lp: 4, dp: 2 }]
        );
        // degenerate budgets still yield the serial grid
        assert_eq!(worker_splits(1, 4), vec![Topology { lp: 1, dp: 1 }]);
        assert_eq!(worker_splits(0, 0), vec![Topology { lp: 1, dp: 1 }]);
        // prime budgets: only the two extremes
        assert_eq!(
            worker_splits(7, 7),
            vec![Topology { lp: 7, dp: 1 }, Topology { lp: 1, dp: 7 }]
        );
        // every candidate spends the whole budget
        for t in worker_splits(12, 6) {
            assert_eq!(t.lp * t.dp, 12);
        }
    }

    #[test]
    fn auto_split_minimizes_cost_and_breaks_ties_toward_lp() {
        // cost favoring maximal dp lanes
        let t = auto_split(8, 4, |d, _l| -(d as f64));
        assert_eq!(t, Topology { lp: 2, dp: 4 });
        // cost favoring maximal lp
        let t = auto_split(8, 4, |_d, l| -(l as f64));
        assert_eq!(t, Topology { lp: 8, dp: 1 });
        // flat cost: tie keeps the first (most layer-parallel) candidate
        let t = auto_split(8, 4, |_d, _l| 1.0);
        assert_eq!(t, Topology { lp: 8, dp: 1 });
    }

    #[test]
    fn paper_example_fig9() {
        // 32 GPUs, dp=8 -> lp=4, 64-layer model -> 16 layers per device
        let t = Topology::new(4, 8);
        assert_eq!(t.n_ranks(), 32);
        let slabs = slab_partition(64, t.lp);
        assert!(slabs.iter().all(|(a, b)| b - a == 16));
    }
}
