//! Channel-based message fabric — the in-process substitute for GPU-aware
//! MPI (DESIGN.md §Substitutions). Every rank gets an [`Endpoint`] with
//! point-to-point send/recv plus collective helpers; global counters track
//! messages and bytes for the §Perf logs and simulator calibration.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

/// A tagged message between ranks.
#[derive(Debug)]
pub struct Msg {
    pub from: usize,
    pub tag: u64,
    pub data: Vec<f32>,
}

/// Global traffic counters.
#[derive(Debug, Default)]
pub struct Counters {
    pub messages: AtomicU64,
    pub bytes: AtomicU64,
}

/// All-to-all mesh of mpsc channels for `n` ranks.
pub struct Fabric {
    endpoints: Vec<Option<Endpoint>>,
    pub counters: Arc<Counters>,
}

/// One rank's view of the fabric.
pub struct Endpoint {
    pub rank: usize,
    pub n_ranks: usize,
    senders: Vec<Sender<Msg>>,
    receiver: Receiver<Msg>,
    /// out-of-order buffer for selective recv
    stash: Vec<Msg>,
    counters: Arc<Counters>,
}

impl Fabric {
    pub fn new(n: usize) -> Fabric {
        let counters = Arc::new(Counters::default());
        let mut senders = Vec::with_capacity(n);
        let mut receivers = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(rx);
        }
        let endpoints = receivers
            .into_iter()
            .enumerate()
            .map(|(rank, receiver)| {
                Some(Endpoint {
                    rank,
                    n_ranks: n,
                    senders: senders.clone(),
                    receiver,
                    stash: Vec::new(),
                    counters: counters.clone(),
                })
            })
            .collect();
        Fabric { endpoints, counters }
    }

    /// Take rank `r`'s endpoint (each can be taken once, then moved into a
    /// worker thread).
    pub fn take(&mut self, r: usize) -> Endpoint {
        self.endpoints[r].take().expect("endpoint already taken")
    }

    /// Take all remaining endpoints.
    pub fn take_all(&mut self) -> Vec<Endpoint> {
        (0..self.endpoints.len()).map(|r| self.take(r)).collect()
    }
}

impl Endpoint {
    /// Send `data` to rank `to` with a tag.
    pub fn send(&self, to: usize, tag: u64, data: Vec<f32>) {
        self.counters.messages.fetch_add(1, Ordering::Relaxed);
        self.counters.bytes.fetch_add(4 * data.len() as u64, Ordering::Relaxed);
        self.senders[to]
            .send(Msg { from: self.rank, tag, data })
            .expect("fabric receiver dropped");
    }

    /// Blocking receive of the next message matching (from, tag).
    pub fn recv(&mut self, from: usize, tag: u64) -> Vec<f32> {
        if let Some(i) = self.stash.iter().position(|m| m.from == from && m.tag == tag) {
            return self.stash.swap_remove(i).data;
        }
        loop {
            let m = self.receiver.recv().expect("fabric sender dropped");
            if m.from == from && m.tag == tag {
                return m.data;
            }
            self.stash.push(m);
        }
    }

    /// Sum-allreduce across all ranks (flat binary-tree reduce + broadcast).
    /// Deterministic reduction order regardless of arrival order.
    pub fn allreduce_sum(&mut self, tag: u64, mut data: Vec<f32>) -> Vec<f32> {
        let n = self.n_ranks;
        // reduce to rank 0 over a binary tree
        let mut gap = 1;
        while gap < n {
            if self.rank % (2 * gap) == 0 {
                let partner = self.rank + gap;
                if partner < n {
                    let other = self.recv(partner, tag);
                    for (a, b) in data.iter_mut().zip(&other) {
                        *a += b;
                    }
                }
            } else if self.rank % (2 * gap) == gap {
                self.send(self.rank - gap, tag, data.clone());
            }
            gap *= 2;
        }
        // broadcast back down the same tree
        gap /= 2;
        while gap >= 1 {
            if self.rank % (2 * gap) == 0 {
                let partner = self.rank + gap;
                if partner < n {
                    self.send(partner, tag + 1, data.clone());
                }
            } else if self.rank % (2 * gap) == gap {
                data = self.recv(self.rank - gap, tag + 1);
            }
            if gap == 1 {
                break;
            }
            gap /= 2;
        }
        data
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn point_to_point_roundtrip() {
        let mut fabric = Fabric::new(2);
        let a = fabric.take(0);
        let mut b = fabric.take(1);
        a.send(1, 7, vec![1.0, 2.0]);
        assert_eq!(b.recv(0, 7), vec![1.0, 2.0]);
        assert_eq!(fabric.counters.messages.load(Ordering::Relaxed), 1);
        assert_eq!(fabric.counters.bytes.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn selective_recv_stashes_out_of_order() {
        let mut fabric = Fabric::new(2);
        let a = fabric.take(0);
        let mut b = fabric.take(1);
        a.send(1, 1, vec![1.0]);
        a.send(1, 2, vec![2.0]);
        // ask for tag 2 first: tag-1 message must be stashed, not lost
        assert_eq!(b.recv(0, 2), vec![2.0]);
        assert_eq!(b.recv(0, 1), vec![1.0]);
    }

    #[test]
    fn allreduce_sums_across_threads() {
        for n in [1usize, 2, 3, 4, 7, 8] {
            let mut fabric = Fabric::new(n);
            let eps = fabric.take_all();
            let results: Vec<Vec<f32>> = thread::scope(|s| {
                let handles: Vec<_> = eps
                    .into_iter()
                    .map(|mut ep| {
                        s.spawn(move || {
                            let contribution = vec![ep.rank as f32 + 1.0, 1.0];
                            ep.allreduce_sum(100, contribution)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            let want_sum: f32 = (1..=n).map(|r| r as f32).sum();
            for r in &results {
                assert_eq!(r[0], want_sum, "n={}", n);
                assert_eq!(r[1], n as f32);
            }
        }
    }
}
