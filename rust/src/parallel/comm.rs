//! Channel-based message fabric — the in-process substitute for GPU-aware
//! MPI (DESIGN.md §Substitutions). Every rank gets an [`Endpoint`] with
//! point-to-point send/recv plus collective helpers; global counters track
//! messages and bytes for the §Perf logs and simulator calibration.
//!
//! ## Allocation discipline
//!
//! The fabric is on the MGRIT relaxation hot path (one halo exchange per
//! FCF sweep per slab boundary), so its steady state must not touch the
//! heap:
//!
//! * mailboxes are preallocated `Mutex<VecDeque<Msg>>` per rank — a send
//!   moves the message's `Vec<f32>` payload into the receiver's deque
//!   (pointer move, no copy, no node allocation; the deque's capacity is
//!   retained across sweeps);
//! * [`Endpoint::send_scratch`] / [`Endpoint::recv_scratch`] implement a
//!   buffer-recycling protocol: the sender fills a persistent flat scratch
//!   buffer, the receiver consumes it and mails the *same* buffer back on
//!   the paired return tag (`tag | RETURN_BIT`), and the sender reclaims
//!   it on its next send. After the first exchange of a given size no
//!   flat buffer is ever allocated again.
//!
//! Return-tag traffic is bookkeeping, not simulated communication, so it
//! is excluded from the byte/message counters.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

/// Tag bit marking a recycled-buffer return message (see module docs).
/// User tags must stay below it.
pub const RETURN_BIT: u64 = 1 << 63;

/// Typed fabric failure. Blocking on a message from a rank whose endpoint
/// has dropped used to `assert!` inside the worker — an untyped panic the
/// recovery layer could not tell apart from a genuine bug. Now the sweep
/// executors receive through [`Endpoint::try_recv`], and a dead sender
/// surfaces as this error through the sweep result, so the owner's
/// failure path is pool-rebuild + retry (see
/// [`crate::coordinator::ForwardContext`]) instead of process abort.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FabricError {
    /// The sending rank's endpoint dropped with no matching message
    /// queued — the peer panicked or was torn down mid-sweep.
    DeadSender { from: usize, tag: u64 },
}

impl std::fmt::Display for FabricError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FabricError::DeadSender { from, tag } => {
                write!(f, "fabric sender rank {} dropped (tag {})", from, tag)
            }
        }
    }
}

impl std::error::Error for FabricError {}

/// A tagged message between ranks.
#[derive(Debug)]
pub struct Msg {
    pub from: usize,
    pub tag: u64,
    pub data: Vec<f32>,
}

/// Global traffic counters.
#[derive(Debug, Default)]
pub struct Counters {
    pub messages: AtomicU64,
    pub bytes: AtomicU64,
}

/// One rank's preallocated inbox.
struct Mailbox {
    q: Mutex<VecDeque<Msg>>,
    cv: Condvar,
}

impl Mailbox {
    /// Poison-tolerant lock: a receiver that panics mid-`recv` (e.g. on a
    /// poison halo) poisons its own mailbox mutex, but the queue state is
    /// always consistent at that point — and senders/drops touching the
    /// box afterwards must not double-panic during unwind.
    fn lock(&self) -> MutexGuard<'_, VecDeque<Msg>> {
        self.q.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Shared state of the whole mesh.
struct Mesh {
    boxes: Vec<Mailbox>,
    /// Per-rank liveness: cleared when that rank's endpoint drops, so a
    /// recv blocked on a message from a dead sender (e.g. a panicked
    /// scoped-spawn slab) fails loudly instead of hanging the sweep.
    alive: Vec<AtomicBool>,
}

/// All-to-all mesh of mailboxes for `n` ranks.
pub struct Fabric {
    mesh: Arc<Mesh>,
    taken: Vec<bool>,
    pub counters: Arc<Counters>,
}

/// One rank's view of the fabric.
pub struct Endpoint {
    pub rank: usize,
    pub n_ranks: usize,
    mesh: Arc<Mesh>,
    counters: Arc<Counters>,
    /// Reusable flat buffer for [`Endpoint::send_scratch`]. Empty while a
    /// send is in flight (the buffer travels with the message and comes
    /// home on the return tag).
    scratch: Vec<f32>,
    /// `(peer, return_tag)` of an outstanding scratch loan, reclaimed
    /// lazily at the next `send_scratch`.
    loan: Option<(usize, u64)>,
}

impl Fabric {
    pub fn new(n: usize) -> Fabric {
        let mesh = Arc::new(Mesh {
            boxes: (0..n)
                .map(|_| Mailbox { q: Mutex::new(VecDeque::with_capacity(4)), cv: Condvar::new() })
                .collect(),
            alive: (0..n).map(|_| AtomicBool::new(true)).collect(),
        });
        Fabric { mesh, taken: vec![false; n], counters: Arc::new(Counters::default()) }
    }

    /// Take rank `r`'s endpoint (each can be taken once, then moved into a
    /// worker thread).
    pub fn take(&mut self, r: usize) -> Endpoint {
        assert!(!self.taken[r], "endpoint already taken");
        self.taken[r] = true;
        Endpoint {
            rank: r,
            n_ranks: self.taken.len(),
            mesh: self.mesh.clone(),
            counters: self.counters.clone(),
            scratch: Vec::new(),
            loan: None,
        }
    }

    /// Take all remaining endpoints.
    pub fn take_all(&mut self) -> Vec<Endpoint> {
        (0..self.taken.len()).map(|r| self.take(r)).collect()
    }
}

impl Endpoint {
    /// Send `data` to rank `to` with a tag. Return-tag messages (buffer
    /// recycling) bypass the traffic counters.
    pub fn send(&self, to: usize, tag: u64, data: Vec<f32>) {
        if tag & RETURN_BIT == 0 {
            self.counters.messages.fetch_add(1, Ordering::Relaxed);
            self.counters.bytes.fetch_add(4 * data.len() as u64, Ordering::Relaxed);
        }
        let mb = &self.mesh.boxes[to];
        let mut q = mb.lock();
        q.push_back(Msg { from: self.rank, tag, data });
        drop(q);
        mb.cv.notify_all();
    }

    /// Blocking receive of the next message matching (from, tag). Returns
    /// [`FabricError::DeadSender`] if the sending rank's endpoint has
    /// dropped with no matching message queued (the channel-disconnect
    /// condition; a panicked slab unwinds its blocked right neighbour this
    /// way). A queued message is still deliverable after the sender dies.
    pub fn try_recv(&mut self, from: usize, tag: u64) -> Result<Vec<f32>, FabricError> {
        let mb = &self.mesh.boxes[self.rank];
        let mut q = mb.lock();
        loop {
            if let Some(i) = q.iter().position(|m| m.from == from && m.tag == tag) {
                return Ok(q.remove(i).expect("indexed message").data);
            }
            if !self.mesh.alive[from].load(Ordering::SeqCst) {
                return Err(FabricError::DeadSender { from, tag });
            }
            q = mb.cv.wait(q).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Panicking wrapper of [`Endpoint::try_recv`] for call sites inside a
    /// sweep body (the unwind carries the typed [`FabricError`] payload,
    /// so `catch_unwind` callers can downcast it back out of the panic).
    pub fn recv(&mut self, from: usize, tag: u64) -> Vec<f32> {
        self.try_recv(from, tag).unwrap_or_else(|e| std::panic::panic_any(e))
    }

    /// Allocation-free (at steady state) send: fill the endpoint's
    /// persistent flat scratch and mail it. If a previous scratch send is
    /// still outstanding, the buffer is first reclaimed from the paired
    /// return message (blocking — the receiver posts it the moment it has
    /// consumed the payload, so in the sweep-barrier discipline of the
    /// worker pool it is always already queued).
    pub fn send_scratch(&mut self, to: usize, tag: u64, fill: impl FnOnce(&mut Vec<f32>)) {
        debug_assert_eq!(tag & RETURN_BIT, 0, "user tags must not set RETURN_BIT");
        let mut buf = match self.loan.take() {
            Some((peer, rtag)) => self.recv(peer, rtag),
            None => std::mem::take(&mut self.scratch),
        };
        buf.clear();
        fill(&mut buf);
        self.send(to, tag, buf);
        self.loan = Some((to, tag | RETURN_BIT));
    }

    /// Receiving half of the recycling protocol: consume the payload, then
    /// mail the transport buffer straight back to the sender so its next
    /// `send_scratch` reuses it. If `consume` panics (e.g. a poison-halo
    /// length check), the buffer is dropped with the unwind — the failed
    /// sweep poisons the pool and the fabric is rebuilt anyway. A dead
    /// sender surfaces as a typed [`FabricError`] instead of the payload.
    pub fn try_recv_scratch(
        &mut self,
        from: usize,
        tag: u64,
        consume: impl FnOnce(&[f32]),
    ) -> Result<(), FabricError> {
        let data = self.try_recv(from, tag)?;
        consume(&data);
        self.send(from, tag | RETURN_BIT, data);
        Ok(())
    }

    /// Panicking wrapper of [`Endpoint::try_recv_scratch`] (typed
    /// [`FabricError`] panic payload, like [`Endpoint::recv`]).
    pub fn recv_scratch(&mut self, from: usize, tag: u64, consume: impl FnOnce(&[f32])) {
        self.try_recv_scratch(from, tag, consume)
            .unwrap_or_else(|e| std::panic::panic_any(e))
    }

    /// Sum-allreduce across all ranks (flat binary-tree reduce + broadcast).
    /// Deterministic reduction order regardless of arrival order — but the
    /// *tree* order `(x0+x1)+(x2+x3)` differs from the left-associated
    /// rank-ascending chain the dp gradient fold pins, and every rank
    /// allocates fresh `Vec`s per call.
    #[deprecated(
        note = "allocates per call and reduces in tree order; hot loops use \
                allreduce_sum_into (scratch-recycling, rank-ascending chain)"
    )]
    pub fn allreduce_sum(&mut self, tag: u64, mut data: Vec<f32>) -> Vec<f32> {
        let n = self.n_ranks;
        // reduce to rank 0 over a binary tree
        let mut gap = 1;
        while gap < n {
            if self.rank % (2 * gap) == 0 {
                let partner = self.rank + gap;
                if partner < n {
                    let other = self.recv(partner, tag);
                    for (a, b) in data.iter_mut().zip(&other) {
                        *a += b;
                    }
                }
            } else if self.rank % (2 * gap) == gap {
                self.send(self.rank - gap, tag, data.clone());
            }
            gap *= 2;
        }
        // broadcast back down the same tree
        gap /= 2;
        while gap >= 1 {
            if self.rank % (2 * gap) == 0 {
                let partner = self.rank + gap;
                if partner < n {
                    self.send(partner, tag + 1, data.clone());
                }
            } else if self.rank % (2 * gap) == gap {
                data = self.recv(self.rank - gap, tag + 1);
            }
            if gap == 1 {
                break;
            }
            gap /= 2;
        }
        data
    }

    /// In-place sum-allreduce over the recycled-scratch transport: after
    /// the call every rank's `data` holds the strictly **left-associated,
    /// rank-ascending** sum `(((x_0 + x_1) + x_2) + …) + x_{n-1}` — the
    /// exact association the dp gradient stash/fold scratch pins, so a
    /// fabric reduction of replica gradients is bitwise identical to the
    /// serial replica loop.
    ///
    /// Topology: an ascending chain. Rank `r > 0` first receives the
    /// running sum of ranks `0..r` from rank `r-1` and folds it *under*
    /// its own contribution (`running + own`, running sum on the left);
    /// every rank but the last forwards the new running sum to `r+1`; the
    /// last rank holds the total and broadcasts it to all peers on
    /// `tag + 1`. All payloads travel through [`Endpoint::send_scratch`] /
    /// [`Endpoint::recv_scratch`], so steady state allocates nothing and
    /// return-tag traffic stays out of the counters. Uses tags `tag` and
    /// `tag + 1`; both must stay below [`RETURN_BIT`].
    ///
    /// Every rank of the fabric must call this concurrently from its own
    /// thread (one endpoint per thread) — like MPI_Allreduce, it is a
    /// collective, not a local operation.
    pub fn allreduce_sum_into(&mut self, tag: u64, data: &mut [f32]) {
        debug_assert_eq!(tag & RETURN_BIT, 0, "user tags must not set RETURN_BIT");
        let n = self.n_ranks;
        if n <= 1 {
            return;
        }
        let r = self.rank;
        if r > 0 {
            // fold the 0..r running sum under our contribution: running
            // sum stays on the left, preserving the serial fold order
            self.recv_scratch(r - 1, tag, |run| {
                assert_eq!(run.len(), data.len(), "allreduce payload length mismatch");
                for (a, &b) in data.iter_mut().zip(run) {
                    *a = b + *a;
                }
            });
        }
        if r < n - 1 {
            self.send_scratch(r + 1, tag, |buf| buf.extend_from_slice(data));
            self.recv_scratch(n - 1, tag + 1, |total| {
                assert_eq!(total.len(), data.len(), "allreduce payload length mismatch");
                data.copy_from_slice(total);
            });
        } else {
            for peer in 0..n - 1 {
                self.send_scratch(peer, tag + 1, |buf| buf.extend_from_slice(data));
            }
        }
    }
}

impl Drop for Endpoint {
    fn drop(&mut self) {
        self.mesh.alive[self.rank].store(false, Ordering::SeqCst);
        // wake every blocked recv so it re-checks sender liveness (the
        // lock round-trip orders the flag write before the wakeup; drops
        // run during unwinds, so the lock must be poison-tolerant)
        for mb in &self.mesh.boxes {
            drop(mb.lock());
            mb.cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn point_to_point_roundtrip() {
        let mut fabric = Fabric::new(2);
        let a = fabric.take(0);
        let mut b = fabric.take(1);
        a.send(1, 7, vec![1.0, 2.0]);
        assert_eq!(b.recv(0, 7), vec![1.0, 2.0]);
        assert_eq!(fabric.counters.messages.load(Ordering::Relaxed), 1);
        assert_eq!(fabric.counters.bytes.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn selective_recv_stashes_out_of_order() {
        let mut fabric = Fabric::new(2);
        let a = fabric.take(0);
        let mut b = fabric.take(1);
        a.send(1, 1, vec![1.0]);
        a.send(1, 2, vec![2.0]);
        // ask for tag 2 first: tag-1 message must stay queued, not be lost
        assert_eq!(b.recv(0, 2), vec![2.0]);
        assert_eq!(b.recv(0, 1), vec![1.0]);
    }

    #[test]
    fn recv_from_dropped_sender_is_a_typed_error() {
        let mut fabric = Fabric::new(2);
        let a = fabric.take(0);
        let mut b = fabric.take(1);
        a.send(1, 3, vec![9.0]);
        drop(a);
        // a queued message is still deliverable after the sender dies...
        assert_eq!(b.try_recv(0, 3), Ok(vec![9.0]));
        // ...but waiting for one that never arrives is the typed error,
        // not a hang and not an untyped panic
        assert_eq!(b.try_recv(0, 4), Err(FabricError::DeadSender { from: 0, tag: 4 }));
        // the panicking wrapper carries the same typed payload, so sweep
        // owners can downcast it out of a caught unwind
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| b.recv(0, 5)));
        let payload = r.expect_err("recv from a dead sender must panic, not hang");
        let e = payload.downcast_ref::<FabricError>().expect("typed FabricError payload");
        assert_eq!(*e, FabricError::DeadSender { from: 0, tag: 5 });
    }

    #[test]
    fn scratch_sends_recycle_the_transport_buffer() {
        let mut fabric = Fabric::new(2);
        let mut a = fabric.take(0);
        let mut b = fabric.take(1);
        for round in 0..4 {
            a.send_scratch(1, 11, |buf| buf.extend_from_slice(&[round as f32, 2.5]));
            let mut got = Vec::new();
            b.recv_scratch(0, 11, |data| got.extend_from_slice(data));
            assert_eq!(got, vec![round as f32, 2.5]);
        }
        // return-tag traffic must not inflate the simulated-comm counters:
        // 4 payload messages of 2 floats each
        assert_eq!(fabric.counters.messages.load(Ordering::Relaxed), 4);
        assert_eq!(fabric.counters.bytes.load(Ordering::Relaxed), 4 * 8);
    }

    #[test]
    fn scratch_sends_survive_size_changes() {
        let mut fabric = Fabric::new(2);
        let mut a = fabric.take(0);
        let mut b = fabric.take(1);
        for n in [3usize, 7, 2, 7] {
            a.send_scratch(1, 5, |buf| buf.extend(std::iter::repeat(n as f32).take(n)));
            b.recv_scratch(0, 5, |data| {
                assert_eq!(data.len(), n);
                assert!(data.iter().all(|&v| v == n as f32));
            });
        }
    }

    #[test]
    #[allow(deprecated)]
    fn allreduce_sums_across_threads() {
        for n in [1usize, 2, 3, 4, 7, 8] {
            let mut fabric = Fabric::new(n);
            let eps = fabric.take_all();
            let results: Vec<Vec<f32>> = thread::scope(|s| {
                let handles: Vec<_> = eps
                    .into_iter()
                    .map(|mut ep| {
                        s.spawn(move || {
                            let contribution = vec![ep.rank as f32 + 1.0, 1.0];
                            ep.allreduce_sum(100, contribution)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            let want_sum: f32 = (1..=n).map(|r| r as f32).sum();
            for r in &results {
                assert_eq!(r[0], want_sum, "n={}", n);
                assert_eq!(r[1], n as f32);
            }
        }
    }

    #[test]
    fn allreduce_into_sums_across_threads_over_repeated_rounds() {
        for n in [1usize, 2, 3, 4, 7, 8] {
            let mut fabric = Fabric::new(n);
            let eps = fabric.take_all();
            let results: Vec<Vec<Vec<f32>>> = thread::scope(|s| {
                let handles: Vec<_> = eps
                    .into_iter()
                    .map(|mut ep| {
                        s.spawn(move || {
                            // several rounds through the same endpoint:
                            // pins the loan-reclaim cycle across calls
                            (0..3u32)
                                .map(|round| {
                                    let mut v =
                                        vec![ep.rank as f32 + 1.0, round as f32];
                                    ep.allreduce_sum_into(200, &mut v);
                                    v
                                })
                                .collect()
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            let want_sum: f32 = (1..=n).map(|r| r as f32).sum();
            for per_rank in &results {
                for (round, v) in per_rank.iter().enumerate() {
                    assert_eq!(v[0], want_sum, "n={}", n);
                    assert_eq!(v[1], (n * round) as f32);
                }
            }
        }
    }

    #[test]
    fn allreduce_into_is_bitwise_left_associated_in_rank_order() {
        // contributions chosen so f32 addition order is observable:
        // (((x0+x1)+x2)+x3) differs from the tree order (x0+x1)+(x2+x3)
        let xs: Vec<f32> = vec![1.0e8, -1.0e8 + 1.0, 3.0e-8, 7.0e-8, 0.25, 1.0e8, -1.0e8, 0.125];
        for n in [2usize, 3, 4, 7, 8] {
            let serial = {
                let mut acc = xs[0];
                for &x in &xs[1..n] {
                    acc += x;
                }
                acc
            };
            let mut fabric = Fabric::new(n);
            let eps = fabric.take_all();
            let results: Vec<f32> = thread::scope(|s| {
                let handles: Vec<_> = eps
                    .into_iter()
                    .map(|mut ep| {
                        let x = xs[ep.rank];
                        s.spawn(move || {
                            let mut v = vec![x];
                            ep.allreduce_sum_into(300, &mut v);
                            v[0]
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for r in &results {
                assert_eq!(r.to_bits(), serial.to_bits(), "n={}", n);
            }
        }
    }

    #[test]
    fn allreduce_into_recycles_buffers_and_counts_payloads_only() {
        let n = 4usize;
        let mut fabric = Fabric::new(n);
        let counters = fabric.counters.clone();
        let eps = fabric.take_all();
        let rounds = 5u64;
        thread::scope(|s| {
            for mut ep in eps {
                s.spawn(move || {
                    for _ in 0..rounds {
                        let mut v = vec![ep.rank as f32; 6];
                        ep.allreduce_sum_into(400, &mut v);
                    }
                });
            }
        });
        // per round: n-1 chain hops + n-1 broadcast sends, nothing for the
        // recycled return traffic
        let payload_msgs = rounds * 2 * (n as u64 - 1);
        assert_eq!(counters.messages.load(Ordering::Relaxed), payload_msgs);
        assert_eq!(counters.bytes.load(Ordering::Relaxed), payload_msgs * 6 * 4);
    }
}
