//! Layer-×-data parallel runtime and performance model.
//!
//! * [`comm`] — channel-based message fabric between ranks (the GPU-aware
//!   MPI substitute): typed sends, chain/tree allreduce, byte/message
//!   counters, and the recycled-scratch send path that keeps steady-state
//!   halo exchange allocation-free.
//! * [`topology`] — the lp×dp device grid, contiguous layer-slab
//!   assignment (paper Fig. 2's distribution of F_k across devices), and
//!   the `--workers` budget split across the two axes
//!   ([`topology::worker_splits`] / [`topology::auto_split`]).
//! * [`exec`] — real multi-worker execution of the F/C-relaxation phases
//!   over OS threads with halo exchange, bitwise identical to the
//!   single-threaded engine. Since the Session API v2 redesign this is the
//!   execution layer of the `ThreadedMgrit` backend: `mgrit::core` routes
//!   its V-cycle relaxation sweeps (forward *and* adjoint) through it.
//! * [`pool`] — persistent relaxation workers (one [`WorkerPool`] per
//!   `ThreadedMgrit` backend / `Session`): the same slab sweeps as `exec`'s
//!   scoped spawns, dispatched as one shared borrowed closure onto
//!   long-lived threads that park between sweeps.
//! * [`simulator`] — discrete-event makespan model calibrated with the
//!   measured Φ cost and an α+β communication model; generates the paper's
//!   scaling figures (6-9) on this single-core testbed (DESIGN.md
//!   §Substitutions).
//!
//! # Shared-grid slab ownership and the halo protocol
//!
//! The in-place executors (`exec::{parallel,pool}_{f,fc}_relax_mut`) relax
//! directly on the level's point array `w[0..=n]` — no slab copies, no
//! stitch-back. Correctness rests on a strict ownership protocol:
//!
//! **Point ownership.** A sweep over `n = chunks · cf` fine steps with
//! `active` ranks partitions the *chunk* space contiguously
//! ([`topology::slab_range`]); rank `r`'s chunk range `[c_r, c_{r+1})`
//! makes it the exclusive owner of grid points `[B_r, B_{r+1})` with
//! `B_r = c_r · cf`, and the last rank additionally owns the final point
//! `n`. Ranks receive pairwise-disjoint `&mut [T]` windows of `w`, so no
//! two threads can ever alias a point.
//!
//! **Who writes what, when.**
//!
//! 1. *First F-relax* — rank `r` rewrites the F-points of its chunks from
//!    each chunk's leading C-point. Every write lands inside its own
//!    window; the entry C-point `w[B_r] = view[0]` is read-only here (its
//!    pre-sweep value is exactly what the staged schedule read from its
//!    slab copy).
//! 2. *C-relax* — rank `r` updates each chunk's trailing C-point.
//!    Interior boundaries are its own points (in-place writes). The
//!    *right* boundary `w[B_{r+1}]` belongs to rank `r+1`: its new value
//!    is computed into the worker's persistent boundary temp
//!    ([`pool::Workspace`]) and **sent** to rank `r+1` the moment it
//!    exists — the owner writes it into the grid, so each point still has
//!    exactly one writer.
//! 3. *Halo recv* — rank `r > 0` receives its refreshed entry C-point
//!    from the left and overwrites `view[0]` in place
//!    (`RelaxState::copy_from_flat`; a zero-length message is a poison
//!    halo from a panicked neighbour and fails the cold length check).
//! 4. *Second F-relax* — as (1), now reading the refreshed entry point.
//!
//! F-only sweeps are phase (1) alone: no C-point is written anywhere, so
//! the boundary reads need no communication at all.
//!
//! **Buffer recycling.** Halo payloads travel as `Vec<f32>` owned by the
//! message. The sender fills its endpoint's persistent flat scratch
//! ([`comm::Endpoint::send_scratch`]); the receiver consumes the payload
//! and mails the same buffer back on the paired return tag
//! ([`comm::RETURN_BIT`]), where the sender reclaims it on its next send.
//! Combined with the pool's generation-bump dispatch this makes the
//! steady-state threaded sweep perform zero heap allocations (pinned by
//! `rust/tests/alloc_audit.rs`).
//!
//! The pre-refactor staged executors (slab `to_vec` + stitch) are kept in
//! [`exec`] as the independently-derived parity oracle and the
//! `perf_hotpath` "staged" baseline.
//!
//! # DP×LP execution: rank layout, replica summation, worker split
//!
//! Since the real-DP pass, `--dp N` replicas actually run concurrently
//! (paper §4.2 / Fig. 9's multiplicative composition) instead of as a
//! serial micro-batch loop:
//!
//! **Rank layout.** The logical grid is [`Topology`]'s
//! `rank = dp_idx * lp + lp_idx`. Physically, each replica is one
//! [`crate::coordinator::SolveContext`] (own MGRIT slab hierarchy, own
//! `StepWorkspace`, own relaxation backend/pool of `lp` workers) plus one
//! [`comm::Endpoint`] on a dp-wide gradient fabric. Replica lanes are
//! dispatched onto a dp scheduler [`WorkerPool`] via the same
//! zero-allocation `run_sweep` generation-bump path the relaxation
//! workers use; each lane runs `ceil(dp / lanes)`-ish replicas
//! ([`topology::slab_range`] over replica indices).
//!
//! **Fixed replica-summation order.** f32 addition is not associative, so
//! the gradient reduction pins a *strictly left-associated, replica-
//! ascending* sum `(((g_0 + g_1) + g_2) + …)` — the same association the
//! serial dp stash/fold scratch used. Lanes ship each replica's flat
//! gradient payload to replica 0's endpoint (`send_scratch`, recycled
//! buffers); the coordinator folds them in ascending replica order. The
//! general collective [`comm::Endpoint::allreduce_sum_into`] pins the
//! identical chain order for one-endpoint-per-thread callers. Result:
//! sharded dp is **bitwise identical** to serial dp (`dp_parity.rs`).
//!
//! **`--dp-workers` split rules.** `--workers W` is the total thread
//! budget. With `--dp-workers D` (clamped to `1..=dp`, `D | W` not
//! required but `lp = max(W / D, 1)`), D replica lanes each drive an
//! lp-worker relaxation pool. Without it, [`topology::auto_split`]
//! scores every divisor split `D × (W/D)` with the [`Simulator`]'s
//! convex dp-vs-lp tradeoff (replica waves × modeled batch time) and
//! picks the cheapest. The split is execution-only: it never changes
//! math, checkpoints, or `StepRecord` streams — only wall-clock.

pub mod comm;
pub mod exec;
pub mod pool;
pub mod simulator;
pub mod topology;

pub use comm::{Fabric, FabricError};
pub use exec::RelaxState;
pub use pool::{WorkerPool, Workspace};
pub use simulator::{DeviceModel, SimConfig, Simulator};
pub use topology::{auto_split, slab_partition, slab_range, worker_splits, Topology};
