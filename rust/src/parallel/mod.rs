//! Layer-×-data parallel runtime and performance model.
//!
//! * [`comm`] — channel-based message fabric between ranks (the GPU-aware
//!   MPI substitute): typed sends, tree allreduce, byte/message counters.
//! * [`topology`] — the lp×dp device grid and contiguous layer-slab
//!   assignment (paper Fig. 2's distribution of F_k across devices).
//! * [`exec`] — real multi-worker execution of the F/C-relaxation phases
//!   over OS threads with halo exchange, bitwise identical to the
//!   single-threaded engine. Since the Session API v2 redesign this is the
//!   execution layer of the `ThreadedMgrit` backend: `mgrit::core` routes
//!   its V-cycle relaxation sweeps (forward *and* adjoint) through it.
//! * [`pool`] — persistent relaxation workers (one [`WorkerPool`] per
//!   `ThreadedMgrit` backend / `Session`): the same slab sweeps as `exec`'s
//!   scoped spawns, dispatched onto long-lived threads that park between
//!   sweeps.
//! * [`simulator`] — discrete-event makespan model calibrated with the
//!   measured Φ cost and an α+β communication model; generates the paper's
//!   scaling figures (6-9) on this single-core testbed (DESIGN.md
//!   §Substitutions).

pub mod comm;
pub mod exec;
pub mod pool;
pub mod simulator;
pub mod topology;

pub use comm::Fabric;
pub use exec::RelaxState;
pub use pool::WorkerPool;
pub use simulator::{DeviceModel, SimConfig, Simulator};
pub use topology::{slab_partition, Topology};
