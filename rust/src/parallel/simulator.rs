//! Discrete-event performance model of layer-parallel training — the
//! engine behind the paper's scaling figures (6-9) on this testbed.
//!
//! The sandbox has one CPU core and no GPUs (DESIGN.md §Substitutions), so
//! wall-clock scaling cannot be measured directly. But MGRIT's runtime is a
//! deterministic function of (a) Φ evaluations on the critical path, (b)
//! messages/bytes crossed between layer slabs, and (c) the data-parallel
//! allreduce — the same quantities the MGRIT literature's performance
//! models count. Φ cost is calibrated from the artifact manifest's FLOP
//! counts (or measured wall-clock via `calibrate`), communication follows
//! an α+β model with V100/A100-class parameters.

/// A device class (paper: Jean-Zay V100 nodes, Singra A100).
#[derive(Debug, Clone, Copy)]
pub struct DeviceModel {
    pub name: &'static str,
    /// Sustained f32 FLOP/s on transformer blocks.
    pub flops: f64,
    /// Message latency (s).
    pub alpha: f64,
    /// Inverse bandwidth (s/byte), intra-node (NVLink class).
    pub beta: f64,
    /// Inverse bandwidth (s/byte) across nodes (IB class) — a ring
    /// allreduce spanning nodes is bottlenecked by its slowest link.
    pub beta_inter: f64,
    /// GPUs per node.
    pub node_size: usize,
    /// Micro-batch size at which the device reaches half of peak
    /// throughput (throughput-saturation model: eff(b) = b/(b+half)).
    /// Captures why per-device batches of 1-2 samples waste the GPU —
    /// the effect that bounds useful data-parallelism in paper Fig. 9.
    pub batch_half: f64,
}

impl DeviceModel {
    /// V100 16GB, 8-GPU NVLink nodes + 25 GB/s IB (Jean-Zay class).
    pub fn v100() -> DeviceModel {
        DeviceModel {
            name: "V100",
            flops: 5.5e12,
            alpha: 5e-6,
            beta: 1.0 / 150e9,
            beta_inter: 1.0 / 25e9,
            node_size: 8,
            batch_half: 4.0,
        }
    }

    /// A100 80GB (Singra) — faster compute and links, 4-GPU nodes.
    pub fn a100() -> DeviceModel {
        DeviceModel {
            name: "A100",
            flops: 9.0e12,
            alpha: 4e-6,
            beta: 1.0 / 300e9,
            beta_inter: 1.0 / 50e9,
            node_size: 4,
            batch_half: 4.0,
        }
    }

    /// This testbed: Φ cost measured on the CPU PJRT runtime (`calibrate`),
    /// channel comm ≈ memcpy bandwidth.
    pub fn cpu_measured(phi_seconds: f64, flops_per_phi: f64) -> DeviceModel {
        DeviceModel {
            name: "CPU-measured",
            flops: flops_per_phi / phi_seconds.max(1e-12),
            alpha: 2e-6,
            beta: 1.0 / 8e9,
            beta_inter: 1.0 / 8e9,
            node_size: 1,
            batch_half: 0.0, // CPU throughput is batch-size independent here
        }
    }
}

/// One simulated run configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Layers inside the MGRIT domain.
    pub n_layers: usize,
    pub cf: usize,
    pub levels: usize,
    /// None = serial forward (Table 3 dashes).
    pub fwd_iters: Option<usize>,
    pub bwd_iters: Option<usize>,
    pub fcf: bool,
    /// Layer-parallel devices.
    pub lp: usize,
    /// Data-parallel replicas.
    pub dp: usize,
    /// FLOPs of one Φ on one *sample* (manifest flops / artifact batch).
    pub flops_per_sample_step: f64,
    /// Global batch size (split over dp replicas).
    pub batch: usize,
    /// Bytes of one state tensor crossing a slab boundary (per replica).
    pub state_bytes: f64,
    /// Total parameter bytes (for the dp gradient allreduce).
    pub param_bytes: f64,
    pub device: DeviceModel,
}

/// Cost breakdown of one training batch (seconds).
#[derive(Debug, Clone, Default)]
pub struct SimReport {
    pub fwd: f64,
    pub bwd: f64,
    pub grad: f64,
    pub allreduce: f64,
    pub comm: f64,
    pub total: f64,
    /// Φ evaluations on the critical path (fwd+bwd).
    pub critical_phi: u64,
}

/// The simulator. All methods are pure functions of the config.
pub struct Simulator {
    pub cfg: SimConfig,
}

impl Simulator {
    pub fn new(cfg: SimConfig) -> Simulator {
        Simulator { cfg }
    }

    fn phi_t(&self) -> f64 {
        // per-replica micro-batch, with throughput saturation: a device at
        // micro-batch b sustains flops·b/(b+batch_half), so
        // t = flops_per_sample·(b + batch_half)/flops.
        let b = (self.cfg.batch as f64 / self.cfg.dp as f64).max(1.0);
        self.cfg.flops_per_sample_step * (b + self.cfg.device.batch_half)
            / self.cfg.device.flops
    }

    /// VJP ≈ 2× forward cost (recompute + transposed ops).
    fn vjp_t(&self) -> f64 {
        2.0 * self.phi_t()
    }

    fn comm1(&self) -> f64 {
        let per_dev_batch = (self.cfg.batch as f64 / self.cfg.dp as f64).max(1.0);
        self.cfg.device.alpha + self.cfg.state_bytes * per_dev_batch * self.cfg.device.beta
    }

    /// Critical-path time + Φ count of one MGRIT V-cycle over all levels.
    fn vcycle(&self, t_step: f64) -> (f64, u64, f64) {
        let cf = self.cfg.cf;
        let mut time = 0.0;
        let mut phis = 0u64;
        let mut comm = 0.0;
        let mut n_l = self.cfg.n_layers;
        let mut level = 0;
        loop {
            let coarsest = level + 1 >= self.cfg.levels || n_l % cf != 0 || n_l / cf < 1;
            if coarsest {
                // serial solve on one device, then broadcast the C-points
                time += n_l as f64 * t_step;
                phis += n_l as u64;
                let bc = (self.cfg.lp as f64).log2().ceil().max(0.0) * self.comm1();
                time += bc;
                comm += bc;
                break;
            }
            let chunks = n_l / cf;
            let p_eff = self.cfg.lp.min(chunks).max(1);
            let per_dev = chunks.div_ceil(p_eff) as f64;
            // relaxation: F (cf-1 steps/chunk), FCF adds C (1) + F (cf-1)
            let relax_steps = if self.cfg.fcf { 2 * (cf - 1) + 1 } else { cf - 1 } as f64;
            // residual + FAS restriction: 2 Φ per C-point
            let restrict_steps = 2.0;
            // post-correction F-relax: cf-1 steps per chunk
            let post_steps = (cf - 1) as f64;
            let steps = per_dev * (relax_steps + restrict_steps + post_steps);
            time += steps * t_step;
            phis += steps as u64;
            // halo exchanges: C-relax boundary + restriction gather + correction scatter
            let halos = if self.cfg.fcf { 3.0 } else { 2.0 };
            let c = halos * self.comm1();
            time += c;
            comm += c;
            n_l /= cf;
            level += 1;
        }
        (time, phis, comm)
    }

    /// Time of one solve (forward if `t_step = phi_t`): serial when
    /// `iters = None` (activations stream through all lp slabs), MGRIT
    /// V-cycles otherwise.
    fn solve(&self, iters: Option<usize>, t_step: f64) -> (f64, u64, f64) {
        match iters {
            None => {
                let comm = (self.cfg.lp.saturating_sub(1)) as f64 * self.comm1();
                (self.cfg.n_layers as f64 * t_step + comm, self.cfg.n_layers as u64, comm)
            }
            Some(k) => {
                let (t, p, c) = self.vcycle(t_step);
                (t * k as f64, p * k as u64, c * k as f64)
            }
        }
    }

    /// Full batch cost: forward + adjoint + gradient pass + dp allreduce.
    pub fn batch_time(&self) -> SimReport {
        let (fwd, pf, cf_) = self.solve(self.cfg.fwd_iters, self.phi_t());
        let (bwd, pb, cb) = self.solve(self.cfg.bwd_iters, self.vjp_t());
        // gradient assembly: each lp rank handles its slab in parallel
        let per_dev_layers = self.cfg.n_layers.div_ceil(self.cfg.lp) as f64;
        let grad = per_dev_layers * self.vjp_t();
        // dp ring allreduce over each slab's parameters. The dp group spans
        // rank stride lp, so once lp·dp exceeds a node the ring crosses the
        // inter-node fabric and is bottlenecked by its slowest link (the
        // paper §4.2: "the final all-to-all … becomes prohibitively
        // expensive" at high dp).
        let allreduce = if self.cfg.dp > 1 {
            let bytes = self.cfg.param_bytes / self.cfg.lp as f64;
            let d = self.cfg.dp as f64;
            let spans_nodes = self.cfg.dp * self.cfg.lp > self.cfg.device.node_size;
            let beta =
                if spans_nodes { self.cfg.device.beta_inter } else { self.cfg.device.beta };
            2.0 * (d - 1.0) * self.cfg.device.alpha
                + 2.0 * (d - 1.0) / d * bytes * beta
        } else {
            0.0
        };
        let comm = cf_ + cb + allreduce;
        SimReport {
            fwd,
            bwd,
            grad,
            allreduce,
            comm,
            total: fwd + bwd + grad + allreduce,
            critical_phi: pf + pb,
        }
    }

    /// Speedup of this config vs the same model serial on one device.
    pub fn speedup_vs_serial(&self) -> f64 {
        let mut serial_cfg = self.cfg.clone();
        serial_cfg.lp = 1;
        serial_cfg.dp = 1;
        serial_cfg.fwd_iters = None;
        serial_cfg.bwd_iters = None;
        serial_cfg.batch = self.cfg.batch / self.cfg.dp.max(1); // same per-replica work
        let serial = Simulator::new(serial_cfg).batch_time().total;
        serial / self.batch_time().total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base(lp: usize, n_layers: usize) -> SimConfig {
        SimConfig {
            n_layers,
            cf: 4,
            levels: 2,
            fwd_iters: Some(1),
            bwd_iters: Some(1),
            fcf: true,
            lp,
            dp: 1,
            flops_per_sample_step: 50e6,
            batch: 32,
            state_bytes: 64.0 * 32.0 * 4.0,
            param_bytes: 1e6,
            device: DeviceModel::v100(),
        }
    }

    #[test]
    fn deeper_models_speed_up_more() {
        // paper Fig. 8 right: benefits grow with depth
        let s64 = Simulator::new(base(8, 64)).speedup_vs_serial();
        let s256 = Simulator::new(base(8, 256)).speedup_vs_serial();
        let s1024 = Simulator::new(base(8, 1024)).speedup_vs_serial();
        assert!(s256 > s64, "{} vs {}", s256, s64);
        assert!(s1024 > s256, "{} vs {}", s1024, s256);
    }

    #[test]
    fn speedup_grows_then_saturates_with_devices() {
        // paper Fig. 6: more devices help up to N/cf-way parallelism
        let sp: Vec<f64> = [1usize, 2, 4, 8, 16, 32]
            .iter()
            .map(|&p| Simulator::new(base(p, 128)).speedup_vs_serial())
            .collect();
        assert!(sp[2] > sp[1] && sp[3] > sp[2], "{:?}", sp);
        // saturation: doubling past the chunk count gains nothing
        let last_gain = sp[5] / sp[4];
        let early_gain = sp[2] / sp[1];
        assert!(last_gain < early_gain, "{:?}", sp);
    }

    #[test]
    fn small_problem_on_two_devices_can_lose() {
        // paper §4.2: MGRIT overhead can exceed serial time for small N
        let mut c = base(2, 8);
        c.fwd_iters = Some(2);
        c.bwd_iters = Some(2);
        let s = Simulator::new(c).speedup_vs_serial();
        assert!(s < 1.2, "tiny model should not speed up much, got {}", s);
    }

    #[test]
    fn more_levels_beat_two_for_deep_models() {
        // paper Fig. 8 left: scalability improves with level count (the
        // coarse serial solve shrinks by cf per level)
        let mut two = base(32, 1024);
        two.cf = 2;
        two.levels = 2;
        let mut four = two.clone();
        four.levels = 4;
        let t2 = Simulator::new(two).batch_time().total;
        let t4 = Simulator::new(four).batch_time().total;
        assert!(t4 < t2, "L=4 {} should beat L=2 {}", t4, t2);
    }

    #[test]
    fn dp_lp_tradeoff_is_convex() {
        // paper Fig. 9: fixed budget of 32 devices, batch 32 -> time per
        // batch is convex in the dp degree with an interior-ish optimum.
        let budget = 32usize;
        let times: Vec<f64> = [1usize, 2, 4, 8, 16, 32]
            .iter()
            .map(|&dp| {
                let mut c = base(budget / dp, 64);
                c.dp = dp;
                c.batch = 32;
                c.param_bytes = 50e6;
                Simulator::new(c).batch_time().total
            })
            .collect();
        // endpoints are worse than the best interior point
        let best = times.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(times[0] > best, "{:?}", times);
        assert!(times[5] > best, "{:?}", times);
    }

    #[test]
    fn serial_forward_config_matches_table3() {
        // 'serial fwd + 1 bwd iter' (ViT/GPT rows) must still beat
        // all-serial when layers are deep, because the adjoint parallelizes.
        let mut c = base(8, 128);
        c.fwd_iters = None;
        c.bwd_iters = Some(1);
        let s = Simulator::new(c).speedup_vs_serial();
        assert!(s > 1.0, "speedup {}", s);
    }

    #[test]
    fn report_components_positive_and_sum() {
        let mut c = base(4, 64);
        c.dp = 2;
        let r = Simulator::new(c).batch_time();
        assert!(r.fwd > 0.0 && r.bwd > 0.0 && r.grad > 0.0 && r.allreduce > 0.0);
        assert!((r.total - (r.fwd + r.bwd + r.grad + r.allreduce)).abs() < 1e-12);
        assert!(r.critical_phi > 0);
    }
}
