//! Real multi-worker execution of the MGRIT relaxation phase.
//!
//! Each worker owns a contiguous slab of chunks, applies F-relaxation
//! locally (no communication — the parallel phase of paper Fig. 2), then
//! C-relaxation with a halo exchange of the slab-boundary state over the
//! channel [`Fabric`](super::comm::Fabric). The update schedule is
//! value-for-value identical to the single-threaded engine, so threaded
//! solves are *bitwise* equal to serial ones.
//!
//! ## Zero-copy in-place executors (the hot path)
//!
//! `parallel_{f,fc}_relax_mut` / `pool_{f,fc}_relax_mut` relax **in place
//! on the shared fine-grid storage**: every worker takes a disjoint
//! `&mut [T]` view of the level's point array (see the ownership protocol
//! in [`crate::parallel`]'s module docs) and writes results where they
//! live — no per-sweep slab copy, no stitch copy-back, no flat-buffer
//! allocation (halo messages recycle one persistent scratch per rank via
//! [`Endpoint::send_scratch`]). With the condvar dispatch of
//! [`WorkerPool::run_sweep`] a steady-state pooled sweep performs zero
//! heap allocations (pinned by `rust/tests/alloc_audit.rs`).
//!
//! ## Staged executors (oracle + bench baseline)
//!
//! `parallel_{f,fc}_relax` / `pool_{f,fc}_relax` are the previous
//! implementation: each slab copies its points out of the grid
//! (`w_all[lo..=hi].to_vec()`), relaxes the copy, and the results are
//! stitched back. They are kept as the independently-derived parity
//! oracle for the in-place path and as the `perf_hotpath` "staged"
//! baseline rows; both dispatch modes of each family share one slab body,
//! so the bitwise-parity invariant cannot silently fork per executor.
//!
//! Buffer-reuse contract (v3): the step closure has write-into form
//! `step(idx, z, out)` — `out` is an existing state slot that must be
//! **fully overwritten** — so the executors update grid points in place
//! via `Propagator::step_into` and never clone states on the sweep path.
//! The FAS right-hand side G, when present, is added after every step with
//! the same arithmetic as the serial engine (bitwise parity).

use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc::channel;
use std::thread;

use super::comm::Endpoint;
use super::comm::Fabric;
use super::pool::{WorkerPool, Workspace};
use super::topology::slab_range;
use crate::tensor::Tensor;

/// Fabric tag for the FCF halo exchange.
const HALO_TAG: u64 = 42;

/// Cold halo-corruption exit. Out of line so the sweep body's length
/// check compiles to one compare-and-branch — the panic formatting
/// machinery (format args, payload boxing) is not materialized in the
/// hot loop.
#[cold]
#[inline(never)]
fn bad_halo(got: usize, want: usize) -> ! {
    panic!(
        "malformed halo message: {} floats, expected {} (left-neighbour worker panicked?)",
        got, want
    )
}

/// A state vector the relaxation executors can carry across threads and
/// through the channel fabric.
pub trait RelaxState: Clone + Send + Sync {
    /// x += y elementwise (the RHS update of one relaxation step; must use
    /// the same arithmetic as the serial engine for bitwise parity).
    fn add_in_place(&mut self, other: &Self);

    /// Flattened element count (halo-message sanity checks).
    fn flat_len(&self) -> usize;

    /// Flatten for a fabric message.
    fn to_flat(&self) -> Vec<f32>;

    /// Rebuild from a fabric message (`like` supplies shape metadata).
    fn from_flat(like: &Self, data: Vec<f32>) -> Self;

    /// Append the flattened state to a reusable flat buffer (the
    /// allocation-free flatten of the in-place halo path). Must produce
    /// the exact bytes of [`RelaxState::to_flat`].
    fn write_flat(&self, out: &mut Vec<f32>) {
        out.extend_from_slice(&self.to_flat());
    }

    /// Overwrite this state from a flat message in place (shape is kept;
    /// the allocation-free inverse of [`RelaxState::write_flat`]).
    fn copy_from_flat(&mut self, data: &[f32]) {
        *self = Self::from_flat(self, data.to_vec());
    }
}

impl RelaxState for Vec<f32> {
    fn add_in_place(&mut self, other: &Self) {
        for (a, b) in self.iter_mut().zip(other) {
            *a += *b;
        }
    }

    fn flat_len(&self) -> usize {
        self.len()
    }

    fn to_flat(&self) -> Vec<f32> {
        self.clone()
    }

    fn from_flat(_like: &Self, data: Vec<f32>) -> Self {
        data
    }

    fn write_flat(&self, out: &mut Vec<f32>) {
        out.extend_from_slice(self);
    }

    fn copy_from_flat(&mut self, data: &[f32]) {
        self.copy_from_slice(data);
    }
}

impl RelaxState for Tensor {
    fn add_in_place(&mut self, other: &Self) {
        self.axpy(1.0, other);
    }

    fn flat_len(&self) -> usize {
        self.len()
    }

    fn to_flat(&self) -> Vec<f32> {
        self.data().to_vec()
    }

    fn from_flat(like: &Self, data: Vec<f32>) -> Self {
        Tensor::from_vec(data, like.shape())
    }

    fn write_flat(&self, out: &mut Vec<f32>) {
        out.extend_from_slice(self.data());
    }

    fn copy_from_flat(&mut self, data: &[f32]) {
        self.data_mut().copy_from_slice(data);
    }
}

// ---------------------------------------------------------------------------
// shared-grid (in-place) executors
// ---------------------------------------------------------------------------

/// Hands concurrently-running slab bodies disjoint `&mut [T]` windows of
/// one shared point array. The only unsafe ingredient of the in-place
/// executors: a raw pointer + length pair standing in for the `&mut [T]`
/// the caller lent for the duration of the sweep (the pool barrier /
/// scoped join guarantees the borrow outlives every access).
struct SharedGrid<'a, T> {
    ptr: *mut T,
    len: usize,
    _borrow: PhantomData<&'a mut [T]>,
}

// SAFETY: the grid only ever hands out slices of `T`; moving those
// accesses across threads is exactly as safe as sending `&mut [T]`.
unsafe impl<T: Send> Sync for SharedGrid<'_, T> {}

impl<'a, T> SharedGrid<'a, T> {
    fn new(data: &'a mut [T]) -> SharedGrid<'a, T> {
        SharedGrid { ptr: data.as_mut_ptr(), len: data.len(), _borrow: PhantomData }
    }

    /// Reborrow the window `[start, start + len)`.
    ///
    /// SAFETY: callers must hand pairwise-disjoint windows to concurrently
    /// running threads. The executors derive every window from
    /// [`slab_view`], whose ranges are disjoint by construction
    /// (`topology::slab_range` partitions the chunk space).
    // the returned borrow is tied to the grid's 'a (the caller's loan of
    // the whole array), not to &self — the mut_from_ref shape is the point
    #[allow(clippy::mut_from_ref)]
    unsafe fn window(&self, start: usize, len: usize) -> &'a mut [T] {
        assert!(start + len <= self.len, "grid window out of bounds");
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

/// Point-ownership geometry of one slab (see the protocol in
/// [`crate::parallel`]): rank `r` of `active` owns grid points
/// `[B_r, B_{r+1})` where `B_r = slab_range(..).0 * cf`, and the last
/// rank additionally owns the final point `n`. Returns
/// `(start_point, point_count, chunk_count)`.
fn slab_view(chunks: usize, cf: usize, active: usize, rank: usize) -> (usize, usize, usize) {
    let (c0, c1) = slab_range(chunks, active, rank);
    let start = c0 * cf;
    let cl = c1 - c0;
    (start, cl * cf + usize::from(rank + 1 == active), cl)
}

/// One relaxation step with the FAS right-hand side applied, writing the
/// updated point `view[idx + 1]` in place. `vlo` is the grid index of
/// `view[0]`; the g-indexing convention is `g[point_written]` — identical
/// to the staged executors' [`relax_point_into`], so the bitwise-parity
/// invariant cannot silently fork between the two families.
fn relax_view_point<T, F>(vlo: usize, idx: usize, view: &mut [T], g: Option<&[T]>, step: &F)
where
    T: RelaxState,
    F: Fn(usize, &T, &mut T),
{
    let (head, tail) = view.split_at_mut(idx + 1);
    step(vlo + idx, &head[idx], &mut tail[0]);
    if let Some(g) = g {
        tail[0].add_in_place(&g[vlo + idx + 1]);
    }
}

/// One F-point sweep over a slab's in-place view: for every owned chunk,
/// re-propagate its F-points from the chunk's leading C-point. C-points
/// (every `cf`-th view slot, including the read-only entry `view[0]`) are
/// never written.
fn f_sweep_view<T, F>(view: &mut [T], vlo: usize, cl: usize, cf: usize, g: Option<&[T]>, step: &F)
where
    T: RelaxState,
    F: Fn(usize, &T, &mut T),
{
    for c in 0..cl {
        for i in 0..cf - 1 {
            relax_view_point(vlo, c * cf + i, view, g, step);
        }
    }
}

/// The full FCF slab body on the shared grid (F-relax, C-relax with the
/// right boundary sent to its owner, halo recv into the entry C-point,
/// second F-relax). `temp` holds the boundary C-step result while it is
/// flattened for the fabric; only non-last ranks need one.
#[allow(clippy::too_many_arguments)]
fn fcf_slab_mut<T, F>(
    view: &mut [T],
    vlo: usize,
    cl: usize,
    cf: usize,
    g: Option<&[T]>,
    rank: usize,
    active: usize,
    mut temp: Option<&mut T>,
    ep: &mut Endpoint,
    step: &F,
) where
    T: RelaxState,
    F: Fn(usize, &T, &mut T),
{
    // F-relaxation: every chunk independently (parallel phase)
    f_sweep_view(view, vlo, cl, cf, g, step);
    // C-relaxation in chunk order. Interior chunk-boundary C-points are
    // owned by this slab and updated in place; the slab's *right* boundary
    // point belongs to the right neighbour — its value is computed into
    // `temp` and sent the moment it exists (the neighbour writes it where
    // it lives), exactly the staged schedule's boundary handoff.
    for c in 0..cl {
        let dest = (c + 1) * cf;
        if dest < view.len() {
            relax_view_point(vlo, dest - 1, view, g, step);
        } else {
            debug_assert_eq!(c, cl - 1, "only the last chunk ends off-slab");
            debug_assert!(rank + 1 < active, "the last rank owns its final point");
            let out: &mut T = temp.as_mut().expect("non-last ranks carry a boundary temp");
            step(vlo + dest - 1, &view[dest - 1], out);
            if let Some(g) = g {
                out.add_in_place(&g[vlo + dest]);
            }
            ep.send_scratch(rank + 1, HALO_TAG, |buf| out.write_flat(buf));
        }
    }
    // second F-relax needs the refreshed entry C-point produced by the
    // left neighbour's C-relax (FCF); receive it straight into the grid
    if rank > 0 {
        let entry = &mut view[0];
        ep.recv_scratch(rank - 1, HALO_TAG, |data| {
            if data.len() != entry.flat_len() {
                bad_halo(data.len(), entry.flat_len());
            }
            entry.copy_from_flat(data);
        });
    }
    f_sweep_view(view, vlo, cl, cf, g, step);
}

/// In-place FCF sweep on `workers` scoped threads: the zero-copy form of
/// [`parallel_fc_relax`] — `w` holds states at points 0..=n and is
/// relaxed where it lives (C-points must be valid on entry; F-points and
/// chunk-boundary C-points are overwritten). Bitwise identical to the
/// serial schedule and to the staged executors.
pub fn parallel_fc_relax_mut<T, F>(w: &mut [T], g: Option<&[T]>, cf: usize, workers: usize, step: F)
where
    T: RelaxState,
    F: Fn(usize, &T, &mut T) + Sync,
{
    let n = w.len() - 1;
    assert_eq!(n % cf, 0, "n must be a multiple of cf");
    let chunks = n / cf;
    let active = workers.min(chunks).max(1);
    let mut fabric = Fabric::new(active);
    let endpoints = fabric.take_all();
    let step_ref = &step;

    // safe sequential split into the per-rank disjoint windows
    let mut views: Vec<&mut [T]> = Vec::with_capacity(active);
    let mut rest = w;
    for rank in 0..active {
        let (_, len, _) = slab_view(chunks, cf, active, rank);
        let (head, tail) = rest.split_at_mut(len);
        views.push(head);
        rest = tail;
    }
    debug_assert!(rest.is_empty(), "slab views must cover the whole grid");

    thread::scope(|s| {
        for ((rank, mut ep), view) in endpoints.into_iter().enumerate().zip(views) {
            s.spawn(move || {
                let (vlo, _, cl) = slab_view(chunks, cf, active, rank);
                let mut temp = if rank + 1 < active { Some(view[0].clone()) } else { None };
                fcf_slab_mut(view, vlo, cl, cf, g, rank, active, temp.as_mut(), &mut ep, step_ref);
            });
        }
    });
}

/// In-place F-only sweep on scoped threads (no communication at all): the
/// zero-copy form of [`parallel_f_relax`].
pub fn parallel_f_relax_mut<T, F>(w: &mut [T], g: Option<&[T]>, cf: usize, workers: usize, step: F)
where
    T: RelaxState,
    F: Fn(usize, &T, &mut T) + Sync,
{
    let n = w.len() - 1;
    assert_eq!(n % cf, 0, "n must be a multiple of cf");
    let chunks = n / cf;
    let active = workers.min(chunks).max(1);
    let step_ref = &step;

    let mut views: Vec<&mut [T]> = Vec::with_capacity(active);
    let mut rest = w;
    for rank in 0..active {
        let (_, len, _) = slab_view(chunks, cf, active, rank);
        let (head, tail) = rest.split_at_mut(len);
        views.push(head);
        rest = tail;
    }
    debug_assert!(rest.is_empty(), "slab views must cover the whole grid");

    thread::scope(|s| {
        for (rank, view) in views.into_iter().enumerate() {
            s.spawn(move || {
                let (vlo, _, cl) = slab_view(chunks, cf, active, rank);
                f_sweep_view(view, vlo, cl, cf, g, step_ref);
            });
        }
    });
}

/// In-place FCF sweep on a persistent [`WorkerPool`]: the zero-allocation
/// hot path of the `ThreadedMgrit` backend. Same slab schedule as
/// [`parallel_fc_relax_mut`] with `workers = pool.size()`, dispatched as
/// one shared borrowed body ([`WorkerPool::run_sweep`]); each worker's
/// boundary temp lives in its persistent [`Workspace`] and halo messages
/// recycle the endpoints' flat scratch.
///
/// Panic containment: a panicking slab first sends a zero-length *poison*
/// halo so its right neighbour — possibly blocked on the halo recv —
/// fails the length check instead of deadlocking the sweep barrier; the
/// chain unwinds rank by rank, the barrier completes, the pool is
/// **poisoned**, and the original payload re-raises here. A dead-sender
/// halo recv unwinds with a typed [`crate::parallel::FabricError`]
/// payload (not an untyped assert), so the owner can downcast the caught
/// panic and route it through pool-rebuild + retry
/// ([`crate::coordinator::ForwardContext`]) instead of aborting. The
/// `pool.sweep_panic` fault point (rank 0, counted per sweep) injects a
/// deterministic slab panic for `rust/tests/chaos.rs`.
pub fn pool_fc_relax_mut<T, F>(pool: &WorkerPool, w: &mut [T], g: Option<&[T]>, cf: usize, step: F)
where
    T: RelaxState + 'static,
    F: Fn(usize, &T, &mut T) + Sync,
{
    let n = w.len() - 1;
    assert_eq!(n % cf, 0, "n must be a multiple of cf");
    let chunks = n / cf;
    let active = pool.size().min(chunks).max(1);
    let grid = SharedGrid::new(w);
    let step_ref = &step;
    pool.run_sweep(active, &|rank: usize, ep: &mut Endpoint, ws: &mut Workspace| {
        let res = catch_unwind(AssertUnwindSafe(|| {
            // deterministic chaos hook: one relaxed atomic load when
            // disarmed (rust/src/fault). Counted on rank 0 only, so
            // `pool.sweep_panic@step=N` means "the N-th pooled FCF sweep".
            if rank == 0 && crate::faultpoint!("pool.sweep_panic") {
                panic!("injected: pool.sweep_panic");
            }
            let (vlo, vlen, cl) = slab_view(chunks, cf, active, rank);
            // SAFETY: slab_view windows are pairwise disjoint across the
            // active ranks of one sweep (see SharedGrid::window).
            let view = unsafe { grid.window(vlo, vlen) };
            if rank + 1 < active {
                let want = view[0].flat_len();
                let temp = ws.typed::<T, _, _>(|t| t.flat_len() == want, || view[0].clone());
                fcf_slab_mut(view, vlo, cl, cf, g, rank, active, Some(temp), ep, step_ref);
            } else {
                fcf_slab_mut(view, vlo, cl, cf, g, rank, active, None, ep, step_ref);
            }
        }));
        if let Err(payload) = res {
            // zero-length poison halo: real states are never empty, so the
            // neighbour's length check fires instead of waiting forever
            if rank + 1 < active {
                ep.send(rank + 1, HALO_TAG, Vec::new());
            }
            resume_unwind(payload);
        }
    });
}

/// In-place F-only sweep on a persistent [`WorkerPool`]. No halo waits, so
/// a panicking slab simply re-raises at the dispatch site after the
/// barrier (the pool is still poisoned by `run_sweep`).
pub fn pool_f_relax_mut<T, F>(pool: &WorkerPool, w: &mut [T], g: Option<&[T]>, cf: usize, step: F)
where
    T: RelaxState + 'static,
    F: Fn(usize, &T, &mut T) + Sync,
{
    let n = w.len() - 1;
    assert_eq!(n % cf, 0, "n must be a multiple of cf");
    let chunks = n / cf;
    let active = pool.size().min(chunks).max(1);
    let grid = SharedGrid::new(w);
    let step_ref = &step;
    pool.run_sweep(active, &|rank: usize, _ep: &mut Endpoint, _ws: &mut Workspace| {
        let (vlo, vlen, cl) = slab_view(chunks, cf, active, rank);
        // SAFETY: disjoint windows, as in pool_fc_relax_mut.
        let view = unsafe { grid.window(vlo, vlen) };
        f_sweep_view(view, vlo, cl, cf, g, step_ref);
    });
}

// ---------------------------------------------------------------------------
// staged executors (parity oracle + bench baseline)
// ---------------------------------------------------------------------------

/// One relaxation step with the FAS right-hand side applied, writing the
/// updated point `local[idx + 1]` in place — the staged twin of
/// [`relax_view_point`] (same g-indexing convention: `g[point_written]`,
/// i.e. `lo+idx+1`).
fn relax_point_into<T, F>(lo: usize, idx: usize, local: &mut [T], g: Option<&[T]>, step: &F)
where
    T: RelaxState,
    F: Fn(usize, &T, &mut T),
{
    let (head, tail) = local.split_at_mut(idx + 1);
    step(lo + idx, &head[idx], &mut tail[0]);
    if let Some(g) = g {
        tail[0].add_in_place(&g[lo + idx + 1]);
    }
}

/// One F-point sweep over a slab's local copy: for every owned chunk,
/// re-propagate its F-points from the chunk's leading C-point (`lo` is
/// the level index of `local[0]`).
fn f_sweep_local<T, F>(
    local: &mut [T],
    lo: usize,
    n_chunks: usize,
    cf: usize,
    g: Option<&[T]>,
    step: &F,
) where
    T: RelaxState,
    F: Fn(usize, &T, &mut T),
{
    for c in 0..n_chunks {
        for i in 0..cf - 1 {
            relax_point_into(lo, c * cf + i, local, g, step);
        }
    }
}

/// The staged FCF slab body (slab copy, F-relax, C-relax, halo exchange,
/// second F-relax) for the slab covering chunks [c0, c1). `active` is the
/// number of ranks participating in this sweep (halo neighbours are gated
/// on it, not on the fabric size, so a pool larger than the sweep still
/// runs the exact scoped schedule).
#[allow(clippy::too_many_arguments)]
fn fcf_slab<T, F>(
    w_all: &[T],
    g: Option<&[T]>,
    cf: usize,
    c0: usize,
    c1: usize,
    active: usize,
    ep: &mut Endpoint,
    step: &F,
) -> (usize, Vec<T>)
where
    T: RelaxState,
    F: Fn(usize, &T, &mut T),
{
    let rank = ep.rank;
    // local copy of this slab's points: chunk c covers fine indices
    // [c*cf, (c+1)*cf]; we own points (c0*cf, c1*cf] plus read access to
    // the C-point at c0*cf.
    let lo = c0 * cf;
    let hi = c1 * cf;
    let mut local: Vec<T> = w_all[lo..=hi].to_vec();
    // F-relaxation: every chunk independently (parallel phase)
    f_sweep_local(&mut local, lo, c1 - c0, cf, g, step);
    // C-relaxation: the final step of each chunk; the first C-point of the
    // *next* slab is produced here, so send the boundary value right after
    // computing it.
    for c in 0..(c1 - c0) {
        relax_point_into(lo, (c + 1) * cf - 1, &mut local, g, step);
    }
    // second F-relax needs the incoming C-point from the left neighbour's
    // C-relax (FCF); exchange halos:
    if rank + 1 < active {
        let boundary = local.last().unwrap().to_flat();
        ep.send(rank + 1, HALO_TAG, boundary);
    }
    if rank > 0 {
        let data = ep.recv(rank - 1, HALO_TAG);
        if data.len() != local[0].flat_len() {
            bad_halo(data.len(), local[0].flat_len());
        }
        local[0] = T::from_flat(&local[0], data);
    }
    // final F-relaxation with the fresh left C-point
    f_sweep_local(&mut local, lo, c1 - c0, cf, g, step);
    (lo, local)
}

/// The staged F-only slab body (no communication at all).
fn f_slab<T, F>(
    w_all: &[T],
    g: Option<&[T]>,
    cf: usize,
    c0: usize,
    c1: usize,
    step: &F,
) -> (usize, Vec<T>)
where
    T: RelaxState,
    F: Fn(usize, &T, &mut T),
{
    let lo = c0 * cf;
    let hi = c1 * cf;
    let mut local: Vec<T> = w_all[lo..=hi].to_vec();
    f_sweep_local(&mut local, lo, c1 - c0, cf, g, step);
    (lo, local)
}

/// Stitch per-slab worker results back into the full point array.
fn stitch<T>(mut out: Vec<T>, mut results: Vec<(usize, Vec<T>)>) -> Vec<T> {
    results.sort_by_key(|(lo, _)| *lo);
    for (lo, local) in results {
        for (i, v) in local.into_iter().enumerate() {
            out[lo + i] = v;
        }
    }
    out
}

/// Staged FCF sweep over `n` fine steps executed by `workers` scoped
/// threads (slab copies + stitch; see the module docs — the training hot
/// path uses [`parallel_fc_relax_mut`]). `w` holds states at points 0..=n
/// (C-points must be valid on entry; F-points are overwritten). `g`, when
/// present, is the FAS right-hand side added after every step
/// (index-aligned with `w`). Returns the updated states — bitwise
/// identical to the serial schedule.
pub fn parallel_fc_relax<T, F>(
    w: Vec<T>,
    g: Option<&[T]>,
    cf: usize,
    workers: usize,
    step: F,
) -> Vec<T>
where
    T: RelaxState,
    F: Fn(usize, &T, &mut T) + Sync,
{
    let n = w.len() - 1;
    assert_eq!(n % cf, 0, "n must be a multiple of cf");
    let chunks = n / cf;
    let workers = workers.min(chunks).max(1);
    let mut fabric = Fabric::new(workers);
    let endpoints = fabric.take_all();
    let step_ref = &step;
    let w_ref = &w;

    let results: Vec<(usize, Vec<T>)> = thread::scope(|s| {
        let handles: Vec<_> = endpoints
            .into_iter()
            .enumerate()
            .map(|(rank, mut ep)| {
                let (c0, c1) = slab_range(chunks, workers, rank);
                s.spawn(move || fcf_slab(w_ref, g, cf, c0, c1, workers, &mut ep, step_ref))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    stitch(w, results)
}

/// Staged F-relaxation sweep over `workers` scoped threads: every chunk
/// re-propagates its F-points from its (read-only) leading C-point — no
/// communication at all, the embarrassingly-parallel phase of paper
/// Fig. 2. `g` as in [`parallel_fc_relax`].
pub fn parallel_f_relax<T, F>(
    w: Vec<T>,
    g: Option<&[T]>,
    cf: usize,
    workers: usize,
    step: F,
) -> Vec<T>
where
    T: RelaxState,
    F: Fn(usize, &T, &mut T) + Sync,
{
    let n = w.len() - 1;
    assert_eq!(n % cf, 0, "n must be a multiple of cf");
    let chunks = n / cf;
    let workers = workers.min(chunks).max(1);
    let step_ref = &step;
    let w_ref = &w;

    let results: Vec<(usize, Vec<T>)> = thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|rank| {
                let (c0, c1) = slab_range(chunks, workers, rank);
                s.spawn(move || f_slab(w_ref, g, cf, c0, c1, step_ref))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    stitch(w, results)
}

/// [`parallel_fc_relax`] dispatched onto a persistent [`WorkerPool`]
/// through the boxed-job compatibility path (staged slab copies; the hot
/// path is [`pool_fc_relax_mut`]). The slab partition uses
/// `min(pool.size(), chunks)` active ranks, so a pool of size k produces
/// bitwise the same states as `parallel_fc_relax(.., workers = k, ..)`.
pub fn pool_fc_relax<T, F>(
    pool: &WorkerPool,
    w: Vec<T>,
    g: Option<&[T]>,
    cf: usize,
    step: F,
) -> Vec<T>
where
    T: RelaxState,
    F: Fn(usize, &T, &mut T) + Sync,
{
    let n = w.len() - 1;
    assert_eq!(n % cf, 0, "n must be a multiple of cf");
    let chunks = n / cf;
    let active = pool.size().min(chunks).max(1);
    let step_ref = &step;
    let w_ref = &w;
    let results =
        pool_dispatch(pool, chunks, active, true, |c0: usize, c1: usize, ep: &mut Endpoint| {
            fcf_slab(w_ref, g, cf, c0, c1, active, ep, step_ref)
        });
    stitch(w, results)
}

/// Shared dispatch scaffold for the staged pooled executors: one boxed job
/// per slab, result/panic channels, and the completion barrier. On any
/// panic the first payload is re-raised after the barrier (poisoning is
/// handled by `run_sweep` underneath); with `poison_halo` a panicking rank
/// first sends a zero-length halo so a blocked right neighbour fails its
/// length check instead of deadlocking (the chain unwinds rank by rank).
fn pool_dispatch<T, B>(
    pool: &WorkerPool,
    chunks: usize,
    active: usize,
    poison_halo: bool,
    body: B,
) -> Vec<(usize, Vec<T>)>
where
    T: RelaxState,
    B: Fn(usize, usize, &mut Endpoint) -> (usize, Vec<T>) + Sync,
{
    let body_ref = &body;
    let (res_tx, res_rx) = channel::<(usize, Vec<T>)>();
    let jobs: Vec<Box<dyn FnOnce(&mut Endpoint) + Send + '_>> = (0..active)
        .map(|rank| {
            let (c0, c1) = slab_range(chunks, active, rank);
            let tx = res_tx.clone();
            Box::new(move |ep: &mut Endpoint| {
                match catch_unwind(AssertUnwindSafe(|| body_ref(c0, c1, ep))) {
                    Ok(r) => {
                        let _ = tx.send(r);
                    }
                    Err(payload) => {
                        // zero-length poison halo: real states are never
                        // empty, so the neighbour's length check fires
                        if poison_halo && ep.rank + 1 < active {
                            ep.send(ep.rank + 1, HALO_TAG, Vec::new());
                        }
                        resume_unwind(payload);
                    }
                }
            }) as Box<dyn FnOnce(&mut Endpoint) + Send + '_>
        })
        .collect();
    drop(res_tx);
    pool.run_scoped(jobs);
    let results: Vec<(usize, Vec<T>)> = res_rx.try_iter().collect();
    assert_eq!(results.len(), active, "a pool worker dropped its sweep result");
    results
}

/// [`parallel_f_relax`] on a persistent [`WorkerPool`] (staged; the hot
/// path is [`pool_f_relax_mut`]). F-only sweeps have no halo waits, so a
/// panicking slab simply surfaces its payload after the barrier.
pub fn pool_f_relax<T, F>(
    pool: &WorkerPool,
    w: Vec<T>,
    g: Option<&[T]>,
    cf: usize,
    step: F,
) -> Vec<T>
where
    T: RelaxState,
    F: Fn(usize, &T, &mut T) + Sync,
{
    let n = w.len() - 1;
    assert_eq!(n % cf, 0, "n must be a multiple of cf");
    let chunks = n / cf;
    let active = pool.size().min(chunks).max(1);
    let step_ref = &step;
    let w_ref = &w;
    let results =
        pool_dispatch(pool, chunks, active, false, |c0: usize, c1: usize, _ep: &mut Endpoint| {
            f_slab(w_ref, g, cf, c0, c1, step_ref)
        });
    stitch(w, results)
}

/// Single-threaded FCF sweep with the same update order (oracle for tests).
pub fn serial_fc_relax<F>(mut w: Vec<Vec<f32>>, cf: usize, step: F) -> Vec<Vec<f32>>
where
    F: Fn(usize, &[f32]) -> Vec<f32>,
{
    let n = w.len() - 1;
    let chunks = n / cf;
    for c in 0..chunks {
        for i in 0..cf - 1 {
            let idx = c * cf + i;
            w[idx + 1] = step(idx, &w[idx]);
        }
    }
    for c in 0..chunks {
        let idx = (c + 1) * cf - 1;
        w[idx + 1] = step(idx, &w[idx]);
    }
    for c in 0..chunks {
        for i in 0..cf - 1 {
            let idx = c * cf + i;
            w[idx + 1] = step(idx, &w[idx]);
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn affine_step(layer: usize, z: &[f32]) -> Vec<f32> {
        // z' = 0.95 z + c(layer): nonlinear enough to catch ordering bugs
        z.iter()
            .enumerate()
            .map(|(i, &v)| 0.95 * v + 0.01 * (layer as f32 + 1.0) + 0.001 * (i as f32) * v.tanh())
            .collect()
    }

    #[allow(clippy::ptr_arg)]
    fn vec_step(layer: usize, z: &Vec<f32>, out: &mut Vec<f32>) {
        *out = affine_step(layer, z);
    }

    #[test]
    fn parallel_matches_serial_exactly() {
        for (n, cf, workers) in [(16, 4, 2), (16, 4, 4), (24, 3, 3), (32, 2, 5), (8, 8, 1)] {
            let mut rng = Rng::new(n as u64);
            let w: Vec<Vec<f32>> = (0..=n).map(|_| rng.normal_vec(6, 1.0)).collect();
            let serial = serial_fc_relax(w.clone(), cf, affine_step);
            let parallel = parallel_fc_relax(w, None, cf, workers, vec_step);
            for (a, b) in parallel.iter().zip(&serial) {
                assert_eq!(a, b, "n={} cf={} workers={}", n, cf, workers);
            }
        }
    }

    #[test]
    fn inplace_matches_staged_bitwise() {
        // the zero-copy acceptance property: for every worker count and
        // grid shape, the in-place executors reproduce the staged (slab
        // copy + stitch) executors bit for bit — FCF and F-only, with and
        // without a FAS right-hand side, scoped and pooled.
        for workers in 1usize..=5 {
            let pool = WorkerPool::new(workers);
            for (n, cf) in [(16usize, 4usize), (24, 3), (32, 2), (8, 8), (6, 2), (4, 2)] {
                let mut rng = Rng::new((workers * 1000 + n) as u64);
                let w: Vec<Vec<f32>> = (0..=n).map(|_| rng.normal_vec(5, 1.0)).collect();
                let g: Vec<Vec<f32>> = (0..=n).map(|_| rng.normal_vec(5, 0.1)).collect();
                for round in 0..2 {
                    let g_opt = if round == 0 { None } else { Some(&g[..]) };

                    let staged = parallel_fc_relax(w.clone(), g_opt, cf, workers, vec_step);
                    let mut inplace = w.clone();
                    parallel_fc_relax_mut(&mut inplace, g_opt, cf, workers, vec_step);
                    assert_eq!(inplace, staged, "scoped fcf n={} cf={} wk={}", n, cf, workers);
                    let mut pooled = w.clone();
                    pool_fc_relax_mut(&pool, &mut pooled, g_opt, cf, vec_step);
                    assert_eq!(pooled, staged, "pooled fcf n={} cf={} wk={}", n, cf, workers);

                    let staged = parallel_f_relax(w.clone(), g_opt, cf, workers, vec_step);
                    let mut inplace = w.clone();
                    parallel_f_relax_mut(&mut inplace, g_opt, cf, workers, vec_step);
                    assert_eq!(inplace, staged, "scoped f n={} cf={} wk={}", n, cf, workers);
                    let mut pooled = w.clone();
                    pool_f_relax_mut(&pool, &mut pooled, g_opt, cf, vec_step);
                    assert_eq!(pooled, staged, "pooled f n={} cf={} wk={}", n, cf, workers);
                }
            }
        }
    }

    #[test]
    fn pool_matches_scoped_spawns_bitwise() {
        // the persistent-pool acceptance property: for 1–4 workers, the
        // pool executor reproduces the scoped-spawn executor bit for bit,
        // FCF and F-only, with and without a FAS right-hand side — across
        // repeated sweeps through the *same* parked threads.
        for workers in 1usize..=4 {
            let pool = WorkerPool::new(workers);
            for (n, cf) in [(16usize, 4usize), (24, 3), (32, 2), (8, 8)] {
                let mut rng = Rng::new((workers * 100 + n) as u64);
                let w: Vec<Vec<f32>> = (0..=n).map(|_| rng.normal_vec(5, 1.0)).collect();
                let g: Vec<Vec<f32>> = (0..=n).map(|_| rng.normal_vec(5, 0.1)).collect();
                for round in 0..2 {
                    let g_opt = if round == 0 { None } else { Some(&g[..]) };
                    let scoped = parallel_fc_relax(w.clone(), g_opt, cf, workers, vec_step);
                    let pooled = pool_fc_relax(&pool, w.clone(), g_opt, cf, vec_step);
                    for (a, b) in pooled.iter().zip(&scoped) {
                        assert_eq!(a, b, "fcf n={} cf={} workers={}", n, cf, workers);
                    }
                    let scoped = parallel_f_relax(w.clone(), g_opt, cf, workers, vec_step);
                    let pooled = pool_f_relax(&pool, w.clone(), g_opt, cf, vec_step);
                    for (a, b) in pooled.iter().zip(&scoped) {
                        assert_eq!(a, b, "f n={} cf={} workers={}", n, cf, workers);
                    }
                }
            }
        }
    }

    #[test]
    fn pool_workspaces_are_reused_across_inplace_sweeps() {
        // boundary temps are built once per sending rank, survive repeated
        // sweeps, and rebuild exactly once per rank on a state-shape change
        let pool = WorkerPool::new(2);
        let mut rng = Rng::new(21);
        let sweep = |pool: &WorkerPool, rng: &mut Rng, dim: usize| {
            let mut w: Vec<Vec<f32>> = (0..=8).map(|_| rng.normal_vec(dim, 1.0)).collect();
            pool_fc_relax_mut(pool, &mut w, None, 2, vec_step);
        };
        sweep(&pool, &mut rng, 5);
        // 2 active ranks, 1 sender (rank 0) -> exactly one temp built
        assert_eq!(pool.workspace_builds(), 1);
        for _ in 0..4 {
            sweep(&pool, &mut rng, 5);
        }
        assert_eq!(pool.workspace_builds(), 1, "stable shapes must not rebuild temps");
        sweep(&pool, &mut rng, 9);
        assert_eq!(pool.workspace_builds(), 2, "a shape change rebuilds exactly once");
        for _ in 0..3 {
            sweep(&pool, &mut rng, 9);
        }
        assert_eq!(pool.workspace_builds(), 2);
    }

    #[test]
    fn poisoned_pool_rebuild_recreates_workspaces() {
        // a panic-poisoned pool is replaced wholesale by its owner; the
        // replacement starts with fresh workspaces that rebuild on first
        // use — the same recycle-don't-reuse policy as poisoned cores
        use std::panic::{catch_unwind as cu, AssertUnwindSafe as Aus};
        let pool = WorkerPool::new(2);
        let mut rng = Rng::new(22);
        let w: Vec<Vec<f32>> = (0..=8).map(|_| rng.normal_vec(3, 1.0)).collect();
        let mut wp = w.clone();
        pool_fc_relax_mut(&pool, &mut wp, None, 2, vec_step);
        assert_eq!(pool.workspace_builds(), 1);
        let boom = |l: usize, z: &Vec<f32>, out: &mut Vec<f32>| {
            assert_ne!(l, 1, "boom");
            *out = affine_step(l, z);
        };
        let mut wb = w.clone();
        let r = cu(Aus(|| pool_fc_relax_mut(&pool, &mut wb, None, 2, boom)));
        assert!(r.is_err());
        assert!(pool.is_poisoned());
        // the owner's replacement pool: fresh workspaces, one rebuild
        let pool2 = WorkerPool::new(2);
        assert_eq!(pool2.workspace_builds(), 0);
        let mut w2 = w.clone();
        pool_fc_relax_mut(&pool2, &mut w2, None, 2, vec_step);
        assert_eq!(pool2.workspace_builds(), 1);
        let want = serial_fc_relax(w, 2, affine_step);
        assert_eq!(w2, want);
    }

    #[test]
    fn pooled_sweep_panics_loudly_instead_of_deadlocking() {
        // a panicking Φ inside a pooled FCF sweep must surface the panic
        // through the executor (poison-halo chain), not hang the barrier
        // — staged and in-place
        use std::panic::{catch_unwind as cu, AssertUnwindSafe as Aus};
        let mut rng = Rng::new(13);
        let w: Vec<Vec<f32>> = (0..=8).map(|_| rng.normal_vec(3, 1.0)).collect();
        let boom = |l: usize, z: &Vec<f32>, out: &mut Vec<f32>| {
            assert_ne!(l, 1, "boom");
            *out = affine_step(l, z);
        };
        let pool = WorkerPool::new(2);
        let result = cu(Aus(|| pool_fc_relax(&pool, w.clone(), None, 2, boom)));
        assert!(result.is_err(), "worker panic must propagate to the caller");
        // the failed sweep poisons the pool (stale halos may be queued);
        // further sweeps refuse loudly instead of computing on stale state
        assert!(pool.is_poisoned());
        let retry = cu(Aus(|| pool_fc_relax(&pool, w.clone(), None, 2, vec_step)));
        assert!(retry.is_err(), "poisoned pool must refuse further sweeps");

        let pool = WorkerPool::new(2);
        let mut wi = w.clone();
        let result = cu(Aus(|| pool_fc_relax_mut(&pool, &mut wi, None, 2, boom)));
        assert!(result.is_err(), "in-place worker panic must propagate");
        assert!(pool.is_poisoned());
    }

    #[test]
    fn oversized_pool_is_clamped_to_chunks() {
        // 2 chunks but a 6-worker pool: only ranks 0..2 participate and
        // the result still matches the serial schedule
        let pool = WorkerPool::new(6);
        let mut rng = Rng::new(77);
        let w: Vec<Vec<f32>> = (0..=8).map(|_| rng.normal_vec(4, 1.0)).collect();
        let serial = serial_fc_relax(w.clone(), 4, affine_step);
        let pooled = pool_fc_relax(&pool, w.clone(), None, 4, vec_step);
        for (a, b) in pooled.iter().zip(&serial) {
            assert_eq!(a, b);
        }
        let mut inplace = w;
        pool_fc_relax_mut(&pool, &mut inplace, None, 4, vec_step);
        assert_eq!(inplace, serial);
    }

    #[test]
    fn more_workers_than_chunks_is_clamped() {
        let mut rng = Rng::new(9);
        let w: Vec<Vec<f32>> = (0..=8).map(|_| rng.normal_vec(4, 1.0)).collect();
        let serial = serial_fc_relax(w.clone(), 4, affine_step);
        let parallel = parallel_fc_relax(w.clone(), None, 4, 16, vec_step); // 2 chunks only
        for (a, b) in parallel.iter().zip(&serial) {
            assert_eq!(a, b);
        }
        let mut inplace = w;
        parallel_fc_relax_mut(&mut inplace, None, 4, 16, vec_step);
        assert_eq!(inplace, serial);
    }

    #[test]
    fn rhs_aware_sweep_matches_serial_with_rhs() {
        // FAS form: every step adds g — compare against a hand-rolled
        // serial FCF sweep with the same adds.
        let (n, cf) = (16usize, 4usize);
        let mut rng = Rng::new(3);
        let w: Vec<Vec<f32>> = (0..=n).map(|_| rng.normal_vec(5, 1.0)).collect();
        let g: Vec<Vec<f32>> = (0..=n).map(|_| rng.normal_vec(5, 0.1)).collect();
        let mut serial = w.clone();
        let chunks = n / cf;
        let sweep_f = |w: &mut Vec<Vec<f32>>| {
            for c in 0..chunks {
                for i in 0..cf - 1 {
                    let idx = c * cf + i;
                    let mut next = affine_step(idx, &w[idx]);
                    next.add_in_place(&g[idx + 1]);
                    w[idx + 1] = next;
                }
            }
        };
        sweep_f(&mut serial);
        for c in 0..chunks {
            let idx = (c + 1) * cf - 1;
            let mut next = affine_step(idx, &serial[idx]);
            next.add_in_place(&g[idx + 1]);
            serial[idx + 1] = next;
        }
        sweep_f(&mut serial);
        for workers in [1usize, 2, 4] {
            let parallel = parallel_fc_relax(w.clone(), Some(&g[..]), cf, workers, vec_step);
            for (a, b) in parallel.iter().zip(&serial) {
                assert_eq!(a, b, "workers={}", workers);
            }
            let mut inplace = w.clone();
            parallel_fc_relax_mut(&mut inplace, Some(&g[..]), cf, workers, vec_step);
            assert_eq!(inplace, serial, "in-place workers={}", workers);
        }
    }

    #[test]
    fn f_only_sweep_touches_only_f_points() {
        let (n, cf) = (12usize, 3usize);
        let mut rng = Rng::new(4);
        let w: Vec<Vec<f32>> = (0..=n).map(|_| rng.normal_vec(4, 1.0)).collect();
        let out = parallel_f_relax(w.clone(), None, cf, 3, vec_step);
        let mut out_mut = w.clone();
        parallel_f_relax_mut(&mut out_mut, None, cf, 3, vec_step);
        assert_eq!(out_mut, out);
        for i in (0..=n).step_by(cf) {
            assert_eq!(out[i], w[i], "C-point {} must be untouched", i);
        }
        // F-points follow the chain from their chunk's C-point
        for c in 0..n / cf {
            let mut cur = w[c * cf].clone();
            for i in 0..cf - 1 {
                cur = affine_step(c * cf + i, &cur);
                assert_eq!(out[c * cf + i + 1], cur);
            }
        }
    }

    #[test]
    fn tensor_states_round_trip_the_fabric() {
        // Tensor-typed relaxation (the real MGRIT hot-loop shape) matches
        // the Vec<f32> executor bit for bit — scoped and pooled, staged
        // and in-place.
        let (n, cf, workers) = (16usize, 4usize, 4usize);
        let mut rng = Rng::new(5);
        let w_vec: Vec<Vec<f32>> = (0..=n).map(|_| rng.normal_vec(6, 1.0)).collect();
        let w_t: Vec<Tensor> =
            w_vec.iter().map(|v| Tensor::from_vec(v.clone(), &[2, 3])).collect();
        let t_step = |l: usize, z: &Tensor, out: &mut Tensor| {
            *out = Tensor::from_vec(affine_step(l, z.data()), &[2, 3]);
        };
        let out_vec = parallel_fc_relax(w_vec, None, cf, workers, vec_step);
        let out_t = parallel_fc_relax(w_t.clone(), None, cf, workers, t_step);
        for (a, b) in out_t.iter().zip(&out_vec) {
            assert_eq!(a.data(), b.as_slice());
        }
        let pool = WorkerPool::new(workers);
        let out_p = pool_fc_relax(&pool, w_t.clone(), None, cf, t_step);
        for (a, b) in out_p.iter().zip(&out_vec) {
            assert_eq!(a.data(), b.as_slice());
        }
        let mut out_ip = w_t;
        pool_fc_relax_mut(&pool, &mut out_ip, None, cf, t_step);
        for (a, b) in out_ip.iter().zip(&out_vec) {
            assert_eq!(a.data(), b.as_slice());
        }
    }
}
