//! Real multi-worker execution of the MGRIT relaxation phase.
//!
//! Each worker owns a contiguous slab of chunks, applies F-relaxation
//! locally (no communication — the parallel phase of paper Fig. 2), then
//! C-relaxation with a halo exchange of the slab-boundary state over the
//! channel [`Fabric`](super::comm::Fabric). The update schedule is
//! value-for-value identical to the single-threaded engine, so threaded
//! solves are *bitwise* equal to serial ones.
//!
//! Two dispatch modes share the exact same slab bodies:
//!
//! * `parallel_f_relax` / `parallel_fc_relax` — scoped threads spawned per
//!   sweep (self-contained; used by ad-hoc solver calls and as the parity
//!   oracle for the pool);
//! * `pool_f_relax` / `pool_fc_relax` — the same sweeps dispatched onto a
//!   persistent [`WorkerPool`] (per-`Session` threads parked between
//!   sweeps, amortizing spawn cost; the `ThreadedMgrit` backend's path).
//!
//! Buffer-reuse contract (v3): the step closure has write-into form
//! `step(idx, z, out)` — `out` is an existing state slot that must be
//! **fully overwritten** — so the executors update grid points in place
//! via `Propagator::step_into` and never clone states on the sweep path.
//! The FAS right-hand side G, when present, is added after every step with
//! the same arithmetic as the serial engine (bitwise parity).

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc::channel;
use std::thread;

use super::comm::Endpoint;
use super::comm::Fabric;
use super::pool::WorkerPool;
use super::topology::slab_partition;
use crate::tensor::Tensor;

/// Fabric tag for the FCF halo exchange.
const HALO_TAG: u64 = 42;

/// A state vector the relaxation executors can carry across threads and
/// through the channel fabric.
pub trait RelaxState: Clone + Send + Sync {
    /// x += y elementwise (the RHS update of one relaxation step; must use
    /// the same arithmetic as the serial engine for bitwise parity).
    fn add_in_place(&mut self, other: &Self);

    /// Flattened element count (halo-message sanity checks).
    fn flat_len(&self) -> usize;

    /// Flatten for a fabric message.
    fn to_flat(&self) -> Vec<f32>;

    /// Rebuild from a fabric message (`like` supplies shape metadata).
    fn from_flat(like: &Self, data: Vec<f32>) -> Self;
}

impl RelaxState for Vec<f32> {
    fn add_in_place(&mut self, other: &Self) {
        for (a, b) in self.iter_mut().zip(other) {
            *a += *b;
        }
    }

    fn flat_len(&self) -> usize {
        self.len()
    }

    fn to_flat(&self) -> Vec<f32> {
        self.clone()
    }

    fn from_flat(_like: &Self, data: Vec<f32>) -> Self {
        data
    }
}

impl RelaxState for Tensor {
    fn add_in_place(&mut self, other: &Self) {
        self.axpy(1.0, other);
    }

    fn flat_len(&self) -> usize {
        self.len()
    }

    fn to_flat(&self) -> Vec<f32> {
        self.data().to_vec()
    }

    fn from_flat(like: &Self, data: Vec<f32>) -> Self {
        Tensor::from_vec(data, like.shape())
    }
}

/// One relaxation step with the FAS right-hand side applied, writing the
/// updated point `local[idx + 1]` in place — the single place the
/// g-indexing convention (`g[point_written]`, i.e. `lo+idx+1`) lives;
/// every F- and C-point update in all executors routes through it, so the
/// bitwise-parity invariant cannot silently fork.
fn relax_point_into<T, F>(lo: usize, idx: usize, local: &mut [T], g: Option<&[T]>, step: &F)
where
    T: RelaxState,
    F: Fn(usize, &T, &mut T),
{
    let (head, tail) = local.split_at_mut(idx + 1);
    step(lo + idx, &head[idx], &mut tail[0]);
    if let Some(g) = g {
        tail[0].add_in_place(&g[lo + idx + 1]);
    }
}

/// One F-point sweep over a slab's local copy: for every owned chunk,
/// re-propagate its F-points from the chunk's leading C-point (`lo` is
/// the level index of `local[0]`). Shared by all executors.
fn f_sweep_local<T, F>(
    local: &mut [T],
    lo: usize,
    n_chunks: usize,
    cf: usize,
    g: Option<&[T]>,
    step: &F,
) where
    T: RelaxState,
    F: Fn(usize, &T, &mut T),
{
    for c in 0..n_chunks {
        for i in 0..cf - 1 {
            relax_point_into(lo, c * cf + i, local, g, step);
        }
    }
}

/// The full FCF slab body (F-relax, C-relax, halo exchange, second
/// F-relax) for the slab covering chunks [c0, c1). `active` is the number
/// of ranks participating in this sweep (halo neighbours are gated on it,
/// not on the fabric size, so a pool larger than the sweep still runs the
/// exact scoped schedule).
#[allow(clippy::too_many_arguments)]
fn fcf_slab<T, F>(
    w_all: &[T],
    g: Option<&[T]>,
    cf: usize,
    c0: usize,
    c1: usize,
    active: usize,
    ep: &mut Endpoint,
    step: &F,
) -> (usize, Vec<T>)
where
    T: RelaxState,
    F: Fn(usize, &T, &mut T),
{
    let rank = ep.rank;
    // local copy of this slab's points: chunk c covers fine indices
    // [c*cf, (c+1)*cf]; we own points (c0*cf, c1*cf] plus read access to
    // the C-point at c0*cf.
    let lo = c0 * cf;
    let hi = c1 * cf;
    let mut local: Vec<T> = w_all[lo..=hi].to_vec();
    // F-relaxation: every chunk independently (parallel phase)
    f_sweep_local(&mut local, lo, c1 - c0, cf, g, step);
    // C-relaxation: the final step of each chunk; the first C-point of the
    // *next* slab is produced here, so send the boundary value right after
    // computing it.
    for c in 0..(c1 - c0) {
        relax_point_into(lo, (c + 1) * cf - 1, &mut local, g, step);
    }
    // second F-relax needs the incoming C-point from the left neighbour's
    // C-relax (FCF); exchange halos:
    if rank + 1 < active {
        let boundary = local.last().unwrap().to_flat();
        ep.send(rank + 1, HALO_TAG, boundary);
    }
    if rank > 0 {
        let data = ep.recv(rank - 1, HALO_TAG);
        assert_eq!(
            data.len(),
            local[0].flat_len(),
            "malformed halo message (left-neighbour worker panicked?)"
        );
        local[0] = T::from_flat(&local[0], data);
    }
    // final F-relaxation with the fresh left C-point
    f_sweep_local(&mut local, lo, c1 - c0, cf, g, step);
    (lo, local)
}

/// The F-only slab body (no communication at all).
fn f_slab<T, F>(
    w_all: &[T],
    g: Option<&[T]>,
    cf: usize,
    c0: usize,
    c1: usize,
    step: &F,
) -> (usize, Vec<T>)
where
    T: RelaxState,
    F: Fn(usize, &T, &mut T),
{
    let lo = c0 * cf;
    let hi = c1 * cf;
    let mut local: Vec<T> = w_all[lo..=hi].to_vec();
    f_sweep_local(&mut local, lo, c1 - c0, cf, g, step);
    (lo, local)
}

/// Stitch per-slab worker results back into the full point array.
fn stitch<T>(mut out: Vec<T>, mut results: Vec<(usize, Vec<T>)>) -> Vec<T> {
    results.sort_by_key(|(lo, _)| *lo);
    for (lo, local) in results {
        for (i, v) in local.into_iter().enumerate() {
            out[lo + i] = v;
        }
    }
    out
}

/// One F-relax + C-relax + F-relax (FCF) sweep over `n` fine steps executed
/// by `workers` scoped threads. `w` holds states at points 0..=n (C-points
/// must be valid on entry; F-points are overwritten). `g`, when present, is
/// the FAS right-hand side added after every step (index-aligned with `w`).
/// Returns the updated states — bitwise identical to the serial schedule.
pub fn parallel_fc_relax<T, F>(
    w: Vec<T>,
    g: Option<&[T]>,
    cf: usize,
    workers: usize,
    step: F,
) -> Vec<T>
where
    T: RelaxState,
    F: Fn(usize, &T, &mut T) + Sync,
{
    let n = w.len() - 1;
    assert_eq!(n % cf, 0, "n must be a multiple of cf");
    let chunks = n / cf;
    let workers = workers.min(chunks).max(1);
    let slabs = slab_partition(chunks, workers);
    let mut fabric = Fabric::new(workers);
    let endpoints = fabric.take_all();
    let step_ref = &step;
    let w_ref = &w;

    let results: Vec<(usize, Vec<T>)> = thread::scope(|s| {
        let handles: Vec<_> = endpoints
            .into_iter()
            .zip(slabs.iter().cloned())
            .map(|(mut ep, (c0, c1))| {
                s.spawn(move || fcf_slab(w_ref, g, cf, c0, c1, workers, &mut ep, step_ref))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    stitch(w, results)
}

/// One F-relaxation sweep over `workers` scoped threads: every chunk
/// re-propagates its F-points from its (read-only) leading C-point — no
/// communication at all, the embarrassingly-parallel phase of paper
/// Fig. 2. `g` as in [`parallel_fc_relax`].
pub fn parallel_f_relax<T, F>(
    w: Vec<T>,
    g: Option<&[T]>,
    cf: usize,
    workers: usize,
    step: F,
) -> Vec<T>
where
    T: RelaxState,
    F: Fn(usize, &T, &mut T) + Sync,
{
    let n = w.len() - 1;
    assert_eq!(n % cf, 0, "n must be a multiple of cf");
    let chunks = n / cf;
    let workers = workers.min(chunks).max(1);
    let slabs = slab_partition(chunks, workers);
    let step_ref = &step;
    let w_ref = &w;

    let results: Vec<(usize, Vec<T>)> = thread::scope(|s| {
        let handles: Vec<_> = slabs
            .iter()
            .cloned()
            .map(|(c0, c1)| s.spawn(move || f_slab(w_ref, g, cf, c0, c1, step_ref)))
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    stitch(w, results)
}

/// [`parallel_fc_relax`] dispatched onto a persistent [`WorkerPool`]
/// instead of per-sweep scoped spawns. The slab partition uses
/// `min(pool.size(), chunks)` active ranks, so a pool of size k produces
/// bitwise the same states as `parallel_fc_relax(.., workers = k, ..)`.
///
/// Panic containment: if a slab body panics (e.g. a shape assert inside
/// Φ), its job sends a zero-length *poison* halo so the right neighbour —
/// possibly blocked on `recv` — fails its halo length check instead of
/// deadlocking the sweep barrier; the chain unwinds rank by rank, the
/// barrier completes, and the original panic is re-raised here. A sweep
/// that panics **poisons the pool** (stale halo messages may remain
/// queued); `WorkerPool::run_scoped` refuses poisoned pools and
/// `ThreadedMgrit` rebuilds its pool automatically.
pub fn pool_fc_relax<T, F>(
    pool: &WorkerPool,
    w: Vec<T>,
    g: Option<&[T]>,
    cf: usize,
    step: F,
) -> Vec<T>
where
    T: RelaxState,
    F: Fn(usize, &T, &mut T) + Sync,
{
    let n = w.len() - 1;
    assert_eq!(n % cf, 0, "n must be a multiple of cf");
    let chunks = n / cf;
    let active = pool.size().min(chunks).max(1);
    let slabs = slab_partition(chunks, active);
    let step_ref = &step;
    let w_ref = &w;
    let results = pool_dispatch(pool, &slabs, true, |c0: usize, c1: usize, ep: &mut Endpoint| {
        fcf_slab(w_ref, g, cf, c0, c1, active, ep, step_ref)
    });
    stitch(w, results)
}

/// Shared dispatch scaffold for the pooled executors: one job per slab,
/// result/panic channels, and the completion barrier. On any panic the
/// pool is **poisoned** (stale halo messages may remain queued in the
/// fabric) and the first payload is re-raised after the barrier; with
/// `poison_halo` a panicking rank first sends a zero-length halo so a
/// blocked right neighbour fails its length check instead of deadlocking
/// (the chain unwinds rank by rank).
fn pool_dispatch<T, B>(
    pool: &WorkerPool,
    slabs: &[(usize, usize)],
    poison_halo: bool,
    body: B,
) -> Vec<(usize, Vec<T>)>
where
    T: RelaxState,
    B: Fn(usize, usize, &mut Endpoint) -> (usize, Vec<T>) + Sync,
{
    let active = slabs.len();
    let body_ref = &body;
    let (res_tx, res_rx) = channel::<(usize, Vec<T>)>();
    let (err_tx, err_rx) = channel::<Box<dyn std::any::Any + Send>>();
    let jobs: Vec<Box<dyn FnOnce(&mut Endpoint) + Send + '_>> = slabs
        .iter()
        .cloned()
        .map(|(c0, c1)| {
            let tx = res_tx.clone();
            let etx = err_tx.clone();
            Box::new(move |ep: &mut Endpoint| {
                match catch_unwind(AssertUnwindSafe(|| body_ref(c0, c1, ep))) {
                    Ok(r) => {
                        let _ = tx.send(r);
                    }
                    Err(payload) => {
                        // zero-length poison halo: real states are never
                        // empty, so the neighbour's length check fires
                        if poison_halo && ep.rank + 1 < active {
                            ep.send(ep.rank + 1, HALO_TAG, Vec::new());
                        }
                        let _ = etx.send(payload);
                    }
                }
            }) as Box<dyn FnOnce(&mut Endpoint) + Send + '_>
        })
        .collect();
    drop(res_tx);
    drop(err_tx);
    pool.run_scoped(jobs);

    if let Ok(payload) = err_rx.try_recv() {
        pool.poison();
        resume_unwind(payload);
    }
    let results: Vec<(usize, Vec<T>)> = res_rx.try_iter().collect();
    if results.len() != active {
        pool.poison();
        panic!("a pool worker died mid-sweep");
    }
    results
}

/// [`parallel_f_relax`] on a persistent [`WorkerPool`]. F-only sweeps have
/// no halo waits, so a panicking slab simply surfaces its payload here
/// after the barrier (no poisoning needed).
pub fn pool_f_relax<T, F>(
    pool: &WorkerPool,
    w: Vec<T>,
    g: Option<&[T]>,
    cf: usize,
    step: F,
) -> Vec<T>
where
    T: RelaxState,
    F: Fn(usize, &T, &mut T) + Sync,
{
    let n = w.len() - 1;
    assert_eq!(n % cf, 0, "n must be a multiple of cf");
    let chunks = n / cf;
    let active = pool.size().min(chunks).max(1);
    let slabs = slab_partition(chunks, active);
    let step_ref = &step;
    let w_ref = &w;
    let results =
        pool_dispatch(pool, &slabs, false, |c0: usize, c1: usize, _ep: &mut Endpoint| {
            f_slab(w_ref, g, cf, c0, c1, step_ref)
        });
    stitch(w, results)
}

/// Single-threaded FCF sweep with the same update order (oracle for tests).
pub fn serial_fc_relax<F>(mut w: Vec<Vec<f32>>, cf: usize, step: F) -> Vec<Vec<f32>>
where
    F: Fn(usize, &[f32]) -> Vec<f32>,
{
    let n = w.len() - 1;
    let chunks = n / cf;
    for c in 0..chunks {
        for i in 0..cf - 1 {
            let idx = c * cf + i;
            w[idx + 1] = step(idx, &w[idx]);
        }
    }
    for c in 0..chunks {
        let idx = (c + 1) * cf - 1;
        w[idx + 1] = step(idx, &w[idx]);
    }
    for c in 0..chunks {
        for i in 0..cf - 1 {
            let idx = c * cf + i;
            w[idx + 1] = step(idx, &w[idx]);
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn affine_step(layer: usize, z: &[f32]) -> Vec<f32> {
        // z' = 0.95 z + c(layer): nonlinear enough to catch ordering bugs
        z.iter()
            .enumerate()
            .map(|(i, &v)| 0.95 * v + 0.01 * (layer as f32 + 1.0) + 0.001 * (i as f32) * v.tanh())
            .collect()
    }

    #[allow(clippy::ptr_arg)]
    fn vec_step(layer: usize, z: &Vec<f32>, out: &mut Vec<f32>) {
        *out = affine_step(layer, z);
    }

    #[test]
    fn parallel_matches_serial_exactly() {
        for (n, cf, workers) in [(16, 4, 2), (16, 4, 4), (24, 3, 3), (32, 2, 5), (8, 8, 1)] {
            let mut rng = Rng::new(n as u64);
            let w: Vec<Vec<f32>> = (0..=n).map(|_| rng.normal_vec(6, 1.0)).collect();
            let serial = serial_fc_relax(w.clone(), cf, affine_step);
            let parallel = parallel_fc_relax(w, None, cf, workers, vec_step);
            for (a, b) in parallel.iter().zip(&serial) {
                assert_eq!(a, b, "n={} cf={} workers={}", n, cf, workers);
            }
        }
    }

    #[test]
    fn pool_matches_scoped_spawns_bitwise() {
        // the persistent-pool acceptance property: for 1–4 workers, the
        // pool executor reproduces the scoped-spawn executor bit for bit,
        // FCF and F-only, with and without a FAS right-hand side — across
        // repeated sweeps through the *same* parked threads.
        for workers in 1usize..=4 {
            let pool = WorkerPool::new(workers);
            for (n, cf) in [(16usize, 4usize), (24, 3), (32, 2), (8, 8)] {
                let mut rng = Rng::new((workers * 100 + n) as u64);
                let w: Vec<Vec<f32>> = (0..=n).map(|_| rng.normal_vec(5, 1.0)).collect();
                let g: Vec<Vec<f32>> = (0..=n).map(|_| rng.normal_vec(5, 0.1)).collect();
                for round in 0..2 {
                    let g_opt = if round == 0 { None } else { Some(&g[..]) };
                    let scoped = parallel_fc_relax(w.clone(), g_opt, cf, workers, vec_step);
                    let pooled = pool_fc_relax(&pool, w.clone(), g_opt, cf, vec_step);
                    for (a, b) in pooled.iter().zip(&scoped) {
                        assert_eq!(a, b, "fcf n={} cf={} workers={}", n, cf, workers);
                    }
                    let scoped = parallel_f_relax(w.clone(), g_opt, cf, workers, vec_step);
                    let pooled = pool_f_relax(&pool, w.clone(), g_opt, cf, vec_step);
                    for (a, b) in pooled.iter().zip(&scoped) {
                        assert_eq!(a, b, "f n={} cf={} workers={}", n, cf, workers);
                    }
                }
            }
        }
    }

    #[test]
    fn pooled_sweep_panics_loudly_instead_of_deadlocking() {
        // a panicking Φ inside a pooled FCF sweep must surface the panic
        // through pool_fc_relax (poison-halo chain), not hang the barrier
        // — and the pool's threads must still shut down cleanly on drop
        use std::panic::{catch_unwind as cu, AssertUnwindSafe as Aus};
        let pool = WorkerPool::new(2);
        let mut rng = Rng::new(13);
        let w: Vec<Vec<f32>> = (0..=8).map(|_| rng.normal_vec(3, 1.0)).collect();
        let boom = |l: usize, z: &Vec<f32>, out: &mut Vec<f32>| {
            assert_ne!(l, 1, "boom");
            *out = affine_step(l, z);
        };
        let result = cu(Aus(|| pool_fc_relax(&pool, w.clone(), None, 2, boom)));
        assert!(result.is_err(), "worker panic must propagate to the caller");
        // the failed sweep poisons the pool (stale halos may be queued);
        // further sweeps refuse loudly instead of computing on stale state
        assert!(pool.is_poisoned());
        let retry = cu(Aus(|| pool_fc_relax(&pool, w, None, 2, vec_step)));
        assert!(retry.is_err(), "poisoned pool must refuse further sweeps");
    }

    #[test]
    fn oversized_pool_is_clamped_to_chunks() {
        // 2 chunks but a 6-worker pool: only ranks 0..2 participate and
        // the result still matches the serial schedule
        let pool = WorkerPool::new(6);
        let mut rng = Rng::new(77);
        let w: Vec<Vec<f32>> = (0..=8).map(|_| rng.normal_vec(4, 1.0)).collect();
        let serial = serial_fc_relax(w.clone(), 4, affine_step);
        let pooled = pool_fc_relax(&pool, w, None, 4, vec_step);
        for (a, b) in pooled.iter().zip(&serial) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn more_workers_than_chunks_is_clamped() {
        let mut rng = Rng::new(9);
        let w: Vec<Vec<f32>> = (0..=8).map(|_| rng.normal_vec(4, 1.0)).collect();
        let serial = serial_fc_relax(w.clone(), 4, affine_step);
        let parallel = parallel_fc_relax(w, None, 4, 16, vec_step); // 2 chunks only
        for (a, b) in parallel.iter().zip(&serial) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn rhs_aware_sweep_matches_serial_with_rhs() {
        // FAS form: every step adds g — compare against a hand-rolled
        // serial FCF sweep with the same adds.
        let (n, cf) = (16usize, 4usize);
        let mut rng = Rng::new(3);
        let w: Vec<Vec<f32>> = (0..=n).map(|_| rng.normal_vec(5, 1.0)).collect();
        let g: Vec<Vec<f32>> = (0..=n).map(|_| rng.normal_vec(5, 0.1)).collect();
        let mut serial = w.clone();
        let chunks = n / cf;
        let sweep_f = |w: &mut Vec<Vec<f32>>| {
            for c in 0..chunks {
                for i in 0..cf - 1 {
                    let idx = c * cf + i;
                    let mut next = affine_step(idx, &w[idx]);
                    next.add_in_place(&g[idx + 1]);
                    w[idx + 1] = next;
                }
            }
        };
        sweep_f(&mut serial);
        for c in 0..chunks {
            let idx = (c + 1) * cf - 1;
            let mut next = affine_step(idx, &serial[idx]);
            next.add_in_place(&g[idx + 1]);
            serial[idx + 1] = next;
        }
        sweep_f(&mut serial);
        for workers in [1usize, 2, 4] {
            let parallel = parallel_fc_relax(w.clone(), Some(&g[..]), cf, workers, vec_step);
            for (a, b) in parallel.iter().zip(&serial) {
                assert_eq!(a, b, "workers={}", workers);
            }
        }
    }

    #[test]
    fn f_only_sweep_touches_only_f_points() {
        let (n, cf) = (12usize, 3usize);
        let mut rng = Rng::new(4);
        let w: Vec<Vec<f32>> = (0..=n).map(|_| rng.normal_vec(4, 1.0)).collect();
        let out = parallel_f_relax(w.clone(), None, cf, 3, vec_step);
        for i in (0..=n).step_by(cf) {
            assert_eq!(out[i], w[i], "C-point {} must be untouched", i);
        }
        // F-points follow the chain from their chunk's C-point
        for c in 0..n / cf {
            let mut cur = w[c * cf].clone();
            for i in 0..cf - 1 {
                cur = affine_step(c * cf + i, &cur);
                assert_eq!(out[c * cf + i + 1], cur);
            }
        }
    }

    #[test]
    fn tensor_states_round_trip_the_fabric() {
        // Tensor-typed relaxation (the real MGRIT hot-loop shape) matches
        // the Vec<f32> executor bit for bit — scoped and pooled.
        let (n, cf, workers) = (16usize, 4usize, 4usize);
        let mut rng = Rng::new(5);
        let w_vec: Vec<Vec<f32>> = (0..=n).map(|_| rng.normal_vec(6, 1.0)).collect();
        let w_t: Vec<Tensor> =
            w_vec.iter().map(|v| Tensor::from_vec(v.clone(), &[2, 3])).collect();
        let t_step = |l: usize, z: &Tensor, out: &mut Tensor| {
            *out = Tensor::from_vec(affine_step(l, z.data()), &[2, 3]);
        };
        let out_vec = parallel_fc_relax(w_vec, None, cf, workers, vec_step);
        let out_t = parallel_fc_relax(w_t.clone(), None, cf, workers, t_step);
        for (a, b) in out_t.iter().zip(&out_vec) {
            assert_eq!(a.data(), b.as_slice());
        }
        let pool = WorkerPool::new(workers);
        let out_p = pool_fc_relax(&pool, w_t, None, cf, t_step);
        for (a, b) in out_p.iter().zip(&out_vec) {
            assert_eq!(a.data(), b.as_slice());
        }
    }
}
