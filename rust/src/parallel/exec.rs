//! Real multi-worker execution of the MGRIT relaxation phase.
//!
//! Demonstrates (and tests) that the layer-slab decomposition + channel
//! fabric compute *bitwise the same result* as the single-threaded engine:
//! each worker owns a contiguous slab of chunks, applies F-relaxation
//! locally (no communication — the parallel phase of paper Fig. 2), then
//! C-relaxation with a halo exchange of the slab-boundary state.
//!
//! The step function is a plain `Fn(layer, &[f32]) -> Vec<f32> + Sync`
//! closure so any thread-safe Φ can plug in; on this 1-core machine the
//! win is correctness evidence, not wall-clock (see `simulator` for the
//! performance model).

use std::thread;

use super::comm::Fabric;
use super::topology::slab_partition;

/// One F-relax + C-relax sweep over `n` fine steps executed by `workers`
/// threads. `w` holds states at points 0..=n (C-points must be valid on
/// entry; F-points are overwritten). Returns the updated states.
pub fn parallel_fc_relax<F>(w: Vec<Vec<f32>>, cf: usize, workers: usize, step: F) -> Vec<Vec<f32>>
where
    F: Fn(usize, &[f32]) -> Vec<f32> + Sync,
{
    let n = w.len() - 1;
    assert_eq!(n % cf, 0, "n must be a multiple of cf");
    let chunks = n / cf;
    let workers = workers.min(chunks).max(1);
    let slabs = slab_partition(chunks, workers);
    let mut fabric = Fabric::new(workers);
    let endpoints = fabric.take_all();
    let step_ref = &step;
    let w_ref = &w;

    let mut results: Vec<(usize, Vec<Vec<f32>>)> = thread::scope(|s| {
        let handles: Vec<_> = endpoints
            .into_iter()
            .zip(slabs.iter().cloned())
            .map(|(mut ep, (c0, c1))| {
                s.spawn(move || {
                    let rank = ep.rank;
                    // local copy of this slab's points: chunk c covers fine
                    // indices [c*cf, (c+1)*cf]; we own points (c0*cf, c1*cf]
                    // plus read access to the C-point at c0*cf.
                    let lo = c0 * cf;
                    let hi = c1 * cf;
                    let mut local: Vec<Vec<f32>> = w_ref[lo..=hi].to_vec();
                    // F-relaxation: every chunk independently (parallel phase)
                    for c in 0..(c1 - c0) {
                        for i in 0..cf - 1 {
                            let idx = c * cf + i;
                            local[idx + 1] = step_ref(lo + idx, &local[idx]);
                        }
                    }
                    // C-relaxation: the final step of each chunk; the first
                    // C-point of the *next* slab is produced here, so send
                    // the boundary value right after computing it.
                    for c in 0..(c1 - c0) {
                        let idx = (c + 1) * cf - 1;
                        local[idx + 1] = step_ref(lo + idx, &local[idx]);
                    }
                    // second F-relax needs the incoming C-point from the left
                    // neighbour's C-relax (FCF); exchange halos:
                    if rank + 1 < ep.n_ranks {
                        let boundary = local.last().unwrap().clone();
                        ep.send(rank + 1, 42, boundary);
                    }
                    if rank > 0 {
                        local[0] = ep.recv(rank - 1, 42);
                    }
                    // final F-relaxation with the fresh left C-point
                    for c in 0..(c1 - c0) {
                        for i in 0..cf - 1 {
                            let idx = c * cf + i;
                            local[idx + 1] = step_ref(lo + idx, &local[idx]);
                        }
                    }
                    (lo, local)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    // stitch slabs back together
    let mut out = w;
    results.sort_by_key(|(lo, _)| *lo);
    for (lo, local) in results {
        for (i, v) in local.into_iter().enumerate() {
            out[lo + i] = v;
        }
    }
    out
}

/// Single-threaded FCF sweep with the same update order (oracle for tests).
pub fn serial_fc_relax<F>(mut w: Vec<Vec<f32>>, cf: usize, step: F) -> Vec<Vec<f32>>
where
    F: Fn(usize, &[f32]) -> Vec<f32>,
{
    let n = w.len() - 1;
    let chunks = n / cf;
    for c in 0..chunks {
        for i in 0..cf - 1 {
            let idx = c * cf + i;
            w[idx + 1] = step(idx, &w[idx]);
        }
    }
    for c in 0..chunks {
        let idx = (c + 1) * cf - 1;
        w[idx + 1] = step(idx, &w[idx]);
    }
    for c in 0..chunks {
        for i in 0..cf - 1 {
            let idx = c * cf + i;
            w[idx + 1] = step(idx, &w[idx]);
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn affine_step(layer: usize, z: &[f32]) -> Vec<f32> {
        // z' = 0.95 z + c(layer): nonlinear enough to catch ordering bugs
        z.iter()
            .enumerate()
            .map(|(i, &v)| 0.95 * v + 0.01 * (layer as f32 + 1.0) + 0.001 * (i as f32) * v.tanh())
            .collect()
    }

    #[test]
    fn parallel_matches_serial_exactly() {
        for (n, cf, workers) in [(16, 4, 2), (16, 4, 4), (24, 3, 3), (32, 2, 5), (8, 8, 1)] {
            let mut rng = Rng::new(n as u64);
            let w: Vec<Vec<f32>> = (0..=n).map(|_| rng.normal_vec(6, 1.0)).collect();
            let serial = serial_fc_relax(w.clone(), cf, affine_step);
            let parallel = parallel_fc_relax(w, cf, workers, affine_step);
            for (a, b) in parallel.iter().zip(&serial) {
                assert_eq!(a, b, "n={} cf={} workers={}", n, cf, workers);
            }
        }
    }

    #[test]
    fn more_workers_than_chunks_is_clamped() {
        let mut rng = Rng::new(9);
        let w: Vec<Vec<f32>> = (0..=8).map(|_| rng.normal_vec(4, 1.0)).collect();
        let serial = serial_fc_relax(w.clone(), 4, affine_step);
        let parallel = parallel_fc_relax(w, 4, 16, affine_step); // 2 chunks only
        for (a, b) in parallel.iter().zip(&serial) {
            assert_eq!(a, b);
        }
    }
}
