//! Real multi-worker execution of the MGRIT relaxation phase.
//!
//! Each worker owns a contiguous slab of chunks, applies F-relaxation
//! locally (no communication — the parallel phase of paper Fig. 2), then
//! C-relaxation with a halo exchange of the slab-boundary state over the
//! channel [`Fabric`]. The update schedule is value-for-value identical to
//! the single-threaded engine, so threaded solves are *bitwise* equal to
//! serial ones.
//!
//! v2: the executors are generic over a [`RelaxState`] (plain `Vec<f32>`
//! slabs in the standalone tests, [`Tensor`] states on the real MGRIT hot
//! loop) and accept the FAS right-hand side G so they can run *inside*
//! `mgrit::core`'s V-cycle — this is the execution layer behind the
//! `ThreadedMgrit` backend, not just correctness evidence.

use std::thread;

use super::comm::Fabric;
use super::topology::slab_partition;
use crate::tensor::Tensor;

/// A state vector the relaxation executors can carry across threads and
/// through the channel fabric.
pub trait RelaxState: Clone + Send + Sync {
    /// x += y elementwise (the RHS update of one relaxation step; must use
    /// the same arithmetic as the serial engine for bitwise parity).
    fn add_in_place(&mut self, other: &Self);

    /// Flatten for a fabric message.
    fn to_flat(&self) -> Vec<f32>;

    /// Rebuild from a fabric message (`like` supplies shape metadata).
    fn from_flat(like: &Self, data: Vec<f32>) -> Self;
}

impl RelaxState for Vec<f32> {
    fn add_in_place(&mut self, other: &Self) {
        for (a, b) in self.iter_mut().zip(other) {
            *a += *b;
        }
    }

    fn to_flat(&self) -> Vec<f32> {
        self.clone()
    }

    fn from_flat(_like: &Self, data: Vec<f32>) -> Self {
        data
    }
}

impl RelaxState for Tensor {
    fn add_in_place(&mut self, other: &Self) {
        self.axpy(1.0, other);
    }

    fn to_flat(&self) -> Vec<f32> {
        self.data().to_vec()
    }

    fn from_flat(like: &Self, data: Vec<f32>) -> Self {
        Tensor::from_vec(data, like.shape())
    }
}

/// One relaxation step with the FAS right-hand side applied — the single
/// place the g-indexing convention (`g[point_written]`, i.e. `lo+idx+1`)
/// lives; every F- and C-point update in both executors routes through
/// it, so the bitwise-parity invariant cannot silently fork.
fn relax_point<T, F>(lo: usize, idx: usize, z: &T, g: Option<&[T]>, step: &F) -> T
where
    T: RelaxState,
    F: Fn(usize, &T) -> T,
{
    let mut next = step(lo + idx, z);
    if let Some(g) = g {
        next.add_in_place(&g[lo + idx + 1]);
    }
    next
}

/// One F-point sweep over a slab's local copy: for every owned chunk,
/// re-propagate its F-points from the chunk's leading C-point (`lo` is
/// the level index of `local[0]`). Shared by both executors.
fn f_sweep_local<T, F>(
    local: &mut [T],
    lo: usize,
    n_chunks: usize,
    cf: usize,
    g: Option<&[T]>,
    step: &F,
) where
    T: RelaxState,
    F: Fn(usize, &T) -> T,
{
    for c in 0..n_chunks {
        for i in 0..cf - 1 {
            let idx = c * cf + i;
            local[idx + 1] = relax_point(lo, idx, &local[idx], g, step);
        }
    }
}

/// Stitch per-slab worker results back into the full point array.
fn stitch<T>(mut out: Vec<T>, mut results: Vec<(usize, Vec<T>)>) -> Vec<T> {
    results.sort_by_key(|(lo, _)| *lo);
    for (lo, local) in results {
        for (i, v) in local.into_iter().enumerate() {
            out[lo + i] = v;
        }
    }
    out
}

/// One F-relax + C-relax + F-relax (FCF) sweep over `n` fine steps executed
/// by `workers` threads. `w` holds states at points 0..=n (C-points must be
/// valid on entry; F-points are overwritten). `g`, when present, is the FAS
/// right-hand side added after every step (index-aligned with `w`).
/// Returns the updated states — bitwise identical to the serial schedule.
pub fn parallel_fc_relax<T, F>(
    w: Vec<T>,
    g: Option<&[T]>,
    cf: usize,
    workers: usize,
    step: F,
) -> Vec<T>
where
    T: RelaxState,
    F: Fn(usize, &T) -> T + Sync,
{
    let n = w.len() - 1;
    assert_eq!(n % cf, 0, "n must be a multiple of cf");
    let chunks = n / cf;
    let workers = workers.min(chunks).max(1);
    let slabs = slab_partition(chunks, workers);
    let mut fabric = Fabric::new(workers);
    let endpoints = fabric.take_all();
    let step_ref = &step;
    let w_ref = &w;

    let results: Vec<(usize, Vec<T>)> = thread::scope(|s| {
        let handles: Vec<_> = endpoints
            .into_iter()
            .zip(slabs.iter().cloned())
            .map(|(mut ep, (c0, c1))| {
                s.spawn(move || {
                    let rank = ep.rank;
                    // local copy of this slab's points: chunk c covers fine
                    // indices [c*cf, (c+1)*cf]; we own points (c0*cf, c1*cf]
                    // plus read access to the C-point at c0*cf.
                    let lo = c0 * cf;
                    let hi = c1 * cf;
                    let mut local: Vec<T> = w_ref[lo..=hi].to_vec();
                    // F-relaxation: every chunk independently (parallel phase)
                    f_sweep_local(&mut local, lo, c1 - c0, cf, g, step_ref);
                    // C-relaxation: the final step of each chunk; the first
                    // C-point of the *next* slab is produced here, so send
                    // the boundary value right after computing it.
                    for c in 0..(c1 - c0) {
                        let idx = (c + 1) * cf - 1;
                        local[idx + 1] = relax_point(lo, idx, &local[idx], g, step_ref);
                    }
                    // second F-relax needs the incoming C-point from the left
                    // neighbour's C-relax (FCF); exchange halos:
                    if rank + 1 < ep.n_ranks {
                        let boundary = local.last().unwrap().to_flat();
                        ep.send(rank + 1, 42, boundary);
                    }
                    if rank > 0 {
                        let data = ep.recv(rank - 1, 42);
                        local[0] = T::from_flat(&local[0], data);
                    }
                    // final F-relaxation with the fresh left C-point
                    f_sweep_local(&mut local, lo, c1 - c0, cf, g, step_ref);
                    (lo, local)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    stitch(w, results)
}

/// One F-relaxation sweep over `workers` threads: every chunk re-propagates
/// its F-points from its (read-only) leading C-point — no communication at
/// all, the embarrassingly-parallel phase of paper Fig. 2. `g` as in
/// [`parallel_fc_relax`].
pub fn parallel_f_relax<T, F>(
    w: Vec<T>,
    g: Option<&[T]>,
    cf: usize,
    workers: usize,
    step: F,
) -> Vec<T>
where
    T: RelaxState,
    F: Fn(usize, &T) -> T + Sync,
{
    let n = w.len() - 1;
    assert_eq!(n % cf, 0, "n must be a multiple of cf");
    let chunks = n / cf;
    let workers = workers.min(chunks).max(1);
    let slabs = slab_partition(chunks, workers);
    let step_ref = &step;
    let w_ref = &w;

    let results: Vec<(usize, Vec<T>)> = thread::scope(|s| {
        let handles: Vec<_> = slabs
            .iter()
            .cloned()
            .map(|(c0, c1)| {
                s.spawn(move || {
                    let lo = c0 * cf;
                    let hi = c1 * cf;
                    let mut local: Vec<T> = w_ref[lo..=hi].to_vec();
                    f_sweep_local(&mut local, lo, c1 - c0, cf, g, step_ref);
                    (lo, local)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    stitch(w, results)
}

/// Single-threaded FCF sweep with the same update order (oracle for tests).
pub fn serial_fc_relax<F>(mut w: Vec<Vec<f32>>, cf: usize, step: F) -> Vec<Vec<f32>>
where
    F: Fn(usize, &[f32]) -> Vec<f32>,
{
    let n = w.len() - 1;
    let chunks = n / cf;
    for c in 0..chunks {
        for i in 0..cf - 1 {
            let idx = c * cf + i;
            w[idx + 1] = step(idx, &w[idx]);
        }
    }
    for c in 0..chunks {
        let idx = (c + 1) * cf - 1;
        w[idx + 1] = step(idx, &w[idx]);
    }
    for c in 0..chunks {
        for i in 0..cf - 1 {
            let idx = c * cf + i;
            w[idx + 1] = step(idx, &w[idx]);
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn affine_step(layer: usize, z: &[f32]) -> Vec<f32> {
        // z' = 0.95 z + c(layer): nonlinear enough to catch ordering bugs
        z.iter()
            .enumerate()
            .map(|(i, &v)| 0.95 * v + 0.01 * (layer as f32 + 1.0) + 0.001 * (i as f32) * v.tanh())
            .collect()
    }

    fn vec_step(layer: usize, z: &Vec<f32>) -> Vec<f32> {
        affine_step(layer, z)
    }

    #[test]
    fn parallel_matches_serial_exactly() {
        for (n, cf, workers) in [(16, 4, 2), (16, 4, 4), (24, 3, 3), (32, 2, 5), (8, 8, 1)] {
            let mut rng = Rng::new(n as u64);
            let w: Vec<Vec<f32>> = (0..=n).map(|_| rng.normal_vec(6, 1.0)).collect();
            let serial = serial_fc_relax(w.clone(), cf, affine_step);
            let parallel = parallel_fc_relax(w, None, cf, workers, vec_step);
            for (a, b) in parallel.iter().zip(&serial) {
                assert_eq!(a, b, "n={} cf={} workers={}", n, cf, workers);
            }
        }
    }

    #[test]
    fn more_workers_than_chunks_is_clamped() {
        let mut rng = Rng::new(9);
        let w: Vec<Vec<f32>> = (0..=8).map(|_| rng.normal_vec(4, 1.0)).collect();
        let serial = serial_fc_relax(w.clone(), 4, affine_step);
        let parallel = parallel_fc_relax(w, None, 4, 16, vec_step); // 2 chunks only
        for (a, b) in parallel.iter().zip(&serial) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn rhs_aware_sweep_matches_serial_with_rhs() {
        // FAS form: every step adds g — compare against a hand-rolled
        // serial FCF sweep with the same adds.
        let (n, cf) = (16usize, 4usize);
        let mut rng = Rng::new(3);
        let w: Vec<Vec<f32>> = (0..=n).map(|_| rng.normal_vec(5, 1.0)).collect();
        let g: Vec<Vec<f32>> = (0..=n).map(|_| rng.normal_vec(5, 0.1)).collect();
        let mut serial = w.clone();
        let chunks = n / cf;
        let sweep_f = |w: &mut Vec<Vec<f32>>| {
            for c in 0..chunks {
                for i in 0..cf - 1 {
                    let idx = c * cf + i;
                    let mut next = affine_step(idx, &w[idx]);
                    next.add_in_place(&g[idx + 1]);
                    w[idx + 1] = next;
                }
            }
        };
        sweep_f(&mut serial);
        for c in 0..chunks {
            let idx = (c + 1) * cf - 1;
            let mut next = affine_step(idx, &serial[idx]);
            next.add_in_place(&g[idx + 1]);
            serial[idx + 1] = next;
        }
        sweep_f(&mut serial);
        for workers in [1usize, 2, 4] {
            let parallel = parallel_fc_relax(w.clone(), Some(&g[..]), cf, workers, vec_step);
            for (a, b) in parallel.iter().zip(&serial) {
                assert_eq!(a, b, "workers={}", workers);
            }
        }
    }

    #[test]
    fn f_only_sweep_touches_only_f_points() {
        let (n, cf) = (12usize, 3usize);
        let mut rng = Rng::new(4);
        let w: Vec<Vec<f32>> = (0..=n).map(|_| rng.normal_vec(4, 1.0)).collect();
        let out = parallel_f_relax(w.clone(), None, cf, 3, vec_step);
        for i in (0..=n).step_by(cf) {
            assert_eq!(out[i], w[i], "C-point {} must be untouched", i);
        }
        // F-points follow the chain from their chunk's C-point
        for c in 0..n / cf {
            let mut cur = w[c * cf].clone();
            for i in 0..cf - 1 {
                cur = affine_step(c * cf + i, &cur);
                assert_eq!(out[c * cf + i + 1], cur);
            }
        }
    }

    #[test]
    fn tensor_states_round_trip_the_fabric() {
        // Tensor-typed relaxation (the real MGRIT hot-loop shape) matches
        // the Vec<f32> executor bit for bit.
        let (n, cf, workers) = (16usize, 4usize, 4usize);
        let mut rng = Rng::new(5);
        let w_vec: Vec<Vec<f32>> = (0..=n).map(|_| rng.normal_vec(6, 1.0)).collect();
        let w_t: Vec<Tensor> =
            w_vec.iter().map(|v| Tensor::from_vec(v.clone(), &[2, 3])).collect();
        let t_step = |l: usize, z: &Tensor| -> Tensor {
            Tensor::from_vec(affine_step(l, z.data()), &[2, 3])
        };
        let out_vec = parallel_fc_relax(w_vec, None, cf, workers, vec_step);
        let out_t = parallel_fc_relax(w_t, None, cf, workers, t_step);
        for (a, b) in out_t.iter().zip(&out_vec) {
            assert_eq!(a.data(), b.as_slice());
        }
    }
}
