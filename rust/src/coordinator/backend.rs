//! `Backend`: the execution strategy of a [`super::Session`]'s forward and
//! adjoint solves.
//!
//! Three first-class implementations:
//!
//! * [`Serial`] — exact serial propagation, ignoring the configured MGRIT
//!   iteration budget (the baseline / post-switch mode of §3.2.3);
//! * [`Mgrit`] — the single-threaded MGRIT solver (`None` iterations still
//!   mean an exact solve, matching [`crate::config::MgritConfig`]);
//! * [`ThreadedMgrit`] — real multi-worker MGRIT: every relaxation sweep of
//!   the forward *and* adjoint V-cycles runs through
//!   [`crate::parallel::exec::pool_fc_relax_mut`] on a persistent
//!   per-backend [`WorkerPool`] (threads parked between sweeps), relaxing
//!   in place on the shared level storage with channel-fabric halo
//!   exchange — bitwise identical to [`Mgrit`] and allocation-free at
//!   steady state.
//!
//! Since the persistent-context refactor a backend is a pure *strategy*:
//! it names the execution mode (worker count, relaxation pool, iteration
//! mapping) and the actual solves run on a per-`Session`
//! [`super::context::SolveContext`] that the session creates once from its
//! backend and holds for its lifetime — the context caches the MGRIT
//! hierarchies and re-consults the backend per solve (so e.g. pool
//! replacement after a poisoned sweep still works).

use std::sync::{Arc, Mutex};

use crate::parallel::WorkerPool;

/// Execution strategy for the MGRIT-shaped solves of one training step.
/// Solves themselves are methods on [`super::context::SolveContext`].
pub trait Backend: Send + Sync {
    /// Short name for logs (`"serial"`, `"mgrit"`, `"threaded-mgrit"`).
    fn name(&self) -> &'static str;

    /// Relaxation worker threads (1 = single-threaded schedule).
    fn workers(&self) -> usize {
        1
    }

    /// Persistent relaxation worker pool, if this backend keeps one. The
    /// default (None) makes multi-worker sweeps fall back to per-sweep
    /// scoped spawns; `ThreadedMgrit` overrides it with a lazily-created
    /// per-backend (i.e. per-`Session`) pool.
    fn pool(&self) -> Option<Arc<WorkerPool>> {
        None
    }

    /// Map the configured iteration budget to this backend's solve mode
    /// (`None` = exact serial propagation).
    fn solve_iters(&self, configured: Option<usize>) -> Option<usize> {
        configured
    }

    /// Does this backend always propagate exactly (serially)?
    fn forces_exact(&self) -> bool {
        self.solve_iters(Some(1)).is_none()
    }
}

/// Exact serial propagation regardless of the configured iteration budget.
pub struct Serial;

impl Backend for Serial {
    fn name(&self) -> &'static str {
        "serial"
    }

    fn solve_iters(&self, _configured: Option<usize>) -> Option<usize> {
        None
    }
}

/// The single-threaded MGRIT solver (the pre-v2 training path).
pub struct Mgrit;

impl Backend for Mgrit {
    fn name(&self) -> &'static str {
        "mgrit"
    }
}

/// Multi-worker MGRIT: relaxation sweeps execute on `workers` OS threads
/// with halo exchange over the channel fabric — the paper's Fig. 2
/// decomposition on the real training hot loop.
///
/// The backend owns a persistent [`WorkerPool`] (created lazily on the
/// first solve): `workers` long-lived threads park between sweeps instead
/// of being respawned ~2× per level per V-cycle, amortizing spawn cost
/// across a whole training run while executing bitwise the same slab
/// schedule (pinned by `rust/tests/backend_parity.rs`). The pool lives as
/// long as the backend — i.e. per `Session` — and its threads shut down
/// when the session drops. A pool poisoned by a panicked sweep (stale
/// halo messages) is rebuilt on the next solve instead of reused.
pub struct ThreadedMgrit {
    pub workers: usize,
    pool: Mutex<Option<Arc<WorkerPool>>>,
}

impl ThreadedMgrit {
    pub fn new(workers: usize) -> ThreadedMgrit {
        ThreadedMgrit { workers, pool: Mutex::new(None) }
    }
}

impl Backend for ThreadedMgrit {
    fn name(&self) -> &'static str {
        "threaded-mgrit"
    }

    fn workers(&self) -> usize {
        self.workers.max(1)
    }

    fn pool(&self) -> Option<Arc<WorkerPool>> {
        if self.workers() <= 1 {
            // single-worker sweeps run the in-thread serial schedule; no
            // pool threads needed
            return None;
        }
        let mut slot = self.pool.lock().unwrap();
        match slot.as_ref() {
            Some(p) if !p.is_poisoned() => Some(p.clone()),
            _ => {
                let p = Arc::new(WorkerPool::new(self.workers()));
                *slot = Some(p.clone());
                Some(p)
            }
        }
    }
}

/// Pick a backend from a worker count (the CLI's `--workers N` surface).
pub fn backend_for_workers(workers: usize) -> Box<dyn Backend> {
    if workers > 1 {
        Box::new(ThreadedMgrit::new(workers))
    } else {
        Box::new(Mgrit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MgritConfig;
    use crate::coordinator::context::{ForwardWorkspace, SolveContext, StepWorkspace};
    use crate::ode::LinearOde;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn cfg() -> MgritConfig {
        MgritConfig { cf: 4, levels: 2, fwd_iters: Some(2), bwd_iters: Some(1), fcf: true }
    }

    fn ctx_for(backend: Box<dyn Backend>, n: usize, shape: &[usize]) -> SolveContext {
        SolveContext::new(
            backend,
            ForwardWorkspace::new(n, shape, shape),
            StepWorkspace::new(n, shape, shape, &vec![0; n], [0, 0, 0, 0]),
        )
    }

    #[test]
    fn serial_backend_forces_exact_solves() {
        assert!(Serial.forces_exact());
        assert!(!Mgrit.forces_exact());
        assert!(!ThreadedMgrit::new(4).forces_exact());
        assert_eq!(Serial.solve_iters(Some(3)), None);
        assert_eq!(Mgrit.solve_iters(Some(3)), Some(3));
    }

    #[test]
    fn backends_share_the_context_plumbing() {
        let mut rng = Rng::new(0);
        let ode = LinearOde::random_stable(&mut rng, 4, 16, 0.1);
        let z0 = Tensor::randn(&mut rng, &[4, 1], 1.0);
        let (w_serial, st) =
            ctx_for(Box::new(Serial), 16, &[4, 1]).forward(&ode, &cfg(), &z0, Some(2), None, false);
        assert!(st.serial);
        let (w_mg, st) =
            ctx_for(Box::new(Mgrit), 16, &[4, 1]).forward(&ode, &cfg(), &z0, Some(8), None, false);
        assert!(!st.serial);
        // converged MGRIT ≈ serial
        assert!(w_mg.last().unwrap().allclose(w_serial.last().unwrap(), 1e-4, 1e-4));
        // threaded == single-threaded, bitwise
        let (w_thr, _) = ctx_for(Box::new(ThreadedMgrit::new(3)), 16, &[4, 1])
            .forward(&ode, &cfg(), &z0, Some(8), None, false);
        for (a, b) in w_mg.iter().zip(&w_thr) {
            assert_eq!(a.data(), b.data());
        }
    }

    #[test]
    fn threaded_backend_keeps_one_persistent_pool() {
        let t = ThreadedMgrit::new(3);
        let p1 = t.pool().expect("multi-worker backend has a pool");
        let p2 = t.pool().unwrap();
        assert!(Arc::ptr_eq(&p1, &p2), "pool must persist across solves");
        assert_eq!(p1.size(), 3);
        // degenerate worker counts run in-thread, no pool
        assert!(ThreadedMgrit::new(1).pool().is_none());
        assert!(ThreadedMgrit::new(0).pool().is_none());
        // other backends default to no pool
        assert!(Serial.pool().is_none());
        assert!(Mgrit.pool().is_none());
        // a poisoned pool is rebuilt, not reused
        p1.poison();
        let p3 = t.pool().unwrap();
        assert!(!Arc::ptr_eq(&p1, &p3), "poisoned pool must be rebuilt");
        assert!(!p3.is_poisoned());
    }

    #[test]
    fn workers_map_to_backends() {
        assert_eq!(backend_for_workers(1).name(), "mgrit");
        assert_eq!(backend_for_workers(4).name(), "threaded-mgrit");
        assert_eq!(backend_for_workers(4).workers(), 4);
    }
}
