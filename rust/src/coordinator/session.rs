//! `Session`: the composable training run of the Session API v2.
//!
//! A session is assembled from four orthogonal pieces by
//! [`SessionBuilder`]:
//!
//! ```text
//! Session::builder()
//!     .preset("mc")                         // or .config(RunConfig)
//!     .propagator(PropagatorKind::Rust)     // or Xla(Arc<XlaEngine>)
//!     .backend(Box::new(ThreadedMgrit::new(4)))   // or .workers(4)
//!     .objective(Box::new(TagObjective::new(..))) // or .task(Task::Tag)
//!     .build()?
//! ```
//!
//! Per batch: embed → full forward on the shared train/infer core
//! ([`super::context::ForwardContext::forward_full`]: serial open
//! buffers, MGRIT mid solve, serial close buffers) → objective loss head
//! → adjoint solve →
//! parameter gradients → clip → optimizer. Every solve runs on the
//! session's persistent [`SolveContext`]: the MGRIT hierarchies are cached
//! across steps, states/λ/gradients *and* the batch/loss-head buffers live
//! in its workspaces (plus the session's long-lived `TrainBatch`), so the
//! steady-state `train_step` performs **zero** heap allocations —
//! sampling, loss head, clipping and all (pinned by
//! `rust/tests/alloc_audit.rs`). The §3.2.3 controller probes the MGRIT
//! convergence factor on a cadence and can raise iteration counts or
//! switch the run to serial (which also drops the now-stale warm-start
//! iterate).
//!
//! ## Self-healing
//!
//! [`Session::train_step`] wraps the raw step in the recovery policies of
//! [`crate::fault`]: a non-finite guard that skips the optimizer update
//! (Adam's moments never see NaN) and replays the batch from a rewound
//! RNG/step/controller snapshot, and a divergence watchdog that
//! auto-rolls back to the newest successful autosave — restoring
//! parameters, moments, RNG, controller and warm iterate in place — before
//! falling back to the §3.2.3 serial switch. Every recovery is recorded as
//! a typed [`StepAnomaly`] (surfaced via [`TrainReport`]) and mirrored
//! into the global fault-event log. Autosave writes are atomic
//! (tmp + fsync + rename, [`crate::checkpoint`]), and a *failed* autosave
//! is a recorded event, not a dead run.
//!
//! ## Checkpointing
//!
//! [`Session::save`] writes a [`crate::checkpoint::Checkpoint`] capturing
//! the run config (including controller-mutated MGRIT iteration counts),
//! parameters, optimizer moments, adaptive-controller state, the training
//! RNG stream, the step counter, and the warm-start iterate.
//! [`Session::resume`] (or [`SessionBuilder::resume`], to also pick a
//! backend/propagator) rebuilds a session that continues the run **bitwise
//! identically** to the uninterrupted original — pinned by
//! `rust/tests/checkpoint_roundtrip.rs`.
//!
//! Data parallelism is executed as `dp` sequential micro-batches with
//! gradient averaging — bit-identical math to distributed replicas (the
//! *time* dimension of dp lives in `parallel::simulator`; this box has one
//! core, DESIGN.md §Substitutions).

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::adaptive::{AdaptiveController, ProbeRecord};
use crate::checkpoint::{Checkpoint, ControllerState};
use crate::config::{presets, Arch, RunConfig};
use crate::model::{Init, ParamStore};
use crate::ode::{Propagator, RustPropagator, XlaPropagator};
use crate::opt::{Decay, LrSchedule, Optimizer};
use crate::runtime::XlaEngine;
use crate::util::json::{self, Json};
use crate::util::rng::Rng;

use super::backend::{backend_for_workers, Backend, Mgrit};
use super::context::{mid_range, ForwardWorkspace, SolveContext, StepWorkspace};
use super::heads;
use super::objective::{EvalAccum, Objective, TrainBatch};
use super::trainer::Task;

/// One training-step record (drives the Fig. 3/4 curves).
#[derive(Debug, Clone)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f32,
    pub acc: f32,
    pub lr: f32,
    pub serial: bool,
    pub rho_fwd: Option<f64>,
    pub rho_bwd: Option<f64>,
}

impl StepRecord {
    pub fn to_json(&self) -> Json {
        let rho = |v: Option<f64>| v.map(finite_num).unwrap_or(Json::Null);
        json::obj(vec![
            ("step", json::int(self.step as i64)),
            ("loss", finite_num(self.loss as f64)),
            ("acc", finite_num(self.acc as f64)),
            ("lr", finite_num(self.lr as f64)),
            ("serial", Json::Bool(self.serial)),
            ("rho_fwd", rho(self.rho_fwd)),
            ("rho_bwd", rho(self.rho_bwd)),
        ])
    }
}

/// Policy-1 cap: consecutive rewound attempts of one training step before
/// the session escalates (serial switch for an adaptive MGRIT run, then
/// giving the step up with the update skipped).
pub const MAX_STEP_RETRIES: u32 = 3;

/// Policy-2 cap: auto-rollbacks per session before the divergence watchdog
/// falls back to the plain serial switch.
pub const MAX_ROLLBACKS: u32 = 2;

/// Classes of recovered training anomalies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AnomalyKind {
    /// The batch loss came back NaN/Inf.
    NonFiniteLoss,
    /// The global gradient norm came back NaN/Inf (loss still finite).
    NonFiniteGrad,
    /// The §3.2.3 divergence watchdog tripped on a finite loss.
    Divergence,
}

impl AnomalyKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            AnomalyKind::NonFiniteLoss => "non_finite_loss",
            AnomalyKind::NonFiniteGrad => "non_finite_grad",
            AnomalyKind::Divergence => "divergence",
        }
    }
}

/// A training-step anomaly the session *recovered from* (a policy record,
/// not an error): the optimizer update was skipped or rolled back instead
/// of poisoning the Adam moments. Collected on [`Session`], surfaced
/// through [`TrainReport::anomalies`], and mirrored into the global
/// [`crate::fault`] event log.
#[derive(Debug, Clone)]
pub struct StepAnomaly {
    /// Step counter at detection (the step whose attempt misbehaved).
    pub step: usize,
    pub kind: AnomalyKind,
    /// Human-readable diagnostics (loss / grad-norm values, rollback target).
    pub detail: String,
}

impl StepAnomaly {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("step", json::int(self.step as i64)),
            ("kind", json::s(self.kind.as_str())),
            ("detail", json::s(&self.detail)),
        ])
    }
}

/// Validation record: metric is accuracy (or BLEU for Translate).
#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub step: usize,
    pub metric: f64,
}

impl EvalRecord {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("step", json::int(self.step as i64)),
            ("metric", finite_num(self.metric)),
        ])
    }
}

/// JSON numbers are IEEE doubles with no NaN/Inf encoding; map them to
/// null so a diverged run still writes a parseable report.
fn finite_num(v: f64) -> Json {
    if v.is_finite() {
        json::num(v)
    } else {
        Json::Null
    }
}

/// Everything a run produced.
#[derive(Debug, Clone, Default)]
pub struct TrainReport {
    pub curve: Vec<StepRecord>,
    pub evals: Vec<EvalRecord>,
    pub probes: Vec<ProbeRecord>,
    pub final_loss: f32,
    pub final_metric: f64,
    pub phi_fwd: u64,
    pub phi_vjp: u64,
    pub switched_at: Option<usize>,
    /// Every anomaly the self-healing policies recovered from, in order.
    /// After a rollback the curve may hold duplicate step numbers (the
    /// replayed span) — this list is how a reader tells the two runs apart.
    pub anomalies: Vec<StepAnomaly>,
}

impl TrainReport {
    /// Machine-readable run record (`layertime train --report out.json`):
    /// the full step curve, eval points, and the retained §3.2.3 probe
    /// history — everything the Fig. 4/5-style plots need, with no stdout
    /// scraping.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("curve", json::arr(self.curve.iter().map(|r| r.to_json()).collect())),
            ("evals", json::arr(self.evals.iter().map(|e| e.to_json()).collect())),
            ("probes", json::arr(self.probes.iter().map(|p| p.to_json()).collect())),
            ("final_loss", finite_num(self.final_loss as f64)),
            ("final_metric", finite_num(self.final_metric)),
            ("phi_fwd", json::int(self.phi_fwd as i64)),
            ("phi_vjp", json::int(self.phi_vjp as i64)),
            (
                "switched_at",
                self.switched_at.map(|s| json::int(s as i64)).unwrap_or(Json::Null),
            ),
            ("anomalies", json::arr(self.anomalies.iter().map(|a| a.to_json()).collect())),
        ])
    }
}

/// Which Φ implementation a session runs on.
pub enum PropagatorKind {
    /// The pure-Rust reference transformer (artifact-free).
    Rust,
    /// AOT artifacts through PJRT (the production path).
    Xla(Arc<XlaEngine>),
}

/// Composable constructor for [`Session`]; every piece has a sensible
/// default derived from the run config.
pub struct SessionBuilder {
    rc: Option<RunConfig>,
    preset: Option<String>,
    task: Option<Task>,
    objective: Option<Box<dyn Objective>>,
    backend: Option<Box<dyn Backend>>,
    propagator: PropagatorKind,
    params: Option<ParamStore>,
    workers: Option<usize>,
    warm_start: bool,
    resume: Option<String>,
}

impl SessionBuilder {
    /// Start from a named preset (resolved at `build`; unknown names error
    /// with the list of valid presets).
    pub fn preset(mut self, name: &str) -> Self {
        self.preset = Some(name.to_string());
        self
    }

    /// Start from an explicit run config (takes precedence over `preset`).
    pub fn config(mut self, rc: RunConfig) -> Self {
        self.rc = Some(rc);
        self
    }

    /// Resume from a checkpoint written by [`Session::save`]. The run
    /// config, parameters, optimizer moments, adaptive state, RNG stream,
    /// step counter and warm-start iterate all come from the file —
    /// mutually exclusive with `.preset` / `.config` / `.params`. The
    /// execution pieces (`.backend` / `.workers` / `.propagator`) remain
    /// free: solves are bitwise identical across backends, so resuming on
    /// a different worker count continues the exact same run.
    pub fn resume(mut self, path: &str) -> Self {
        self.resume = Some(path.to_string());
        self
    }

    /// Select one of the paper's five tasks (default: derived from the
    /// config's preset name).
    pub fn task(mut self, task: Task) -> Self {
        self.task = Some(task);
        self
    }

    /// Plug in a custom training objective (overrides `task`).
    pub fn objective(mut self, objective: Box<dyn Objective>) -> Self {
        self.objective = Some(objective);
        self
    }

    /// Select the execution backend (default: [`Mgrit`], or
    /// `ThreadedMgrit` when `.workers(n > 1)` was given).
    pub fn backend(mut self, backend: Box<dyn Backend>) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Convenience backend selection: `n > 1` → `ThreadedMgrit { n }`.
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = Some(n);
        self
    }

    /// Select the Φ implementation (default: pure Rust).
    pub fn propagator(mut self, kind: PropagatorKind) -> Self {
        self.propagator = kind;
        self
    }

    /// Convenience: `Some(engine)` → XLA Φ, `None` → Rust Φ.
    pub fn engine(self, engine: Option<Arc<XlaEngine>>) -> Self {
        match engine {
            Some(e) => self.propagator(PropagatorKind::Xla(e)),
            None => self.propagator(PropagatorKind::Rust),
        }
    }

    /// Train from existing parameters (fine-tuning / comparison runs).
    pub fn params(mut self, params: ParamStore) -> Self {
        self.params = Some(params);
        self
    }

    /// Toggle TorchBraid-style warm starts of the forward solve.
    pub fn warm_start(mut self, on: bool) -> Self {
        self.warm_start = on;
        self
    }

    /// Assemble the session, resolving defaults and validating the preset
    /// and task names (and, when resuming, the checkpoint).
    pub fn build(self) -> Result<Session> {
        let ck = match &self.resume {
            Some(path) => {
                if self.rc.is_some() || self.preset.is_some() || self.params.is_some() {
                    bail!(
                        "SessionBuilder: .resume(..) carries its own config and parameters — \
                         drop .preset/.config/.params"
                    );
                }
                Some(Checkpoint::read(path)?)
            }
            None => None,
        };
        let rc = match (&ck, self.rc, self.preset) {
            (Some(c), _, _) => c.rc.clone(),
            (None, Some(rc), _) => rc,
            (None, None, Some(name)) => presets::by_name(&name).ok_or_else(|| {
                anyhow!("unknown preset '{}' (valid: {})", name, presets::ALL.join(", "))
            })?,
            (None, None, None) => bail!("Session::builder() needs .preset(..) or .config(..)"),
        };
        let objective: Box<dyn Objective> = match (self.objective, self.task) {
            (Some(o), _) => o,
            (None, Some(t)) => t.objective(&rc.model, rc.train.seed),
            (None, None) => Task::for_preset(&rc.name)?.objective(&rc.model, rc.train.seed),
        };
        let backend: Box<dyn Backend> = match (self.backend, self.workers) {
            (Some(_), Some(_)) => {
                bail!("SessionBuilder: .backend(..) and .workers(..) are both set — pick one \
                       (workers is shorthand for selecting Mgrit/ThreadedMgrit)")
            }
            (Some(b), None) => b,
            (None, Some(n)) => backend_for_workers(n),
            (None, None) => Box::new(Mgrit),
        };
        let params = match &ck {
            Some(c) => ParamStore::from_parts(
                rc.model.clone(),
                c.layers.clone(),
                c.w_emb.clone(),
                c.w_pos.clone(),
                c.w_out.clone(),
                c.w_cls.clone(),
            ),
            None => match self.params {
                Some(p) => p,
                None => {
                    let scheme =
                        if rc.model.total_layers() >= 64 { Init::DeepNet } else { Init::Default };
                    ParamStore::init(&rc.model, scheme, rc.train.seed)
                }
            },
        };
        let prop: Box<dyn Propagator> = match self.propagator {
            PropagatorKind::Rust => {
                Box::new(RustPropagator::for_model(&rc.model, params.layers.clone()))
            }
            PropagatorKind::Xla(e) => {
                Box::new(XlaPropagator::for_model(e, &rc.model, params.layers.clone())?)
            }
        };
        let mut opt = Optimizer::new(rc.train.opt, &params.group_sizes(), rc.train.weight_decay);
        let sched = LrSchedule {
            base_lr: rc.train.lr,
            warmup: rc.train.warmup,
            decay: if rc.train.warmup > 0 {
                Decay::Cosine { total: rc.train.steps, min_frac: 0.1 }
            } else {
                Decay::Constant
            },
        };
        let controller = AdaptiveController::new(if rc.train.adaptive {
            rc.train.probe_every
        } else {
            0
        });
        let seed = rc.train.seed;
        // persistent solve context: cached MGRIT hierarchies + the shared
        // forward workspace + the training step workspace, sized once from
        // the session geometry
        let n_layers = rc.model.total_layers();
        let theta_lens: Vec<usize> = (0..n_layers).map(|l| prop.theta_len(l)).collect();
        let head_shape = [rc.model.batch, rc.model.seq, rc.model.d_model];
        let state_shape = prop.state_shape();
        let fwd_ws = ForwardWorkspace::new(n_layers, &state_shape, &head_shape);
        let ws = StepWorkspace::new(
            n_layers,
            &state_shape,
            &head_shape,
            &theta_lens,
            [params.w_emb.len(), params.w_pos.len(), params.w_out.len(), params.w_cls.len()],
        );
        let mut ctx = SolveContext::new(backend, fwd_ws, ws);
        // checkpoint restore: every stateful piece beyond params/config
        let (mut train_rng, mut step, mut initial_loss, mut switched_at, mut warm_start) =
            (Rng::new(seed.wrapping_mul(2) + 1), 0usize, None, None, self.warm_start);
        let controller = match ck {
            None => controller,
            Some(c) => {
                opt.restore_moments(c.opt_m, c.opt_v, c.opt_t);
                train_rng = Rng::from_parts(c.rng_state, c.rng_spare);
                step = c.step;
                initial_loss = c.initial_loss;
                switched_at = c.switched_at;
                warm_start = c.warm_start;
                if let Some(warm) = c.warm {
                    let (bo, n_mid) = mid_range(&rc.model);
                    // Checkpoint::read validated count and element sizes
                    // against the config's state shape
                    for (dst, src) in ctx.fwd.ws.states[bo..=bo + n_mid].iter_mut().zip(&warm) {
                        dst.copy_from(src);
                    }
                    ctx.fwd.mark_warm();
                }
                let cs = c.controller;
                AdaptiveController::restore(
                    cs.probe_every,
                    cs.rho_switch,
                    cs.rho_grow,
                    cs.max_iters,
                    cs.step,
                    cs.switched,
                    cs.history_cap,
                    cs.history,
                )
            }
        };
        Ok(Session {
            rc,
            params,
            objective,
            batch_buf: TrainBatch::default(),
            ctx,
            prop,
            opt,
            sched,
            controller,
            train_rng,
            val_rng_seed: seed.wrapping_mul(2) + 2,
            warm_start,
            step,
            initial_loss,
            switched_at,
            autosave: None,
            last_autosave: None,
            consec_anomalies: 0,
            rollbacks: 0,
            anomalies: Vec::new(),
        })
    }
}

/// A fully-wired training run (the paper's end-to-end procedure).
pub struct Session {
    pub rc: RunConfig,
    pub params: ParamStore,
    objective: Box<dyn Objective>,
    /// Long-lived batch buffer, refilled in place by
    /// `Objective::sample_into` every micro-batch/eval batch (taken out of
    /// the session during the batch body to keep the borrows disjoint —
    /// a pointer move, not an allocation).
    batch_buf: TrainBatch,
    /// Persistent solve state: the shared train/infer forward core (with
    /// both cached MGRIT hierarchies and the warm-start iterate) plus the
    /// training step workspace.
    ctx: SolveContext,
    prop: Box<dyn Propagator>,
    opt: Optimizer,
    sched: LrSchedule,
    pub controller: AdaptiveController,
    train_rng: Rng,
    val_rng_seed: u64,
    pub warm_start: bool,
    step: usize,
    initial_loss: Option<f32>,
    switched_at: Option<usize>,
    /// Periodic checkpointing during [`Session::train`] (`--save-every`).
    autosave: Option<Autosave>,
    /// Path of the newest *successful* autosave — the policy-2 rollback
    /// target.
    last_autosave: Option<String>,
    /// Consecutive rewound attempts of the current step (policy-1 cap).
    consec_anomalies: u32,
    /// Auto-rollbacks performed so far (policy-2 cap).
    rollbacks: u32,
    /// Every recovered anomaly, in order (also mirrored into the global
    /// [`crate::fault`] event log).
    anomalies: Vec<StepAnomaly>,
}

/// Periodic-autosave policy: every `every` steps, write
/// [`crate::checkpoint::autosave_path`]`(base, step)` and keep only the
/// newest `keep` snapshots (`keep = 0` disables pruning).
struct Autosave {
    base: String,
    every: usize,
    keep: usize,
}

impl Session {
    /// Start assembling a session.
    pub fn builder() -> SessionBuilder {
        SessionBuilder {
            rc: None,
            preset: None,
            task: None,
            objective: None,
            backend: None,
            propagator: PropagatorKind::Rust,
            params: None,
            workers: None,
            warm_start: true,
            resume: None,
        }
    }

    /// Compat shim for the v1 `TrainRun::new` signature: fresh parameters,
    /// `engine = None` → pure-Rust Φ.
    pub fn new(rc: RunConfig, task: Task, engine: Option<Arc<XlaEngine>>) -> Result<Session> {
        Session::builder().config(rc).task(task).engine(engine).build()
    }

    /// Compat shim for the v1 `TrainRun::from_params` signature.
    pub fn from_params(
        rc: RunConfig,
        task: Task,
        params: ParamStore,
        engine: Option<Arc<XlaEngine>>,
    ) -> Result<Session> {
        Session::builder().config(rc).task(task).params(params).engine(engine).build()
    }

    /// Resume a checkpointed run with default execution pieces (pure-Rust
    /// Φ, `Mgrit` backend). Use `Session::builder().resume(path)` to pick
    /// a backend, worker count, or the XLA propagator.
    pub fn resume(path: &str) -> Result<Session> {
        Session::builder().resume(path).build()
    }

    /// Enable periodic autosave during [`Session::train`]: every `every`
    /// steps (and at the final step) write a full checkpoint to
    /// [`crate::checkpoint::autosave_path`]`(base, step)`, then prune the
    /// family down to the newest `keep` snapshots (`keep = 0` keeps all).
    /// A `serve --watch` process pointed at the same directory hot-reloads
    /// each snapshot as it lands.
    pub fn set_autosave(&mut self, base: &str, every: usize, keep: usize) {
        self.autosave = Some(Autosave { base: base.to_string(), every: every.max(1), keep });
    }

    /// Write a full session checkpoint (config, parameters, optimizer
    /// moments, adaptive state, RNG stream, step counter, warm iterate) —
    /// see [`crate::checkpoint`] for the format. A session resumed from it
    /// continues bitwise identically.
    pub fn save(&self, path: &str) -> Result<()> {
        let (bo, n_mid) = self.mid_range();
        let warm = if self.ctx.has_warm() {
            Some(self.ctx.fwd.ws.states[bo..=bo + n_mid].to_vec())
        } else {
            None
        };
        let (rng_state, rng_spare) = self.train_rng.state_parts();
        let (m, v) = self.opt.moments();
        let c = &self.controller;
        let ck = Checkpoint {
            rc: self.rc.clone(),
            step: self.step,
            initial_loss: self.initial_loss,
            switched_at: self.switched_at,
            warm_start: self.warm_start,
            rng_state,
            rng_spare,
            controller: ControllerState {
                probe_every: c.probe_every,
                rho_switch: c.rho_switch,
                rho_grow: c.rho_grow,
                max_iters: c.max_iters,
                step: c.batch_step(),
                switched: c.is_serial(),
                history_cap: c.history_cap(),
                history: c.history().to_vec(),
            },
            opt_t: self.opt.step_count(),
            opt_m: m.to_vec(),
            opt_v: v.to_vec(),
            layers: self.params.layers.read().unwrap().clone(),
            w_emb: self.params.w_emb.clone(),
            w_pos: self.params.w_pos.clone(),
            w_out: self.params.w_out.clone(),
            w_cls: self.params.w_cls.clone(),
            warm,
        };
        ck.write(path)
    }

    /// The active objective's short name.
    pub fn objective_name(&self) -> &'static str {
        self.objective.name()
    }

    /// The active backend's short name.
    pub fn backend_name(&self) -> &'static str {
        self.ctx.backend().name()
    }

    /// Completed optimizer steps (checkpoint-resumed sessions start from
    /// the saved counter, not 0).
    pub fn step(&self) -> usize {
        self.step
    }

    /// Every anomaly the self-healing policies recovered from so far.
    pub fn anomalies(&self) -> &[StepAnomaly] {
        &self.anomalies
    }

    /// Auto-rollbacks performed so far (capped at [`MAX_ROLLBACKS`]).
    pub fn rollback_count(&self) -> u32 {
        self.rollbacks
    }

    /// Are the optimizer's Adam moments all finite? The self-healing
    /// invariant chaos tests pin: no recovered anomaly may have leaked
    /// NaN/Inf into the moment buffers.
    pub fn moments_finite(&self) -> bool {
        self.opt.moments_finite()
    }

    /// Adjust the total run length (`train` runs until this step count),
    /// keeping the cosine LR horizon in sync — the `--resume --steps N`
    /// surface. No-op on the schedule when the decay is not cosine.
    pub fn set_total_steps(&mut self, steps: usize) {
        self.rc.train.steps = steps;
        if let Decay::Cosine { min_frac, .. } = self.sched.decay {
            self.sched.decay = Decay::Cosine { total: steps, min_frac };
        }
    }

    /// Cached-hierarchy introspection: how many MGRIT cores this session's
    /// solve context has built so far (2 at steady state — one per solve
    /// direction — plus explicit rebuilds on cf/levels changes).
    pub fn solve_core_builds(&self) -> u64 {
        self.ctx.core_builds()
    }

    /// Drop the cached MGRIT hierarchies; the next solve rebuilds them.
    /// The explicit-rebuild hook for out-of-band solver-geometry changes
    /// (and the "fresh ctx" benchmark baseline).
    pub fn invalidate_solve_context(&mut self) {
        self.ctx.invalidate();
    }

    /// Is a TorchBraid-style warm-start iterate currently held?
    pub fn has_warm_iterate(&self) -> bool {
        self.ctx.has_warm()
    }

    fn mid_range(&self) -> (usize, usize) {
        mid_range(&self.rc.model)
    }

    /// Embed a batch into the propagator's state shape, written straight
    /// into the forward workspace's Z_0 buffer (no allocation).
    fn embed_into(&mut self, tokens: &[i32], tgt_in: Option<&[i32]>) {
        let m = &self.rc.model;
        heads::embed_state_into(
            tokens,
            tgt_in,
            &self.params.w_emb,
            &self.params.w_pos,
            m.batch,
            m.seq,
            m.d_model,
            self.ctx.fwd.ws.states[0].data_mut(),
        );
    }

    /// One micro-batch: forward, loss, adjoint, gradients (no update).
    /// Every state/adjoint/gradient lives in the solve context's
    /// workspaces; gradients *accumulate* there (zeroed once per training
    /// step, so dp micro-batches sum naturally). Returns
    /// (loss, acc, rho_fwd, rho_bwd).
    fn micro_batch(&mut self, probe: bool) -> (f32, f32, Option<f64>, Option<f64>) {
        let m = self.rc.model.clone();
        let n_layers = m.total_layers();
        let (bo, n_mid) = self.mid_range();
        let stacked = m.arch == Arch::EncDec;

        // --- sample a batch (into the session's long-lived buffer) ------
        let mut batch = std::mem::take(&mut self.batch_buf);
        self.objective.sample_into(&mut self.train_rng, &m, &mut batch);

        // --- forward (the shared train/infer core) -----------------------
        self.embed_into(&batch.tokens, batch.tgt_in.as_deref());
        let fwd_iters = if probe {
            self.controller.probe_iters(&self.rc.mgrit).0
        } else {
            self.rc.mgrit.fwd_iters
        };
        let fstats = self.ctx.fwd.forward_full(
            self.prop.as_ref(),
            &self.rc.mgrit,
            bo,
            n_mid,
            fwd_iters,
            self.warm_start,
            probe,
        );

        // --- loss head (workspace-reusing: cotangent into ws.lam_head,
        //     head gradients straight into the step accumulators) --------
        let out = {
            let (x_final, sink) = self.ctx.head_view_and_sink(n_layers, stacked);
            self.objective.loss_into(x_final, &self.params, &batch, &m, sink)
        };
        let acc = out.correct / out.denom;

        // --- adjoint ---------------------------------------------------------
        {
            // seed λ_N: lift the head cotangent into the state shape
            let StepWorkspace { lams, lam_head, .. } = &mut self.ctx.ws;
            let lam_n = &mut lams[n_layers];
            if stacked {
                let half = lam_n.len() / 2;
                let d = lam_n.data_mut();
                d[..half].fill(0.0);
                d[half..].copy_from_slice(lam_head.data());
            } else {
                lam_n.copy_from(lam_head);
            }
        }
        {
            // close buffers: serial adjoint + grads
            let states = &self.ctx.fwd.ws.states;
            let StepWorkspace { lams, grads, .. } = &mut self.ctx.ws;
            for l in ((bo + n_mid)..n_layers).rev() {
                let (lam_lo, lam_hi) = lams.split_at_mut(l + 1);
                self.prop.accumulate_grad(l, &states[l], &lam_hi[0], &mut grads[l]);
                self.prop.adjoint_step_into(l, 1.0, &states[l], &lam_hi[0], &mut lam_lo[l]);
            }
        }
        // backend adjoint solve + mid-range gradients on the cached cores
        let bwd_iters = if probe {
            self.controller.probe_iters(&self.rc.mgrit).1
        } else {
            self.rc.mgrit.bwd_iters
        };
        let mid = super::range::RangeProp::new(self.prop.as_ref(), bo, n_mid);
        let bstats = self.ctx.adjoint_mid(&mid, &self.rc.mgrit, bo, bwd_iters, probe);
        self.ctx.gradients_mid(&mid, bo);
        {
            // open buffers
            let states = &self.ctx.fwd.ws.states;
            let StepWorkspace { lams, grads, .. } = &mut self.ctx.ws;
            for l in (0..bo).rev() {
                let (lam_lo, lam_hi) = lams.split_at_mut(l + 1);
                self.prop.accumulate_grad(l, &states[l], &lam_hi[0], &mut grads[l]);
                self.prop.adjoint_step_into(l, 1.0, &states[l], &lam_hi[0], &mut lam_lo[l]);
            }
        }

        // --- embedding gradients ----------------------------------------------
        {
            let StepWorkspace { lams, g_emb, g_pos, .. } = &mut self.ctx.ws;
            let lam0 = lams[0].data();
            if stacked {
                let half = lam0.len() / 2;
                heads::embed_bwd(
                    &batch.tokens,
                    &lam0[..half],
                    m.batch,
                    m.seq,
                    m.d_model,
                    g_emb,
                    g_pos,
                );
                heads::embed_bwd(
                    batch.tgt_in.as_ref().unwrap(),
                    &lam0[half..],
                    m.batch,
                    m.seq,
                    m.d_model,
                    g_emb,
                    g_pos,
                );
            } else {
                heads::embed_bwd(&batch.tokens, lam0, m.batch, m.seq, m.d_model, g_emb, g_pos);
            }
        }
        // hand the batch buffer back for the next micro-batch (the head
        // gradients were already accumulated by loss_into)
        self.batch_buf = batch;
        (out.loss, acc, fstats.conv_factor(), bstats.conv_factor())
    }

    /// One full training step (dp micro-batches + probe + update), wrapped
    /// in the self-healing policies of [`crate::fault`]:
    ///
    /// * **Non-finite guard (policy 1).** If the batch loss or the global
    ///   gradient norm comes back NaN/Inf, the optimizer update is
    ///   *skipped* — Adam's moments never see the poison — and the attempt
    ///   is rewound (RNG stream, step counter, controller cadence) and the
    ///   same batch replayed. Under an exact (serial) configuration the
    ///   replay is bitwise identical to a run that never faulted; a
    ///   warm-started MGRIT replay re-solves from the advanced iterate
    ///   (same math, different warm start). After [`MAX_STEP_RETRIES`]
    ///   consecutive anomalies an adaptive MGRIT run switches to serial
    ///   and keeps retrying; a run with nowhere left to escalate emits the
    ///   anomalous record with the update skipped — a typed
    ///   [`StepAnomaly`] either way, never a panic or a poisoned moment.
    /// * **Divergence watchdog (policy 2).** A finite loss above the
    ///   §3.2.3 divergence threshold first tries an **auto-rollback**:
    ///   restore the newest successful autosave in place
    ///   ([`Session::set_autosave`]) and replay from there — bitwise
    ///   identical to a run that never diverged. After [`MAX_ROLLBACKS`]
    ///   rollbacks, or with no autosave available, it falls back to the
    ///   original switch-to-serial escalation.
    pub fn train_step(&mut self) -> StepRecord {
        loop {
            // policy-1 rewind snapshot: two scalar copies, no allocation
            let (rng_state, rng_spare) = self.train_rng.state_parts();
            self.step += 1;
            let probe = self.controller.should_probe();
            let dp = self.rc.dp_degree.max(1);
            self.ctx.ws.zero_grads();

            let mut loss_sum = 0.0f32;
            let mut acc_sum = 0.0f32;
            let (mut rho_f, mut rho_b) = (None, None);
            for rep in 0..dp {
                // gradient allreduce with replica semantics: each micro-batch
                // sums into fresh zeroed accumulators (the running sum is
                // parked in the dp scratch set meanwhile) and the per-replica
                // totals are then added — bit-identical to v1 / distributed
                // summation, unlike accumulating element updates in place
                if rep > 0 {
                    self.ctx.ws.stash_grads();
                }
                let (l, a, rf, rb) = self.micro_batch(probe && rep == 0);
                if rep > 0 {
                    self.ctx.ws.fold_stashed_grads();
                }
                loss_sum += l;
                acc_sum += a;
                if rep == 0 {
                    rho_f = rf;
                    rho_b = rb;
                }
            }
            if dp > 1 {
                self.ctx.ws.scale_grads(1.0 / dp as f32);
            }
            let mut loss = loss_sum / dp as f32;
            let acc = acc_sum / dp as f32;

            // deterministic chaos hooks — one relaxed atomic load each when
            // disarmed (rust/src/fault), inside the audited 0-alloc path
            if crate::faultpoint!("train.nan_grad") {
                if let Some(x) = self.ctx.ws.grads.first_mut().and_then(|g| g.iter_mut().next()) {
                    *x = f32::NAN;
                }
            }
            if crate::faultpoint!("train.loss_spike") {
                loss = 1.0e6;
            }

            // clip straight from the workspace accumulators (the untouched
            // head groups are full-size zeros, so including them changes
            // neither the norm nor the updates); clip_global walks the
            // accumulators directly — no per-step ref-list allocation. The
            // returned pre-clip norm doubles as the policy-1 gradient
            // health check: NaN/Inf anywhere in the accumulators
            // propagates into it.
            let gnorm = self.ctx.ws.clip_global(self.rc.train.grad_clip);

            // --- policy 1: non-finite guard ------------------------------
            if !loss.is_finite() || !gnorm.is_finite() {
                match self.recover_non_finite(loss, acc, gnorm, rng_state, rng_spare) {
                    Some(rec) => return rec, // gave the step up (update skipped)
                    None => continue,        // rewound — replay the batch
                }
            }
            self.consec_anomalies = 0;

            // adaptive controller (probe result + divergence watchdog)
            if probe {
                self.controller.observe(rho_f, rho_b, &mut self.rc.mgrit);
                if self.controller.is_serial() && self.switched_at.is_none() {
                    self.switched_at = Some(self.step);
                }
            }
            if self.initial_loss.is_none() {
                self.initial_loss = Some(loss);
            }
            if self.rc.train.adaptive
                && !self.controller.is_serial()
                && loss > 3.0 * self.initial_loss.unwrap() + 1.0
            {
                // --- policy 2: watchdog — rollback first, serial second --
                if self.try_rollback(loss) {
                    continue; // replay from the restored snapshot
                }
                self.controller.force_serial(&mut self.rc.mgrit);
                self.switched_at = Some(self.step);
            }
            if self.controller.is_serial() {
                // the switch is sticky: the warm iterate is dead memory (and
                // would poison a later non-serial run restored from this
                // session) and the cached hierarchies will never be solved on
                // again — drop both at the switch, not lazily
                self.ctx.clear_warm();
                self.ctx.invalidate();
            }

            let lr = self.sched.at(self.step);
            self.opt.begin_step();
            {
                // the only write-lock acquisition on the training path
                let mut layers = self.params.layers.write().unwrap();
                for (i, g) in self.ctx.ws.grads.iter().enumerate() {
                    self.opt.update(i, lr, &mut layers[i], g);
                }
            }
            let nl = self.rc.model.total_layers();
            self.opt.update(nl, lr, &mut self.params.w_emb, &self.ctx.ws.g_emb);
            self.opt.update(nl + 1, lr, &mut self.params.w_pos, &self.ctx.ws.g_pos);
            self.opt.update(nl + 2, lr, &mut self.params.w_out, &self.ctx.ws.g_out);
            self.opt.update(nl + 3, lr, &mut self.params.w_cls, &self.ctx.ws.g_cls);

            return StepRecord {
                step: self.step,
                loss,
                acc,
                lr,
                serial: self.rc.mgrit.is_serial()
                    || self.controller.is_serial()
                    || self.ctx.backend().forces_exact(),
                rho_fwd: rho_f,
                rho_bwd: rho_b,
            };
        }
    }

    /// Policy 1: a non-finite loss or gradient norm was detected *before*
    /// the optimizer update. Record the typed anomaly, then either rewind
    /// the attempt (RNG stream, step counter, controller batch cadence) so
    /// the caller replays it — escalating to the serial propagator once
    /// the retry budget is spent — or, with nowhere left to escalate, give
    /// the step up: `Some(record)` with the update skipped.
    fn recover_non_finite(
        &mut self,
        loss: f32,
        acc: f32,
        gnorm: f32,
        rng_state: u64,
        rng_spare: Option<f32>,
    ) -> Option<StepRecord> {
        let step = self.step;
        let kind =
            if loss.is_finite() { AnomalyKind::NonFiniteGrad } else { AnomalyKind::NonFiniteLoss };
        self.consec_anomalies += 1;
        let detail =
            format!("loss={} grad_norm={} attempt={}", loss, gnorm, self.consec_anomalies);
        self.anomalies.push(StepAnomaly { step, kind, detail: detail.clone() });
        crate::fault::record("train.step_anomaly", step as u64, "skipped_step", detail);
        let escalate = self.consec_anomalies >= MAX_STEP_RETRIES;
        if !escalate || (self.rc.train.adaptive && !self.controller.is_serial()) {
            if escalate {
                // the MGRIT solve itself may be the poison source — switch
                // to the exact serial propagation and retry with a fresh
                // budget
                self.controller.force_serial(&mut self.rc.mgrit);
                self.switched_at = Some(step);
                self.ctx.clear_warm();
                self.ctx.invalidate();
                self.consec_anomalies = 0;
                crate::fault::record(
                    "train.step_anomaly",
                    step as u64,
                    "force_serial",
                    "retry budget spent — switching to serial propagation".to_string(),
                );
            }
            // rewind the attempt for replay
            self.train_rng = Rng::from_parts(rng_state, rng_spare);
            self.step -= 1;
            self.controller.rewind_batch();
            return None;
        }
        // nowhere left to escalate: the step counts (so the run
        // terminates) but the update is skipped; later steps get their own
        // retry budget
        self.consec_anomalies = 0;
        Some(StepRecord {
            step,
            loss,
            acc,
            lr: self.sched.at(step),
            serial: self.rc.mgrit.is_serial()
                || self.controller.is_serial()
                || self.ctx.backend().forces_exact(),
            rho_fwd: None,
            rho_bwd: None,
        })
    }

    /// Policy 2: the divergence watchdog tripped on a finite loss. Restore
    /// the newest successful autosave in place and let the caller replay
    /// from it (`true`), or report that the caller should fall back to the
    /// serial switch (`false`: no autosave yet, rollback cap reached, or
    /// the snapshot failed to load).
    fn try_rollback(&mut self, loss: f32) -> bool {
        let step = self.step;
        let path = match &self.last_autosave {
            Some(p) if self.rollbacks < MAX_ROLLBACKS => p.clone(),
            _ => return false,
        };
        match Checkpoint::read(&path).and_then(|c| self.restore_in_place(c)) {
            Ok(()) => {
                self.rollbacks += 1;
                self.controller.record_rollback();
                let detail = format!(
                    "loss={} at step {} — restored {} (step {})",
                    loss, step, path, self.step
                );
                self.anomalies.push(StepAnomaly {
                    step,
                    kind: AnomalyKind::Divergence,
                    detail: detail.clone(),
                });
                crate::fault::record("train.watchdog", step as u64, "rollback", detail);
                true
            }
            Err(e) => {
                crate::fault::record(
                    "train.watchdog",
                    step as u64,
                    "rollback_failed",
                    e.to_string(),
                );
                false
            }
        }
    }

    /// Restore every stateful piece of the session from a checkpoint, in
    /// place — the rollback arm of the divergence watchdog. The same
    /// recipe as [`SessionBuilder::resume`], but reusing the live solve
    /// context and propagator (the layer slabs are shared through
    /// [`ParamStore::layers`], so the propagator sees the restored θ
    /// without a rebuild).
    fn restore_in_place(&mut self, c: Checkpoint) -> Result<()> {
        if c.rc.model.total_layers() != self.rc.model.total_layers()
            || c.rc.model.d_model != self.rc.model.d_model
        {
            bail!("rollback checkpoint has a different model geometry");
        }
        self.rc = c.rc.clone();
        *self.params.layers.write().unwrap() = c.layers;
        self.params.w_emb = c.w_emb;
        self.params.w_pos = c.w_pos;
        self.params.w_out = c.w_out;
        self.params.w_cls = c.w_cls;
        self.opt.restore_moments(c.opt_m, c.opt_v, c.opt_t);
        self.train_rng = Rng::from_parts(c.rng_state, c.rng_spare);
        self.step = c.step;
        self.initial_loss = c.initial_loss;
        self.switched_at = c.switched_at;
        self.warm_start = c.warm_start;
        let cs = c.controller;
        self.controller = AdaptiveController::restore(
            cs.probe_every,
            cs.rho_switch,
            cs.rho_grow,
            cs.max_iters,
            cs.step,
            cs.switched,
            cs.history_cap,
            cs.history,
        );
        // the cached hierarchies may have been built for controller-grown
        // iteration counts — drop them together with the now-stale warm
        // iterate, then re-seed the warm iterate from the snapshot (the
        // exact resume recipe, so the replay is bitwise identical)
        self.ctx.clear_warm();
        self.ctx.invalidate();
        if let Some(warm) = c.warm {
            let (bo, n_mid) = mid_range(&self.rc.model);
            for (dst, src) in self.ctx.fwd.ws.states[bo..=bo + n_mid].iter_mut().zip(&warm) {
                dst.copy_from(src);
            }
            self.ctx.fwd.mark_warm();
        }
        Ok(())
    }

    /// Validation metric over `n_batches` fresh batches (exact forward).
    /// Accuracy for token/sequence tasks; BLEU-4 for Translate. The sweep
    /// runs through the propagator's zero-allocation `step_into` ping-pong
    /// over two persistent workspace buffers — no per-batch state
    /// allocations (and still one dispatch for the whole sweep).
    pub fn evaluate(&mut self, n_batches: usize) -> f64 {
        let m = self.rc.model.clone();
        let n_layers = m.total_layers();
        let stacked = m.arch == Arch::EncDec;
        let mut rng = Rng::new(self.val_rng_seed);
        let mut acc = EvalAccum::default();
        for _ in 0..n_batches {
            let mut batch = std::mem::take(&mut self.batch_buf);
            self.objective.sample_into(&mut rng, &m, &mut batch);
            self.embed_into(&batch.tokens, batch.tgt_in.as_deref());
            {
                let ForwardWorkspace { states, pp, .. } = &mut self.ctx.fwd.ws;
                self.prop.step_to_into(0, n_layers, 1.0, &mut states[0], pp);
            }
            let x_final = self.ctx.fwd.ws.staged_head_view(0, stacked);
            self.objective.eval_batch(x_final, &self.params, &batch, &m, &mut acc);
            self.batch_buf = batch;
        }
        self.objective.metric(&acc)
    }

    /// Full training loop with periodic evaluation, running until the
    /// configured total step count (a resumed session picks up at its
    /// saved step and trains the remaining ones).
    pub fn train(&mut self) -> Result<TrainReport> {
        let mut report = TrainReport::default();
        let steps = self.rc.train.steps;
        let eval_every = self.rc.train.eval_every.max(1);
        while self.step < steps {
            let rec = self.train_step();
            if self.step % eval_every == 0 || self.step == steps {
                let metric = self.evaluate(2);
                report.evals.push(EvalRecord { step: self.step, metric });
            }
            let due = match &self.autosave {
                Some(a) if self.step % a.every == 0 || self.step == steps => {
                    Some((a.base.clone(), a.keep))
                }
                _ => None,
            };
            if let Some((base, keep)) = due {
                let path = crate::checkpoint::autosave_path(&base, self.step);
                match self.save(&path) {
                    Ok(()) => {
                        // the newest good snapshot is the watchdog's
                        // rollback target; pruning keeps the newest
                        // `keep`, so it never deletes this one
                        self.last_autosave = Some(path);
                        if keep > 0 {
                            crate::checkpoint::prune_autosaves(&base, keep);
                        }
                    }
                    Err(e) => {
                        // a failed snapshot must not kill a healthy run:
                        // record the typed event and train on (the atomic
                        // tmp+rename write protocol guarantees no partial
                        // .ltcp file was left behind)
                        crate::fault::record(
                            "checkpoint.autosave",
                            self.step as u64,
                            "autosave_failed",
                            e.to_string(),
                        );
                    }
                }
            }
            report.curve.push(rec);
        }
        report.final_loss = report.curve.last().map(|r| r.loss).unwrap_or(f32::NAN);
        report.final_metric = report.evals.last().map(|e| e.metric).unwrap_or(0.0);
        report.probes = self.controller.history().to_vec();
        report.phi_fwd = self.prop.counters().fwd();
        report.phi_vjp = self.prop.counters().vjp();
        report.switched_at = self.switched_at;
        report.anomalies = self.anomalies.clone();
        Ok(report)
    }
}
