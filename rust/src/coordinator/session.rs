//! `Session`: the composable training run of the Session API v2.
//!
//! A session is assembled from four orthogonal pieces by
//! [`SessionBuilder`]:
//!
//! ```text
//! Session::builder()
//!     .preset("mc")                         // or .config(RunConfig)
//!     .propagator(PropagatorKind::Rust)     // or Xla(Arc<XlaEngine>)
//!     .backend(Box::new(ThreadedMgrit::new(4)))   // or .workers(4)
//!     .objective(Box::new(TagObjective::new(..))) // or .task(Task::Tag)
//!     .build()?
//! ```
//!
//! Per batch: embed → (serial open buffers via `step_range`) → backend
//! forward solve over the ParallelNet → (serial close buffers) → objective
//! loss head → backend adjoint solve → parameter gradients → clip →
//! optimizer. The §3.2.3 controller probes the MGRIT convergence factor on
//! a cadence and can raise iteration counts or switch the run to serial.
//!
//! Data parallelism is executed as `dp` sequential micro-batches with
//! gradient averaging — bit-identical math to distributed replicas (the
//! *time* dimension of dp lives in `parallel::simulator`; this box has one
//! core, DESIGN.md §Substitutions).

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::adaptive::{AdaptiveController, ProbeRecord};
use crate::config::{presets, Arch, RunConfig};
use crate::model::{Init, ParamStore};
use crate::ode::{Propagator, RustPropagator, XlaPropagator};
use crate::opt::{clip_global_norm, Decay, LrSchedule, Optimizer};
use crate::runtime::XlaEngine;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

use super::backend::{backend_for_workers, Backend, Mgrit};
use super::heads;
use super::objective::{EvalAccum, HeadGrads, Objective, TrainBatch};
use super::range::RangeProp;
use super::trainer::Task;

/// One training-step record (drives the Fig. 3/4 curves).
#[derive(Debug, Clone)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f32,
    pub acc: f32,
    pub lr: f32,
    pub serial: bool,
    pub rho_fwd: Option<f64>,
    pub rho_bwd: Option<f64>,
}

/// Validation record: metric is accuracy (or BLEU for Translate).
#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub step: usize,
    pub metric: f64,
}

/// Everything a run produced.
#[derive(Debug, Clone, Default)]
pub struct TrainReport {
    pub curve: Vec<StepRecord>,
    pub evals: Vec<EvalRecord>,
    pub probes: Vec<ProbeRecord>,
    pub final_loss: f32,
    pub final_metric: f64,
    pub phi_fwd: u64,
    pub phi_vjp: u64,
    pub switched_at: Option<usize>,
}

/// Which Φ implementation a session runs on.
pub enum PropagatorKind {
    /// The pure-Rust reference transformer (artifact-free).
    Rust,
    /// AOT artifacts through PJRT (the production path).
    Xla(Arc<XlaEngine>),
}

/// Composable constructor for [`Session`]; every piece has a sensible
/// default derived from the run config.
pub struct SessionBuilder {
    rc: Option<RunConfig>,
    preset: Option<String>,
    task: Option<Task>,
    objective: Option<Box<dyn Objective>>,
    backend: Option<Box<dyn Backend>>,
    propagator: PropagatorKind,
    params: Option<ParamStore>,
    workers: Option<usize>,
    warm_start: bool,
}

impl SessionBuilder {
    /// Start from a named preset (resolved at `build`; unknown names error
    /// with the list of valid presets).
    pub fn preset(mut self, name: &str) -> Self {
        self.preset = Some(name.to_string());
        self
    }

    /// Start from an explicit run config (takes precedence over `preset`).
    pub fn config(mut self, rc: RunConfig) -> Self {
        self.rc = Some(rc);
        self
    }

    /// Select one of the paper's five tasks (default: derived from the
    /// config's preset name).
    pub fn task(mut self, task: Task) -> Self {
        self.task = Some(task);
        self
    }

    /// Plug in a custom training objective (overrides `task`).
    pub fn objective(mut self, objective: Box<dyn Objective>) -> Self {
        self.objective = Some(objective);
        self
    }

    /// Select the execution backend (default: [`Mgrit`], or
    /// `ThreadedMgrit` when `.workers(n > 1)` was given).
    pub fn backend(mut self, backend: Box<dyn Backend>) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Convenience backend selection: `n > 1` → `ThreadedMgrit { n }`.
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = Some(n);
        self
    }

    /// Select the Φ implementation (default: pure Rust).
    pub fn propagator(mut self, kind: PropagatorKind) -> Self {
        self.propagator = kind;
        self
    }

    /// Convenience: `Some(engine)` → XLA Φ, `None` → Rust Φ.
    pub fn engine(self, engine: Option<Arc<XlaEngine>>) -> Self {
        match engine {
            Some(e) => self.propagator(PropagatorKind::Xla(e)),
            None => self.propagator(PropagatorKind::Rust),
        }
    }

    /// Train from existing parameters (fine-tuning / comparison runs).
    pub fn params(mut self, params: ParamStore) -> Self {
        self.params = Some(params);
        self
    }

    /// Toggle TorchBraid-style warm starts of the forward solve.
    pub fn warm_start(mut self, on: bool) -> Self {
        self.warm_start = on;
        self
    }

    /// Assemble the session, resolving defaults and validating the preset
    /// and task names.
    pub fn build(self) -> Result<Session> {
        let rc = match (self.rc, self.preset) {
            (Some(rc), _) => rc,
            (None, Some(name)) => presets::by_name(&name).ok_or_else(|| {
                anyhow!("unknown preset '{}' (valid: {})", name, presets::ALL.join(", "))
            })?,
            (None, None) => bail!("Session::builder() needs .preset(..) or .config(..)"),
        };
        let objective: Box<dyn Objective> = match (self.objective, self.task) {
            (Some(o), _) => o,
            (None, Some(t)) => t.objective(&rc.model, rc.train.seed),
            (None, None) => Task::for_preset(&rc.name)?.objective(&rc.model, rc.train.seed),
        };
        let backend: Box<dyn Backend> = match (self.backend, self.workers) {
            (Some(_), Some(_)) => {
                bail!("SessionBuilder: .backend(..) and .workers(..) are both set — pick one \
                       (workers is shorthand for selecting Mgrit/ThreadedMgrit)")
            }
            (Some(b), None) => b,
            (None, Some(n)) => backend_for_workers(n),
            (None, None) => Box::new(Mgrit),
        };
        let params = match self.params {
            Some(p) => p,
            None => {
                let scheme =
                    if rc.model.total_layers() >= 64 { Init::DeepNet } else { Init::Default };
                ParamStore::init(&rc.model, scheme, rc.train.seed)
            }
        };
        let prop: Box<dyn Propagator> = match self.propagator {
            PropagatorKind::Rust => {
                Box::new(RustPropagator::for_model(&rc.model, params.layers.clone()))
            }
            PropagatorKind::Xla(e) => {
                Box::new(XlaPropagator::for_model(e, &rc.model, params.layers.clone())?)
            }
        };
        let opt = Optimizer::new(rc.train.opt, &params.group_sizes(), rc.train.weight_decay);
        let sched = LrSchedule {
            base_lr: rc.train.lr,
            warmup: rc.train.warmup,
            decay: if rc.train.warmup > 0 {
                Decay::Cosine { total: rc.train.steps, min_frac: 0.1 }
            } else {
                Decay::Constant
            },
        };
        let controller = AdaptiveController::new(if rc.train.adaptive {
            rc.train.probe_every
        } else {
            0
        });
        let seed = rc.train.seed;
        Ok(Session {
            rc,
            params,
            objective,
            backend,
            prop,
            opt,
            sched,
            controller,
            train_rng: Rng::new(seed.wrapping_mul(2) + 1),
            val_rng_seed: seed.wrapping_mul(2) + 2,
            warm: None,
            warm_start: self.warm_start,
            step: 0,
            initial_loss: None,
            switched_at: None,
        })
    }
}

/// A fully-wired training run (the paper's end-to-end procedure).
pub struct Session {
    pub rc: RunConfig,
    pub params: ParamStore,
    objective: Box<dyn Objective>,
    backend: Box<dyn Backend>,
    prop: Box<dyn Propagator>,
    opt: Optimizer,
    sched: LrSchedule,
    pub controller: AdaptiveController,
    train_rng: Rng,
    val_rng_seed: u64,
    /// Warm-start iterate for the MGRIT forward solve (TorchBraid-style).
    warm: Option<Vec<Tensor>>,
    pub warm_start: bool,
    step: usize,
    initial_loss: Option<f32>,
    switched_at: Option<usize>,
}

impl Session {
    /// Start assembling a session.
    pub fn builder() -> SessionBuilder {
        SessionBuilder {
            rc: None,
            preset: None,
            task: None,
            objective: None,
            backend: None,
            propagator: PropagatorKind::Rust,
            params: None,
            workers: None,
            warm_start: true,
        }
    }

    /// Compat shim for the v1 `TrainRun::new` signature: fresh parameters,
    /// `engine = None` → pure-Rust Φ.
    pub fn new(rc: RunConfig, task: Task, engine: Option<Arc<XlaEngine>>) -> Result<Session> {
        Session::builder().config(rc).task(task).engine(engine).build()
    }

    /// Compat shim for the v1 `TrainRun::from_params` signature.
    pub fn from_params(
        rc: RunConfig,
        task: Task,
        params: ParamStore,
        engine: Option<Arc<XlaEngine>>,
    ) -> Result<Session> {
        Session::builder().config(rc).task(task).params(params).engine(engine).build()
    }

    /// The active objective's short name.
    pub fn objective_name(&self) -> &'static str {
        self.objective.name()
    }

    /// The active backend's short name.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    fn mid_range(&self) -> (usize, usize) {
        let n = self.rc.model.total_layers();
        let bo = self.rc.model.buffer_open;
        let bc = self.rc.model.buffer_close;
        (bo, n - bo - bc)
    }

    /// Embed a batch into the propagator's state shape.
    fn embed(&self, tokens: &[i32], tgt_in: Option<&[i32]>) -> Tensor {
        let m = &self.rc.model;
        let x = heads::embed_fwd(tokens, &self.params.w_emb, &self.params.w_pos, m.batch, m.seq, m.d_model);
        match tgt_in {
            None => x,
            Some(t) => {
                let y = heads::embed_fwd(t, &self.params.w_emb, &self.params.w_pos, m.batch, m.seq, m.d_model);
                let mut data = Vec::with_capacity(x.len() * 2);
                data.extend_from_slice(x.data());
                data.extend_from_slice(y.data());
                Tensor::from_vec(data, &self.prop.state_shape())
            }
        }
    }

    /// Final decoder-side activation (the Y half for EncDec, x otherwise).
    fn head_view(&self, z: &Tensor) -> Tensor {
        let m = &self.rc.model;
        if m.arch == Arch::EncDec {
            let half = z.len() / 2;
            Tensor::from_vec(z.data()[half..].to_vec(), &[m.batch, m.seq, m.d_model])
        } else {
            z.clone()
        }
    }

    /// Lift a head cotangent back into the state shape.
    fn lift_ct(&self, lam_head: Tensor) -> Tensor {
        let m = &self.rc.model;
        if m.arch == Arch::EncDec {
            let mut data = vec![0.0f32; lam_head.len() * 2];
            data[lam_head.len()..].copy_from_slice(lam_head.data());
            Tensor::from_vec(data, &self.prop.state_shape())
        } else {
            lam_head
        }
    }

    /// One micro-batch: forward, loss, adjoint, gradients (no update).
    /// Returns (loss, acc, rho_fwd, rho_bwd, layer_grads, head_grads).
    #[allow(clippy::type_complexity)]
    fn micro_batch(
        &mut self,
        probe: bool,
    ) -> (f32, f32, Option<f64>, Option<f64>, Vec<Vec<f32>>, HeadGrads) {
        let m = self.rc.model.clone();
        let n_layers = m.total_layers();
        let (bo, n_mid) = self.mid_range();

        // --- sample a batch ---------------------------------------------
        let batch: TrainBatch = self.objective.sample(&mut self.train_rng, &m);

        // --- forward ------------------------------------------------------
        let z0 = self.embed(&batch.tokens, batch.tgt_in.as_deref());
        let mut states: Vec<Tensor> = Vec::with_capacity(n_layers + 1);
        states.push(z0);
        if bo > 0 {
            // open buffers: serial, batched under one dispatch (v2)
            let buf = self.prop.step_range(0, bo, 1.0, &states[0]);
            states.extend(buf);
        }
        let mid = RangeProp::new(self.prop.as_ref(), bo, n_mid);
        let fwd_iters = if probe {
            self.controller.probe_iters(&self.rc.mgrit).0
        } else {
            self.rc.mgrit.fwd_iters
        };
        let warm = if self.warm_start { self.warm.as_deref() } else { None };
        let (mid_states, fstats) =
            self.backend.forward(&mid, &self.rc.mgrit, &states[bo], fwd_iters, warm, probe);
        if self.warm_start && !fstats.serial {
            self.warm = Some(mid_states.clone());
        }
        states.extend(mid_states.into_iter().skip(1));
        if bo + n_mid < n_layers {
            // close buffers: serial
            let buf = self.prop.step_range(bo + n_mid, n_layers, 1.0, &states[bo + n_mid]);
            states.extend(buf);
        }

        // --- loss head ------------------------------------------------------
        let x_final = self.head_view(&states[n_layers]);
        let out = self.objective.loss(&x_final, &self.params, &batch, &m);
        let acc = out.correct / out.denom;

        // --- adjoint ---------------------------------------------------------
        let mut lams: Vec<Option<Tensor>> = vec![None; n_layers + 1];
        lams[n_layers] = Some(self.lift_ct(out.lam_head));
        let mut grads: Vec<Vec<f32>> = (0..n_layers)
            .map(|l| vec![0.0f32; self.prop.theta_len(l)])
            .collect();
        // close buffers: serial adjoint + grads
        for l in ((bo + n_mid)..n_layers).rev() {
            let lam_next = lams[l + 1].take().unwrap();
            self.prop.accumulate_grad(l, &states[l], &lam_next, &mut grads[l]);
            lams[l] = Some(self.prop.adjoint_step(l, 1.0, &states[l], &lam_next));
            lams[l + 1] = Some(lam_next);
        }
        // backend adjoint solve over the middle
        let bwd_iters = if probe {
            self.controller.probe_iters(&self.rc.mgrit).1
        } else {
            self.rc.mgrit.bwd_iters
        };
        let mid_states_ref = &states[bo..=bo + n_mid];
        let ct = lams[bo + n_mid].clone().unwrap();
        let (mid_lams, bstats) =
            self.backend.adjoint(&mid, &self.rc.mgrit, mid_states_ref, &ct, bwd_iters, probe);
        let mid_grads = self.backend.gradients(&mid, &self.rc.mgrit, mid_states_ref, &mid_lams);
        for (i, g) in mid_grads.into_iter().enumerate() {
            grads[bo + i] = g;
        }
        for (i, lam) in mid_lams.into_iter().enumerate() {
            lams[bo + i] = Some(lam);
        }
        // open buffers
        for l in (0..bo).rev() {
            let lam_next = lams[l + 1].take().unwrap();
            self.prop.accumulate_grad(l, &states[l], &lam_next, &mut grads[l]);
            lams[l] = Some(self.prop.adjoint_step(l, 1.0, &states[l], &lam_next));
            lams[l + 1] = Some(lam_next);
        }

        // --- embedding gradients ----------------------------------------------
        let lam0 = lams[0].take().unwrap();
        let mut g_emb = vec![0.0f32; self.params.w_emb.len()];
        let mut g_pos = vec![0.0f32; self.params.w_pos.len()];
        if m.arch == Arch::EncDec {
            let half = lam0.len() / 2;
            let inner = [m.batch, m.seq, m.d_model];
            let lx = Tensor::from_vec(lam0.data()[..half].to_vec(), &inner);
            let ly = Tensor::from_vec(lam0.data()[half..].to_vec(), &inner);
            heads::embed_bwd(&batch.tokens, &lx, m.batch, m.seq, m.d_model, &mut g_emb, &mut g_pos);
            heads::embed_bwd(
                batch.tgt_in.as_ref().unwrap(),
                &ly,
                m.batch,
                m.seq,
                m.d_model,
                &mut g_emb,
                &mut g_pos,
            );
        } else {
            heads::embed_bwd(&batch.tokens, &lam0, m.batch, m.seq, m.d_model, &mut g_emb, &mut g_pos);
        }

        let head = HeadGrads { emb: g_emb, pos: g_pos, ..out.head };
        (out.loss, acc, fstats.conv_factor(), bstats.conv_factor(), grads, head)
    }

    /// One full training step (dp micro-batches + probe + update).
    pub fn train_step(&mut self) -> StepRecord {
        self.step += 1;
        let probe = self.controller.should_probe();
        let dp = self.rc.dp_degree.max(1);

        let mut loss_sum = 0.0f32;
        let mut acc_sum = 0.0f32;
        let (mut rho_f, mut rho_b) = (None, None);
        let mut layer_grads: Option<Vec<Vec<f32>>> = None;
        let mut head_grads: Option<HeadGrads> = None;
        for rep in 0..dp {
            let (l, a, rf, rb, lg, hg) = self.micro_batch(probe && rep == 0);
            loss_sum += l;
            acc_sum += a;
            if rep == 0 {
                rho_f = rf;
                rho_b = rb;
            }
            // gradient allreduce (sum; averaged below)
            match (&mut layer_grads, lg) {
                (None, lg) => layer_grads = Some(lg),
                (Some(acc), lg) => {
                    for (a2, b2) in acc.iter_mut().zip(lg) {
                        for (x, y) in a2.iter_mut().zip(b2) {
                            *x += y;
                        }
                    }
                }
            }
            match (&mut head_grads, hg) {
                (None, hg) => head_grads = Some(hg),
                (Some(acc), hg) => acc.add(&hg),
            }
        }
        let mut layer_grads = layer_grads.unwrap();
        let mut head = head_grads.unwrap();
        if dp > 1 {
            let inv = 1.0 / dp as f32;
            for g in layer_grads.iter_mut() {
                g.iter_mut().for_each(|x| *x *= inv);
            }
            head.scale(inv);
        }
        let loss = loss_sum / dp as f32;
        let acc = acc_sum / dp as f32;

        // adaptive controller (probe result + divergence watchdog)
        if probe {
            self.controller.observe(rho_f, rho_b, &mut self.rc.mgrit);
            if self.controller.is_serial() && self.switched_at.is_none() {
                self.switched_at = Some(self.step);
            }
        }
        if self.initial_loss.is_none() {
            self.initial_loss = Some(loss);
        }
        if self.rc.train.adaptive
            && !self.controller.is_serial()
            && (!loss.is_finite() || loss > 3.0 * self.initial_loss.unwrap() + 1.0)
        {
            self.controller.force_serial(&mut self.rc.mgrit);
            self.switched_at = Some(self.step);
        }

        // clip + update
        {
            let mut refs: Vec<&mut [f32]> = layer_grads.iter_mut().map(|g| g.as_mut_slice()).collect();
            let mut head_refs = head.as_mut_refs();
            refs.append(&mut head_refs);
            clip_global_norm(&mut refs, self.rc.train.grad_clip);
        }
        // tasks only touch one head: fill the untouched groups with zeros
        HeadGrads::ensure_like(&mut head.emb, self.params.w_emb.len());
        HeadGrads::ensure_like(&mut head.pos, self.params.w_pos.len());
        HeadGrads::ensure_like(&mut head.out, self.params.w_out.len());
        HeadGrads::ensure_like(&mut head.cls, self.params.w_cls.len());
        let lr = self.sched.at(self.step);
        self.opt.begin_step();
        {
            // the only write-lock acquisition on the training path
            let mut layers = self.params.layers.write().unwrap();
            for (i, g) in layer_grads.iter().enumerate() {
                self.opt.update(i, lr, &mut layers[i], g);
            }
        }
        let nl = self.rc.model.total_layers();
        self.opt.update(nl, lr, &mut self.params.w_emb, &head.emb);
        self.opt.update(nl + 1, lr, &mut self.params.w_pos, &head.pos);
        self.opt.update(nl + 2, lr, &mut self.params.w_out, &head.out);
        self.opt.update(nl + 3, lr, &mut self.params.w_cls, &head.cls);

        StepRecord {
            step: self.step,
            loss,
            acc,
            lr,
            serial: self.rc.mgrit.is_serial()
                || self.controller.is_serial()
                || self.backend.forces_exact(),
            rho_fwd: rho_f,
            rho_bwd: rho_b,
        }
    }

    /// Validation metric over `n_batches` fresh batches (exact forward).
    /// Accuracy for token/sequence tasks; BLEU-4 for Translate.
    pub fn evaluate(&mut self, n_batches: usize) -> f64 {
        let m = self.rc.model.clone();
        let n_layers = m.total_layers();
        let mut rng = Rng::new(self.val_rng_seed);
        let mut acc = EvalAccum::default();
        for _ in 0..n_batches {
            let batch = self.objective.sample(&mut rng, &m);
            // exact serial forward for evaluation: rolling state, one
            // dispatch (lock/executable) for the whole sweep
            let z0 = self.embed(&batch.tokens, batch.tgt_in.as_deref());
            let z = self.prop.step_to(0, n_layers, 1.0, &z0);
            let x_final = self.head_view(&z);
            self.objective.eval_batch(&x_final, &self.params, &batch, &m, &mut acc);
        }
        self.objective.metric(&acc)
    }

    /// Full training loop with periodic evaluation.
    pub fn train(&mut self) -> Result<TrainReport> {
        let mut report = TrainReport::default();
        let steps = self.rc.train.steps;
        let eval_every = self.rc.train.eval_every.max(1);
        for _ in 0..steps {
            let rec = self.train_step();
            if self.step % eval_every == 0 || self.step == steps {
                let metric = self.evaluate(2);
                report.evals.push(EvalRecord { step: self.step, metric });
            }
            report.curve.push(rec);
        }
        report.final_loss = report.curve.last().map(|r| r.loss).unwrap_or(f32::NAN);
        report.final_metric = report.evals.last().map(|e| e.metric).unwrap_or(0.0);
        report.probes = self.controller.history.clone();
        report.phi_fwd = self.prop.counters().fwd();
        report.phi_vjp = self.prop.counters().vjp();
        report.switched_at = self.switched_at;
        Ok(report)
    }
}
