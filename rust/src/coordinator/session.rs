//! `Session`: the composable training run of the Session API v2.
//!
//! A session is assembled from four orthogonal pieces by
//! [`SessionBuilder`]:
//!
//! ```text
//! Session::builder()
//!     .preset("mc")                         // or .config(RunConfig)
//!     .propagator(PropagatorKind::Rust)     // or Xla(Arc<XlaEngine>)
//!     .backend(Box::new(ThreadedMgrit::new(4)))   // or .workers(4)
//!     .objective(Box::new(TagObjective::new(..))) // or .task(Task::Tag)
//!     .build()?
//! ```
//!
//! Per batch: embed → (serial open buffers, in place) → forward solve over
//! the ParallelNet → (serial close buffers) → objective loss head →
//! adjoint solve → parameter gradients → clip → optimizer. Every solve
//! runs on the session's persistent [`SolveContext`]: the MGRIT
//! hierarchies are cached across steps, states/λ/gradients *and* the
//! batch/loss-head buffers live in its [`StepWorkspace`] (plus the
//! session's long-lived `TrainBatch`), so the steady-state `train_step`
//! performs **zero** heap allocations — sampling, loss head, clipping and
//! all (pinned by `rust/tests/alloc_audit.rs`). The §3.2.3
//! controller probes the MGRIT convergence factor
//! on a cadence and can raise iteration counts or switch the run to
//! serial (which also drops the now-stale warm-start iterate).
//!
//! Data parallelism is executed as `dp` sequential micro-batches with
//! gradient averaging — bit-identical math to distributed replicas (the
//! *time* dimension of dp lives in `parallel::simulator`; this box has one
//! core, DESIGN.md §Substitutions).

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::adaptive::{AdaptiveController, ProbeRecord};
use crate::config::{presets, Arch, RunConfig};
use crate::model::{Init, ParamStore};
use crate::ode::{Propagator, RustPropagator, XlaPropagator};
use crate::opt::{Decay, LrSchedule, Optimizer};
use crate::runtime::XlaEngine;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

use super::backend::{backend_for_workers, Backend, Mgrit};
use super::context::{SolveContext, StepWorkspace};
use super::heads;
use super::objective::{EvalAccum, Objective, TrainBatch};
use super::range::RangeProp;
use super::trainer::Task;

/// One training-step record (drives the Fig. 3/4 curves).
#[derive(Debug, Clone)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f32,
    pub acc: f32,
    pub lr: f32,
    pub serial: bool,
    pub rho_fwd: Option<f64>,
    pub rho_bwd: Option<f64>,
}

/// Validation record: metric is accuracy (or BLEU for Translate).
#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub step: usize,
    pub metric: f64,
}

/// Everything a run produced.
#[derive(Debug, Clone, Default)]
pub struct TrainReport {
    pub curve: Vec<StepRecord>,
    pub evals: Vec<EvalRecord>,
    pub probes: Vec<ProbeRecord>,
    pub final_loss: f32,
    pub final_metric: f64,
    pub phi_fwd: u64,
    pub phi_vjp: u64,
    pub switched_at: Option<usize>,
}

/// Which Φ implementation a session runs on.
pub enum PropagatorKind {
    /// The pure-Rust reference transformer (artifact-free).
    Rust,
    /// AOT artifacts through PJRT (the production path).
    Xla(Arc<XlaEngine>),
}

/// Composable constructor for [`Session`]; every piece has a sensible
/// default derived from the run config.
pub struct SessionBuilder {
    rc: Option<RunConfig>,
    preset: Option<String>,
    task: Option<Task>,
    objective: Option<Box<dyn Objective>>,
    backend: Option<Box<dyn Backend>>,
    propagator: PropagatorKind,
    params: Option<ParamStore>,
    workers: Option<usize>,
    warm_start: bool,
}

impl SessionBuilder {
    /// Start from a named preset (resolved at `build`; unknown names error
    /// with the list of valid presets).
    pub fn preset(mut self, name: &str) -> Self {
        self.preset = Some(name.to_string());
        self
    }

    /// Start from an explicit run config (takes precedence over `preset`).
    pub fn config(mut self, rc: RunConfig) -> Self {
        self.rc = Some(rc);
        self
    }

    /// Select one of the paper's five tasks (default: derived from the
    /// config's preset name).
    pub fn task(mut self, task: Task) -> Self {
        self.task = Some(task);
        self
    }

    /// Plug in a custom training objective (overrides `task`).
    pub fn objective(mut self, objective: Box<dyn Objective>) -> Self {
        self.objective = Some(objective);
        self
    }

    /// Select the execution backend (default: [`Mgrit`], or
    /// `ThreadedMgrit` when `.workers(n > 1)` was given).
    pub fn backend(mut self, backend: Box<dyn Backend>) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Convenience backend selection: `n > 1` → `ThreadedMgrit { n }`.
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = Some(n);
        self
    }

    /// Select the Φ implementation (default: pure Rust).
    pub fn propagator(mut self, kind: PropagatorKind) -> Self {
        self.propagator = kind;
        self
    }

    /// Convenience: `Some(engine)` → XLA Φ, `None` → Rust Φ.
    pub fn engine(self, engine: Option<Arc<XlaEngine>>) -> Self {
        match engine {
            Some(e) => self.propagator(PropagatorKind::Xla(e)),
            None => self.propagator(PropagatorKind::Rust),
        }
    }

    /// Train from existing parameters (fine-tuning / comparison runs).
    pub fn params(mut self, params: ParamStore) -> Self {
        self.params = Some(params);
        self
    }

    /// Toggle TorchBraid-style warm starts of the forward solve.
    pub fn warm_start(mut self, on: bool) -> Self {
        self.warm_start = on;
        self
    }

    /// Assemble the session, resolving defaults and validating the preset
    /// and task names.
    pub fn build(self) -> Result<Session> {
        let rc = match (self.rc, self.preset) {
            (Some(rc), _) => rc,
            (None, Some(name)) => presets::by_name(&name).ok_or_else(|| {
                anyhow!("unknown preset '{}' (valid: {})", name, presets::ALL.join(", "))
            })?,
            (None, None) => bail!("Session::builder() needs .preset(..) or .config(..)"),
        };
        let objective: Box<dyn Objective> = match (self.objective, self.task) {
            (Some(o), _) => o,
            (None, Some(t)) => t.objective(&rc.model, rc.train.seed),
            (None, None) => Task::for_preset(&rc.name)?.objective(&rc.model, rc.train.seed),
        };
        let backend: Box<dyn Backend> = match (self.backend, self.workers) {
            (Some(_), Some(_)) => {
                bail!("SessionBuilder: .backend(..) and .workers(..) are both set — pick one \
                       (workers is shorthand for selecting Mgrit/ThreadedMgrit)")
            }
            (Some(b), None) => b,
            (None, Some(n)) => backend_for_workers(n),
            (None, None) => Box::new(Mgrit),
        };
        let params = match self.params {
            Some(p) => p,
            None => {
                let scheme =
                    if rc.model.total_layers() >= 64 { Init::DeepNet } else { Init::Default };
                ParamStore::init(&rc.model, scheme, rc.train.seed)
            }
        };
        let prop: Box<dyn Propagator> = match self.propagator {
            PropagatorKind::Rust => {
                Box::new(RustPropagator::for_model(&rc.model, params.layers.clone()))
            }
            PropagatorKind::Xla(e) => {
                Box::new(XlaPropagator::for_model(e, &rc.model, params.layers.clone())?)
            }
        };
        let opt = Optimizer::new(rc.train.opt, &params.group_sizes(), rc.train.weight_decay);
        let sched = LrSchedule {
            base_lr: rc.train.lr,
            warmup: rc.train.warmup,
            decay: if rc.train.warmup > 0 {
                Decay::Cosine { total: rc.train.steps, min_frac: 0.1 }
            } else {
                Decay::Constant
            },
        };
        let controller = AdaptiveController::new(if rc.train.adaptive {
            rc.train.probe_every
        } else {
            0
        });
        let seed = rc.train.seed;
        // persistent solve context: cached MGRIT hierarchies + the step
        // workspace, sized once from the session geometry
        let n_layers = rc.model.total_layers();
        let theta_lens: Vec<usize> = (0..n_layers).map(|l| prop.theta_len(l)).collect();
        let head_shape = [rc.model.batch, rc.model.seq, rc.model.d_model];
        let ws = StepWorkspace::new(
            n_layers,
            &prop.state_shape(),
            &head_shape,
            &theta_lens,
            [params.w_emb.len(), params.w_pos.len(), params.w_out.len(), params.w_cls.len()],
        );
        let ctx = SolveContext::new(backend, ws);
        Ok(Session {
            rc,
            params,
            objective,
            batch_buf: TrainBatch::default(),
            ctx,
            prop,
            opt,
            sched,
            controller,
            train_rng: Rng::new(seed.wrapping_mul(2) + 1),
            val_rng_seed: seed.wrapping_mul(2) + 2,
            warm_start: self.warm_start,
            step: 0,
            initial_loss: None,
            switched_at: None,
        })
    }
}

/// A fully-wired training run (the paper's end-to-end procedure).
pub struct Session {
    pub rc: RunConfig,
    pub params: ParamStore,
    objective: Box<dyn Objective>,
    /// Long-lived batch buffer, refilled in place by
    /// `Objective::sample_into` every micro-batch/eval batch (taken out of
    /// the session during the batch body to keep the borrows disjoint —
    /// a pointer move, not an allocation).
    batch_buf: TrainBatch,
    /// Persistent solve state: the backend strategy, both cached MGRIT
    /// hierarchies, the warm-start iterate, and the step workspace.
    ctx: SolveContext,
    prop: Box<dyn Propagator>,
    opt: Optimizer,
    sched: LrSchedule,
    pub controller: AdaptiveController,
    train_rng: Rng,
    val_rng_seed: u64,
    pub warm_start: bool,
    step: usize,
    initial_loss: Option<f32>,
    switched_at: Option<usize>,
}

impl Session {
    /// Start assembling a session.
    pub fn builder() -> SessionBuilder {
        SessionBuilder {
            rc: None,
            preset: None,
            task: None,
            objective: None,
            backend: None,
            propagator: PropagatorKind::Rust,
            params: None,
            workers: None,
            warm_start: true,
        }
    }

    /// Compat shim for the v1 `TrainRun::new` signature: fresh parameters,
    /// `engine = None` → pure-Rust Φ.
    pub fn new(rc: RunConfig, task: Task, engine: Option<Arc<XlaEngine>>) -> Result<Session> {
        Session::builder().config(rc).task(task).engine(engine).build()
    }

    /// Compat shim for the v1 `TrainRun::from_params` signature.
    pub fn from_params(
        rc: RunConfig,
        task: Task,
        params: ParamStore,
        engine: Option<Arc<XlaEngine>>,
    ) -> Result<Session> {
        Session::builder().config(rc).task(task).params(params).engine(engine).build()
    }

    /// The active objective's short name.
    pub fn objective_name(&self) -> &'static str {
        self.objective.name()
    }

    /// The active backend's short name.
    pub fn backend_name(&self) -> &'static str {
        self.ctx.backend().name()
    }

    /// Cached-hierarchy introspection: how many MGRIT cores this session's
    /// solve context has built so far (2 at steady state — one per solve
    /// direction — plus explicit rebuilds on cf/levels changes).
    pub fn solve_core_builds(&self) -> u64 {
        self.ctx.core_builds()
    }

    /// Drop the cached MGRIT hierarchies; the next solve rebuilds them.
    /// The explicit-rebuild hook for out-of-band solver-geometry changes
    /// (and the "fresh ctx" benchmark baseline).
    pub fn invalidate_solve_context(&mut self) {
        self.ctx.invalidate();
    }

    /// Is a TorchBraid-style warm-start iterate currently held?
    pub fn has_warm_iterate(&self) -> bool {
        self.ctx.has_warm()
    }

    fn mid_range(&self) -> (usize, usize) {
        let n = self.rc.model.total_layers();
        let bo = self.rc.model.buffer_open;
        let bc = self.rc.model.buffer_close;
        (bo, n - bo - bc)
    }

    /// Embed a batch into the propagator's state shape, written straight
    /// into the workspace's Z_0 buffer (no allocation).
    fn embed_into(&mut self, tokens: &[i32], tgt_in: Option<&[i32]>) {
        let m = &self.rc.model;
        let dst = self.ctx.ws.states[0].data_mut();
        let (we, wp) = (&self.params.w_emb, &self.params.w_pos);
        match tgt_in {
            None => heads::embed_into(tokens, we, wp, m.batch, m.seq, m.d_model, dst),
            Some(t) => {
                let half = dst.len() / 2;
                let (x, y) = dst.split_at_mut(half);
                heads::embed_into(tokens, we, wp, m.batch, m.seq, m.d_model, x);
                heads::embed_into(t, we, wp, m.batch, m.seq, m.d_model, y);
            }
        }
    }

    /// One micro-batch: forward, loss, adjoint, gradients (no update).
    /// Every state/adjoint/gradient lives in the solve context's step
    /// workspace; gradients *accumulate* there (zeroed once per training
    /// step, so dp micro-batches sum naturally). Returns
    /// (loss, acc, rho_fwd, rho_bwd).
    fn micro_batch(&mut self, probe: bool) -> (f32, f32, Option<f64>, Option<f64>) {
        let m = self.rc.model.clone();
        let n_layers = m.total_layers();
        let (bo, n_mid) = self.mid_range();
        let stacked = m.arch == Arch::EncDec;

        // --- sample a batch (into the session's long-lived buffer) ------
        let mut batch = std::mem::take(&mut self.batch_buf);
        self.objective.sample_into(&mut self.train_rng, &m, &mut batch);

        // --- forward ------------------------------------------------------
        self.embed_into(&batch.tokens, batch.tgt_in.as_deref());
        if bo > 0 {
            // open buffers: serial, in place, one dispatch for the sweep
            self.prop.step_seq_into(0, 1.0, &mut self.ctx.ws.states[..=bo]);
        }
        let mid = RangeProp::new(self.prop.as_ref(), bo, n_mid);
        let fwd_iters = if probe {
            self.controller.probe_iters(&self.rc.mgrit).0
        } else {
            self.rc.mgrit.fwd_iters
        };
        let fstats =
            self.ctx.forward_mid(&mid, &self.rc.mgrit, bo, fwd_iters, self.warm_start, probe);
        if bo + n_mid < n_layers {
            // close buffers: serial, in place, one dispatch for the sweep
            self.prop.step_seq_into(bo + n_mid, 1.0, &mut self.ctx.ws.states[bo + n_mid..]);
        }

        // --- loss head (workspace-reusing: cotangent into ws.lam_head,
        //     head gradients straight into the step accumulators) --------
        let out = {
            let (x_final, sink) = self.ctx.ws.head_view_and_sink(n_layers, stacked);
            self.objective.loss_into(x_final, &self.params, &batch, &m, sink)
        };
        let acc = out.correct / out.denom;

        // --- adjoint ---------------------------------------------------------
        {
            // seed λ_N: lift the head cotangent into the state shape
            let StepWorkspace { lams, lam_head, .. } = &mut self.ctx.ws;
            let lam_n = &mut lams[n_layers];
            if stacked {
                let half = lam_n.len() / 2;
                let d = lam_n.data_mut();
                d[..half].fill(0.0);
                d[half..].copy_from_slice(lam_head.data());
            } else {
                lam_n.copy_from(lam_head);
            }
        }
        {
            // close buffers: serial adjoint + grads
            let StepWorkspace { states, lams, grads, .. } = &mut self.ctx.ws;
            for l in ((bo + n_mid)..n_layers).rev() {
                let (lam_lo, lam_hi) = lams.split_at_mut(l + 1);
                self.prop.accumulate_grad(l, &states[l], &lam_hi[0], &mut grads[l]);
                self.prop.adjoint_step_into(l, 1.0, &states[l], &lam_hi[0], &mut lam_lo[l]);
            }
        }
        // backend adjoint solve + mid-range gradients on the cached cores
        let bwd_iters = if probe {
            self.controller.probe_iters(&self.rc.mgrit).1
        } else {
            self.rc.mgrit.bwd_iters
        };
        let bstats = self.ctx.adjoint_mid(&mid, &self.rc.mgrit, bo, bwd_iters, probe);
        self.ctx.gradients_mid(&mid, bo);
        {
            // open buffers
            let StepWorkspace { states, lams, grads, .. } = &mut self.ctx.ws;
            for l in (0..bo).rev() {
                let (lam_lo, lam_hi) = lams.split_at_mut(l + 1);
                self.prop.accumulate_grad(l, &states[l], &lam_hi[0], &mut grads[l]);
                self.prop.adjoint_step_into(l, 1.0, &states[l], &lam_hi[0], &mut lam_lo[l]);
            }
        }

        // --- embedding gradients ----------------------------------------------
        {
            let StepWorkspace { lams, g_emb, g_pos, .. } = &mut self.ctx.ws;
            let lam0 = lams[0].data();
            if stacked {
                let half = lam0.len() / 2;
                heads::embed_bwd(
                    &batch.tokens,
                    &lam0[..half],
                    m.batch,
                    m.seq,
                    m.d_model,
                    g_emb,
                    g_pos,
                );
                heads::embed_bwd(
                    batch.tgt_in.as_ref().unwrap(),
                    &lam0[half..],
                    m.batch,
                    m.seq,
                    m.d_model,
                    g_emb,
                    g_pos,
                );
            } else {
                heads::embed_bwd(&batch.tokens, lam0, m.batch, m.seq, m.d_model, g_emb, g_pos);
            }
        }
        // hand the batch buffer back for the next micro-batch (the head
        // gradients were already accumulated by loss_into)
        self.batch_buf = batch;
        (out.loss, acc, fstats.conv_factor(), bstats.conv_factor())
    }

    /// One full training step (dp micro-batches + probe + update).
    pub fn train_step(&mut self) -> StepRecord {
        self.step += 1;
        let probe = self.controller.should_probe();
        let dp = self.rc.dp_degree.max(1);
        self.ctx.ws.zero_grads();

        let mut loss_sum = 0.0f32;
        let mut acc_sum = 0.0f32;
        let (mut rho_f, mut rho_b) = (None, None);
        for rep in 0..dp {
            // gradient allreduce with replica semantics: each micro-batch
            // sums into fresh zeroed accumulators (the running sum is
            // parked in the dp scratch set meanwhile) and the per-replica
            // totals are then added — bit-identical to v1 / distributed
            // summation, unlike accumulating element updates in place
            if rep > 0 {
                self.ctx.ws.stash_grads();
            }
            let (l, a, rf, rb) = self.micro_batch(probe && rep == 0);
            if rep > 0 {
                self.ctx.ws.fold_stashed_grads();
            }
            loss_sum += l;
            acc_sum += a;
            if rep == 0 {
                rho_f = rf;
                rho_b = rb;
            }
        }
        if dp > 1 {
            self.ctx.ws.scale_grads(1.0 / dp as f32);
        }
        let loss = loss_sum / dp as f32;
        let acc = acc_sum / dp as f32;

        // adaptive controller (probe result + divergence watchdog)
        if probe {
            self.controller.observe(rho_f, rho_b, &mut self.rc.mgrit);
            if self.controller.is_serial() && self.switched_at.is_none() {
                self.switched_at = Some(self.step);
            }
        }
        if self.initial_loss.is_none() {
            self.initial_loss = Some(loss);
        }
        if self.rc.train.adaptive
            && !self.controller.is_serial()
            && (!loss.is_finite() || loss > 3.0 * self.initial_loss.unwrap() + 1.0)
        {
            self.controller.force_serial(&mut self.rc.mgrit);
            self.switched_at = Some(self.step);
        }
        if self.controller.is_serial() {
            // the switch is sticky: the warm iterate is dead memory (and
            // would poison a later non-serial run restored from this
            // session) and the cached hierarchies will never be solved on
            // again — drop both at the switch, not lazily
            self.ctx.clear_warm();
            self.ctx.invalidate();
        }

        // clip + update straight from the workspace accumulators (the
        // untouched head groups are full-size zeros, so including them
        // changes neither the norm nor the updates); clip_global walks the
        // accumulators directly — no per-step ref-list allocation
        self.ctx.ws.clip_global(self.rc.train.grad_clip);
        let lr = self.sched.at(self.step);
        self.opt.begin_step();
        {
            // the only write-lock acquisition on the training path
            let mut layers = self.params.layers.write().unwrap();
            for (i, g) in self.ctx.ws.grads.iter().enumerate() {
                self.opt.update(i, lr, &mut layers[i], g);
            }
        }
        let nl = self.rc.model.total_layers();
        self.opt.update(nl, lr, &mut self.params.w_emb, &self.ctx.ws.g_emb);
        self.opt.update(nl + 1, lr, &mut self.params.w_pos, &self.ctx.ws.g_pos);
        self.opt.update(nl + 2, lr, &mut self.params.w_out, &self.ctx.ws.g_out);
        self.opt.update(nl + 3, lr, &mut self.params.w_cls, &self.ctx.ws.g_cls);

        StepRecord {
            step: self.step,
            loss,
            acc,
            lr,
            serial: self.rc.mgrit.is_serial()
                || self.controller.is_serial()
                || self.ctx.backend().forces_exact(),
            rho_fwd: rho_f,
            rho_bwd: rho_b,
        }
    }

    /// Validation metric over `n_batches` fresh batches (exact forward).
    /// Accuracy for token/sequence tasks; BLEU-4 for Translate. The sweep
    /// runs through the propagator's zero-allocation `step_into` ping-pong
    /// over two persistent workspace buffers — no per-batch state
    /// allocations (and still one dispatch for the whole sweep).
    pub fn evaluate(&mut self, n_batches: usize) -> f64 {
        let m = self.rc.model.clone();
        let n_layers = m.total_layers();
        let stacked = m.arch == Arch::EncDec;
        let mut rng = Rng::new(self.val_rng_seed);
        let mut acc = EvalAccum::default();
        for _ in 0..n_batches {
            let mut batch = std::mem::take(&mut self.batch_buf);
            self.objective.sample_into(&mut rng, &m, &mut batch);
            self.embed_into(&batch.tokens, batch.tgt_in.as_deref());
            {
                let StepWorkspace { states, pp, .. } = &mut self.ctx.ws;
                self.prop.step_to_into(0, n_layers, 1.0, &mut states[0], pp);
            }
            let x_final = stage_head_view(&mut self.ctx.ws, 0, stacked);
            self.objective.eval_batch(x_final, &self.params, &batch, &m, &mut acc);
            self.batch_buf = batch;
        }
        self.objective.metric(&acc)
    }

    /// Full training loop with periodic evaluation.
    pub fn train(&mut self) -> Result<TrainReport> {
        let mut report = TrainReport::default();
        let steps = self.rc.train.steps;
        let eval_every = self.rc.train.eval_every.max(1);
        for _ in 0..steps {
            let rec = self.train_step();
            if self.step % eval_every == 0 || self.step == steps {
                let metric = self.evaluate(2);
                report.evals.push(EvalRecord { step: self.step, metric });
            }
            report.curve.push(rec);
        }
        report.final_loss = report.curve.last().map(|r| r.loss).unwrap_or(f32::NAN);
        report.final_metric = report.evals.last().map(|e| e.metric).unwrap_or(0.0);
        report.probes = self.controller.history.clone();
        report.phi_fwd = self.prop.counters().fwd();
        report.phi_vjp = self.prop.counters().vjp();
        report.switched_at = self.switched_at;
        Ok(report)
    }
}

/// Stage the loss head's input for workspace state `idx` (delegates to the
/// single decoder-half-split implementation in `context`).
fn stage_head_view(ws: &mut StepWorkspace, idx: usize, stacked: bool) -> &Tensor {
    let StepWorkspace { states, head, .. } = ws;
    super::context::staged_head_view(states, head, idx, stacked)
}
