//! `Session`: the composable training run of the Session API v2.
//!
//! A session is assembled from four orthogonal pieces by
//! [`SessionBuilder`]:
//!
//! ```text
//! Session::builder()
//!     .preset("mc")                         // or .config(RunConfig)
//!     .propagator(PropagatorKind::Rust)     // or Xla(Arc<XlaEngine>)
//!     .backend(Box::new(ThreadedMgrit::new(4)))   // or .workers(4)
//!     .objective(Box::new(TagObjective::new(..))) // or .task(Task::Tag)
//!     .build()?
//! ```
//!
//! Per batch: embed → full forward on the shared train/infer core
//! ([`super::context::ForwardContext::forward_full`]: serial open
//! buffers, MGRIT mid solve, serial close buffers) → objective loss head
//! → adjoint solve →
//! parameter gradients → clip → optimizer. Every solve runs on the
//! session's persistent [`SolveContext`]: the MGRIT hierarchies are cached
//! across steps, states/λ/gradients *and* the batch/loss-head buffers live
//! in its workspaces (plus the session's long-lived `TrainBatch`), so the
//! steady-state `train_step` performs **zero** heap allocations —
//! sampling, loss head, clipping and all (pinned by
//! `rust/tests/alloc_audit.rs`). The §3.2.3 controller probes the MGRIT
//! convergence factor on a cadence and can raise iteration counts or
//! switch the run to serial (which also drops the now-stale warm-start
//! iterate).
//!
//! ## Self-healing
//!
//! [`Session::train_step`] wraps the raw step in the recovery policies of
//! [`crate::fault`]: a non-finite guard that skips the optimizer update
//! (Adam's moments never see NaN) and replays the batch from a rewound
//! RNG/step/controller snapshot, and a divergence watchdog that
//! auto-rolls back to the newest successful autosave — restoring
//! parameters, moments, RNG, controller and warm iterate in place — before
//! falling back to the §3.2.3 serial switch. Every recovery is recorded as
//! a typed [`StepAnomaly`] (surfaced via [`TrainReport`]) and mirrored
//! into the global fault-event log. Autosave writes are atomic
//! (tmp + fsync + rename, [`crate::checkpoint`]), and a *failed* autosave
//! is a recorded event, not a dead run.
//!
//! ## Checkpointing
//!
//! [`Session::save`] writes a [`crate::checkpoint::Checkpoint`] capturing
//! the run config (including controller-mutated MGRIT iteration counts),
//! parameters, optimizer moments, adaptive-controller state, the training
//! RNG stream, the step counter, and the warm-start iterate.
//! [`Session::resume`] (or [`SessionBuilder::resume`], to also pick a
//! backend/propagator) rebuilds a session that continues the run **bitwise
//! identically** to the uninterrupted original — pinned by
//! `rust/tests/checkpoint_roundtrip.rs`.
//!
//! ## Data parallelism
//!
//! `--dp N` runs N real concurrent replicas: each [`Replica`] owns a full
//! `SolveContext` (its own MGRIT hierarchies, workspaces and relaxation
//! backend/pool) plus an endpoint on a dp-wide gradient [`Fabric`].
//! `--dp-workers D` (or the simulator-scored auto-split of `--workers`)
//! picks how many replica *lanes* run at once on the session's scheduler
//! [`WorkerPool`]; batches are pre-sampled on the coordinator thread in
//! ascending replica order and gradients are folded back into replica 0
//! in the same strictly left-associated ascending order the serialized
//! stash/fold scratch used — so every lane count (including 1) trains
//! **bitwise identically** (pinned by `rust/tests/dp_parity.rs`). See
//! `parallel/mod.rs` §"DP×LP execution" for the rank layout and split
//! rules.

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::adaptive::{AdaptiveController, ProbeRecord};
use crate::checkpoint::{Checkpoint, ControllerState};
use crate::config::{presets, Arch, RunConfig};
use crate::model::{Init, ParamStore};
use crate::ode::{Propagator, RustPropagator, XlaPropagator};
use crate::opt::{Decay, LrSchedule, Optimizer};
use crate::parallel::comm::Endpoint;
use crate::parallel::{
    auto_split, slab_range, DeviceModel, Fabric, SimConfig, Simulator, WorkerPool, Workspace,
};
use crate::runtime::XlaEngine;
use crate::util::json::{self, Json};
use crate::util::rng::Rng;

use super::backend::{backend_for_workers, Backend, Mgrit, Serial};
use super::context::{mid_range, ForwardWorkspace, SolveContext, StepWorkspace};
use super::heads;
use super::objective::{EvalAccum, Objective, TrainBatch};
use super::trainer::Task;

/// One training-step record (drives the Fig. 3/4 curves).
#[derive(Debug, Clone)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f32,
    pub acc: f32,
    pub lr: f32,
    pub serial: bool,
    pub rho_fwd: Option<f64>,
    pub rho_bwd: Option<f64>,
}

impl StepRecord {
    pub fn to_json(&self) -> Json {
        let rho = |v: Option<f64>| v.map(finite_num).unwrap_or(Json::Null);
        json::obj(vec![
            ("step", json::int(self.step as i64)),
            ("loss", finite_num(self.loss as f64)),
            ("acc", finite_num(self.acc as f64)),
            ("lr", finite_num(self.lr as f64)),
            ("serial", Json::Bool(self.serial)),
            ("rho_fwd", rho(self.rho_fwd)),
            ("rho_bwd", rho(self.rho_bwd)),
        ])
    }
}

/// Policy-1 cap: consecutive rewound attempts of one training step before
/// the session escalates (serial switch for an adaptive MGRIT run, then
/// giving the step up with the update skipped).
pub const MAX_STEP_RETRIES: u32 = 3;

/// Policy-2 cap: auto-rollbacks per session before the divergence watchdog
/// falls back to the plain serial switch.
pub const MAX_ROLLBACKS: u32 = 2;

/// Classes of recovered training anomalies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AnomalyKind {
    /// The batch loss came back NaN/Inf.
    NonFiniteLoss,
    /// The global gradient norm came back NaN/Inf (loss still finite).
    NonFiniteGrad,
    /// The §3.2.3 divergence watchdog tripped on a finite loss.
    Divergence,
}

impl AnomalyKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            AnomalyKind::NonFiniteLoss => "non_finite_loss",
            AnomalyKind::NonFiniteGrad => "non_finite_grad",
            AnomalyKind::Divergence => "divergence",
        }
    }
}

/// A training-step anomaly the session *recovered from* (a policy record,
/// not an error): the optimizer update was skipped or rolled back instead
/// of poisoning the Adam moments. Collected on [`Session`], surfaced
/// through [`TrainReport::anomalies`], and mirrored into the global
/// [`crate::fault`] event log.
#[derive(Debug, Clone)]
pub struct StepAnomaly {
    /// Step counter at detection (the step whose attempt misbehaved).
    pub step: usize,
    pub kind: AnomalyKind,
    /// Human-readable diagnostics (loss / grad-norm values, rollback target).
    pub detail: String,
}

impl StepAnomaly {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("step", json::int(self.step as i64)),
            ("kind", json::s(self.kind.as_str())),
            ("detail", json::s(&self.detail)),
        ])
    }
}

/// Validation record: metric is accuracy (or BLEU for Translate).
#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub step: usize,
    pub metric: f64,
}

impl EvalRecord {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("step", json::int(self.step as i64)),
            ("metric", finite_num(self.metric)),
        ])
    }
}

/// JSON numbers are IEEE doubles with no NaN/Inf encoding; map them to
/// null so a diverged run still writes a parseable report.
fn finite_num(v: f64) -> Json {
    if v.is_finite() {
        json::num(v)
    } else {
        Json::Null
    }
}

/// Everything a run produced.
#[derive(Debug, Clone, Default)]
pub struct TrainReport {
    pub curve: Vec<StepRecord>,
    pub evals: Vec<EvalRecord>,
    pub probes: Vec<ProbeRecord>,
    pub final_loss: f32,
    pub final_metric: f64,
    pub phi_fwd: u64,
    pub phi_vjp: u64,
    pub switched_at: Option<usize>,
    /// Every anomaly the self-healing policies recovered from, in order.
    /// After a rollback the curve may hold duplicate step numbers (the
    /// replayed span) — this list is how a reader tells the two runs apart.
    pub anomalies: Vec<StepAnomaly>,
}

impl TrainReport {
    /// Machine-readable run record (`layertime train --report out.json`):
    /// the full step curve, eval points, and the retained §3.2.3 probe
    /// history — everything the Fig. 4/5-style plots need, with no stdout
    /// scraping.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("curve", json::arr(self.curve.iter().map(|r| r.to_json()).collect())),
            ("evals", json::arr(self.evals.iter().map(|e| e.to_json()).collect())),
            ("probes", json::arr(self.probes.iter().map(|p| p.to_json()).collect())),
            ("final_loss", finite_num(self.final_loss as f64)),
            ("final_metric", finite_num(self.final_metric)),
            ("phi_fwd", json::int(self.phi_fwd as i64)),
            ("phi_vjp", json::int(self.phi_vjp as i64)),
            (
                "switched_at",
                self.switched_at.map(|s| json::int(s as i64)).unwrap_or(Json::Null),
            ),
            ("anomalies", json::arr(self.anomalies.iter().map(|a| a.to_json()).collect())),
        ])
    }
}

/// Which Φ implementation a session runs on.
pub enum PropagatorKind {
    /// The pure-Rust reference transformer (artifact-free).
    Rust,
    /// AOT artifacts through PJRT (the production path).
    Xla(Arc<XlaEngine>),
}

/// Composable constructor for [`Session`]; every piece has a sensible
/// default derived from the run config.
pub struct SessionBuilder {
    rc: Option<RunConfig>,
    preset: Option<String>,
    task: Option<Task>,
    objective: Option<Box<dyn Objective>>,
    backend: Option<Box<dyn Backend>>,
    propagator: PropagatorKind,
    params: Option<ParamStore>,
    workers: Option<usize>,
    dp_workers: Option<usize>,
    warm_start: bool,
    resume: Option<String>,
}

impl SessionBuilder {
    /// Start from a named preset (resolved at `build`; unknown names error
    /// with the list of valid presets).
    pub fn preset(mut self, name: &str) -> Self {
        self.preset = Some(name.to_string());
        self
    }

    /// Start from an explicit run config (takes precedence over `preset`).
    pub fn config(mut self, rc: RunConfig) -> Self {
        self.rc = Some(rc);
        self
    }

    /// Resume from a checkpoint written by [`Session::save`]. The run
    /// config, parameters, optimizer moments, adaptive state, RNG stream,
    /// step counter and warm-start iterate all come from the file —
    /// mutually exclusive with `.preset` / `.config` / `.params`. The
    /// execution pieces (`.backend` / `.workers` / `.propagator`) remain
    /// free: solves are bitwise identical across backends, so resuming on
    /// a different worker count continues the exact same run.
    pub fn resume(mut self, path: &str) -> Self {
        self.resume = Some(path.to_string());
        self
    }

    /// Select one of the paper's five tasks (default: derived from the
    /// config's preset name).
    pub fn task(mut self, task: Task) -> Self {
        self.task = Some(task);
        self
    }

    /// Plug in a custom training objective (overrides `task`).
    pub fn objective(mut self, objective: Box<dyn Objective>) -> Self {
        self.objective = Some(objective);
        self
    }

    /// Select the execution backend (default: [`Mgrit`], or
    /// `ThreadedMgrit` when `.workers(n > 1)` was given).
    pub fn backend(mut self, backend: Box<dyn Backend>) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Convenience backend selection: `n > 1` → `ThreadedMgrit { n }`.
    /// When the config has `dp_degree > 1` the budget is split across the
    /// two axes (see [`SessionBuilder::dp_workers`]); a bare `.workers(n)`
    /// lets the simulator's auto-split heuristic pick the split.
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = Some(n);
        self
    }

    /// Concurrent replica lanes for data parallelism: how many of the
    /// session's `dp_degree` replicas run their micro-batches at the same
    /// time (clamped to `1..=dp`). With `.workers(n)` the per-replica
    /// relaxation budget becomes `max(n / dp_workers, 1)`. Default: the
    /// simulator-scored auto-split of the worker budget
    /// ([`crate::parallel::auto_split`]) when `dp > 1`, else 1. Purely an
    /// execution choice — every value trains bitwise identically.
    pub fn dp_workers(mut self, n: usize) -> Self {
        self.dp_workers = Some(n);
        self
    }

    /// Select the Φ implementation (default: pure Rust).
    pub fn propagator(mut self, kind: PropagatorKind) -> Self {
        self.propagator = kind;
        self
    }

    /// Convenience: `Some(engine)` → XLA Φ, `None` → Rust Φ.
    pub fn engine(self, engine: Option<Arc<XlaEngine>>) -> Self {
        match engine {
            Some(e) => self.propagator(PropagatorKind::Xla(e)),
            None => self.propagator(PropagatorKind::Rust),
        }
    }

    /// Train from existing parameters (fine-tuning / comparison runs).
    pub fn params(mut self, params: ParamStore) -> Self {
        self.params = Some(params);
        self
    }

    /// Toggle TorchBraid-style warm starts of the forward solve.
    pub fn warm_start(mut self, on: bool) -> Self {
        self.warm_start = on;
        self
    }

    /// Assemble the session, resolving defaults and validating the preset
    /// and task names (and, when resuming, the checkpoint).
    pub fn build(self) -> Result<Session> {
        let ck = match &self.resume {
            Some(path) => {
                if self.rc.is_some() || self.preset.is_some() || self.params.is_some() {
                    bail!(
                        "SessionBuilder: .resume(..) carries its own config and parameters — \
                         drop .preset/.config/.params"
                    );
                }
                Some(Checkpoint::read(path)?)
            }
            None => None,
        };
        let rc = match (&ck, self.rc, self.preset) {
            (Some(c), _, _) => c.rc.clone(),
            (None, Some(rc), _) => rc,
            (None, None, Some(name)) => presets::by_name(&name).ok_or_else(|| {
                anyhow!("unknown preset '{}' (valid: {})", name, presets::ALL.join(", "))
            })?,
            (None, None, None) => bail!("Session::builder() needs .preset(..) or .config(..)"),
        };
        let objective: Box<dyn Objective> = match (self.objective, self.task) {
            (Some(o), _) => o,
            (None, Some(t)) => t.objective(&rc.model, rc.train.seed),
            (None, None) => Task::for_preset(&rc.name)?.objective(&rc.model, rc.train.seed),
        };
        // split the worker budget across the dp×lp axes: `dp_workers`
        // concurrent replica lanes, each driving an lp-worker relaxation
        // backend (explicit .dp_workers, or the simulator's convex
        // auto-split of a bare .workers budget)
        let dp = rc.dp_degree.max(1);
        let (backend, dp_workers): (Box<dyn Backend>, usize) = match (self.backend, self.workers) {
            (Some(_), Some(_)) => {
                bail!("SessionBuilder: .backend(..) and .workers(..) are both set — pick one \
                       (workers is shorthand for selecting Mgrit/ThreadedMgrit)")
            }
            (Some(b), None) => (b, self.dp_workers.unwrap_or(1).clamp(1, dp)),
            (None, Some(n)) => {
                let n = n.max(1);
                let d = match self.dp_workers {
                    Some(d) => d.clamp(1, dp),
                    None if dp > 1 && n > 1 => {
                        auto_split(n, dp, |dw, lw| split_cost(&rc, dw, lw)).dp
                    }
                    None => 1,
                };
                (backend_for_workers((n / d).max(1)), d)
            }
            (None, None) => (Box::new(Mgrit), self.dp_workers.unwrap_or(1).clamp(1, dp)),
        };
        let params = match &ck {
            Some(c) => ParamStore::from_parts(
                rc.model.clone(),
                c.layers.clone(),
                c.w_emb.clone(),
                c.w_pos.clone(),
                c.w_out.clone(),
                c.w_cls.clone(),
            ),
            None => match self.params {
                Some(p) => p,
                None => {
                    let scheme =
                        if rc.model.total_layers() >= 64 { Init::DeepNet } else { Init::Default };
                    ParamStore::init(&rc.model, scheme, rc.train.seed)
                }
            },
        };
        let prop: Box<dyn Propagator> = match self.propagator {
            PropagatorKind::Rust => {
                Box::new(RustPropagator::for_model(&rc.model, params.layers.clone()))
            }
            PropagatorKind::Xla(e) => {
                Box::new(XlaPropagator::for_model(e, &rc.model, params.layers.clone())?)
            }
        };
        let mut opt = Optimizer::new(rc.train.opt, &params.group_sizes(), rc.train.weight_decay);
        let sched = LrSchedule {
            base_lr: rc.train.lr,
            warmup: rc.train.warmup,
            decay: if rc.train.warmup > 0 {
                Decay::Cosine { total: rc.train.steps, min_frac: 0.1 }
            } else {
                Decay::Constant
            },
        };
        let controller = AdaptiveController::new(if rc.train.adaptive {
            rc.train.probe_every
        } else {
            0
        });
        let seed = rc.train.seed;
        // persistent solve context: cached MGRIT hierarchies + the shared
        // forward workspace + the training step workspace, sized once from
        // the session geometry
        let n_layers = rc.model.total_layers();
        let theta_lens: Vec<usize> = (0..n_layers).map(|l| prop.theta_len(l)).collect();
        let head_shape = [rc.model.batch, rc.model.seq, rc.model.d_model];
        let state_shape = prop.state_shape();
        let head_sizes =
            [params.w_emb.len(), params.w_pos.len(), params.w_out.len(), params.w_cls.len()];
        // one replica per dp degree, each with a full solve context and —
        // when dp > 1 — an endpoint on the dp-wide gradient fabric
        let mut fabric = if dp > 1 { Some(Fabric::new(dp)) } else { None };
        let mut backends: Vec<Box<dyn Backend>> =
            (1..dp).map(|_| replica_backend(backend.as_ref())).collect();
        backends.insert(0, backend);
        let mut replicas: Vec<Replica> = backends
            .into_iter()
            .enumerate()
            .map(|(r, b)| Replica {
                ctx: SolveContext::new(
                    b,
                    ForwardWorkspace::new(n_layers, &state_shape, &head_shape),
                    StepWorkspace::new(
                        n_layers,
                        &state_shape,
                        &head_shape,
                        &theta_lens,
                        head_sizes,
                    ),
                ),
                batch: TrainBatch::default(),
                ep: fabric.as_mut().map(|f| f.take(r)),
                loss: 0.0,
                acc: 0.0,
                rho_f: None,
                rho_b: None,
            })
            .collect();
        // checkpoint restore: every stateful piece beyond params/config
        let (mut train_rng, mut step, mut initial_loss, mut switched_at, mut warm_start) =
            (Rng::new(seed.wrapping_mul(2) + 1), 0usize, None, None, self.warm_start);
        let controller = match ck {
            None => controller,
            Some(c) => {
                opt.restore_moments(c.opt_m, c.opt_v, c.opt_t);
                train_rng = Rng::from_parts(c.rng_state, c.rng_spare);
                step = c.step;
                initial_loss = c.initial_loss;
                switched_at = c.switched_at;
                warm_start = c.warm_start;
                if let Some(warm) = c.warm {
                    let (bo, n_mid) = mid_range(&rc.model);
                    // Checkpoint::read validated the replica-major count
                    // (dp × (n_mid + 1)) and element sizes against the
                    // config's state shape
                    let per = n_mid + 1;
                    for (r, rep) in replicas.iter_mut().enumerate() {
                        let src = &warm[r * per..(r + 1) * per];
                        for (dst, s) in rep.ctx.fwd.ws.states[bo..=bo + n_mid].iter_mut().zip(src)
                        {
                            dst.copy_from(s);
                        }
                        rep.ctx.fwd.mark_warm();
                    }
                }
                let cs = c.controller;
                AdaptiveController::restore(
                    cs.probe_every,
                    cs.rho_switch,
                    cs.rho_grow,
                    cs.max_iters,
                    cs.step,
                    cs.switched,
                    cs.history_cap,
                    cs.history,
                )
            }
        };
        Ok(Session {
            rc,
            params,
            objective,
            replicas,
            dp_workers,
            dp_pool: None,
            prop,
            opt,
            sched,
            controller,
            train_rng,
            val_rng_seed: seed.wrapping_mul(2) + 2,
            warm_start,
            step,
            initial_loss,
            switched_at,
            autosave: None,
            last_autosave: None,
            consec_anomalies: 0,
            rollbacks: 0,
            anomalies: Vec::new(),
        })
    }
}

/// A fully-wired training run (the paper's end-to-end procedure).
pub struct Session {
    pub rc: RunConfig,
    pub params: ParamStore,
    objective: Box<dyn Objective>,
    /// The dp data-parallel replicas (always ≥ 1). Replica 0 is the
    /// coordinator: probes, the gradient fold, the optimizer read and
    /// evaluation all go through it; replicas 1.. mirror its solve
    /// strategy with their own contexts, batch buffers and fabric
    /// endpoints. Each replica's batch buffer is long-lived and refilled
    /// in place by `Objective::sample_into` every step.
    replicas: Vec<Replica>,
    /// Concurrent replica lanes (`--dp-workers`, clamped to `1..=dp`).
    dp_workers: usize,
    /// Lazily-created scheduler pool dispatching the replica lanes when
    /// `dp_workers > 1`; rebuilt if a panicked lane poisoned it.
    dp_pool: Option<Arc<WorkerPool>>,
    prop: Box<dyn Propagator>,
    opt: Optimizer,
    sched: LrSchedule,
    pub controller: AdaptiveController,
    train_rng: Rng,
    val_rng_seed: u64,
    pub warm_start: bool,
    step: usize,
    initial_loss: Option<f32>,
    switched_at: Option<usize>,
    /// Periodic checkpointing during [`Session::train`] (`--save-every`).
    autosave: Option<Autosave>,
    /// Path of the newest *successful* autosave — the policy-2 rollback
    /// target.
    last_autosave: Option<String>,
    /// Consecutive rewound attempts of the current step (policy-1 cap).
    consec_anomalies: u32,
    /// Auto-rollbacks performed so far (policy-2 cap).
    rollbacks: u32,
    /// Every recovered anomaly, in order (also mirrored into the global
    /// [`crate::fault`] event log).
    anomalies: Vec<StepAnomaly>,
}

/// Periodic-autosave policy: every `every` steps, write
/// [`crate::checkpoint::autosave_path`]`(base, step)` and keep only the
/// newest `keep` snapshots (`keep = 0` disables pruning).
struct Autosave {
    base: String,
    every: usize,
    keep: usize,
}

/// Mailbox tag for the per-replica flat gradient payloads of one training
/// step. High bit-space so it can never collide with the halo/allreduce
/// tags of other fabrics; only `DP_GRAD_TAG` and its scratch-return twin
/// (`RETURN_BIT | DP_GRAD_TAG`) are ever in flight on the dp fabric.
const DP_GRAD_TAG: u64 = 1 << 40;

/// One data-parallel replica: its own solve context (cached MGRIT
/// hierarchies, forward + step workspaces, relaxation backend/pool), its
/// own long-lived batch buffer, and — when `dp > 1` — an endpoint on the
/// session's dp-wide gradient [`Fabric`]. Replica 0 is the coordinator:
/// §3.2.3 probes run on it, the gradient fold sums replicas 1.. into its
/// accumulators in ascending order (the serialized stash/fold
/// association, kept bitwise), and evaluation/optimizer reads go through
/// it.
struct Replica {
    ctx: SolveContext,
    batch: TrainBatch,
    ep: Option<Endpoint>,
    loss: f32,
    acc: f32,
    rho_f: Option<f64>,
    rho_b: Option<f64>,
}

/// The shared-read environment of one training step's micro-batches:
/// everything [`run_micro_batch`] needs besides the replica's own mutable
/// state. Every field is a `Sync` shared reference (or a scalar), so one
/// `MicroEnv` is borrowed concurrently by all replica lanes.
struct MicroEnv<'a> {
    rc: &'a RunConfig,
    prop: &'a dyn Propagator,
    objective: &'a dyn Objective,
    params: &'a ParamStore,
    /// Configured (fwd, bwd) iteration budgets.
    iters: (Option<usize>, Option<usize>),
    /// Controller-probe (fwd, bwd) budgets (replica 0 on probe steps).
    probe_iters: (Option<usize>, Option<usize>),
    warm_start: bool,
}

/// One replica micro-batch on a pre-sampled batch: embed → full forward
/// on the shared train/infer core → objective loss head → adjoint solve →
/// parameter gradients (no update). Gradients *accumulate* into the
/// replica's own `StepWorkspace` (zeroed by the caller); states/λ live in
/// its workspaces — zero heap allocations at steady state, per replica.
/// Returns (loss, acc, rho_fwd, rho_bwd).
fn run_micro_batch(
    env: &MicroEnv<'_>,
    ctx: &mut SolveContext,
    batch: &TrainBatch,
    probe: bool,
) -> (f32, f32, Option<f64>, Option<f64>) {
    let m = &env.rc.model;
    let n_layers = m.total_layers();
    let (bo, n_mid) = mid_range(m);
    let stacked = m.arch == Arch::EncDec;

    // --- forward (the shared train/infer core) -----------------------
    heads::embed_state_into(
        &batch.tokens,
        batch.tgt_in.as_deref(),
        &env.params.w_emb,
        &env.params.w_pos,
        m.batch,
        m.seq,
        m.d_model,
        ctx.fwd.ws.states[0].data_mut(),
    );
    let fwd_iters = if probe { env.probe_iters.0 } else { env.iters.0 };
    let fstats =
        ctx.fwd.forward_full(env.prop, &env.rc.mgrit, bo, n_mid, fwd_iters, env.warm_start, probe);

    // --- loss head (workspace-reusing: cotangent into ws.lam_head,
    //     head gradients straight into the step accumulators) --------
    let out = {
        let (x_final, sink) = ctx.head_view_and_sink(n_layers, stacked);
        env.objective.loss_into(x_final, env.params, batch, m, sink)
    };
    let acc = out.correct / out.denom;

    // --- adjoint ---------------------------------------------------------
    {
        // seed λ_N: lift the head cotangent into the state shape
        let StepWorkspace { lams, lam_head, .. } = &mut ctx.ws;
        let lam_n = &mut lams[n_layers];
        if stacked {
            let half = lam_n.len() / 2;
            let d = lam_n.data_mut();
            d[..half].fill(0.0);
            d[half..].copy_from_slice(lam_head.data());
        } else {
            lam_n.copy_from(lam_head);
        }
    }
    {
        // close buffers: serial adjoint + grads
        let states = &ctx.fwd.ws.states;
        let StepWorkspace { lams, grads, .. } = &mut ctx.ws;
        for l in ((bo + n_mid)..n_layers).rev() {
            let (lam_lo, lam_hi) = lams.split_at_mut(l + 1);
            env.prop.accumulate_grad(l, &states[l], &lam_hi[0], &mut grads[l]);
            env.prop.adjoint_step_into(l, 1.0, &states[l], &lam_hi[0], &mut lam_lo[l]);
        }
    }
    // backend adjoint solve + mid-range gradients on the cached cores
    let bwd_iters = if probe { env.probe_iters.1 } else { env.iters.1 };
    let mid = super::range::RangeProp::new(env.prop, bo, n_mid);
    let bstats = ctx.adjoint_mid(&mid, &env.rc.mgrit, bo, bwd_iters, probe);
    ctx.gradients_mid(&mid, bo);
    {
        // open buffers
        let states = &ctx.fwd.ws.states;
        let StepWorkspace { lams, grads, .. } = &mut ctx.ws;
        for l in (0..bo).rev() {
            let (lam_lo, lam_hi) = lams.split_at_mut(l + 1);
            env.prop.accumulate_grad(l, &states[l], &lam_hi[0], &mut grads[l]);
            env.prop.adjoint_step_into(l, 1.0, &states[l], &lam_hi[0], &mut lam_lo[l]);
        }
    }

    // --- embedding gradients ----------------------------------------------
    {
        let StepWorkspace { lams, g_emb, g_pos, .. } = &mut ctx.ws;
        let lam0 = lams[0].data();
        if stacked {
            let half = lam0.len() / 2;
            heads::embed_bwd(&batch.tokens, &lam0[..half], m.batch, m.seq, m.d_model, g_emb, g_pos);
            heads::embed_bwd(
                batch.tgt_in.as_ref().unwrap(),
                &lam0[half..],
                m.batch,
                m.seq,
                m.d_model,
                g_emb,
                g_pos,
            );
        } else {
            heads::embed_bwd(&batch.tokens, lam0, m.batch, m.seq, m.d_model, g_emb, g_pos);
        }
    }
    (out.loss, acc, fstats.conv_factor(), bstats.conv_factor())
}

/// A sibling execution backend for replicas 1..dp, mirroring replica 0's
/// strategy: each replica owns its backend — and so its own relaxation
/// pool — so replica solves run concurrently and a panicked sweep poisons
/// only its own replica group's pool (policy-3 containment then rebuilds
/// that one pool; the other replicas never notice).
fn replica_backend(main: &dyn Backend) -> Box<dyn Backend> {
    if main.forces_exact() {
        Box::new(Serial)
    } else {
        backend_for_workers(main.workers())
    }
}

/// Simulated cost of running this config's dp micro-batches as `d`
/// concurrent replica lanes × `lp` relaxation workers per lane — the
/// auto-split scoring behind a bare `--workers` budget (paper Fig. 9's
/// convex dp-vs-lp tradeoff, via the [`Simulator`]). Only *relative* cost
/// matters here; the Φ time is a nominal constant. The choice is an
/// execution detail: any split trains bitwise identically.
fn split_cost(rc: &RunConfig, d: usize, lp: usize) -> f64 {
    let m = &rc.model;
    let flops_per_sample = 12.0 * (m.seq * m.d_model * m.d_model) as f64
        + 4.0 * (m.seq * m.seq * m.d_model) as f64
        + 4.0 * (m.seq * m.d_model * m.d_ff) as f64;
    let dp = rc.dp_degree.max(1);
    let sim = Simulator::new(SimConfig {
        n_layers: m.parallel_layers().max(1),
        cf: rc.mgrit.cf,
        levels: rc.mgrit.levels,
        fwd_iters: rc.mgrit.fwd_iters,
        bwd_iters: rc.mgrit.bwd_iters,
        fcf: rc.mgrit.fcf,
        lp,
        dp: d,
        flops_per_sample_step: flops_per_sample,
        // the step's total work is dp micro-batches; the simulator's dp
        // axis splits it over the d lanes
        batch: m.batch * dp,
        state_bytes: (m.seq * m.d_model * 4) as f64,
        param_bytes: (m.total_layers() * m.p_enc() * 4) as f64,
        device: DeviceModel::cpu_measured(1.0e-4, flops_per_sample),
    });
    sim.batch_time().total
}

impl Session {
    /// Start assembling a session.
    pub fn builder() -> SessionBuilder {
        SessionBuilder {
            rc: None,
            preset: None,
            task: None,
            objective: None,
            backend: None,
            propagator: PropagatorKind::Rust,
            params: None,
            workers: None,
            dp_workers: None,
            warm_start: true,
            resume: None,
        }
    }

    /// Compat shim for the v1 `TrainRun::new` signature: fresh parameters,
    /// `engine = None` → pure-Rust Φ.
    pub fn new(rc: RunConfig, task: Task, engine: Option<Arc<XlaEngine>>) -> Result<Session> {
        Session::builder().config(rc).task(task).engine(engine).build()
    }

    /// Compat shim for the v1 `TrainRun::from_params` signature.
    pub fn from_params(
        rc: RunConfig,
        task: Task,
        params: ParamStore,
        engine: Option<Arc<XlaEngine>>,
    ) -> Result<Session> {
        Session::builder().config(rc).task(task).params(params).engine(engine).build()
    }

    /// Resume a checkpointed run with default execution pieces (pure-Rust
    /// Φ, `Mgrit` backend). Use `Session::builder().resume(path)` to pick
    /// a backend, worker count, or the XLA propagator.
    pub fn resume(path: &str) -> Result<Session> {
        Session::builder().resume(path).build()
    }

    /// Enable periodic autosave during [`Session::train`]: every `every`
    /// steps (and at the final step) write a full checkpoint to
    /// [`crate::checkpoint::autosave_path`]`(base, step)`, then prune the
    /// family down to the newest `keep` snapshots (`keep = 0` keeps all).
    /// A `serve --watch` process pointed at the same directory hot-reloads
    /// each snapshot as it lands.
    pub fn set_autosave(&mut self, base: &str, every: usize, keep: usize) {
        self.autosave = Some(Autosave { base: base.to_string(), every: every.max(1), keep });
    }

    /// Write a full session checkpoint (config, parameters, optimizer
    /// moments, adaptive state, RNG stream, step counter, warm iterate) —
    /// see [`crate::checkpoint`] for the format. A session resumed from it
    /// continues bitwise identically.
    pub fn save(&self, path: &str) -> Result<()> {
        let (bo, n_mid) = self.mid_range();
        // warm flags move in lockstep across replicas (forward_full sets
        // them together, the serial switch clears them together); the
        // all-or-nothing gather keeps an impossible partially-warm
        // session safely cold on resume. Layout: replica-major flat,
        // dp × (n_mid + 1) states.
        let warm = if self.replicas.iter().all(|r| r.ctx.has_warm()) {
            let mut w = Vec::with_capacity(self.replicas.len() * (n_mid + 1));
            for rep in &self.replicas {
                w.extend(rep.ctx.fwd.ws.states[bo..=bo + n_mid].iter().cloned());
            }
            Some(w)
        } else {
            None
        };
        let (rng_state, rng_spare) = self.train_rng.state_parts();
        let (m, v) = self.opt.moments();
        let c = &self.controller;
        let ck = Checkpoint {
            rc: self.rc.clone(),
            step: self.step,
            initial_loss: self.initial_loss,
            switched_at: self.switched_at,
            warm_start: self.warm_start,
            rng_state,
            rng_spare,
            controller: ControllerState {
                probe_every: c.probe_every,
                rho_switch: c.rho_switch,
                rho_grow: c.rho_grow,
                max_iters: c.max_iters,
                step: c.batch_step(),
                switched: c.is_serial(),
                history_cap: c.history_cap(),
                history: c.history().to_vec(),
            },
            opt_t: self.opt.step_count(),
            opt_m: m.to_vec(),
            opt_v: v.to_vec(),
            layers: self.params.layers.read().unwrap().clone(),
            w_emb: self.params.w_emb.clone(),
            w_pos: self.params.w_pos.clone(),
            w_out: self.params.w_out.clone(),
            w_cls: self.params.w_cls.clone(),
            warm,
        };
        ck.write(path)
    }

    /// The active objective's short name.
    pub fn objective_name(&self) -> &'static str {
        self.objective.name()
    }

    /// The active backend's short name (replica 0's; siblings mirror it).
    pub fn backend_name(&self) -> &'static str {
        self.ctx().backend().name()
    }

    /// Replica 0's solve context — the coordinator context that holds the
    /// folded gradients and the warm iterate the accessors report on.
    fn ctx(&self) -> &SolveContext {
        &self.replicas[0].ctx
    }

    /// The lane scheduler pool, rebuilt if missing, wrongly sized, or
    /// poisoned by a panicked lane (the owner-rebuilds protocol the
    /// relaxation backends use for their own pools).
    fn dp_pool_handle(&mut self, lanes: usize) -> Arc<WorkerPool> {
        match &self.dp_pool {
            Some(p) if p.size() == lanes && !p.is_poisoned() => p.clone(),
            _ => {
                let p = Arc::new(WorkerPool::new(lanes));
                self.dp_pool = Some(p.clone());
                p
            }
        }
    }

    /// Completed optimizer steps (checkpoint-resumed sessions start from
    /// the saved counter, not 0).
    pub fn step(&self) -> usize {
        self.step
    }

    /// Every anomaly the self-healing policies recovered from so far.
    pub fn anomalies(&self) -> &[StepAnomaly] {
        &self.anomalies
    }

    /// Auto-rollbacks performed so far (capped at [`MAX_ROLLBACKS`]).
    pub fn rollback_count(&self) -> u32 {
        self.rollbacks
    }

    /// Are the optimizer's Adam moments all finite? The self-healing
    /// invariant chaos tests pin: no recovered anomaly may have leaked
    /// NaN/Inf into the moment buffers.
    pub fn moments_finite(&self) -> bool {
        self.opt.moments_finite()
    }

    /// Adjust the total run length (`train` runs until this step count),
    /// keeping the cosine LR horizon in sync — the `--resume --steps N`
    /// surface. No-op on the schedule when the decay is not cosine.
    pub fn set_total_steps(&mut self, steps: usize) {
        self.rc.train.steps = steps;
        if let Decay::Cosine { min_frac, .. } = self.sched.decay {
            self.sched.decay = Decay::Cosine { total: steps, min_frac };
        }
    }

    /// Cached-hierarchy introspection: how many MGRIT cores this session's
    /// solve context has built so far (2 at steady state — one per solve
    /// direction — plus explicit rebuilds on cf/levels changes).
    pub fn solve_core_builds(&self) -> u64 {
        self.ctx().core_builds()
    }

    /// Drop the cached MGRIT hierarchies (every replica's); the next solve
    /// rebuilds them. The explicit-rebuild hook for out-of-band
    /// solver-geometry changes (and the "fresh ctx" benchmark baseline).
    pub fn invalidate_solve_context(&mut self) {
        for rep in &mut self.replicas {
            rep.ctx.invalidate();
        }
    }

    /// Is a TorchBraid-style warm-start iterate currently held? (The flags
    /// move in lockstep across replicas; replica 0 answers for all.)
    pub fn has_warm_iterate(&self) -> bool {
        self.ctx().has_warm()
    }

    fn mid_range(&self) -> (usize, usize) {
        mid_range(&self.rc.model)
    }

    /// Embed a batch into the propagator's state shape, written straight
    /// into replica 0's forward workspace Z_0 buffer (no allocation) —
    /// the evaluation path; training embeds inside [`run_micro_batch`].
    fn embed_into(&mut self, tokens: &[i32], tgt_in: Option<&[i32]>) {
        let m = &self.rc.model;
        heads::embed_state_into(
            tokens,
            tgt_in,
            &self.params.w_emb,
            &self.params.w_pos,
            m.batch,
            m.seq,
            m.d_model,
            self.replicas[0].ctx.fwd.ws.states[0].data_mut(),
        );
    }

    /// One full training step (dp micro-batches + probe + update), wrapped
    /// in the self-healing policies of [`crate::fault`]:
    ///
    /// * **Non-finite guard (policy 1).** If the batch loss or the global
    ///   gradient norm comes back NaN/Inf, the optimizer update is
    ///   *skipped* — Adam's moments never see the poison — and the attempt
    ///   is rewound (RNG stream, step counter, controller cadence) and the
    ///   same batch replayed. Under an exact (serial) configuration the
    ///   replay is bitwise identical to a run that never faulted; a
    ///   warm-started MGRIT replay re-solves from the advanced iterate
    ///   (same math, different warm start). After [`MAX_STEP_RETRIES`]
    ///   consecutive anomalies an adaptive MGRIT run switches to serial
    ///   and keeps retrying; a run with nowhere left to escalate emits the
    ///   anomalous record with the update skipped — a typed
    ///   [`StepAnomaly`] either way, never a panic or a poisoned moment.
    /// * **Divergence watchdog (policy 2).** A finite loss above the
    ///   §3.2.3 divergence threshold first tries an **auto-rollback**:
    ///   restore the newest successful autosave in place
    ///   ([`Session::set_autosave`]) and replay from there — bitwise
    ///   identical to a run that never diverged. After [`MAX_ROLLBACKS`]
    ///   rollbacks, or with no autosave available, it falls back to the
    ///   original switch-to-serial escalation.
    pub fn train_step(&mut self) -> StepRecord {
        loop {
            // policy-1 rewind snapshot: two scalar copies, no allocation
            let (rng_state, rng_spare) = self.train_rng.state_parts();
            self.step += 1;
            let probe = self.controller.should_probe();
            let dp = self.rc.dp_degree.max(1);
            let probe_iters = self.controller.probe_iters(&self.rc.mgrit);
            let lanes = self.dp_workers.min(dp).max(1);
            let pool = if lanes > 1 { Some(self.dp_pool_handle(lanes)) } else { None };

            {
                // pre-sample every replica's batch on the coordinator
                // thread in ascending replica order — the exact train_rng
                // consumption of the serialized micro-batch loop, so the
                // record stream stays bitwise for any lane count
                let Session { rc, objective, train_rng, replicas, .. } = self;
                for rep in replicas.iter_mut() {
                    objective.sample_into(train_rng, &rc.model, &mut rep.batch);
                    rep.ctx.ws.zero_grads();
                }
            }

            {
                let Session { rc, prop, objective, params, replicas, warm_start, .. } = self;
                let env = MicroEnv {
                    rc,
                    prop: prop.as_ref(),
                    objective: objective.as_ref(),
                    params,
                    iters: (rc.mgrit.fwd_iters, rc.mgrit.bwd_iters),
                    probe_iters,
                    warm_start: *warm_start,
                };
                // replica lanes mutate disjoint `Replica`s concurrently:
                // lane `l` exclusively owns the contiguous slab_range of
                // replica indices, so the raw-pointer shares never alias
                struct Lanes(*mut Replica);
                unsafe impl Sync for Lanes {}
                let share = Lanes(replicas.as_mut_ptr());
                let run_lane = |lane: usize| {
                    let (lo, hi) = slab_range(dp, lanes, lane);
                    for r in lo..hi {
                        let rep: &mut Replica = unsafe { &mut *share.0.add(r) };
                        let Replica { ctx, batch, ep, loss, acc, rho_f, rho_b } = rep;
                        let (l, a, rf, rb) = run_micro_batch(&env, ctx, batch, probe && r == 0);
                        *loss = l;
                        *acc = a;
                        *rho_f = rf;
                        *rho_b = rb;
                        if r > 0 {
                            // ship this replica's flat gradient payload to
                            // the coordinator (recycled scratch buffer —
                            // the previous step's fold mailed it back)
                            let ep = ep.as_mut().expect("dp > 1 replicas carry an endpoint");
                            ep.send_scratch(0, DP_GRAD_TAG, |buf| ctx.ws.write_grads_flat(buf));
                        }
                    }
                };
                match &pool {
                    Some(p) => p.run_sweep(
                        lanes,
                        &|lane: usize, _ep: &mut Endpoint, _ws: &mut Workspace| run_lane(lane),
                    ),
                    None => run_lane(0),
                }
                if dp > 1 {
                    // fold in strictly ascending replica order — the same
                    // left-associated sum `(((g0 + g1) + g2) + …)` the
                    // serialized stash/fold scratch pinned, so sharded dp
                    // stays bitwise against serial dp
                    let (r0, _) = replicas.split_first_mut().unwrap();
                    let Replica { ctx: ctx0, ep: ep0, .. } = r0;
                    let ep0 = ep0.as_mut().expect("replica 0 carries an endpoint");
                    for r in 1..dp {
                        ep0.recv_scratch(r, DP_GRAD_TAG, |flat| ctx0.ws.fold_grads_flat(flat));
                    }
                    ctx0.ws.scale_grads(1.0 / dp as f32);
                }
            }

            // loss/acc averages in the same ascending replica order as the
            // serialized loop (f32 sums are order-sensitive)
            let mut loss_sum = 0.0f32;
            let mut acc_sum = 0.0f32;
            for rep in &self.replicas {
                loss_sum += rep.loss;
                acc_sum += rep.acc;
            }
            let (rho_f, rho_b) = (self.replicas[0].rho_f, self.replicas[0].rho_b);
            let mut loss = loss_sum / dp as f32;
            let acc = acc_sum / dp as f32;

            // deterministic chaos hooks — one relaxed atomic load each when
            // disarmed (rust/src/fault), inside the audited 0-alloc path
            if crate::faultpoint!("train.nan_grad") {
                let ws = &mut self.replicas[0].ctx.ws;
                if let Some(x) = ws.grads.first_mut().and_then(|g| g.iter_mut().next()) {
                    *x = f32::NAN;
                }
            }
            if crate::faultpoint!("train.loss_spike") {
                loss = 1.0e6;
            }

            // clip straight from the workspace accumulators (the untouched
            // head groups are full-size zeros, so including them changes
            // neither the norm nor the updates); clip_global walks the
            // accumulators directly — no per-step ref-list allocation. The
            // returned pre-clip norm doubles as the policy-1 gradient
            // health check: NaN/Inf anywhere in the accumulators
            // propagates into it.
            let gnorm = self.replicas[0].ctx.ws.clip_global(self.rc.train.grad_clip);

            // --- policy 1: non-finite guard ------------------------------
            if !loss.is_finite() || !gnorm.is_finite() {
                match self.recover_non_finite(loss, acc, gnorm, rng_state, rng_spare) {
                    Some(rec) => return rec, // gave the step up (update skipped)
                    None => continue,        // rewound — replay the batch
                }
            }
            self.consec_anomalies = 0;

            // adaptive controller (probe result + divergence watchdog)
            if probe {
                self.controller.observe(rho_f, rho_b, &mut self.rc.mgrit);
                if self.controller.is_serial() && self.switched_at.is_none() {
                    self.switched_at = Some(self.step);
                }
            }
            if self.initial_loss.is_none() {
                self.initial_loss = Some(loss);
            }
            if self.rc.train.adaptive
                && !self.controller.is_serial()
                && loss > 3.0 * self.initial_loss.unwrap() + 1.0
            {
                // --- policy 2: watchdog — rollback first, serial second --
                if self.try_rollback(loss) {
                    continue; // replay from the restored snapshot
                }
                self.controller.force_serial(&mut self.rc.mgrit);
                self.switched_at = Some(self.step);
            }
            if self.controller.is_serial() {
                // the switch is sticky: the warm iterates are dead memory
                // (and would poison a later non-serial run restored from
                // this session) and the cached hierarchies will never be
                // solved on again — drop both at the switch, not lazily,
                // in every replica (keeping the warm flags in lockstep)
                for rep in &mut self.replicas {
                    rep.ctx.clear_warm();
                    rep.ctx.invalidate();
                }
            }

            let lr = self.sched.at(self.step);
            self.opt.begin_step();
            {
                // the only write-lock acquisition on the training path;
                // the optimizer reads replica 0's folded accumulators
                let mut layers = self.params.layers.write().unwrap();
                for (i, g) in self.replicas[0].ctx.ws.grads.iter().enumerate() {
                    self.opt.update(i, lr, &mut layers[i], g);
                }
            }
            let nl = self.rc.model.total_layers();
            self.opt.update(nl, lr, &mut self.params.w_emb, &self.replicas[0].ctx.ws.g_emb);
            self.opt.update(nl + 1, lr, &mut self.params.w_pos, &self.replicas[0].ctx.ws.g_pos);
            self.opt.update(nl + 2, lr, &mut self.params.w_out, &self.replicas[0].ctx.ws.g_out);
            self.opt.update(nl + 3, lr, &mut self.params.w_cls, &self.replicas[0].ctx.ws.g_cls);

            return StepRecord {
                step: self.step,
                loss,
                acc,
                lr,
                serial: self.rc.mgrit.is_serial()
                    || self.controller.is_serial()
                    || self.ctx().backend().forces_exact(),
                rho_fwd: rho_f,
                rho_bwd: rho_b,
            };
        }
    }

    /// Policy 1: a non-finite loss or gradient norm was detected *before*
    /// the optimizer update. Record the typed anomaly, then either rewind
    /// the attempt (RNG stream, step counter, controller batch cadence) so
    /// the caller replays it — escalating to the serial propagator once
    /// the retry budget is spent — or, with nowhere left to escalate, give
    /// the step up: `Some(record)` with the update skipped.
    fn recover_non_finite(
        &mut self,
        loss: f32,
        acc: f32,
        gnorm: f32,
        rng_state: u64,
        rng_spare: Option<f32>,
    ) -> Option<StepRecord> {
        let step = self.step;
        let kind =
            if loss.is_finite() { AnomalyKind::NonFiniteGrad } else { AnomalyKind::NonFiniteLoss };
        self.consec_anomalies += 1;
        let detail =
            format!("loss={} grad_norm={} attempt={}", loss, gnorm, self.consec_anomalies);
        self.anomalies.push(StepAnomaly { step, kind, detail: detail.clone() });
        crate::fault::record("train.step_anomaly", step as u64, "skipped_step", detail);
        let escalate = self.consec_anomalies >= MAX_STEP_RETRIES;
        if !escalate || (self.rc.train.adaptive && !self.controller.is_serial()) {
            if escalate {
                // the MGRIT solve itself may be the poison source — switch
                // to the exact serial propagation and retry with a fresh
                // budget
                self.controller.force_serial(&mut self.rc.mgrit);
                self.switched_at = Some(step);
                for rep in &mut self.replicas {
                    rep.ctx.clear_warm();
                    rep.ctx.invalidate();
                }
                self.consec_anomalies = 0;
                crate::fault::record(
                    "train.step_anomaly",
                    step as u64,
                    "force_serial",
                    "retry budget spent — switching to serial propagation".to_string(),
                );
            }
            // rewind the attempt for replay
            self.train_rng = Rng::from_parts(rng_state, rng_spare);
            self.step -= 1;
            self.controller.rewind_batch();
            return None;
        }
        // nowhere left to escalate: the step counts (so the run
        // terminates) but the update is skipped; later steps get their own
        // retry budget
        self.consec_anomalies = 0;
        Some(StepRecord {
            step,
            loss,
            acc,
            lr: self.sched.at(step),
            serial: self.rc.mgrit.is_serial()
                || self.controller.is_serial()
                || self.ctx().backend().forces_exact(),
            rho_fwd: None,
            rho_bwd: None,
        })
    }

    /// Policy 2: the divergence watchdog tripped on a finite loss. Restore
    /// the newest successful autosave in place and let the caller replay
    /// from it (`true`), or report that the caller should fall back to the
    /// serial switch (`false`: no autosave yet, rollback cap reached, or
    /// the snapshot failed to load).
    fn try_rollback(&mut self, loss: f32) -> bool {
        let step = self.step;
        let path = match &self.last_autosave {
            Some(p) if self.rollbacks < MAX_ROLLBACKS => p.clone(),
            _ => return false,
        };
        match Checkpoint::read(&path).and_then(|c| self.restore_in_place(c)) {
            Ok(()) => {
                self.rollbacks += 1;
                self.controller.record_rollback();
                let detail = format!(
                    "loss={} at step {} — restored {} (step {})",
                    loss, step, path, self.step
                );
                self.anomalies.push(StepAnomaly {
                    step,
                    kind: AnomalyKind::Divergence,
                    detail: detail.clone(),
                });
                crate::fault::record("train.watchdog", step as u64, "rollback", detail);
                true
            }
            Err(e) => {
                crate::fault::record(
                    "train.watchdog",
                    step as u64,
                    "rollback_failed",
                    e.to_string(),
                );
                false
            }
        }
    }

    /// Restore every stateful piece of the session from a checkpoint, in
    /// place — the rollback arm of the divergence watchdog. The same
    /// recipe as [`SessionBuilder::resume`], but reusing the live solve
    /// context and propagator (the layer slabs are shared through
    /// [`ParamStore::layers`], so the propagator sees the restored θ
    /// without a rebuild).
    fn restore_in_place(&mut self, c: Checkpoint) -> Result<()> {
        if c.rc.model.total_layers() != self.rc.model.total_layers()
            || c.rc.model.d_model != self.rc.model.d_model
        {
            bail!("rollback checkpoint has a different model geometry");
        }
        if c.rc.dp_degree.max(1) != self.replicas.len() {
            bail!("rollback checkpoint has a different dp degree");
        }
        self.rc = c.rc.clone();
        *self.params.layers.write().unwrap() = c.layers;
        self.params.w_emb = c.w_emb;
        self.params.w_pos = c.w_pos;
        self.params.w_out = c.w_out;
        self.params.w_cls = c.w_cls;
        self.opt.restore_moments(c.opt_m, c.opt_v, c.opt_t);
        self.train_rng = Rng::from_parts(c.rng_state, c.rng_spare);
        self.step = c.step;
        self.initial_loss = c.initial_loss;
        self.switched_at = c.switched_at;
        self.warm_start = c.warm_start;
        let cs = c.controller;
        self.controller = AdaptiveController::restore(
            cs.probe_every,
            cs.rho_switch,
            cs.rho_grow,
            cs.max_iters,
            cs.step,
            cs.switched,
            cs.history_cap,
            cs.history,
        );
        // the cached hierarchies may have been built for controller-grown
        // iteration counts — drop them together with the now-stale warm
        // iterates, then re-seed every replica's warm iterate from the
        // snapshot's replica-major warm section (the exact resume recipe,
        // so the replay is bitwise identical)
        for rep in &mut self.replicas {
            rep.ctx.clear_warm();
            rep.ctx.invalidate();
        }
        if let Some(warm) = c.warm {
            let (bo, n_mid) = mid_range(&self.rc.model);
            let per = n_mid + 1;
            for (r, rep) in self.replicas.iter_mut().enumerate() {
                let src = &warm[r * per..(r + 1) * per];
                for (dst, s) in rep.ctx.fwd.ws.states[bo..=bo + n_mid].iter_mut().zip(src) {
                    dst.copy_from(s);
                }
                rep.ctx.fwd.mark_warm();
            }
        }
        Ok(())
    }

    /// Validation metric over `n_batches` fresh batches (exact forward).
    /// Accuracy for token/sequence tasks; BLEU-4 for Translate. The sweep
    /// runs through the propagator's zero-allocation `step_into` ping-pong
    /// over two persistent workspace buffers — no per-batch state
    /// allocations (and still one dispatch for the whole sweep).
    pub fn evaluate(&mut self, n_batches: usize) -> f64 {
        let m = self.rc.model.clone();
        let n_layers = m.total_layers();
        let stacked = m.arch == Arch::EncDec;
        let mut rng = Rng::new(self.val_rng_seed);
        let mut acc = EvalAccum::default();
        for _ in 0..n_batches {
            let mut batch = std::mem::take(&mut self.replicas[0].batch);
            self.objective.sample_into(&mut rng, &m, &mut batch);
            self.embed_into(&batch.tokens, batch.tgt_in.as_deref());
            {
                let ForwardWorkspace { states, pp, .. } = &mut self.replicas[0].ctx.fwd.ws;
                self.prop.step_to_into(0, n_layers, 1.0, &mut states[0], pp);
            }
            let x_final = self.replicas[0].ctx.fwd.ws.staged_head_view(0, stacked);
            self.objective.eval_batch(x_final, &self.params, &batch, &m, &mut acc);
            self.replicas[0].batch = batch;
        }
        self.objective.metric(&acc)
    }

    /// Full training loop with periodic evaluation, running until the
    /// configured total step count (a resumed session picks up at its
    /// saved step and trains the remaining ones).
    pub fn train(&mut self) -> Result<TrainReport> {
        let mut report = TrainReport::default();
        let steps = self.rc.train.steps;
        let eval_every = self.rc.train.eval_every.max(1);
        while self.step < steps {
            let rec = self.train_step();
            if self.step % eval_every == 0 || self.step == steps {
                let metric = self.evaluate(2);
                report.evals.push(EvalRecord { step: self.step, metric });
            }
            let due = match &self.autosave {
                Some(a) if self.step % a.every == 0 || self.step == steps => {
                    Some((a.base.clone(), a.keep))
                }
                _ => None,
            };
            if let Some((base, keep)) = due {
                let path = crate::checkpoint::autosave_path(&base, self.step);
                match self.save(&path) {
                    Ok(()) => {
                        // the newest good snapshot is the watchdog's
                        // rollback target; pruning keeps the newest
                        // `keep`, so it never deletes this one
                        self.last_autosave = Some(path);
                        if keep > 0 {
                            crate::checkpoint::prune_autosaves(&base, keep);
                        }
                    }
                    Err(e) => {
                        // a failed snapshot must not kill a healthy run:
                        // record the typed event and train on (the atomic
                        // tmp+rename write protocol guarantees no partial
                        // .ltcp file was left behind)
                        crate::fault::record(
                            "checkpoint.autosave",
                            self.step as u64,
                            "autosave_failed",
                            e.to_string(),
                        );
                    }
                }
            }
            report.curve.push(rec);
        }
        report.final_loss = report.curve.last().map(|r| r.loss).unwrap_or(f32::NAN);
        report.final_metric = report.evals.last().map(|e| e.metric).unwrap_or(0.0);
        report.probes = self.controller.history().to_vec();
        report.phi_fwd = self.prop.counters().fwd();
        report.phi_vjp = self.prop.counters().vjp();
        report.switched_at = self.switched_at;
        report.anomalies = self.anomalies.clone();
        Ok(report)
    }
}
