//! Sub-range view of a propagator: MGRIT runs over the ParallelNet middle
//! while the open/close "buffer" layers (paper Appendix B) are driven
//! serially by the trainer outside this view.

use crate::ode::{CacheUnsupported, Propagator, StepCounters};
use crate::reference::KvCache;
use crate::tensor::Tensor;

/// Layers [start, start+len) of `inner`, re-indexed from 0.
pub struct RangeProp<'a> {
    inner: &'a dyn Propagator,
    start: usize,
    len: usize,
}

impl<'a> RangeProp<'a> {
    pub fn new(inner: &'a dyn Propagator, start: usize, len: usize) -> RangeProp<'a> {
        assert!(start + len <= inner.n_steps(), "range outside propagator");
        RangeProp { inner, start, len }
    }
}

impl<'a> Propagator for RangeProp<'a> {
    fn n_steps(&self) -> usize {
        self.len
    }

    fn state_shape(&self) -> Vec<usize> {
        self.inner.state_shape()
    }

    fn fine_h(&self, layer: usize) -> f32 {
        self.inner.fine_h(self.start + layer)
    }

    fn step(&self, layer: usize, h_scale: f32, z: &Tensor) -> Tensor {
        self.inner.step(self.start + layer, h_scale, z)
    }

    fn step_into(&self, layer: usize, h_scale: f32, z: &Tensor, out: &mut Tensor) {
        // forward rather than taking the default so the inner propagator's
        // buffer-reusing path stays on the MGRIT hot loop
        self.inner.step_into(self.start + layer, h_scale, z, out)
    }

    fn step_range(&self, lo: usize, hi: usize, h_scale: f32, z: &Tensor) -> Vec<Tensor> {
        // forward so the inner dispatch amortization (one lock/executable
        // acquisition per sweep) also covers sub-range views
        self.inner.step_range(self.start + lo, self.start + hi, h_scale, z)
    }

    fn step_to(&self, lo: usize, hi: usize, h_scale: f32, z: &Tensor) -> Tensor {
        self.inner.step_to(self.start + lo, self.start + hi, h_scale, z)
    }

    fn step_to_into(
        &self,
        lo: usize,
        hi: usize,
        h_scale: f32,
        cur: &mut Tensor,
        scratch: &mut Tensor,
    ) {
        self.inner.step_to_into(self.start + lo, self.start + hi, h_scale, cur, scratch)
    }

    fn step_seq_into(&self, layer_lo: usize, h_scale: f32, states: &mut [Tensor]) {
        self.inner.step_seq_into(self.start + layer_lo, h_scale, states)
    }

    fn adjoint_step(&self, layer: usize, h_scale: f32, z: &Tensor, lam_next: &Tensor) -> Tensor {
        self.inner.adjoint_step(self.start + layer, h_scale, z, lam_next)
    }

    fn adjoint_step_into(
        &self,
        layer: usize,
        h_scale: f32,
        z: &Tensor,
        lam_next: &Tensor,
        out: &mut Tensor,
    ) {
        self.inner.adjoint_step_into(self.start + layer, h_scale, z, lam_next, out)
    }

    fn accumulate_grad(&self, layer: usize, z: &Tensor, lam_next: &Tensor, grad: &mut [f32]) {
        self.inner.accumulate_grad(self.start + layer, z, lam_next, grad)
    }

    fn theta_len(&self, layer: usize) -> usize {
        self.inner.theta_len(self.start + layer)
    }

    fn make_cache(&self) -> Option<KvCache> {
        // the cache indexes *global* layers (layer0 offset), so the inner
        // cache is correct for a sub-range view as-is
        self.inner.make_cache()
    }

    fn step_cached(
        &self,
        layer: usize,
        cache: &mut KvCache,
        positions: &[usize],
        cur: &Tensor,
        out: &mut Tensor,
    ) -> Result<(), CacheUnsupported> {
        self.inner.step_cached(self.start + layer, cache, positions, cur, out)
    }

    fn step_to_cached(
        &self,
        lo: usize,
        hi: usize,
        cache: &mut KvCache,
        positions: &[usize],
        cur: &mut Tensor,
        scratch: &mut Tensor,
    ) -> Result<(), CacheUnsupported> {
        self.inner.step_to_cached(self.start + lo, self.start + hi, cache, positions, cur,
                                  scratch)
    }

    fn fill_cached(
        &self,
        layer: usize,
        cache: &mut KvCache,
        z: &Tensor,
        positions: &[usize],
    ) -> Result<(), CacheUnsupported> {
        self.inner.fill_cached(self.start + layer, cache, z, positions)
    }

    fn counters(&self) -> &StepCounters {
        self.inner.counters()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::LinearOde;
    use crate::util::rng::Rng;

    #[test]
    fn range_offsets_layer_indices() {
        let mut rng = Rng::new(0);
        let ode = LinearOde::random_stable(&mut rng, 4, 10, 0.1);
        let sub = RangeProp::new(&ode, 3, 5);
        assert_eq!(sub.n_steps(), 5);
        let z = Tensor::randn(&mut rng, &[4, 1], 1.0);
        // LinearOde is layer-independent, so values must agree exactly
        assert_eq!(sub.step(0, 1.0, &z), ode.step(3, 1.0, &z));
        assert_eq!(sub.fine_h(2), ode.fine_h(5));
    }

    #[test]
    #[should_panic]
    fn out_of_range_rejected() {
        let mut rng = Rng::new(1);
        let ode = LinearOde::random_stable(&mut rng, 4, 10, 0.1);
        RangeProp::new(&ode, 8, 5);
    }
}
