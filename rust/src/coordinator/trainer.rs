//! Task selection and the v1 compatibility surface of the trainer.
//!
//! ## Architecture (Session API v2 + persistent solve contexts)
//!
//! The training engine lives in [`super::session`] and is composed of
//! four orthogonal abstractions:
//!
//! * [`super::session::Session`] — the run itself: batch loop, buffer-layer
//!   sweeps (in place through `Propagator::step_into`), §3.2.3 probes,
//!   gradient clipping, optimizer updates, evaluation, run recording.
//!   Built via `Session::builder()` (preset/config → propagator → backend
//!   → objective).
//! * [`super::backend::Backend`] — the execution *strategy* of the forward
//!   and adjoint solves: `Serial` (exact), `Mgrit` (single-threaded
//!   V-cycles), `ThreadedMgrit` (multi-worker relaxation through
//!   `parallel::exec` on a persistent worker pool, bitwise identical to
//!   `Mgrit`). A backend only names the mode — worker count, relaxation
//!   pool, iteration-budget mapping; it no longer runs solves itself.
//! * [`super::context::SolveContext`] — the execution *state*: the session
//!   creates one context from its backend at build time and holds it for
//!   its lifetime. The context owns both cached MGRIT hierarchies
//!   (forward + adjoint, built at most once per direction and reused
//!   across every solve of the run — §3.2.3 iteration doubling reuses
//!   them, the serial switch bypasses them, cf/levels changes rebuild
//!   them), the TorchBraid-style warm-start iterate (dropped at the
//!   serial switch), and the `StepWorkspace` with every fine-grid
//!   states/λ/gradient buffer, so the steady-state training step
//!   performs no solver-side allocations (`rust/tests/alloc_audit.rs`,
//!   `rust/tests/core_reuse.rs`).
//! * [`super::objective::Objective`] — the workload: data sampling, loss
//!   head, validation metric. The paper's five tasks are provided; new
//!   workloads implement the trait without touching the coordinator.
//!
//! This module keeps the closed [`Task`] enum as the preset→objective
//! mapping plus [`TrainRun`], a type alias so v1 call sites
//! (`TrainRun::new(rc, task, engine)`) keep working.

use anyhow::{anyhow, bail, Result};

use crate::config::{presets, ModelConfig};
use crate::data::charlm::CharCorpus;
use crate::data::images::ImageTask;
use crate::data::morpho::MorphoTask;
use crate::data::translate::TranslateTask;

use super::objective::{ClsObjective, LmObjective, Objective, TagObjective, TranslateObjective};
use super::session::Session;

/// The v1 name of [`Session`] (constructors `new` / `from_params` are
/// provided as inherent methods for compatibility).
pub type TrainRun = Session;

/// Training objective selector (maps presets to the paper's five tasks).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    /// Masked-language modeling (BERT).
    Mlm,
    /// Causal language modeling (GPT).
    Lm,
    /// Per-token morphological tagging (MC).
    Tag,
    /// Sequence classification (ViT).
    Cls,
    /// Encoder-decoder translation (MT).
    Translate,
}

impl Task {
    /// Task for a preset name. Errors on unknown presets instead of
    /// silently defaulting, listing the valid names. Alias knowledge lives
    /// only in [`presets::by_name`]; this maps the canonical names.
    pub fn for_preset(name: &str) -> Result<Task> {
        let canonical = presets::by_name(name)
            .ok_or_else(|| {
                anyhow!(
                    "unknown preset '{}' (valid presets: {}; short aliases \
                     bert, mc, vit, mt, gpt also accepted)",
                    name,
                    presets::ALL.join(", ")
                )
            })?
            .name;
        // accept both the constructor-style canonical names and the
        // RunConfig::name fields ("mc", "vit", …) — resumed checkpoints
        // and InferSession route the *stored* name back through here
        match canonical.as_str() {
            "bert" | "bert_deep" => Ok(Task::Mlm),
            "gpt" | "gpt_small" => Ok(Task::Lm),
            "vit" | "vit_small" => Ok(Task::Cls),
            "mt" | "mt_small" => Ok(Task::Translate),
            "mc" | "mc_tiny" => Ok(Task::Tag),
            other => bail!(
                "preset '{}' resolves to '{}', which has no task mapping — \
                 update Task::for_preset alongside presets::by_name",
                name,
                other
            ),
        }
    }

    /// Instantiate this task's objective (data source seeded from the run
    /// seed, geometry from the model config).
    pub fn objective(self, m: &ModelConfig, seed: u64) -> Box<dyn Objective> {
        match self {
            Task::Mlm => Box::new(LmObjective::masked(
                CharCorpus::new(m.vocab - 1, seed, 3),
                (m.vocab - 1) as i32,
                0.2,
            )),
            Task::Lm => Box::new(LmObjective::causal(CharCorpus::new(m.vocab - 1, seed, 3))),
            Task::Tag => Box::new(TagObjective::new(MorphoTask::new(m.vocab, m.n_classes, seed))),
            Task::Cls => Box::new(ClsObjective::new(ImageTask::new(m.seq, m.vocab, m.n_classes))),
            Task::Translate => {
                Box::new(TranslateObjective::new(TranslateTask::new(m.vocab, seed, false)))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_task_mapping_is_total_over_known_presets() {
        for name in presets::ALL {
            assert!(Task::for_preset(name).is_ok(), "{}", name);
            // the RunConfig::name field must resolve too: checkpoints
            // store it, and resume/inference map it back to a task
            let stored = presets::by_name(name).unwrap().name;
            assert!(Task::for_preset(&stored).is_ok(), "stored name '{}'", stored);
        }
        assert_eq!(Task::for_preset("mc").unwrap(), Task::Tag);
        assert_eq!(Task::for_preset("bert").unwrap(), Task::Mlm);
        assert_eq!(Task::for_preset("gpt").unwrap(), Task::Lm);
        assert_eq!(Task::for_preset("vit").unwrap(), Task::Cls);
        assert_eq!(Task::for_preset("mt").unwrap(), Task::Translate);
    }

    #[test]
    fn unknown_preset_errors_with_valid_names() {
        let err = Task::for_preset("nope").unwrap_err().to_string();
        assert!(err.contains("nope"), "{}", err);
        assert!(err.contains("mc_tiny"), "error should list presets: {}", err);
    }

    #[test]
    fn tasks_build_matching_objectives() {
        let m = presets::mc_tiny().model;
        assert_eq!(Task::Tag.objective(&m, 0).name(), "tag");
        assert_eq!(Task::Lm.objective(&m, 0).name(), "lm");
        assert_eq!(Task::Mlm.objective(&m, 0).name(), "mlm");
        assert_eq!(Task::Cls.objective(&m, 0).name(), "cls");
        assert_eq!(Task::Translate.objective(&m, 0).name(), "translate");
    }
}
