//! `TrainRun`: the end-to-end training procedure of the paper.
//!
//! Per batch: embed → (serial open buffers) → MGRIT forward over the
//! ParallelNet → (serial close buffers) → loss head → adjoint (serial
//! close, MGRIT middle, serial open) → parameter gradients → clip →
//! optimizer. The §3.2.3 controller probes the MGRIT convergence factor on
//! a cadence and can raise iteration counts or switch the run to serial.
//!
//! Data parallelism is executed as `dp` sequential micro-batches with
//! gradient averaging — bit-identical math to distributed replicas (the
//! *time* dimension of dp lives in `parallel::simulator`; this box has one
//! core, DESIGN.md §Substitutions).

use std::rc::Rc;

use anyhow::Result;

use crate::adaptive::{AdaptiveController, ProbeRecord};
use crate::analysis::bleu4;
use crate::config::{Arch, RunConfig};
use crate::data::{charlm::CharCorpus, images::ImageTask, morpho::MorphoTask, translate::TranslateTask};
use crate::mgrit::MgritSolver;
use crate::model::{Init, ParamStore};
use crate::ode::{Propagator, RustPropagator, XlaPropagator};
use crate::opt::{clip_global_norm, Decay, LrSchedule, Optimizer};
use crate::runtime::XlaEngine;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

use super::heads;
use super::range::RangeProp;

/// Training objective (maps presets to the paper's five tasks).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    /// Masked-language modeling (BERT).
    Mlm,
    /// Causal language modeling (GPT).
    Lm,
    /// Per-token morphological tagging (MC).
    Tag,
    /// Sequence classification (ViT).
    Cls,
    /// Encoder-decoder translation (MT).
    Translate,
}

impl Task {
    /// Default task for a preset name.
    pub fn for_preset(name: &str) -> Task {
        match name {
            "bert_deep" | "bert" => Task::Mlm,
            "gpt" | "gpt_small" => Task::Lm,
            "vit" | "vit_small" => Task::Cls,
            "mt" | "mt_small" => Task::Translate,
            _ => Task::Tag,
        }
    }
}

/// One training-step record (drives the Fig. 3/4 curves).
#[derive(Debug, Clone)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f32,
    pub acc: f32,
    pub lr: f32,
    pub serial: bool,
    pub rho_fwd: Option<f64>,
    pub rho_bwd: Option<f64>,
}

/// Validation record: metric is accuracy (or BLEU for Translate).
#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub step: usize,
    pub metric: f64,
}

/// Everything a run produced.
#[derive(Debug, Clone, Default)]
pub struct TrainReport {
    pub curve: Vec<StepRecord>,
    pub evals: Vec<EvalRecord>,
    pub probes: Vec<ProbeRecord>,
    pub final_loss: f32,
    pub final_metric: f64,
    pub phi_fwd: u64,
    pub phi_vjp: u64,
    pub switched_at: Option<usize>,
}

/// Task data sources (seed-split train/val).
enum DataGen {
    Char(CharCorpus),
    Morpho(MorphoTask),
    Images(ImageTask),
    Pairs(TranslateTask),
}

/// A fully-wired training run.
pub struct TrainRun {
    pub rc: RunConfig,
    pub task: Task,
    pub params: ParamStore,
    prop: Box<dyn Propagator>,
    opt: Optimizer,
    sched: LrSchedule,
    pub controller: AdaptiveController,
    data: DataGen,
    train_rng: Rng,
    val_rng_seed: u64,
    /// Warm-start iterate for the MGRIT forward solve (TorchBraid-style).
    warm: Option<Vec<Tensor>>,
    pub warm_start: bool,
    step: usize,
    initial_loss: Option<f32>,
    switched_at: Option<usize>,
}

impl TrainRun {
    /// Build from a preset run config. `engine = None` uses the pure-Rust
    /// propagator; `Some` runs Φ through the AOT artifacts on PJRT.
    pub fn new(rc: RunConfig, task: Task, engine: Option<Rc<XlaEngine>>) -> Result<TrainRun> {
        let scheme =
            if rc.model.total_layers() >= 64 { Init::DeepNet } else { Init::Default };
        let params = ParamStore::init(&rc.model, scheme, rc.train.seed);
        Self::from_params(rc, task, params, engine)
    }

    /// Build around existing parameters (fine-tuning / comparison runs).
    pub fn from_params(
        rc: RunConfig,
        task: Task,
        params: ParamStore,
        engine: Option<Rc<XlaEngine>>,
    ) -> Result<TrainRun> {
        let prop: Box<dyn Propagator> = match engine {
            Some(e) => Box::new(XlaPropagator::for_model(e, &rc.model, params.layers.clone())?),
            None => Box::new(RustPropagator::for_model(&rc.model, params.layers.clone())),
        };
        let m = &rc.model;
        let data = match task {
            Task::Mlm | Task::Lm => DataGen::Char(CharCorpus::new(m.vocab - 1, rc.train.seed, 3)),
            Task::Tag => DataGen::Morpho(MorphoTask::new(m.vocab, m.n_classes, rc.train.seed)),
            Task::Cls => DataGen::Images(ImageTask::new(m.seq, m.vocab, m.n_classes)),
            Task::Translate => DataGen::Pairs(TranslateTask::new(m.vocab, rc.train.seed, false)),
        };
        let opt = Optimizer::new(rc.train.opt, &params.group_sizes(), rc.train.weight_decay);
        let sched = LrSchedule {
            base_lr: rc.train.lr,
            warmup: rc.train.warmup,
            decay: if rc.train.warmup > 0 {
                Decay::Cosine { total: rc.train.steps, min_frac: 0.1 }
            } else {
                Decay::Constant
            },
        };
        let controller = AdaptiveController::new(if rc.train.adaptive {
            rc.train.probe_every
        } else {
            0
        });
        let seed = rc.train.seed;
        Ok(TrainRun {
            rc,
            task,
            params,
            prop,
            opt,
            sched,
            controller,
            data,
            train_rng: Rng::new(seed.wrapping_mul(2) + 1),
            val_rng_seed: seed.wrapping_mul(2) + 2,
            warm: None,
            warm_start: true,
            step: 0,
            initial_loss: None,
            switched_at: None,
        })
    }

    fn mid_range(&self) -> (usize, usize) {
        let n = self.rc.model.total_layers();
        let bo = self.rc.model.buffer_open;
        let bc = self.rc.model.buffer_close;
        (bo, n - bo - bc)
    }

    /// Embed a batch into the propagator's state shape.
    fn embed(&self, tokens: &[i32], tgt_in: Option<&[i32]>) -> Tensor {
        let m = &self.rc.model;
        let x = heads::embed_fwd(tokens, &self.params.w_emb, &self.params.w_pos, m.batch, m.seq, m.d_model);
        match tgt_in {
            None => x,
            Some(t) => {
                let y = heads::embed_fwd(t, &self.params.w_emb, &self.params.w_pos, m.batch, m.seq, m.d_model);
                let mut data = Vec::with_capacity(x.len() * 2);
                data.extend_from_slice(x.data());
                data.extend_from_slice(y.data());
                Tensor::from_vec(data, &self.prop.state_shape())
            }
        }
    }

    /// Final decoder-side activation (the Y half for EncDec, x otherwise).
    fn head_view(&self, z: &Tensor) -> Tensor {
        let m = &self.rc.model;
        if m.arch == Arch::EncDec {
            let half = z.len() / 2;
            Tensor::from_vec(z.data()[half..].to_vec(), &[m.batch, m.seq, m.d_model])
        } else {
            z.clone()
        }
    }

    /// Lift a head cotangent back into the state shape.
    fn lift_ct(&self, lam_head: Tensor) -> Tensor {
        let m = &self.rc.model;
        if m.arch == Arch::EncDec {
            let mut data = vec![0.0f32; lam_head.len() * 2];
            data[lam_head.len()..].copy_from_slice(lam_head.data());
            Tensor::from_vec(data, &self.prop.state_shape())
        } else {
            lam_head
        }
    }

    /// One micro-batch: forward, loss, adjoint, gradients (no update).
    /// Returns (loss, acc, rho_fwd, rho_bwd, layer_grads, head_grads).
    #[allow(clippy::type_complexity)]
    fn micro_batch(
        &mut self,
        probe: bool,
    ) -> (f32, f32, Option<f64>, Option<f64>, Vec<Vec<f32>>, HeadGrads) {
        let m = self.rc.model.clone();
        let n_layers = m.total_layers();
        let (bo, n_mid) = self.mid_range();

        // --- sample a batch ---------------------------------------------
        let (tokens, targets, mask, labels, tgt_in): (Vec<i32>, Vec<i32>, Vec<f32>, Vec<i32>, Option<Vec<i32>>) =
            match (&self.data, self.task) {
                (DataGen::Char(c), Task::Lm) => {
                    let b = c.lm_batch(&mut self.train_rng, m.batch, m.seq);
                    (b.tokens, b.targets, b.mask, vec![], None)
                }
                (DataGen::Char(c), Task::Mlm) => {
                    let b = c.mlm_batch(&mut self.train_rng, m.batch, m.seq, 0.2, (m.vocab - 1) as i32);
                    (b.tokens, b.targets, b.mask, vec![], None)
                }
                (DataGen::Morpho(t), _) => {
                    let b = t.batch(&mut self.train_rng, m.batch, m.seq);
                    (b.tokens, b.targets, b.mask, vec![], None)
                }
                (DataGen::Images(t), _) => {
                    let b = t.batch(&mut self.train_rng, m.batch);
                    (b.tokens, vec![], vec![], b.labels, None)
                }
                (DataGen::Pairs(t), _) => {
                    let b = t.batch(&mut self.train_rng, m.batch, m.seq);
                    (b.src, b.tgt_out, b.mask, vec![], Some(b.tgt_in))
                }
                _ => unreachable!("task/data mismatch"),
            };

        // --- forward ------------------------------------------------------
        let z0 = self.embed(&tokens, tgt_in.as_deref());
        let mut states: Vec<Tensor> = Vec::with_capacity(n_layers + 1);
        states.push(z0);
        for l in 0..bo {
            let next = self.prop.step(l, 1.0, &states[l]);
            states.push(next);
        }
        let mid = RangeProp::new(self.prop.as_ref(), bo, n_mid);
        let solver = MgritSolver::new(&mid, self.rc.mgrit.clone());
        let fwd_iters = if probe {
            self.controller.probe_iters(&self.rc.mgrit).0
        } else {
            self.rc.mgrit.fwd_iters
        };
        let warm = if self.warm_start { self.warm.as_deref() } else { None };
        let (mid_states, fstats) = solver.forward(&states[bo], fwd_iters, warm, probe);
        if self.warm_start && !fstats.serial {
            self.warm = Some(mid_states.clone());
        }
        states.extend(mid_states.into_iter().skip(1));
        for l in (bo + n_mid)..n_layers {
            let next = self.prop.step(l, 1.0, &states[l]);
            states.push(next);
        }

        // --- loss head ------------------------------------------------------
        let x_final = self.head_view(&states[n_layers]);
        let (loss, correct, lam_head, head_grad, denom) = match self.task {
            Task::Lm | Task::Mlm | Task::Translate => {
                let (l, c, lam, gw) =
                    heads::lm_loss(&x_final, &self.params.w_out, &targets, &mask, m.vocab);
                let denom = mask.iter().sum::<f32>().max(1.0);
                (l, c, lam, HeadGrads::out(gw), denom)
            }
            Task::Tag => {
                let (l, c, lam, gw) =
                    heads::tag_loss(&x_final, &self.params.w_cls, &targets, m.n_classes);
                (l, c, lam, HeadGrads::cls(gw), (m.batch * m.seq) as f32)
            }
            Task::Cls => {
                let (l, c, lam, gw) =
                    heads::cls_loss(&x_final, &self.params.w_cls, &labels, m.n_classes);
                (l, c, lam, HeadGrads::cls(gw), m.batch as f32)
            }
        };
        let acc = correct / denom;

        // --- adjoint ---------------------------------------------------------
        let mut lams: Vec<Option<Tensor>> = vec![None; n_layers + 1];
        lams[n_layers] = Some(self.lift_ct(lam_head));
        let mut grads: Vec<Vec<f32>> = (0..n_layers)
            .map(|l| vec![0.0f32; self.prop.theta_len(l)])
            .collect();
        // close buffers: serial adjoint + grads
        for l in ((bo + n_mid)..n_layers).rev() {
            let lam_next = lams[l + 1].take().unwrap();
            self.prop.accumulate_grad(l, &states[l], &lam_next, &mut grads[l]);
            lams[l] = Some(self.prop.adjoint_step(l, 1.0, &states[l], &lam_next));
            lams[l + 1] = Some(lam_next);
        }
        // MGRIT adjoint over the middle
        let bwd_iters = if probe {
            self.controller.probe_iters(&self.rc.mgrit).1
        } else {
            self.rc.mgrit.bwd_iters
        };
        let mid_states_ref = &states[bo..=bo + n_mid];
        let ct = lams[bo + n_mid].clone().unwrap();
        let (mid_lams, bstats) = solver.adjoint(mid_states_ref, &ct, bwd_iters, probe);
        let mid_grads = solver.gradients(mid_states_ref, &mid_lams);
        for (i, g) in mid_grads.into_iter().enumerate() {
            grads[bo + i] = g;
        }
        for (i, lam) in mid_lams.into_iter().enumerate() {
            lams[bo + i] = Some(lam);
        }
        // open buffers
        for l in (0..bo).rev() {
            let lam_next = lams[l + 1].take().unwrap();
            self.prop.accumulate_grad(l, &states[l], &lam_next, &mut grads[l]);
            lams[l] = Some(self.prop.adjoint_step(l, 1.0, &states[l], &lam_next));
            lams[l + 1] = Some(lam_next);
        }

        // --- embedding gradients ----------------------------------------------
        let lam0 = lams[0].take().unwrap();
        let mut g_emb = vec![0.0f32; self.params.w_emb.len()];
        let mut g_pos = vec![0.0f32; self.params.w_pos.len()];
        if m.arch == Arch::EncDec {
            let half = lam0.len() / 2;
            let inner = [m.batch, m.seq, m.d_model];
            let lx = Tensor::from_vec(lam0.data()[..half].to_vec(), &inner);
            let ly = Tensor::from_vec(lam0.data()[half..].to_vec(), &inner);
            heads::embed_bwd(&tokens, &lx, m.batch, m.seq, m.d_model, &mut g_emb, &mut g_pos);
            heads::embed_bwd(tgt_in.as_ref().unwrap(), &ly, m.batch, m.seq, m.d_model, &mut g_emb, &mut g_pos);
        } else {
            heads::embed_bwd(&tokens, &lam0, m.batch, m.seq, m.d_model, &mut g_emb, &mut g_pos);
        }

        let head = HeadGrads { emb: g_emb, pos: g_pos, ..head_grad };
        (loss, acc, fstats.conv_factor(), bstats.conv_factor(), grads, head)
    }

    /// One full training step (dp micro-batches + probe + update).
    pub fn train_step(&mut self) -> StepRecord {
        self.step += 1;
        let probe = self.controller.should_probe();
        let dp = self.rc.dp_degree.max(1);

        let mut loss_sum = 0.0f32;
        let mut acc_sum = 0.0f32;
        let (mut rho_f, mut rho_b) = (None, None);
        let mut layer_grads: Option<Vec<Vec<f32>>> = None;
        let mut head_grads: Option<HeadGrads> = None;
        for rep in 0..dp {
            let (l, a, rf, rb, lg, hg) = self.micro_batch(probe && rep == 0);
            loss_sum += l;
            acc_sum += a;
            if rep == 0 {
                rho_f = rf;
                rho_b = rb;
            }
            // gradient allreduce (sum; averaged below)
            match (&mut layer_grads, lg) {
                (None, lg) => layer_grads = Some(lg),
                (Some(acc), lg) => {
                    for (a2, b2) in acc.iter_mut().zip(lg) {
                        for (x, y) in a2.iter_mut().zip(b2) {
                            *x += y;
                        }
                    }
                }
            }
            match (&mut head_grads, hg) {
                (None, hg) => head_grads = Some(hg),
                (Some(acc), hg) => acc.add(&hg),
            }
        }
        let mut layer_grads = layer_grads.unwrap();
        let mut head = head_grads.unwrap();
        if dp > 1 {
            let inv = 1.0 / dp as f32;
            for g in layer_grads.iter_mut() {
                g.iter_mut().for_each(|x| *x *= inv);
            }
            head.scale(inv);
        }
        let loss = loss_sum / dp as f32;
        let acc = acc_sum / dp as f32;

        // adaptive controller (probe result + divergence watchdog)
        if probe {
            self.controller.observe(rho_f, rho_b, &mut self.rc.mgrit);
            if self.controller.is_serial() && self.switched_at.is_none() {
                self.switched_at = Some(self.step);
            }
        }
        if self.initial_loss.is_none() {
            self.initial_loss = Some(loss);
        }
        if self.rc.train.adaptive
            && !self.controller.is_serial()
            && (!loss.is_finite() || loss > 3.0 * self.initial_loss.unwrap() + 1.0)
        {
            self.controller.force_serial(&mut self.rc.mgrit);
            self.switched_at = Some(self.step);
        }

        // clip + update
        {
            let mut refs: Vec<&mut [f32]> = layer_grads.iter_mut().map(|g| g.as_mut_slice()).collect();
            let mut head_refs = head.as_mut_refs();
            refs.append(&mut head_refs);
            clip_global_norm(&mut refs, self.rc.train.grad_clip);
        }
        // tasks only touch one head: fill the untouched groups with zeros
        HeadGrads::ensure_like(&mut head.emb, self.params.w_emb.len());
        HeadGrads::ensure_like(&mut head.pos, self.params.w_pos.len());
        HeadGrads::ensure_like(&mut head.out, self.params.w_out.len());
        HeadGrads::ensure_like(&mut head.cls, self.params.w_cls.len());
        let lr = self.sched.at(self.step);
        self.opt.begin_step();
        {
            let mut layers = self.params.layers.borrow_mut();
            for (i, g) in layer_grads.iter().enumerate() {
                self.opt.update(i, lr, &mut layers[i], g);
            }
        }
        let nl = self.rc.model.total_layers();
        self.opt.update(nl, lr, &mut self.params.w_emb, &head.emb);
        self.opt.update(nl + 1, lr, &mut self.params.w_pos, &head.pos);
        self.opt.update(nl + 2, lr, &mut self.params.w_out, &head.out);
        self.opt.update(nl + 3, lr, &mut self.params.w_cls, &head.cls);

        StepRecord {
            step: self.step,
            loss,
            acc,
            lr,
            serial: self.rc.mgrit.is_serial() || self.controller.is_serial(),
            rho_fwd: rho_f,
            rho_bwd: rho_b,
        }
    }

    /// Validation metric over `n_batches` fresh batches (exact forward).
    /// Accuracy for token/sequence tasks; BLEU-4 for Translate.
    pub fn evaluate(&mut self, n_batches: usize) -> f64 {
        let m = self.rc.model.clone();
        let n_layers = m.total_layers();
        let mut rng = Rng::new(self.val_rng_seed);
        let mut correct = 0.0f64;
        let mut total = 0.0f64;
        let mut pairs: Vec<(Vec<i32>, Vec<i32>)> = Vec::new();
        for _ in 0..n_batches {
            let (tokens, targets, mask, labels, tgt_in): (Vec<i32>, Vec<i32>, Vec<f32>, Vec<i32>, Option<Vec<i32>>) =
                match (&self.data, self.task) {
                    (DataGen::Char(c), Task::Lm) => {
                        let b = c.lm_batch(&mut rng, m.batch, m.seq);
                        (b.tokens, b.targets, b.mask, vec![], None)
                    }
                    (DataGen::Char(c), Task::Mlm) => {
                        let b = c.mlm_batch(&mut rng, m.batch, m.seq, 0.2, (m.vocab - 1) as i32);
                        (b.tokens, b.targets, b.mask, vec![], None)
                    }
                    (DataGen::Morpho(t), _) => {
                        let b = t.batch(&mut rng, m.batch, m.seq);
                        (b.tokens, b.targets, b.mask, vec![], None)
                    }
                    (DataGen::Images(t), _) => {
                        let b = t.batch(&mut rng, m.batch);
                        (b.tokens, vec![], vec![], b.labels, None)
                    }
                    (DataGen::Pairs(t), _) => {
                        let b = t.batch(&mut rng, m.batch, m.seq);
                        (b.src, b.tgt_out, b.mask, vec![], Some(b.tgt_in))
                    }
                    _ => unreachable!(),
                };
            // exact serial forward for evaluation
            let mut z = self.embed(&tokens, tgt_in.as_deref());
            for l in 0..n_layers {
                z = self.prop.step(l, 1.0, &z);
            }
            let x_final = self.head_view(&z);
            match self.task {
                Task::Lm | Task::Mlm => {
                    let (_, c, _, _) =
                        heads::lm_loss(&x_final, &self.params.w_out, &targets, &mask, m.vocab);
                    correct += c as f64;
                    total += mask.iter().sum::<f32>() as f64;
                }
                Task::Tag => {
                    let (_, c, _, _) =
                        heads::tag_loss(&x_final, &self.params.w_cls, &targets, m.n_classes);
                    correct += c as f64;
                    total += (m.batch * m.seq) as f64;
                }
                Task::Cls => {
                    let (_, c, _, _) =
                        heads::cls_loss(&x_final, &self.params.w_cls, &labels, m.n_classes);
                    correct += c as f64;
                    total += m.batch as f64;
                }
                Task::Translate => {
                    let preds = heads::argmax_tokens(&x_final, &self.params.w_out, m.vocab);
                    for b in 0..m.batch {
                        pairs.push((
                            preds[b * m.seq..(b + 1) * m.seq].to_vec(),
                            targets[b * m.seq..(b + 1) * m.seq].to_vec(),
                        ));
                    }
                }
            }
        }
        if self.task == Task::Translate {
            bleu4(&pairs)
        } else {
            correct / total.max(1.0)
        }
    }

    /// Full training loop with periodic evaluation.
    pub fn train(&mut self) -> Result<TrainReport> {
        let mut report = TrainReport::default();
        let steps = self.rc.train.steps;
        let eval_every = self.rc.train.eval_every.max(1);
        for _ in 0..steps {
            let rec = self.train_step();
            if self.step % eval_every == 0 || self.step == steps {
                let metric = self.evaluate(2);
                report.evals.push(EvalRecord { step: self.step, metric });
            }
            report.curve.push(rec);
        }
        report.final_loss = report.curve.last().map(|r| r.loss).unwrap_or(f32::NAN);
        report.final_metric = report.evals.last().map(|e| e.metric).unwrap_or(0.0);
        report.probes = self.controller.history.clone();
        report.phi_fwd = self.prop.counters().fwd();
        report.phi_vjp = self.prop.counters().vjp();
        report.switched_at = self.switched_at;
        Ok(report)
    }
}

/// Gradients of the non-layer parameter groups.
pub struct HeadGrads {
    pub emb: Vec<f32>,
    pub pos: Vec<f32>,
    pub out: Vec<f32>,
    pub cls: Vec<f32>,
}

impl HeadGrads {
    fn out(gw: Vec<f32>) -> HeadGrads {
        HeadGrads { emb: vec![], pos: vec![], out: gw, cls: vec![] }
    }

    fn cls(gw: Vec<f32>) -> HeadGrads {
        HeadGrads { emb: vec![], pos: vec![], out: vec![], cls: gw }
    }

    pub(super) fn ensure_like(v: &mut Vec<f32>, n: usize) {
        if v.is_empty() {
            v.resize(n, 0.0);
        }
    }

    fn add(&mut self, other: &HeadGrads) {
        for (a, b) in [
            (&mut self.emb, &other.emb),
            (&mut self.pos, &other.pos),
            (&mut self.out, &other.out),
            (&mut self.cls, &other.cls),
        ] {
            if b.is_empty() {
                continue;
            }
            Self::ensure_like(a, b.len());
            for (x, y) in a.iter_mut().zip(b) {
                *x += y;
            }
        }
    }

    fn scale(&mut self, s: f32) {
        for v in [&mut self.emb, &mut self.pos, &mut self.out, &mut self.cls] {
            v.iter_mut().for_each(|x| *x *= s);
        }
    }

    fn as_mut_refs(&mut self) -> Vec<&mut [f32]> {
        [&mut self.emb, &mut self.pos, &mut self.out, &mut self.cls]
            .into_iter()
            .filter(|v| !v.is_empty())
            .map(|v| v.as_mut_slice())
            .collect()
    }
}
