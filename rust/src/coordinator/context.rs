//! Persistent per-session solve state: the shared train/infer **forward
//! core**, the adjoint-side extension the training session adds on top,
//! and the reusable fine-grid workspaces.
//!
//! Before this module existed every forward/adjoint solve rebuilt the full
//! MGRIT level hierarchy (`MgritCore::new` allocates W/G/W_init storage on
//! every level) and handed its solution back as a `to_vec()` clone, and
//! `Session::micro_batch` reallocated its `states`/`lams`/`grads` vectors
//! per batch. The grid structure only depends on (n_steps, cf, levels,
//! fcf, state shape) — fixed for the lifetime of a session — so all of
//! that is pure per-step overhead, growing with depth exactly where
//! layer-parallel training is supposed to win (Günther et al. 2020 and the
//! source paper both amortize the hierarchy across the whole run).
//!
//! ## The train/infer split
//!
//! The forward solve is the part of a training step that *serving* needs
//! too — batched decoding is nothing but repeated forward solves over the
//! same cached hierarchy. The ownership is therefore layered:
//!
//! * [`ForwardWorkspace`] — forward-only fine-grid buffers: the states
//!   Z_0..Z_N, the `[B,S,D]` head-staging tensor (decoder half of the
//!   stacked EncDec state), and the ping-pong tensor for rolling
//!   (evaluation-style) forwards.
//! * [`ForwardContext`] — the shared **train/infer forward core**: the
//!   [`Backend`] strategy, the cached forward [`MgritCore`], the
//!   TorchBraid-style warm-start flag, and a [`ForwardWorkspace`]. Both
//!   [`crate::coordinator::Session`] (training) and
//!   [`crate::infer::InferSession`] (batched decoding/prediction) own one
//!   and drive every forward solve through it —
//!   [`ForwardContext::forward_mid`] for the ParallelNet mid-range,
//!   [`ForwardContext::forward_full`] for the whole stack including the
//!   serial buffer layers (Appendix B).
//! * [`StepWorkspace`] — the training-only extension: adjoints λ_0..λ_N,
//!   per-layer and head gradient accumulators, the loss-head cotangent
//!   buffer and numeric scratch, and the dp stash/fold scratch set.
//! * [`SolveContext`] — a [`ForwardContext`] plus the cached **adjoint**
//!   hierarchy and a [`StepWorkspace`]; what a training `Session` owns.
//!
//! Warm starts are tracked as a validity flag over the forward workspace
//! (the previous solve's solution is already sitting there, so
//! warm-starting is copy-free) and dropped the moment any solve runs
//! serial: stale after the §3.2.3 switch, and it would poison a later
//! non-serial run restored from the same session. Serial mode (`iters =
//! None` after backend mapping) bypasses the hierarchy entirely — exact
//! sweeps run in place on the workspace, no core is built, touched, or
//! copied through. Iteration-count changes (the §3.2.3 `IncreaseIters`
//! transition) reuse the cached cores; a cf / levels / fcf change triggers
//! an explicit rebuild. Everything is allocation-free at steady state on
//! every backend (threaded sweeps relax in place on the shared level
//! storage; pinned by `rust/tests/alloc_audit.rs`, training step *and*
//! decode loop).
//!
//! The backend is re-consulted per solve so pool replacement after a
//! poisoned sweep still works with cached cores.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};

use crate::config::{MgritConfig, ModelConfig};
use crate::mgrit::{accumulate_layer_grads, MgritCore, MgritSolver, SolveStats};
use crate::ode::Propagator;
use crate::tensor::Tensor;

use super::backend::Backend;
use super::objective::{HeadGrads, LossScratch, LossSink};
use super::range::RangeProp;

/// (buffer_open, parallel mid-range length) for a model — the split
/// between serial buffer layers and the MGRIT domain, shared by the
/// training session and the inference session so the two cannot drift.
pub fn mid_range(m: &ModelConfig) -> (usize, usize) {
    (m.buffer_open, m.parallel_layers())
}

/// Forward-only fine-grid buffers: states Z_0..Z_N plus the head-staging
/// and ping-pong tensors. Sized once at session build, reused every batch
/// by training *and* inference (the shared train/infer core's storage).
pub struct ForwardWorkspace {
    /// Fine-grid states Z_0..Z_N (N = total layers), state-shaped.
    pub states: Vec<Tensor>,
    /// Head-side activation buffer [B,S,D] (the decoder half of the
    /// stacked EncDec state; unused for flat-state architectures).
    pub head: Tensor,
    /// Second ping-pong buffer for rolling (evaluation) forwards.
    pub pp: Tensor,
    /// Single-position decode row state [B,1,D] — the incremental
    /// (KV-cached) decode path's current row per batch slot.
    pub row_cur: Tensor,
    /// Ping-pong partner of `row_cur` for cached layer sweeps.
    pub row_pp: Tensor,
}

impl ForwardWorkspace {
    pub fn new(n_layers: usize, state_shape: &[usize], head_shape: &[usize]) -> ForwardWorkspace {
        // decode rows are one position wide; non-[B,S,D] head shapes (the
        // linear-ODE test problems) never decode, so any shape serves
        let row_shape: Vec<usize> = if head_shape.len() == 3 {
            vec![head_shape[0], 1, head_shape[2]]
        } else {
            head_shape.to_vec()
        };
        ForwardWorkspace {
            states: (0..=n_layers).map(|_| Tensor::zeros(state_shape)).collect(),
            head: Tensor::zeros(head_shape),
            pp: Tensor::zeros(state_shape),
            row_cur: Tensor::zeros(&row_shape),
            row_pp: Tensor::zeros(&row_shape),
        }
    }

    /// Stage the loss/inference head's input for workspace state `idx`:
    /// stacked EncDec states copy their decoder half into the persistent
    /// `head` buffer; flat states are handed to the head directly.
    pub fn staged_head_view(&mut self, idx: usize, stacked: bool) -> &Tensor {
        staged_head_view(&self.states, &mut self.head, idx, stacked)
    }
}

/// Training-only step buffers: adjoints λ_0..λ_N and every gradient
/// accumulator plus the loss-head side. Sized once at session build,
/// reused every batch. The forward-side buffers live in the session's
/// [`ForwardWorkspace`] — an `InferSession` never allocates any of this.
pub struct StepWorkspace {
    /// Fine-grid adjoints λ_0..λ_N, state-shaped.
    pub lams: Vec<Tensor>,
    /// Per-layer parameter gradient accumulators (θ-shaped). Zeroed once
    /// per optimizer step; `accumulate_grad` adds into them, and dp > 1
    /// micro-batches sum replica-style via
    /// [`StepWorkspace::stash_grads`]/[`StepWorkspace::fold_stashed_grads`].
    pub grads: Vec<Vec<f32>>,
    /// Embedding-table gradient accumulator (always full-size).
    pub g_emb: Vec<f32>,
    /// Positional-embedding gradient accumulator.
    pub g_pos: Vec<f32>,
    /// LM-head gradient accumulator.
    pub g_out: Vec<f32>,
    /// Classifier-head gradient accumulator.
    pub g_cls: Vec<f32>,
    /// Loss-head cotangent buffer [B,S,D] (filled by
    /// [`crate::coordinator::Objective::loss_into`], then lifted into λ_N).
    pub lam_head: Tensor,
    /// Reusable loss-head numeric scratch (logits / pooled rows).
    pub loss_scratch: LossScratch,
    /// Second gradient-accumulator set for dp > 1 micro-batch summation
    /// (see [`StepWorkspace::stash_grads`]); lazily allocated on the first
    /// multi-micro-batch step so dp = 1 never pays for it.
    pub(crate) dp_scratch: Option<GradScratch>,
}

/// The parked running sum while a dp micro-batch computes its own totals.
pub(crate) struct GradScratch {
    grads: Vec<Vec<f32>>,
    g_emb: Vec<f32>,
    g_pos: Vec<f32>,
    g_out: Vec<f32>,
    g_cls: Vec<f32>,
}

impl StepWorkspace {
    /// Allocate all adjoint-side buffers up front. `head_sizes` is
    /// `[w_emb, w_pos, w_out, w_cls]` flat lengths.
    pub fn new(
        n_layers: usize,
        state_shape: &[usize],
        head_shape: &[usize],
        theta_lens: &[usize],
        head_sizes: [usize; 4],
    ) -> StepWorkspace {
        assert_eq!(theta_lens.len(), n_layers, "need one θ length per layer");
        StepWorkspace {
            lams: (0..=n_layers).map(|_| Tensor::zeros(state_shape)).collect(),
            grads: theta_lens.iter().map(|&t| vec![0.0f32; t]).collect(),
            g_emb: vec![0.0f32; head_sizes[0]],
            g_pos: vec![0.0f32; head_sizes[1]],
            g_out: vec![0.0f32; head_sizes[2]],
            g_cls: vec![0.0f32; head_sizes[3]],
            lam_head: Tensor::zeros(head_shape),
            loss_scratch: LossScratch::default(),
            dp_scratch: None,
        }
    }

    /// Global-norm gradient clipping over every accumulator, without
    /// materializing a ref-list (the allocation-free twin of
    /// [`crate::opt::clip_global_norm`]; identical accumulation and
    /// scaling order, so the clipped values are bitwise the same).
    pub fn clip_global(&mut self, max_norm: f32) -> f32 {
        let mut sq = 0.0f64;
        for g in self.grads.iter() {
            for &x in g.iter() {
                sq += (x as f64) * (x as f64);
            }
        }
        for g in [&self.g_emb, &self.g_pos, &self.g_out, &self.g_cls] {
            for &x in g.iter() {
                sq += (x as f64) * (x as f64);
            }
        }
        let norm = sq.sqrt() as f32;
        if max_norm > 0.0 && norm > max_norm {
            let scale = max_norm / norm;
            for g in self.grads.iter_mut() {
                for x in g.iter_mut() {
                    *x *= scale;
                }
            }
            for g in [&mut self.g_emb, &mut self.g_pos, &mut self.g_out, &mut self.g_cls] {
                for x in g.iter_mut() {
                    *x *= scale;
                }
            }
        }
        norm
    }

    /// Park the running gradient sum in the dp scratch set and zero the
    /// primary accumulators, so the next micro-batch computes its totals
    /// independently. Paired with [`StepWorkspace::fold_stashed_grads`] —
    /// together they reproduce the distributed-replica allreduce order
    /// bitwise: each micro-batch sums into fresh zeroed buffers and the
    /// per-micro-batch *totals* are then added (v1 semantics), instead of
    /// interleaving one micro-batch's element updates onto another's
    /// partial sums (FP addition is not associative).
    pub fn stash_grads(&mut self) {
        if self.dp_scratch.is_none() {
            self.dp_scratch = Some(GradScratch {
                grads: self.grads.iter().map(|g| vec![0.0f32; g.len()]).collect(),
                g_emb: vec![0.0f32; self.g_emb.len()],
                g_pos: vec![0.0f32; self.g_pos.len()],
                g_out: vec![0.0f32; self.g_out.len()],
                g_cls: vec![0.0f32; self.g_cls.len()],
            });
        }
        let s = self.dp_scratch.as_mut().unwrap();
        std::mem::swap(&mut self.grads, &mut s.grads);
        std::mem::swap(&mut self.g_emb, &mut s.g_emb);
        std::mem::swap(&mut self.g_pos, &mut s.g_pos);
        std::mem::swap(&mut self.g_out, &mut s.g_out);
        std::mem::swap(&mut self.g_cls, &mut s.g_cls);
        self.zero_grads();
    }

    /// Fold the parked running sum back in: primary = stashed + primary
    /// per element (running sum on the left, matching the v1 allreduce;
    /// bitwise equal by commutativity of IEEE addition).
    pub fn fold_stashed_grads(&mut self) {
        let s = self.dp_scratch.as_ref().expect("fold_stashed_grads without stash_grads");
        for (p, sg) in self.grads.iter_mut().zip(s.grads.iter()) {
            for (a, b) in p.iter_mut().zip(sg.iter()) {
                *a = *b + *a;
            }
        }
        for (p, sg) in [
            (&mut self.g_emb, &s.g_emb),
            (&mut self.g_pos, &s.g_pos),
            (&mut self.g_out, &s.g_out),
            (&mut self.g_cls, &s.g_cls),
        ] {
            for (a, b) in p.iter_mut().zip(sg.iter()) {
                *a = *b + *a;
            }
        }
    }

    /// Zero every gradient accumulator (start of a training step).
    pub fn zero_grads(&mut self) {
        for g in self.grads.iter_mut() {
            g.iter_mut().for_each(|x| *x = 0.0);
        }
        for g in [&mut self.g_emb, &mut self.g_pos, &mut self.g_out, &mut self.g_cls] {
            g.iter_mut().for_each(|x| *x = 0.0);
        }
    }

    /// Scale every gradient accumulator (dp gradient averaging).
    pub fn scale_grads(&mut self, s: f32) {
        for g in self.grads.iter_mut() {
            g.iter_mut().for_each(|x| *x *= s);
        }
        for g in [&mut self.g_emb, &mut self.g_pos, &mut self.g_out, &mut self.g_cls] {
            g.iter_mut().for_each(|x| *x *= s);
        }
    }

    /// Fold the head gradients an objective's loss head produced into the
    /// persistent accumulators. Objectives fill only the groups they
    /// touch; empty groups are skipped (the accumulators are full-size
    /// and zero, so untouched groups stay zero for the optimizer).
    pub fn add_head_grads(&mut self, head: &HeadGrads) {
        for (acc, src) in [
            (&mut self.g_emb, &head.emb),
            (&mut self.g_pos, &head.pos),
            (&mut self.g_out, &head.out),
            (&mut self.g_cls, &head.cls),
        ] {
            if src.is_empty() {
                continue;
            }
            assert_eq!(acc.len(), src.len(), "head gradient group size mismatch");
            for (a, b) in acc.iter_mut().zip(src.iter()) {
                *a += b;
            }
        }
    }

    /// Total flat length of one replica's gradient payload (layers then
    /// the four head groups) — the `comm::Fabric` allreduce message size.
    pub fn flat_grad_len(&self) -> usize {
        self.grads.iter().map(|g| g.len()).sum::<usize>()
            + self.g_emb.len()
            + self.g_pos.len()
            + self.g_out.len()
            + self.g_cls.len()
    }

    /// Append every gradient accumulator to `buf` as one flat payload:
    /// `grads[0..n]`, then `g_emb`, `g_pos`, `g_out`, `g_cls`. The wire
    /// format of the dp gradient reduction — written into a recycled
    /// [`crate::parallel::comm::Endpoint::send_scratch`] buffer, so the
    /// steady state allocates nothing.
    pub fn write_grads_flat(&self, buf: &mut Vec<f32>) {
        for g in self.grads.iter() {
            buf.extend_from_slice(g);
        }
        buf.extend_from_slice(&self.g_emb);
        buf.extend_from_slice(&self.g_pos);
        buf.extend_from_slice(&self.g_out);
        buf.extend_from_slice(&self.g_cls);
    }

    /// Fold another replica's flat payload (the [`StepWorkspace::write_grads_flat`]
    /// layout) into these accumulators: `primary = primary + incoming` per
    /// element — the running sum stays on the left, the same association
    /// as [`StepWorkspace::fold_stashed_grads`]'s `stashed + fresh`, so a
    /// replica-ascending sequence of folds reproduces the serial dp loop's
    /// summation order bitwise.
    pub fn fold_grads_flat(&mut self, flat: &[f32]) {
        assert_eq!(flat.len(), self.flat_grad_len(), "dp gradient payload length mismatch");
        let mut off = 0usize;
        for g in self.grads.iter_mut() {
            for (a, &b) in g.iter_mut().zip(&flat[off..off + g.len()]) {
                *a += b;
            }
            off += g.len();
        }
        for g in [&mut self.g_emb, &mut self.g_pos, &mut self.g_out, &mut self.g_cls] {
            for (a, &b) in g.iter_mut().zip(&flat[off..off + g.len()]) {
                *a += b;
            }
            off += g.len();
        }
    }
}

/// Stage the loss head's input for workspace state `idx`: stacked EncDec
/// states copy their decoder half into the persistent `head` buffer; flat
/// states are handed to the head directly. The one place the decoder-half
/// split lives — shared by the training path
/// ([`SolveContext::head_view_and_sink`]), the session's evaluation sweep,
/// and the inference head dispatch, so none of them can drift.
pub(crate) fn staged_head_view<'a>(
    states: &'a [Tensor],
    head: &'a mut Tensor,
    idx: usize,
    stacked: bool,
) -> &'a Tensor {
    if stacked {
        let half = states[idx].len() / 2;
        head.data_mut().copy_from_slice(&states[idx].data()[half..]);
        head
    } else {
        &states[idx]
    }
}

/// One cached hierarchy plus the inputs its storage was built from.
struct CachedCore {
    n: usize,
    cf: usize,
    levels: usize,
    fcf: bool,
    workers: usize,
    shape: Vec<usize>,
    core: MgritCore,
}

/// Fetch (or build) the cached core for one direction. Allocation-free on
/// a cache hit; a miss builds storage for the new key.
fn core_for<'a>(
    slot: &'a mut Option<CachedCore>,
    builds: &mut u64,
    n: usize,
    cfg: &MgritConfig,
    workers: usize,
    shape: &[usize],
) -> &'a mut MgritCore {
    let hit = matches!(
        slot,
        Some(c) if c.n == n
            && c.cf == cfg.cf
            && c.levels == cfg.levels
            && c.fcf == cfg.fcf
            && c.workers == workers
            && c.shape[..] == *shape
            // a panicked threaded sweep leaves the core with taken-out
            // level storage; rebuild instead of reusing it gutted
            && c.core.is_intact()
    );
    if !hit {
        let proto = Tensor::zeros(shape);
        let core = MgritCore::new(n, cfg.cf, cfg.levels, cfg.fcf, &proto).with_workers(workers);
        *slot = Some(CachedCore {
            n,
            cf: cfg.cf,
            levels: cfg.levels,
            fcf: cfg.fcf,
            workers,
            shape: shape.to_vec(),
            core,
        });
        *builds += 1;
    }
    &mut slot.as_mut().unwrap().core
}

/// Render a caught sweep-panic payload for the fault-event log: typed
/// [`crate::parallel::FabricError`] payloads (a dead halo sender), plain
/// `&str`/`String` panics (a worker Φ panic), or an opaque marker.
fn panic_detail(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(e) = p.downcast_ref::<crate::parallel::FabricError>() {
        e.to_string()
    } else if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Per-solve backend re-consultation, single-sourced for every entry
/// point: fetch (or build) the cached core for one direction and re-attach
/// the backend's *current* pool (a pool poisoned by a panicked sweep is
/// rebuilt by the backend; the cached hierarchy must pick the replacement
/// up, not pin the dead one).
fn configured_core<'a>(
    backend: &dyn Backend,
    slot: &'a mut Option<CachedCore>,
    builds: &mut u64,
    n: usize,
    cfg: &MgritConfig,
    shape: &[usize],
) -> &'a mut MgritCore {
    let core = core_for(slot, builds, n, cfg, backend.workers(), shape);
    core.set_pool(backend.pool());
    core
}

/// The shared train/infer **forward core** (see module docs): backend
/// strategy + cached forward hierarchy + warm-start flag + forward
/// workspace. A training [`SolveContext`] wraps one; an
/// [`crate::infer::InferSession`] owns one directly.
pub struct ForwardContext {
    backend: Box<dyn Backend>,
    fwd: Option<CachedCore>,
    /// Warm-start validity for the MGRIT forward solve (TorchBraid-style).
    /// The iterate itself is not stored separately: after every V-cycle
    /// solve `ws.states[bo..=bo+n]` *is* the converged mid-range iterate,
    /// and nothing between solves overwrites its interior (buffer sweeps
    /// touch `..=bo` and `bo+n..`, evaluation ping-pongs `states[0]`/`pp`)
    /// — so the next solve warm-starts straight from the workspace with no
    /// copy. The flag is dropped the moment a solve runs serial (the
    /// §3.2.3 switch leaves a stale trajectory).
    warm_valid: bool,
    /// Forward fine-grid buffers (public: buffer-layer sweeps, embedding
    /// and the heads operate on them directly).
    pub ws: ForwardWorkspace,
    core_builds: u64,
}

impl ForwardContext {
    /// Wrap a backend and a pre-sized forward workspace. The core is built
    /// lazily on the first V-cycle solve.
    pub fn new(backend: Box<dyn Backend>, ws: ForwardWorkspace) -> ForwardContext {
        ForwardContext { backend, fwd: None, warm_valid: false, ws, core_builds: 0 }
    }

    /// The execution strategy this context solves with.
    pub fn backend(&self) -> &dyn Backend {
        self.backend.as_ref()
    }

    /// How many forward `MgritCore` hierarchies this context has built.
    pub fn core_builds(&self) -> u64 {
        self.core_builds
    }

    /// Is a warm-start iterate currently valid in the workspace?
    pub fn has_warm(&self) -> bool {
        self.warm_valid
    }

    /// Drop the warm-start iterate (stale after a serial switch; also
    /// called by `forward_mid` itself whenever a solve ran serially).
    pub fn clear_warm(&mut self) {
        self.warm_valid = false;
    }

    /// Declare the workspace's current mid-range contents a valid warm
    /// iterate (checkpoint restore: the saved iterate was just copied in).
    pub fn mark_warm(&mut self) {
        self.warm_valid = true;
    }

    /// Drop the cached hierarchy: the next V-cycle solve rebuilds it.
    pub fn invalidate(&mut self) {
        self.fwd = None;
    }

    /// Forward solve over the mid (ParallelNet) range: reads Z_{bo} from
    /// `ws.states[bo]`, writes the solution into `ws.states[bo..=bo+n]`
    /// (n = `prop.n_steps()`, the mid view's step count). Serial mode
    /// (`iters = None` after backend mapping — the Serial backend or the
    /// §3.2.3 switch) bypasses the hierarchy entirely: it sweeps in place
    /// on the workspace without building, touching, or copying through a
    /// core, and drops the now-dead warm iterate. V-cycle mode runs on the
    /// cached core and refreshes the warm iterate in place when `use_warm`
    /// is set. Allocation-free at steady state on every backend (threaded
    /// sweeps relax in place on the shared level storage).
    pub fn forward_mid(
        &mut self,
        prop: &dyn Propagator,
        cfg: &MgritConfig,
        bo: usize,
        iters: Option<usize>,
        use_warm: bool,
        track_residuals: bool,
    ) -> SolveStats {
        let n = prop.n_steps();
        let ForwardContext { backend, fwd, warm_valid, ws, core_builds } = self;
        assert!(bo + n < ws.states.len(), "mid range outside the workspace");
        let mapped = backend.solve_iters(iters);
        if mapped.is_none() {
            // exact propagation: no hierarchy, no handoff copy, and the
            // warm trajectory is stale the moment the run goes serial (it
            // would poison a later non-serial run from this session)
            *warm_valid = false;
            let before = prop.counters().fwd();
            prop.step_seq_into(0, 1.0, &mut ws.states[bo..=bo + n]);
            return SolveStats {
                iterations: 0,
                residuals: vec![],
                phi_evals: prop.counters().fwd() - before,
                serial: true,
            };
        }
        // policy 3 (see crate::fault): a panicked pooled sweep — a worker
        // Φ panic or a typed `FabricError` halo failure — is caught here
        // instead of unwinding into the session. The sweep retries once on
        // the backend's rebuilt pool (the cached hierarchy survives a
        // panic, pinned by `panicked_threaded_sweep_is_recovered_...`
        // below); a second panic drops to the in-thread V-cycle schedule
        // (`set_pool(None)` + one worker) — bitwise identical to the
        // pooled sweep, unlike an exact serial solve. A third failure
        // propagates: the poison is in Φ itself, not the execution layer.
        let mut attempt = 0u32;
        let stats = loop {
            let core =
                configured_core(&**backend, fwd, core_builds, n, cfg, ws.states[bo].shape());
            if attempt == 2 {
                core.set_pool(None);
                core.set_workers(1);
            }
            let solver = MgritSolver::new(prop, cfg.clone());
            // the previous solve's solution is still sitting in the
            // workspace: warm-start from it directly, no stored copy (the
            // core snapshots warm[1..=n] into its own storage before
            // anything is written, so a panicked attempt never tears it)
            let warm_ref: Option<&[Tensor]> =
                if use_warm && *warm_valid { Some(&ws.states[bo..=bo + n]) } else { None };
            match catch_unwind(AssertUnwindSafe(|| {
                solver.forward_with(core, &ws.states[bo], mapped, warm_ref, track_residuals)
            })) {
                Ok(stats) => break stats,
                Err(p) if attempt < 2 => {
                    attempt += 1;
                    let action =
                        if attempt == 2 { "sweep_serial_fallback" } else { "sweep_retry" };
                    crate::fault::record("pool.sweep", attempt as u64, action, panic_detail(&*p));
                }
                Err(p) => resume_unwind(p),
            }
        };
        let core = configured_core(&**backend, fwd, core_builds, n, cfg, ws.states[bo].shape());
        core.solution_into(&mut ws.states[bo..=bo + n]);
        *warm_valid = use_warm;
        stats
    }

    /// Full forward pass over the whole stack, from the embedded Z_0
    /// already sitting in `ws.states[0]`: serial open-buffer sweep →
    /// mid-range solve ([`ForwardContext::forward_mid`] over a
    /// [`RangeProp`] view) → serial close-buffer sweep. The one forward
    /// path both the training micro-batch and batched inference run
    /// through (Appendix B buffer handling included). `prop` is the
    /// full-depth propagator; `(bo, n_mid)` from [`mid_range`].
    #[allow(clippy::too_many_arguments)]
    pub fn forward_full(
        &mut self,
        prop: &dyn Propagator,
        cfg: &MgritConfig,
        bo: usize,
        n_mid: usize,
        iters: Option<usize>,
        use_warm: bool,
        track_residuals: bool,
    ) -> SolveStats {
        self.forward_full_cold_rows(prop, cfg, bo, n_mid, iters, use_warm, track_residuals, &[], 0)
    }

    /// [`ForwardContext::forward_full`] with **per-row warm-start resets**,
    /// the continuous-batching entry point. Batch rows listed in
    /// `cold_rows` (each `row_elems` contiguous elements wide in every
    /// state tensor) have their slice of the warm trajectory overwritten
    /// with that row's slice of the freshly-embedded Z_{bo} before the mid
    /// solve — exactly the initial iterate `MgritCore::solve` installs for
    /// a cold solve. Since every kernel under Φ, restriction, prolongation
    /// and FAS correction is batch-row-independent, a row that just joined
    /// the batch then solves bitwise like the first decode step of a solo
    /// run, while the remaining rows keep warm-chaining undisturbed. With
    /// `cold_rows` empty this is `forward_full`; when no warm iterate is
    /// live (or the solve runs serial) the resets are skipped/irrelevant.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_full_cold_rows(
        &mut self,
        prop: &dyn Propagator,
        cfg: &MgritConfig,
        bo: usize,
        n_mid: usize,
        iters: Option<usize>,
        use_warm: bool,
        track_residuals: bool,
        cold_rows: &[usize],
        row_elems: usize,
    ) -> SolveStats {
        let n_layers = prop.n_steps();
        if bo > 0 {
            // open buffers: serial, in place, one dispatch for the sweep
            prop.step_seq_into(0, 1.0, &mut self.ws.states[..=bo]);
        }
        if use_warm && self.warm_valid && !cold_rows.is_empty() && n_mid > 0 {
            let (z0, rest) = self.ws.states[bo..=bo + n_mid].split_first_mut().unwrap();
            let z0 = z0.data();
            for t in rest.iter_mut() {
                let td = t.data_mut();
                for &r in cold_rows {
                    td[r * row_elems..(r + 1) * row_elems]
                        .copy_from_slice(&z0[r * row_elems..(r + 1) * row_elems]);
                }
            }
        }
        let mid = RangeProp::new(prop, bo, n_mid);
        let stats = self.forward_mid(&mid, cfg, bo, iters, use_warm, track_residuals);
        if bo + n_mid < n_layers {
            // close buffers: serial, in place, one dispatch for the sweep
            prop.step_seq_into(bo + n_mid, 1.0, &mut self.ws.states[bo + n_mid..]);
        }
        stats
    }

    /// Standalone forward solve on the cached hierarchy (the serving-style
    /// many-solves-one-hierarchy entry point; same signature shape as the
    /// pre-context `Backend::forward`, allocating its result).
    pub fn forward(
        &mut self,
        prop: &dyn Propagator,
        cfg: &MgritConfig,
        z0: &Tensor,
        iters: Option<usize>,
        warm: Option<&[Tensor]>,
        track_residuals: bool,
    ) -> (Vec<Tensor>, SolveStats) {
        let mapped = self.backend.solve_iters(iters);
        if mapped.is_none() {
            // exact propagation has no hierarchy worth caching: run the
            // one-shot solver (transient storage, freed on return)
            return MgritSolver::new(prop, cfg.clone()).forward(z0, None, warm, track_residuals);
        }
        let ForwardContext { backend, fwd, core_builds, .. } = self;
        let core =
            configured_core(&**backend, fwd, core_builds, prop.n_steps(), cfg, z0.shape());
        let solver = MgritSolver::new(prop, cfg.clone());
        let stats = solver.forward_with(core, z0, mapped, warm, track_residuals);
        (core.solution().to_vec(), stats)
    }
}

/// Persistent solve state of one training `Session`: the shared forward
/// core plus the cached adjoint hierarchy and the training-only step
/// buffers (see module docs).
pub struct SolveContext {
    /// The shared train/infer forward core.
    pub fwd: ForwardContext,
    adj: Option<CachedCore>,
    adj_builds: u64,
    /// Training-only step buffers (public: the session's adjoint sweeps,
    /// λ-seeding and optimizer updates operate on them directly).
    pub ws: StepWorkspace,
}

impl SolveContext {
    /// Wrap a backend and pre-sized workspaces into a context. Cores are
    /// built lazily on the first solve per direction.
    pub fn new(
        backend: Box<dyn Backend>,
        fwd_ws: ForwardWorkspace,
        ws: StepWorkspace,
    ) -> SolveContext {
        SolveContext {
            fwd: ForwardContext::new(backend, fwd_ws),
            adj: None,
            adj_builds: 0,
            ws,
        }
    }

    /// The execution strategy this context solves with.
    pub fn backend(&self) -> &dyn Backend {
        self.fwd.backend()
    }

    /// How many `MgritCore` hierarchies this context has built — the
    /// cache-validity acceptance counter: exactly one per direction per
    /// session unless cf/levels/fcf (or the grid size) change mid-run.
    pub fn core_builds(&self) -> u64 {
        self.fwd.core_builds() + self.adj_builds
    }

    /// Is a warm-start iterate currently valid in the workspace?
    pub fn has_warm(&self) -> bool {
        self.fwd.has_warm()
    }

    /// Drop the warm-start iterate (stale after a serial switch).
    pub fn clear_warm(&mut self) {
        self.fwd.clear_warm();
    }

    /// Drop the cached hierarchies: the next solve per direction rebuilds
    /// from scratch. The explicit-rebuild hook for callers that mutate
    /// solver geometry out-of-band (also what the "fresh ctx" benchmark
    /// row exercises).
    pub fn invalidate(&mut self) {
        self.fwd.invalidate();
        self.adj = None;
    }

    /// Split-borrow the loss head's input and output buffers: the final
    /// activation view for forward-workspace state `idx` (stacked EncDec
    /// states copy their decoder half into the persistent `head` buffer)
    /// plus a [`LossSink`] over the cotangent buffer, head-gradient
    /// accumulators, and numeric scratch — disjoint fields, so the
    /// objective can read x_final while writing the sink, with zero
    /// allocations.
    pub fn head_view_and_sink(&mut self, idx: usize, stacked: bool) -> (&Tensor, LossSink<'_>) {
        let SolveContext { fwd, ws, .. } = self;
        let x_final = staged_head_view(&fwd.ws.states, &mut fwd.ws.head, idx, stacked);
        let StepWorkspace { lam_head, g_emb, g_pos, g_out, g_cls, loss_scratch, .. } = ws;
        let sink = LossSink { lam_head, g_emb, g_pos, g_out, g_cls, scratch: loss_scratch };
        (x_final, sink)
    }

    /// Forward solve over the mid range on the shared forward core (see
    /// [`ForwardContext::forward_mid`]).
    pub fn forward_mid(
        &mut self,
        prop: &dyn Propagator,
        cfg: &MgritConfig,
        bo: usize,
        iters: Option<usize>,
        use_warm: bool,
        track_residuals: bool,
    ) -> SolveStats {
        self.fwd.forward_mid(prop, cfg, bo, iters, use_warm, track_residuals)
    }

    /// Adjoint solve over the mid range: reads the frozen states from the
    /// forward workspace `fwd.ws.states[bo..=bo+n]` and the cotangent from
    /// `ws.lams[bo+n]`, writes λ back into `ws.lams[bo..=bo+n]` in natural
    /// order. Serial mode sweeps the transposed Jacobian in place (no
    /// hierarchy); V-cycle mode runs on the cached core. Allocation-free
    /// at steady state on every backend.
    pub fn adjoint_mid(
        &mut self,
        prop: &dyn Propagator,
        cfg: &MgritConfig,
        bo: usize,
        iters: Option<usize>,
        track_residuals: bool,
    ) -> SolveStats {
        let n = prop.n_steps();
        let SolveContext { fwd, adj, adj_builds, ws } = self;
        let states = &fwd.ws.states;
        let lams = &mut ws.lams;
        assert!(bo + n < lams.len(), "mid range outside the workspace");
        let mapped = fwd.backend.solve_iters(iters);
        if mapped.is_none() {
            // exact backward sweep over the frozen states, in place
            let before = prop.counters().vjp();
            for l in (0..n).rev() {
                let (lam_lo, lam_hi) = lams.split_at_mut(bo + l + 1);
                prop.adjoint_step_into(l, 1.0, &states[bo + l], &lam_hi[0], &mut lam_lo[bo + l]);
            }
            return SolveStats {
                iterations: 0,
                residuals: vec![],
                phi_evals: prop.counters().vjp() - before,
                serial: true,
            };
        }
        // policy-3 sweep retry, mirroring `ForwardContext::forward_mid`:
        // retry the panicked adjoint sweep once on the rebuilt pool, then
        // fall back to the in-thread V-cycle schedule, then propagate
        let mut attempt = 0u32;
        let stats = loop {
            let core =
                configured_core(&*fwd.backend, adj, adj_builds, n, cfg, states[bo].shape());
            if attempt == 2 {
                core.set_pool(None);
                core.set_workers(1);
            }
            let solver = MgritSolver::new(prop, cfg.clone());
            match catch_unwind(AssertUnwindSafe(|| {
                solver.adjoint_with(
                    core,
                    &states[bo..=bo + n],
                    &lams[bo + n],
                    mapped,
                    track_residuals,
                )
            })) {
                Ok(stats) => break stats,
                Err(p) if attempt < 2 => {
                    attempt += 1;
                    let action =
                        if attempt == 2 { "sweep_serial_fallback" } else { "sweep_retry" };
                    crate::fault::record("pool.sweep", attempt as u64, action, panic_detail(&*p));
                }
                Err(p) => resume_unwind(p),
            }
        };
        let core = configured_core(&*fwd.backend, adj, adj_builds, n, cfg, states[bo].shape());
        core.solution_rev_into(&mut lams[bo..=bo + n]);
        stats
    }

    /// Accumulate the mid-range per-layer parameter gradients from the
    /// workspace states/adjoints into `ws.grads[bo..bo+n]` (added, not
    /// overwritten — zero once per optimizer step). The loop itself is
    /// [`accumulate_layer_grads`], shared with `MgritSolver`.
    pub fn gradients_mid(&mut self, prop: &dyn Propagator, bo: usize) {
        let SolveContext { fwd, ws, .. } = self;
        accumulate_layer_grads(prop, &fwd.ws.states, &ws.lams, &mut ws.grads, bo);
    }

    /// Standalone forward solve on the cached hierarchy (see
    /// [`ForwardContext::forward`]).
    pub fn forward(
        &mut self,
        prop: &dyn Propagator,
        cfg: &MgritConfig,
        z0: &Tensor,
        iters: Option<usize>,
        warm: Option<&[Tensor]>,
        track_residuals: bool,
    ) -> (Vec<Tensor>, SolveStats) {
        self.fwd.forward(prop, cfg, z0, iters, warm, track_residuals)
    }

    /// Standalone adjoint solve on the cached hierarchy; returns λ_0..λ_N
    /// in natural order.
    pub fn adjoint(
        &mut self,
        prop: &dyn Propagator,
        cfg: &MgritConfig,
        states: &[Tensor],
        ct: &Tensor,
        iters: Option<usize>,
        track_residuals: bool,
    ) -> (Vec<Tensor>, SolveStats) {
        let n = prop.n_steps();
        let mapped = self.fwd.backend.solve_iters(iters);
        if mapped.is_none() {
            return MgritSolver::new(prop, cfg.clone()).adjoint(states, ct, None, track_residuals);
        }
        let SolveContext { fwd, adj, adj_builds, .. } = self;
        let core = configured_core(&*fwd.backend, adj, adj_builds, n, cfg, ct.shape());
        let solver = MgritSolver::new(prop, cfg.clone());
        let stats = solver.adjoint_with(core, states, ct, mapped, track_residuals);
        let sol = core.solution();
        let lambdas: Vec<Tensor> = (0..=n).map(|i| sol[n - i].clone()).collect();
        (lambdas, stats)
    }

    /// Standalone per-layer gradients on the fine grid (allocating; the
    /// training path uses [`SolveContext::gradients_mid`]).
    pub fn gradients(
        &self,
        prop: &dyn Propagator,
        states: &[Tensor],
        lambdas: &[Tensor],
    ) -> Vec<Vec<f32>> {
        let mut grads: Vec<Vec<f32>> =
            (0..prop.n_steps()).map(|l| vec![0.0f32; prop.theta_len(l)]).collect();
        accumulate_layer_grads(prop, states, lambdas, &mut grads, 0);
        grads
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::{Mgrit, Serial, ThreadedMgrit};
    use crate::ode::LinearOde;
    use crate::util::rng::Rng;

    fn cfg(cf: usize, levels: usize) -> MgritConfig {
        MgritConfig { cf, levels, fwd_iters: Some(2), bwd_iters: Some(1), fcf: true }
    }

    fn tiny_ctx(backend: Box<dyn Backend>, n: usize, shape: &[usize]) -> SolveContext {
        SolveContext::new(
            backend,
            ForwardWorkspace::new(n, shape, shape),
            StepWorkspace::new(n, shape, shape, &vec![0usize; n], [0, 0, 0, 0]),
        )
    }

    #[test]
    fn cores_are_built_once_and_reused_across_solves() {
        let mut rng = Rng::new(0);
        let ode = LinearOde::random_stable(&mut rng, 4, 16, 0.1);
        let z0 = Tensor::randn(&mut rng, &[4, 1], 1.0);
        let ct = Tensor::randn(&mut rng, &[4, 1], 1.0);
        let mut ctx = tiny_ctx(Box::new(Mgrit), 16, &[4, 1]);
        assert_eq!(ctx.core_builds(), 0, "cores are lazy");
        let (w, _) = ctx.forward(&ode, &cfg(4, 2), &z0, Some(3), None, false);
        let (l, _) = ctx.adjoint(&ode, &cfg(4, 2), &w, &ct, Some(2), false);
        assert_eq!(ctx.core_builds(), 2, "one core per direction");
        for _ in 0..5 {
            let (w2, _) = ctx.forward(&ode, &cfg(4, 2), &z0, Some(3), None, false);
            let (l2, _) = ctx.adjoint(&ode, &cfg(4, 2), &w2, &ct, Some(2), false);
            for (a, b) in w.iter().zip(&w2) {
                assert_eq!(a.data(), b.data(), "cached forward must be bitwise stable");
            }
            for (a, b) in l.iter().zip(&l2) {
                assert_eq!(a.data(), b.data(), "cached adjoint must be bitwise stable");
            }
        }
        assert_eq!(ctx.core_builds(), 2, "steady state builds nothing");
        // iteration-count changes (the §3.2.3 IncreaseIters transition)
        // reuse the cores; the serial switch (iters = None) bypasses them
        ctx.forward(&ode, &cfg(4, 2), &z0, Some(6), None, false);
        ctx.forward(&ode, &cfg(4, 2), &z0, None, None, false);
        assert_eq!(ctx.core_builds(), 2);
        // a cf change is a different grid: explicit rebuild
        ctx.forward(&ode, &cfg(2, 2), &z0, Some(3), None, false);
        assert_eq!(ctx.core_builds(), 3);
        // and switching back rebuilds again (the cache is 1-deep by design)
        ctx.forward(&ode, &cfg(4, 2), &z0, Some(3), None, false);
        assert_eq!(ctx.core_builds(), 4);
    }

    #[test]
    fn cached_context_matches_fresh_solver_bitwise() {
        let mut rng = Rng::new(1);
        let ode = LinearOde::random_stable(&mut rng, 5, 32, 0.05);
        let z0 = Tensor::randn(&mut rng, &[5, 1], 1.0);
        let ct = Tensor::randn(&mut rng, &[5, 1], 1.0);
        for workers in [1usize, 2, 4] {
            let solver = MgritSolver::with_workers(&ode, cfg(4, 2), workers);
            let (wf, _) = solver.forward(&z0, Some(3), None, false);
            let (lf, _) = solver.adjoint(&wf, &ct, Some(2), false);
            let gf = solver.gradients(&wf, &lf);
            let backend: Box<dyn Backend> = if workers > 1 {
                Box::new(ThreadedMgrit::new(workers))
            } else {
                Box::new(Mgrit)
            };
            let mut ctx = tiny_ctx(backend, 32, &[5, 1]);
            for round in 0..3 {
                let (wc, _) = ctx.forward(&ode, &cfg(4, 2), &z0, Some(3), None, false);
                let (lc, _) = ctx.adjoint(&ode, &cfg(4, 2), &wc, &ct, Some(2), false);
                let gc = ctx.gradients(&ode, &wc, &lc);
                for (a, b) in wf.iter().zip(&wc) {
                    assert_eq!(a.data(), b.data(), "fwd workers={} round={}", workers, round);
                }
                for (a, b) in lf.iter().zip(&lc) {
                    assert_eq!(a.data(), b.data(), "adj workers={} round={}", workers, round);
                }
                assert_eq!(gf, gc, "grads workers={} round={}", workers, round);
            }
            assert_eq!(ctx.core_builds(), 2);
        }
    }

    #[test]
    fn workspace_solves_match_standalone_and_manage_warm() {
        let mut rng = Rng::new(2);
        let n = 16;
        let ode = LinearOde::random_stable(&mut rng, 4, n, 0.1);
        let z0 = Tensor::randn(&mut rng, &[4, 1], 1.0);
        let ct = Tensor::randn(&mut rng, &[4, 1], 1.0);
        let mut ctx = tiny_ctx(Box::new(Mgrit), n, &[4, 1]);
        ctx.fwd.ws.states[0].copy_from(&z0);
        let c = cfg(4, 2);
        let stats = ctx.forward_mid(&ode, &c, 0, Some(3), true, false);
        assert!(!stats.serial);
        assert!(ctx.has_warm(), "V-cycle solve with use_warm stores the iterate");
        ctx.ws.lams[n].copy_from(&ct);
        ctx.adjoint_mid(&ode, &c, 0, Some(2), false);
        // reference: one-shot solver from the same inputs (cold start —
        // so compare against a cold context run, i.e. the first call)
        let solver = MgritSolver::new(&ode, c.clone());
        let (wf, _) = solver.forward(&z0, Some(3), None, false);
        for (a, b) in ctx.fwd.ws.states.iter().zip(&wf) {
            assert_eq!(a.data(), b.data(), "ws forward must match the one-shot solver");
        }
        let (lf, _) = solver.adjoint(&wf, &ct, Some(2), false);
        for (a, b) in ctx.ws.lams.iter().zip(&lf) {
            assert_eq!(a.data(), b.data(), "ws adjoint must match the one-shot solver");
        }
        // a serial solve drops the warm iterate (the §3.2.3 switch)
        let stats = ctx.forward_mid(&ode, &c, 0, None, true, false);
        assert!(stats.serial);
        assert!(!ctx.has_warm(), "serial switch must drop the stale iterate");
        // mark_warm (checkpoint restore) re-arms it
        ctx.fwd.mark_warm();
        assert!(ctx.has_warm());
    }

    #[test]
    fn forward_full_matches_manual_buffer_plus_mid_composition() {
        // forward_full must equal (serial open sweep, mid solve, serial
        // close sweep) composed by hand — the pre-split session behavior
        let mut rng = Rng::new(7);
        let n = 12;
        let (bo, bc) = (2usize, 2usize);
        let n_mid = n - bo - bc;
        let ode = LinearOde::random_stable(&mut rng, 4, n, 0.1);
        let z0 = Tensor::randn(&mut rng, &[4, 1], 1.0);
        let c = cfg(2, 2);
        for iters in [Some(2), None] {
            let mut ctx = ForwardContext::new(
                Box::new(Mgrit),
                ForwardWorkspace::new(n, &[4, 1], &[4, 1]),
            );
            ctx.ws.states[0].copy_from(&z0);
            ctx.forward_full(&ode, &c, bo, n_mid, iters, false, false);
            // manual composition on a second context
            let mut manual = ForwardContext::new(
                Box::new(Mgrit),
                ForwardWorkspace::new(n, &[4, 1], &[4, 1]),
            );
            manual.ws.states[0].copy_from(&z0);
            ode.step_seq_into(0, 1.0, &mut manual.ws.states[..=bo]);
            let mid = RangeProp::new(&ode, bo, n_mid);
            manual.forward_mid(&mid, &c, bo, iters, false, false);
            ode.step_seq_into(bo + n_mid, 1.0, &mut manual.ws.states[bo + n_mid..]);
            for (i, (a, b)) in ctx.ws.states.iter().zip(&manual.ws.states).enumerate() {
                assert_eq!(a.data(), b.data(), "state {} (iters {:?})", i, iters);
            }
        }
    }

    #[test]
    fn serial_backend_forces_serial_solves_through_the_context() {
        let mut rng = Rng::new(3);
        let ode = LinearOde::random_stable(&mut rng, 4, 16, 0.1);
        let z0 = Tensor::randn(&mut rng, &[4, 1], 1.0);
        let mut ctx = tiny_ctx(Box::new(Serial), 16, &[4, 1]);
        let (w, stats) = ctx.forward(&ode, &cfg(4, 2), &z0, Some(8), None, false);
        assert!(stats.serial, "Serial backend maps every budget to an exact solve");
        let traj = ode.serial_trajectory(&z0);
        for (a, b) in w.iter().zip(&traj) {
            assert!(a.allclose(b, 1e-6, 1e-6));
        }
    }

    #[test]
    fn workspace_grad_accumulators_fold_scale_and_zero() {
        let mut ws = StepWorkspace::new(2, &[2, 1], &[2, 1], &[3, 3], [2, 2, 2, 1]);
        ws.grads[0][1] = 4.0;
        let head = HeadGrads::out(vec![1.0, 2.0]);
        ws.add_head_grads(&head);
        ws.add_head_grads(&head);
        assert_eq!(ws.g_out, vec![2.0, 4.0]);
        assert_eq!(ws.g_cls, vec![0.0], "untouched groups stay zero");
        ws.scale_grads(0.5);
        assert_eq!(ws.g_out, vec![1.0, 2.0]);
        assert_eq!(ws.grads[0][1], 2.0);
        ws.zero_grads();
        assert_eq!(ws.g_out, vec![0.0, 0.0]);
        assert_eq!(ws.grads[0], vec![0.0; 3]);
    }

    #[test]
    fn dp_stash_fold_sums_independent_micro_batch_totals() {
        // replica-allreduce order: each micro-batch's totals are computed
        // in fresh zeroed buffers, then the totals are added
        let mut ws = StepWorkspace::new(1, &[2, 1], &[2, 1], &[2], [1, 1, 1, 1]);
        ws.zero_grads();
        ws.grads[0][0] = 0.1; // micro-batch 0 totals
        ws.g_emb[0] = 0.3;
        ws.stash_grads();
        assert_eq!(ws.grads[0][0], 0.0, "primary must be zeroed for the next micro-batch");
        assert_eq!(ws.g_emb[0], 0.0);
        ws.grads[0][0] = 0.2; // micro-batch 1 totals
        ws.g_emb[0] = 0.5;
        ws.fold_stashed_grads();
        assert_eq!(ws.grads[0][0], 0.1f32 + 0.2f32);
        assert_eq!(ws.g_emb[0], 0.3f32 + 0.5f32);
        // a second dp step reuses the scratch set from a clean slate
        ws.zero_grads();
        ws.grads[0][0] = 1.0;
        ws.stash_grads();
        ws.grads[0][0] = 2.0;
        ws.fold_stashed_grads();
        assert_eq!(ws.grads[0][0], 3.0);
    }

    #[test]
    fn flat_grad_fold_matches_stash_fold_bitwise() {
        // the fabric wire fold (flat payload, running sum on the left)
        // must reproduce the serial dp stash/fold association bitwise,
        // with values chosen so f32 addition order is observable
        let vals = [
            [1.0e8f32, 0.125, -7.5],
            [1.0f32, 3.0e-8, 0.25],
            [-1.0e8f32, 7.0e-8, 2.5],
        ];
        let fill = |ws: &mut StepWorkspace, v: [f32; 3]| {
            ws.grads[0][0] = v[0];
            ws.grads[1][1] = v[1];
            ws.g_emb[0] = v[2];
            ws.g_out[0] = v[0] * 0.5;
        };
        // serial dp loop: stash the running sum, compute fresh, fold
        let mut serial = StepWorkspace::new(2, &[2, 1], &[2, 1], &[1, 2], [1, 1, 1, 1]);
        serial.zero_grads();
        fill(&mut serial, vals[0]);
        for &v in &vals[1..] {
            serial.stash_grads();
            fill(&mut serial, v);
            serial.fold_stashed_grads();
        }
        // sharded dp: replica 0 folds flat payloads in ascending order
        let mut r0 = StepWorkspace::new(2, &[2, 1], &[2, 1], &[1, 2], [1, 1, 1, 1]);
        r0.zero_grads();
        fill(&mut r0, vals[0]);
        assert_eq!(r0.flat_grad_len(), 1 + 2 + 4);
        let mut flat = Vec::new();
        for &v in &vals[1..] {
            let mut rep = StepWorkspace::new(2, &[2, 1], &[2, 1], &[1, 2], [1, 1, 1, 1]);
            rep.zero_grads();
            fill(&mut rep, v);
            flat.clear();
            rep.write_grads_flat(&mut flat);
            assert_eq!(flat.len(), rep.flat_grad_len());
            r0.fold_grads_flat(&flat);
        }
        for (a, b) in serial.grads.iter().zip(r0.grads.iter()) {
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        for (a, b) in [
            (&serial.g_emb, &r0.g_emb),
            (&serial.g_pos, &r0.g_pos),
            (&serial.g_out, &r0.g_out),
            (&serial.g_cls, &r0.g_cls),
        ] {
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn panicked_threaded_sweep_is_recovered_without_a_core_rebuild() {
        // A Φ panic inside a pooled relaxation sweep unwinds out of the
        // in-place slab executors, leaving the cached core structurally
        // whole (torn point values only — `solve` reinitializes them).
        // The backend must replace its poisoned pool, the context must
        // keep the cached hierarchy (`is_intact` holds), and a retry on
        // the same session must solve cleanly and match a fresh solver
        // bitwise.
        use std::panic::{catch_unwind, AssertUnwindSafe};
        use std::sync::atomic::{AtomicBool, Ordering};

        use crate::ode::StepCounters;

        struct PanicOnce<'a> {
            inner: &'a LinearOde,
            armed: AtomicBool,
        }
        impl Propagator for PanicOnce<'_> {
            fn n_steps(&self) -> usize {
                self.inner.n_steps()
            }
            fn state_shape(&self) -> Vec<usize> {
                self.inner.state_shape()
            }
            fn fine_h(&self, layer: usize) -> f32 {
                self.inner.fine_h(layer)
            }
            fn step(&self, layer: usize, h_scale: f32, z: &Tensor) -> Tensor {
                if self.armed.swap(false, Ordering::SeqCst) {
                    panic!("injected Φ panic");
                }
                self.inner.step(layer, h_scale, z)
            }
            fn adjoint_step(&self, layer: usize, h_scale: f32, z: &Tensor, lam: &Tensor) -> Tensor {
                self.inner.adjoint_step(layer, h_scale, z, lam)
            }
            fn accumulate_grad(&self, layer: usize, z: &Tensor, lam: &Tensor, grad: &mut [f32]) {
                self.inner.accumulate_grad(layer, z, lam, grad)
            }
            fn theta_len(&self, layer: usize) -> usize {
                self.inner.theta_len(layer)
            }
            fn counters(&self) -> &StepCounters {
                self.inner.counters()
            }
        }

        let mut rng = Rng::new(9);
        let ode = LinearOde::random_stable(&mut rng, 4, 32, 0.05);
        let z0 = Tensor::randn(&mut rng, &[4, 1], 1.0);
        let mut ctx = tiny_ctx(Box::new(ThreadedMgrit::new(2)), 32, &[4, 1]);
        let prop = PanicOnce { inner: &ode, armed: AtomicBool::new(true) };
        let r = catch_unwind(AssertUnwindSafe(|| {
            ctx.forward(&prop, &cfg(4, 2), &z0, Some(3), None, false)
        }));
        assert!(r.is_err(), "the injected panic must re-raise at the call site");
        // retry on the same context: cached core kept (in-place sweeps
        // never gut it), poisoned pool replaced, bitwise-identical result
        // to a fresh solver
        let (w, _) = ctx.forward(&prop, &cfg(4, 2), &z0, Some(3), None, false);
        let (want, _) =
            MgritSolver::with_workers(&ode, cfg(4, 2), 2).forward(&z0, Some(3), None, false);
        for (a, b) in w.iter().zip(&want) {
            assert_eq!(a.data(), b.data(), "post-recovery solve must match a fresh solver");
        }
        assert_eq!(
            ctx.core_builds(),
            1,
            "panic recovery must reuse the cached hierarchy, not rebuild it"
        );
    }

    #[test]
    fn forward_mid_absorbs_panicked_sweeps_and_stays_bitwise() {
        // Policy 3 at the training entry point: the same class of injected
        // sweep panic that re-raises from the standalone `forward` (test
        // above) is absorbed by `forward_mid` — retried once on the
        // rebuilt pool, and on a second panic run on the in-thread V-cycle
        // schedule — with the solution bitwise identical to an unfaulted
        // context's in both cases.
        use std::panic::{catch_unwind, AssertUnwindSafe};
        use std::sync::atomic::{AtomicU32, Ordering};

        use crate::ode::StepCounters;

        struct PanicTimes<'a> {
            inner: &'a LinearOde,
            remaining: AtomicU32,
        }
        impl PanicTimes<'_> {
            fn take(&self) -> bool {
                self.remaining
                    .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| v.checked_sub(1))
                    .is_ok()
            }
        }
        impl Propagator for PanicTimes<'_> {
            fn n_steps(&self) -> usize {
                self.inner.n_steps()
            }
            fn state_shape(&self) -> Vec<usize> {
                self.inner.state_shape()
            }
            fn fine_h(&self, layer: usize) -> f32 {
                self.inner.fine_h(layer)
            }
            fn step(&self, layer: usize, h_scale: f32, z: &Tensor) -> Tensor {
                if self.take() {
                    panic!("injected Φ panic");
                }
                self.inner.step(layer, h_scale, z)
            }
            fn adjoint_step(&self, layer: usize, h_scale: f32, z: &Tensor, lam: &Tensor) -> Tensor {
                self.inner.adjoint_step(layer, h_scale, z, lam)
            }
            fn accumulate_grad(&self, layer: usize, z: &Tensor, lam: &Tensor, grad: &mut [f32]) {
                self.inner.accumulate_grad(layer, z, lam, grad)
            }
            fn theta_len(&self, layer: usize) -> usize {
                self.inner.theta_len(layer)
            }
            fn counters(&self) -> &StepCounters {
                self.inner.counters()
            }
        }

        let mut rng = Rng::new(11);
        let ode = LinearOde::random_stable(&mut rng, 4, 32, 0.05);
        let z0 = Tensor::randn(&mut rng, &[4, 1], 1.0);

        let solve = |panics: u32| -> Vec<Vec<f32>> {
            let mut ctx = tiny_ctx(Box::new(ThreadedMgrit::new(2)), 32, &[4, 1]);
            ctx.fwd.ws.states[0].copy_from(&z0);
            let prop = PanicTimes { inner: &ode, remaining: AtomicU32::new(panics) };
            ctx.forward_mid(&prop, &cfg(4, 2), 0, Some(3), false, false);
            ctx.fwd.ws.states[..=32].iter().map(|t| t.data().to_vec()).collect()
        };

        let clean = solve(0);
        assert_eq!(solve(1), clean, "one panic: pool-rebuild retry must be bitwise clean");
        assert_eq!(solve(2), clean, "two panics: in-thread fallback must be bitwise clean");

        // a persistent Φ poison still propagates after both fallbacks
        let r = catch_unwind(AssertUnwindSafe(|| solve(u32::MAX)));
        assert!(r.is_err(), "a fault in Φ itself must not be swallowed forever");
    }

    #[test]
    fn invalidate_forces_rebuild_with_identical_results() {
        let mut rng = Rng::new(4);
        let ode = LinearOde::random_stable(&mut rng, 4, 16, 0.1);
        let z0 = Tensor::randn(&mut rng, &[4, 1], 1.0);
        let mut ctx = tiny_ctx(Box::new(Mgrit), 16, &[4, 1]);
        let (w1, _) = ctx.forward(&ode, &cfg(4, 2), &z0, Some(3), None, false);
        ctx.invalidate();
        let (w2, _) = ctx.forward(&ode, &cfg(4, 2), &z0, Some(3), None, false);
        assert_eq!(ctx.core_builds(), 2, "invalidate → one rebuild");
        for (a, b) in w1.iter().zip(&w2) {
            assert_eq!(a.data(), b.data(), "rebuilt core must be bitwise identical");
        }
    }
}
