//! Embedding and loss heads (forward + backward), pure Rust.
//!
//! Mirrors `ref.py`'s embed / lm_loss / cls_loss / tag_loss exactly (same
//! masking and pooling semantics); validated against finite differences
//! here and against the XLA entry points in the runtime integration tests.

use crate::tensor::{mm_into, Tensor};

/// x[b,s,:] = w_emb[token] + w_pos[s].
pub fn embed_fwd(
    tokens: &[i32],
    w_emb: &[f32],
    w_pos: &[f32],
    batch: usize,
    seq: usize,
    d: usize,
) -> Tensor {
    let mut x = vec![0.0f32; batch * seq * d];
    embed_into(tokens, w_emb, w_pos, batch, seq, d, &mut x);
    Tensor::from_vec(x, &[batch, seq, d])
}

/// Buffer-reusing embed: writes x[b,s,:] = w_emb[token] + w_pos[s] into a
/// caller-owned `[B·S·D]` slice (fully overwritten) — the zero-allocation
/// entry point the session's step workspace routes through.
pub fn embed_into(
    tokens: &[i32],
    w_emb: &[f32],
    w_pos: &[f32],
    batch: usize,
    seq: usize,
    d: usize,
    x: &mut [f32],
) {
    assert_eq!(x.len(), batch * seq * d, "embed_into: destination size mismatch");
    for b in 0..batch {
        for s in 0..seq {
            let tok = tokens[b * seq + s] as usize;
            let out = &mut x[(b * seq + s) * d..(b * seq + s + 1) * d];
            let emb = &w_emb[tok * d..(tok + 1) * d];
            let pos = &w_pos[s * d..(s + 1) * d];
            for i in 0..d {
                out[i] = emb[i] + pos[i];
            }
        }
    }
}

/// Embed a batch straight into a propagator-state-shaped slice: the flat
/// `[B·S·D]` layout for encoder/decoder states, or both halves of the
/// stacked `[2·B·S·D]` EncDec state when a decoder input is present. The
/// single embedding entry point of the shared train/infer forward core —
/// `Session::micro_batch`, evaluation, and `InferSession` all route
/// through it, so the state layout cannot drift between them.
#[allow(clippy::too_many_arguments)]
pub fn embed_state_into(
    tokens: &[i32],
    tgt_in: Option<&[i32]>,
    w_emb: &[f32],
    w_pos: &[f32],
    batch: usize,
    seq: usize,
    d: usize,
    dst: &mut [f32],
) {
    match tgt_in {
        None => embed_into(tokens, w_emb, w_pos, batch, seq, d, dst),
        Some(t) => {
            let half = dst.len() / 2;
            let (x, y) = dst.split_at_mut(half);
            embed_into(tokens, w_emb, w_pos, batch, seq, d, x);
            embed_into(t, w_emb, w_pos, batch, seq, d, y);
        }
    }
}

/// Embed one token per batch row at a **per-row** board position into a
/// `[B·D]` slice (fully overwritten): row `b` gets
/// `w_emb[tokens[b]] + w_pos[positions[b]]` — bitwise the row
/// [`embed_into`] writes at `(b, positions[b])`, which keeps the
/// incremental decode step's `[B,1,D]` input identical to the full-board
/// embedding it replaces.
pub fn embed_rows_into(
    tokens: &[i32],
    positions: &[usize],
    w_emb: &[f32],
    w_pos: &[f32],
    d: usize,
    x: &mut [f32],
) {
    assert_eq!(tokens.len(), positions.len(), "embed_rows_into: one position per row");
    assert_eq!(x.len(), tokens.len() * d, "embed_rows_into: destination size mismatch");
    for (b, (&tok, &pos)) in tokens.iter().zip(positions).enumerate() {
        let tok = tok as usize;
        let out = &mut x[b * d..(b + 1) * d];
        let emb = &w_emb[tok * d..(tok + 1) * d];
        let pos = &w_pos[pos * d..(pos + 1) * d];
        for i in 0..d {
            out[i] = emb[i] + pos[i];
        }
    }
}

/// Scatter-add the embedding gradients: (g_emb, g_pos) += from λ_x
/// (a `[B·S·D]` slice, so stacked-state halves pass without a copy).
pub fn embed_bwd(
    tokens: &[i32],
    l: &[f32],
    batch: usize,
    seq: usize,
    d: usize,
    g_emb: &mut [f32],
    g_pos: &mut [f32],
) {
    for b in 0..batch {
        for s in 0..seq {
            let tok = tokens[b * seq + s] as usize;
            let src = &l[(b * seq + s) * d..(b * seq + s + 1) * d];
            for i in 0..d {
                g_emb[tok * d + i] += src[i];
                g_pos[s * d + i] += src[i];
            }
        }
    }
}

/// Masked token-level cross-entropy with logits x @ w_out.
/// Returns (mean loss over mask, #correct in mask, λ_x, grad w_out).
pub fn lm_loss(
    x: &Tensor,
    w_out: &[f32],
    targets: &[i32],
    mask: &[f32],
    vocab: usize,
) -> (f32, f32, Tensor, Vec<f32>) {
    let d = x.shape()[2];
    let mut lam = Tensor::zeros(x.shape());
    let mut gw = vec![0.0f32; d * vocab];
    let mut logits = Vec::new();
    let (loss, correct, _denom) =
        lm_loss_into(x, w_out, targets, Some(mask), vocab, &mut lam, &mut gw, &mut logits);
    (loss, correct, lam, gw)
}

/// Workspace-reusing form of [`lm_loss`]: the cotangent is written into
/// `lam` (x-shaped, fully overwritten), the head gradient is **added**
/// into `gw` (the caller's zeroed-per-step accumulator), and the per-row
/// logits live in the caller's reusable scratch — zero allocations once
/// the scratch capacity is warm. `mask = None` means all-ones (the
/// tagging objective), with the identical arithmetic (an explicit 1.0
/// mask summed row-by-row equals the row count exactly in f32 for any
/// realistic batch). Returns (mean loss, #correct, accuracy denominator)
/// — the denominator is handed back so callers don't re-sum the mask.
#[allow(clippy::too_many_arguments)]
pub fn lm_loss_into(
    x: &Tensor,
    w_out: &[f32],
    targets: &[i32],
    mask: Option<&[f32]>,
    vocab: usize,
    lam: &mut Tensor,
    gw: &mut [f32],
    logits: &mut Vec<f32>,
) -> (f32, f32, f32) {
    let d = x.shape()[2];
    let rows = x.shape()[0] * x.shape()[1];
    let xd = x.data();
    assert_eq!(lam.len(), x.len(), "lm_loss_into: λ buffer must be x-shaped");
    assert_eq!(gw.len(), d * vocab, "lm_loss_into: head-gradient size mismatch");
    let denom: f32 = match mask {
        Some(m) => m.iter().sum::<f32>().max(1.0),
        None => (rows as f32).max(1.0),
    };
    let mut loss = 0.0f64;
    let mut correct = 0.0f32;
    let lam = lam.data_mut();
    lam.fill(0.0);
    logits.clear();
    logits.resize(vocab, 0.0);

    for r in 0..rows {
        let xr = &xd[r * d..(r + 1) * d];
        // logits = xr @ w_out
        logits.iter_mut().for_each(|v| *v = 0.0);
        for (i, &xv) in xr.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w_out[i * vocab..(i + 1) * vocab];
            for (lg, &w) in logits.iter_mut().zip(wrow) {
                *lg += xv * w;
            }
        }
        let tgt = targets[r] as usize;
        // softmax + argmax
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        let mut argmax = 0;
        for (i, l) in logits.iter().enumerate() {
            if *l > logits[argmax] {
                argmax = i;
            }
            sum += (l - max).exp();
        }
        let logz = max + sum.ln();
        let m = mask.map_or(1.0, |mk| mk[r]);
        if m > 0.0 {
            loss += (m * (logz - logits[tgt])) as f64;
            if argmax == tgt {
                correct += m;
            }
            // dlogits = m/denom * (softmax - onehot)
            let scale = m / denom;
            for i in 0..vocab {
                let p = (logits[i] - logz).exp();
                let dl = scale * (p - if i == tgt { 1.0 } else { 0.0 });
                if dl == 0.0 {
                    continue;
                }
                // lam_x += dl * w_out[:, i]; gw[:, i] += dl * xr
                for j in 0..d {
                    lam[r * d + j] += dl * w_out[j * vocab + i];
                    gw[j * vocab + i] += dl * xr[j];
                }
            }
        }
    }
    ((loss / denom as f64) as f32, correct, denom)
}

/// Mean-pooled sequence classification CE.
/// Returns (mean loss, #correct, λ_x, grad w_cls).
pub fn cls_loss(
    x: &Tensor,
    w_cls: &[f32],
    labels: &[i32],
    n_classes: usize,
) -> (f32, f32, Tensor, Vec<f32>) {
    let d = x.shape()[2];
    let mut lam = Tensor::zeros(x.shape());
    let mut gw = vec![0.0f32; d * n_classes];
    let (mut logits, mut pooled) = (Vec::new(), Vec::new());
    let (loss, correct) =
        cls_loss_into(x, w_cls, labels, n_classes, &mut lam, &mut gw, &mut logits, &mut pooled);
    (loss, correct, lam, gw)
}

/// Workspace-reusing form of [`cls_loss`]: λ into `lam` (overwritten),
/// head gradient **added** into `gw`, logits/pooled in caller scratch.
/// Returns (mean loss, #correct).
#[allow(clippy::too_many_arguments)]
pub fn cls_loss_into(
    x: &Tensor,
    w_cls: &[f32],
    labels: &[i32],
    n_classes: usize,
    lam: &mut Tensor,
    gw: &mut [f32],
    logits: &mut Vec<f32>,
    pooled: &mut Vec<f32>,
) -> (f32, f32) {
    let (batch, seq, d) = (x.shape()[0], x.shape()[1], x.shape()[2]);
    let xd = x.data();
    assert_eq!(lam.len(), x.len(), "cls_loss_into: λ buffer must be x-shaped");
    assert_eq!(gw.len(), d * n_classes, "cls_loss_into: head-gradient size mismatch");
    let mut loss = 0.0f64;
    let mut correct = 0.0f32;
    let lam = lam.data_mut();
    lam.fill(0.0);
    logits.clear();
    logits.resize(n_classes, 0.0);
    pooled.clear();
    pooled.resize(d, 0.0);

    for b in 0..batch {
        // pooled = mean over seq
        pooled.iter_mut().for_each(|v| *v = 0.0);
        for s in 0..seq {
            let xr = &xd[(b * seq + s) * d..(b * seq + s + 1) * d];
            for i in 0..d {
                pooled[i] += xr[i];
            }
        }
        pooled.iter_mut().for_each(|v| *v /= seq as f32);
        logits.iter_mut().for_each(|v| *v = 0.0);
        for (i, &pv) in pooled.iter().enumerate() {
            let wrow = &w_cls[i * n_classes..(i + 1) * n_classes];
            for (lg, &w) in logits.iter_mut().zip(wrow) {
                *lg += pv * w;
            }
        }
        let tgt = labels[b] as usize;
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let sum: f32 = logits.iter().map(|l| (l - max).exp()).sum();
        let logz = max + sum.ln();
        loss += (logz - logits[tgt]) as f64;
        let argmax =
            (0..n_classes).max_by(|&a, &c| logits[a].partial_cmp(&logits[c]).unwrap()).unwrap();
        if argmax == tgt {
            correct += 1.0;
        }
        let scale = 1.0 / batch as f32;
        for c in 0..n_classes {
            let p = (logits[c] - logz).exp();
            let dl = scale * (p - if c == tgt { 1.0 } else { 0.0 });
            for j in 0..d {
                gw[j * n_classes + c] += dl * pooled[j];
                // dpooled[j] = dl * w[j,c]; spread over seq positions
                let dp = dl * w_cls[j * n_classes + c] / seq as f32;
                for s in 0..seq {
                    lam[(b * seq + s) * d + j] += dp;
                }
            }
        }
    }
    ((loss / batch as f64) as f32, correct)
}

/// Per-token tagging CE (labels i32[B,S]): thin wrapper over `lm_loss`
/// semantics with w_cls as the output matrix and an all-ones mask, except
/// the loss is averaged over all tokens (matches ref.tag_loss).
pub fn tag_loss(
    x: &Tensor,
    w_cls: &[f32],
    labels: &[i32],
    n_classes: usize,
) -> (f32, f32, Tensor, Vec<f32>) {
    let mask = vec![1.0f32; x.shape()[0] * x.shape()[1]];
    lm_loss(x, w_cls, labels, &mask, n_classes)
}

/// Workspace-reusing form of [`tag_loss`]: [`lm_loss_into`] with the
/// implicit all-ones mask (no mask vector is materialized).
pub fn tag_loss_into(
    x: &Tensor,
    w_cls: &[f32],
    labels: &[i32],
    n_classes: usize,
    lam: &mut Tensor,
    gw: &mut [f32],
    logits: &mut Vec<f32>,
) -> (f32, f32, f32) {
    lm_loss_into(x, w_cls, labels, None, n_classes, lam, gw, logits)
}

// ---------------------------------------------------------------------------
// Logits-only inference entry points. The loss heads above compute loss +
// cotangent + head gradients in one pass; serving needs none of that — these
// kernels produce raw logits into caller-owned scratch (fully overwritten,
// zero allocations once the buffers are sized), and the `infer` module does
// selection (argmax / top-k sampling) on top.
// ---------------------------------------------------------------------------

/// LM-head logits at one sequence position for every batch row:
/// `out[b·V .. (b+1)·V] = x[b, pos, :] @ w_out`. The autoregressive-decode
/// kernel — each decode step needs exactly one position's logits, so the
/// O(B·S·V) full-grid projection is skipped. Projection runs on the
/// blocked [`mm_into`] kernel (one row per batch element — the rows are
/// not contiguous in x, so this is B single-row matmuls).
pub fn lm_infer_into(x: &Tensor, w_out: &[f32], pos: usize, vocab: usize, out: &mut [f32]) {
    let (batch, seq, d) = (x.shape()[0], x.shape()[1], x.shape()[2]);
    assert!(pos < seq, "lm_infer_into: position {} outside seq {}", pos, seq);
    assert_eq!(out.len(), batch * vocab, "lm_infer_into: logits buffer size mismatch");
    let xd = x.data();
    for b in 0..batch {
        let xr = &xd[(b * seq + pos) * d..(b * seq + pos + 1) * d];
        mm_into(xr, w_out, 1, d, vocab, &mut out[b * vocab..(b + 1) * vocab], false);
    }
}

/// LM-head logits at a **per-row** sequence position:
/// `out[b·V .. (b+1)·V] = x[b, positions[b], :] @ w_out`. The
/// continuous-batching decode kernel — concurrent sequences in one batch
/// sit at different cursors, so each row projects its own position. Row
/// `b`'s arithmetic is the identical single-row [`mm_into`] call that
/// [`lm_infer_into`] makes at `pos = positions[b]`, which is what makes
/// scheduler outputs bitwise comparable to solo decode runs.
pub fn lm_infer_rows_into(
    x: &Tensor,
    w_out: &[f32],
    positions: &[usize],
    vocab: usize,
    out: &mut [f32],
) {
    let (batch, seq, d) = (x.shape()[0], x.shape()[1], x.shape()[2]);
    assert_eq!(positions.len(), batch, "lm_infer_rows_into: one position per batch row");
    assert_eq!(out.len(), batch * vocab, "lm_infer_rows_into: logits buffer size mismatch");
    let xd = x.data();
    for b in 0..batch {
        let pos = positions[b];
        assert!(pos < seq, "lm_infer_rows_into: position {} outside seq {}", pos, seq);
        let xr = &xd[(b * seq + pos) * d..(b * seq + pos + 1) * d];
        mm_into(xr, w_out, 1, d, vocab, &mut out[b * vocab..(b + 1) * vocab], false);
    }
}

/// Per-token logits for every row: `out[r·C .. (r+1)·C] = x[r, :] @ w`
/// over all `B·S` rows — batched tagging prediction (w = w_cls) and
/// masked-LM / teacher-forced prediction (w = w_out, C = vocab). One
/// blocked [`mm_into`] over the whole grid.
pub fn tag_infer_into(x: &Tensor, w: &[f32], n_classes: usize, out: &mut [f32]) {
    let d = x.shape()[2];
    let rows = x.len() / d;
    assert_eq!(w.len(), d * n_classes, "tag_infer_into: head size mismatch");
    assert_eq!(out.len(), rows * n_classes, "tag_infer_into: logits buffer size mismatch");
    mm_into(x.data(), w, rows, d, n_classes, out, false);
}

/// Batched classification logits: mean-pool each sequence then project —
/// `out[b·C .. (b+1)·C] = mean_s(x[b, s, :]) @ w_cls`. Identical pooling
/// arithmetic to [`cls_loss_into`], so predictions match training
/// accuracy accounting bitwise; the projection is one blocked
/// [`mm_into`] over the pooled `[B, D]` grid. `pooled` is reusable
/// `[B·D]` scratch.
pub fn cls_infer_into(
    x: &Tensor,
    w_cls: &[f32],
    n_classes: usize,
    pooled: &mut Vec<f32>,
    out: &mut [f32],
) {
    let (batch, seq, d) = (x.shape()[0], x.shape()[1], x.shape()[2]);
    assert_eq!(w_cls.len(), d * n_classes, "cls_infer_into: head size mismatch");
    assert_eq!(out.len(), batch * n_classes, "cls_infer_into: logits buffer size mismatch");
    let xd = x.data();
    pooled.clear();
    pooled.resize(batch * d, 0.0);
    for b in 0..batch {
        let row = &mut pooled[b * d..(b + 1) * d];
        for s in 0..seq {
            let xr = &xd[(b * seq + s) * d..(b * seq + s + 1) * d];
            for i in 0..d {
                row[i] += xr[i];
            }
        }
        row.iter_mut().for_each(|v| *v /= seq as f32);
    }
    mm_into(pooled, w_cls, batch, d, n_classes, out, false);
}

/// Argmax predictions of the LM head (greedy, teacher-forced) — feeds BLEU.
pub fn argmax_tokens(x: &Tensor, w_out: &[f32], vocab: usize) -> Vec<i32> {
    let d = x.shape()[2];
    let rows = x.len() / d;
    let xd = x.data();
    let mut out = Vec::with_capacity(rows);
    let mut logits = vec![0.0f32; vocab];
    for r in 0..rows {
        let xr = &xd[r * d..(r + 1) * d];
        logits.iter_mut().for_each(|v| *v = 0.0);
        for (i, &xv) in xr.iter().enumerate() {
            let wrow = &w_out[i * vocab..(i + 1) * vocab];
            for (lg, &w) in logits.iter_mut().zip(wrow) {
                *lg += xv * w;
            }
        }
        let argmax =
            (0..vocab).max_by(|&a, &c| logits[a].partial_cmp(&logits[c]).unwrap()).unwrap();
        out.push(argmax as i32);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn embed_places_rows() {
        let (b, s, d, v) = (2, 3, 4, 8);
        let mut rng = Rng::new(0);
        let we = rng.normal_vec(v * d, 1.0);
        let wp = rng.normal_vec(s * d, 1.0);
        let toks = vec![1, 2, 3, 4, 5, 6];
        let x = embed_fwd(&toks, &we, &wp, b, s, d);
        for i in 0..d {
            assert!((x.data()[i] - (we[d + i] + wp[i])).abs() < 1e-6);
        }
    }

    #[test]
    fn embed_bwd_scatter_adds() {
        let (b, s, d, v) = (1, 2, 3, 4);
        let toks = vec![2, 2]; // same token twice -> grads add
        let lam = vec![1.0f32; b * s * d];
        let mut ge = vec![0.0; v * d];
        let mut gp = vec![0.0; s * d];
        embed_bwd(&toks, &lam, b, s, d, &mut ge, &mut gp);
        assert_eq!(ge[2 * d], 2.0); // token 2 hit twice
        assert_eq!(gp[0], 1.0);
    }

    #[test]
    fn embed_rows_matches_full_board_rows_bitwise() {
        let (b, s, d, v) = (3, 4, 4, 8);
        let mut rng = Rng::new(17);
        let we = rng.normal_vec(v * d, 1.0);
        let wp = rng.normal_vec(s * d, 1.0);
        let toks: Vec<i32> = (0..(b * s) as i32).map(|t| t % v as i32).collect();
        let mut board = vec![0.0f32; b * s * d];
        embed_into(&toks, &we, &wp, b, s, d, &mut board);
        let positions = [2usize, 0, 3];
        let row_toks: Vec<i32> = positions.iter().enumerate()
            .map(|(r, &p)| toks[r * s + p]).collect();
        let mut rows = vec![9.0f32; b * d];
        embed_rows_into(&row_toks, &positions, &we, &wp, d, &mut rows);
        for (r, &p) in positions.iter().enumerate() {
            assert_eq!(&rows[r * d..(r + 1) * d],
                       &board[(r * s + p) * d..(r * s + p + 1) * d],
                       "row {} at position {}", r, p);
        }
    }

    #[test]
    fn lm_infer_rows_matches_single_position_kernel_bitwise() {
        let (b, s, d, v) = (3, 4, 8, 6);
        let mut rng = Rng::new(9);
        let x = Tensor::randn(&mut rng, &[b, s, d], 0.7);
        let w = rng.normal_vec(d * v, 0.3);
        let positions = [2usize, 0, 3];
        let mut per_row = vec![0.0f32; b * v];
        lm_infer_rows_into(&x, &w, &positions, v, &mut per_row);
        // every row must equal the single-position kernel at that row's
        // position, bit for bit (the scheduler-parity contract)
        let mut single = vec![0.0f32; b * v];
        for (r, &pos) in positions.iter().enumerate() {
            lm_infer_into(&x, &w, pos, v, &mut single);
            assert_eq!(
                per_row[r * v..(r + 1) * v]
                    .iter()
                    .map(|f| f.to_bits())
                    .collect::<Vec<_>>(),
                single[r * v..(r + 1) * v]
                    .iter()
                    .map(|f| f.to_bits())
                    .collect::<Vec<_>>(),
                "row {} at position {}",
                r,
                pos
            );
        }
    }

    #[test]
    fn lm_loss_matches_fd() {
        let (b, s, d, v) = (1, 3, 4, 5);
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&mut rng, &[b, s, d], 0.5);
        let w = rng.normal_vec(d * v, 0.3);
        let tgt = vec![1, 4, 2];
        let mask = vec![1.0, 0.0, 1.0];
        let (loss, _correct, lam, gw) = lm_loss(&x, &w, &tgt, &mask, v);
        assert!(loss > 0.0);

        let eps = 1e-3;
        let f = |xv: &Tensor, wv: &[f32]| lm_loss(xv, wv, &tgt, &mask, v).0;
        for i in 0..x.len() {
            let mut xp = x.clone();
            xp.data_mut()[i] += eps;
            let mut xm = x.clone();
            xm.data_mut()[i] -= eps;
            let fd = (f(&xp, &w) - f(&xm, &w)) / (2.0 * eps);
            assert!((lam.data()[i] - fd).abs() < 2e-3, "lam[{}]={} fd={}", i, lam.data()[i], fd);
        }
        for i in (0..w.len()).step_by(3) {
            let mut wp = w.clone();
            wp[i] += eps;
            let mut wm = w.clone();
            wm[i] -= eps;
            let fd = (f(&x, &wp) - f(&x, &wm)) / (2.0 * eps);
            assert!((gw[i] - fd).abs() < 2e-3, "gw[{}]={} fd={}", i, gw[i], fd);
        }
    }

    #[test]
    fn masked_positions_do_not_contribute() {
        let (b, s, d, v) = (1, 2, 3, 4);
        let mut rng = Rng::new(2);
        let x = Tensor::randn(&mut rng, &[b, s, d], 0.5);
        let w = rng.normal_vec(d * v, 0.3);
        let (_l, _c, lam, _g) = lm_loss(&x, &w, &[0, 1], &[1.0, 0.0], v);
        // λ at the masked-out position is exactly zero
        assert!(lam.data()[d..2 * d].iter().all(|&z| z == 0.0));
    }

    #[test]
    fn cls_loss_matches_fd() {
        let (b, s, d, c) = (2, 3, 4, 3);
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&mut rng, &[b, s, d], 0.5);
        let w = rng.normal_vec(d * c, 0.3);
        let labels = vec![1, 2];
        let (loss, _cor, lam, gw) = cls_loss(&x, &w, &labels, c);
        assert!(loss > 0.0);
        let eps = 1e-3;
        let f = |xv: &Tensor, wv: &[f32]| cls_loss(xv, wv, &labels, c).0;
        for i in 0..x.len() {
            let mut xp = x.clone();
            xp.data_mut()[i] += eps;
            let mut xm = x.clone();
            xm.data_mut()[i] -= eps;
            let fd = (f(&xp, &w) - f(&xm, &w)) / (2.0 * eps);
            assert!((lam.data()[i] - fd).abs() < 2e-3, "lam[{}]", i);
        }
        for i in 0..w.len() {
            let mut wp = w.clone();
            wp[i] += eps;
            let mut wm = w.clone();
            wm[i] -= eps;
            let fd = (f(&x, &wp) - f(&x, &wm)) / (2.0 * eps);
            assert!((gw[i] - fd).abs() < 2e-3, "gw[{}]", i);
        }
    }

    #[test]
    fn into_heads_match_allocating_heads_bitwise() {
        // the workspace-reusing kernels are the hot path; the allocating
        // wrappers delegate to them, and direct calls with reused (dirty)
        // scratch must produce identical bits
        let (b, s, d, v) = (2, 3, 4, 5);
        let mut rng = Rng::new(9);
        let x = Tensor::randn(&mut rng, &[b, s, d], 0.5);
        let w = rng.normal_vec(d * v, 0.3);
        let tgt = vec![1, 4, 2, 0, 3, 1];
        let mask = vec![1.0, 0.0, 1.0, 1.0, 1.0, 0.0];
        let (l0, c0, lam0, gw0) = lm_loss(&x, &w, &tgt, &mask, v);
        let mut lam = Tensor::randn(&mut rng, &[b, s, d], 1.0); // dirty buffers
        let mut gw = vec![0.0f32; d * v];
        let mut logits = vec![7.0f32; 2];
        let (l1, c1, d1) =
            lm_loss_into(&x, &w, &tgt, Some(&mask), v, &mut lam, &mut gw, &mut logits);
        assert_eq!((l0, c0), (l1, c1));
        assert_eq!(d1, mask.iter().sum::<f32>());
        assert_eq!(lam0.data(), lam.data());
        assert_eq!(gw0, gw);
        // gw accumulates: a second call doubles it exactly
        lm_loss_into(&x, &w, &tgt, Some(&mask), v, &mut lam, &mut gw, &mut logits);
        for (a, b) in gw.iter().zip(&gw0) {
            assert_eq!(*a, b + b);
        }
        // tagging: implicit all-ones mask == materialized all-ones mask
        let labels = vec![0, 1, 2, 3, 0, 1];
        let (l0, c0, lam0, gw0) = tag_loss(&x, &w, &labels, v);
        let mut gw = vec![0.0f32; d * v];
        let (l1, c1, d1) = tag_loss_into(&x, &w, &labels, v, &mut lam, &mut gw, &mut logits);
        assert_eq!((l0, c0), (l1, c1));
        assert_eq!(d1, (b * s) as f32);
        assert_eq!(lam0.data(), lam.data());
        assert_eq!(gw0, gw);
        // classification
        let labels = vec![1, 2];
        let (l0, c0, lam0, gw0) = cls_loss(&x, &w[..d * 3], &labels, 3);
        let mut gw = vec![0.0f32; d * 3];
        let mut pooled = Vec::new();
        let (l1, c1) =
            cls_loss_into(&x, &w[..d * 3], &labels, 3, &mut lam, &mut gw, &mut logits, &mut pooled);
        assert_eq!((l0, c0), (l1, c1));
        assert_eq!(lam0.data(), lam.data());
        assert_eq!(gw0, gw);
    }

    #[test]
    fn embed_state_into_matches_flat_and_stacked_layouts() {
        let (b, s, d, v) = (2, 3, 4, 8);
        let mut rng = Rng::new(21);
        let we = rng.normal_vec(v * d, 1.0);
        let wp = rng.normal_vec(s * d, 1.0);
        let toks = vec![1, 2, 3, 4, 5, 6];
        let tgt = vec![6, 5, 4, 3, 2, 1];
        // flat == embed_fwd
        let mut flat = vec![9.0f32; b * s * d];
        embed_state_into(&toks, None, &we, &wp, b, s, d, &mut flat);
        assert_eq!(flat, embed_fwd(&toks, &we, &wp, b, s, d).into_vec());
        // stacked = [embed(src), embed(tgt)]
        let mut stacked = vec![9.0f32; 2 * b * s * d];
        embed_state_into(&toks, Some(&tgt), &we, &wp, b, s, d, &mut stacked);
        assert_eq!(&stacked[..b * s * d], &flat[..]);
        assert_eq!(&stacked[b * s * d..], &embed_fwd(&tgt, &we, &wp, b, s, d).into_vec()[..]);
    }

    #[test]
    fn infer_kernels_agree_with_the_loss_heads() {
        let (b, s, d, v) = (2, 3, 4, 5);
        let mut rng = Rng::new(33);
        let x = Tensor::randn(&mut rng, &[b, s, d], 0.7);
        let w = rng.normal_vec(d * v, 0.4);
        // per-row logits (tag_infer_into) argmax == argmax_tokens
        let mut lg = vec![7.0f32; b * s * v];
        tag_infer_into(&x, &w, v, &mut lg);
        let preds: Vec<i32> = (0..b * s)
            .map(|r| {
                (0..v)
                    .max_by(|&i, &j| lg[r * v + i].partial_cmp(&lg[r * v + j]).unwrap())
                    .unwrap() as i32
            })
            .collect();
        assert_eq!(preds, argmax_tokens(&x, &w, v));
        // per-position logits (lm_infer_into) agree row-by-row with the
        // full per-token grid
        let mut pos_lg = vec![0.0f32; b * v];
        for pos in 0..s {
            lm_infer_into(&x, &w, pos, v, &mut pos_lg);
            for bi in 0..b {
                assert_eq!(
                    &pos_lg[bi * v..(bi + 1) * v],
                    &lg[(bi * s + pos) * v..(bi * s + pos + 1) * v],
                    "pos {} row {}",
                    pos,
                    bi
                );
            }
        }
        // classification logits argmax == cls_loss's accuracy accounting
        let c = 3;
        let wc = &w[..d * c];
        let labels = vec![1, 2];
        let (_, correct, _, _) = cls_loss(&x, wc, &labels, c);
        let mut pooled = Vec::new();
        let mut clg = vec![0.0f32; b * c];
        cls_infer_into(&x, wc, c, &mut pooled, &mut clg);
        let agree: f32 = (0..b)
            .map(|bi| {
                let am = (0..c)
                    .max_by(|&i, &j| clg[bi * c + i].partial_cmp(&clg[bi * c + j]).unwrap())
                    .unwrap();
                (am as i32 == labels[bi]) as u8 as f32
            })
            .sum();
        assert_eq!(agree, correct);
    }

    #[test]
    fn perfect_logits_give_full_accuracy() {
        // w_out selects the right class with a huge margin
        let (b, s, d, v) = (1, 4, 4, 4);
        let mut x = Tensor::zeros(&[b, s, d]);
        for s_i in 0..s {
            x.data_mut()[(s_i) * d + s_i % d] = 10.0;
        }
        let mut w = vec![0.0f32; d * v];
        for i in 0..d {
            w[i * v + i] = 1.0;
        }
        let tgt: Vec<i32> = (0..s as i32).map(|t| t % d as i32).collect();
        let mask = vec![1.0; s];
        let (_loss, correct, _lam, _gw) = lm_loss(&x, &w, &tgt, &mask, v);
        assert_eq!(correct, s as f32);
        assert_eq!(argmax_tokens(&x, &w, v), tgt);
    }
}
