//! `Objective`: the open training-workload interface of the Session API.
//!
//! An objective owns its data source and defines how a batch is sampled,
//! how the loss head maps the final activation to (loss, cotangent, head
//! gradients), and how validation batches fold into a metric. The paper's
//! five tasks ship as four implementations ([`LmObjective`] covers both
//! causal LM and MLM); new workloads plug in by implementing the trait —
//! the coordinator never enumerates tasks.

use crate::config::ModelConfig;
use crate::data::charlm::CharCorpus;
use crate::data::images::ImageTask;
use crate::data::morpho::MorphoTask;
use crate::data::translate::TranslateTask;
use crate::model::ParamStore;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

use super::heads;

/// One sampled training/validation batch in the coordinator's unified
/// layout (unused fields stay empty/None).
#[derive(Debug, Clone)]
pub struct TrainBatch {
    /// Input token ids [B, S] (encoder side for EncDec).
    pub tokens: Vec<i32>,
    /// Token-level targets [B, S] — empty for classification.
    pub targets: Vec<i32>,
    /// Loss mask [B, S] — empty for classification.
    pub mask: Vec<f32>,
    /// Sequence-level labels [B] — classification only.
    pub labels: Vec<i32>,
    /// Decoder input (shifted right) [B, S] — EncDec only; its presence
    /// selects the stacked state Z = [X, Y].
    pub tgt_in: Option<Vec<i32>>,
}

/// What the loss head produced for one micro-batch.
pub struct LossOut {
    pub loss: f32,
    /// Correct predictions (numerator of the batch accuracy).
    pub correct: f32,
    /// Accuracy denominator (masked tokens / tokens / sequences).
    pub denom: f32,
    /// Loss cotangent w.r.t. the head-side final activation [B, S, D].
    pub lam_head: Tensor,
    /// Gradients of the head parameter groups this objective touches.
    pub head: HeadGrads,
}

/// Accumulator for validation metrics across eval batches.
#[derive(Debug, Clone, Default)]
pub struct EvalAccum {
    pub correct: f64,
    pub total: f64,
    /// (prediction, reference) token sequences for corpus-level metrics
    /// (BLEU); empty for accuracy-style objectives.
    pub pairs: Vec<(Vec<i32>, Vec<i32>)>,
}

/// A training workload: data source + loss head + validation metric.
///
/// Implementations must be deterministic in the `Rng` they are handed so
/// backend-parity holds bitwise across execution strategies. `Send + Sync`
/// keeps whole `Session`s movable across threads, matching the
/// [`crate::ode::Propagator`] / [`super::backend::Backend`] contracts.
pub trait Objective: Send + Sync {
    /// Short name for logs (`"mlm"`, `"tag"`, …).
    fn name(&self) -> &'static str;

    /// Sample one batch (training and validation share this; the caller
    /// controls the stream via the `Rng`).
    fn sample(&self, rng: &mut Rng, m: &ModelConfig) -> TrainBatch;

    /// Loss + cotangent + head-parameter gradients at the final activation
    /// `x_final` [B, S, D] (decoder half for EncDec).
    fn loss(
        &self,
        x_final: &Tensor,
        params: &ParamStore,
        batch: &TrainBatch,
        m: &ModelConfig,
    ) -> LossOut;

    /// Fold one validation batch into the accumulator.
    fn eval_batch(
        &self,
        x_final: &Tensor,
        params: &ParamStore,
        batch: &TrainBatch,
        m: &ModelConfig,
        acc: &mut EvalAccum,
    );

    /// Final metric from the accumulated validation state (accuracy, BLEU).
    fn metric(&self, acc: &EvalAccum) -> f64;
}

/// Character language modeling: causal (GPT) or masked (BERT).
pub struct LmObjective {
    corpus: CharCorpus,
    /// `Some(mask_id)` → MLM with that mask token; `None` → causal LM.
    mask_id: Option<i32>,
    mask_rate: f32,
}

impl LmObjective {
    pub fn causal(corpus: CharCorpus) -> LmObjective {
        LmObjective { corpus, mask_id: None, mask_rate: 0.0 }
    }

    pub fn masked(corpus: CharCorpus, mask_id: i32, mask_rate: f32) -> LmObjective {
        LmObjective { corpus, mask_id: Some(mask_id), mask_rate }
    }
}

impl Objective for LmObjective {
    fn name(&self) -> &'static str {
        if self.mask_id.is_some() {
            "mlm"
        } else {
            "lm"
        }
    }

    fn sample(&self, rng: &mut Rng, m: &ModelConfig) -> TrainBatch {
        let b = match self.mask_id {
            Some(id) => self.corpus.mlm_batch(rng, m.batch, m.seq, self.mask_rate, id),
            None => self.corpus.lm_batch(rng, m.batch, m.seq),
        };
        TrainBatch {
            tokens: b.tokens,
            targets: b.targets,
            mask: b.mask,
            labels: vec![],
            tgt_in: None,
        }
    }

    fn loss(
        &self,
        x_final: &Tensor,
        params: &ParamStore,
        batch: &TrainBatch,
        m: &ModelConfig,
    ) -> LossOut {
        let (loss, correct, lam_head, gw) =
            heads::lm_loss(x_final, &params.w_out, &batch.targets, &batch.mask, m.vocab);
        let denom = batch.mask.iter().sum::<f32>().max(1.0);
        LossOut { loss, correct, denom, lam_head, head: HeadGrads::out(gw) }
    }

    fn eval_batch(
        &self,
        x_final: &Tensor,
        params: &ParamStore,
        batch: &TrainBatch,
        m: &ModelConfig,
        acc: &mut EvalAccum,
    ) {
        let (_, c, _, _) =
            heads::lm_loss(x_final, &params.w_out, &batch.targets, &batch.mask, m.vocab);
        acc.correct += c as f64;
        acc.total += batch.mask.iter().sum::<f32>() as f64;
    }

    fn metric(&self, acc: &EvalAccum) -> f64 {
        acc.correct / acc.total.max(1.0)
    }
}

/// Per-token morphological tagging (the paper's MC task).
pub struct TagObjective {
    task: MorphoTask,
}

impl TagObjective {
    pub fn new(task: MorphoTask) -> TagObjective {
        TagObjective { task }
    }
}

impl Objective for TagObjective {
    fn name(&self) -> &'static str {
        "tag"
    }

    fn sample(&self, rng: &mut Rng, m: &ModelConfig) -> TrainBatch {
        let b = self.task.batch(rng, m.batch, m.seq);
        TrainBatch {
            tokens: b.tokens,
            targets: b.targets,
            mask: b.mask,
            labels: vec![],
            tgt_in: None,
        }
    }

    fn loss(
        &self,
        x_final: &Tensor,
        params: &ParamStore,
        batch: &TrainBatch,
        m: &ModelConfig,
    ) -> LossOut {
        let (loss, correct, lam_head, gw) =
            heads::tag_loss(x_final, &params.w_cls, &batch.targets, m.n_classes);
        LossOut {
            loss,
            correct,
            denom: (m.batch * m.seq) as f32,
            lam_head,
            head: HeadGrads::cls(gw),
        }
    }

    fn eval_batch(
        &self,
        x_final: &Tensor,
        params: &ParamStore,
        batch: &TrainBatch,
        m: &ModelConfig,
        acc: &mut EvalAccum,
    ) {
        let (_, c, _, _) = heads::tag_loss(x_final, &params.w_cls, &batch.targets, m.n_classes);
        acc.correct += c as f64;
        acc.total += (m.batch * m.seq) as f64;
    }

    fn metric(&self, acc: &EvalAccum) -> f64 {
        acc.correct / acc.total.max(1.0)
    }
}

/// Sequence classification over patch tokens (the paper's ViT task).
pub struct ClsObjective {
    task: ImageTask,
}

impl ClsObjective {
    pub fn new(task: ImageTask) -> ClsObjective {
        ClsObjective { task }
    }
}

impl Objective for ClsObjective {
    fn name(&self) -> &'static str {
        "cls"
    }

    fn sample(&self, rng: &mut Rng, m: &ModelConfig) -> TrainBatch {
        let b = self.task.batch(rng, m.batch);
        TrainBatch {
            tokens: b.tokens,
            targets: vec![],
            mask: vec![],
            labels: b.labels,
            tgt_in: None,
        }
    }

    fn loss(
        &self,
        x_final: &Tensor,
        params: &ParamStore,
        batch: &TrainBatch,
        m: &ModelConfig,
    ) -> LossOut {
        let (loss, correct, lam_head, gw) =
            heads::cls_loss(x_final, &params.w_cls, &batch.labels, m.n_classes);
        LossOut { loss, correct, denom: m.batch as f32, lam_head, head: HeadGrads::cls(gw) }
    }

    fn eval_batch(
        &self,
        x_final: &Tensor,
        params: &ParamStore,
        batch: &TrainBatch,
        m: &ModelConfig,
        acc: &mut EvalAccum,
    ) {
        let (_, c, _, _) = heads::cls_loss(x_final, &params.w_cls, &batch.labels, m.n_classes);
        acc.correct += c as f64;
        acc.total += m.batch as f64;
    }

    fn metric(&self, acc: &EvalAccum) -> f64 {
        acc.correct / acc.total.max(1.0)
    }
}

/// Encoder-decoder translation over the stacked state Z = [X, Y] (the
/// paper's MT task); validation metric is BLEU-4.
pub struct TranslateObjective {
    task: TranslateTask,
}

impl TranslateObjective {
    pub fn new(task: TranslateTask) -> TranslateObjective {
        TranslateObjective { task }
    }
}

impl Objective for TranslateObjective {
    fn name(&self) -> &'static str {
        "translate"
    }

    fn sample(&self, rng: &mut Rng, m: &ModelConfig) -> TrainBatch {
        let b = self.task.batch(rng, m.batch, m.seq);
        TrainBatch {
            tokens: b.src,
            targets: b.tgt_out,
            mask: b.mask,
            labels: vec![],
            tgt_in: Some(b.tgt_in),
        }
    }

    fn loss(
        &self,
        x_final: &Tensor,
        params: &ParamStore,
        batch: &TrainBatch,
        m: &ModelConfig,
    ) -> LossOut {
        let (loss, correct, lam_head, gw) =
            heads::lm_loss(x_final, &params.w_out, &batch.targets, &batch.mask, m.vocab);
        let denom = batch.mask.iter().sum::<f32>().max(1.0);
        LossOut { loss, correct, denom, lam_head, head: HeadGrads::out(gw) }
    }

    fn eval_batch(
        &self,
        x_final: &Tensor,
        params: &ParamStore,
        batch: &TrainBatch,
        m: &ModelConfig,
        acc: &mut EvalAccum,
    ) {
        let preds = heads::argmax_tokens(x_final, &params.w_out, m.vocab);
        for b in 0..m.batch {
            acc.pairs.push((
                preds[b * m.seq..(b + 1) * m.seq].to_vec(),
                batch.targets[b * m.seq..(b + 1) * m.seq].to_vec(),
            ));
        }
    }

    fn metric(&self, acc: &EvalAccum) -> f64 {
        crate::analysis::bleu4(&acc.pairs)
    }
}

/// Gradients of the non-layer parameter groups (embeddings + heads) an
/// objective's loss head produced. Objectives fill only the groups they
/// touch (the rest stay empty); the training step folds them into the
/// full-size accumulators of
/// [`crate::coordinator::context::StepWorkspace`].
pub struct HeadGrads {
    pub emb: Vec<f32>,
    pub pos: Vec<f32>,
    pub out: Vec<f32>,
    pub cls: Vec<f32>,
}

impl HeadGrads {
    /// LM-head gradient only.
    pub fn out(gw: Vec<f32>) -> HeadGrads {
        HeadGrads { emb: vec![], pos: vec![], out: gw, cls: vec![] }
    }

    /// Classifier-head gradient only.
    pub fn cls(gw: Vec<f32>) -> HeadGrads {
        HeadGrads { emb: vec![], pos: vec![], out: vec![], cls: gw }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn objectives_sample_consistent_shapes() {
        let m = presets::mc_tiny().model;
        let mut rng = Rng::new(0);
        let obj = TagObjective::new(MorphoTask::new(m.vocab, m.n_classes, 1));
        let b = obj.sample(&mut rng, &m);
        assert_eq!(b.tokens.len(), m.batch * m.seq);
        assert_eq!(b.targets.len(), m.batch * m.seq);
        assert!(b.tgt_in.is_none());
        assert_eq!(obj.name(), "tag");
    }

    #[test]
    fn translate_samples_decoder_input() {
        let m = presets::mt_small().model;
        let mut rng = Rng::new(0);
        let obj = TranslateObjective::new(TranslateTask::new(m.vocab, 1, false));
        let b = obj.sample(&mut rng, &m);
        assert_eq!(b.tgt_in.as_ref().unwrap().len(), m.batch * m.seq);
    }

    #[test]
    fn head_grads_constructors_touch_one_group() {
        let a = HeadGrads::out(vec![1.0, 2.0]);
        assert_eq!(a.out, vec![1.0, 2.0]);
        assert!(a.emb.is_empty() && a.pos.is_empty() && a.cls.is_empty());
        let b = HeadGrads::cls(vec![3.0]);
        assert_eq!(b.cls, vec![3.0]);
        assert!(b.emb.is_empty() && b.pos.is_empty() && b.out.is_empty());
    }
}
