//! `Objective`: the open training-workload interface of the Session API.
//!
//! An objective owns its data source and defines how a batch is sampled,
//! how the loss head maps the final activation to (loss, cotangent, head
//! gradients), and how validation batches fold into a metric. The paper's
//! five tasks ship as four implementations ([`LmObjective`] covers both
//! causal LM and MLM); new workloads plug in by implementing the trait —
//! the coordinator never enumerates tasks.

use std::sync::Mutex;

use crate::config::ModelConfig;
use crate::data::charlm::CharCorpus;
use crate::data::images::ImageTask;
use crate::data::morpho::MorphoTask;
use crate::data::translate::TranslateTask;
use crate::model::ParamStore;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

use super::heads;

/// One sampled training/validation batch in the coordinator's unified
/// layout (unused fields stay empty/None). `Default` is the empty batch —
/// the session keeps one long-lived instance and refills it in place via
/// [`Objective::sample_into`] every step.
#[derive(Debug, Clone, Default)]
pub struct TrainBatch {
    /// Input token ids [B, S] (encoder side for EncDec).
    pub tokens: Vec<i32>,
    /// Token-level targets [B, S] — empty for classification.
    pub targets: Vec<i32>,
    /// Loss mask [B, S] — empty for classification.
    pub mask: Vec<f32>,
    /// Sequence-level labels [B] — classification only.
    pub labels: Vec<i32>,
    /// Decoder input (shifted right) [B, S] — EncDec only; its presence
    /// selects the stacked state Z = [X, Y].
    pub tgt_in: Option<Vec<i32>>,
}

/// What the loss head produced for one micro-batch.
pub struct LossOut {
    pub loss: f32,
    /// Correct predictions (numerator of the batch accuracy).
    pub correct: f32,
    /// Accuracy denominator (masked tokens / tokens / sequences).
    pub denom: f32,
    /// Loss cotangent w.r.t. the head-side final activation [B, S, D].
    pub lam_head: Tensor,
    /// Gradients of the head parameter groups this objective touches.
    pub head: HeadGrads,
}

/// Scalar results of a workspace-reusing loss-head evaluation (the
/// cotangent and head gradients land in the caller's [`LossSink`]).
#[derive(Debug, Clone, Copy)]
pub struct LossStats {
    pub loss: f32,
    /// Correct predictions (numerator of the batch accuracy).
    pub correct: f32,
    /// Accuracy denominator (masked tokens / tokens / sequences).
    pub denom: f32,
}

/// Destination buffers for [`Objective::loss_into`]: the head-shaped
/// cotangent buffer (fully overwritten), the step's head-parameter
/// gradient accumulators (**added** into — they are zeroed once per
/// optimizer step by the training loop), and the reusable numeric
/// scratch. All of it lives in the session's persistent
/// [`crate::coordinator::StepWorkspace`], so a steady-state loss-head
/// evaluation allocates nothing.
pub struct LossSink<'a> {
    pub lam_head: &'a mut Tensor,
    pub g_emb: &'a mut [f32],
    pub g_pos: &'a mut [f32],
    pub g_out: &'a mut [f32],
    pub g_cls: &'a mut [f32],
    pub scratch: &'a mut LossScratch,
}

/// Reusable numeric scratch of the loss heads (sized on first use).
#[derive(Debug, Default)]
pub struct LossScratch {
    /// Per-row logits (vocab- or class-sized).
    pub logits: Vec<f32>,
    /// Mean-pooled activation (classification head).
    pub pooled: Vec<f32>,
}

/// Accumulator for validation metrics across eval batches.
#[derive(Debug, Clone, Default)]
pub struct EvalAccum {
    pub correct: f64,
    pub total: f64,
    /// (prediction, reference) token sequences for corpus-level metrics
    /// (BLEU); empty for accuracy-style objectives.
    pub pairs: Vec<(Vec<i32>, Vec<i32>)>,
}

/// A training workload: data source + loss head + validation metric.
///
/// Implementations must be deterministic in the `Rng` they are handed so
/// backend-parity holds bitwise across execution strategies. `Send + Sync`
/// keeps whole `Session`s movable across threads, matching the
/// [`crate::ode::Propagator`] / [`super::backend::Backend`] contracts.
pub trait Objective: Send + Sync {
    /// Short name for logs (`"mlm"`, `"tag"`, …).
    fn name(&self) -> &'static str;

    /// Sample one batch (training and validation share this; the caller
    /// controls the stream via the `Rng`).
    fn sample(&self, rng: &mut Rng, m: &ModelConfig) -> TrainBatch;

    /// Workspace-reusing sampler: refill `out` in place. The default
    /// delegates to [`Objective::sample`] (allocating); the in-tree
    /// objectives override it so steady-state sampling allocates nothing.
    /// Must consume the `Rng` identically to `sample` — the training data
    /// stream may not depend on which entry point produced it.
    fn sample_into(&self, rng: &mut Rng, m: &ModelConfig, out: &mut TrainBatch) {
        *out = self.sample(rng, m);
    }

    /// Loss + cotangent + head-parameter gradients at the final activation
    /// `x_final` [B, S, D] (decoder half for EncDec).
    fn loss(
        &self,
        x_final: &Tensor,
        params: &ParamStore,
        batch: &TrainBatch,
        m: &ModelConfig,
    ) -> LossOut;

    /// Workspace-reusing loss head: write the cotangent into
    /// `sink.lam_head`, **accumulate** head-parameter gradients into the
    /// sink's group accumulators, and return the scalar stats. The default
    /// delegates to [`Objective::loss`] and copies; the in-tree objectives
    /// override it with the `heads::*_into` kernels so the steady-state
    /// step allocates nothing (pinned by `rust/tests/alloc_audit.rs`).
    fn loss_into(
        &self,
        x_final: &Tensor,
        params: &ParamStore,
        batch: &TrainBatch,
        m: &ModelConfig,
        sink: LossSink<'_>,
    ) -> LossStats {
        let out = self.loss(x_final, params, batch, m);
        sink.lam_head.copy_from(&out.lam_head);
        for (acc, src) in [
            (sink.g_emb, &out.head.emb),
            (sink.g_pos, &out.head.pos),
            (sink.g_out, &out.head.out),
            (sink.g_cls, &out.head.cls),
        ] {
            if src.is_empty() {
                continue;
            }
            assert_eq!(acc.len(), src.len(), "head gradient group size mismatch");
            for (a, b) in acc.iter_mut().zip(src.iter()) {
                *a += b;
            }
        }
        LossStats { loss: out.loss, correct: out.correct, denom: out.denom }
    }

    /// Fold one validation batch into the accumulator.
    fn eval_batch(
        &self,
        x_final: &Tensor,
        params: &ParamStore,
        batch: &TrainBatch,
        m: &ModelConfig,
        acc: &mut EvalAccum,
    );

    /// Final metric from the accumulated validation state (accuracy, BLEU).
    fn metric(&self, acc: &EvalAccum) -> f64;
}

/// Character language modeling: causal (GPT) or masked (BERT).
pub struct LmObjective {
    corpus: CharCorpus,
    /// `Some(mask_id)` → MLM with that mask token; `None` → causal LM.
    mask_id: Option<i32>,
    mask_rate: f32,
}

impl LmObjective {
    pub fn causal(corpus: CharCorpus) -> LmObjective {
        LmObjective { corpus, mask_id: None, mask_rate: 0.0 }
    }

    pub fn masked(corpus: CharCorpus, mask_id: i32, mask_rate: f32) -> LmObjective {
        LmObjective { corpus, mask_id: Some(mask_id), mask_rate }
    }
}

impl Objective for LmObjective {
    fn name(&self) -> &'static str {
        if self.mask_id.is_some() {
            "mlm"
        } else {
            "lm"
        }
    }

    fn sample(&self, rng: &mut Rng, m: &ModelConfig) -> TrainBatch {
        let mut out = TrainBatch::default();
        self.sample_into(rng, m, &mut out);
        out
    }

    fn sample_into(&self, rng: &mut Rng, m: &ModelConfig, out: &mut TrainBatch) {
        match self.mask_id {
            Some(id) => self.corpus.mlm_batch_into(
                rng,
                m.batch,
                m.seq,
                self.mask_rate,
                id,
                &mut out.tokens,
                &mut out.targets,
                &mut out.mask,
            ),
            None => self.corpus.lm_batch_into(
                rng,
                m.batch,
                m.seq,
                &mut out.tokens,
                &mut out.targets,
                &mut out.mask,
            ),
        }
        out.labels.clear();
        out.tgt_in = None;
    }

    fn loss(
        &self,
        x_final: &Tensor,
        params: &ParamStore,
        batch: &TrainBatch,
        m: &ModelConfig,
    ) -> LossOut {
        let (loss, correct, lam_head, gw) =
            heads::lm_loss(x_final, &params.w_out, &batch.targets, &batch.mask, m.vocab);
        let denom = batch.mask.iter().sum::<f32>().max(1.0);
        LossOut { loss, correct, denom, lam_head, head: HeadGrads::out(gw) }
    }

    fn loss_into(
        &self,
        x_final: &Tensor,
        params: &ParamStore,
        batch: &TrainBatch,
        m: &ModelConfig,
        sink: LossSink<'_>,
    ) -> LossStats {
        let (loss, correct, denom) = heads::lm_loss_into(
            x_final,
            &params.w_out,
            &batch.targets,
            Some(&batch.mask),
            m.vocab,
            sink.lam_head,
            sink.g_out,
            &mut sink.scratch.logits,
        );
        LossStats { loss, correct, denom }
    }

    fn eval_batch(
        &self,
        x_final: &Tensor,
        params: &ParamStore,
        batch: &TrainBatch,
        m: &ModelConfig,
        acc: &mut EvalAccum,
    ) {
        let (_, c, _, _) =
            heads::lm_loss(x_final, &params.w_out, &batch.targets, &batch.mask, m.vocab);
        acc.correct += c as f64;
        acc.total += batch.mask.iter().sum::<f32>() as f64;
    }

    fn metric(&self, acc: &EvalAccum) -> f64 {
        acc.correct / acc.total.max(1.0)
    }
}

/// Per-token morphological tagging (the paper's MC task).
pub struct TagObjective {
    task: MorphoTask,
}

impl TagObjective {
    pub fn new(task: MorphoTask) -> TagObjective {
        TagObjective { task }
    }
}

impl Objective for TagObjective {
    fn name(&self) -> &'static str {
        "tag"
    }

    fn sample(&self, rng: &mut Rng, m: &ModelConfig) -> TrainBatch {
        let mut out = TrainBatch::default();
        self.sample_into(rng, m, &mut out);
        out
    }

    fn sample_into(&self, rng: &mut Rng, m: &ModelConfig, out: &mut TrainBatch) {
        self.task.batch_into(rng, m.batch, m.seq, &mut out.tokens, &mut out.targets);
        out.mask.clear();
        out.mask.resize(m.batch * m.seq, 1.0);
        out.labels.clear();
        out.tgt_in = None;
    }

    fn loss(
        &self,
        x_final: &Tensor,
        params: &ParamStore,
        batch: &TrainBatch,
        m: &ModelConfig,
    ) -> LossOut {
        let (loss, correct, lam_head, gw) =
            heads::tag_loss(x_final, &params.w_cls, &batch.targets, m.n_classes);
        LossOut {
            loss,
            correct,
            denom: (m.batch * m.seq) as f32,
            lam_head,
            head: HeadGrads::cls(gw),
        }
    }

    fn loss_into(
        &self,
        x_final: &Tensor,
        params: &ParamStore,
        batch: &TrainBatch,
        m: &ModelConfig,
        sink: LossSink<'_>,
    ) -> LossStats {
        let (loss, correct, denom) = heads::tag_loss_into(
            x_final,
            &params.w_cls,
            &batch.targets,
            m.n_classes,
            sink.lam_head,
            sink.g_cls,
            &mut sink.scratch.logits,
        );
        // the kernel's all-ones denominator is exactly (batch * seq) as f32
        LossStats { loss, correct, denom }
    }

    fn eval_batch(
        &self,
        x_final: &Tensor,
        params: &ParamStore,
        batch: &TrainBatch,
        m: &ModelConfig,
        acc: &mut EvalAccum,
    ) {
        let (_, c, _, _) = heads::tag_loss(x_final, &params.w_cls, &batch.targets, m.n_classes);
        acc.correct += c as f64;
        acc.total += (m.batch * m.seq) as f64;
    }

    fn metric(&self, acc: &EvalAccum) -> f64 {
        acc.correct / acc.total.max(1.0)
    }
}

/// Sequence classification over patch tokens (the paper's ViT task).
pub struct ClsObjective {
    task: ImageTask,
    /// Reusable pixel buffer for the procedural renderer (`sample_into`
    /// takes `&self`, so the scratch hides behind an uncontended mutex —
    /// sampling is single-threaded per session).
    img_scratch: Mutex<Vec<f32>>,
}

impl ClsObjective {
    pub fn new(task: ImageTask) -> ClsObjective {
        ClsObjective { task, img_scratch: Mutex::new(Vec::new()) }
    }
}

impl Objective for ClsObjective {
    fn name(&self) -> &'static str {
        "cls"
    }

    fn sample(&self, rng: &mut Rng, m: &ModelConfig) -> TrainBatch {
        let mut out = TrainBatch::default();
        self.sample_into(rng, m, &mut out);
        out
    }

    fn sample_into(&self, rng: &mut Rng, m: &ModelConfig, out: &mut TrainBatch) {
        let mut img = self.img_scratch.lock().unwrap();
        self.task.batch_into(rng, m.batch, &mut out.tokens, &mut out.labels, &mut img);
        out.targets.clear();
        out.mask.clear();
        out.tgt_in = None;
    }

    fn loss(
        &self,
        x_final: &Tensor,
        params: &ParamStore,
        batch: &TrainBatch,
        m: &ModelConfig,
    ) -> LossOut {
        let (loss, correct, lam_head, gw) =
            heads::cls_loss(x_final, &params.w_cls, &batch.labels, m.n_classes);
        LossOut { loss, correct, denom: m.batch as f32, lam_head, head: HeadGrads::cls(gw) }
    }

    fn loss_into(
        &self,
        x_final: &Tensor,
        params: &ParamStore,
        batch: &TrainBatch,
        m: &ModelConfig,
        sink: LossSink<'_>,
    ) -> LossStats {
        let (loss, correct) = heads::cls_loss_into(
            x_final,
            &params.w_cls,
            &batch.labels,
            m.n_classes,
            sink.lam_head,
            sink.g_cls,
            &mut sink.scratch.logits,
            &mut sink.scratch.pooled,
        );
        LossStats { loss, correct, denom: m.batch as f32 }
    }

    fn eval_batch(
        &self,
        x_final: &Tensor,
        params: &ParamStore,
        batch: &TrainBatch,
        m: &ModelConfig,
        acc: &mut EvalAccum,
    ) {
        let (_, c, _, _) = heads::cls_loss(x_final, &params.w_cls, &batch.labels, m.n_classes);
        acc.correct += c as f64;
        acc.total += m.batch as f64;
    }

    fn metric(&self, acc: &EvalAccum) -> f64 {
        acc.correct / acc.total.max(1.0)
    }
}

/// Encoder-decoder translation over the stacked state Z = [X, Y] (the
/// paper's MT task); validation metric is BLEU-4.
pub struct TranslateObjective {
    task: TranslateTask,
}

impl TranslateObjective {
    pub fn new(task: TranslateTask) -> TranslateObjective {
        TranslateObjective { task }
    }
}

impl Objective for TranslateObjective {
    fn name(&self) -> &'static str {
        "translate"
    }

    fn sample(&self, rng: &mut Rng, m: &ModelConfig) -> TrainBatch {
        let mut out = TrainBatch::default();
        self.sample_into(rng, m, &mut out);
        out
    }

    fn sample_into(&self, rng: &mut Rng, m: &ModelConfig, out: &mut TrainBatch) {
        if out.tgt_in.is_none() {
            out.tgt_in = Some(Vec::new());
        }
        let tgt_in = out.tgt_in.as_mut().expect("tgt_in ensured above");
        self.task.batch_into(
            rng,
            m.batch,
            m.seq,
            &mut out.tokens,
            tgt_in,
            &mut out.targets,
            &mut out.mask,
        );
        out.labels.clear();
    }

    fn loss(
        &self,
        x_final: &Tensor,
        params: &ParamStore,
        batch: &TrainBatch,
        m: &ModelConfig,
    ) -> LossOut {
        let (loss, correct, lam_head, gw) =
            heads::lm_loss(x_final, &params.w_out, &batch.targets, &batch.mask, m.vocab);
        let denom = batch.mask.iter().sum::<f32>().max(1.0);
        LossOut { loss, correct, denom, lam_head, head: HeadGrads::out(gw) }
    }

    fn loss_into(
        &self,
        x_final: &Tensor,
        params: &ParamStore,
        batch: &TrainBatch,
        m: &ModelConfig,
        sink: LossSink<'_>,
    ) -> LossStats {
        let (loss, correct, denom) = heads::lm_loss_into(
            x_final,
            &params.w_out,
            &batch.targets,
            Some(&batch.mask),
            m.vocab,
            sink.lam_head,
            sink.g_out,
            &mut sink.scratch.logits,
        );
        LossStats { loss, correct, denom }
    }

    fn eval_batch(
        &self,
        x_final: &Tensor,
        params: &ParamStore,
        batch: &TrainBatch,
        m: &ModelConfig,
        acc: &mut EvalAccum,
    ) {
        let preds = heads::argmax_tokens(x_final, &params.w_out, m.vocab);
        for b in 0..m.batch {
            acc.pairs.push((
                preds[b * m.seq..(b + 1) * m.seq].to_vec(),
                batch.targets[b * m.seq..(b + 1) * m.seq].to_vec(),
            ));
        }
    }

    fn metric(&self, acc: &EvalAccum) -> f64 {
        crate::analysis::bleu4(&acc.pairs)
    }
}

/// Gradients of the non-layer parameter groups (embeddings + heads) an
/// objective's loss head produced. Objectives fill only the groups they
/// touch (the rest stay empty); the training step folds them into the
/// full-size accumulators of
/// [`crate::coordinator::context::StepWorkspace`].
pub struct HeadGrads {
    pub emb: Vec<f32>,
    pub pos: Vec<f32>,
    pub out: Vec<f32>,
    pub cls: Vec<f32>,
}

impl HeadGrads {
    /// LM-head gradient only.
    pub fn out(gw: Vec<f32>) -> HeadGrads {
        HeadGrads { emb: vec![], pos: vec![], out: gw, cls: vec![] }
    }

    /// Classifier-head gradient only.
    pub fn cls(gw: Vec<f32>) -> HeadGrads {
        HeadGrads { emb: vec![], pos: vec![], out: vec![], cls: gw }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn objectives_sample_consistent_shapes() {
        let m = presets::mc_tiny().model;
        let mut rng = Rng::new(0);
        let obj = TagObjective::new(MorphoTask::new(m.vocab, m.n_classes, 1));
        let b = obj.sample(&mut rng, &m);
        assert_eq!(b.tokens.len(), m.batch * m.seq);
        assert_eq!(b.targets.len(), m.batch * m.seq);
        assert!(b.tgt_in.is_none());
        assert_eq!(obj.name(), "tag");
    }

    #[test]
    fn translate_samples_decoder_input() {
        let m = presets::mt_small().model;
        let mut rng = Rng::new(0);
        let obj = TranslateObjective::new(TranslateTask::new(m.vocab, 1, false));
        let b = obj.sample(&mut rng, &m);
        assert_eq!(b.tgt_in.as_ref().unwrap().len(), m.batch * m.seq);
    }

    #[test]
    fn sample_into_matches_sample_for_every_objective() {
        // the workspace-reusing sampler must consume the rng identically
        // and refill a dirty reused batch into the exact same contents
        let check = |obj: &dyn Objective, m: &ModelConfig| {
            let mut r1 = Rng::new(42);
            let fresh = obj.sample(&mut r1, m);
            // start from a dirty, wrongly-sized reused batch
            let mut reused = TrainBatch {
                tokens: vec![9; 3],
                targets: vec![9; 99],
                mask: vec![0.5; 7],
                labels: vec![4],
                tgt_in: Some(vec![1]),
            };
            let mut r2 = Rng::new(42);
            obj.sample_into(&mut r2, m, &mut reused);
            // identical rng consumption: the streams stay in lockstep
            assert_eq!(r1.next_u64(), r2.next_u64(), "rng stream diverged ({})", obj.name());
            assert_eq!(reused.tokens, fresh.tokens, "{}", obj.name());
            assert_eq!(reused.targets, fresh.targets, "{}", obj.name());
            assert_eq!(reused.mask, fresh.mask, "{}", obj.name());
            assert_eq!(reused.labels, fresh.labels, "{}", obj.name());
            assert_eq!(reused.tgt_in, fresh.tgt_in, "{}", obj.name());
            // a steady-state refill of the now-right-sized batch matches too
            let mut r3 = Rng::new(42);
            obj.sample_into(&mut r3, m, &mut reused);
            assert_eq!(reused.tokens, fresh.tokens, "steady refill ({})", obj.name());
            assert_eq!(reused.tgt_in, fresh.tgt_in, "steady refill ({})", obj.name());
        };
        let m = presets::mc_tiny().model;
        check(&TagObjective::new(MorphoTask::new(m.vocab, m.n_classes, 1)), &m);
        let corpus = || CharCorpus::new(m.vocab, 3, 3);
        check(&LmObjective::causal(corpus()), &m);
        check(&LmObjective::masked(corpus(), (m.vocab - 1) as i32, 0.2), &m);
        let mt = presets::mt_small().model;
        check(&TranslateObjective::new(TranslateTask::new(mt.vocab, 1, false)), &mt);
        let mut vit = m.clone();
        vit.seq = 16;
        check(&ClsObjective::new(ImageTask::new(16, vit.vocab, vit.n_classes)), &vit);
    }

    #[test]
    fn loss_into_matches_loss_bitwise() {
        use crate::model::{Init, ParamStore};
        let m = presets::mc_tiny().model;
        let params = ParamStore::init(&m, Init::Default, 7);
        let obj = TagObjective::new(MorphoTask::new(m.vocab, m.n_classes, 1));
        let mut rng = Rng::new(5);
        let batch = obj.sample(&mut rng, &m);
        let x = Tensor::randn(&mut rng, &[m.batch, m.seq, m.d_model], 0.6);
        let out = obj.loss(&x, &params, &batch, &m);
        let mut lam_head = Tensor::zeros(&[m.batch, m.seq, m.d_model]);
        let mut g_emb = vec![0.0f32; params.w_emb.len()];
        let mut g_pos = vec![0.0f32; params.w_pos.len()];
        let mut g_out = vec![0.0f32; params.w_out.len()];
        let mut g_cls = vec![0.0f32; params.w_cls.len()];
        let mut scratch = LossScratch::default();
        let stats = obj.loss_into(
            &x,
            &params,
            &batch,
            &m,
            LossSink {
                lam_head: &mut lam_head,
                g_emb: &mut g_emb,
                g_pos: &mut g_pos,
                g_out: &mut g_out,
                g_cls: &mut g_cls,
                scratch: &mut scratch,
            },
        );
        assert_eq!(stats.loss, out.loss);
        assert_eq!(stats.correct, out.correct);
        assert_eq!(stats.denom, out.denom);
        assert_eq!(lam_head.data(), out.lam_head.data());
        assert_eq!(g_cls, out.head.cls);
        assert!(g_out.iter().all(|&v| v == 0.0), "untouched groups stay zero");
    }

    #[test]
    fn head_grads_constructors_touch_one_group() {
        let a = HeadGrads::out(vec![1.0, 2.0]);
        assert_eq!(a.out, vec![1.0, 2.0]);
        assert!(a.emb.is_empty() && a.pos.is_empty() && a.cls.is_empty());
        let b = HeadGrads::cls(vec![3.0]);
        assert_eq!(b.cls, vec![3.0]);
        assert!(b.emb.is_empty() && b.pos.is_empty() && b.out.is_empty());
    }
}
