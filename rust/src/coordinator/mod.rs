//! The training coordinator — the launcher-facing layer that composes
//! embedding/heads, the MGRIT engine, the adaptive controller, optimizers,
//! and data pipelines into the paper's training procedure.
//!
//! Session API v2 layering:
//!
//! * [`session`] — [`Session`] + [`SessionBuilder`]: the composable run
//!   (`Session::builder().preset(..).propagator(..).backend(..)
//!   .objective(..).build()?`).
//! * [`backend`] — [`Backend`]: execution strategy of the forward/adjoint
//!   solves (`Serial` / `Mgrit` / `ThreadedMgrit`, the last driving
//!   multi-worker relaxation through `parallel::exec` on the hot loop).
//! * [`context`] — the persistent solve state, layered for the train/infer
//!   split: [`ForwardContext`] + [`ForwardWorkspace`] are the shared
//!   **forward core** (backend strategy, cached forward MGRIT hierarchy,
//!   warm-start iterate, fine-grid states) that batched inference
//!   ([`crate::infer::InferSession`]) reuses verbatim; [`SolveContext`] +
//!   [`StepWorkspace`] add the cached adjoint hierarchy and the
//!   training-only λ/gradient/loss-head buffers on top. The session
//!   creates one context from its backend and every solve of the run
//!   replays on it (no `MgritCore` construction at steady state).
//! * [`objective`] — [`Objective`]: open workload interface (data
//!   sampling, loss head, validation metric) replacing the closed task
//!   enums.
//! * [`heads`] — pure-Rust embedding and loss heads (fwd+bwd). The ODE
//!   layers dominate compute and run through XLA; heads are O(B·S·D·V)
//!   and run on the coordinator.
//! * [`range`] — a sub-range view of a propagator: buffer layers
//!   (Appendix B) run serially outside the MGRIT domain.
//! * [`trainer`] — the preset→[`Task`]→objective mapping and the v1
//!   [`TrainRun`] compatibility alias.

pub mod backend;
pub mod context;
pub mod heads;
pub mod objective;
pub mod range;
pub mod session;
pub mod trainer;

pub use backend::{backend_for_workers, Backend, Mgrit, Serial, ThreadedMgrit};
pub use context::{mid_range, ForwardContext, ForwardWorkspace, SolveContext, StepWorkspace};
pub use objective::{
    ClsObjective, EvalAccum, HeadGrads, LmObjective, LossOut, LossScratch, LossSink, LossStats,
    Objective, TagObjective, TrainBatch, TranslateObjective,
};
pub use range::RangeProp;
pub use session::{
    AnomalyKind, EvalRecord, PropagatorKind, Session, SessionBuilder, StepAnomaly, StepRecord,
    TrainReport, MAX_ROLLBACKS, MAX_STEP_RETRIES,
};
pub use trainer::{Task, TrainRun};
