//! The training coordinator — the launcher-facing layer that composes
//! embedding/heads ([`heads`]), the MGRIT engine, the adaptive controller,
//! optimizers, and data pipelines into the paper's training procedure.
//!
//! * [`heads`] — pure-Rust embedding and loss heads (fwd+bwd). The ODE
//!   layers dominate compute and run through XLA; heads are O(B·S·D·V)
//!   and run on the coordinator.
//! * [`range`] — a sub-range view of a propagator: buffer layers
//!   (Appendix B) run serially outside the MGRIT domain.
//! * [`trainer`] — `TrainRun`: batch loop, forward/adjoint MGRIT solves,
//!   §3.2.3 probes, gradient clipping, optimizer updates, evaluation
//!   (accuracy / BLEU), CSV run recording.

pub mod heads;
pub mod range;
pub mod trainer;

pub use range::RangeProp;
pub use trainer::{Task, TrainReport, TrainRun};
