//! The FAS-MGRIT core: relaxation, restriction with τ-correction, V-cycle.
//!
//! Solves the all-at-once system  A(W) = G  where
//!   A(W)_0 = W_0,          A(W)_n = W_n − Φ_{n-1}(W_{n-1})  (n ≥ 1)
//! (paper §3.2.1). Nonlinear Φ requires the Full Approximation Scheme: the
//! coarse level solves A_c(W_c) = A_c(R W) + R (G − A(W)) rather than an
//! error equation. For linear Φ this reduces exactly to the residual/error
//! form shown in the paper's Fig. 2.
//!
//! The core is generic over a [`LevelStepper`] so the *same* code runs the
//! forward solve (over Φ) and the adjoint solve (over Φᵀ in reversed time).
//!
//! Hot-loop discipline: all level storage is preallocated once and every
//! relaxation/restriction update goes through [`LevelStepper::apply_into`]
//! plus two reusable residual scratch tensors — the V-cycle itself performs
//! no per-point allocations or clones (the old implementation cloned ~17
//! tensors per cycle).
//!
//! With `with_workers(n > 1)` every relaxation sweep (the parallel phase of
//! paper Fig. 2) executes through the multi-worker slab executor in
//! [`crate::parallel::exec`] — OS threads + channel-fabric halo exchange —
//! producing bitwise the same iterates as the single-threaded schedule.
//! Since the zero-copy refactor the workers relax **in place on this
//! core's level storage** (disjoint `&mut` slab views; no staging copies,
//! no stitch-back). `with_pool` routes those sweeps onto a persistent
//! [`WorkerPool`](crate::parallel::WorkerPool) instead of per-sweep scoped
//! spawns (same schedule, amortized spawn cost, and — with the pool's
//! persistent workspaces and the fabric's recycled halo buffers — zero
//! steady-state allocations). This is the engine room of the
//! `ThreadedMgrit` backend.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::parallel::exec;
use crate::parallel::pool::WorkerPool;
use crate::tensor::Tensor;

/// Process-wide count of [`MgritCore`] constructions. The persistent
/// solve-context design promises that cores are built at most once per
/// `Session` per direction (plus explicit rebuilds on cf/levels changes);
/// `rust/tests/core_reuse.rs` pins that promise by watching this counter
/// across steady-state training steps.
static CORE_CONSTRUCTIONS: AtomicU64 = AtomicU64::new(0);

/// One time-step on an arbitrary MGRIT level.
///
/// `fine_idx` is the fine-grid index of the step's *source* point and
/// `stride` the level's step width: the stepper advances from `fine_idx`
/// to `fine_idx + stride` using a single step of size `stride · h_fine`
/// (rediscretization). `Sync` because threaded relaxation applies the
/// stepper from worker threads.
pub trait LevelStepper: Sync {
    /// Fine-grid step count N.
    fn n(&self) -> usize;

    /// Advance: returns the state at `fine_idx + stride`.
    fn apply(&self, fine_idx: usize, stride: usize, z: &Tensor) -> Tensor;

    /// Advance, writing into an existing state tensor (fully overwritten).
    /// Default allocates via [`LevelStepper::apply`]; the solver's steppers
    /// forward to `Propagator::step_into` / `adjoint_step_into` so the
    /// relaxation sweeps run allocation-free.
    fn apply_into(&self, fine_idx: usize, stride: usize, z: &Tensor, out: &mut Tensor) {
        *out = self.apply(fine_idx, stride, z);
    }
}

/// Per-level storage (preallocated once, reused across V-cycles).
struct Level {
    /// Fine-index stride of one step on this level (c_f^ℓ).
    stride: usize,
    /// Steps on this level.
    n: usize,
    /// Solution iterate W (n+1 points).
    w: Vec<Tensor>,
    /// FAS right-hand side G (n+1 points; g[0] is the initial condition).
    g: Vec<Tensor>,
    /// Snapshot of the restricted iterate (for the FAS correction).
    w_init: Vec<Tensor>,
}

/// Reusable FAS-MGRIT engine over one stepper.
pub struct MgritCore {
    cf: usize,
    fcf: bool,
    /// Relaxation worker threads (1 = single-threaded schedule).
    workers: usize,
    /// Persistent workers for the relaxation sweeps (None = scoped spawns).
    pool: Option<Arc<WorkerPool>>,
    levels: Vec<Level>,
    /// Residual/restriction scratch (state-shaped), reused across cycles.
    tmp_pred: Tensor,
    tmp_r: Tensor,
}

/// Per-solve statistics.
#[derive(Debug, Clone, Default)]
pub struct CoreStats {
    /// Fine-grid residual norm after each V-cycle (only when tracking).
    pub residuals: Vec<f64>,
}

impl MgritCore {
    /// Build storage for `n` fine steps with state shaped like `proto`.
    pub fn new(n: usize, cf: usize, max_levels: usize, fcf: bool, proto: &Tensor) -> MgritCore {
        CORE_CONSTRUCTIONS.fetch_add(1, Ordering::Relaxed);
        let grid = super::grid::GridHierarchy::new(n, cf, max_levels);
        let levels = grid
            .steps
            .iter()
            .enumerate()
            .map(|(l, &nl)| Level {
                stride: grid.stride(l),
                n: nl,
                w: vec![Tensor::zeros(proto.shape()); nl + 1],
                g: vec![Tensor::zeros(proto.shape()); nl + 1],
                w_init: vec![Tensor::zeros(proto.shape()); nl + 1],
            })
            .collect();
        MgritCore {
            cf,
            fcf,
            workers: 1,
            pool: None,
            levels,
            tmp_pred: Tensor::zeros(proto.shape()),
            tmp_r: Tensor::zeros(proto.shape()),
        }
    }

    /// Route every relaxation sweep through `workers` slab threads
    /// (bitwise identical to the single-threaded schedule; see
    /// [`crate::parallel::exec`]).
    pub fn with_workers(mut self, workers: usize) -> MgritCore {
        self.workers = workers.max(1);
        self
    }

    /// Route relaxation sweeps through a persistent worker pool (same slab
    /// schedule as `with_workers(pool.size())`, threads parked between
    /// sweeps instead of respawned).
    pub fn with_pool(mut self, pool: Arc<WorkerPool>) -> MgritCore {
        self.workers = pool.size().max(1);
        self.pool = Some(pool);
        self
    }

    /// Process-wide number of `MgritCore::new` calls so far (see
    /// [`CORE_CONSTRUCTIONS`]): the hierarchy-reuse acceptance counter.
    pub fn total_constructed() -> u64 {
        CORE_CONSTRUCTIONS.load(Ordering::Relaxed)
    }

    /// (Re-)attach the relaxation execution mode for the next solve.
    ///
    /// Cached cores outlive individual solves, but the backend's worker
    /// pool does not have to (a pool poisoned by a panicked sweep is
    /// rebuilt): callers refresh the attachment per solve. `Some(pool)`
    /// routes sweeps onto the pool and adopts its worker count; `None`
    /// detaches the pool but keeps the configured worker count (scoped
    /// spawns / single-threaded schedule).
    pub fn set_pool(&mut self, pool: Option<Arc<WorkerPool>>) {
        if let Some(p) = &pool {
            self.workers = p.size().max(1);
        }
        self.pool = pool;
    }

    /// Override the relaxation worker count for the next solve. The
    /// sweep-panic last-resort path (`set_pool(None)` + `set_workers(1)`)
    /// runs the same V-cycle schedule entirely in-thread — bitwise
    /// identical to the threaded sweeps, no threads to fail. A later
    /// `set_pool(Some(..))` re-adopts that pool's count.
    pub fn set_workers(&mut self, n: usize) {
        self.workers = n.max(1);
    }

    pub fn n_levels(&self) -> usize {
        self.levels.len()
    }

    /// Fine steps N this core's storage was built for.
    pub fn n_fine_steps(&self) -> usize {
        self.levels[0].n
    }

    /// Structural health check for cores cached across solves. Since the
    /// in-place relaxation executors, threaded sweeps no longer
    /// `mem::take` the level storage, so a panicked sweep leaves the core
    /// structurally whole (possibly with torn point values, which the
    /// next `solve` fully reinitializes) — cached cores survive panic
    /// recovery and only the poisoned pool is replaced. The check is kept
    /// as a defensive invariant for the per-`Session` solve context,
    /// which still treats a non-intact core as a cache miss.
    pub fn is_intact(&self) -> bool {
        self.levels
            .iter()
            .all(|l| l.w.len() == l.n + 1 && l.g.len() == l.n + 1 && l.w_init.len() == l.n + 1)
    }

    /// Direct serial solve of A(W)=G on the fine grid (the baseline / L=1
    /// path): W_0 = G_0, W_n = Φ(W_{n-1}) + G_n.
    pub fn serial_solve<S: LevelStepper>(&mut self, stepper: &S, z0: &Tensor) -> &[Tensor] {
        let lvl = &mut self.levels[0];
        lvl.w[0].copy_from(z0);
        for i in 1..=lvl.n {
            let (head, tail) = lvl.w.split_at_mut(i);
            stepper.apply_into(i - 1, 1, &head[i - 1], &mut tail[0]);
        }
        &lvl.w
    }

    /// Run `iters` V-cycles from an initial guess; returns stats.
    ///
    /// * `z0` — initial condition (becomes W_0 and G_0).
    /// * `warm` — optional warm-start iterate for all fine points (e.g. the
    ///   previous batch's states, TorchBraid-style); defaults to z0 copies.
    /// * `track_residuals` — compute ‖G − A(W)‖ after every cycle (costs one
    ///   extra fine sweep per cycle; used by the §3.2.3 indicator probes).
    pub fn solve<S: LevelStepper>(
        &mut self,
        stepper: &S,
        z0: &Tensor,
        warm: Option<&[Tensor]>,
        iters: usize,
        track_residuals: bool,
    ) -> CoreStats {
        {
            let lvl = &mut self.levels[0];
            assert_eq!(lvl.n, stepper.n(), "stepper/grid size mismatch");
            lvl.w[0].copy_from(z0);
            lvl.g[0].copy_from(z0);
            for i in 1..=lvl.n {
                lvl.g[i].fill_zero();
                match warm {
                    Some(ws) => lvl.w[i].copy_from(&ws[i]),
                    None => lvl.w[i].copy_from(z0),
                }
            }
        }
        let mut stats = CoreStats::default();
        for _ in 0..iters {
            Self::vcycle(
                &mut self.levels,
                stepper,
                self.cf,
                self.fcf,
                self.workers,
                self.pool.as_deref(),
                &mut self.tmp_pred,
                &mut self.tmp_r,
            );
            if track_residuals {
                stats.residuals.push(self.fine_residual_norm(stepper));
            }
        }
        stats
    }

    /// Fine-grid solution points (valid after `solve`/`serial_solve`).
    pub fn solution(&self) -> &[Tensor] {
        &self.levels[0].w
    }

    /// Copy the fine-grid solution into caller-owned buffers (`out` must
    /// hold N+1 state-shaped tensors, fully overwritten). The `_into`
    /// handoff for cached cores: no `to_vec()` clone, no allocation once
    /// the destination buffers exist.
    pub fn solution_into(&self, out: &mut [Tensor]) {
        let w = &self.levels[0].w;
        assert_eq!(out.len(), w.len(), "solution_into: need N+1 destination tensors");
        for (dst, src) in out.iter_mut().zip(w) {
            dst.copy_from(src);
        }
    }

    /// Like [`MgritCore::solution_into`] but in reversed point order
    /// (`out[i] = W[N−i]`): the adjoint solve runs in reversed time
    /// coordinates and hands its λ back on the natural fine grid.
    pub fn solution_rev_into(&self, out: &mut [Tensor]) {
        let w = &self.levels[0].w;
        assert_eq!(out.len(), w.len(), "solution_rev_into: need N+1 destination tensors");
        for (dst, src) in out.iter_mut().zip(w.iter().rev()) {
            dst.copy_from(src);
        }
    }

    /// Consume the core and move the fine-grid solution out (the one-shot
    /// path: fresh core per solve, zero-copy extraction).
    pub fn into_solution(mut self) -> Vec<Tensor> {
        std::mem::take(&mut self.levels[0].w)
    }

    /// Multilevel (FMG / nested-iteration) initialization, after Cyr,
    /// Günther & Schroder 2019 ("Multilevel initialization for
    /// layer-parallel deep neural network training", cited in the paper's
    /// §2): solve the *coarsest* rediscretization serially (c_f^{L-1}×
    /// cheaper than a fine sweep), then interpolate level by level —
    /// inject to C-points, F-relax to fill F-points — producing a fine-grid
    /// initial guess that typically saves V-cycles vs starting from z0
    /// copies. Returns the iterate in-place; follow with `solve(...,
    /// warm=Some(core.solution()))` or use `solve_fmg`.
    pub fn fmg_init<S: LevelStepper>(&mut self, stepper: &S, z0: &Tensor) {
        let n_levels = self.levels.len();
        // zero RHS everywhere; initial condition on every level
        for lvl in self.levels.iter_mut() {
            lvl.g.iter_mut().for_each(|g| g.fill_zero());
            lvl.g[0].copy_from(z0);
            lvl.w[0].copy_from(z0);
        }
        // serial solve on the coarsest rediscretization
        {
            let lvl = self.levels.last_mut().unwrap();
            for i in 1..=lvl.n {
                let (head, tail) = lvl.w.split_at_mut(i);
                stepper.apply_into((i - 1) * lvl.stride, lvl.stride, &head[i - 1], &mut tail[0]);
            }
        }
        // interpolate down: inject C-points, F-relax to fill the rest
        for l in (0..n_levels - 1).rev() {
            let (fine, coarse) = {
                let (a, b) = self.levels.split_at_mut(l + 1);
                (&mut a[l], &b[0])
            };
            for k in 0..=coarse.n {
                fine.w[k * self.cf].copy_from(&coarse.w[k]);
            }
            Self::f_relax(fine, stepper, self.cf);
        }
    }

    /// FMG-initialized solve: nested-iteration initial guess followed by
    /// `iters` V-cycles.
    pub fn solve_fmg<S: LevelStepper>(
        &mut self,
        stepper: &S,
        z0: &Tensor,
        iters: usize,
        track_residuals: bool,
    ) -> CoreStats {
        self.fmg_init(stepper, z0);
        let warm: Vec<Tensor> = self.levels[0].w.clone();
        self.solve(stepper, z0, Some(&warm), iters, track_residuals)
    }

    /// ‖G − A(W)‖ on the fine grid (allocation-free: reuses the core's
    /// residual scratch).
    pub fn fine_residual_norm<S: LevelStepper>(&mut self, stepper: &S) -> f64 {
        let lvl = &self.levels[0];
        let (pred, r) = (&mut self.tmp_pred, &mut self.tmp_r);
        let mut acc = 0.0f64;
        for i in 1..=lvl.n {
            stepper.apply_into((i - 1) * lvl.stride, lvl.stride, &lvl.w[i - 1], pred);
            r.copy_from(&lvl.g[i]);
            r.axpy(-1.0, &lvl.w[i]);
            r.axpy(1.0, pred);
            let nrm = r.norm() as f64;
            acc += nrm * nrm;
        }
        acc.sqrt()
    }

    // -- internals ----------------------------------------------------------

    /// One in-place relaxation update of point `idx + 1` from point `idx`:
    /// w[idx+1] = Φ(w[idx]) + g[idx+1], written straight into the level
    /// storage (no temporaries).
    fn relax_into<S: LevelStepper>(lvl: &mut Level, stepper: &S, idx: usize) {
        let (head, tail) = lvl.w.split_at_mut(idx + 1);
        stepper.apply_into(idx * lvl.stride, lvl.stride, &head[idx], &mut tail[0]);
        tail[0].axpy(1.0, &lvl.g[idx + 1]);
    }

    /// F-relaxation: from every C-point, re-propagate across the F-points
    /// up to (not including) the next C-point. Each chunk is independent —
    /// this is the N/c_f-way-parallel phase (paper Fig. 2, red/blue arrows).
    fn f_relax<S: LevelStepper>(lvl: &mut Level, stepper: &S, cf: usize) {
        let n_chunks = lvl.n / cf;
        for k in 0..n_chunks {
            for i in 0..cf - 1 {
                Self::relax_into(lvl, stepper, k * cf + i);
            }
        }
    }

    /// C-relaxation: update every C-point from its preceding F-point.
    fn c_relax<S: LevelStepper>(lvl: &mut Level, stepper: &S, cf: usize) {
        let n_chunks = lvl.n / cf;
        for k in 1..=n_chunks {
            Self::relax_into(lvl, stepper, k * cf - 1);
        }
    }

    /// Does threading this level pay? Needs >1 workers, even coarsening
    /// (always true below the coarsest level), and at least two chunks —
    /// a single-chunk level has no parallelism to expose, only dispatch
    /// and slab-copy overhead.
    fn thread_level(lvl: &Level, cf: usize, workers: usize) -> bool {
        workers > 1 && lvl.n % cf == 0 && lvl.n / cf >= 2
    }

    /// F-relaxation, threaded when [`Self::thread_level`] says it pays —
    /// through the persistent pool when one is attached, scoped spawns
    /// otherwise (identical schedules). Workers relax **in place** on the
    /// level's point storage (disjoint slab views; no staging copies, no
    /// stitch — see `parallel::exec`).
    fn f_relax_exec<S: LevelStepper>(
        lvl: &mut Level,
        stepper: &S,
        cf: usize,
        workers: usize,
        pool: Option<&WorkerPool>,
    ) {
        if Self::thread_level(lvl, cf, workers) {
            let stride = lvl.stride;
            let step = |idx: usize, z: &Tensor, out: &mut Tensor| {
                stepper.apply_into(idx * stride, stride, z, out)
            };
            let Level { w, g, .. } = lvl;
            match pool {
                Some(p) => exec::pool_f_relax_mut(p, w, Some(&g[..]), cf, step),
                None => exec::parallel_f_relax_mut(w, Some(&g[..]), cf, workers, step),
            }
        } else {
            Self::f_relax(lvl, stepper, cf);
        }
    }

    /// Full FCF sweep (slab F-relax, C-relax with halo exchange, second
    /// F-relax — paper Fig. 2), threaded when [`Self::thread_level`] says
    /// it pays. In place on the shared level storage, like
    /// [`Self::f_relax_exec`].
    fn fcf_relax_exec<S: LevelStepper>(
        lvl: &mut Level,
        stepper: &S,
        cf: usize,
        workers: usize,
        pool: Option<&WorkerPool>,
    ) {
        if Self::thread_level(lvl, cf, workers) {
            let stride = lvl.stride;
            let step = |idx: usize, z: &Tensor, out: &mut Tensor| {
                stepper.apply_into(idx * stride, stride, z, out)
            };
            let Level { w, g, .. } = lvl;
            match pool {
                Some(p) => exec::pool_fc_relax_mut(p, w, Some(&g[..]), cf, step),
                None => exec::parallel_fc_relax_mut(w, Some(&g[..]), cf, workers, step),
            }
        } else {
            Self::f_relax(lvl, stepper, cf);
            Self::c_relax(lvl, stepper, cf);
            Self::f_relax(lvl, stepper, cf);
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn vcycle<S: LevelStepper>(
        levels: &mut [Level],
        stepper: &S,
        cf: usize,
        fcf: bool,
        workers: usize,
        pool: Option<&WorkerPool>,
        tmp_pred: &mut Tensor,
        tmp_r: &mut Tensor,
    ) {
        let (fine, coarser) = levels.split_first_mut().expect("at least one level");

        if coarser.is_empty() {
            // Coarsest level: exact serial solve W_n = Φ(W_{n-1}) + G_n.
            fine.w[0].copy_from(&fine.g[0]);
            for i in 1..=fine.n {
                Self::relax_into(fine, stepper, i - 1);
            }
            return;
        }
        let coarse = &mut coarser[0];

        // 1. relaxation (F or FCF)
        if fcf {
            Self::fcf_relax_exec(fine, stepper, cf, workers, pool);
        } else {
            Self::f_relax_exec(fine, stepper, cf, workers, pool);
        }

        // 2. FAS restriction: W_c = R W (injection); G_c = A_c(W_c) + R r.
        let nc = coarse.n;
        for k in 0..=nc {
            coarse.w[k].copy_from(&fine.w[k * cf]);
            coarse.w_init[k].copy_from(&coarse.w[k]);
        }
        {
            let (g0, w0) = (&mut coarse.g[0], &coarse.w[0]);
            g0.copy_from(w0);
        }
        for k in 1..=nc {
            let fine_idx = k * cf;
            // fine residual at the C-point: r = g - w + Φ_f(w_{prev})
            stepper.apply_into(
                (fine_idx - 1) * fine.stride,
                fine.stride,
                &fine.w[fine_idx - 1],
                tmp_pred,
            );
            tmp_r.copy_from(&fine.g[fine_idx]);
            tmp_r.axpy(-1.0, &fine.w[fine_idx]);
            tmp_r.axpy(1.0, tmp_pred);
            // τ-corrected coarse RHS: A_c(W_c)_k + r
            stepper.apply_into((k - 1) * coarse.stride, coarse.stride, &coarse.w[k - 1], tmp_pred);
            let gk = &mut coarse.g[k];
            gk.copy_from(&coarse.w[k]);
            gk.axpy(-1.0, tmp_pred);
            gk.axpy(1.0, tmp_r);
        }

        // 3. coarse solve (recursive)
        Self::vcycle(coarser, stepper, cf, fcf, workers, pool, tmp_pred, tmp_r);

        // 4. FAS correction at C-points + final F-relax to spread it
        let coarse = &coarser[0];
        for k in 1..=nc {
            tmp_r.copy_from(&coarse.w[k]);
            tmp_r.axpy(-1.0, &coarse.w_init[k]);
            fine.w[k * cf].axpy(1.0, tmp_r);
        }
        Self::f_relax_exec(fine, stepper, cf, workers, pool);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::{LinearOde, Propagator};
    use crate::util::rng::Rng;

    /// Forward stepper over a Propagator (duplicated from solver.rs to keep
    /// the core testable standalone). Uses the trait's default
    /// `apply_into` — the in-place engine must work with allocating
    /// steppers too.
    struct Fwd<'a, P: Propagator>(&'a P);

    impl<'a, P: Propagator> LevelStepper for Fwd<'a, P> {
        fn n(&self) -> usize {
            self.0.n_steps()
        }

        fn apply(&self, fine_idx: usize, stride: usize, z: &Tensor) -> Tensor {
            self.0.step(fine_idx, stride as f32, z)
        }
    }

    fn setup(n: usize, seed: u64) -> (LinearOde, Tensor) {
        let mut rng = Rng::new(seed);
        let ode = LinearOde::random_stable(&mut rng, 6, n, 0.05);
        let z0 = Tensor::randn(&mut rng, &[6, 1], 1.0);
        (ode, z0)
    }

    #[test]
    fn serial_solve_matches_trajectory() {
        let (ode, z0) = setup(16, 0);
        let mut core = MgritCore::new(16, 4, 2, true, &z0);
        let w = core.serial_solve(&Fwd(&ode), &z0).to_vec();
        let traj = ode.serial_trajectory(&z0);
        for (a, b) in w.iter().zip(&traj) {
            assert!(a.allclose(b, 1e-6, 1e-6));
        }
    }

    #[test]
    fn mgrit_converges_to_serial_solution() {
        let (ode, z0) = setup(32, 1);
        let traj = ode.serial_trajectory(&z0);
        let mut core = MgritCore::new(32, 4, 2, true, &z0);
        let stats = core.solve(&Fwd(&ode), &z0, None, 8, true);
        // residual decays monotonically and substantially
        assert!(stats.residuals.last().unwrap() < &1e-5, "{:?}", stats.residuals);
        for (w, t) in core.solution().iter().zip(&traj) {
            assert!(w.allclose(t, 1e-4, 1e-4), "diff {}", w.max_abs_diff(t));
        }
    }

    #[test]
    fn mgrit_is_exact_after_enough_iterations() {
        // FCF-MGRIT is a direct method after ~N/(2 c_f) cycles.
        let (ode, z0) = setup(16, 2);
        let traj = ode.serial_trajectory(&z0);
        let mut core = MgritCore::new(16, 2, 2, true, &z0);
        core.solve(&Fwd(&ode), &z0, None, 8, false);
        for (w, t) in core.solution().iter().zip(&traj) {
            assert!(w.allclose(t, 1e-5, 1e-5));
        }
    }

    #[test]
    fn three_level_hierarchy_converges() {
        let (ode, z0) = setup(64, 3);
        let traj = ode.serial_trajectory(&z0);
        let mut core = MgritCore::new(64, 4, 3, true, &z0);
        assert_eq!(core.n_levels(), 3);
        let stats = core.solve(&Fwd(&ode), &z0, None, 10, true);
        assert!(stats.residuals.last().unwrap() < &1e-4, "{:?}", stats.residuals);
        let end = core.solution().last().unwrap();
        assert!(end.allclose(traj.last().unwrap(), 1e-3, 1e-3));
    }

    #[test]
    fn f_relaxation_only_also_converges_but_slower() {
        let (ode, z0) = setup(32, 4);
        let mut fcf = MgritCore::new(32, 4, 2, true, &z0);
        let s_fcf = fcf.solve(&Fwd(&ode), &z0, None, 4, true);
        let mut fonly = MgritCore::new(32, 4, 2, false, &z0);
        let s_f = fonly.solve(&Fwd(&ode), &z0, None, 4, true);
        assert!(
            s_fcf.residuals.last().unwrap() <= s_f.residuals.last().unwrap(),
            "FCF {:?} vs F {:?}",
            s_fcf.residuals,
            s_f.residuals
        );
    }

    #[test]
    fn warm_start_reduces_initial_residual() {
        let (ode, z0) = setup(32, 5);
        let mut core = MgritCore::new(32, 4, 2, true, &z0);
        core.solve(&Fwd(&ode), &z0, None, 1, true);
        let cold_w: Vec<Tensor> = core.solution().to_vec();
        let s_cold = core.solve(&Fwd(&ode), &z0, None, 1, true);
        let s_warm = core.solve(&Fwd(&ode), &z0, Some(&cold_w), 1, true);
        assert!(s_warm.residuals[0] <= s_cold.residuals[0] * 1.01);
    }

    #[test]
    fn fmg_init_beats_cold_start() {
        // nested-iteration initial guess (Cyr et al. 2019) must reduce the
        // first-cycle residual vs initializing every point with z0
        let (ode, z0) = setup(64, 7);
        let mut cold = MgritCore::new(64, 4, 3, true, &z0);
        let s_cold = cold.solve(&Fwd(&ode), &z0, None, 1, true);
        let mut fmg = MgritCore::new(64, 4, 3, true, &z0);
        let s_fmg = fmg.solve_fmg(&Fwd(&ode), &z0, 1, true);
        assert!(
            s_fmg.residuals[0] < s_cold.residuals[0],
            "fmg {} vs cold {}",
            s_fmg.residuals[0],
            s_cold.residuals[0]
        );
    }

    #[test]
    fn fmg_solution_converges_to_serial() {
        let (ode, z0) = setup(32, 8);
        let traj = ode.serial_trajectory(&z0);
        let mut core = MgritCore::new(32, 4, 2, true, &z0);
        core.solve_fmg(&Fwd(&ode), &z0, 4, false);
        for (w, t) in core.solution().iter().zip(&traj) {
            assert!(w.allclose(t, 1e-4, 1e-4));
        }
    }

    #[test]
    fn threaded_vcycles_match_single_thread_bitwise() {
        // the ThreadedMgrit guarantee at core level: identical iterates,
        // bit for bit, for any worker count — scoped spawns AND the
        // persistent pool
        let (ode, z0) = setup(32, 9);
        let mut a = MgritCore::new(32, 4, 2, true, &z0);
        a.solve(&Fwd(&ode), &z0, None, 3, false);
        for workers in [2usize, 4, 7] {
            let mut b = MgritCore::new(32, 4, 2, true, &z0).with_workers(workers);
            b.solve(&Fwd(&ode), &z0, None, 3, false);
            for (x, y) in a.solution().iter().zip(b.solution()) {
                assert_eq!(x.data(), y.data(), "workers={}", workers);
            }
            let pool = Arc::new(WorkerPool::new(workers));
            let mut c = MgritCore::new(32, 4, 2, true, &z0).with_pool(pool);
            c.solve(&Fwd(&ode), &z0, None, 3, false);
            for (x, y) in a.solution().iter().zip(c.solution()) {
                assert_eq!(x.data(), y.data(), "pooled workers={}", workers);
            }
        }
        // F-only relaxation path too
        let mut a = MgritCore::new(32, 4, 2, false, &z0);
        a.solve(&Fwd(&ode), &z0, None, 3, false);
        let mut b = MgritCore::new(32, 4, 2, false, &z0).with_workers(3);
        b.solve(&Fwd(&ode), &z0, None, 3, false);
        for (x, y) in a.solution().iter().zip(b.solution()) {
            assert_eq!(x.data(), y.data());
        }
        let mut c = MgritCore::new(32, 4, 2, false, &z0).with_pool(Arc::new(WorkerPool::new(3)));
        c.solve(&Fwd(&ode), &z0, None, 3, false);
        for (x, y) in a.solution().iter().zip(c.solution()) {
            assert_eq!(x.data(), y.data());
        }
    }

    #[test]
    fn single_level_grid_serial_solves() {
        // N not divisible by cf -> hierarchy clamps to 1 level; solve() must
        // then behave like a serial solve per cycle.
        let (ode, z0) = setup(10, 6);
        let mut core = MgritCore::new(10, 4, 2, true, &z0);
        assert_eq!(core.n_levels(), 1);
        core.solve(&Fwd(&ode), &z0, None, 1, false);
        let traj = ode.serial_trajectory(&z0);
        for (w, t) in core.solution().iter().zip(&traj) {
            assert!(w.allclose(t, 1e-5, 1e-5));
        }
    }
}
