//! Forward and adjoint MGRIT solvers over a [`Propagator`] (paper §3.2.1-2).
//!
//! The forward solve integrates the neural ODE (inexactly, in parallel-ready
//! form); the adjoint solve runs the *same* FAS core over the transposed
//! Jacobian in reversed time coordinates; gradients are then assembled on
//! the fine grid from (states, adjoints).

use std::sync::Arc;

use crate::config::MgritConfig;
use crate::ode::Propagator;
use crate::parallel::WorkerPool;
use crate::tensor::Tensor;

use super::core::{LevelStepper, MgritCore};

/// Per-solve statistics: residual history and the paper's convergence
/// factor ρ = ‖r^(k+1)‖ / ‖r^(k)‖ (§3.2.3 indicator input).
#[derive(Debug, Clone, Default)]
pub struct SolveStats {
    pub iterations: usize,
    pub residuals: Vec<f64>,
    pub phi_evals: u64,
    pub serial: bool,
}

impl SolveStats {
    /// Convergence factor of the final iteration (None for serial or <2 samples).
    pub fn conv_factor(&self) -> Option<f64> {
        let n = self.residuals.len();
        if n < 2 {
            return None;
        }
        let prev = self.residuals[n - 2];
        if prev <= 1e-300 {
            return Some(0.0);
        }
        Some(self.residuals[n - 1] / prev)
    }
}

struct FwdStepper<'a, P: Propagator + ?Sized>(&'a P);

impl<'a, P: Propagator + ?Sized> LevelStepper for FwdStepper<'a, P> {
    fn n(&self) -> usize {
        self.0.n_steps()
    }

    fn apply(&self, fine_idx: usize, stride: usize, z: &Tensor) -> Tensor {
        self.0.step(fine_idx, stride as f32, z)
    }

    fn apply_into(&self, fine_idx: usize, stride: usize, z: &Tensor, out: &mut Tensor) {
        // buffer-reusing dispatch: the MGRIT sweeps update grid points in
        // place through the propagator's zero-allocation path
        self.0.step_into(fine_idx, stride as f32, z, out)
    }
}

/// Adjoint problem in reversed coordinates: Λ_j := λ_{N−j}. One step of
/// size `stride` from j advances Λ_{j+stride} = Φ'(z_{N−j−stride})ᵀ Λ_j,
/// i.e. the transposed Jacobian evaluated at the *frozen* primal state
/// (paper §3.2.2: the adjoint solve reuses stored forward states).
struct AdjStepper<'a, P: Propagator + ?Sized> {
    prop: &'a P,
    states: &'a [Tensor],
}

impl<'a, P: Propagator + ?Sized> LevelStepper for AdjStepper<'a, P> {
    fn n(&self) -> usize {
        self.prop.n_steps()
    }

    fn apply(&self, fine_idx: usize, stride: usize, lam: &Tensor) -> Tensor {
        let n = self.prop.n_steps();
        let layer = n - fine_idx - stride;
        self.prop.adjoint_step(layer, stride as f32, &self.states[layer], lam)
    }

    fn apply_into(&self, fine_idx: usize, stride: usize, lam: &Tensor, out: &mut Tensor) {
        let n = self.prop.n_steps();
        let layer = n - fine_idx - stride;
        self.prop.adjoint_step_into(layer, stride as f32, &self.states[layer], lam, out)
    }
}

/// High-level MGRIT driver bound to one propagator + one configuration.
pub struct MgritSolver<'a, P: Propagator + ?Sized> {
    prop: &'a P,
    pub cfg: MgritConfig,
    /// Relaxation worker threads (1 = single-threaded; >1 routes every
    /// relaxation sweep — forward *and* adjoint — through the slab
    /// executor in `parallel::exec`, bitwise identical results).
    workers: usize,
    /// Persistent relaxation workers (None = per-sweep scoped spawns).
    pool: Option<Arc<WorkerPool>>,
}

impl<'a, P: Propagator + ?Sized> MgritSolver<'a, P> {
    pub fn new(prop: &'a P, cfg: MgritConfig) -> Self {
        MgritSolver { prop, cfg, workers: 1, pool: None }
    }

    /// Multi-worker solver (the `ThreadedMgrit` backend's entry point).
    pub fn with_workers(prop: &'a P, cfg: MgritConfig, workers: usize) -> Self {
        MgritSolver { prop, cfg, workers: workers.max(1), pool: None }
    }

    /// Attach a persistent worker pool: relaxation sweeps run on its
    /// parked threads with `pool.size()` workers (bitwise identical to the
    /// scoped-spawn schedule for the same worker count). `None` is a no-op
    /// so backends can thread an optional pool straight through.
    pub fn pooled(mut self, pool: Option<Arc<WorkerPool>>) -> Self {
        if let Some(p) = pool {
            self.workers = p.size().max(1);
            self.pool = Some(p);
        }
        self
    }

    /// Worker threads this solver relaxes with.
    pub fn workers(&self) -> usize {
        self.workers
    }

    fn proto(&self) -> Tensor {
        Tensor::zeros(&self.prop.state_shape())
    }

    /// Build the preallocated FAS core for this solver's propagator, wired
    /// to its execution mode (workers and optional pool). Public since the
    /// persistent-context refactor: a [`crate::coordinator::SolveContext`]
    /// builds a core once per direction and then replays solves on it via
    /// [`MgritSolver::forward_with`] / [`MgritSolver::adjoint_with`].
    pub fn build_core(&self) -> MgritCore {
        let n = self.prop.n_steps();
        let core = MgritCore::new(n, self.cfg.cf, self.cfg.levels, self.cfg.fcf, &self.proto())
            .with_workers(self.workers);
        match &self.pool {
            Some(p) => core.with_pool(p.clone()),
            None => core,
        }
    }

    /// Forward propagation (paper §3.2.1).
    ///
    /// * `iters = None` → exact serial propagation (the baseline / the
    ///   "switch to serial" mode of §3.2.3);
    /// * `iters = Some(k)` → k MGRIT V-cycles; `warm` optionally seeds the
    ///   iterate with the previous batch's states.
    ///
    /// One-shot convenience: builds a fresh core and moves the solution
    /// out. The steady-state training path keeps a cached core instead and
    /// calls [`MgritSolver::forward_with`].
    pub fn forward(
        &self,
        z0: &Tensor,
        iters: Option<usize>,
        warm: Option<&[Tensor]>,
        track_residuals: bool,
    ) -> (Vec<Tensor>, SolveStats) {
        let mut core = self.build_core();
        let stats = self.forward_with(&mut core, z0, iters, warm, track_residuals);
        (core.into_solution(), stats)
    }

    /// Forward solve on a caller-owned core (cached across solves by the
    /// per-`Session` solve context). The solution stays in the core; hand
    /// it off with [`MgritCore::solution_into`] / `solution()`.
    pub fn forward_with(
        &self,
        core: &mut MgritCore,
        z0: &Tensor,
        iters: Option<usize>,
        warm: Option<&[Tensor]>,
        track_residuals: bool,
    ) -> SolveStats {
        assert_eq!(core.n_fine_steps(), self.prop.n_steps(), "core/propagator size mismatch");
        let stepper = FwdStepper(self.prop);
        let before = self.prop.counters().fwd();
        match iters {
            None => {
                core.serial_solve(&stepper, z0);
                SolveStats {
                    iterations: 0,
                    residuals: vec![],
                    phi_evals: self.prop.counters().fwd() - before,
                    serial: true,
                }
            }
            Some(k) => {
                let s = core.solve(&stepper, z0, warm, k, track_residuals);
                SolveStats {
                    iterations: k,
                    residuals: s.residuals,
                    phi_evals: self.prop.counters().fwd() - before,
                    serial: false,
                }
            }
        }
    }

    /// Forward solve with multilevel (FMG / nested-iteration)
    /// initialization — Cyr, Günther & Schroder 2019, cited in the paper's
    /// §2: a serial solve of the coarsest rediscretization is interpolated
    /// down as the initial iterate, typically saving V-cycles over a cold
    /// start (see `mgrit::core::tests::fmg_init_beats_cold_start`).
    pub fn forward_fmg(
        &self,
        z0: &Tensor,
        iters: usize,
        track_residuals: bool,
    ) -> (Vec<Tensor>, SolveStats) {
        let stepper = FwdStepper(self.prop);
        let before = self.prop.counters().fwd();
        let mut core = self.build_core();
        let s = core.solve_fmg(&stepper, z0, iters, track_residuals);
        let stats = SolveStats {
            iterations: iters,
            residuals: s.residuals,
            phi_evals: self.prop.counters().fwd() - before,
            serial: false,
        };
        (core.into_solution(), stats)
    }

    /// Adjoint propagation (paper §3.2.2): solves the discretized adjoint
    /// equation backward over the frozen `states`, starting from the loss
    /// cotangent `ct` at t_N. Returns λ_0..λ_N (fine grid, natural order).
    pub fn adjoint(
        &self,
        states: &[Tensor],
        ct: &Tensor,
        iters: Option<usize>,
        track_residuals: bool,
    ) -> (Vec<Tensor>, SolveStats) {
        let mut core = self.build_core();
        let stats = self.adjoint_with(&mut core, states, ct, iters, track_residuals);
        // reverse back to natural ordering: λ_fine[n] = Λ[N − n]
        let mut lambdas = core.into_solution();
        lambdas.reverse();
        (lambdas, stats)
    }

    /// Adjoint solve on a caller-owned core. The solution stays in the
    /// core **in reversed time coordinates** (Λ_j = λ_{N−j}); hand it back
    /// on the natural grid with [`MgritCore::solution_rev_into`].
    pub fn adjoint_with(
        &self,
        core: &mut MgritCore,
        states: &[Tensor],
        ct: &Tensor,
        iters: Option<usize>,
        track_residuals: bool,
    ) -> SolveStats {
        let n = self.prop.n_steps();
        assert_eq!(states.len(), n + 1, "need all fine states for the adjoint");
        assert_eq!(core.n_fine_steps(), n, "core/propagator size mismatch");
        let stepper = AdjStepper { prop: self.prop, states };
        let before = self.prop.counters().vjp();
        match iters {
            None => {
                core.serial_solve(&stepper, ct);
                SolveStats {
                    iterations: 0,
                    residuals: vec![],
                    phi_evals: self.prop.counters().vjp() - before,
                    serial: true,
                }
            }
            Some(k) => {
                let s = core.solve(&stepper, ct, None, k, track_residuals);
                SolveStats {
                    iterations: k,
                    residuals: s.residuals,
                    phi_evals: self.prop.counters().vjp() - before,
                    serial: false,
                }
            }
        }
    }

    /// Assemble per-layer parameter gradients on the fine grid:
    /// g_n = ∂(λ_{n+1}ᵀ Φ(Z_n; θ_n))/∂θ_n.
    pub fn gradients(&self, states: &[Tensor], lambdas: &[Tensor]) -> Vec<Vec<f32>> {
        let n = self.prop.n_steps();
        let mut grads: Vec<Vec<f32>> =
            (0..n).map(|layer| vec![0.0f32; self.prop.theta_len(layer)]).collect();
        self.gradients_into(states, lambdas, &mut grads);
        grads
    }

    /// Accumulate per-layer parameter gradients into caller-owned buffers
    /// (`grads[l]` must have `theta_len(l)` elements; contributions are
    /// **added**, so zero the buffers once per optimizer step).
    pub fn gradients_into(&self, states: &[Tensor], lambdas: &[Tensor], grads: &mut [Vec<f32>]) {
        assert_eq!(grads.len(), self.prop.n_steps(), "need one gradient buffer per layer");
        accumulate_layer_grads(self.prop, states, lambdas, grads, 0);
    }
}

/// The one gradient-assembly loop every caller shares:
/// g_l += ∂(λ_{l+1}ᵀ Φ(Z_l; θ_l))/∂θ_l for each of `prop`'s layers,
/// offset by `at` into the caller's fine-grid slices (contributions are
/// added — zero the buffers once per optimizer step). Used by
/// [`MgritSolver::gradients_into`] and the per-`Session`
/// [`crate::coordinator::SolveContext`] so gradient conventions cannot
/// silently diverge between the solver-level and context-level paths.
pub fn accumulate_layer_grads<P: Propagator + ?Sized>(
    prop: &P,
    states: &[Tensor],
    lams: &[Tensor],
    grads: &mut [Vec<f32>],
    at: usize,
) {
    for l in 0..prop.n_steps() {
        prop.accumulate_grad(l, &states[at + l], &lams[at + l + 1], &mut grads[at + l]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MgritConfig;
    use crate::ode::LinearOde;
    use crate::util::rng::Rng;

    fn cfg(cf: usize, levels: usize) -> MgritConfig {
        MgritConfig { cf, levels, fwd_iters: Some(2), bwd_iters: Some(1), fcf: true }
    }

    #[test]
    fn forward_serial_equals_trajectory() {
        let mut rng = Rng::new(0);
        let ode = LinearOde::random_stable(&mut rng, 5, 16, 0.1);
        let z0 = Tensor::randn(&mut rng, &[5, 1], 1.0);
        let solver = MgritSolver::new(&ode, cfg(4, 2));
        let (w, stats) = solver.forward(&z0, None, None, false);
        assert!(stats.serial);
        let traj = ode.serial_trajectory(&z0);
        for (a, b) in w.iter().zip(&traj) {
            assert!(a.allclose(b, 1e-6, 1e-6));
        }
    }

    #[test]
    fn forward_mgrit_converges_with_stats() {
        let mut rng = Rng::new(1);
        let ode = LinearOde::random_stable(&mut rng, 5, 32, 0.05);
        let z0 = Tensor::randn(&mut rng, &[5, 1], 1.0);
        let solver = MgritSolver::new(&ode, cfg(4, 2));
        let (w, stats) = solver.forward(&z0, Some(6), None, true);
        assert_eq!(stats.iterations, 6);
        assert_eq!(stats.residuals.len(), 6);
        assert!(stats.conv_factor().unwrap() < 1.0);
        let traj = ode.serial_trajectory(&z0);
        assert!(w.last().unwrap().allclose(traj.last().unwrap(), 1e-4, 1e-4));
        assert!(stats.phi_evals > 0);
    }

    /// The adjoint MGRIT solve must reproduce exact backprop: for the
    /// linear ODE, λ_0 = (∏ (I+hA))ᵀ ct.
    #[test]
    fn adjoint_matches_serial_backprop() {
        let mut rng = Rng::new(2);
        let ode = LinearOde::random_stable(&mut rng, 5, 16, 0.1);
        let z0 = Tensor::randn(&mut rng, &[5, 1], 1.0);
        let ct = Tensor::randn(&mut rng, &[5, 1], 1.0);
        let solver = MgritSolver::new(&ode, cfg(4, 2));
        let (states, _) = solver.forward(&z0, None, None, false);
        let (lam_serial, st) = solver.adjoint(&states, &ct, None, false);
        assert!(st.serial);
        // exact serial backprop by hand
        let mut lam = ct.clone();
        let mut expect = vec![lam.clone()];
        for nidx in (0..16).rev() {
            lam = ode.adjoint_step(nidx, 1.0, &states[nidx], &lam);
            expect.push(lam.clone());
        }
        expect.reverse();
        for (a, b) in lam_serial.iter().zip(&expect) {
            assert!(a.allclose(b, 1e-5, 1e-5));
        }
        // MGRIT adjoint converges to the same λ
        let (lam_mg, st) = solver.adjoint(&states, &ct, Some(6), true);
        assert!(st.residuals.last().unwrap() < &1e-5);
        for (a, b) in lam_mg.iter().zip(&expect) {
            assert!(a.allclose(b, 1e-4, 1e-4), "diff {}", a.max_abs_diff(b));
        }
    }

    #[test]
    fn threaded_solver_is_bitwise_identical_forward_and_adjoint() {
        let mut rng = Rng::new(5);
        let ode = LinearOde::random_stable(&mut rng, 5, 32, 0.05);
        let z0 = Tensor::randn(&mut rng, &[5, 1], 1.0);
        let ct = Tensor::randn(&mut rng, &[5, 1], 1.0);
        let single = MgritSolver::new(&ode, cfg(4, 2));
        let (w1, _) = single.forward(&z0, Some(3), None, false);
        let (l1, _) = single.adjoint(&w1, &ct, Some(2), false);
        for workers in [2usize, 4] {
            let multi = MgritSolver::with_workers(&ode, cfg(4, 2), workers);
            let (w2, _) = multi.forward(&z0, Some(3), None, false);
            for (a, b) in w1.iter().zip(&w2) {
                assert_eq!(a.data(), b.data(), "fwd workers={}", workers);
            }
            let (l2, _) = multi.adjoint(&w2, &ct, Some(2), false);
            for (a, b) in l1.iter().zip(&l2) {
                assert_eq!(a.data(), b.data(), "adj workers={}", workers);
            }
        }
    }

    #[test]
    fn pooled_solver_is_bitwise_identical_to_scoped_spawns() {
        // the persistent-pool guarantee at solver level, forward + adjoint
        let mut rng = Rng::new(6);
        let ode = LinearOde::random_stable(&mut rng, 5, 32, 0.05);
        let z0 = Tensor::randn(&mut rng, &[5, 1], 1.0);
        let ct = Tensor::randn(&mut rng, &[5, 1], 1.0);
        for workers in [1usize, 2, 4] {
            let scoped = MgritSolver::with_workers(&ode, cfg(4, 2), workers);
            let (w1, _) = scoped.forward(&z0, Some(3), None, false);
            let (l1, _) = scoped.adjoint(&w1, &ct, Some(2), false);
            let pool = Arc::new(WorkerPool::new(workers));
            let pooled = MgritSolver::new(&ode, cfg(4, 2)).pooled(Some(pool));
            let (w2, _) = pooled.forward(&z0, Some(3), None, false);
            for (a, b) in w1.iter().zip(&w2) {
                assert_eq!(a.data(), b.data(), "fwd workers={}", workers);
            }
            let (l2, _) = pooled.adjoint(&w2, &ct, Some(2), false);
            for (a, b) in l1.iter().zip(&l2) {
                assert_eq!(a.data(), b.data(), "adj workers={}", workers);
            }
        }
    }

    #[test]
    fn one_adjoint_iteration_is_already_close() {
        // Paper §3.2.2: a single backward MGRIT iteration is typically
        // enough — verify it lands within a few percent for the stable ODE.
        let mut rng = Rng::new(3);
        let ode = LinearOde::random_stable(&mut rng, 5, 32, 0.05);
        let z0 = Tensor::randn(&mut rng, &[5, 1], 1.0);
        let ct = Tensor::randn(&mut rng, &[5, 1], 1.0);
        let solver = MgritSolver::new(&ode, cfg(4, 2));
        let (states, _) = solver.forward(&z0, None, None, false);
        let (exact, _) = solver.adjoint(&states, &ct, None, false);
        let (approx, _) = solver.adjoint(&states, &ct, Some(1), false);
        let num: f32 = approx[0].dist(&exact[0]);
        let den: f32 = exact[0].norm().max(1e-9);
        assert!(num / den < 0.2, "relative λ_0 error {}", num / den);
        // and a second iteration improves it further
        let (approx2, _) = solver.adjoint(&states, &ct, Some(2), false);
        assert!(approx2[0].dist(&exact[0]) < num);
    }
}
