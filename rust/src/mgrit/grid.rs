//! Time-grid hierarchy geometry.
//!
//! Level ℓ has N_ℓ = N / c_f^ℓ steps (points 0..=N_ℓ); level-ℓ point i sits
//! at fine index i · c_f^ℓ. The effective number of levels is clamped so
//! every level divides evenly and the coarsest level keeps at least one
//! step (the paper's L ∈ {2, 3} configurations always satisfy this).

/// Geometry of the MGRIT level hierarchy.
#[derive(Debug, Clone)]
pub struct GridHierarchy {
    pub cf: usize,
    /// Per-level step counts N_ℓ (levels[0] = fine N).
    pub steps: Vec<usize>,
}

impl GridHierarchy {
    /// Build for N fine steps, coarsening factor cf, at most `max_levels`.
    pub fn new(n: usize, cf: usize, max_levels: usize) -> GridHierarchy {
        assert!(n >= 1, "need at least one time step");
        assert!(cf >= 2, "coarsening factor must be >= 2");
        let mut steps = vec![n];
        while steps.len() < max_levels {
            let cur = *steps.last().unwrap();
            if cur % cf != 0 || cur / cf < 1 {
                break;
            }
            steps.push(cur / cf);
        }
        GridHierarchy { cf, steps }
    }

    pub fn levels(&self) -> usize {
        self.steps.len()
    }

    /// Stride (in fine indices) of one step on level ℓ.
    pub fn stride(&self, level: usize) -> usize {
        self.cf.pow(level as u32)
    }

    /// Number of C-points (excluding t=0) on level ℓ, i.e. steps of ℓ+1.
    pub fn coarse_steps(&self, level: usize) -> usize {
        self.steps[level] / self.cf
    }

    /// Is level-ℓ index i a C-point?
    pub fn is_c_point(&self, i: usize) -> bool {
        i % self.cf == 0
    }

    /// Theoretical parallelism exposed by relaxation on level ℓ (paper §3.2:
    /// N_ℓ / c_f concurrent chunks).
    pub fn relax_parallelism(&self, level: usize) -> usize {
        (self.steps[level] / self.cf).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_paper_configs() {
        // BERT: 128 layers, cf=4, L=2
        let g = GridHierarchy::new(128, 4, 2);
        assert_eq!(g.steps, vec![128, 32]);
        // MC scaling: 1024 layers, cf=2, L=4
        let g = GridHierarchy::new(1024, 2, 4);
        assert_eq!(g.steps, vec![1024, 512, 256, 128]);
        // MT: 12 layers, cf=3, L=2
        let g = GridHierarchy::new(12, 3, 2);
        assert_eq!(g.steps, vec![12, 4]);
    }

    #[test]
    fn clamps_when_not_divisible() {
        let g = GridHierarchy::new(12, 8, 3);
        assert_eq!(g.steps, vec![12]); // 12 % 8 != 0 -> single level
        let g = GridHierarchy::new(16, 4, 5);
        assert_eq!(g.steps, vec![16, 4, 1]); // 1/4 < 1 stops descent
    }

    #[test]
    fn strides_and_cpoints() {
        let g = GridHierarchy::new(16, 4, 2);
        assert_eq!(g.stride(0), 1);
        assert_eq!(g.stride(1), 4);
        assert!(g.is_c_point(0) && g.is_c_point(8));
        assert!(!g.is_c_point(3));
        assert_eq!(g.coarse_steps(0), 4);
        assert_eq!(g.relax_parallelism(0), 4);
    }
}
