//! MGRIT (multigrid-reduction-in-time) over the layer dimension — the
//! paper's §3.2. Implemented as nonlinear FAS multigrid (Günther et al.
//! 2020 / TorchBraid lineage):
//!
//! * a hierarchy of time grids with coarsening factor c_f ([`grid`]);
//! * F-/C-/FCF-relaxation, injection restriction with τ-correction (FAS),
//!   coarse-grid solve, C-point correction + final F-relax ([`core`]);
//! * forward solver over Φ and adjoint solver over Φᵀ sharing the same
//!   core ([`solver`]), with residual tracking and the convergence factor
//!   ρ = ‖r^(k+1)‖/‖r^(k)‖ that drives the §3.2.3 indicator.

mod core;
mod grid;
mod solver;

pub use self::core::{LevelStepper, MgritCore};
pub use grid::GridHierarchy;
pub use solver::{accumulate_layer_grads, MgritSolver, SolveStats};
