//! Scalar/row math for the reference transformer: LayerNorm (fwd + bwd)
//! and tanh-approximate GELU (matching `jax.nn.gelu(approximate=True)`).
//!
//! The row-wise forward kernels (`layer_norm_fwd_*`, [`gelu_row`])
//! dispatch to the SIMD implementations in `crate::tensor::simd` when
//! `--features simd` is compiled in and the host supports it. Both are
//! reassociating kernels (ulp-bounded vs scalar, pinned by
//! `tests/simd_parity.rs`), but a row's output bits depend only on that
//! row's contents — never on the row count — which is what incremental
//! decode parity requires.

pub const LN_EPS: f32 = 1e-5;

/// GELU, tanh approximation: 0.5 x (1 + tanh(√(2/π)(x + 0.044715 x³))).
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.7978845608; // sqrt(2/pi)
    const A: f32 = 0.044715;
    0.5 * x * (1.0 + (C * (x + A * x * x * x)).tanh())
}

/// GELU applied to a row in place (dispatched: SIMD when active, the
/// scalar [`gelu`] loop otherwise).
pub fn gelu_row(row: &mut [f32]) {
    #[cfg(feature = "simd")]
    if crate::tensor::simd_active() {
        crate::tensor::simd::gelu_row(row);
        return;
    }
    for v in row.iter_mut() {
        *v = gelu(*v);
    }
}

/// d gelu / dx for the tanh approximation.
pub fn gelu_grad(x: f32) -> f32 {
    const C: f32 = 0.7978845608;
    const A: f32 = 0.044715;
    let u = C * (x + A * x * x * x);
    let t = u.tanh();
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * C * (1.0 + 3.0 * A * x * x)
}

/// One LayerNorm row: normalize `xr` into `or`, returning `(mu, inv)`.
/// Dispatched: SIMD when active, scalar otherwise. Both `layer_norm_fwd_*`
/// variants share this single row kernel so their output bits agree.
fn ln_row(xr: &[f32], g: &[f32], b: &[f32], or: &mut [f32]) -> (f32, f32) {
    #[cfg(feature = "simd")]
    if crate::tensor::simd_active() {
        return crate::tensor::simd::ln_row(xr, g, b, LN_EPS, or);
    }
    ln_row_scalar(xr, g, b, or)
}

fn ln_row_scalar(xr: &[f32], g: &[f32], b: &[f32], or: &mut [f32]) -> (f32, f32) {
    let d = xr.len();
    let mu = xr.iter().sum::<f32>() / d as f32;
    let var = xr.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
    let inv = 1.0 / (var + LN_EPS).sqrt();
    for i in 0..d {
        or[i] = (xr[i] - mu) * inv * g[i] + b[i];
    }
    (mu, inv)
}

/// LayerNorm forward over rows of length `d`, no stats capture (the hot
/// forward path — allocation-free).
pub fn layer_norm_fwd_into(x: &[f32], g: &[f32], b: &[f32], d: usize, out: &mut [f32]) {
    let rows = x.len() / d;
    for r in 0..rows {
        ln_row(&x[r * d..(r + 1) * d], g, b, &mut out[r * d..(r + 1) * d]);
    }
}

/// LayerNorm forward capturing per-row `(mu, inv_sigma)` into a reusable
/// buffer (cleared first) for the backward pass.
pub fn layer_norm_fwd_stats(
    x: &[f32],
    g: &[f32],
    b: &[f32],
    d: usize,
    out: &mut [f32],
    stats: &mut Vec<(f32, f32)>,
) {
    let rows = x.len() / d;
    stats.clear();
    stats.reserve(rows);
    for r in 0..rows {
        stats.push(ln_row(&x[r * d..(r + 1) * d], g, b, &mut out[r * d..(r + 1) * d]));
    }
}

/// LayerNorm forward over rows of length `d`.
///
/// Writes the normalized output into `out` and returns `(mu, inv_sigma)`
/// per row for the backward pass. Allocates the stats vector; hot paths
/// use [`layer_norm_fwd_into`] / [`layer_norm_fwd_stats`] instead.
pub fn layer_norm_fwd(
    x: &[f32],
    g: &[f32],
    b: &[f32],
    d: usize,
    out: &mut [f32],
) -> Vec<(f32, f32)> {
    let mut stats = Vec::new();
    layer_norm_fwd_stats(x, g, b, d, out, &mut stats);
    stats
}

/// LayerNorm backward.
///
/// Given upstream `dz` on the LN output, the original input `x`, and the
/// per-row `(mu, inv_sigma)` stats, accumulates `dx += …`, `dg += …`,
/// `db += …` (accumulation lets callers sum over a batch).
pub fn layer_norm_bwd(
    dz: &[f32],
    x: &[f32],
    g: &[f32],
    stats: &[(f32, f32)],
    d: usize,
    dx: &mut [f32],
    dg: &mut [f32],
    db: &mut [f32],
) {
    let rows = x.len() / d;
    for r in 0..rows {
        let (mu, inv) = stats[r];
        let xr = &x[r * d..(r + 1) * d];
        let dzr = &dz[r * d..(r + 1) * d];
        let dxr = &mut dx[r * d..(r + 1) * d];
        // y = (x - mu) * inv (normalized); dy = dz * g
        let mut mean_dy = 0.0f32;
        let mut mean_dy_y = 0.0f32;
        for i in 0..d {
            let y = (xr[i] - mu) * inv;
            let dy = dzr[i] * g[i];
            mean_dy += dy;
            mean_dy_y += dy * y;
            dg[i] += dzr[i] * y;
            db[i] += dzr[i];
        }
        mean_dy /= d as f32;
        mean_dy_y /= d as f32;
        for i in 0..d {
            let y = (xr[i] - mu) * inv;
            let dy = dzr[i] * g[i];
            dxr[i] += inv * (dy - mean_dy - y * mean_dy_y);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    #[test]
    fn gelu_known_values() {
        assert!((gelu(0.0)).abs() < 1e-7);
        assert!((gelu(100.0) - 100.0).abs() < 1e-3);
        assert!(gelu(-100.0).abs() < 1e-3);
        // symmetric-ish point: gelu(1) ≈ 0.8412
        assert!((gelu(1.0) - 0.8412).abs() < 1e-3);
    }

    #[test]
    fn prop_gelu_grad_matches_fd() {
        forall("gelu-grad-fd", 100, |rng| {
            let x = rng.normal() * 3.0;
            let eps = 1e-3f32;
            let fd = (gelu(x + eps) - gelu(x - eps)) / (2.0 * eps);
            assert!((gelu_grad(x) - fd).abs() < 1e-3, "x={} grad={} fd={}", x, gelu_grad(x), fd);
        });
    }

    #[test]
    fn ln_fwd_normalizes() {
        let d = 16;
        let mut rng = crate::util::rng::Rng::new(0);
        let x = rng.normal_vec(3 * d, 5.0);
        let g = vec![1.0; d];
        let b = vec![0.0; d];
        let mut out = vec![0.0; 3 * d];
        layer_norm_fwd(&x, &g, &b, d, &mut out);
        for r in 0..3 {
            let row = &out[r * d..(r + 1) * d];
            let mu = row.iter().sum::<f32>() / d as f32;
            let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
            assert!(mu.abs() < 1e-5);
            assert!((var - 1.0).abs() < 1e-2);
        }
    }

    #[test]
    fn prop_ln_bwd_matches_fd() {
        forall("ln-bwd-fd", 25, |rng| {
            let d = 2 + rng.range(8);
            let x = rng.normal_vec(d, 1.0);
            let g: Vec<f32> = (0..d).map(|_| 1.0 + 0.3 * rng.normal()).collect();
            let b = rng.normal_vec(d, 0.3);
            let dz = rng.normal_vec(d, 1.0);

            let mut out = vec![0.0; d];
            let stats = layer_norm_fwd(&x, &g, &b, d, &mut out);
            let mut dx = vec![0.0; d];
            let mut dg = vec![0.0; d];
            let mut db = vec![0.0; d];
            layer_norm_bwd(&dz, &x, &g, &stats, d, &mut dx, &mut dg, &mut db);

            // scalar objective: sum(dz * ln(x))
            let f = |xv: &[f32]| -> f32 {
                let mut o = vec![0.0; d];
                layer_norm_fwd(xv, &g, &b, d, &mut o);
                o.iter().zip(&dz).map(|(a, c)| a * c).sum()
            };
            let eps = 3e-3f32;
            for i in 0..d {
                let mut xp = x.clone();
                xp[i] += eps;
                let mut xm = x.clone();
                xm[i] -= eps;
                let fd = (f(&xp) - f(&xm)) / (2.0 * eps);
                assert!(
                    (dx[i] - fd).abs() < 5e-2 * (1.0 + fd.abs()),
                    "dx[{}]={} fd={}",
                    i,
                    dx[i],
                    fd
                );
            }
        });
    }

    #[test]
    fn ln_bwd_param_grads_match_fd() {
        let d = 6;
        let mut rng = crate::util::rng::Rng::new(7);
        let x = rng.normal_vec(d, 1.0);
        let g: Vec<f32> = (0..d).map(|_| 1.0 + 0.2 * rng.normal()).collect();
        let b = rng.normal_vec(d, 0.2);
        let dz = rng.normal_vec(d, 1.0);
        let mut out = vec![0.0; d];
        let stats = layer_norm_fwd(&x, &g, &b, d, &mut out);
        let (mut dx, mut dg, mut db) = (vec![0.0; d], vec![0.0; d], vec![0.0; d]);
        layer_norm_bwd(&dz, &x, &g, &stats, d, &mut dx, &mut dg, &mut db);
        let f = |gv: &[f32], bv: &[f32]| -> f32 {
            let mut o = vec![0.0; d];
            layer_norm_fwd(&x, gv, bv, d, &mut o);
            o.iter().zip(&dz).map(|(a, c)| a * c).sum()
        };
        let eps = 1e-3f32;
        for i in 0..d {
            let mut gp = g.clone();
            gp[i] += eps;
            let mut gm = g.clone();
            gm[i] -= eps;
            let fd = (f(&gp, &b) - f(&gm, &b)) / (2.0 * eps);
            assert!((dg[i] - fd).abs() < 1e-2, "dg[{}]={} fd={}", i, dg[i], fd);
            let mut bp = b.clone();
            bp[i] += eps;
            let mut bm = b.clone();
            bm[i] -= eps;
            let fdb = (f(&g, &bp) - f(&g, &bm)) / (2.0 * eps);
            assert!((db[i] - fdb).abs() < 1e-2);
        }
    }
}
