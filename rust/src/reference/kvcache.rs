//! Append-only per-layer K/V column store for incremental decode.
//!
//! During autoregressive decode the board grows one position per step, so
//! the only *new* attention work per layer is one query row. [`KvCache`]
//! keeps the already-projected key/value vectors of every previous
//! position so a cached step ([`super::enc_step_fwd_cached`] /
//! [`super::dec_step_fwd_cached`]) scores the single new query against
//! them and appends its own K/V column — O(1) projections per layer
//! instead of a full-board re-forward.
//!
//! Layout: keys and values live **pre-gathered per head**,
//! `[layers, batch, n_heads, seq_cap, head_dim]`, so the score kernel
//! streams one contiguous `[len, head_dim]` slab per (row, head) with no
//! gather pass. Encoder-decoder models additionally carry a cross-attention
//! store (`[layers, batch, n_heads, cross_cap, head_dim]`) holding the
//! φ3 keys/values projected from the frozen encoder output; it is primed
//! once at prefill and read-only afterwards.
//!
//! Rows are batch slots and stay fully independent: `reset_row` forgets
//! exactly one slot's columns (serve cold-join / retirement) without
//! touching its neighbours, which is what keeps cached serve decode
//! bitwise independent of occupancy, slot index, and join time. All
//! storage is allocated once in [`KvCache::new`]; reset and append are
//! allocation-free.

/// Mutable per-layer view into the cache: self-attention K/V slabs
/// (`[batch, n_heads, seq_cap, head_dim]`), the cross-attention slabs
/// (empty when the model has no cross attention), and the per-row
/// valid-column counts.
pub struct LayerKv<'a> {
    pub k: &'a mut [f32],
    pub v: &'a mut [f32],
    pub ck: &'a mut [f32],
    pub cv: &'a mut [f32],
    pub lens: &'a [usize],
}

/// Append-only K/V cache over the cached layer range of one model.
#[derive(Debug, Clone)]
pub struct KvCache {
    n_layers: usize,
    layer0: usize,
    batch: usize,
    n_heads: usize,
    head_dim: usize,
    seq_cap: usize,
    cross_cap: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    ck: Vec<f32>,
    cv: Vec<f32>,
    len: Vec<usize>,
    cross_primed: bool,
}

impl KvCache {
    /// Allocate a cache for `n_layers` cached layers starting at global
    /// layer index `layer0`. `cross_cap = 0` means no cross-attention
    /// store (decoder-only models).
    pub fn new(
        n_layers: usize,
        layer0: usize,
        batch: usize,
        n_heads: usize,
        head_dim: usize,
        seq_cap: usize,
        cross_cap: usize,
    ) -> KvCache {
        let n = n_layers * batch * n_heads * seq_cap * head_dim;
        let nc = n_layers * batch * n_heads * cross_cap * head_dim;
        KvCache {
            n_layers,
            layer0,
            batch,
            n_heads,
            head_dim,
            seq_cap,
            cross_cap,
            k: vec![0.0; n],
            v: vec![0.0; n],
            ck: vec![0.0; nc],
            cv: vec![0.0; nc],
            len: vec![0; batch],
            cross_primed: false,
        }
    }

    /// Global layer index of cached layer 0.
    pub fn layer0(&self) -> usize {
        self.layer0
    }

    /// Number of cached layers.
    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn n_heads(&self) -> usize {
        self.n_heads
    }

    pub fn head_dim(&self) -> usize {
        self.head_dim
    }

    /// Self-attention column capacity (the model window).
    pub fn seq_cap(&self) -> usize {
        self.seq_cap
    }

    /// Cross-attention column count (0 = decoder-only, no φ3 store).
    pub fn cross_cap(&self) -> usize {
        self.cross_cap
    }

    /// Whether the cross-attention store holds valid encoder projections.
    pub fn cross_primed(&self) -> bool {
        self.cross_primed
    }

    pub fn set_cross_primed(&mut self, primed: bool) {
        self.cross_primed = primed;
    }

    /// Valid self-attention columns for batch row `r`.
    pub fn len(&self, r: usize) -> usize {
        self.len[r]
    }

    /// Per-row valid-column counts.
    pub fn lens(&self) -> &[usize] {
        &self.len
    }

    /// Forget row `r`'s columns (serve cold-join injection / retirement).
    /// Storage is retained; neighbouring rows are untouched.
    pub fn reset_row(&mut self, r: usize) {
        self.len[r] = 0;
    }

    /// Forget every row and the cross store (weight swap, new decode).
    pub fn reset_all(&mut self) {
        self.len.iter_mut().for_each(|l| *l = 0);
        self.cross_primed = false;
    }

    /// Mark columns `0..=positions[r]` valid for every row — called once
    /// per decode step, after all layers have appended at `positions[r]`.
    pub fn commit(&mut self, positions: &[usize]) {
        debug_assert_eq!(positions.len(), self.batch);
        for (l, &p) in self.len.iter_mut().zip(positions) {
            debug_assert!(p < self.seq_cap);
            *l = p + 1;
        }
    }

    /// Split-borrow the slabs of cached layer `li` (local index).
    pub fn layer_mut(&mut self, li: usize) -> LayerKv<'_> {
        debug_assert!(li < self.n_layers);
        let per = self.batch * self.n_heads * self.seq_cap * self.head_dim;
        let cper = self.batch * self.n_heads * self.cross_cap * self.head_dim;
        LayerKv {
            k: &mut self.k[li * per..(li + 1) * per],
            v: &mut self.v[li * per..(li + 1) * per],
            ck: &mut self.ck[li * cper..(li + 1) * cper],
            cv: &mut self.cv[li * cper..(li + 1) * cper],
            lens: &self.len,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_rows_commit_and_reset_independently() {
        let mut c = KvCache::new(3, 1, 4, 2, 8, 16, 0);
        assert_eq!(c.layer0(), 1);
        assert_eq!(c.n_layers(), 3);
        assert_eq!(c.cross_cap(), 0);
        assert!(c.lens().iter().all(|&l| l == 0));

        c.commit(&[0, 3, 1, 0]);
        assert_eq!(c.len(0), 1);
        assert_eq!(c.len(1), 4);
        c.reset_row(1);
        assert_eq!(c.len(1), 0, "reset forgets exactly one row");
        assert_eq!(c.len(2), 2, "neighbour rows untouched");

        c.reset_all();
        assert!(c.lens().iter().all(|&l| l == 0));
    }

    #[test]
    fn layer_views_are_disjoint_slabs() {
        let mut c = KvCache::new(2, 0, 1, 1, 4, 3, 5);
        {
            let l0 = c.layer_mut(0);
            assert_eq!(l0.k.len(), 12);
            assert_eq!(l0.ck.len(), 20);
            l0.k.fill(1.0);
            l0.ck.fill(2.0);
        }
        let l1 = c.layer_mut(1);
        assert!(l1.k.iter().all(|&x| x == 0.0), "layer 1 untouched by layer 0 writes");
        assert!(l1.ck.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn cross_priming_flag_follows_reset() {
        let mut c = KvCache::new(1, 0, 1, 1, 2, 2, 2);
        assert!(!c.cross_primed());
        c.set_cross_primed(true);
        assert!(c.cross_primed());
        c.reset_all();
        assert!(!c.cross_primed(), "reset_all invalidates the cross store");
    }
}
