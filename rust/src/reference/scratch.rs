//! Reusable workspace for the reference-transformer kernels.
//!
//! Every Φ forward/backward needs a handful of temporaries (projections,
//! attention scores, FFN activations, adjoint partials). Allocating them
//! per call dominated the pre-optimization profile, so [`Scratch`] keeps a
//! LIFO pool of buffers (plus a small pool of LayerNorm stat vectors):
//! `take(len)` pops a buffer, zero-fills it to `len`, and hands it out;
//! `give` returns it. Because every Φ application requests the same buffer
//! lengths in the same order, capacities stabilize after the first couple
//! of calls and the steady state performs **zero heap allocations**
//! (pinned by `rust/tests/alloc_audit.rs`).
//!
//! Buffers are [`AlignedVec`]s — 32-byte-aligned backing stores so the
//! SIMD kernels' eight-lane loads from buffer starts never split a cache
//! line. `AlignedVec` derefs to `&[f32]` / `&mut [f32]`, so kernel call
//! sites are unchanged.
//!
//! A `Scratch` is *not* shared across threads — each relaxation worker
//! checks one out of the propagator's pool (see
//! [`crate::ode::RustPropagator`]).

use crate::tensor::AlignedVec;

/// LIFO buffer pool for the Φ hot path.
#[derive(Debug, Default)]
pub struct Scratch {
    bufs: Vec<AlignedVec>,
    stats: Vec<Vec<(f32, f32)>>,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// Check out a zero-filled buffer of exactly `len` elements (for
    /// accumulation targets).
    pub fn take(&mut self, len: usize) -> AlignedVec {
        let mut v = self.bufs.pop().unwrap_or_default();
        v.resize_zeroed(len);
        v
    }

    /// Check out a buffer of `len` elements with **unspecified contents**,
    /// for consumers that fully overwrite it — skips `take`'s memset on
    /// the hot path. Using this for a buffer that is only accumulated into
    /// is a determinism bug; the bitwise `_into`-vs-wrapper property tests
    /// catch such misuse because the wrappers run on a fresh (all-zero)
    /// workspace while the hot path sees recycled contents.
    pub fn take_any(&mut self, len: usize) -> AlignedVec {
        let mut v = self.bufs.pop().unwrap_or_default();
        v.resize_preserve(len);
        v
    }

    /// Return a buffer to the pool (its capacity is what gets reused).
    pub fn give(&mut self, v: AlignedVec) {
        self.bufs.push(v);
    }

    /// Check out a cleared LayerNorm-stats buffer.
    pub fn take_stats(&mut self) -> Vec<(f32, f32)> {
        let mut v = self.stats.pop().unwrap_or_default();
        v.clear();
        v
    }

    /// Return a stats buffer to the pool.
    pub fn give_stats(&mut self, v: Vec<(f32, f32)>) {
        self.stats.push(v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_zeroes_and_reuses_capacity() {
        let mut s = Scratch::new();
        let mut a = s.take(8);
        a.iter_mut().for_each(|v| *v = 7.0);
        let cap = a.capacity();
        let ptr = a.as_ptr();
        s.give(a);
        let b = s.take(4);
        assert_eq!(&b[..], &[0.0; 4], "reused buffer must be zeroed");
        assert_eq!(b.as_ptr(), ptr, "same allocation must be reused");
        assert!(b.capacity() >= 4 && cap >= 8);
    }

    #[test]
    fn buffers_are_32_byte_aligned() {
        let mut s = Scratch::new();
        for len in [1usize, 7, 8, 9, 33] {
            let b = s.take(len);
            assert_eq!(b.as_ptr() as usize % 32, 0, "len={}", len);
            s.give(b);
        }
    }

    #[test]
    fn lifo_order_matches_nested_use() {
        let mut s = Scratch::new();
        let a = s.take(16);
        let b = s.take(4);
        s.give(b);
        s.give(a);
        // next taker of a 16-length buffer gets the 16-capacity one back
        let c = s.take(16);
        assert!(c.capacity() >= 16);
        s.give(c);
    }

    #[test]
    fn take_any_skips_the_memset_but_sizes_correctly() {
        let mut s = Scratch::new();
        let mut a = s.take(8);
        a.iter_mut().for_each(|v| *v = 3.0);
        s.give(a);
        // shrink: old contents retained (unspecified but deterministic)
        let b = s.take_any(4);
        assert_eq!(b.len(), 4);
        assert_eq!(&b[..], &[3.0; 4]);
        s.give(b);
        // grow: appended elements are zeroed, prefix retained
        let c = s.take_any(6);
        assert_eq!(&c[..], &[3.0, 3.0, 3.0, 3.0, 0.0, 0.0]);
    }

    #[test]
    fn stats_pool_round_trips() {
        let mut s = Scratch::new();
        let mut st = s.take_stats();
        st.push((1.0, 2.0));
        s.give_stats(st);
        let st2 = s.take_stats();
        assert!(st2.is_empty(), "stats buffers are cleared on take");
    }
}
