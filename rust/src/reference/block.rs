//! Neural-ODE transformer step Φ: forward and hand-derived backward.
//!
//! Matches `ref.py` exactly (pre-LN, tanh-GELU, eq. 1-3):
//!
//!   encoder:  x' = x + h (φ1(x) + φ2(x + φ1(x)))
//!   decoder:  ȳ  = φ1(y) + φ3(y + φ1(y), X_enc)
//!             y' = y + h (ȳ + φ2(y + ȳ))
//!
//! The backward functions recompute the forward internally (no cache
//! plumbing) and accumulate the adjoint state λ plus flat parameter
//! gradients.
//!
//! All kernels are slice-based and route their temporaries through a
//! caller-provided [`Scratch`] workspace: the `*_into` entry points
//! (`enc_step_fwd_into`, …) are allocation-free at steady state and form
//! the training hot path via [`crate::ode::RustPropagator`]. The
//! Tensor-level wrappers (`enc_step_fwd`, …) allocate a throwaway
//! workspace and exist for tests and one-off analysis calls. Matrix work
//! runs on the blocked kernels in [`crate::tensor::ops`].

use super::math::{gelu_grad, gelu_row, layer_norm_bwd, layer_norm_fwd_into, layer_norm_fwd_stats};
use super::params::{DecGrads, DecParams, EncGrads, EncParams};
use super::scratch::Scratch;
use crate::tensor::{mm_at_into, mm_bt_into, mm_into, Tensor};

/// Shape context for one Φ application.
#[derive(Debug, Clone, Copy)]
pub struct RefDims {
    pub batch: usize,
    pub seq: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
}

impl RefDims {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn rows(&self) -> usize {
        self.batch * self.seq
    }
}

// ---------------------------------------------------------------------------
// head gather/scatter + masked softmax
// ---------------------------------------------------------------------------

/// Copy head block h of a [b, s, d] activation into a contiguous [s, hd] buffer.
fn gather_head(src: &[f32], b: usize, s: usize, d: usize, h: usize, hd: usize, out: &mut [f32]) {
    for t in 0..s {
        let base = (b * s + t) * d + h * hd;
        out[t * hd..(t + 1) * hd].copy_from_slice(&src[base..base + hd]);
    }
}

/// Accumulate a contiguous [s, hd] head buffer back into [b, s, d] layout.
fn scatter_head_add(dst: &mut [f32], b: usize, s: usize, d: usize, h: usize, hd: usize, src: &[f32]) {
    for t in 0..s {
        let base = (b * s + t) * d + h * hd;
        for i in 0..hd {
            dst[base + i] += src[t * hd + i];
        }
    }
}

/// Row-wise softmax with optional causal mask; operates on [sq, sk].
///
/// The per-row normalization is the shared dispatched kernel
/// [`crate::tensor::softmax_row`] (SIMD when active), whose output bits
/// depend only on the row's contents — the invariant the cached-decode
/// paths rely on.
fn masked_softmax(scores: &mut [f32], sq: usize, sk: usize, causal: bool) {
    for qi in 0..sq {
        let row = &mut scores[qi * sk..(qi + 1) * sk];
        if causal {
            // allow k <= q + (sk - sq)  (matches ref.py tril with k = sk-sq)
            let limit = qi + (sk - sq);
            for (ki, v) in row.iter_mut().enumerate() {
                if ki > limit {
                    *v = f32::NEG_INFINITY;
                }
            }
        }
        crate::tensor::softmax_row(row);
    }
}

/// Add a length-`n` bias to every row of a [rows, n] buffer.
fn add_bias_rows(x: &mut [f32], bias: &[f32], n: usize) {
    for row in x.chunks_exact_mut(n) {
        for (v, &bv) in row.iter_mut().zip(bias) {
            *v += bv;
        }
    }
}

// ---------------------------------------------------------------------------
// attention (generic over self/cross): q from zq [bq rows], k/v from kv
// ---------------------------------------------------------------------------

struct AttnShapes {
    batch: usize,
    sq: usize,
    sk: usize,
    d: usize,
    nh: usize,
}

/// merged = MHA_core(zq @ wq, kv @ wk, kv @ wv); out = merged @ wo
/// (`out` fully overwritten).
#[allow(clippy::too_many_arguments)]
fn attention_fwd(
    zq: &[f32],
    kv: &[f32],
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    wo: &[f32],
    sh: &AttnShapes,
    causal: bool,
    out: &mut [f32],
    s: &mut Scratch,
) {
    let AttnShapes { batch, sq, sk, d, nh } = *sh;
    let hd = d / nh;
    let scale = 1.0 / (hd as f32).sqrt();
    let (rq, rk) = (batch * sq, batch * sk);

    let mut q = s.take_any(rq * d);
    let mut k = s.take_any(rk * d);
    let mut v = s.take_any(rk * d);
    mm_into(zq, wq, rq, d, d, &mut q, false);
    mm_into(kv, wk, rk, d, d, &mut k, false);
    mm_into(kv, wv, rk, d, d, &mut v, false);

    let mut merged = s.take(rq * d); // zeroed: scatter_head_add accumulates
    let mut qh = s.take_any(sq * hd);
    let mut kh = s.take_any(sk * hd);
    let mut vh = s.take_any(sk * hd);
    let mut scores = s.take_any(sq * sk);
    let mut oh = s.take_any(sq * hd);
    for b in 0..batch {
        for h in 0..nh {
            gather_head(&q, b, sq, d, h, hd, &mut qh);
            gather_head(&k, b, sk, d, h, hd, &mut kh);
            gather_head(&v, b, sk, d, h, hd, &mut vh);
            mm_bt_into(&qh, &kh, sq, hd, sk, &mut scores, false);
            scores.iter_mut().for_each(|x| *x *= scale);
            masked_softmax(&mut scores, sq, sk, causal);
            mm_into(&scores, &vh, sq, sk, hd, &mut oh, false);
            scatter_head_add(&mut merged, b, sq, d, h, hd, &oh);
        }
    }
    mm_into(&merged, wo, rq, d, d, out, false);
    s.give(oh);
    s.give(scores);
    s.give(vh);
    s.give(kh);
    s.give(qh);
    s.give(merged);
    s.give(v);
    s.give(k);
    s.give(q);
}

/// Backward of `attention_fwd` (recomputes internals).
/// Accumulates d_zq, d_kv and the four weight grads.
#[allow(clippy::too_many_arguments)]
fn attention_bwd(
    zq: &[f32],
    kv: &[f32],
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    wo: &[f32],
    sh: &AttnShapes,
    causal: bool,
    d_out: &[f32],
    d_zq: &mut [f32],
    d_kv: &mut [f32],
    dwq: &mut [f32],
    dwk: &mut [f32],
    dwv: &mut [f32],
    dwo: &mut [f32],
    s: &mut Scratch,
) {
    let AttnShapes { batch, sq, sk, d, nh } = *sh;
    let hd = d / nh;
    let scale = 1.0 / (hd as f32).sqrt();
    let (rq, rk) = (batch * sq, batch * sk);

    // recompute projections
    let mut q = s.take_any(rq * d);
    let mut k = s.take_any(rk * d);
    let mut v = s.take_any(rk * d);
    mm_into(zq, wq, rq, d, d, &mut q, false);
    mm_into(kv, wk, rk, d, d, &mut k, false);
    mm_into(kv, wv, rk, d, d, &mut v, false);

    let mut qh = s.take_any(sq * hd);
    let mut kh = s.take_any(sk * hd);
    let mut vh = s.take_any(sk * hd);
    let mut p = s.take_any(sq * sk);
    let mut oh = s.take_any(sq * hd);

    // recompute merged (needed for dwo)
    let mut merged = s.take(rq * d);
    for b in 0..batch {
        for h in 0..nh {
            gather_head(&q, b, sq, d, h, hd, &mut qh);
            gather_head(&k, b, sk, d, h, hd, &mut kh);
            gather_head(&v, b, sk, d, h, hd, &mut vh);
            mm_bt_into(&qh, &kh, sq, hd, sk, &mut p, false);
            p.iter_mut().for_each(|x| *x *= scale);
            masked_softmax(&mut p, sq, sk, causal);
            mm_into(&p, &vh, sq, sk, hd, &mut oh, false);
            scatter_head_add(&mut merged, b, sq, d, h, hd, &oh);
        }
    }

    // out = merged @ wo
    mm_at_into(&merged, d_out, rq, d, d, dwo, true);
    let mut d_merged = s.take_any(rq * d);
    mm_bt_into(d_out, wo, rq, d, d, &mut d_merged, false);

    let mut dq = s.take(rq * d);
    let mut dk = s.take(rk * d);
    let mut dv = s.take(rk * d);
    {
        let mut doh = s.take_any(sq * hd);
        let mut dp = s.take_any(sq * sk);
        let mut ds = s.take_any(sq * sk);
        let mut dqh = s.take_any(sq * hd);
        let mut dkh = s.take_any(sk * hd);
        let mut dvh = s.take_any(sk * hd);
        for b in 0..batch {
            for h in 0..nh {
                gather_head(&q, b, sq, d, h, hd, &mut qh);
                gather_head(&k, b, sk, d, h, hd, &mut kh);
                gather_head(&v, b, sk, d, h, hd, &mut vh);
                mm_bt_into(&qh, &kh, sq, hd, sk, &mut p, false);
                p.iter_mut().for_each(|x| *x *= scale);
                masked_softmax(&mut p, sq, sk, causal);

                gather_head(&d_merged, b, sq, d, h, hd, &mut doh);
                // dP = dO @ Vᵀ ; dV = Pᵀ @ dO
                mm_bt_into(&doh, &vh, sq, hd, sk, &mut dp, false);
                mm_at_into(&p, &doh, sq, sk, hd, &mut dvh, false);
                // softmax backward: dS = P ∘ (dP - rowsum(dP ∘ P))
                for qi in 0..sq {
                    let prow = &p[qi * sk..(qi + 1) * sk];
                    let dprow = &dp[qi * sk..(qi + 1) * sk];
                    let dot: f32 = prow.iter().zip(dprow).map(|(a, b2)| a * b2).sum();
                    let dsrow = &mut ds[qi * sk..(qi + 1) * sk];
                    for ki in 0..sk {
                        dsrow[ki] = prow[ki] * (dprow[ki] - dot);
                    }
                }
                // dQ = scale * dS @ K ; dK = scale * dSᵀ @ Q
                mm_into(&ds, &kh, sq, sk, hd, &mut dqh, false);
                dqh.iter_mut().for_each(|x| *x *= scale);
                mm_at_into(&ds, &qh, sq, sk, hd, &mut dkh, false);
                dkh.iter_mut().for_each(|x| *x *= scale);

                scatter_head_add(&mut dq, b, sq, d, h, hd, &dqh);
                scatter_head_add(&mut dk, b, sk, d, h, hd, &dkh);
                scatter_head_add(&mut dv, b, sk, d, h, hd, &dvh);
            }
        }
        s.give(dvh);
        s.give(dkh);
        s.give(dqh);
        s.give(ds);
        s.give(dp);
        s.give(doh);
    }

    // projection backward
    mm_bt_into(&dq, wq, rq, d, d, d_zq, true);
    mm_bt_into(&dk, wk, rk, d, d, d_kv, true);
    mm_bt_into(&dv, wv, rk, d, d, d_kv, true);
    mm_at_into(zq, &dq, rq, d, d, dwq, true);
    mm_at_into(kv, &dk, rk, d, d, dwk, true);
    mm_at_into(kv, &dv, rk, d, d, dwv, true);

    s.give(dv);
    s.give(dk);
    s.give(dq);
    s.give(d_merged);
    s.give(merged);
    s.give(oh);
    s.give(p);
    s.give(vh);
    s.give(kh);
    s.give(qh);
    s.give(v);
    s.give(k);
    s.give(q);
}

// ---------------------------------------------------------------------------
// phi sublayers
// ---------------------------------------------------------------------------

/// φ1(x) = SA(LN1(x)) — forward (`out` fully overwritten).
fn phi1_fwd(x: &[f32], p: &EncParams, dm: &RefDims, causal: bool, out: &mut [f32], s: &mut Scratch) {
    let (r, d) = (dm.rows(), dm.d_model);
    let mut z = s.take_any(r * d);
    layer_norm_fwd_into(x, p.ln1_g, p.ln1_b, d, &mut z);
    let sh = AttnShapes { batch: dm.batch, sq: dm.seq, sk: dm.seq, d, nh: dm.n_heads };
    attention_fwd(&z, &z, p.wq, p.wk, p.wv, p.wo, &sh, causal, out, s);
    s.give(z);
}

/// φ1 backward: accumulates dx and parameter grads.
#[allow(clippy::too_many_arguments)]
fn phi1_bwd(
    x: &[f32],
    p: &EncParams,
    g: &mut EncGrads,
    dm: &RefDims,
    causal: bool,
    d_out: &[f32],
    dx: &mut [f32],
    s: &mut Scratch,
) {
    let (r, d) = (dm.rows(), dm.d_model);
    let mut z = s.take_any(r * d);
    let mut stats = s.take_stats();
    layer_norm_fwd_stats(x, p.ln1_g, p.ln1_b, d, &mut z, &mut stats);
    let sh = AttnShapes { batch: dm.batch, sq: dm.seq, sk: dm.seq, d, nh: dm.n_heads };
    // self-attention: zq and kv are the SAME tensor -> sum both grad paths
    let mut dz_q = s.take(r * d);
    let mut dz_kv = s.take(r * d);
    attention_bwd(&z, &z, p.wq, p.wk, p.wv, p.wo, &sh, causal, d_out, &mut dz_q, &mut dz_kv,
                  g.wq, g.wk, g.wv, g.wo, s);
    for (a2, b2) in dz_q.iter_mut().zip(dz_kv.iter()) {
        *a2 += *b2;
    }
    layer_norm_bwd(&dz_q, x, p.ln1_g, &stats, d, dx, g.ln1_g, g.ln1_b);
    s.give(dz_kv);
    s.give(dz_q);
    s.give(z);
    s.give_stats(stats);
}

/// φ2(u) = MLP(LN2(u)) — forward (`out` fully overwritten).
fn phi2_fwd(u: &[f32], p: &EncParams, dm: &RefDims, out: &mut [f32], s: &mut Scratch) {
    let (r, d, f) = (dm.rows(), dm.d_model, dm.d_ff);
    let mut z = s.take_any(r * d);
    layer_norm_fwd_into(u, p.ln2_g, p.ln2_b, d, &mut z);
    let mut hpre = s.take_any(r * f);
    mm_into(&z, p.w1, r, d, f, &mut hpre, false);
    add_bias_rows(&mut hpre, p.b1, f);
    // gelu in place, one f-length row at a time (the dispatched row
    // kernel keeps element bits independent of the row count, so the
    // cached single-position and full-sequence paths agree): hpre
    // becomes hmid
    for row in hpre.chunks_exact_mut(f) {
        gelu_row(row);
    }
    mm_into(&hpre, p.w2, r, f, d, out, false);
    add_bias_rows(out, p.b2, d);
    s.give(hpre);
    s.give(z);
}

/// φ2 backward: accumulates du and parameter grads.
fn phi2_bwd(
    u: &[f32],
    p: &EncParams,
    g: &mut EncGrads,
    dm: &RefDims,
    d_out: &[f32],
    du: &mut [f32],
    s: &mut Scratch,
) {
    let (r, d, f) = (dm.rows(), dm.d_model, dm.d_ff);
    let mut z = s.take_any(r * d);
    let mut stats = s.take_stats();
    layer_norm_fwd_stats(u, p.ln2_g, p.ln2_b, d, &mut z, &mut stats);
    let mut hpre = s.take_any(r * f);
    mm_into(&z, p.w1, r, d, f, &mut hpre, false);
    add_bias_rows(&mut hpre, p.b1, f);
    let mut hmid = s.take_any(r * f);
    hmid.copy_from_slice(&hpre);
    // same dispatched row-wise gelu as phi2_fwd: the recomputed hmid must
    // match the forward pass bit for bit
    for row in hmid.chunks_exact_mut(f) {
        gelu_row(row);
    }

    // out = hmid @ w2 + b2
    mm_at_into(&hmid, d_out, r, f, d, g.w2, true);
    for row in d_out.chunks_exact(d) {
        for (gb, &dv) in g.b2.iter_mut().zip(row) {
            *gb += dv;
        }
    }
    let mut d_hmid = s.take_any(r * f);
    mm_bt_into(d_out, p.w2, r, d, f, &mut d_hmid, false);
    // gelu backward in place: d_hmid becomes d_hpre
    for (dh, &hp) in d_hmid.iter_mut().zip(hpre.iter()) {
        *dh *= gelu_grad(hp);
    }
    // hpre = z @ w1 + b1
    mm_at_into(&z, &d_hmid, r, d, f, g.w1, true);
    for row in d_hmid.chunks_exact(f) {
        for (gb, &dv) in g.b1.iter_mut().zip(row) {
            *gb += dv;
        }
    }
    let mut dz = s.take_any(r * d);
    mm_bt_into(&d_hmid, p.w1, r, f, d, &mut dz, false);
    layer_norm_bwd(&dz, u, p.ln2_g, &stats, d, du, g.ln2_g, g.ln2_b);
    s.give(dz);
    s.give(d_hmid);
    s.give(hmid);
    s.give(hpre);
    s.give(z);
    s.give_stats(stats);
}

/// φ3(u, x_enc) = CA(LN3(u), x_enc) — forward. Keys/values from raw x_enc
/// (not layer-normed), matching ref.py. `out` fully overwritten.
fn phi3_fwd(
    u: &[f32],
    x_enc: &[f32],
    p: &DecParams,
    dm_q: &RefDims,
    seq_k: usize,
    out: &mut [f32],
    s: &mut Scratch,
) {
    let (r, d) = (dm_q.rows(), dm_q.d_model);
    let mut z = s.take_any(r * d);
    layer_norm_fwd_into(u, p.ln3_g, p.ln3_b, d, &mut z);
    let sh = AttnShapes { batch: dm_q.batch, sq: dm_q.seq, sk: seq_k, d, nh: dm_q.n_heads };
    attention_fwd(&z, x_enc, p.cq, p.ck, p.cv, p.co, &sh, false, out, s);
    s.give(z);
}

/// φ3 backward: accumulates du, dx_enc and parameter grads.
#[allow(clippy::too_many_arguments)]
fn phi3_bwd(
    u: &[f32],
    x_enc: &[f32],
    p: &DecParams,
    g: &mut DecGrads,
    dm_q: &RefDims,
    seq_k: usize,
    d_out: &[f32],
    du: &mut [f32],
    dx_enc: &mut [f32],
    s: &mut Scratch,
) {
    let (r, d) = (dm_q.rows(), dm_q.d_model);
    let mut z = s.take_any(r * d);
    let mut stats = s.take_stats();
    layer_norm_fwd_stats(u, p.ln3_g, p.ln3_b, d, &mut z, &mut stats);
    let sh = AttnShapes { batch: dm_q.batch, sq: dm_q.seq, sk: seq_k, d, nh: dm_q.n_heads };
    let mut dz = s.take(r * d);
    attention_bwd(&z, x_enc, p.cq, p.ck, p.cv, p.co, &sh, false, d_out, &mut dz, dx_enc,
                  g.cq, g.ck, g.cv, g.co, s);
    layer_norm_bwd(&dz, u, p.ln3_g, &stats, d, du, g.ln3_g, g.ln3_b);
    s.give(dz);
    s.give(z);
    s.give_stats(stats);
}

// ---------------------------------------------------------------------------
// public step functions (slice-based `_into` + Tensor wrappers)
// ---------------------------------------------------------------------------

/// Encoder (or causal decoder-only) step into a caller buffer:
/// out = x + h (φ1(x) + φ2(x + φ1(x))). `out` is fully overwritten;
/// allocation-free at steady state given a warm `Scratch`.
pub fn enc_step_fwd_into(
    x: &[f32],
    theta: &[f32],
    h: f32,
    dm: &RefDims,
    causal: bool,
    out: &mut [f32],
    s: &mut Scratch,
) {
    let p = EncParams::view(theta, dm.d_model, dm.d_ff);
    let n = x.len();
    let mut a = s.take_any(n);
    phi1_fwd(x, &p, dm, causal, &mut a, s);
    let mut u = s.take_any(n);
    for i in 0..n {
        u[i] = x[i] + a[i];
    }
    let mut m = s.take_any(n);
    phi2_fwd(&u, &p, dm, &mut m, s);
    for i in 0..n {
        out[i] = x[i] + h * (a[i] + m[i]);
    }
    s.give(m);
    s.give(u);
    s.give(a);
}

/// Encoder step: x' = x + h (φ1(x) + φ2(x + φ1(x))).
pub fn enc_step_fwd(x: &Tensor, theta: &[f32], h: f32, dm: &RefDims, causal: bool) -> Tensor {
    let mut s = Scratch::new();
    let mut out = Tensor::zeros(x.shape());
    enc_step_fwd_into(x.data(), theta, h, dm, causal, out.data_mut(), &mut s);
    out
}

/// Encoder step VJP into caller buffers: `dx` is overwritten with
/// λ = ∂/∂x, `gtheta` is *accumulated* with the parameter gradient.
#[allow(clippy::too_many_arguments)]
pub fn enc_step_bwd_into(
    x: &[f32],
    theta: &[f32],
    h: f32,
    dm: &RefDims,
    causal: bool,
    ct: &[f32],
    dx: &mut [f32],
    gtheta: &mut [f32],
    s: &mut Scratch,
) {
    let p = EncParams::view(theta, dm.d_model, dm.d_ff);
    let n = x.len();

    // forward pieces needed: a = φ1(x), u = x + a
    let mut a = s.take_any(n);
    phi1_fwd(x, &p, dm, causal, &mut a, s);
    let mut u = s.take_any(n);
    for i in 0..n {
        u[i] = x[i] + a[i];
    }

    // out = x + h (a + m), m = φ2(u)
    let mut d_f = s.take_any(n); // gradient into (a + m)
    for i in 0..n {
        d_f[i] = h * ct[i];
    }
    dx.copy_from_slice(ct); // identity path

    // φ2 path
    let mut du = s.take(n);
    {
        let mut g = EncGrads::view(gtheta, dm.d_model, dm.d_ff);
        phi2_bwd(&u, &p, &mut g, dm, &d_f, &mut du, s);
    }
    // u = x + a
    for i in 0..n {
        dx[i] += du[i];
    }
    // total gradient into a: direct h·ct + via u
    let mut da = s.take_any(n);
    for i in 0..n {
        da[i] = d_f[i] + du[i];
    }
    {
        let mut g = EncGrads::view(gtheta, dm.d_model, dm.d_ff);
        phi1_bwd(x, &p, &mut g, dm, causal, &da, dx, s);
    }
    s.give(da);
    s.give(du);
    s.give(d_f);
    s.give(u);
    s.give(a);
}

/// Encoder step VJP: returns (λ = ∂/∂x, grad_theta) for upstream ct.
pub fn enc_step_bwd(
    x: &Tensor,
    theta: &[f32],
    h: f32,
    dm: &RefDims,
    causal: bool,
    ct: &Tensor,
) -> (Tensor, Vec<f32>) {
    let mut s = Scratch::new();
    let mut gtheta = vec![0.0; theta.len()];
    let mut dx = vec![0.0; x.len()];
    enc_step_bwd_into(x.data(), theta, h, dm, causal, ct.data(), &mut dx, &mut gtheta, &mut s);
    (Tensor::from_vec(dx, x.shape()), gtheta)
}

/// Encoder-decoder decoder step into a caller buffer (eq. 2); `out` is
/// fully overwritten.
#[allow(clippy::too_many_arguments)]
pub fn dec_step_fwd_into(
    y: &[f32],
    x_enc: &[f32],
    theta: &[f32],
    h: f32,
    dm: &RefDims,
    seq_enc: usize,
    out: &mut [f32],
    s: &mut Scratch,
) {
    let p = DecParams::view(theta, dm.d_model, dm.d_ff);
    let n = y.len();
    let mut a = s.take_any(n);
    phi1_fwd(y, &p.enc, dm, true, &mut a, s);
    let mut u3 = s.take_any(n);
    for i in 0..n {
        u3[i] = y[i] + a[i];
    }
    let mut c = s.take_any(n);
    phi3_fwd(&u3, x_enc, &p, dm, seq_enc, &mut c, s);
    let mut ybar = s.take_any(n);
    for i in 0..n {
        ybar[i] = a[i] + c[i];
    }
    let mut u2 = s.take_any(n);
    for i in 0..n {
        u2[i] = y[i] + ybar[i];
    }
    let mut m = s.take_any(n);
    phi2_fwd(&u2, &p.enc, dm, &mut m, s);
    for i in 0..n {
        out[i] = y[i] + h * (ybar[i] + m[i]);
    }
    s.give(m);
    s.give(u2);
    s.give(ybar);
    s.give(c);
    s.give(u3);
    s.give(a);
}

/// Encoder-decoder decoder step (eq. 2).
pub fn dec_step_fwd(
    y: &Tensor,
    x_enc: &Tensor,
    theta: &[f32],
    h: f32,
    dm: &RefDims,
    seq_enc: usize,
) -> Tensor {
    let mut s = Scratch::new();
    let mut out = Tensor::zeros(y.shape());
    dec_step_fwd_into(y.data(), x_enc.data(), theta, h, dm, seq_enc, out.data_mut(), &mut s);
    out
}

/// Decoder step VJP into caller buffers: `dy` and `dx_enc` are
/// overwritten (λ_y, λ_x_enc); `gtheta` is *accumulated*.
#[allow(clippy::too_many_arguments)]
pub fn dec_step_bwd_into(
    y: &[f32],
    x_enc: &[f32],
    theta: &[f32],
    h: f32,
    dm: &RefDims,
    seq_enc: usize,
    ct: &[f32],
    dy: &mut [f32],
    dx_enc: &mut [f32],
    gtheta: &mut [f32],
    s: &mut Scratch,
) {
    let p = DecParams::view(theta, dm.d_model, dm.d_ff);
    let n = y.len();

    // recompute forward pieces
    let mut a = s.take_any(n);
    phi1_fwd(y, &p.enc, dm, true, &mut a, s);
    let mut u3 = s.take_any(n);
    for i in 0..n {
        u3[i] = y[i] + a[i];
    }
    let mut c = s.take_any(n);
    phi3_fwd(&u3, x_enc, &p, dm, seq_enc, &mut c, s);
    let mut ybar = s.take_any(n);
    for i in 0..n {
        ybar[i] = a[i] + c[i];
    }
    let mut u2 = s.take_any(n);
    for i in 0..n {
        u2[i] = y[i] + ybar[i];
    }

    // out = y + h (ybar + m)
    let mut d_f = s.take_any(n);
    for i in 0..n {
        d_f[i] = h * ct[i];
    }
    dy.copy_from_slice(ct);
    dx_enc.fill(0.0);

    // φ2 path at u2
    let mut du2 = s.take(n);
    {
        let mut g = DecGrads::view(gtheta, dm.d_model, dm.d_ff);
        phi2_bwd(&u2, &p.enc, &mut g.enc, dm, &d_f, &mut du2, s);
    }
    for i in 0..n {
        dy[i] += du2[i];
    }
    // d_ybar = h·ct (direct) + du2 (via u2)
    let mut d_ybar = s.take_any(n);
    for i in 0..n {
        d_ybar[i] = d_f[i] + du2[i];
    }

    // ybar = a + φ3(u3, x_enc):  d_a += d_ybar;  φ3 gets d_ybar
    let mut du3 = s.take(n);
    {
        let mut g = DecGrads::view(gtheta, dm.d_model, dm.d_ff);
        phi3_bwd(&u3, x_enc, &p, &mut g, dm, seq_enc, &d_ybar, &mut du3, dx_enc, s);
    }
    // u3 = y + a
    for i in 0..n {
        dy[i] += du3[i];
    }
    let mut da = s.take_any(n);
    for i in 0..n {
        da[i] = d_ybar[i] + du3[i];
    }
    {
        let mut g = DecGrads::view(gtheta, dm.d_model, dm.d_ff);
        phi1_bwd(y, &p.enc, &mut g.enc, dm, true, &da, dy, s);
    }
    s.give(da);
    s.give(du3);
    s.give(d_ybar);
    s.give(du2);
    s.give(d_f);
    s.give(u2);
    s.give(ybar);
    s.give(c);
    s.give(u3);
    s.give(a);
}

/// Decoder step VJP: returns (λ_y, λ_x_enc, grad_theta).
pub fn dec_step_bwd(
    y: &Tensor,
    x_enc: &Tensor,
    theta: &[f32],
    h: f32,
    dm: &RefDims,
    seq_enc: usize,
    ct: &Tensor,
) -> (Tensor, Tensor, Vec<f32>) {
    let mut s = Scratch::new();
    let mut gtheta = vec![0.0; theta.len()];
    let mut dy = vec![0.0; y.len()];
    let mut dx_enc = vec![0.0; x_enc.len()];
    dec_step_bwd_into(
        y.data(),
        x_enc.data(),
        theta,
        h,
        dm,
        seq_enc,
        ct.data(),
        &mut dy,
        &mut dx_enc,
        &mut gtheta,
        &mut s,
    );
    (
        Tensor::from_vec(dy, y.shape()),
        Tensor::from_vec(dx_enc, x_enc.shape()),
        gtheta,
    )
}

// ---------------------------------------------------------------------------
// incremental (KV-cached) decode kernels
// ---------------------------------------------------------------------------
//
// One new position per batch row instead of the whole board. The cache
// slabs are laid out [batch, n_heads, cap, head_dim] (pre-gathered per
// head, see `crate::reference::KvCache`), so scoring streams one
// contiguous [len, head_dim] slab per (row, head). Bitwise parity with
// the full-board kernels rests on three properties pinned by the tests
// below, in `tensor/ops.rs`, and (for the SIMD kernels) in
// `tests/simd_parity.rs`:
//
// * `mm_into` accumulates each output element over k in ascending order
//   (naive-loop bitwise — the SIMD path uses separate mul/add roundings,
//   never FMA, to preserve exactly this), so projecting one row gives
//   the same bits as that row inside a full-board projection, and a
//   softmax row whose masked tail weights are exactly +0.0 contributes
//   nothing to the ascending-k value accumulation;
// * `mm_bt_into`'s per-element value depends only on the head_dim
//   contraction (ascending k; in the SIMD build one FMA chain per
//   element, with the scalar-remainder columns using the identically
//   rounded `f32::mul_add`), never on the row/column count — identical
//   in both paths;
// * layer-norm / GELU / softmax are dispatched *row-wise* kernels whose
//   output bits depend only on the row contents (softmax additionally
//   flushes `exp(-inf)` and sub-(-87) tails to exactly +0.0, keeping the
//   masked-tail property above), and bias adds are element-wise.
//
// Scalar and SIMD builds may differ from each other on the reassociated
// kernels (mm_bt/softmax/LN/GELU, ulp-bounded), but each build agrees
// with itself across the cached and full-board paths — which is what
// decode parity means.

/// Score one new query row per batch against cached K/V; for
/// self-attention (`cross_len = None`) first project `append` and store
/// it as column `positions[b]`, then attend over `positions[b] + 1`
/// columns (the causal set). `out` is `[batch, d]`, fully overwritten.
#[allow(clippy::too_many_arguments)]
fn attention_fwd_cached(
    zq: &[f32],
    append: Option<&[f32]>,
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    wo: &[f32],
    batch: usize,
    d: usize,
    nh: usize,
    cap: usize,
    positions: &[usize],
    cross_len: Option<usize>,
    kcache: &mut [f32],
    vcache: &mut [f32],
    out: &mut [f32],
    s: &mut Scratch,
) {
    let hd = d / nh;
    let scale = 1.0 / (hd as f32).sqrt();

    let mut q = s.take_any(batch * d);
    mm_into(zq, wq, batch, d, d, &mut q, false);
    if let Some(rows) = append {
        let mut kn = s.take_any(batch * d);
        let mut vn = s.take_any(batch * d);
        mm_into(rows, wk, batch, d, d, &mut kn, false);
        mm_into(rows, wv, batch, d, d, &mut vn, false);
        for b in 0..batch {
            for h in 0..nh {
                let src = b * d + h * hd;
                let dst = ((b * nh + h) * cap + positions[b]) * hd;
                kcache[dst..dst + hd].copy_from_slice(&kn[src..src + hd]);
                vcache[dst..dst + hd].copy_from_slice(&vn[src..src + hd]);
            }
        }
        s.give(vn);
        s.give(kn);
    }

    let mut merged = s.take(batch * d); // zeroed: head outputs accumulate
    let mut scores = s.take_any(cap.max(1));
    let mut oh = s.take_any(hd);
    for b in 0..batch {
        let len = cross_len.unwrap_or(positions[b] + 1);
        for h in 0..nh {
            let qh = &q[b * d + h * hd..b * d + (h + 1) * hd];
            let base = (b * nh + h) * cap * hd;
            let kh = &kcache[base..base + len * hd];
            let vh = &vcache[base..base + len * hd];
            let sc = &mut scores[..len];
            mm_bt_into(qh, kh, 1, hd, len, sc, false);
            sc.iter_mut().for_each(|x| *x *= scale);
            masked_softmax(sc, 1, len, false);
            mm_into(sc, vh, 1, len, hd, &mut oh, false);
            // same add-into-zeroed accumulation as scatter_head_add
            let mrow = &mut merged[b * d + h * hd..b * d + (h + 1) * hd];
            for (m, &o) in mrow.iter_mut().zip(oh.iter()) {
                *m += o;
            }
        }
    }
    mm_into(&merged, wo, batch, d, d, out, false);
    s.give(oh);
    s.give(scores);
    s.give(merged);
    s.give(q);
}

/// Cached encoder-family step on the single newest position per row:
/// `x` holds the `[batch, d]` layer-input rows at `positions[b]`. The φ1
/// K/V column for the new position is appended to the cache and the row
/// advances exactly as it would inside a full causal
/// [`enc_step_fwd_into`] board — bit for bit. `dm.seq` must be 1.
#[allow(clippy::too_many_arguments)]
pub fn enc_step_fwd_cached(
    x: &[f32],
    theta: &[f32],
    h: f32,
    dm: &RefDims,
    cap: usize,
    positions: &[usize],
    kcache: &mut [f32],
    vcache: &mut [f32],
    out: &mut [f32],
    s: &mut Scratch,
) {
    debug_assert_eq!(dm.seq, 1, "cached step advances one position per row");
    let p = EncParams::view(theta, dm.d_model, dm.d_ff);
    let n = dm.batch * dm.d_model;
    let mut z = s.take_any(n);
    layer_norm_fwd_into(x, p.ln1_g, p.ln1_b, dm.d_model, &mut z);
    let mut a = s.take_any(n);
    attention_fwd_cached(&z, Some(&z), p.wq, p.wk, p.wv, p.wo, dm.batch, dm.d_model, dm.n_heads,
                         cap, positions, None, kcache, vcache, &mut a, s);
    let mut u = s.take_any(n);
    for i in 0..n {
        u[i] = x[i] + a[i];
    }
    let mut m = s.take_any(n);
    phi2_fwd(&u, &p, dm, &mut m, s);
    for i in 0..n {
        out[i] = x[i] + h * (a[i] + m[i]);
    }
    s.give(m);
    s.give(u);
    s.give(a);
    s.give(z);
}

/// Cached decoder step (eq. 2) on the single newest position per row:
/// φ1 appends to and scores against the decoder self-attention cache; φ3
/// reads the primed cross-attention store (encoder K/V, filled once by
/// [`fill_cross_kv`]). `dm.seq` must be 1.
#[allow(clippy::too_many_arguments)]
pub fn dec_step_fwd_cached(
    y: &[f32],
    theta: &[f32],
    h: f32,
    dm: &RefDims,
    cap: usize,
    positions: &[usize],
    k_self: &mut [f32],
    v_self: &mut [f32],
    cross_cap: usize,
    cross_len: usize,
    k_cross: &mut [f32],
    v_cross: &mut [f32],
    out: &mut [f32],
    s: &mut Scratch,
) {
    debug_assert_eq!(dm.seq, 1, "cached step advances one position per row");
    let p = DecParams::view(theta, dm.d_model, dm.d_ff);
    let n = dm.batch * dm.d_model;
    // a = φ1(y): causal self-attention over the cached decoder columns
    let mut z1 = s.take_any(n);
    layer_norm_fwd_into(y, p.enc.ln1_g, p.enc.ln1_b, dm.d_model, &mut z1);
    let mut a = s.take_any(n);
    attention_fwd_cached(&z1, Some(&z1), p.enc.wq, p.enc.wk, p.enc.wv, p.enc.wo, dm.batch,
                         dm.d_model, dm.n_heads, cap, positions, None, k_self, v_self, &mut a, s);
    let mut u3 = s.take_any(n);
    for i in 0..n {
        u3[i] = y[i] + a[i];
    }
    // c = φ3(u3, X_enc): cross-attention against the primed encoder store
    let mut z3 = s.take_any(n);
    layer_norm_fwd_into(&u3, p.ln3_g, p.ln3_b, dm.d_model, &mut z3);
    let mut c = s.take_any(n);
    attention_fwd_cached(&z3, None, p.cq, p.ck, p.cv, p.co, dm.batch, dm.d_model, dm.n_heads,
                         cross_cap, positions, Some(cross_len), k_cross, v_cross, &mut c, s);
    let mut ybar = s.take_any(n);
    for i in 0..n {
        ybar[i] = a[i] + c[i];
    }
    let mut u2 = s.take_any(n);
    for i in 0..n {
        u2[i] = y[i] + ybar[i];
    }
    let mut m = s.take_any(n);
    phi2_fwd(&u2, &p.enc, dm, &mut m, s);
    for i in 0..n {
        out[i] = y[i] + h * (ybar[i] + m[i]);
    }
    s.give(m);
    s.give(u2);
    s.give(ybar);
    s.give(c);
    s.give(z3);
    s.give(u3);
    s.give(a);
    s.give(z1);
}

/// Prefill helper: project and store the φ1 K/V columns
/// `from[b]..=to[b]` of one layer from its full-board input `x`
/// (`[batch, seq, d]`). Row `b` with `from[b] > to[b]` is skipped. The
/// per-row projections are bitwise what the full forward computes
/// internally and what [`enc_step_fwd_cached`] /
/// [`dec_step_fwd_cached`] would have appended.
#[allow(clippy::too_many_arguments)]
pub fn fill_self_kv(
    x: &[f32],
    ln_g: &[f32],
    ln_b: &[f32],
    wk: &[f32],
    wv: &[f32],
    batch: usize,
    seq: usize,
    d: usize,
    nh: usize,
    cap: usize,
    from: &[usize],
    to: &[usize],
    kcache: &mut [f32],
    vcache: &mut [f32],
    s: &mut Scratch,
) {
    let hd = d / nh;
    let mut z = s.take_any(d);
    let mut kr = s.take_any(d);
    let mut vr = s.take_any(d);
    for b in 0..batch {
        debug_assert!(to[b] < seq, "fill column beyond the board");
        for t in from[b]..=to[b] {
            let row = &x[(b * seq + t) * d..(b * seq + t + 1) * d];
            layer_norm_fwd_into(row, ln_g, ln_b, d, &mut z);
            mm_into(&z, wk, 1, d, d, &mut kr, false);
            mm_into(&z, wv, 1, d, d, &mut vr, false);
            for h in 0..nh {
                let dst = ((b * nh + h) * cap + t) * hd;
                kcache[dst..dst + hd].copy_from_slice(&kr[h * hd..(h + 1) * hd]);
                vcache[dst..dst + hd].copy_from_slice(&vr[h * hd..(h + 1) * hd]);
            }
        }
    }
    s.give(vr);
    s.give(kr);
    s.give(z);
}

/// Prefill helper: project and store the φ3 cross-attention K/V of one
/// decoder layer — every row, all `seq_enc` columns — from the **raw**
/// encoder output (φ3 keys/values are not layer-normed, matching
/// ref.py). Primed once per prefill, read-only afterwards.
#[allow(clippy::too_many_arguments)]
pub fn fill_cross_kv(
    x_enc: &[f32],
    ck: &[f32],
    cv: &[f32],
    batch: usize,
    seq_enc: usize,
    d: usize,
    nh: usize,
    cap: usize,
    kcache: &mut [f32],
    vcache: &mut [f32],
    s: &mut Scratch,
) {
    let hd = d / nh;
    let rows = batch * seq_enc;
    let mut k = s.take_any(rows * d);
    let mut v = s.take_any(rows * d);
    mm_into(x_enc, ck, rows, d, d, &mut k, false);
    mm_into(x_enc, cv, rows, d, d, &mut v, false);
    for b in 0..batch {
        for h in 0..nh {
            let dst = (b * nh + h) * cap * hd;
            gather_head(&k, b, seq_enc, d, h, hd, &mut kcache[dst..dst + seq_enc * hd]);
            gather_head(&v, b, seq_enc, d, h, hd, &mut vcache[dst..dst + seq_enc * hd]);
        }
    }
    s.give(v);
    s.give(k);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn dims() -> RefDims {
        RefDims { batch: 2, seq: 4, d_model: 8, n_heads: 2, d_ff: 16 }
    }

    fn p_enc(dm: &RefDims) -> usize {
        let (d, f) = (dm.d_model, dm.d_ff);
        4 * d * d + 2 * d * f + 5 * d + f
    }

    fn p_dec(dm: &RefDims) -> usize {
        p_enc(dm) + 2 * dm.d_model + 4 * dm.d_model * dm.d_model
    }

    #[test]
    fn enc_step_h_zero_is_identity() {
        let dm = dims();
        let mut rng = Rng::new(0);
        let x = Tensor::randn(&mut rng, &[dm.batch, dm.seq, dm.d_model], 1.0);
        let theta = rng.normal_vec(p_enc(&dm), 0.1);
        let out = enc_step_fwd(&x, &theta, 0.0, &dm, false);
        assert!(out.allclose(&x, 1e-6, 1e-6));
    }

    #[test]
    fn enc_step_residual_linear_in_h() {
        let dm = dims();
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&mut rng, &[dm.batch, dm.seq, dm.d_model], 1.0);
        let theta = rng.normal_vec(p_enc(&dm), 0.1);
        let d1 = enc_step_fwd(&x, &theta, 0.1, &dm, false).sub(&x);
        let mut d2 = enc_step_fwd(&x, &theta, 0.2, &dm, false).sub(&x);
        d2.scale(0.5);
        assert!(d1.allclose(&d2, 1e-4, 1e-5));
    }

    #[test]
    fn into_variants_match_wrappers_bitwise_and_reuse_scratch() {
        // one warm Scratch reused across calls must reproduce the
        // allocating wrappers bit for bit, with `out` pre-filled with
        // garbage (pins the full-overwrite contract)
        let dm = dims();
        let mut rng = Rng::new(42);
        let mut s = Scratch::new();
        for trial in 0..3 {
            let x = Tensor::randn(&mut rng, &[dm.batch, dm.seq, dm.d_model], 1.0);
            let theta = rng.normal_vec(p_enc(&dm), 0.2);
            let ct = Tensor::randn(&mut rng, &[dm.batch, dm.seq, dm.d_model], 1.0);
            let h = 0.3 + 0.1 * trial as f32;

            let want = enc_step_fwd(&x, &theta, h, &dm, true);
            let mut out = vec![f32::NAN; x.len()];
            enc_step_fwd_into(x.data(), &theta, h, &dm, true, &mut out, &mut s);
            assert_eq!(out, want.data());

            let (wdx, wgt) = enc_step_bwd(&x, &theta, h, &dm, true, &ct);
            let mut dx = vec![f32::NAN; x.len()];
            let mut gt = vec![0.0; theta.len()];
            enc_step_bwd_into(x.data(), &theta, h, &dm, true, ct.data(), &mut dx, &mut gt, &mut s);
            assert_eq!(dx, wdx.data());
            assert_eq!(gt, wgt);

            // decoder family
            let seq_enc = 5;
            let thd = rng.normal_vec(p_dec(&dm), 0.2);
            let y = Tensor::randn(&mut rng, &[dm.batch, dm.seq, dm.d_model], 1.0);
            let xe = Tensor::randn(&mut rng, &[dm.batch, seq_enc, dm.d_model], 1.0);
            let want = dec_step_fwd(&y, &xe, &thd, h, &dm, seq_enc);
            let mut out = vec![f32::NAN; y.len()];
            dec_step_fwd_into(y.data(), xe.data(), &thd, h, &dm, seq_enc, &mut out, &mut s);
            assert_eq!(out, want.data());

            let (wdy, wdxe, wgt) = dec_step_bwd(&y, &xe, &thd, h, &dm, seq_enc, &ct);
            let mut dy = vec![f32::NAN; y.len()];
            let mut dxe = vec![f32::NAN; xe.len()];
            let mut gt = vec![0.0; thd.len()];
            dec_step_bwd_into(
                y.data(), xe.data(), &thd, h, &dm, seq_enc, ct.data(),
                &mut dy, &mut dxe, &mut gt, &mut s,
            );
            assert_eq!(dy, wdy.data());
            assert_eq!(dxe, wdxe.data());
            assert_eq!(gt, wgt.as_slice());
        }
    }

    #[test]
    fn cached_enc_step_matches_full_board_rows_bitwise() {
        // Walk the board left to right with the cached kernel (each call
        // appends its K/V column) and pin every advanced row against the
        // same row of the full causal board step, bit for bit. Then pin
        // the prefill path: fill_self_kv over all columns must land the
        // exact column bits the appends did.
        let dm = dims();
        let (b, sq, d, nh, hd) = (dm.batch, dm.seq, dm.d_model, dm.n_heads, dm.head_dim());
        let mut rng = Rng::new(7);
        let mut s = Scratch::new();
        let theta = rng.normal_vec(p_enc(&dm), 0.2);
        let x = Tensor::randn(&mut rng, &[b, sq, d], 1.0);
        let h = 0.4;
        let want = enc_step_fwd(&x, &theta, h, &dm, true);

        let dm1 = RefDims { seq: 1, ..dm };
        let slab = b * nh * sq * hd;
        let (mut kc, mut vc) = (vec![0.0; slab], vec![0.0; slab]);
        let mut xrow = vec![0.0; b * d];
        let mut out = vec![f32::NAN; b * d];
        let mut positions = vec![0usize; b];
        for pos in 0..sq {
            for bi in 0..b {
                let off = (bi * sq + pos) * d;
                xrow[bi * d..(bi + 1) * d].copy_from_slice(&x.data()[off..off + d]);
            }
            positions.iter_mut().for_each(|p| *p = pos);
            enc_step_fwd_cached(&xrow, &theta, h, &dm1, sq, &positions, &mut kc, &mut vc,
                                &mut out, &mut s);
            for bi in 0..b {
                let off = (bi * sq + pos) * d;
                assert_eq!(
                    &out[bi * d..(bi + 1) * d],
                    &want.data()[off..off + d],
                    "cached row b={} pos={}",
                    bi,
                    pos
                );
            }
        }

        let (mut kf, mut vf) = (vec![0.0; slab], vec![0.0; slab]);
        let p = EncParams::view(&theta, d, dm.d_ff);
        let from = vec![0usize; b];
        let to = vec![sq - 1; b];
        fill_self_kv(x.data(), p.ln1_g, p.ln1_b, p.wk, p.wv, b, sq, d, nh, sq, &from, &to,
                     &mut kf, &mut vf, &mut s);
        assert_eq!(kf, kc, "prefilled K columns differ from appended ones");
        assert_eq!(vf, vc, "prefilled V columns differ from appended ones");
    }

    #[test]
    fn cached_dec_step_matches_full_board_rows_bitwise() {
        let dm = dims();
        let seq_enc = 5;
        let (b, sq, d, nh, hd) = (dm.batch, dm.seq, dm.d_model, dm.n_heads, dm.head_dim());
        let mut rng = Rng::new(8);
        let mut s = Scratch::new();
        let theta = rng.normal_vec(p_dec(&dm), 0.2);
        let y = Tensor::randn(&mut rng, &[b, sq, d], 1.0);
        let xe = Tensor::randn(&mut rng, &[b, seq_enc, d], 1.0);
        let h = 0.6;
        let want = dec_step_fwd(&y, &xe, &theta, h, &dm, seq_enc);

        let dm1 = RefDims { seq: 1, ..dm };
        let slab = b * nh * sq * hd;
        let cslab = b * nh * seq_enc * hd;
        let p = DecParams::view(&theta, d, dm.d_ff);
        let (mut kc, mut vc) = (vec![0.0; slab], vec![0.0; slab]);
        let (mut ck, mut cv) = (vec![0.0; cslab], vec![0.0; cslab]);
        fill_cross_kv(xe.data(), p.ck, p.cv, b, seq_enc, d, nh, seq_enc, &mut ck, &mut cv, &mut s);

        let mut yrow = vec![0.0; b * d];
        let mut out = vec![f32::NAN; b * d];
        let mut positions = vec![0usize; b];
        for pos in 0..sq {
            for bi in 0..b {
                let off = (bi * sq + pos) * d;
                yrow[bi * d..(bi + 1) * d].copy_from_slice(&y.data()[off..off + d]);
            }
            positions.iter_mut().for_each(|p| *p = pos);
            dec_step_fwd_cached(&yrow, &theta, h, &dm1, sq, &positions, &mut kc, &mut vc,
                                seq_enc, seq_enc, &mut ck, &mut cv, &mut out, &mut s);
            for bi in 0..b {
                let off = (bi * sq + pos) * d;
                assert_eq!(
                    &out[bi * d..(bi + 1) * d],
                    &want.data()[off..off + d],
                    "cached dec row b={} pos={}",
                    bi,
                    pos
                );
            }
        }
    }

    #[test]
    fn cached_rows_are_batch_independent() {
        // Append row 0 alone vs alongside a second, different row: row
        // 0's output and cache columns must not change (the serve
        // occupancy-independence contract at the kernel level).
        let dm = RefDims { batch: 1, seq: 4, d_model: 8, n_heads: 2, d_ff: 16 };
        let dm2 = RefDims { batch: 2, ..dm };
        let (sq, d, nh, hd) = (dm.seq, dm.d_model, dm.n_heads, dm.head_dim());
        let mut rng = Rng::new(9);
        let mut s = Scratch::new();
        let theta = rng.normal_vec(p_enc(&dm), 0.2);
        let x = Tensor::randn(&mut rng, &[2, sq, d], 1.0);

        let solo_slab = nh * sq * hd;
        let (mut k1, mut v1) = (vec![0.0; solo_slab], vec![0.0; solo_slab]);
        let (mut k2, mut v2) = (vec![0.0; 2 * solo_slab], vec![0.0; 2 * solo_slab]);
        let dm1 = RefDims { seq: 1, ..dm };
        let dm21 = RefDims { seq: 1, ..dm2 };
        let mut out1 = vec![0.0; d];
        let mut out2 = vec![0.0; 2 * d];
        for pos in 0..sq {
            let row0 = &x.data()[pos * d..(pos + 1) * d];
            enc_step_fwd_cached(row0, &theta, 0.5, &dm1, sq, &[pos], &mut k1, &mut v1, &mut out1,
                                &mut s);
            let mut both = vec![0.0; 2 * d];
            both[..d].copy_from_slice(row0);
            both[d..].copy_from_slice(&x.data()[(sq + pos) * d..(sq + pos + 1) * d]);
            enc_step_fwd_cached(&both, &theta, 0.5, &dm21, sq, &[pos, pos], &mut k2, &mut v2,
                                &mut out2, &mut s);
            assert_eq!(out1, out2[..d], "row 0 output depends on occupancy at pos {}", pos);
        }
        assert_eq!(k1, k2[..solo_slab], "row 0 K columns depend on the neighbour row");
        assert_eq!(v1, v2[..solo_slab], "row 0 V columns depend on the neighbour row");
    }

    #[test]
    fn causal_step_no_future_dependence() {
        let dm = dims();
        let mut rng = Rng::new(2);
        let x = Tensor::randn(&mut rng, &[dm.batch, dm.seq, dm.d_model], 1.0);
        let theta = rng.normal_vec(p_enc(&dm), 0.3);
        let base = enc_step_fwd(&x, &theta, 1.0, &dm, true);
        let mut x2 = x.clone();
        // perturb last position of each sequence
        let d = dm.d_model;
        for b in 0..dm.batch {
            let off = (b * dm.seq + dm.seq - 1) * d;
            for i in 0..d {
                x2.data_mut()[off + i] += 5.0;
            }
        }
        let pert = enc_step_fwd(&x2, &theta, 1.0, &dm, true);
        for b in 0..dm.batch {
            for t in 0..dm.seq - 1 {
                let off = (b * dm.seq + t) * d;
                for i in 0..d {
                    assert!(
                        (base.data()[off + i] - pert.data()[off + i]).abs() < 1e-5,
                        "future leaked at b={} t={}",
                        b,
                        t
                    );
                }
            }
        }
    }

    /// Central finite-difference check of the full encoder-step VJP.
    #[test]
    fn enc_step_bwd_matches_fd() {
        let dm = RefDims { batch: 1, seq: 3, d_model: 4, n_heads: 2, d_ff: 8 };
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&mut rng, &[1, dm.seq, dm.d_model], 0.7);
        let theta = rng.normal_vec(p_enc(&dm), 0.2);
        let ct = Tensor::randn(&mut rng, &[1, dm.seq, dm.d_model], 1.0);
        let h = 0.7;
        let (dx, dth) = enc_step_bwd(&x, &theta, h, &dm, false, &ct);

        let f_x = |xv: &Tensor| enc_step_fwd(xv, &theta, h, &dm, false).dot(&ct);
        let eps = 2e-3;
        for i in 0..x.len() {
            let mut xp = x.clone();
            xp.data_mut()[i] += eps;
            let mut xm = x.clone();
            xm.data_mut()[i] -= eps;
            let fd = (f_x(&xp) - f_x(&xm)) / (2.0 * eps);
            assert!(
                (dx.data()[i] - fd).abs() < 3e-2 * (1.0 + fd.abs()),
                "dx[{}]={} fd={}",
                i,
                dx.data()[i],
                fd
            );
        }
        // spot-check a spread of parameter coordinates
        let f_t = |tv: &[f32]| enc_step_fwd(&x, tv, h, &dm, false).dot(&ct);
        let stride = (theta.len() / 23).max(1);
        for i in (0..theta.len()).step_by(stride) {
            let mut tp = theta.clone();
            tp[i] += eps;
            let mut tm = theta.clone();
            tm[i] -= eps;
            let fd = (f_t(&tp) - f_t(&tm)) / (2.0 * eps);
            assert!(
                (dth[i] - fd).abs() < 3e-2 * (1.0 + fd.abs()),
                "dtheta[{}]={} fd={}",
                i,
                dth[i],
                fd
            );
        }
    }

    #[test]
    fn dec_step_bwd_matches_fd() {
        let dm = RefDims { batch: 1, seq: 3, d_model: 4, n_heads: 2, d_ff: 8 };
        let seq_enc = 5;
        let mut rng = Rng::new(4);
        let y = Tensor::randn(&mut rng, &[1, dm.seq, dm.d_model], 0.7);
        let xe = Tensor::randn(&mut rng, &[1, seq_enc, dm.d_model], 0.7);
        let theta = rng.normal_vec(p_dec(&dm), 0.2);
        let ct = Tensor::randn(&mut rng, &[1, dm.seq, dm.d_model], 1.0);
        let h = 0.5;
        let (dy, dxe, dth) = dec_step_bwd(&y, &xe, &theta, h, &dm, seq_enc, &ct);

        let eps = 2e-3;
        let f_y = |yv: &Tensor| dec_step_fwd(yv, &xe, &theta, h, &dm, seq_enc).dot(&ct);
        for i in 0..y.len() {
            let mut yp = y.clone();
            yp.data_mut()[i] += eps;
            let mut ym = y.clone();
            ym.data_mut()[i] -= eps;
            let fd = (f_y(&yp) - f_y(&ym)) / (2.0 * eps);
            assert!((dy.data()[i] - fd).abs() < 3e-2 * (1.0 + fd.abs()), "dy[{}]", i);
        }
        let f_e = |ev: &Tensor| dec_step_fwd(&y, ev, &theta, h, &dm, seq_enc).dot(&ct);
        for i in 0..xe.len() {
            let mut ep = xe.clone();
            ep.data_mut()[i] += eps;
            let mut em = xe.clone();
            em.data_mut()[i] -= eps;
            let fd = (f_e(&ep) - f_e(&em)) / (2.0 * eps);
            assert!((dxe.data()[i] - fd).abs() < 3e-2 * (1.0 + fd.abs()), "dxe[{}]", i);
        }
        let f_t = |tv: &[f32]| dec_step_fwd(&y, &xe, tv, h, &dm, seq_enc).dot(&ct);
        let stride = (theta.len() / 19).max(1);
        for i in (0..theta.len()).step_by(stride) {
            let mut tp = theta.clone();
            tp[i] += eps;
            let mut tm = theta.clone();
            tm[i] -= eps;
            let fd = (f_t(&tp) - f_t(&tm)) / (2.0 * eps);
            assert!((dth[i] - fd).abs() < 3e-2 * (1.0 + fd.abs()), "dth[{}]", i);
        }
    }
}
