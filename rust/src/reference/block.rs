//! Neural-ODE transformer step Φ: forward and hand-derived backward.
//!
//! Matches `ref.py` exactly (pre-LN, tanh-GELU, eq. 1-3):
//!
//!   encoder:  x' = x + h (φ1(x) + φ2(x + φ1(x)))
//!   decoder:  ȳ  = φ1(y) + φ3(y + φ1(y), X_enc)
//!             y' = y + h (ȳ + φ2(y + ȳ))
//!
//! The backward functions recompute the forward internally (no cache
//! plumbing — this path is a correctness oracle, not the hot path) and
//! return the adjoint state λ plus flat parameter gradients.

use super::math::{gelu, gelu_grad, layer_norm_bwd, layer_norm_fwd};
use super::params::{DecGrads, DecParams, EncGrads, EncParams};
use crate::tensor::Tensor;

/// Shape context for one Φ application.
#[derive(Debug, Clone, Copy)]
pub struct RefDims {
    pub batch: usize,
    pub seq: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
}

impl RefDims {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn rows(&self) -> usize {
        self.batch * self.seq
    }
}

// ---------------------------------------------------------------------------
// raw matmul helpers (row-major slices)
// ---------------------------------------------------------------------------

/// out (+)= a[m,k] @ b[k,n]
fn mm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32], acc: bool) {
    if !acc {
        out.iter_mut().for_each(|v| *v = 0.0);
    }
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// out += aᵀ @ b where a is [k,m], b is [k,n] -> out [m,n] (weight grads)
fn mm_at(a: &[f32], b: &[f32], k: usize, m: usize, n: usize, out: &mut [f32]) {
    for kk in 0..k {
        let arow = &a[kk * m..(kk + 1) * m];
        let brow = &b[kk * n..(kk + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// out += a @ bᵀ where a is [m,k], b is [n,k] -> out [m,n] (input grads)
fn mm_bt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0;
            for kk in 0..k {
                acc += arow[kk] * brow[kk];
            }
            *o += acc;
        }
    }
}

/// Copy head block h of a [b, s, d] activation into a contiguous [s, hd] buffer.
fn gather_head(src: &[f32], b: usize, s: usize, d: usize, h: usize, hd: usize, out: &mut [f32]) {
    for t in 0..s {
        let base = (b * s + t) * d + h * hd;
        out[t * hd..(t + 1) * hd].copy_from_slice(&src[base..base + hd]);
    }
}

/// Accumulate a contiguous [s, hd] head buffer back into [b, s, d] layout.
fn scatter_head_add(dst: &mut [f32], b: usize, s: usize, d: usize, h: usize, hd: usize, src: &[f32]) {
    for t in 0..s {
        let base = (b * s + t) * d + h * hd;
        for i in 0..hd {
            dst[base + i] += src[t * hd + i];
        }
    }
}

/// Row-wise softmax with optional causal mask; operates on [sq, sk].
fn masked_softmax(scores: &mut [f32], sq: usize, sk: usize, causal: bool) {
    for qi in 0..sq {
        let row = &mut scores[qi * sk..(qi + 1) * sk];
        if causal {
            // allow k <= q + (sk - sq)  (matches ref.py tril with k = sk-sq)
            let limit = qi + (sk - sq);
            for (ki, v) in row.iter_mut().enumerate() {
                if ki > limit {
                    *v = f32::NEG_INFINITY;
                }
            }
        }
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        row.iter_mut().for_each(|v| *v *= inv);
    }
}

// ---------------------------------------------------------------------------
// attention (generic over self/cross): q from zq [bq rows], k/v from kv
// ---------------------------------------------------------------------------

struct AttnShapes {
    batch: usize,
    sq: usize,
    sk: usize,
    d: usize,
    nh: usize,
}

/// merged = MHA_core(zq @ wq, kv @ wk, kv @ wv); out = merged @ wo
#[allow(clippy::too_many_arguments)]
fn attention_fwd(
    zq: &[f32],
    kv: &[f32],
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    wo: &[f32],
    sh: &AttnShapes,
    causal: bool,
    out: &mut [f32],
) {
    let AttnShapes { batch, sq, sk, d, nh } = *sh;
    let hd = d / nh;
    let scale = 1.0 / (hd as f32).sqrt();
    let (rq, rk) = (batch * sq, batch * sk);

    let mut q = vec![0.0; rq * d];
    let mut k = vec![0.0; rk * d];
    let mut v = vec![0.0; rk * d];
    mm(zq, wq, rq, d, d, &mut q, false);
    mm(kv, wk, rk, d, d, &mut k, false);
    mm(kv, wv, rk, d, d, &mut v, false);

    let mut merged = vec![0.0; rq * d];
    let mut qh = vec![0.0; sq * hd];
    let mut kh = vec![0.0; sk * hd];
    let mut vh = vec![0.0; sk * hd];
    let mut scores = vec![0.0; sq * sk];
    let mut oh = vec![0.0; sq * hd];
    for b in 0..batch {
        for h in 0..nh {
            gather_head(&q, b, sq, d, h, hd, &mut qh);
            gather_head(&k, b, sk, d, h, hd, &mut kh);
            gather_head(&v, b, sk, d, h, hd, &mut vh);
            mm_bt(&qh, &kh, sq, hd, sk, {
                scores.iter_mut().for_each(|x| *x = 0.0);
                &mut scores
            });
            scores.iter_mut().for_each(|x| *x *= scale);
            masked_softmax(&mut scores, sq, sk, causal);
            mm(&scores, &vh, sq, sk, hd, &mut oh, false);
            scatter_head_add(&mut merged, b, sq, d, h, hd, &oh);
        }
    }
    mm(&merged, wo, rq, d, d, out, false);
}

/// Backward of `attention_fwd` (recomputes internals).
/// Accumulates d_zq, d_kv and the four weight grads.
#[allow(clippy::too_many_arguments)]
fn attention_bwd(
    zq: &[f32],
    kv: &[f32],
    wq: &[f32],
    wk: &[f32],
    wv: &[f32],
    wo: &[f32],
    sh: &AttnShapes,
    causal: bool,
    d_out: &[f32],
    d_zq: &mut [f32],
    d_kv: &mut [f32],
    dwq: &mut [f32],
    dwk: &mut [f32],
    dwv: &mut [f32],
    dwo: &mut [f32],
) {
    let AttnShapes { batch, sq, sk, d, nh } = *sh;
    let hd = d / nh;
    let scale = 1.0 / (hd as f32).sqrt();
    let (rq, rk) = (batch * sq, batch * sk);

    // recompute projections
    let mut q = vec![0.0; rq * d];
    let mut k = vec![0.0; rk * d];
    let mut v = vec![0.0; rk * d];
    mm(zq, wq, rq, d, d, &mut q, false);
    mm(kv, wk, rk, d, d, &mut k, false);
    mm(kv, wv, rk, d, d, &mut v, false);

    // recompute merged (needed for dwo)
    let mut merged = vec![0.0; rq * d];
    {
        let mut qh = vec![0.0; sq * hd];
        let mut kh = vec![0.0; sk * hd];
        let mut vh = vec![0.0; sk * hd];
        let mut scores = vec![0.0; sq * sk];
        let mut oh = vec![0.0; sq * hd];
        for b in 0..batch {
            for h in 0..nh {
                gather_head(&q, b, sq, d, h, hd, &mut qh);
                gather_head(&k, b, sk, d, h, hd, &mut kh);
                gather_head(&v, b, sk, d, h, hd, &mut vh);
                scores.iter_mut().for_each(|x| *x = 0.0);
                mm_bt(&qh, &kh, sq, hd, sk, &mut scores);
                scores.iter_mut().for_each(|x| *x *= scale);
                masked_softmax(&mut scores, sq, sk, causal);
                mm(&scores, &vh, sq, sk, hd, &mut oh, false);
                scatter_head_add(&mut merged, b, sq, d, h, hd, &oh);
            }
        }
    }

    // out = merged @ wo
    mm_at(&merged, d_out, rq, d, d, dwo);
    let mut d_merged = vec![0.0; rq * d];
    mm_bt(d_out, wo, rq, d, d, &mut d_merged);

    let mut dq = vec![0.0; rq * d];
    let mut dk = vec![0.0; rk * d];
    let mut dv = vec![0.0; rk * d];
    {
        let mut qh = vec![0.0; sq * hd];
        let mut kh = vec![0.0; sk * hd];
        let mut vh = vec![0.0; sk * hd];
        let mut p = vec![0.0; sq * sk];
        let mut doh = vec![0.0; sq * hd];
        let mut dp = vec![0.0; sq * sk];
        let mut ds = vec![0.0; sq * sk];
        let mut dqh = vec![0.0; sq * hd];
        let mut dkh = vec![0.0; sk * hd];
        let mut dvh = vec![0.0; sk * hd];
        for b in 0..batch {
            for h in 0..nh {
                gather_head(&q, b, sq, d, h, hd, &mut qh);
                gather_head(&k, b, sk, d, h, hd, &mut kh);
                gather_head(&v, b, sk, d, h, hd, &mut vh);
                p.iter_mut().for_each(|x| *x = 0.0);
                mm_bt(&qh, &kh, sq, hd, sk, &mut p);
                p.iter_mut().for_each(|x| *x *= scale);
                masked_softmax(&mut p, sq, sk, causal);

                gather_head(&d_merged, b, sq, d, h, hd, &mut doh);
                // dP = dO @ Vᵀ ; dV = Pᵀ @ dO
                dp.iter_mut().for_each(|x| *x = 0.0);
                mm_bt(&doh, &vh, sq, hd, sk, &mut dp);
                dvh.iter_mut().for_each(|x| *x = 0.0);
                mm_at(&p, &doh, sq, sk, hd, &mut dvh);
                // softmax backward: dS = P ∘ (dP - rowsum(dP ∘ P))
                for qi in 0..sq {
                    let prow = &p[qi * sk..(qi + 1) * sk];
                    let dprow = &dp[qi * sk..(qi + 1) * sk];
                    let dot: f32 = prow.iter().zip(dprow).map(|(a, b2)| a * b2).sum();
                    let dsrow = &mut ds[qi * sk..(qi + 1) * sk];
                    for ki in 0..sk {
                        dsrow[ki] = prow[ki] * (dprow[ki] - dot);
                    }
                }
                // dQ = scale * dS @ K ; dK = scale * dSᵀ @ Q
                dqh.iter_mut().for_each(|x| *x = 0.0);
                mm(&ds, &kh, sq, sk, hd, &mut dqh, false);
                dqh.iter_mut().for_each(|x| *x *= scale);
                dkh.iter_mut().for_each(|x| *x = 0.0);
                mm_at(&ds, &qh, sq, sk, hd, &mut dkh);
                dkh.iter_mut().for_each(|x| *x *= scale);

                scatter_head_add(&mut dq, b, sq, d, h, hd, &dqh);
                scatter_head_add(&mut dk, b, sk, d, h, hd, &dkh);
                scatter_head_add(&mut dv, b, sk, d, h, hd, &dvh);
            }
        }
    }

    // projection backward
    mm_bt(&dq, wq, rq, d, d, d_zq);
    mm_bt(&dk, wk, rk, d, d, d_kv);
    mm_bt(&dv, wv, rk, d, d, d_kv);
    mm_at(zq, &dq, rq, d, d, dwq);
    mm_at(kv, &dk, rk, d, d, dwk);
    mm_at(kv, &dv, rk, d, d, dwv);
}

// ---------------------------------------------------------------------------
// phi sublayers
// ---------------------------------------------------------------------------

/// φ1(x) = SA(LN1(x)) — forward.
fn phi1_fwd(x: &[f32], p: &EncParams, dm: &RefDims, causal: bool, out: &mut [f32]) {
    let (r, d) = (dm.rows(), dm.d_model);
    let mut z = vec![0.0; r * d];
    layer_norm_fwd(x, p.ln1_g, p.ln1_b, d, &mut z);
    let sh = AttnShapes { batch: dm.batch, sq: dm.seq, sk: dm.seq, d, nh: dm.n_heads };
    attention_fwd(&z, &z, p.wq, p.wk, p.wv, p.wo, &sh, causal, out);
}

/// φ1 backward: accumulates dx and parameter grads.
fn phi1_bwd(
    x: &[f32],
    p: &EncParams,
    g: &mut EncGrads,
    dm: &RefDims,
    causal: bool,
    d_out: &[f32],
    dx: &mut [f32],
) {
    let (r, d) = (dm.rows(), dm.d_model);
    let mut z = vec![0.0; r * d];
    let stats = layer_norm_fwd(x, p.ln1_g, p.ln1_b, d, &mut z);
    let sh = AttnShapes { batch: dm.batch, sq: dm.seq, sk: dm.seq, d, nh: dm.n_heads };
    // self-attention: zq and kv are the SAME tensor -> sum both grad paths
    let mut dz_q = vec![0.0; r * d];
    let mut dz_kv = vec![0.0; r * d];
    attention_bwd(&z, &z, p.wq, p.wk, p.wv, p.wo, &sh, causal, d_out, &mut dz_q, &mut dz_kv,
                  g.wq, g.wk, g.wv, g.wo);
    for (a2, b2) in dz_q.iter_mut().zip(&dz_kv) {
        *a2 += b2;
    }
    layer_norm_bwd(&dz_q, x, p.ln1_g, &stats, d, dx, g.ln1_g, g.ln1_b);
}

/// φ2(u) = MLP(LN2(u)) — forward.
fn phi2_fwd(u: &[f32], p: &EncParams, dm: &RefDims, out: &mut [f32]) {
    let (r, d, f) = (dm.rows(), dm.d_model, dm.d_ff);
    let mut z = vec![0.0; r * d];
    layer_norm_fwd(u, p.ln2_g, p.ln2_b, d, &mut z);
    let mut hpre = vec![0.0; r * f];
    mm(&z, p.w1, r, d, f, &mut hpre, false);
    for row in 0..r {
        for j in 0..f {
            hpre[row * f + j] += p.b1[j];
        }
    }
    let hmid: Vec<f32> = hpre.iter().map(|&v| gelu(v)).collect();
    mm(&hmid, p.w2, r, f, d, out, false);
    for row in 0..r {
        for j in 0..d {
            out[row * d + j] += p.b2[j];
        }
    }
}

/// φ2 backward: accumulates du and parameter grads.
fn phi2_bwd(
    u: &[f32],
    p: &EncParams,
    g: &mut EncGrads,
    dm: &RefDims,
    d_out: &[f32],
    du: &mut [f32],
) {
    let (r, d, f) = (dm.rows(), dm.d_model, dm.d_ff);
    let mut z = vec![0.0; r * d];
    let stats = layer_norm_fwd(u, p.ln2_g, p.ln2_b, d, &mut z);
    let mut hpre = vec![0.0; r * f];
    mm(&z, p.w1, r, d, f, &mut hpre, false);
    for row in 0..r {
        for j in 0..f {
            hpre[row * f + j] += p.b1[j];
        }
    }
    let hmid: Vec<f32> = hpre.iter().map(|&v| gelu(v)).collect();

    // out = hmid @ w2 + b2
    mm_at(&hmid, d_out, r, f, d, g.w2);
    for row in 0..r {
        for j in 0..d {
            g.b2[j] += d_out[row * d + j];
        }
    }
    let mut d_hmid = vec![0.0; r * f];
    mm_bt(d_out, p.w2, r, d, f, &mut d_hmid);
    // gelu
    let d_hpre: Vec<f32> =
        d_hmid.iter().zip(&hpre).map(|(dh, &hp)| dh * gelu_grad(hp)).collect();
    // hpre = z @ w1 + b1
    mm_at(&z, &d_hpre, r, d, f, g.w1);
    for row in 0..r {
        for j in 0..f {
            g.b1[j] += d_hpre[row * f + j];
        }
    }
    let mut dz = vec![0.0; r * d];
    mm_bt(&d_hpre, p.w1, r, f, d, &mut dz);
    layer_norm_bwd(&dz, u, p.ln2_g, &stats, d, du, g.ln2_g, g.ln2_b);
}

/// φ3(u, x_enc) = CA(LN3(u), x_enc) — forward. Keys/values from raw x_enc
/// (not layer-normed), matching ref.py.
fn phi3_fwd(
    u: &[f32],
    x_enc: &[f32],
    p: &DecParams,
    dm_q: &RefDims,
    seq_k: usize,
    out: &mut [f32],
) {
    let (r, d) = (dm_q.rows(), dm_q.d_model);
    let mut z = vec![0.0; r * d];
    layer_norm_fwd(u, p.ln3_g, p.ln3_b, d, &mut z);
    let sh = AttnShapes { batch: dm_q.batch, sq: dm_q.seq, sk: seq_k, d, nh: dm_q.n_heads };
    attention_fwd(&z, x_enc, p.cq, p.ck, p.cv, p.co, &sh, false, out);
}

/// φ3 backward: accumulates du, dx_enc and parameter grads.
#[allow(clippy::too_many_arguments)]
fn phi3_bwd(
    u: &[f32],
    x_enc: &[f32],
    p: &DecParams,
    g: &mut DecGrads,
    dm_q: &RefDims,
    seq_k: usize,
    d_out: &[f32],
    du: &mut [f32],
    dx_enc: &mut [f32],
) {
    let (r, d) = (dm_q.rows(), dm_q.d_model);
    let mut z = vec![0.0; r * d];
    let stats = layer_norm_fwd(u, p.ln3_g, p.ln3_b, d, &mut z);
    let sh = AttnShapes { batch: dm_q.batch, sq: dm_q.seq, sk: seq_k, d, nh: dm_q.n_heads };
    let mut dz = vec![0.0; r * d];
    attention_bwd(&z, x_enc, p.cq, p.ck, p.cv, p.co, &sh, false, d_out, &mut dz, dx_enc,
                  g.cq, g.ck, g.cv, g.co);
    layer_norm_bwd(&dz, u, p.ln3_g, &stats, d, du, g.ln3_g, g.ln3_b);
}

// ---------------------------------------------------------------------------
// public step functions
// ---------------------------------------------------------------------------

/// Encoder (or causal decoder-only) step: x' = x + h (φ1(x) + φ2(x + φ1(x))).
pub fn enc_step_fwd(x: &Tensor, theta: &[f32], h: f32, dm: &RefDims, causal: bool) -> Tensor {
    let p = EncParams::view(theta, dm.d_model, dm.d_ff);
    let n = x.len();
    let mut a = vec![0.0; n];
    phi1_fwd(x.data(), &p, dm, causal, &mut a);
    let u: Vec<f32> = x.data().iter().zip(&a).map(|(xv, av)| xv + av).collect();
    let mut m = vec![0.0; n];
    phi2_fwd(&u, &p, dm, &mut m);
    let out: Vec<f32> = x
        .data()
        .iter()
        .zip(a.iter().zip(&m))
        .map(|(xv, (av, mv))| xv + h * (av + mv))
        .collect();
    Tensor::from_vec(out, x.shape())
}

/// Encoder step VJP: returns (λ = ∂/∂x, grad_theta) for upstream ct.
pub fn enc_step_bwd(
    x: &Tensor,
    theta: &[f32],
    h: f32,
    dm: &RefDims,
    causal: bool,
    ct: &Tensor,
) -> (Tensor, Vec<f32>) {
    let p = EncParams::view(theta, dm.d_model, dm.d_ff);
    let mut gtheta = vec![0.0; theta.len()];
    let n = x.len();

    // forward pieces needed: a = φ1(x), u = x + a
    let mut a = vec![0.0; n];
    phi1_fwd(x.data(), &p, dm, causal, &mut a);
    let u: Vec<f32> = x.data().iter().zip(&a).map(|(xv, av)| xv + av).collect();

    // out = x + h (a + m), m = φ2(u)
    let d_out = ct.data();
    let d_f: Vec<f32> = d_out.iter().map(|v| h * v).collect(); // into (a + m)
    let mut dx: Vec<f32> = d_out.to_vec(); // identity path

    // φ2 path
    let mut du = vec![0.0; n];
    {
        let mut g = EncGrads::view(&mut gtheta, dm.d_model, dm.d_ff);
        phi2_bwd(&u, &p, &mut g, dm, &d_f, &mut du);
    }
    // u = x + a
    for i in 0..n {
        dx[i] += du[i];
    }
    // total gradient into a: direct h·ct + via u
    let da: Vec<f32> = d_f.iter().zip(&du).map(|(dfv, duv)| dfv + duv).collect();
    {
        let mut g = EncGrads::view(&mut gtheta, dm.d_model, dm.d_ff);
        phi1_bwd(x.data(), &p, &mut g, dm, causal, &da, &mut dx);
    }
    (Tensor::from_vec(dx, x.shape()), gtheta)
}

/// Encoder-decoder decoder step (eq. 2).
pub fn dec_step_fwd(
    y: &Tensor,
    x_enc: &Tensor,
    theta: &[f32],
    h: f32,
    dm: &RefDims,
    seq_enc: usize,
) -> Tensor {
    let p = DecParams::view(theta, dm.d_model, dm.d_ff);
    let n = y.len();
    let mut a = vec![0.0; n];
    phi1_fwd(y.data(), &p.enc, dm, true, &mut a);
    let u3: Vec<f32> = y.data().iter().zip(&a).map(|(yv, av)| yv + av).collect();
    let mut c = vec![0.0; n];
    phi3_fwd(&u3, x_enc.data(), &p, dm, seq_enc, &mut c);
    let ybar: Vec<f32> = a.iter().zip(&c).map(|(av, cv)| av + cv).collect();
    let u2: Vec<f32> = y.data().iter().zip(&ybar).map(|(yv, bv)| yv + bv).collect();
    let mut m = vec![0.0; n];
    phi2_fwd(&u2, &p.enc, dm, &mut m);
    let out: Vec<f32> = y
        .data()
        .iter()
        .zip(ybar.iter().zip(&m))
        .map(|(yv, (bv, mv))| yv + h * (bv + mv))
        .collect();
    Tensor::from_vec(out, y.shape())
}

/// Decoder step VJP: returns (λ_y, λ_x_enc, grad_theta).
pub fn dec_step_bwd(
    y: &Tensor,
    x_enc: &Tensor,
    theta: &[f32],
    h: f32,
    dm: &RefDims,
    seq_enc: usize,
    ct: &Tensor,
) -> (Tensor, Tensor, Vec<f32>) {
    let p = DecParams::view(theta, dm.d_model, dm.d_ff);
    let mut gtheta = vec![0.0; theta.len()];
    let n = y.len();

    // recompute forward pieces
    let mut a = vec![0.0; n];
    phi1_fwd(y.data(), &p.enc, dm, true, &mut a);
    let u3: Vec<f32> = y.data().iter().zip(&a).map(|(yv, av)| yv + av).collect();
    let mut c = vec![0.0; n];
    phi3_fwd(&u3, x_enc.data(), &p, dm, seq_enc, &mut c);
    let ybar: Vec<f32> = a.iter().zip(&c).map(|(av, cv)| av + cv).collect();
    let u2: Vec<f32> = y.data().iter().zip(&ybar).map(|(yv, bv)| yv + bv).collect();

    // out = y + h (ybar + m)
    let d_out = ct.data();
    let d_f: Vec<f32> = d_out.iter().map(|v| h * v).collect();
    let mut dy: Vec<f32> = d_out.to_vec();
    let mut dx_enc = vec![0.0; x_enc.len()];

    // φ2 path at u2
    let mut du2 = vec![0.0; n];
    {
        let mut g = DecGrads::view(&mut gtheta, dm.d_model, dm.d_ff);
        phi2_bwd(&u2, &p.enc, &mut g.enc, dm, &d_f, &mut du2);
    }
    for i in 0..n {
        dy[i] += du2[i];
    }
    // d_ybar = h·ct (direct) + du2 (via u2)
    let d_ybar: Vec<f32> = d_f.iter().zip(&du2).map(|(a2, b2)| a2 + b2).collect();

    // ybar = a + φ3(u3, x_enc):  d_a += d_ybar;  φ3 gets d_ybar
    let mut du3 = vec![0.0; n];
    {
        let mut g = DecGrads::view(&mut gtheta, dm.d_model, dm.d_ff);
        phi3_bwd(&u3, x_enc.data(), &p, &mut g, dm, seq_enc, &d_ybar, &mut du3, &mut dx_enc);
    }
    // u3 = y + a
    for i in 0..n {
        dy[i] += du3[i];
    }
    let da: Vec<f32> = d_ybar.iter().zip(&du3).map(|(a2, b2)| a2 + b2).collect();
    {
        let mut g = DecGrads::view(&mut gtheta, dm.d_model, dm.d_ff);
        phi1_bwd(y.data(), &p.enc, &mut g.enc, dm, true, &da, &mut dy);
    }
    (
        Tensor::from_vec(dy, y.shape()),
        Tensor::from_vec(dx_enc, x_enc.shape()),
        gtheta,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn dims() -> RefDims {
        RefDims { batch: 2, seq: 4, d_model: 8, n_heads: 2, d_ff: 16 }
    }

    fn p_enc(dm: &RefDims) -> usize {
        let (d, f) = (dm.d_model, dm.d_ff);
        4 * d * d + 2 * d * f + 5 * d + f
    }

    fn p_dec(dm: &RefDims) -> usize {
        p_enc(dm) + 2 * dm.d_model + 4 * dm.d_model * dm.d_model
    }

    #[test]
    fn enc_step_h_zero_is_identity() {
        let dm = dims();
        let mut rng = Rng::new(0);
        let x = Tensor::randn(&mut rng, &[dm.batch, dm.seq, dm.d_model], 1.0);
        let theta = rng.normal_vec(p_enc(&dm), 0.1);
        let out = enc_step_fwd(&x, &theta, 0.0, &dm, false);
        assert!(out.allclose(&x, 1e-6, 1e-6));
    }

    #[test]
    fn enc_step_residual_linear_in_h() {
        let dm = dims();
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&mut rng, &[dm.batch, dm.seq, dm.d_model], 1.0);
        let theta = rng.normal_vec(p_enc(&dm), 0.1);
        let d1 = enc_step_fwd(&x, &theta, 0.1, &dm, false).sub(&x);
        let mut d2 = enc_step_fwd(&x, &theta, 0.2, &dm, false).sub(&x);
        d2.scale(0.5);
        assert!(d1.allclose(&d2, 1e-4, 1e-5));
    }

    #[test]
    fn causal_step_no_future_dependence() {
        let dm = dims();
        let mut rng = Rng::new(2);
        let x = Tensor::randn(&mut rng, &[dm.batch, dm.seq, dm.d_model], 1.0);
        let theta = rng.normal_vec(p_enc(&dm), 0.3);
        let base = enc_step_fwd(&x, &theta, 1.0, &dm, true);
        let mut x2 = x.clone();
        // perturb last position of each sequence
        let d = dm.d_model;
        for b in 0..dm.batch {
            let off = (b * dm.seq + dm.seq - 1) * d;
            for i in 0..d {
                x2.data_mut()[off + i] += 5.0;
            }
        }
        let pert = enc_step_fwd(&x2, &theta, 1.0, &dm, true);
        for b in 0..dm.batch {
            for t in 0..dm.seq - 1 {
                let off = (b * dm.seq + t) * d;
                for i in 0..d {
                    assert!(
                        (base.data()[off + i] - pert.data()[off + i]).abs() < 1e-5,
                        "future leaked at b={} t={}",
                        b,
                        t
                    );
                }
            }
        }
    }

    /// Central finite-difference check of the full encoder-step VJP.
    #[test]
    fn enc_step_bwd_matches_fd() {
        let dm = RefDims { batch: 1, seq: 3, d_model: 4, n_heads: 2, d_ff: 8 };
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&mut rng, &[1, dm.seq, dm.d_model], 0.7);
        let theta = rng.normal_vec(p_enc(&dm), 0.2);
        let ct = Tensor::randn(&mut rng, &[1, dm.seq, dm.d_model], 1.0);
        let h = 0.7;
        let (dx, dth) = enc_step_bwd(&x, &theta, h, &dm, false, &ct);

        let f_x = |xv: &Tensor| enc_step_fwd(xv, &theta, h, &dm, false).dot(&ct);
        let eps = 2e-3;
        for i in 0..x.len() {
            let mut xp = x.clone();
            xp.data_mut()[i] += eps;
            let mut xm = x.clone();
            xm.data_mut()[i] -= eps;
            let fd = (f_x(&xp) - f_x(&xm)) / (2.0 * eps);
            assert!(
                (dx.data()[i] - fd).abs() < 3e-2 * (1.0 + fd.abs()),
                "dx[{}]={} fd={}",
                i,
                dx.data()[i],
                fd
            );
        }
        // spot-check a spread of parameter coordinates
        let f_t = |tv: &[f32]| enc_step_fwd(&x, tv, h, &dm, false).dot(&ct);
        let stride = (theta.len() / 23).max(1);
        for i in (0..theta.len()).step_by(stride) {
            let mut tp = theta.clone();
            tp[i] += eps;
            let mut tm = theta.clone();
            tm[i] -= eps;
            let fd = (f_t(&tp) - f_t(&tm)) / (2.0 * eps);
            assert!(
                (dth[i] - fd).abs() < 3e-2 * (1.0 + fd.abs()),
                "dtheta[{}]={} fd={}",
                i,
                dth[i],
                fd
            );
        }
    }

    #[test]
    fn dec_step_bwd_matches_fd() {
        let dm = RefDims { batch: 1, seq: 3, d_model: 4, n_heads: 2, d_ff: 8 };
        let seq_enc = 5;
        let mut rng = Rng::new(4);
        let y = Tensor::randn(&mut rng, &[1, dm.seq, dm.d_model], 0.7);
        let xe = Tensor::randn(&mut rng, &[1, seq_enc, dm.d_model], 0.7);
        let theta = rng.normal_vec(p_dec(&dm), 0.2);
        let ct = Tensor::randn(&mut rng, &[1, dm.seq, dm.d_model], 1.0);
        let h = 0.5;
        let (dy, dxe, dth) = dec_step_bwd(&y, &xe, &theta, h, &dm, seq_enc, &ct);

        let eps = 2e-3;
        let f_y = |yv: &Tensor| dec_step_fwd(yv, &xe, &theta, h, &dm, seq_enc).dot(&ct);
        for i in 0..y.len() {
            let mut yp = y.clone();
            yp.data_mut()[i] += eps;
            let mut ym = y.clone();
            ym.data_mut()[i] -= eps;
            let fd = (f_y(&yp) - f_y(&ym)) / (2.0 * eps);
            assert!((dy.data()[i] - fd).abs() < 3e-2 * (1.0 + fd.abs()), "dy[{}]", i);
        }
        let f_e = |ev: &Tensor| dec_step_fwd(&y, ev, &theta, h, &dm, seq_enc).dot(&ct);
        for i in 0..xe.len() {
            let mut ep = xe.clone();
            ep.data_mut()[i] += eps;
            let mut em = xe.clone();
            em.data_mut()[i] -= eps;
            let fd = (f_e(&ep) - f_e(&em)) / (2.0 * eps);
            assert!((dxe.data()[i] - fd).abs() < 3e-2 * (1.0 + fd.abs()), "dxe[{}]", i);
        }
        let f_t = |tv: &[f32]| dec_step_fwd(&y, &xe, tv, h, &dm, seq_enc).dot(&ct);
        let stride = (theta.len() / 19).max(1);
        for i in (0..theta.len()).step_by(stride) {
            let mut tp = theta.clone();
            tp[i] += eps;
            let mut tm = theta.clone();
            tm[i] -= eps;
            let fd = (f_t(&tp) - f_t(&tm)) / (2.0 * eps);
            assert!((dth[i] - fd).abs() < 3e-2 * (1.0 + fd.abs()), "dth[{}]", i);
        }
    }
}
