//! Pure-Rust reference transformer (forward **and** manual backward).
//!
//! Mirrors `python/compile/kernels/ref.py` exactly — same pre-LN neural-ODE
//! step (paper eq. 1-3), same flat parameter layout — so that:
//!
//! 1. every MGRIT/coordinator algorithm in this crate is testable without
//!    Python or artifacts (`cargo test` is self-contained);
//! 2. the PJRT runtime integration test can pin the AOT artifacts against
//!    an independent implementation;
//! 3. analysis tooling (Lipschitz estimation, Appendix B) can evaluate Φ
//!    cheaply at arbitrary widths.
//!
//! The backward pass is hand-derived (no autodiff in Rust) and validated
//! against central finite differences in `tests`.

mod block;
mod kvcache;
mod math;
mod params;
mod scratch;

pub use block::{
    dec_step_bwd, dec_step_bwd_into, dec_step_fwd, dec_step_fwd_cached, dec_step_fwd_into,
    enc_step_bwd, enc_step_bwd_into, enc_step_fwd, enc_step_fwd_cached, enc_step_fwd_into,
    fill_cross_kv, fill_self_kv, RefDims,
};
pub use kvcache::{KvCache, LayerKv};
pub use math::{
    gelu, gelu_grad, gelu_row, layer_norm_bwd, layer_norm_fwd, layer_norm_fwd_into,
    layer_norm_fwd_stats,
};
pub use params::{DecGrads, DecParams, EncGrads, EncParams};
pub use scratch::Scratch;
