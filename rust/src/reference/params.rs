//! Views into the flat per-layer parameter vector.
//!
//! Layout MUST match `ref.enc_layout` / `ref.dec_layout` on the Python side
//! (exported via artifacts/manifest.json and asserted at runtime load):
//!
//! encoder layer: ln1_g[D] ln1_b[D] wq[D,D] wk[D,D] wv[D,D] wo[D,D]
//!                ln2_g[D] ln2_b[D] w1[D,F] b1[F] w2[F,D] b2[D]
//! decoder layer: encoder layout ++ ln3_g[D] ln3_b[D] cq ck cv co [D,D]

/// Borrowed slices over one encoder-family layer's flat parameters.
#[derive(Debug, Clone, Copy)]
pub struct EncParams<'a> {
    pub ln1_g: &'a [f32],
    pub ln1_b: &'a [f32],
    pub wq: &'a [f32],
    pub wk: &'a [f32],
    pub wv: &'a [f32],
    pub wo: &'a [f32],
    pub ln2_g: &'a [f32],
    pub ln2_b: &'a [f32],
    pub w1: &'a [f32],
    pub b1: &'a [f32],
    pub w2: &'a [f32],
    pub b2: &'a [f32],
}

/// Encoder params + the cross-attention block of a decoder layer.
#[derive(Debug, Clone, Copy)]
pub struct DecParams<'a> {
    pub enc: EncParams<'a>,
    pub ln3_g: &'a [f32],
    pub ln3_b: &'a [f32],
    pub cq: &'a [f32],
    pub ck: &'a [f32],
    pub cv: &'a [f32],
    pub co: &'a [f32],
}

/// Field sizes, in layout order, for an encoder layer.
pub fn enc_field_sizes(d: usize, f: usize) -> [usize; 12] {
    [d, d, d * d, d * d, d * d, d * d, d, d, d * f, f, f * d, d]
}

/// Field sizes for the decoder-only tail (ln3 + cross-attention).
pub fn dec_extra_sizes(d: usize) -> [usize; 6] {
    [d, d, d * d, d * d, d * d, d * d]
}

/// Split a flat θ into per-field slices. Fixed-size output (no heap
/// allocation — these views sit on the zero-allocation Φ hot path).
fn split<'a, const N: usize>(theta: &'a [f32], sizes: &[usize; N]) -> [&'a [f32]; N] {
    let mut out: [&'a [f32]; N] = [&[]; N];
    let mut off = 0;
    for (o, &s) in out.iter_mut().zip(sizes.iter()) {
        *o = &theta[off..off + s];
        off += s;
    }
    assert_eq!(off, theta.len(), "parameter vector length mismatch");
    out
}

fn split_mut<'a, const N: usize>(
    theta: &'a mut [f32],
    sizes: &[usize; N],
) -> [&'a mut [f32]; N] {
    let mut out: [&'a mut [f32]; N] = std::array::from_fn(|_| Default::default());
    let mut rest = theta;
    for (o, &s) in out.iter_mut().zip(sizes.iter()) {
        let (head, tail) = rest.split_at_mut(s);
        *o = head;
        rest = tail;
    }
    assert!(rest.is_empty(), "parameter vector length mismatch");
    out
}

impl<'a> EncParams<'a> {
    pub fn view(theta: &'a [f32], d: usize, f: usize) -> EncParams<'a> {
        let [ln1_g, ln1_b, wq, wk, wv, wo, ln2_g, ln2_b, w1, b1, w2, b2] =
            split(theta, &enc_field_sizes(d, f));
        EncParams { ln1_g, ln1_b, wq, wk, wv, wo, ln2_g, ln2_b, w1, b1, w2, b2 }
    }
}

/// Mutable views for gradient accumulation (same layout).
pub struct EncGrads<'a> {
    pub ln1_g: &'a mut [f32],
    pub ln1_b: &'a mut [f32],
    pub wq: &'a mut [f32],
    pub wk: &'a mut [f32],
    pub wv: &'a mut [f32],
    pub wo: &'a mut [f32],
    pub ln2_g: &'a mut [f32],
    pub ln2_b: &'a mut [f32],
    pub w1: &'a mut [f32],
    pub b1: &'a mut [f32],
    pub w2: &'a mut [f32],
    pub b2: &'a mut [f32],
}

impl<'a> EncGrads<'a> {
    pub fn view(theta: &'a mut [f32], d: usize, f: usize) -> EncGrads<'a> {
        let [ln1_g, ln1_b, wq, wk, wv, wo, ln2_g, ln2_b, w1, b1, w2, b2] =
            split_mut(theta, &enc_field_sizes(d, f));
        EncGrads { ln1_g, ln1_b, wq, wk, wv, wo, ln2_g, ln2_b, w1, b1, w2, b2 }
    }
}

impl<'a> DecParams<'a> {
    pub fn view(theta: &'a [f32], d: usize, f: usize) -> DecParams<'a> {
        let enc_len: usize = enc_field_sizes(d, f).iter().sum();
        let enc = EncParams::view(&theta[..enc_len], d, f);
        let [ln3_g, ln3_b, cq, ck, cv, co] = split(&theta[enc_len..], &dec_extra_sizes(d));
        DecParams { enc, ln3_g, ln3_b, cq, ck, cv, co }
    }
}

/// Mutable decoder gradient views.
pub struct DecGrads<'a> {
    pub enc: EncGrads<'a>,
    pub ln3_g: &'a mut [f32],
    pub ln3_b: &'a mut [f32],
    pub cq: &'a mut [f32],
    pub ck: &'a mut [f32],
    pub cv: &'a mut [f32],
    pub co: &'a mut [f32],
}

impl<'a> DecGrads<'a> {
    pub fn view(theta: &'a mut [f32], d: usize, f: usize) -> DecGrads<'a> {
        let enc_len: usize = enc_field_sizes(d, f).iter().sum();
        let (enc_part, rest) = theta.split_at_mut(enc_len);
        let enc = EncGrads::view(enc_part, d, f);
        let [ln3_g, ln3_b, cq, ck, cv, co] = split_mut(rest, &dec_extra_sizes(d));
        DecGrads { enc, ln3_g, ln3_b, cq, ck, cv, co }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enc_view_covers_whole_vector() {
        let (d, f) = (8, 16);
        let len: usize = enc_field_sizes(d, f).iter().sum();
        assert_eq!(len, 4 * d * d + 2 * d * f + 5 * d + f); // config::p_enc formula
        let theta: Vec<f32> = (0..len).map(|i| i as f32).collect();
        let p = EncParams::view(&theta, d, f);
        assert_eq!(p.ln1_g[0], 0.0);
        assert_eq!(p.b2.len(), d);
        assert_eq!(p.b2[d - 1], (len - 1) as f32);
    }

    #[test]
    fn dec_view_extends_enc() {
        let (d, f) = (4, 8);
        let enc_len: usize = enc_field_sizes(d, f).iter().sum();
        let dec_len = enc_len + 2 * d + 4 * d * d;
        let theta: Vec<f32> = (0..dec_len).map(|i| i as f32).collect();
        let p = DecParams::view(&theta, d, f);
        assert_eq!(p.ln3_g[0] as usize, enc_len);
        assert_eq!(p.co.len(), d * d);
        assert_eq!(p.co[d * d - 1] as usize, dec_len - 1);
    }

    #[test]
    #[should_panic]
    fn wrong_length_rejected() {
        let theta = vec![0.0; 10];
        EncParams::view(&theta, 8, 16);
    }
}
