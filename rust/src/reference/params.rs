//! Views into the flat per-layer parameter vector.
//!
//! Layout MUST match `ref.enc_layout` / `ref.dec_layout` on the Python side
//! (exported via artifacts/manifest.json and asserted at runtime load):
//!
//! encoder layer: ln1_g[D] ln1_b[D] wq[D,D] wk[D,D] wv[D,D] wo[D,D]
//!                ln2_g[D] ln2_b[D] w1[D,F] b1[F] w2[F,D] b2[D]
//! decoder layer: encoder layout ++ ln3_g[D] ln3_b[D] cq ck cv co [D,D]

/// Borrowed slices over one encoder-family layer's flat parameters.
#[derive(Debug, Clone, Copy)]
pub struct EncParams<'a> {
    pub ln1_g: &'a [f32],
    pub ln1_b: &'a [f32],
    pub wq: &'a [f32],
    pub wk: &'a [f32],
    pub wv: &'a [f32],
    pub wo: &'a [f32],
    pub ln2_g: &'a [f32],
    pub ln2_b: &'a [f32],
    pub w1: &'a [f32],
    pub b1: &'a [f32],
    pub w2: &'a [f32],
    pub b2: &'a [f32],
}

/// Encoder params + the cross-attention block of a decoder layer.
#[derive(Debug, Clone, Copy)]
pub struct DecParams<'a> {
    pub enc: EncParams<'a>,
    pub ln3_g: &'a [f32],
    pub ln3_b: &'a [f32],
    pub cq: &'a [f32],
    pub ck: &'a [f32],
    pub cv: &'a [f32],
    pub co: &'a [f32],
}

/// Field sizes, in layout order, for an encoder layer.
pub fn enc_field_sizes(d: usize, f: usize) -> [usize; 12] {
    [d, d, d * d, d * d, d * d, d * d, d, d, d * f, f, f * d, d]
}

/// Field sizes for the decoder-only tail (ln3 + cross-attention).
pub fn dec_extra_sizes(d: usize) -> [usize; 6] {
    [d, d, d * d, d * d, d * d, d * d]
}

fn split<'a>(theta: &'a [f32], sizes: &[usize]) -> Vec<&'a [f32]> {
    let mut out = Vec::with_capacity(sizes.len());
    let mut off = 0;
    for &s in sizes {
        out.push(&theta[off..off + s]);
        off += s;
    }
    assert_eq!(off, theta.len(), "parameter vector length mismatch");
    out
}

fn split_mut<'a>(theta: &'a mut [f32], sizes: &[usize]) -> Vec<&'a mut [f32]> {
    let mut out = Vec::with_capacity(sizes.len());
    let mut rest = theta;
    for &s in sizes {
        let (head, tail) = rest.split_at_mut(s);
        out.push(head);
        rest = tail;
    }
    assert!(rest.is_empty(), "parameter vector length mismatch");
    out
}

impl<'a> EncParams<'a> {
    pub fn view(theta: &'a [f32], d: usize, f: usize) -> EncParams<'a> {
        let v = split(theta, &enc_field_sizes(d, f));
        EncParams {
            ln1_g: v[0],
            ln1_b: v[1],
            wq: v[2],
            wk: v[3],
            wv: v[4],
            wo: v[5],
            ln2_g: v[6],
            ln2_b: v[7],
            w1: v[8],
            b1: v[9],
            w2: v[10],
            b2: v[11],
        }
    }
}

/// Mutable views for gradient accumulation (same layout).
pub struct EncGrads<'a> {
    pub ln1_g: &'a mut [f32],
    pub ln1_b: &'a mut [f32],
    pub wq: &'a mut [f32],
    pub wk: &'a mut [f32],
    pub wv: &'a mut [f32],
    pub wo: &'a mut [f32],
    pub ln2_g: &'a mut [f32],
    pub ln2_b: &'a mut [f32],
    pub w1: &'a mut [f32],
    pub b1: &'a mut [f32],
    pub w2: &'a mut [f32],
    pub b2: &'a mut [f32],
}

impl<'a> EncGrads<'a> {
    pub fn view(theta: &'a mut [f32], d: usize, f: usize) -> EncGrads<'a> {
        let mut v = split_mut(theta, &enc_field_sizes(d, f));
        // drain in order to move the mutable borrows out of the Vec
        let mut it = v.drain(..);
        EncGrads {
            ln1_g: it.next().unwrap(),
            ln1_b: it.next().unwrap(),
            wq: it.next().unwrap(),
            wk: it.next().unwrap(),
            wv: it.next().unwrap(),
            wo: it.next().unwrap(),
            ln2_g: it.next().unwrap(),
            ln2_b: it.next().unwrap(),
            w1: it.next().unwrap(),
            b1: it.next().unwrap(),
            w2: it.next().unwrap(),
            b2: it.next().unwrap(),
        }
    }
}

impl<'a> DecParams<'a> {
    pub fn view(theta: &'a [f32], d: usize, f: usize) -> DecParams<'a> {
        let enc_len: usize = enc_field_sizes(d, f).iter().sum();
        let enc = EncParams::view(&theta[..enc_len], d, f);
        let v = split(&theta[enc_len..], &dec_extra_sizes(d));
        DecParams { enc, ln3_g: v[0], ln3_b: v[1], cq: v[2], ck: v[3], cv: v[4], co: v[5] }
    }
}

/// Mutable decoder gradient views.
pub struct DecGrads<'a> {
    pub enc: EncGrads<'a>,
    pub ln3_g: &'a mut [f32],
    pub ln3_b: &'a mut [f32],
    pub cq: &'a mut [f32],
    pub ck: &'a mut [f32],
    pub cv: &'a mut [f32],
    pub co: &'a mut [f32],
}

impl<'a> DecGrads<'a> {
    pub fn view(theta: &'a mut [f32], d: usize, f: usize) -> DecGrads<'a> {
        let enc_len: usize = enc_field_sizes(d, f).iter().sum();
        let (enc_part, rest) = theta.split_at_mut(enc_len);
        let enc = EncGrads::view(enc_part, d, f);
        let mut v = split_mut(rest, &dec_extra_sizes(d));
        let mut it = v.drain(..);
        DecGrads {
            enc,
            ln3_g: it.next().unwrap(),
            ln3_b: it.next().unwrap(),
            cq: it.next().unwrap(),
            ck: it.next().unwrap(),
            cv: it.next().unwrap(),
            co: it.next().unwrap(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enc_view_covers_whole_vector() {
        let (d, f) = (8, 16);
        let len: usize = enc_field_sizes(d, f).iter().sum();
        assert_eq!(len, 4 * d * d + 2 * d * f + 5 * d + f); // config::p_enc formula
        let theta: Vec<f32> = (0..len).map(|i| i as f32).collect();
        let p = EncParams::view(&theta, d, f);
        assert_eq!(p.ln1_g[0], 0.0);
        assert_eq!(p.b2.len(), d);
        assert_eq!(p.b2[d - 1], (len - 1) as f32);
    }

    #[test]
    fn dec_view_extends_enc() {
        let (d, f) = (4, 8);
        let enc_len: usize = enc_field_sizes(d, f).iter().sum();
        let dec_len = enc_len + 2 * d + 4 * d * d;
        let theta: Vec<f32> = (0..dec_len).map(|i| i as f32).collect();
        let p = DecParams::view(&theta, d, f);
        assert_eq!(p.ln3_g[0] as usize, enc_len);
        assert_eq!(p.co.len(), d * d);
        assert_eq!(p.co[d * d - 1] as usize, dec_len - 1);
    }

    #[test]
    #[should_panic]
    fn wrong_length_rejected() {
        let theta = vec![0.0; 10];
        EncParams::view(&theta, 8, 16);
    }
}
