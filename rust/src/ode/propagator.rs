//! The Φ interface MGRIT is generic over.

use std::cell::Cell;

use crate::tensor::Tensor;

/// Φ-evaluation counters (feed the performance simulator and §Perf logs).
#[derive(Debug, Default, Clone)]
pub struct StepCounters {
    fwd: Cell<u64>,
    vjp: Cell<u64>,
}

impl StepCounters {
    pub fn count_fwd(&self) {
        self.fwd.set(self.fwd.get() + 1);
    }

    pub fn count_vjp(&self) {
        self.vjp.set(self.vjp.get() + 1);
    }

    pub fn fwd(&self) -> u64 {
        self.fwd.get()
    }

    pub fn vjp(&self) -> u64 {
        self.vjp.get()
    }

    pub fn reset(&self) {
        self.fwd.set(0);
        self.vjp.set(0);
    }
}

/// One discrete neural-ODE propagator Φ over layers 0..n_steps().
///
/// `layer` is always a *fine-grid* layer index; MGRIT level ℓ calls Φ with
/// `h_scale = c_f^ℓ` (rediscretization: same parameters, larger step), so
/// the effective step is `h_scale · fine_h(layer)`.
pub trait Propagator {
    /// Number of fine time-steps N (layers inside the MGRIT domain).
    fn n_steps(&self) -> usize;

    /// Shape of the evolving state Z (e.g. [B,S,D], or [2,B,S,D] stacked).
    fn state_shape(&self) -> Vec<usize>;

    /// Fine-grid step size h at `layer` (paper: 1, or 1/L_mid with buffers).
    fn fine_h(&self, layer: usize) -> f32;

    /// Z_{n+1} = Φ(Z_n; θ_layer, h_scale · fine_h).
    fn step(&self, layer: usize, h_scale: f32, z: &Tensor) -> Tensor;

    /// Adjoint step: λ_n = (∂Φ/∂Z(Z_n; θ_layer, h_scale·fine_h))ᵀ λ_{n+1}.
    fn adjoint_step(&self, layer: usize, h_scale: f32, z: &Tensor, lam_next: &Tensor) -> Tensor;

    /// Parameter gradient of layer `layer`: ∂(λ_{n+1}ᵀ Φ(Z_n;θ))/∂θ,
    /// accumulated into `grad` (always on the fine grid, h_scale = 1).
    fn accumulate_grad(&self, layer: usize, z: &Tensor, lam_next: &Tensor, grad: &mut [f32]);

    /// Flat parameter length of layer `layer`.
    fn theta_len(&self, layer: usize) -> usize;

    /// Evaluation counters.
    fn counters(&self) -> &StepCounters;
}
