//! The Φ interface MGRIT is generic over (Propagator v2).
//!
//! v2 contract: every propagator is `Send + Sync` so the threaded MGRIT
//! backend can drive relaxation chunks from worker threads against one
//! shared Φ. Evaluation counters are atomics; parameter stores behind the
//! implementations use `Arc<RwLock<..>>` (see [`super::SharedParams`]).

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::reference::KvCache;
use crate::tensor::Tensor;

/// Φ-evaluation counters (feed the performance simulator and §Perf logs).
///
/// Relaxed atomics: counts are statistics, not synchronization — workers
/// bump them concurrently during threaded relaxation. `cached` counts
/// incremental single-position decode steps separately from full-board
/// `fwd` evaluations so tests can pin "no full solve per token".
#[derive(Debug, Default)]
pub struct StepCounters {
    fwd: AtomicU64,
    vjp: AtomicU64,
    cached: AtomicU64,
}

impl Clone for StepCounters {
    fn clone(&self) -> StepCounters {
        StepCounters {
            fwd: AtomicU64::new(self.fwd()),
            vjp: AtomicU64::new(self.vjp()),
            cached: AtomicU64::new(self.cached()),
        }
    }
}

impl StepCounters {
    pub fn count_fwd(&self) {
        self.fwd.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count_vjp(&self) {
        self.vjp.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count_cached(&self) {
        self.cached.fetch_add(1, Ordering::Relaxed);
    }

    pub fn fwd(&self) -> u64 {
        self.fwd.load(Ordering::Relaxed)
    }

    pub fn vjp(&self) -> u64 {
        self.vjp.load(Ordering::Relaxed)
    }

    pub fn cached(&self) -> u64 {
        self.cached.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.fwd.store(0, Ordering::Relaxed);
        self.vjp.store(0, Ordering::Relaxed);
        self.cached.store(0, Ordering::Relaxed);
    }
}

/// Returned by the cached-decode contract when a propagator has no
/// incremental step (the default): callers fall back to the full-board
/// forward path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheUnsupported;

impl fmt::Display for CacheUnsupported {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "propagator does not support incremental (KV-cached) decode")
    }
}

impl std::error::Error for CacheUnsupported {}

/// One discrete neural-ODE propagator Φ over layers 0..n_steps().
///
/// `layer` is always a *fine-grid* layer index; MGRIT level ℓ calls Φ with
/// `h_scale = c_f^ℓ` (rediscretization: same parameters, larger step), so
/// the effective step is `h_scale · fine_h(layer)`.
///
/// `Send + Sync` is part of the contract: the `ThreadedMgrit` backend
/// shares one propagator across relaxation workers.
pub trait Propagator: Send + Sync {
    /// Number of fine time-steps N (layers inside the MGRIT domain).
    fn n_steps(&self) -> usize;

    /// Shape of the evolving state Z (e.g. [B,S,D], or [2,B,S,D] stacked).
    fn state_shape(&self) -> Vec<usize>;

    /// Fine-grid step size h at `layer` (paper: 1, or 1/L_mid with buffers).
    fn fine_h(&self, layer: usize) -> f32;

    /// Z_{n+1} = Φ(Z_n; θ_layer, h_scale · fine_h).
    fn step(&self, layer: usize, h_scale: f32, z: &Tensor) -> Tensor;

    /// Buffer-reusing step: write Φ(Z_n) into `out`, which must be
    /// state-shaped and is **fully overwritten** (no need to zero it).
    /// The default delegates to [`Propagator::step`], so implementations
    /// are semantically untouched; `RustPropagator` overrides this with a
    /// zero-allocation path and the MGRIT relaxation sweeps call it to
    /// update grid points in place.
    fn step_into(&self, layer: usize, h_scale: f32, z: &Tensor, out: &mut Tensor) {
        *out = self.step(layer, h_scale, z);
    }

    /// Batched propagation over consecutive layers `[layer_lo, layer_hi)`:
    /// returns the state after each step (`layer_hi − layer_lo` tensors,
    /// the last being Z_{layer_hi}). Implementations override this to
    /// amortize per-call dispatch (parameter-lock acquisition, executable
    /// lookup) across a whole chunk — the serial buffer sweeps, evaluation
    /// forwards, and relaxation chunks all step consecutive layers.
    fn step_range(
        &self,
        layer_lo: usize,
        layer_hi: usize,
        h_scale: f32,
        z: &Tensor,
    ) -> Vec<Tensor> {
        let mut out: Vec<Tensor> = Vec::with_capacity(layer_hi.saturating_sub(layer_lo));
        for layer in layer_lo..layer_hi {
            let next = self.step(layer, h_scale, out.last().unwrap_or(z));
            out.push(next);
        }
        out
    }

    /// Like [`Propagator::step_range`] but returns only the final state
    /// Z_{layer_hi} — the rolling-state variant for full forwards where
    /// intermediates are not needed (evaluation): O(1) state memory.
    fn step_to(&self, layer_lo: usize, layer_hi: usize, h_scale: f32, z: &Tensor) -> Tensor {
        let mut cur = z.clone();
        for layer in layer_lo..layer_hi {
            cur = self.step(layer, h_scale, &cur);
        }
        cur
    }

    /// Buffer-reusing rolling forward: `cur` holds Z_{layer_lo} on entry
    /// and Z_{layer_hi} on return; `scratch` is a second state-shaped
    /// ping-pong buffer (contents unspecified afterwards). Zero
    /// allocations when [`Propagator::step_into`] is; evaluation sweeps
    /// route through this with two persistent workspace tensors.
    fn step_to_into(
        &self,
        layer_lo: usize,
        layer_hi: usize,
        h_scale: f32,
        cur: &mut Tensor,
        scratch: &mut Tensor,
    ) {
        for layer in layer_lo..layer_hi {
            self.step_into(layer, h_scale, cur, scratch);
            std::mem::swap(cur, scratch);
        }
    }

    /// Buffer-reusing batched propagation over consecutive layers:
    /// `states[0]` holds Z_{layer_lo} on entry; on return `states[i]`
    /// holds Z_{layer_lo+i}, i.e. the sweep advances `states.len() − 1`
    /// layers keeping every intermediate. The in-place counterpart of
    /// [`Propagator::step_range`]: implementations amortize per-call
    /// dispatch (parameter lock, executable lookup) across the sweep
    /// without its allocations — the session's serial buffer-layer sweeps
    /// run through this on persistent workspace tensors.
    fn step_seq_into(&self, layer_lo: usize, h_scale: f32, states: &mut [Tensor]) {
        for i in 1..states.len() {
            let (head, tail) = states.split_at_mut(i);
            self.step_into(layer_lo + i - 1, h_scale, &head[i - 1], &mut tail[0]);
        }
    }

    /// Adjoint step: λ_n = (∂Φ/∂Z(Z_n; θ_layer, h_scale·fine_h))ᵀ λ_{n+1}.
    fn adjoint_step(&self, layer: usize, h_scale: f32, z: &Tensor, lam_next: &Tensor) -> Tensor;

    /// Buffer-reusing adjoint step; `out` must be state-shaped and is
    /// fully overwritten. Default delegates to
    /// [`Propagator::adjoint_step`].
    fn adjoint_step_into(
        &self,
        layer: usize,
        h_scale: f32,
        z: &Tensor,
        lam_next: &Tensor,
        out: &mut Tensor,
    ) {
        *out = self.adjoint_step(layer, h_scale, z, lam_next);
    }

    /// Parameter gradient of layer `layer`: ∂(λ_{n+1}ᵀ Φ(Z_n;θ))/∂θ,
    /// accumulated into `grad` (always on the fine grid, h_scale = 1).
    fn accumulate_grad(&self, layer: usize, z: &Tensor, lam_next: &Tensor, grad: &mut [f32]);

    /// Flat parameter length of layer `layer`.
    fn theta_len(&self, layer: usize) -> usize;

    // --- incremental (KV-cached) decode contract -----------------------
    //
    // Optional: the default implementations advertise no support
    // (`make_cache` → None, the steps → Err(CacheUnsupported)), so
    // `XlaPropagator` / `LinearOde` are untouched. `RustPropagator`
    // overrides the whole family with a pooled-scratch zero-allocation
    // path; `RangeProp` forwards with its layer offset.

    /// Allocate a K/V cache sized for this propagator's decode path, or
    /// `None` when incremental decode is unsupported (e.g. bidirectional
    /// encoders, whose rows are not causal).
    fn make_cache(&self) -> Option<KvCache> {
        None
    }

    /// One cached Φ step at `layer`: `cur`/`out` hold the `[B, 1, d]`
    /// newest-position rows (decoder half only for stacked models),
    /// `positions[b]` is the board position being advanced. Appends the
    /// layer's K/V column for the new position and fully overwrites
    /// `out`. Bitwise identical to the same row of a full-board
    /// [`Propagator::step_into`] given a cache populated from the same
    /// history.
    fn step_cached(
        &self,
        layer: usize,
        cache: &mut KvCache,
        positions: &[usize],
        cur: &Tensor,
        out: &mut Tensor,
    ) -> Result<(), CacheUnsupported> {
        let _ = (layer, cache, positions, cur, out);
        Err(CacheUnsupported)
    }

    /// Cached rolling sweep over `[layer_lo, layer_hi)`: `cur` holds the
    /// newest-position rows entering `layer_lo` and, on success, the rows
    /// after `layer_hi`; `scratch` is a ping-pong buffer (contents
    /// unspecified afterwards). Implementations amortize per-call
    /// dispatch (parameter lock) across the sweep.
    fn step_to_cached(
        &self,
        layer_lo: usize,
        layer_hi: usize,
        cache: &mut KvCache,
        positions: &[usize],
        cur: &mut Tensor,
        scratch: &mut Tensor,
    ) -> Result<(), CacheUnsupported> {
        for layer in layer_lo..layer_hi {
            self.step_cached(layer, cache, positions, cur, scratch)?;
            std::mem::swap(cur, scratch);
        }
        Ok(())
    }

    /// Prefill: project layer `layer`'s K/V columns
    /// `cache.len(b)..=positions[b]` (per row) out of the full-board
    /// layer-input state `z` — called once per layer after an exact full
    /// forward, followed by one `cache.commit(positions)`. Layers outside
    /// the cached range are a no-op.
    fn fill_cached(
        &self,
        layer: usize,
        cache: &mut KvCache,
        z: &Tensor,
        positions: &[usize],
    ) -> Result<(), CacheUnsupported> {
        let _ = (layer, cache, z, positions);
        Err(CacheUnsupported)
    }

    /// Evaluation counters.
    fn counters(&self) -> &StepCounters;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_are_thread_safe() {
        let c = StepCounters::default();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..100 {
                        c.count_fwd();
                        c.count_vjp();
                    }
                });
            }
        });
        assert_eq!(c.fwd(), 400);
        assert_eq!(c.vjp(), 400);
        c.reset();
        assert_eq!(c.fwd(), 0);
    }

    #[test]
    fn clone_snapshots_counts() {
        let c = StepCounters::default();
        c.count_fwd();
        c.count_cached();
        let d = c.clone();
        c.count_fwd();
        c.count_cached();
        assert_eq!(d.fwd(), 1);
        assert_eq!(d.cached(), 1);
        assert_eq!(c.fwd(), 2);
        assert_eq!(c.cached(), 2);
        c.reset();
        assert_eq!(c.cached(), 0);
    }
}
