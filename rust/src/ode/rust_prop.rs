//! Pure-Rust propagator: the reference transformer as a Φ.
//!
//! Used by unit/property tests (no artifacts needed), by the analysis
//! tooling, and as a fallback engine. Mirrors the stacked encoder-decoder
//! state handling of [`super::XlaPropagator`] exactly.

use std::sync::{Arc, RwLock};

use super::propagator::{Propagator, StepCounters};
use crate::config::{Arch, ModelConfig};
use crate::reference::{self, RefDims};
use crate::tensor::Tensor;

/// Shared per-layer flat parameters (the trainer mutates through this Arc).
///
/// v2: `Arc<RwLock<..>>` instead of `Rc<RefCell<..>>` so propagators are
/// `Send + Sync` and the threaded MGRIT backend can evaluate Φ from worker
/// threads. The training loop takes the write lock only inside the
/// optimizer update; all solves hold read locks.
pub type SharedParams = Arc<RwLock<Vec<Vec<f32>>>>;

/// Build a [`SharedParams`] from per-layer flat vectors.
pub fn shared_params(layers: Vec<Vec<f32>>) -> SharedParams {
    Arc::new(RwLock::new(layers))
}

/// Reference-transformer propagator over the MGRIT domain.
pub struct RustPropagator {
    dims: RefDims,
    arch: Arch,
    n_enc: usize,
    n_steps: usize,
    /// per-layer fine step sizes (buffer layers get Δt=1, Appendix B)
    hs: Vec<f32>,
    params: SharedParams,
    counters: StepCounters,
}

/// Per-layer fine h: buffer layers Δt=1, ParallelNet layers Δt=fine_h()
/// (paper Appendix B).
pub fn layer_hs(model: &ModelConfig, n_layers: usize) -> Vec<f32> {
    let h_mid = model.fine_h();
    (0..n_layers)
        .map(|l| {
            if l < model.buffer_open || l >= n_layers.saturating_sub(model.buffer_close) {
                1.0
            } else {
                h_mid
            }
        })
        .collect()
}

impl RustPropagator {
    /// `params[l]` is layer l's flat θ (enc layout, or dec layout past
    /// n_enc); uniform fine step `h` across all layers.
    pub fn new(model: &ModelConfig, h: f32, params: SharedParams) -> RustPropagator {
        let n = params.read().unwrap().len();
        Self::with_hs(model, vec![h; n], params)
    }

    /// Buffer-aware constructor: Δt per layer from [`layer_hs`].
    pub fn for_model(model: &ModelConfig, params: SharedParams) -> RustPropagator {
        let n = params.read().unwrap().len();
        Self::with_hs(model, layer_hs(model, n), params)
    }

    pub fn with_hs(model: &ModelConfig, hs: Vec<f32>, params: SharedParams) -> RustPropagator {
        let n_steps = params.read().unwrap().len();
        assert_eq!(hs.len(), n_steps);
        RustPropagator {
            dims: RefDims {
                batch: model.batch,
                seq: model.seq,
                d_model: model.d_model,
                n_heads: model.n_heads,
                d_ff: model.d_ff,
            },
            arch: model.arch,
            n_enc: if model.arch == Arch::EncDec { model.n_enc_layers } else { 0 },
            n_steps,
            hs,
            params,
            counters: StepCounters::default(),
        }
    }

    fn split_state<'a>(&self, z: &'a Tensor) -> (Tensor, Tensor, &'a [usize]) {
        // stacked [2,B,S,D] -> (X, Y)
        let half = z.len() / 2;
        let inner = [self.dims.batch, self.dims.seq, self.dims.d_model];
        let x = Tensor::from_vec(z.data()[..half].to_vec(), &inner);
        let y = Tensor::from_vec(z.data()[half..].to_vec(), &inner);
        (x, y, z.shape())
    }

    fn join_state(&self, x: &Tensor, y: &Tensor, shape: &[usize]) -> Tensor {
        let mut data = Vec::with_capacity(x.len() * 2);
        data.extend_from_slice(x.data());
        data.extend_from_slice(y.data());
        Tensor::from_vec(data, shape)
    }

    /// One Φ application with the parameter lock already resolved to θ.
    fn apply_theta(&self, layer: usize, theta: &[f32], h: f32, z: &Tensor) -> Tensor {
        match self.arch {
            Arch::Encoder => reference::enc_step_fwd(z, theta, h, &self.dims, false),
            Arch::Decoder => reference::enc_step_fwd(z, theta, h, &self.dims, true),
            Arch::EncDec => {
                let (x, y, shape) = self.split_state(z);
                if layer < self.n_enc {
                    let x2 = reference::enc_step_fwd(&x, theta, h, &self.dims, false);
                    self.join_state(&x2, &y, shape)
                } else {
                    let y2 = reference::dec_step_fwd(&y, &x, theta, h, &self.dims, self.dims.seq);
                    self.join_state(&x, &y2, shape)
                }
            }
        }
    }
}

impl Propagator for RustPropagator {
    fn n_steps(&self) -> usize {
        self.n_steps
    }

    fn state_shape(&self) -> Vec<usize> {
        let base = vec![self.dims.batch, self.dims.seq, self.dims.d_model];
        match self.arch {
            Arch::EncDec => {
                let mut s = vec![2];
                s.extend(base);
                s
            }
            _ => base,
        }
    }

    fn fine_h(&self, layer: usize) -> f32 {
        self.hs[layer]
    }

    fn step(&self, layer: usize, h_scale: f32, z: &Tensor) -> Tensor {
        self.counters.count_fwd();
        let h = self.hs[layer] * h_scale;
        let params = self.params.read().unwrap();
        self.apply_theta(layer, &params[layer], h, z)
    }

    /// Batched steps under a single read-lock acquisition (the v2
    /// dispatch-amortization entry point).
    fn step_range(&self, layer_lo: usize, layer_hi: usize, h_scale: f32, z: &Tensor) -> Vec<Tensor> {
        let params = self.params.read().unwrap();
        let mut out: Vec<Tensor> = Vec::with_capacity(layer_hi.saturating_sub(layer_lo));
        for layer in layer_lo..layer_hi {
            self.counters.count_fwd();
            let h = self.hs[layer] * h_scale;
            let next = self.apply_theta(layer, &params[layer], h, out.last().unwrap_or(z));
            out.push(next);
        }
        out
    }

    /// Rolling full forward under a single read-lock acquisition.
    fn step_to(&self, layer_lo: usize, layer_hi: usize, h_scale: f32, z: &Tensor) -> Tensor {
        let params = self.params.read().unwrap();
        let mut cur = z.clone();
        for layer in layer_lo..layer_hi {
            self.counters.count_fwd();
            let h = self.hs[layer] * h_scale;
            cur = self.apply_theta(layer, &params[layer], h, &cur);
        }
        cur
    }

    fn adjoint_step(&self, layer: usize, h_scale: f32, z: &Tensor, lam_next: &Tensor) -> Tensor {
        self.counters.count_vjp();
        let h = self.hs[layer] * h_scale;
        let params = self.params.read().unwrap();
        let theta = &params[layer];
        match self.arch {
            Arch::Encoder => reference::enc_step_bwd(z, theta, h, &self.dims, false, lam_next).0,
            Arch::Decoder => reference::enc_step_bwd(z, theta, h, &self.dims, true, lam_next).0,
            Arch::EncDec => {
                let (x, y, shape) = self.split_state(z);
                let (lx, ly, _) = self.split_state(lam_next);
                if layer < self.n_enc {
                    // X evolves: λx back through enc step; λy passes through
                    let (lx2, _) = reference::enc_step_bwd(&x, theta, h, &self.dims, false, &lx);
                    self.join_state(&lx2, &ly, shape)
                } else {
                    // Y evolves: λy back through dec step; λx += ∂dec/∂X_enc
                    let (ly2, lxe, _) =
                        reference::dec_step_bwd(&y, &x, theta, h, &self.dims, self.dims.seq, &ly);
                    let mut lx2 = lx;
                    lx2.axpy(1.0, &lxe);
                    self.join_state(&lx2, &ly2, shape)
                }
            }
        }
    }

    fn accumulate_grad(&self, layer: usize, z: &Tensor, lam_next: &Tensor, grad: &mut [f32]) {
        self.counters.count_vjp();
        let h = self.hs[layer];
        let params = self.params.read().unwrap();
        let theta = &params[layer];
        let g = match self.arch {
            Arch::Encoder => reference::enc_step_bwd(z, theta, h, &self.dims, false, lam_next).1,
            Arch::Decoder => reference::enc_step_bwd(z, theta, h, &self.dims, true, lam_next).1,
            Arch::EncDec => {
                let (x, y, _) = self.split_state(z);
                let (lx, ly, _) = self.split_state(lam_next);
                if layer < self.n_enc {
                    reference::enc_step_bwd(&x, theta, h, &self.dims, false, &lx).1
                } else {
                    reference::dec_step_bwd(&y, &x, theta, h, &self.dims, self.dims.seq, &ly).2
                }
            }
        };
        assert_eq!(g.len(), grad.len(), "grad length mismatch at layer {}", layer);
        for (a, b) in grad.iter_mut().zip(&g) {
            *a += b;
        }
    }

    fn theta_len(&self, layer: usize) -> usize {
        self.params.read().unwrap()[layer].len()
    }

    fn counters(&self) -> &StepCounters {
        &self.counters
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_model(arch: Arch) -> ModelConfig {
        ModelConfig {
            arch,
            vocab: 8,
            d_model: 8,
            n_heads: 2,
            d_ff: 16,
            seq: 4,
            batch: 1,
            n_classes: 2,
            n_enc_layers: if arch == Arch::EncDec { 2 } else { 4 },
            n_dec_layers: if arch == Arch::EncDec { 2 } else { 0 },
            buffer_open: 0,
            buffer_close: 0,
        }
    }

    pub fn make_params(model: &ModelConfig, rng: &mut Rng, std: f32) -> SharedParams {
        let mut v = Vec::new();
        for l in 0..model.total_layers() {
            let len = if model.arch == Arch::EncDec && l >= model.n_enc_layers {
                model.p_dec()
            } else {
                model.p_enc()
            };
            v.push(rng.normal_vec(len, std));
        }
        shared_params(v)
    }

    #[test]
    fn encoder_step_shape_preserved() {
        let model = tiny_model(Arch::Encoder);
        let mut rng = Rng::new(0);
        let params = make_params(&model, &mut rng, 0.1);
        let prop = RustPropagator::new(&model, 1.0, params);
        let z = Tensor::randn(&mut rng, &prop.state_shape(), 1.0);
        let z2 = prop.step(0, 1.0, &z);
        assert_eq!(z2.shape(), z.shape());
    }

    #[test]
    fn encdec_encoder_phase_keeps_y_fixed() {
        let model = tiny_model(Arch::EncDec);
        let mut rng = Rng::new(1);
        let params = make_params(&model, &mut rng, 0.1);
        let prop = RustPropagator::new(&model, 1.0, params);
        let z = Tensor::randn(&mut rng, &prop.state_shape(), 1.0);
        let z2 = prop.step(0, 1.0, &z); // encoder phase
        let half = z.len() / 2;
        assert_eq!(&z2.data()[half..], &z.data()[half..], "Y must not move");
        assert_ne!(&z2.data()[..half], &z.data()[..half], "X must move");
        let z3 = prop.step(2, 1.0, &z); // decoder phase (n_enc = 2)
        assert_eq!(&z3.data()[..half], &z.data()[..half], "X must not move");
        assert_ne!(&z3.data()[half..], &z.data()[half..], "Y must move");
    }

    #[test]
    fn step_range_matches_repeated_steps_bitwise() {
        let model = tiny_model(Arch::Encoder);
        let mut rng = Rng::new(5);
        let params = make_params(&model, &mut rng, 0.1);
        let prop = RustPropagator::new(&model, 1.0, params);
        let z = Tensor::randn(&mut rng, &prop.state_shape(), 1.0);
        let batched = prop.step_range(0, 4, 1.0, &z);
        assert_eq!(batched.len(), 4);
        let mut cur = z.clone();
        for (l, b) in batched.iter().enumerate() {
            cur = prop.step(l, 1.0, &cur);
            assert_eq!(cur.data(), b.data(), "layer {}", l);
        }
        // the rolling variant lands on the same final state
        let rolled = prop.step_to(0, 4, 1.0, &z);
        assert_eq!(rolled.data(), batched.last().unwrap().data());
    }

    #[test]
    fn propagator_is_shareable_across_threads() {
        // the v2 contract: &RustPropagator can be used from worker threads
        let model = tiny_model(Arch::Encoder);
        let mut rng = Rng::new(6);
        let params = make_params(&model, &mut rng, 0.1);
        let prop = RustPropagator::new(&model, 1.0, params);
        let z = Tensor::randn(&mut rng, &prop.state_shape(), 1.0);
        let want = prop.step(0, 1.0, &z);
        let outs: Vec<Tensor> = std::thread::scope(|s| {
            (0..4)
                .map(|_| s.spawn(|| prop.step(0, 1.0, &z)))
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        for o in outs {
            assert_eq!(o.data(), want.data());
        }
    }

    #[test]
    fn adjoint_consistent_with_fd_dot_product() {
        // <Φ(z+εu) - Φ(z), v> ≈ ε <u, Φ'ᵀ v>
        let model = tiny_model(Arch::EncDec);
        let mut rng = Rng::new(2);
        let params = make_params(&model, &mut rng, 0.1);
        let prop = RustPropagator::new(&model, 1.0, params);
        for layer in [0usize, 2] {
            let z = Tensor::randn(&mut rng, &prop.state_shape(), 0.7);
            let u = Tensor::randn(&mut rng, &prop.state_shape(), 1.0);
            let v = Tensor::randn(&mut rng, &prop.state_shape(), 1.0);
            let eps = 1e-3;
            let mut zp = z.clone();
            zp.axpy(eps, &u);
            let mut zm = z.clone();
            zm.axpy(-eps, &u);
            let fd = (prop.step(layer, 1.0, &zp).dot(&v) - prop.step(layer, 1.0, &zm).dot(&v))
                / (2.0 * eps);
            let adj = prop.adjoint_step(layer, 1.0, &z, &v);
            let want = u.dot(&adj);
            assert!(
                (fd - want).abs() < 2e-2 * (1.0 + want.abs()),
                "layer {}: fd={} adj={}",
                layer,
                fd,
                want
            );
        }
    }
}
